"""Launch-spec sharding rules (every arch) + whitening baseline."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.core.whitening import newton_schulz_inv_sqrt, wmse_loss, zca_whiten


class TestWhiteningBaseline:
    def test_newton_schulz_inverse_sqrt(self):
        key = jax.random.PRNGKey(0)
        a = jax.random.normal(key, (16, 16))
        spd = a @ a.T + 0.5 * jnp.eye(16)
        inv_sqrt = newton_schulz_inv_sqrt(spd, iters=15)
        should_be_eye = inv_sqrt @ spd @ inv_sqrt
        np.testing.assert_allclose(should_be_eye, jnp.eye(16), atol=5e-2)

    def test_zca_whitening_gives_identity_covariance(self):
        z = jax.random.normal(jax.random.PRNGKey(1), (512, 12)) * jnp.asarray(
            [1.0, 5.0, 0.5] * 4
        )
        w = zca_whiten(z, iters=15)
        cov = (w.T @ w) / 511
        np.testing.assert_allclose(cov, jnp.eye(12), atol=0.1)

    def test_wmse_loss_runs_and_differentiates(self):
        k1, k2 = jax.random.split(jax.random.PRNGKey(2))
        z1 = jax.random.normal(k1, (64, 16))
        z2 = z1 + 0.1 * jax.random.normal(k2, (64, 16))
        loss, _ = wmse_loss(z1, z2)
        assert 0.0 <= float(loss) <= 4.0
        g = jax.grad(lambda a: wmse_loss(a, z2)[0])(z1)
        assert bool(jnp.all(jnp.isfinite(g)))


class TestParamShardingSpecs:
    """Every arch's parameter tree must produce shardings that (a) divide
    the dims they shard, (b) shard every large matrix on at least one axis
    (no accidentally-replicated 100GB weights)."""

    @pytest.mark.parametrize("arch", list_archs())
    def test_specs_divisible_and_large_leaves_sharded(self, arch):
        from repro.launch import specs as S

        cfg = get_config(arch)
        shapes = jax.eval_shape(
            lambda: __import__("repro.models.transformer", fromlist=["init_params"]).init_params(
                jax.random.PRNGKey(0), cfg
            )
        )

        class FakeMesh:
            shape = {"data": 16, "model": 16}
            axis_names = ("data", "model")

        mesh = FakeMesh()
        leaves = jax.tree_util.tree_flatten_with_path(shapes)[0]
        for path, leaf in leaves:
            spec = S.param_spec(path, leaf)
            if not S._divisible(leaf.shape, spec, mesh):
                spec = None  # falls back to replication in param_sharding
            n_elems = int(np.prod(leaf.shape))
            if n_elems * 2 > 1e9:  # >1GB bf16 must be sharded
                assert spec is not None and any(
                    s is not None for s in spec
                ), f"{arch}: large leaf {jax.tree_util.keystr(path)} {leaf.shape} replicated"

    def test_batch_spec_falls_back_when_indivisible(self):
        from repro.launch import specs as S

        # batch=1 (long_500k) cannot shard over 32 ways -> replicated
        class M:
            shape = {"pod": 2, "data": 16, "model": 16}
            axis_names = ("pod", "data", "model")

        # use the real helper through a real mesh is heavy; check helper math
        assert S.SHAPES["long_500k"].global_batch == 1


class TestCellApplicability:
    def test_long_context_only_for_ssm_hybrid(self):
        from repro.launch import specs as S

        for arch in list_archs():
            ok, why = S.cell_applicable(get_config(arch), S.SHAPES["long_500k"])
            if arch in ("rwkv6-3b", "jamba-v0.1-52b"):
                assert ok
            else:
                assert not ok and "quadratic" in why

    def test_all_other_shapes_applicable_everywhere(self):
        from repro.launch import specs as S

        for arch in list_archs():
            for shape in ("train_4k", "prefill_32k", "decode_32k"):
                ok, _ = S.cell_applicable(get_config(arch), S.SHAPES[shape])
                assert ok


class TestChunkedRWKVOracle:
    """The chunked recurrence (shipped default) must match the sequential
    scan — including an adversarial strong-decay regime."""

    def test_matches_sequential(self):
        import dataclasses

        from repro.models import forward, init_params

        cfg_chunk = get_config("rwkv6-3b").reduced()  # inherits rwkv_chunk=64
        cfg_seq = dataclasses.replace(cfg_chunk, rwkv_chunk=None)
        params = init_params(jax.random.PRNGKey(0), cfg_seq)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 128), 0, cfg_seq.vocab_size)
        a = forward(params, cfg_seq, tokens=tokens)
        b = forward(params, cfg_chunk, tokens=tokens)
        rel = float(jnp.max(jnp.abs(a.logits - b.logits))) / float(jnp.max(jnp.abs(a.logits)))
        assert rel < 1e-4, rel

    def test_strong_decay_regime(self):
        import dataclasses

        from repro.models import forward, init_params

        cfg_seq = dataclasses.replace(get_config("rwkv6-3b").reduced(), rwkv_chunk=None)
        cfg_chunk = dataclasses.replace(cfg_seq, rwkv_chunk=8)
        params = init_params(jax.random.PRNGKey(0), cfg_seq)
        params["blocks"]["pos0"]["rwkv"]["decay_base"] = jnp.full_like(
            params["blocks"]["pos0"]["rwkv"]["decay_base"], 1.5
        )
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg_seq.vocab_size)
        a = forward(params, cfg_seq, tokens=tokens)
        b = forward(params, cfg_chunk, tokens=tokens)
        rel = float(jnp.max(jnp.abs(a.logits - b.logits))) / float(jnp.max(jnp.abs(a.logits)))
        assert rel < 1e-3, rel


class TestGroupedMoEOracle:
    def test_matches_ungrouped_with_ample_capacity(self):
        import dataclasses

        from repro.models import forward, init_params

        cfg = dataclasses.replace(
            get_config("llama4-scout-17b-a16e").reduced(), capacity_factor=8.0, moe_group_size=None
        )
        cfg_g = dataclasses.replace(cfg, moe_group_size=16)
        params = init_params(jax.random.PRNGKey(0), cfg)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)
        a = forward(params, cfg, tokens=tokens)
        b = forward(params, cfg_g, tokens=tokens)
        rel = float(jnp.max(jnp.abs(a.logits - b.logits))) / float(jnp.max(jnp.abs(a.logits)))
        assert rel < 1e-3, rel
