"""Regularizer equivalences the paper states (and the Gram-trick baseline)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import losses as L
from repro.core import regularizers as regs


def _views(n=16, d=24, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return jax.random.normal(k1, (n, d)), jax.random.normal(k2, (n, d))


class TestROff:
    def test_matches_manual(self):
        z1, z2 = _views()
        c = regs.cross_correlation_matrix(z1, z2, scale=16)
        manual = sum(
            float(c[i, j]) ** 2 for i in range(24) for j in range(24) if i != j
        )
        np.testing.assert_allclose(regs.r_off(c), manual, rtol=1e-4)

    def test_gram_trick_matches(self):
        from repro.kernels.xcorr_offdiag.ops import r_off_gram

        z1, z2 = _views()
        c = regs.cross_correlation_matrix(z1, z2, scale=16)
        np.testing.assert_allclose(
            r_off_gram(z1, z2, scale=16.0), regs.r_off(c), rtol=1e-4
        )

    def test_gram_trick_gradients_match(self):
        from repro.kernels.xcorr_offdiag.ops import off_diagonal_sq_sum

        z1, z2 = _views(n=8, d=12)
        f_ref = lambda a, b: regs.r_off(regs.cross_correlation_matrix(a, b, scale=8))
        f_kern = lambda a, b: off_diagonal_sq_sum(a, b, scale=8.0)
        g_ref = jax.grad(f_ref, argnums=(0, 1))(z1, z2)
        g_kern = jax.grad(f_kern, argnums=(0, 1))(z1, z2)
        np.testing.assert_allclose(g_kern[0], g_ref[0], atol=1e-4)
        np.testing.assert_allclose(g_kern[1], g_ref[1], atol=1e-4)


class TestRSum:
    @pytest.mark.parametrize("q", [1, 2])
    def test_matches_matrix_oracle(self, q):
        z1, z2 = _views()
        c = regs.cross_correlation_matrix(z1, z2, scale=16)
        np.testing.assert_allclose(
            regs.r_sum(z1, z2, q=q, scale=16.0), regs.r_sum_from_matrix(c, q), rtol=1e-3
        )

    def test_b_equals_d_recovers_ungrouped(self):
        z1, z2 = _views()
        a = regs.r_sum_auto(z1, z2, q=2, block_size=24, scale=16.0)
        b = regs.r_sum(z1, z2, q=2, scale=16.0)
        np.testing.assert_allclose(a, b, rtol=1e-5)

    def test_b1_q2_recovers_r_off(self):
        # paper §4.4: R_sum^(1) == R_off when q=2
        z1, z2 = _views()
        c = regs.cross_correlation_matrix(z1, z2, scale=16)
        a = regs.r_sum_auto(z1, z2, q=2, block_size=1, scale=16.0)
        np.testing.assert_allclose(a, regs.r_off(c), rtol=1e-5)

    @pytest.mark.parametrize("b,q", [(4, 1), (4, 2), (8, 1), (8, 2), (7, 2)])
    def test_grouped_matches_matrix_oracle(self, b, q):
        z1, z2 = _views()
        c = regs.cross_correlation_matrix(z1, z2, scale=16)
        got = regs.r_sum_grouped(z1, z2, b, q=q, scale=16.0)
        want = regs.r_sum_grouped_from_matrix(c, b, q=q)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)

    def test_relaxation_bound(self):
        # R_sum is a relaxation: minimizers of R_off also minimize R_sum;
        # for C with zero off-diagonals, R_sum(C) == 0
        n, d = 16, 12
        z = jax.random.normal(jax.random.PRNGKey(0), (n, d))
        z1 = L.standardize(z)
        r = regs.r_sum(z1, z1, q=2, scale=float(n))
        # C(A,A) of standardized data has unit diagonal; sumvec tail sums
        # off-diagonals only — finite and >= 0
        assert float(r) >= 0.0

    def test_zero_offdiag_implies_zero_r_sum(self):
        # minimizers of R_off also minimize R_sum (paper §4.1): construct
        # views whose cross-correlation is exactly diagonal
        n, d = 64, 8
        z = jax.random.normal(jax.random.PRNGKey(0), (n, d))
        q, _ = jnp.linalg.qr(z)  # orthonormal columns -> C(A,A) diagonal
        z1 = q * jnp.sqrt(n)
        r = float(regs.r_sum(z1, z1, q=2, scale=float(n)))
        # fp tolerance relative to the d^2-scale Parseval terms that cancel
        assert abs(r) < 1e-5 * d * d


class TestRVar:
    def test_zero_when_std_above_gamma(self):
        z = 10.0 * jax.random.normal(jax.random.PRNGKey(0), (256, 8))
        assert float(regs.r_var_from_embeddings(z, gamma=1.0)) < 1e-3

    def test_positive_for_collapsed(self):
        z = jnp.zeros((64, 8))
        v = float(regs.r_var_from_embeddings(z, gamma=1.0))
        assert v > 7.5  # ~ d * gamma
