"""``repro.ft.elastic`` — elastic re-mesh round trips.

A checkpoint written under one mesh geometry must restore onto any other:
``reshard_to_mesh`` rebuilds shardings for the new mesh from the same
logical rules and falls back to replication when a leaf no longer divides.
Single-device semantics run in-process; the grow (2 -> 4 hosts) and shrink
(4 -> 2) round trips run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (same pattern as
test_serve_system) so the main pytest process keeps one CPU device.
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.checkpoint.checkpointer import save_checkpoint
from repro.ft.elastic import _divisible, elastic_restore, reshard_to_mesh

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mesh1():
    return Mesh(np.array(jax.devices()[:1]), ("data",))


class TestReshardSemantics:
    def test_values_preserved_and_replicated_fallbacks(self):
        mesh = _mesh1()
        state = {
            "w": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": np.arange(5, dtype=np.float32),
        }
        # spec None -> replication; a spec that does not divide -> replication
        out = reshard_to_mesh(
            state, mesh, lambda path, leaf: None if leaf.ndim == 1 else P("data")
        )
        for k in state:
            np.testing.assert_array_equal(np.asarray(out[k]), state[k])
        assert out["w"].sharding.is_fully_replicated or mesh.size == 1

    def test_divisible_handles_tuple_axes_and_short_specs(self):
        mesh = _mesh1()
        assert _divisible((8, 6), P("data"), mesh)
        assert _divisible((8,), P(("data",)), mesh)
        # spec shorter than rank: trailing dims unconstrained
        assert _divisible((8, 6, 4), P("data"), mesh)

    def test_elastic_restore_defaults_to_replication(self, tmp_path):
        state = {"w": np.ones((4, 4), np.float32) * 3.0}
        save_checkpoint(str(tmp_path), 1, state)
        restored = elastic_restore(str(tmp_path), 1, state, _mesh1())
        np.testing.assert_array_equal(np.asarray(restored["w"]), state["w"])


def _run_subprocess(code: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=420
    )
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-3000:]}"
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_grow_and_shrink_round_trip():
    """Save under a 2-device mesh, restore onto 4 (grow), save again, restore
    onto 2 (shrink): every leaf keeps its values bit-exactly, batch-sharded
    leaves re-shard to the new extent, and a leaf that stops dividing falls
    back to replication instead of failing."""
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json, tempfile
        import jax, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.checkpoint.checkpointer import save_checkpoint
        from repro.ft.elastic import elastic_restore, reshard_to_mesh

        devs = jax.devices()
        mesh2 = Mesh(np.array(devs[:2]), ("data",))
        mesh4 = Mesh(np.array(devs[:4]), ("data",))

        rng = np.random.default_rng(0)
        state = {
            "w": rng.standard_normal((8, 6)).astype(np.float32),   # divides 2 and 4
            "odd": rng.standard_normal((6, 3)).astype(np.float32), # divides 2, NOT 4
            "scalar": np.float32(7.5),
        }
        spec_fn = lambda path, leaf: P("data") if leaf.ndim == 2 else P()

        placed2 = reshard_to_mesh(state, mesh2, spec_fn)
        ckpt = tempfile.mkdtemp(prefix="elastic_ckpt_")
        save_checkpoint(ckpt, 1, placed2)

        out = {}
        # grow 2 -> 4
        grown = elastic_restore(ckpt, 1, state, mesh4, spec_fn=spec_fn)
        out["grow_err"] = float(max(
            np.max(np.abs(np.asarray(grown[k]) - state[k])) for k in ("w", "odd")
        ))
        out["grow_w_sharded"] = not grown["w"].sharding.is_fully_replicated
        # odd no longer divides 4 -> replication fallback, values intact
        out["grow_odd_replicated"] = bool(grown["odd"].sharding.is_fully_replicated)
        out["grow_w_nshards"] = len({s.device for s in grown["w"].addressable_shards})

        # shrink 4 -> 2 (save the grown state, restore onto the small mesh)
        save_checkpoint(ckpt, 2, grown)
        shrunk = elastic_restore(ckpt, 2, state, mesh2, spec_fn=spec_fn)
        out["shrink_err"] = float(max(
            np.max(np.abs(np.asarray(shrunk[k]) - state[k])) for k in ("w", "odd")
        ))
        out["shrink_odd_sharded"] = not shrunk["odd"].sharding.is_fully_replicated
        out["shrink_w_nshards"] = len({s.device for s in shrunk["w"].addressable_shards})
        out["scalar"] = float(np.asarray(shrunk["scalar"]))
        print(json.dumps(out))
        """
    )
    res = _run_subprocess(code)
    assert res["grow_err"] == 0.0, res
    assert res["shrink_err"] == 0.0, res
    assert res["grow_w_sharded"] and res["grow_w_nshards"] == 4, res
    assert res["grow_odd_replicated"], res
    assert res["shrink_odd_sharded"] and res["shrink_w_nshards"] == 2, res
    assert res["scalar"] == 7.5, res
