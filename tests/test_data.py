"""Data pipeline: determinism, structure, prefetch."""

import numpy as np

from repro.data import (
    LMDataConfig,
    SSLDataConfig,
    ShardedPrefetcher,
    lm_batch,
    lm_iterator,
    ssl_batch,
)


def test_lm_batch_deterministic():
    cfg = LMDataConfig(vocab_size=97, batch=4, seq_len=16, seed=3)
    a = lm_batch(cfg, 5)
    b = lm_batch(cfg, 5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_lm_batch_labels_are_shifted_tokens():
    cfg = LMDataConfig(vocab_size=97, batch=2, seq_len=8)
    b = lm_batch(cfg, 0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_lm_batch_distinct_steps_differ():
    cfg = LMDataConfig(vocab_size=997, batch=2, seq_len=32)
    assert not np.array_equal(lm_batch(cfg, 0)["tokens"], lm_batch(cfg, 1)["tokens"])


def test_lm_batch_codebooks_shape():
    cfg = LMDataConfig(vocab_size=64, batch=2, seq_len=8, n_codebooks=4)
    b = lm_batch(cfg, 0)
    assert b["tokens"].shape == (2, 8, 4)


def test_ssl_views_share_signal():
    cfg = SSLDataConfig(input_dim=256, batch=128, noise=0.01, mask_prob=0.1, jitter=0.05)
    v1, v2 = ssl_batch(cfg, 0)
    # same underlying latents: views of the same row correlate much more
    # than views of different rows
    same = np.mean([np.corrcoef(v1[i], v2[i])[0, 1] for i in range(32)])
    diff = np.mean([np.corrcoef(v1[i], v2[i + 1])[0, 1] for i in range(32)])
    assert same > 0.5
    assert abs(diff) < 0.3


def test_ssl_deterministic():
    cfg = SSLDataConfig(input_dim=64, batch=8)
    a1, a2 = ssl_batch(cfg, 7)
    b1, b2 = ssl_batch(cfg, 7)
    np.testing.assert_array_equal(a1, b1)
    np.testing.assert_array_equal(a2, b2)


def test_prefetcher_order_and_close():
    cfg = LMDataConfig(vocab_size=31, batch=2, seq_len=4)
    it = ShardedPrefetcher(lm_iterator(cfg), sharding=None, depth=2)
    first = next(it)
    second = next(it)
    np.testing.assert_array_equal(first["tokens"], lm_batch(cfg, 0)["tokens"])
    np.testing.assert_array_equal(second["tokens"], lm_batch(cfg, 1)["tokens"])
    it.close()


def test_prefetcher_propagates_errors():
    def bad_iter():
        yield {"x": np.zeros(2)}
        raise ValueError("source died")

    it = ShardedPrefetcher(bad_iter(), depth=1)
    next(it)
    try:
        next(it)
        next(it)
        assert False, "should raise"
    except ValueError:
        pass
