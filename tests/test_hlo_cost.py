"""The dry-run's HLO analyzer must count scan (while) bodies trip-exactly."""

import jax
import jax.numpy as jnp

from repro.launch.hlo_cost import analyze_hlo, roofline_terms


def test_plain_matmul_flops_exact():
    m, k, n = 64, 32, 48

    def f(a, b):
        return a @ b

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((m, k), jnp.float32), jax.ShapeDtypeStruct((k, n), jnp.float32)
    ).compile()
    a = analyze_hlo(compiled.as_text())
    assert a.flops == 2.0 * m * k * n


def test_scan_flops_scaled_by_trip_count():
    trips, m = 13, 32

    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None

        y, _ = jax.lax.scan(body, x, ws)
        return y

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((m, m), jnp.float32),
        jax.ShapeDtypeStruct((trips, m, m), jnp.float32),
    ).compile()
    a = analyze_hlo(compiled.as_text())
    want = trips * 2.0 * m**3
    # trip-count heuristic tolerance: exact or within one trip
    assert want * (trips - 1) / trips <= a.flops <= want * (trips + 1) / trips, (a.flops, want)
    assert any(t == trips for t in a.trip_counts.values()), a.trip_counts


def test_nested_scan_multiplies():
    t1, t2, m = 4, 6, 16

    def f(x, ws):
        def outer(c, wrow):
            def inner(ci, w):
                return ci @ w, None

            c2, _ = jax.lax.scan(inner, c, wrow)
            return c2, None

        y, _ = jax.lax.scan(outer, x, ws)
        return y

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((m, m), jnp.float32),
        jax.ShapeDtypeStruct((t1, t2, m, m), jnp.float32),
    ).compile()
    a = analyze_hlo(compiled.as_text())
    want = t1 * t2 * 2.0 * m**3
    assert 0.7 * want <= a.flops <= 1.3 * want, (a.flops, want)


def test_grad_of_scan_counts_fwd_and_bwd():
    trips, m = 8, 16

    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None

        y, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(y)

    compiled = jax.jit(jax.grad(f, argnums=1)).lower(
        jax.ShapeDtypeStruct((m, m), jnp.float32),
        jax.ShapeDtypeStruct((trips, m, m), jnp.float32),
    ).compile()
    a = analyze_hlo(compiled.as_text())
    fwd = trips * 2.0 * m**3
    # fwd + ~2x bwd (dot grads) => at least 2.5x fwd
    assert a.flops >= 2.5 * fwd, (a.flops, fwd)


def test_roofline_terms_structure():
    def f(a, b):
        return a @ b

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((256, 256), jnp.float32),
        jax.ShapeDtypeStruct((256, 256), jnp.float32),
    ).compile()
    a = analyze_hlo(compiled.as_text())
    t = roofline_terms(a)
    assert set(t) >= {"compute_s", "memory_s", "collective_s", "dominant", "bound_s"}
    assert t["dominant"] in ("compute", "memory", "collective")
    assert t["bound_s"] == max(t["compute_s"], t["memory_s"], t["collective_s"])
    assert t["collective_s"] == 0.0  # single device
