"""Train-loop fault tolerance: retry, preemption, deterministic resume."""


import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import LMDataConfig, lm_batch
from repro.ft import PreemptionSignal, StragglerWatchdog, with_retries
from repro.models import init_params
from repro.optim import adamw, warmup_cosine
from repro.train import LoopConfig, create_train_state, make_train_step, run_training


def _setup():
    cfg = get_config("rwkv6-3b").reduced(n_layers=2)
    opt = adamw()
    step_fn = jax.jit(make_train_step(cfg, opt, warmup_cosine(1e-3, 2, 50)))
    dcfg = LMDataConfig(vocab_size=cfg.vocab_size, batch=2, seq_len=8)

    def batch_fn(step):
        return {k: jnp.asarray(v) for k, v in lm_batch(dcfg, step).items()}

    def fresh_state():
        return create_train_state(init_params(jax.random.PRNGKey(0), cfg), opt)

    return step_fn, batch_fn, fresh_state


def test_transient_fault_retried(tmp_path):
    step_fn, batch_fn, fresh = _setup()
    calls = {"faults": 0}

    def fault_hook(step):
        if step == 3 and calls["faults"] < 2:
            calls["faults"] += 1
            raise RuntimeError("flaky device")

    cfg = LoopConfig(total_steps=5, ckpt_dir=str(tmp_path), ckpt_interval=100, max_step_retries=3)
    state = run_training(fresh(), step_fn, batch_fn, cfg, fault_hook=fault_hook)
    assert int(state.step) == 5
    assert calls["faults"] == 2


def test_unrecoverable_fault_raises(tmp_path):
    step_fn, batch_fn, fresh = _setup()

    def fault_hook(step):
        if step == 2:
            raise RuntimeError("dead host")

    cfg = LoopConfig(total_steps=5, ckpt_dir=str(tmp_path), max_step_retries=1)
    try:
        run_training(fresh(), step_fn, batch_fn, cfg, fault_hook=fault_hook)
        assert False, "should raise"
    except RuntimeError:
        pass


def test_resume_trajectory_identical(tmp_path):
    """Crash-restart must produce the same final params as an uninterrupted
    run (deterministic data keyed by step + checkpointed RNG)."""
    step_fn, batch_fn, fresh = _setup()

    # uninterrupted 8 steps
    ref = run_training(
        fresh(), step_fn, batch_fn, LoopConfig(total_steps=8, ckpt_dir=None)
    )

    # run to 4 with checkpointing, then "crash" and resume to 8
    d1 = str(tmp_path / "ckpt")
    run_training(fresh(), step_fn, batch_fn, LoopConfig(total_steps=4, ckpt_dir=d1, ckpt_interval=2))
    resumed = run_training(fresh(), step_fn, batch_fn, LoopConfig(total_steps=8, ckpt_dir=d1, ckpt_interval=2))

    for a, b in zip(jax.tree.leaves(ref.params), jax.tree.leaves(resumed.params)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-6
        )


def test_preemption_checkpoints_and_exits(tmp_path):
    step_fn, batch_fn, fresh = _setup()
    flag = str(tmp_path / "PREEMPT")
    PreemptionSignal(flag).set()
    cfg = LoopConfig(total_steps=100, ckpt_dir=str(tmp_path), ckpt_interval=1000, preempt_flag=flag)
    state = run_training(fresh(), step_fn, batch_fn, cfg)
    assert int(state.step) == 1  # exited after first step
    from repro.checkpoint import latest_step

    assert latest_step(str(tmp_path)) == 1


def test_straggler_watchdog_flags_outliers():
    import time

    wd = StragglerWatchdog(window=16, factor=3.0, min_samples=4)
    for i in range(6):
        wd.step_start()
        time.sleep(0.002)
        wd.step_end()
    wd.step_start()
    time.sleep(0.05)
    assert wd.step_end() is True
    assert wd.straggler_events == 1


def test_with_retries_backoff():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("boom")
        return 42

    assert with_retries(flaky, max_retries=5, backoff_s=0.001)() == 42
    assert calls["n"] == 3


def test_heartbeat_missed_detection():
    """Missed-heartbeat detection with an injected clock (no sleeping)."""
    from repro.ft import HeartbeatMonitor

    t = {"now": 100.0}
    hb = HeartbeatMonitor(default_timeout_s=2.0, clock=lambda: t["now"])
    hb.register("serve.dispatch")
    hb.register("ckpt.writer", timeout_s=10.0)

    assert hb.stale() == {}
    t["now"] = 103.0  # dispatch overdue (3s > 2s), writer fine (3s < 10s)
    overdue = hb.stale()
    assert list(overdue) == ["serve.dispatch"]
    assert overdue["serve.dispatch"] == 3.0
    assert hb.missed_events == 1
    # still stale on re-check: edge-triggered counter does not double-count
    hb.stale()
    assert hb.missed_events == 1

    hb.beat("serve.dispatch")
    assert hb.stale() == {}
    t["now"] = 106.5  # second miss -> second event
    assert "serve.dispatch" in hb.stale()
    assert hb.missed_events == 2

    m = hb.metrics()
    assert m["heartbeat_components"] == 2.0
    assert m["heartbeat_stale"] == 1.0
    assert m["heartbeat_missed_events"] == 2.0
    # per-name ages are exposition-safe (dots sanitized) so alert rules can
    # target them directly
    assert m["heartbeat_age_s_serve_dispatch"] == 3.5


def test_heartbeat_auto_registers_on_beat():
    from repro.ft import HeartbeatMonitor

    t = {"now": 0.0}
    hb = HeartbeatMonitor(default_timeout_s=1.0, clock=lambda: t["now"])
    hb.beat("adhoc")
    t["now"] = 0.5
    assert hb.stale() == {}
    t["now"] = 2.0
    assert "adhoc" in hb.stale()
