"""Continuous-batching LM serving: slot pool bookkeeping, the oracle that
matters (interleaved continuous decoding emits EXACTLY whole-request
``greedy_generate``'s tokens, per request), EOS/budget retirement, admission
rejection (never hang), backpressure sharing, and probe-under-interleaving
agreement with the offline training-path oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.decorr.config import DecorrConfig
from repro.models import init_params
from repro.serve import (
    Backpressure,
    ContinuousLMEngine,
    DecorrProbe,
    LMRequest,
    LMService,
    MicroBatcher,
    BucketPolicy,
    SlotPool,
)
from repro.serve.loadgen import lm_probe_oracle_err
from repro.train.serve import greedy_generate


@pytest.fixture(scope="module")
def gemma():
    cfg = get_config("gemma2-2b").reduced()
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def rwkv():
    cfg = get_config("rwkv6-3b").reduced()
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


def _prompts(cfg, spec, seed=0):
    rng = np.random.default_rng(seed)
    return [
        (rng.integers(0, cfg.vocab_size, s).astype(np.int32), m) for s, m in spec
    ]


# ---------------------------------------------------------------------------
# Slot pool (pure bookkeeping)
# ---------------------------------------------------------------------------


class TestSlotPool:
    def _req(self, n=4, m=3, eos=None):
        return LMRequest(np.zeros(n, np.int32), m, eos_id=eos)

    def test_admit_retire_freelist(self):
        pool = SlotPool(2, max_len=32)
        a = pool.admit(self._req(), None)
        b = pool.admit(self._req(), None)
        assert pool.free_slots() == 0 and {a.index, b.index} == {0, 1}
        with pytest.raises(RuntimeError):
            pool.admit(self._req(), None)
        pool.retire(a.index)
        c = pool.admit(self._req(), None)
        assert c.index == a.index  # freed slot reused
        assert pool.admitted_total == 3 and pool.retired_total == 1

    def test_admit_rejects_cache_overflow(self):
        pool = SlotPool(2, max_len=8)
        with pytest.raises(ValueError):
            pool.admit(self._req(n=6, m=4), None)

    def test_positions_and_tokens_vectors(self):
        pool = SlotPool(3, max_len=32)
        s = pool.admit(self._req(n=5), None)
        # prefill emits the first token without writing it: pos == prompt_len
        assert s.pos == 4
        done = s.emit(7)
        assert not done and s.pos == 5 and s.last_token == 7
        np.testing.assert_array_equal(pool.cache_lens(), [5, 0, 0])
        np.testing.assert_array_equal(pool.last_tokens(), [7, 0, 0])

    def test_eos_and_budget_retirement(self):
        s = SlotPool(1, 32).admit(self._req(m=3, eos=9), None)
        assert not s.emit(1)
        assert s.emit(9)  # EOS retires early
        s2 = SlotPool(1, 32).admit(self._req(m=2), None)
        assert not s2.emit(1)
        assert s2.emit(2)  # token budget exhausted

    def test_occupancy_accounting(self):
        pool = SlotPool(4, max_len=32)
        pool.admit(self._req(), None)
        pool.admit(self._req(), None)
        pool.observe_step()
        assert pool.occupancy() == 0.5
        m = pool.metrics()
        assert m["slots_active"] == 2.0 and m["slots_total"] == 4.0


# ---------------------------------------------------------------------------
# Engine + service: interleaved decoding == whole-request greedy oracle
# ---------------------------------------------------------------------------


SPEC = [(4, 5), (9, 3), (13, 8), (24, 2), (1, 4), (7, 7)]


class TestContinuousMatchesGreedy:
    def _run(self, cfg, params, spec, n_slots=4, max_len=48):
        eng = ContinuousLMEngine(cfg, params, n_slots=n_slots, max_len=max_len,
                                 max_prompt_len=24)
        svc = LMService(eng)
        svc.warmup(prompt_lens=[len(t) for t, _ in spec])
        futs = [svc.submit(t, m) for t, m in spec]
        svc.drain()
        for (t, m), f in zip(spec, futs):
            want = np.asarray(
                greedy_generate(params, cfg, jnp.asarray(t[None]), m, max_len=max_len)
            )[0]
            np.testing.assert_array_equal(f.result(timeout=5), want)
        return svc

    def test_attention_arch_padded_prompt_buckets(self, gemma):
        cfg, params = gemma
        svc = self._run(cfg, params, _prompts(cfg, SPEC))
        assert svc.engine.pad_prompts
        m = svc.metrics()
        assert m["slots_retired_total"] == len(SPEC)
        assert 0.0 < m["slots_occupancy"] <= 1.0
        assert m["ttft_p99_ms"] >= m["ttft_p50_ms"] > 0.0

    def test_recurrent_arch_exact_length_prefill(self, rwkv):
        cfg, params = rwkv
        svc = self._run(cfg, params, _prompts(cfg, SPEC[:4]))
        assert not svc.engine.pad_prompts

    def test_eos_retires_early_with_matching_prefix(self, gemma):
        cfg, params = gemma
        (tokens, _), = _prompts(cfg, [(6, 1)])
        max_len = 48
        want = np.asarray(
            greedy_generate(params, cfg, jnp.asarray(tokens[None]), 8, max_len=max_len)
        )[0]
        eos = int(want[4])  # force retirement mid-request
        k = int(np.argmax(want == eos))  # first occurrence is the stop point
        eng = ContinuousLMEngine(cfg, params, n_slots=2, max_len=max_len, max_prompt_len=24)
        svc = LMService(eng)
        svc.warmup()
        fut = svc.submit(tokens, 8, eos_id=eos)
        svc.drain()
        np.testing.assert_array_equal(fut.result(timeout=5), want[: k + 1])

    def test_single_token_budget_retires_at_prefill(self, gemma):
        cfg, params = gemma
        (tokens, _), = _prompts(cfg, [(5, 1)])
        eng = ContinuousLMEngine(cfg, params, n_slots=2, max_len=32, max_prompt_len=16)
        svc = LMService(eng)
        svc.warmup()
        fut = svc.submit(tokens, 1)
        svc.step(timeout=0.0)  # admitted + retired in one tick, no decode needed
        want = np.asarray(
            greedy_generate(params, cfg, jnp.asarray(tokens[None]), 1, max_len=32)
        )[0]
        np.testing.assert_array_equal(fut.result(timeout=5), want)
        assert eng.pool.free_slots() == 2


# ---------------------------------------------------------------------------
# Admission edge cases: reject (never hang) + backpressure
# ---------------------------------------------------------------------------


class TestAdmissionEdgeCases:
    def _service(self, gemma, **kw):
        cfg, params = gemma
        eng = ContinuousLMEngine(cfg, params, n_slots=2, max_len=32, max_prompt_len=16)
        return LMService(eng, **kw)

    def test_empty_prompt_rejected(self, gemma):
        svc = self._service(gemma)
        with pytest.raises(ValueError, match="empty prompt"):
            svc.submit(np.zeros(0, np.int32), 4)
        assert svc.batcher.depth() == 0  # rejected at submit, never queued

    def test_prompt_longer_than_largest_bucket_rejected(self, gemma):
        svc = self._service(gemma)
        assert svc.engine.max_prompt_len == 16
        with pytest.raises(ValueError, match="largest prompt bucket"):
            svc.submit(np.zeros(17, np.int32), 4)
        assert svc.batcher.depth() == 0

    def test_cache_overflow_rejected(self, gemma):
        svc = self._service(gemma)
        with pytest.raises(ValueError, match="slot cache"):
            svc.submit(np.zeros(16, np.int32), 20)  # 16 + 20 - 1 > 32

    def test_exact_cache_fill_admitted_and_completes(self, gemma):
        """Regression: a request whose written rows exactly fill the cache
        used to be rejected at submit time.  The final generated token is
        emitted but never written, so prompt_len + max_new_tokens - 1 rows is
        the true footprint — both the == max_len and the one-past boundary
        must admit and finish against the whole-request oracle."""
        cfg, params = gemma
        eng = ContinuousLMEngine(cfg, params, n_slots=1, max_len=32, max_prompt_len=16)
        svc = LMService(eng)
        svc.warmup()
        (tokens, _), = _prompts(cfg, [(16, 1)])
        for max_new in (16, 17):  # 16 + 17 - 1 == 32 exactly fills the rows
            fut = svc.submit(tokens, max_new)
            svc.drain()
            want = np.asarray(
                greedy_generate(params, cfg, jnp.asarray(tokens[None]), max_new, max_len=32)
            )[0]
            np.testing.assert_array_equal(fut.result(timeout=5), want)
        with pytest.raises(ValueError, match="slot cache"):
            svc.submit(tokens, 18)  # one row too many
        assert eng.pool.free_slots() == 1

    def test_padded_bucket_ladder_must_fit_cache(self, gemma):
        """Regression: max_prompt_len=19 rounds UP to a 24-row prompt bucket
        that cannot prefill into a 20-row cache — must fail at construction,
        not crash a request mid-insert."""
        cfg, params = gemma
        with pytest.raises(ValueError, match="padded prompt bucket"):
            ContinuousLMEngine(cfg, params, n_slots=2, max_len=20, max_prompt_len=19)

    def test_backpressure_when_queue_full(self, gemma):
        svc = self._service(gemma, max_queue=2)
        svc.submit(np.zeros(4, np.int32), 2)
        svc.submit(np.zeros(4, np.int32), 2)
        with pytest.raises(Backpressure):
            svc.submit(np.zeros(4, np.int32), 2)

    def test_embedding_service_rejects_empty(self):
        from repro.serve import EmbeddingService, ServeEngine
        from repro.train.ssl import SSLModelConfig, init_ssl_params

        model = SSLModelConfig(input_dim=8, backbone_widths=(16,), projector_widths=(16, 16))
        svc = EmbeddingService(
            ServeEngine(model, init_ssl_params(jax.random.PRNGKey(0), model))
        )
        with pytest.raises(ValueError, match="empty request"):
            svc.submit(np.zeros((0, 8), np.float32))
        with pytest.raises(ValueError, match="row-batch"):
            svc.submit(np.zeros((2, 2, 2), np.float32))

    def test_audio_codes_arch_rejected(self):
        cfg = get_config("musicgen-large").reduced()
        with pytest.raises(NotImplementedError):
            ContinuousLMEngine(cfg, params=None, n_slots=2, max_len=32)


class TestBatcherNextRequests:
    def test_pops_up_to_k_whole_requests(self):
        mb = MicroBatcher(BucketPolicy(max_batch=8, max_wait_ms=0.0))
        for i in range(5):
            mb.submit(LMRequest(np.zeros(3, np.int32), 2))
        got = mb.next_requests(3, timeout=0.0)
        assert len(got) == 3
        assert len(mb.next_requests(8, timeout=0.0)) == 2
        assert mb.next_requests(8, timeout=0.0) == []
        assert mb.next_requests(0, timeout=0.0) == []

    def test_shutdown_drains_then_signals_none(self):
        mb = MicroBatcher(BucketPolicy(max_batch=8, max_wait_ms=0.0))
        mb.submit(LMRequest(np.zeros(3, np.int32), 2))
        mb.shutdown()
        assert len(mb.next_requests(4, timeout=0.0)) == 1
        assert mb.next_requests(4, timeout=0.0) is None
        assert mb.next_requests(0, timeout=0.0) is None


# ---------------------------------------------------------------------------
# Probes under interleaving + the threaded loop
# ---------------------------------------------------------------------------


class TestContinuousService:
    def test_probe_matches_oracle_under_interleaving(self, gemma):
        cfg, params = gemma
        eng = ContinuousLMEngine(cfg, params, n_slots=4, max_len=48, max_prompt_len=24)
        probe = DecorrProbe(DecorrConfig(style="vic", reg="sum", q=2))
        svc = LMService(eng, probe=probe, record_probe_rows=True)
        svc.warmup()
        for t, m in _prompts(cfg, SPEC):
            svc.submit(t, m)
        svc.drain()
        assert probe.steps >= 1
        err = lm_probe_oracle_err(svc)
        assert err is not None and err < 1e-3
        m = svc.metrics()
        assert m["decorr_probe_steps"] == float(probe.steps)
        # probe rows all came from in-flight slots: total rows fed ==
        # prefills + sum of active-slot decode lanes
        fed = sum(r.shape[0] for r in svc.probe_rows)
        assert fed == eng.pool.admitted_total + eng.pool.active_slot_steps

    def test_threaded_service_with_heartbeat(self, gemma):
        cfg, params = gemma
        eng = ContinuousLMEngine(cfg, params, n_slots=2, max_len=32, max_prompt_len=16)
        svc = LMService(eng)
        svc.warmup()
        svc.start()
        try:
            futs = [svc.submit(t, m, block=True, timeout=30.0)
                    for t, m in _prompts(cfg, [(4, 3), (7, 2), (9, 4)])]
            outs = [f.result(timeout=60.0) for f in futs]
        finally:
            svc.stop()
        assert [len(o) for o in outs] == [3, 2, 4]
        m = svc.metrics()
        assert m["served_total"] == 3.0
        assert m["heartbeat_stale"] == 0.0
        assert m["tokens_total"] == 9.0
