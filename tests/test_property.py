"""Hypothesis property tests for the system's invariants.

hypothesis is an optional dev dependency — the module skips cleanly (instead
of crashing collection) when it is not installed.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dev dependency: pip install hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import losses as L
from repro.core import regularizers as regs
from repro.core import sumvec as sv

SETTINGS = dict(max_examples=25, deadline=None)


def _data(n, d, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return (
        jax.random.normal(k1, (n, d), jnp.float32),
        jax.random.normal(k2, (n, d), jnp.float32),
    )


@given(n=st.integers(2, 24), d=st.integers(2, 48), seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_fft_sumvec_equals_matrix_sumvec(n, d, seed):
    z1, z2 = _data(n, d, seed)
    c = regs.cross_correlation_matrix(z1, z2, scale=n)
    np.testing.assert_allclose(
        sv.sumvec_fft(z1, z2, scale=float(n)),
        sv.sumvec_from_matrix(c),
        atol=5e-3 * np.sqrt(n * d),
    )


@given(n=st.integers(2, 16), d=st.integers(2, 40), seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_sumvec_total_equals_matrix_total(n, d, seed):
    # the components partition C: sum(sumvec) == sum(C) exactly
    z1, z2 = _data(n, d, seed)
    c = regs.cross_correlation_matrix(z1, z2, scale=n)
    np.testing.assert_allclose(
        jnp.sum(sv.sumvec_fft(z1, z2, scale=float(n))), jnp.sum(c), atol=1e-2
    )


@given(
    n=st.integers(2, 16),
    d=st.integers(4, 40),
    b=st.integers(2, 16),
    q=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_grouped_matches_matrix_oracle(n, d, b, q, seed):
    z1, z2 = _data(n, d, seed)
    c = regs.cross_correlation_matrix(z1, z2, scale=n)
    got = regs.r_sum_grouped(z1, z2, b, q=q, scale=float(n))
    want = regs.r_sum_grouped_from_matrix(c, b, q=q)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@given(d=st.integers(2, 64), seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_parseval_identity(d, seed):
    s = jax.random.normal(jax.random.PRNGKey(seed), (d,))
    g = jnp.fft.rfft(s)
    sq, s0 = sv.sq_sum_and_zeroth_from_freq(g, d)
    np.testing.assert_allclose(sq, jnp.sum(s**2), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(s0, s[0], atol=1e-4)


@given(n=st.integers(3, 16), d=st.integers(2, 32), seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_r_off_permutation_invariant(n, d, seed):
    z1, z2 = _data(n, d, seed)
    perm = jax.random.permutation(jax.random.PRNGKey(seed + 1), d)
    a = regs.r_off(regs.cross_correlation_matrix(z1, z2, scale=n))
    b = regs.r_off(regs.cross_correlation_matrix(z1[:, perm], z2[:, perm], scale=n))
    np.testing.assert_allclose(a, b, rtol=1e-3)


@given(n=st.integers(3, 16), d=st.integers(4, 32), seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_r_sum_nonnegative_and_relaxation(n, d, seed):
    # 0 <= R_sum(C)  and  R_sum <= (d-1) * R_off upper bound via Cauchy-Schwarz
    z1, z2 = _data(n, d, seed)
    c = regs.cross_correlation_matrix(z1, z2, scale=n)
    rs = float(regs.r_sum(z1, z2, q=2, scale=float(n)))
    ro = float(regs.r_off(c))
    assert rs >= -1e-5
    assert rs <= d * ro + 1e-3  # each sumvec comp is a sum of d elements


@given(seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_standardize_properties(seed):
    z = 3.0 + 2.0 * jax.random.normal(jax.random.PRNGKey(seed), (64, 8))
    s = L.standardize(z)
    np.testing.assert_allclose(jnp.mean(s, axis=0), 0.0, atol=1e-4)
    np.testing.assert_allclose(jnp.var(s, axis=0), 1.0, atol=1e-2)


@given(
    n=st.integers(2, 12),
    d=st.integers(2, 24),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_loss_finite_across_dtypes(n, d, dtype, seed):
    z1, z2 = _data(n, d, seed)
    z1, z2 = z1.astype(dtype), z2.astype(dtype)
    for style in ("bt", "vic"):
        cfg = L.DecorrConfig(style=style, reg="sum", q=2)
        loss, _ = L.ssl_loss(z1, z2, cfg, jax.random.PRNGKey(0))
        assert bool(jnp.isfinite(loss))


@given(steps=st.integers(1, 5), seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_permutation_deterministic_per_step(steps, seed):
    from repro.core.permutation import permutation_for_step

    key = jax.random.PRNGKey(seed)
    p1 = permutation_for_step(key, steps, 16)
    p2 = permutation_for_step(key, steps, 16)
    np.testing.assert_array_equal(p1, p2)
    assert sorted(np.asarray(p1).tolist()) == list(range(16))
