"""Serving correctness: decode == full-forward; generation shapes."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import forward, init_caches, init_params
from repro.train.serve import greedy_generate


@pytest.mark.parametrize(
    "arch,tol",
    [("gemma2-2b", 1e-4), ("rwkv6-3b", 1e-4), ("musicgen-large", 1e-4), ("codeqwen1.5-7b", 1e-4)],
)
def test_decode_matches_full_forward(arch, tol):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 12
    if cfg.frontend == "audio_codes":
        tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s, cfg.n_codebooks), 0, cfg.vocab_size)
    else:
        tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    full = forward(params, cfg, tokens=tokens)
    caches = init_caches(cfg, b, s + 2)
    pre = forward(params, cfg, tokens=tokens[:, : s - 1], caches=caches, cache_len=jnp.asarray(0, jnp.int32))
    dec = forward(params, cfg, tokens=tokens[:, s - 1 : s], caches=pre.caches, cache_len=jnp.asarray(s - 1, jnp.int32))
    scale = float(jnp.max(jnp.abs(full.logits[:, -1]))) + 1e-6
    np.testing.assert_allclose(
        np.asarray(dec.logits[:, 0]) / scale, np.asarray(full.logits[:, -1]) / scale, atol=tol * 100
    )


def test_jamba_decode_matches_with_high_capacity():
    # MoE capacity dropping is token-count dependent; with ample capacity
    # prefill+decode must agree with the full forward.
    cfg = dataclasses.replace(get_config("jamba-v0.1-52b").reduced(), capacity_factor=8.0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    full = forward(params, cfg, tokens=tokens)
    caches = init_caches(cfg, b, s + 2)
    pre = forward(params, cfg, tokens=tokens[:, : s - 1], caches=caches, cache_len=jnp.asarray(0, jnp.int32))
    dec = forward(params, cfg, tokens=tokens[:, s - 1 : s], caches=pre.caches, cache_len=jnp.asarray(s - 1, jnp.int32))
    np.testing.assert_allclose(dec.logits[:, 0], full.logits[:, -1], atol=5e-4)


def test_greedy_generate_shapes_lm():
    cfg = get_config("gemma2-2b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    out = greedy_generate(params, cfg, prompt, max_new_tokens=5)
    assert out.shape == (2, 5)
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab_size)))


def test_greedy_generate_shapes_audio():
    cfg = get_config("musicgen-large").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8, cfg.n_codebooks), 0, cfg.vocab_size)
    out = greedy_generate(params, cfg, prompt, max_new_tokens=4)
    assert out.shape == (2, 4, cfg.n_codebooks)


def test_greedy_generation_deterministic():
    cfg = get_config("rwkv6-3b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab_size)
    a = greedy_generate(params, cfg, prompt, max_new_tokens=6)
    b = greedy_generate(params, cfg, prompt, max_new_tokens=6)
    np.testing.assert_array_equal(a, b)
