"""Distributed-mode tests.  Multi-device cases run in a SUBPROCESS with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the main pytest
process keeps its single CPU device (per the assignment: only dryrun.py may
fake the device count globally)."""

import json
import os
import subprocess
import sys
import textwrap


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_in_subprocess(body: str, n_devices: int = 8) -> dict:
    """Run ``body`` (which must print a final JSON line) under N fake devices."""
    code = textwrap.dedent(
        f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_devices}"
        import json
        import jax
        import jax.numpy as jnp
        import numpy as np
        """
    ) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=420
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}\nstdout:\n{out.stdout[-1000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_r_sum_global_matches_single_device():
    res = run_in_subprocess(
        """
        from functools import partial
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.core import distributed as dist
        from repro.core import regularizers as regs

        mesh = jax.make_mesh((8,), ("data",))
        n, d = 64, 24
        z1 = jax.random.normal(jax.random.PRNGKey(0), (n, d))
        z2 = jax.random.normal(jax.random.PRNGKey(1), (n, d))

        @partial(shard_map, mesh=mesh, in_specs=(P("data"), P("data")), out_specs=P())
        def global_reg(a, b):
            return dist.r_sum_global(a, b, axis_name="data", q=2, scale=a.shape[0])[None]

        got = float(global_reg(z1, z2)[0])
        want = float(regs.r_sum(z1, z2, q=2, scale=n))
        grouped = shard_map(
            lambda a, b: dist.r_sum_global(a, b, axis_name="data", q=2, block_size=8, scale=a.shape[0])[None],
            mesh=mesh, in_specs=(P("data"), P("data")), out_specs=P())
        got_g = float(grouped(z1, z2)[0])
        want_g = float(regs.r_sum_grouped(z1, z2, 8, q=2, scale=n))
        print(json.dumps({"got": got, "want": want, "got_g": got_g, "want_g": want_g}))
        """
    )
    assert abs(res["got"] - res["want"]) < 1e-2 * max(abs(res["want"]), 1)
    assert abs(res["got_g"] - res["want_g"]) < 1e-2 * max(abs(res["want_g"]), 1)


def test_r_sum_tp_feature_sharded_matches_single_device():
    res = run_in_subprocess(
        """
        from functools import partial
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.core import distributed as dist
        from repro.core import regularizers as regs

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        n, d = 32, 32
        z1 = jax.random.normal(jax.random.PRNGKey(0), (n, d))
        z2 = jax.random.normal(jax.random.PRNGKey(1), (n, d))

        @partial(shard_map, mesh=mesh,
                 in_specs=(P("data", "model"), P("data", "model")), out_specs=P())
        def tp_reg(a, b):
            # scale is the LOCAL batch size; r_sum_tp multiplies by the
            # batch-axis size itself
            return dist.r_sum_tp(a, b, model_axis="model", batch_axis="data",
                                 q=2, scale=a.shape[0])[None]

        got = float(tp_reg(z1, z2)[0])
        want = float(regs.r_sum(z1, z2, q=2, scale=n))
        print(json.dumps({"got": got, "want": want}))
        """
    )
    assert abs(res["got"] - res["want"]) < 1e-2 * max(abs(res["want"]), 1)


def test_compressed_gradient_allreduce():
    res = run_in_subprocess(
        """
        from functools import partial
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.optim import compression as comp

        mesh = jax.make_mesh((8,), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (64, 16))
        e = jnp.zeros((64, 16))

        @partial(shard_map, mesh=mesh, in_specs=(P("data"), P("data")), out_specs=(P("data"), P("data")))
        def int8_reduce(gs, es):
            out, new_e = comp.int8_psum_ef({"g": gs}, {"g": es}, "data")
            return out["g"] / 8.0, new_e["g"]

        reduced, err = int8_reduce(g, e)
        exact = jnp.mean(g.reshape(8, 8, 16), axis=0)
        exact_full = jnp.tile(exact, (8, 1))
        rel = float(jnp.linalg.norm(reduced - exact_full) / jnp.linalg.norm(exact_full))

        @partial(shard_map, mesh=mesh, in_specs=(P("data"),), out_specs=P("data"))
        def bf16_reduce(gs):
            return comp.bf16_psum({"g": gs}, "data")["g"] / 8.0

        red2 = bf16_reduce(g)
        rel2 = float(jnp.linalg.norm(red2 - exact_full) / jnp.linalg.norm(exact_full))
        print(json.dumps({"rel_int8": rel, "rel_bf16": rel2}))
        """
    )
    assert res["rel_int8"] < 0.05
    assert res["rel_bf16"] < 0.01


def test_error_feedback_converges_over_steps():
    """With error feedback, the accumulated compressed sum tracks the true
    sum over steps even though each step quantizes to int8."""
    res = run_in_subprocess(
        """
        from functools import partial
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.optim import compression as comp

        mesh = jax.make_mesh((8,), ("data",))
        key = jax.random.PRNGKey(0)
        e = jnp.zeros((64, 4))
        acc_c = jnp.zeros((8, 4))
        acc_t = jnp.zeros((8, 4))

        @partial(shard_map, mesh=mesh, in_specs=(P("data"), P("data")), out_specs=(P("data"), P("data")))
        def step(gs, es):
            out, new_e = comp.int8_psum_ef({"g": gs}, {"g": es}, "data")
            return out["g"], new_e["g"]

        for i in range(20):
            g = jax.random.normal(jax.random.fold_in(key, i), (64, 4))
            red, e = step(g, e)
            acc_c = acc_c + red[:8]
            acc_t = acc_t + jnp.sum(g.reshape(8, 8, 4), axis=0)
        rel = float(jnp.linalg.norm(acc_c - acc_t) / jnp.linalg.norm(acc_t))
        print(json.dumps({"rel": rel}))
        """
    )
    assert res["rel"] < 0.02


def test_sharded_lm_train_step_runs_spmd():
    """A reduced arch train step under a (2, 4) mesh with real shardings —
    value must match the single-device step."""
    res = run_in_subprocess(
        """
        import dataclasses
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import init_params
        from repro.optim import adamw, warmup_cosine
        from repro.parallel.sharding import sharding_context
        from repro.train import create_train_state, make_train_step
        from repro.data import LMDataConfig, lm_batch

        cfg = get_config("codeqwen1.5-7b").reduced()
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        opt = adamw()
        step = make_train_step(cfg, opt, warmup_cosine(1e-3, 2, 10))
        params = init_params(jax.random.PRNGKey(0), cfg)
        state = create_train_state(params, opt)
        dcfg = LMDataConfig(vocab_size=cfg.vocab_size, batch=8, seq_len=16)
        batch = {k: jnp.asarray(v) for k, v in lm_batch(dcfg, 0).items()}

        # single device reference
        _, m_ref = jax.jit(step)(state, batch)

        def sharded_step(s, b):
            with sharding_context(mesh):
                return step(s, b)
        bsh = NamedSharding(mesh, P("data", None))
        batch_sh = {k: jax.device_put(v, bsh) for k, v in batch.items()}
        with sharding_context(mesh):
            _, m = jax.jit(sharded_step)(state, batch_sh)
        print(json.dumps({"loss": float(m["loss"]), "ref": float(m_ref["loss"]),
                          "n_dev": len(jax.devices())}))
        """
    )
    assert res["n_dev"] == 8
    assert abs(res["loss"] - res["ref"]) < 5e-3 * max(abs(res["ref"]), 1)
