"""Paged KV cache subsystem: allocator bookkeeping (alloc/free/reservation/
OOM/compaction), the paged-attention kernel against its jnp oracle, the tune
registration of the page-size space, and the oracle that matters end to end —
paged continuous batching emits EXACTLY the dense engine's greedy tokens
(which themselves pin to whole-request ``greedy_generate``), with the
in-flight decorrelation probe still training-oracle-exact.  Plus chunked
prefill and the temperature/top-k sampler."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import tune
from repro.configs import get_config
from repro.decorr.config import DecorrConfig
from repro.models import init_params
from repro.serve import ContinuousLMEngine, DecorrProbe, LMService, SamplingParams
from repro.serve.loadgen import lm_probe_oracle_err
from repro.serve.paging import PageAllocator, PagedKVManager, dense_cache_bytes
from repro.serve.sampling import make_rng, sample_token
from repro.train.serve import greedy_generate


@pytest.fixture(scope="module")
def gemma():
    cfg = get_config("gemma2-2b").reduced()
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


def _prompts(cfg, spec, seed=0):
    rng = np.random.default_rng(seed)
    return [
        (rng.integers(0, cfg.vocab_size, s).astype(np.int32), m) for s, m in spec
    ]


def _oracle(cfg, params, spec, max_len):
    return [
        np.asarray(greedy_generate(params, cfg, jnp.asarray(t[None]), m, max_len=max_len))[0]
        for t, m in spec
    ]


# ---------------------------------------------------------------------------
# PageAllocator (pure bookkeeping)
# ---------------------------------------------------------------------------


class TestPageAllocator:
    def _alloc(self, total=9, page=8, n_slots=4, nb=4):
        return PageAllocator(total, page, n_slots, nb)

    def test_alloc_prefers_low_ids_and_never_sentinel(self):
        a = self._alloc()
        a.reserve(0, 24)  # 3 pages
        added = a.ensure(0, 24)
        assert [phys for _, phys in added] == [1, 2, 3]  # heap: lowest first, 0 reserved
        assert a.table(0) == [1, 2, 3]
        assert a.in_use == 3 and a.peak_pages == 3

    def test_free_pages_return_and_are_reused(self):
        a = self._alloc()
        a.reserve(0, 16)
        a.ensure(0, 16)
        a.reserve(1, 8)
        a.ensure(1, 8)
        assert a.table(1) == [3]
        a.release(0)
        assert a.free_pages() == a.usable_pages - 1
        a.reserve(2, 8)
        a.ensure(2, 8)
        assert a.table(2) == [1]  # freed low id reused first

    def test_reservation_accounting_oom_safe(self):
        a = self._alloc(total=5)  # 4 usable pages
        assert a.can_reserve(32)  # 4 pages
        a.reserve(0, 24)  # 3 pages
        assert not a.can_reserve(16)  # 2 more would overflow
        assert a.can_reserve(8)
        with pytest.raises(RuntimeError, match="reservation overflow"):
            a.reserve(1, 16)
        # growth beyond the slot's own reservation is a bug, not an OOM
        a.ensure(0, 24)
        with pytest.raises(RuntimeError, match="> reservation"):
            a.ensure(0, 25)
        a.release(0)
        assert a.reserved_total == 0 and a.in_use == 0

    def test_fits_ever_bounds_by_pool_and_slot_blocks(self):
        a = self._alloc(total=5, nb=2)
        assert a.fits_ever(16)  # 2 pages <= min(4 usable, 2 per slot)
        assert not a.fits_ever(17)  # 3 pages > 2 blocks per slot

    def test_compaction_relocates_high_pages_into_low_holes(self):
        a = self._alloc(total=9)
        a.reserve(0, 16)
        a.ensure(0, 16)  # pages 1, 2
        a.reserve(1, 16)
        a.ensure(1, 16)  # pages 3, 4
        a.release(0)  # holes at 1, 2 below in-use 3, 4
        moves = a.plan_compaction(max_moves=4)
        assert moves == [(4, 1), (3, 2)]  # highest first into lowest holes
        assert a.table(1) == [2, 1]  # table rewritten in place
        assert a.frontier() == 3
        assert a.plan_compaction(max_moves=4) == []  # already compact

    def test_metrics_shape(self):
        m = self._alloc().metrics()
        for k in ("pages_total", "pages_in_use", "pages_peak", "pages_reserved"):
            assert k in m


class TestPagedKVManager:
    def test_requires_attention_position(self):
        cfg = get_config("rwkv6-3b").reduced()
        with pytest.raises(ValueError, match="attention position"):
            PagedKVManager(cfg, n_slots=2, max_len=32, page=8)

    def test_max_len_must_divide_and_bytes_accounting(self, gemma):
        cfg, _ = gemma
        with pytest.raises(AssertionError):
            PagedKVManager(cfg, n_slots=2, max_len=20, page=8)
        mgr = PagedKVManager(cfg, n_slots=2, max_len=32, page=8)
        assert mgr.dense_equiv_bytes() == dense_cache_bytes(cfg, 2, 32)
        # full pool equals dense capacity by construction (the win comes
        # from peak usage, gated in the bench)
        assert mgr.pool_cache_bytes() == mgr.dense_equiv_bytes()

    def test_table_rows_sentinel_padded(self, gemma):
        cfg, _ = gemma
        mgr = PagedKVManager(cfg, n_slots=2, max_len=32, page=8)
        mgr.admit(0, prompt_len=9, max_new_tokens=4)
        mgr.ensure_rows(0, 9)  # 2 pages
        row = mgr.table_row(0)
        assert row.shape == (4,) and row[0] > 0 and row[1] > 0
        assert row[2] == 0 and row[3] == 0  # sentinel padding


# ---------------------------------------------------------------------------
# Kernel vs jnp oracle + tune registration
# ---------------------------------------------------------------------------


class TestPagedAttentionKernel:
    @pytest.mark.parametrize("softcap,window", [(0.0, 0), (30.0, 0), (0.0, 7), (50.0, 9)])
    def test_kernel_matches_ref(self, softcap, window):
        from repro.kernels.paged_attention import ops

        rng = np.random.default_rng(0)
        b, h, kv, hd, page, nb = 3, 4, 2, 16, 8, 4
        p_total = b * nb + 1
        q = jnp.asarray(rng.standard_normal((b, h, hd)), jnp.float32)
        kp = jnp.asarray(rng.standard_normal((p_total, page, kv, hd)), jnp.float32)
        vp = jnp.asarray(rng.standard_normal((p_total, page, kv, hd)), jnp.float32)
        bt = jnp.asarray(
            rng.permutation(np.arange(1, p_total))[: b * nb].reshape(b, nb), jnp.int32
        )
        lens = jnp.asarray([5, 17, 32], jnp.int32)
        kw = dict(scale=0.25, softcap=softcap, window=window)
        out_k = ops.paged_decode_attention(q, kp, vp, bt, lens, **kw)
        out_j = ops.paged_decode_jnp(q, kp, vp, bt, lens, **kw)
        np.testing.assert_allclose(out_k, out_j, atol=1e-5)

    def test_tune_space_and_dispatch(self):
        shape = (8, 48, 2, 16)
        cands = tune.candidates("paged_attention", shape)
        pages = sorted(c["page"] for c in cands)
        assert pages == [8, 16, 32, 48]
        assert tune.default_config("paged_attention", shape) == {"page": 16}
        assert tune.best_config("paged_attention", shape)["page"] in pages
        with tune.override("paged_attention", page=8):
            assert tune.best_config("paged_attention", shape)["page"] == 8

    def test_auto_page_size_caps_fragmentation(self):
        from repro.kernels.paged_attention.ops import auto_page_size

        assert auto_page_size(8, 48, 2, 16) <= 32
        with tune.override("paged_attention", page=8):
            assert auto_page_size(8, 48, 2, 16) == 8

    def test_dry_tuner_never_regresses_default(self):
        res = tune.tune(
            "paged_attention", (4, 32, 2, 16), mode="dry", persist=False, max_candidates=3
        )
        default = res.candidate_for(res.default)
        tuned = res.candidate_for(res.best)
        assert tuned.cost["flops"] <= default.cost["flops"]
        assert tuned.cost["hbm_bytes"] <= default.cost["hbm_bytes"]


# ---------------------------------------------------------------------------
# Engine equivalence: paged == dense == whole-request greedy, probes exact
# ---------------------------------------------------------------------------


SPEC = [(4, 5), (9, 3), (13, 8), (24, 2), (1, 4), (7, 7)]


def _run_service(cfg, params, spec, probe=None, record=False, **engine_kw):
    eng = ContinuousLMEngine(cfg, params, n_slots=4, max_len=48, max_prompt_len=24, **engine_kw)
    svc = LMService(eng, probe=probe, record_probe_rows=record)
    svc.warmup(prompt_lens=[len(t) for t, _ in spec])
    futs = [svc.submit(t, m) for t, m in spec]
    svc.drain()
    return [f.result(timeout=10) for f in futs], svc


class TestPagedMatchesDense:
    def test_bit_identical_greedy_mixed_lengths(self, gemma):
        cfg, params = gemma
        spec = _prompts(cfg, SPEC)
        want = _oracle(cfg, params, spec, max_len=48)
        outs, svc = _run_service(cfg, params, spec, paged=True, page_size=16)
        for w, o in zip(want, outs):
            np.testing.assert_array_equal(o, w)
        m = svc.metrics()
        # the skewed mix never fills the dense-equivalent pool
        assert 0 < m["paged_peak_cache_bytes"] < m["paged_dense_equiv_bytes"]
        assert m["paged_pages_in_use"] == 0.0  # everything retired and freed
        assert m["paged_pages_reserved"] == 0.0

    def test_compaction_runs_and_preserves_tokens(self, gemma):
        cfg, params = gemma
        spec = _prompts(cfg, SPEC)
        want = _oracle(cfg, params, spec, max_len=48)
        outs, svc = _run_service(cfg, params, spec, paged=True, page_size=8)
        for w, o in zip(want, outs):
            np.testing.assert_array_equal(o, w)
        assert svc.metrics()["paged_pages_compaction_moves"] > 0

    def test_small_pool_defers_admission_and_completes(self, gemma):
        cfg, params = gemma
        spec = _prompts(cfg, SPEC)
        want = _oracle(cfg, params, spec, max_len=48)
        # 10 usable pages of 8 tokens: far below 4 slots x 48 rows — requests
        # queue behind the page reservation instead of OOMing
        outs, svc = _run_service(cfg, params, spec, paged=True, page_size=8, total_pages=11)
        for w, o in zip(want, outs):
            np.testing.assert_array_equal(o, w)
        assert svc.metrics()["paged_pages_peak"] <= 10

    def test_pallas_impl_route_matches(self, gemma):
        cfg, params = gemma
        spec = _prompts(cfg, SPEC[:2])
        want = _oracle(cfg, params, spec, max_len=32)
        with tune.override("paged_attention", impl="pallas"):
            outs, _ = _run_service(
                cfg, params, spec, paged=True, page_size=8,
            )
        # interpret-mode kernel vs jnp differ at float ulp level; tokens from
        # a random-init net have far larger logit margins than that
        for w, o in zip(want, outs):
            np.testing.assert_array_equal(o, w)

    def test_probe_matches_oracle_under_paging(self, gemma):
        cfg, params = gemma
        probe = DecorrProbe(DecorrConfig(style="vic", reg="sum", q=2))
        outs, svc = _run_service(
            cfg, params, _prompts(cfg, SPEC), probe=probe, record=True,
            paged=True, page_size=16,
        )
        assert probe.steps >= 1
        err = lm_probe_oracle_err(svc)
        assert err is not None and err < 1e-3
        pool = svc.engine.pool
        fed = sum(r.shape[0] for r in svc.probe_rows)
        assert fed == pool.admitted_total + pool.active_slot_steps

    def test_mixed_pattern_paged_attention_only(self):
        """jamba: attention positions page, mamba state stays dense — the
        per-pattern dispatch the paged cache tree encodes."""
        cfg = get_config("jamba-v0.1-52b").reduced()
        params = init_params(jax.random.PRNGKey(0), cfg)
        spec = _prompts(cfg, [(5, 4), (11, 3), (3, 6)])
        want = _oracle(cfg, params, spec, max_len=32)
        eng = ContinuousLMEngine(
            cfg, params, n_slots=2, max_len=32, max_prompt_len=16, paged=True, page_size=8
        )
        assert not eng.pad_prompts  # recurrent in the pattern: exact-length prefill
        svc = LMService(eng)
        svc.warmup(prompt_lens=[len(t) for t, _ in spec])
        futs = [svc.submit(t, m) for t, m in spec]
        svc.drain()
        for w, f in zip(want, futs):
            np.testing.assert_array_equal(f.result(timeout=10), w)


class TestChunkedPrefill:
    def test_long_prompts_chunked_tokens_match(self, gemma):
        cfg, params = gemma
        spec = _prompts(cfg, SPEC)
        want = _oracle(cfg, params, spec, max_len=48)
        outs, svc = _run_service(
            cfg, params, spec, paged=True, page_size=8, prefill_chunk=8
        )
        for w, o in zip(want, outs):
            np.testing.assert_array_equal(o, w)
        # prompts longer than the chunk occupied a slot without decoding, so
        # occupancy accounting saw fewer decode lanes than active slots
        assert svc.engine.prefill_chunk == 8

    def test_gating_errors(self, gemma):
        cfg, params = gemma
        with pytest.raises(ValueError, match="paged"):
            ContinuousLMEngine(cfg, params, n_slots=2, max_len=32, prefill_chunk=8)
        rcfg = get_config("jamba-v0.1-52b").reduced()
        with pytest.raises(ValueError, match="attention-only"):
            ContinuousLMEngine(
                rcfg, params, n_slots=2, max_len=32, paged=True, page_size=8, prefill_chunk=8
            )

    def test_abort_slot_clears_live_chunk_and_pages(self, gemma):
        """Regression: a decode failure mid-chunked-prefill must drop the
        live work tree and the slot's page reservation, or a reused slot
        index wedges every later chunked prefill."""
        from repro.serve.slots import LMRequest

        cfg, params = gemma
        eng = ContinuousLMEngine(
            cfg, params, n_slots=2, max_len=48, max_prompt_len=24,
            paged=True, page_size=8, prefill_chunk=8,
        )
        eng.warmup()
        req = LMRequest(np.zeros(20, np.int32), 4)
        slot = eng.pool.admit(req, None)
        eng.admit_slot(slot)
        assert slot.prefilling
        assert eng.advance_prefill(slot) is None  # first chunk: tree live
        assert eng._chunk_live is not None and eng._chunk_live[0] == slot.index
        eng.abort_slot(slot.index)
        eng.pool.retire(slot.index)
        assert eng._chunk_live is None
        assert eng.pager.alloc.reserved_total == 0 and eng.pager.alloc.in_use == 0

    def test_chunk_tail_must_fit_cache(self, gemma):
        cfg, params = gemma
        with pytest.raises(ValueError, match="template rows"):
            ContinuousLMEngine(
                cfg, params, n_slots=2, max_len=32, max_prompt_len=31,
                paged=True, page_size=8, prefill_chunk=24,
            )


# ---------------------------------------------------------------------------
# Sampling
# ---------------------------------------------------------------------------


class TestSampling:
    def test_sample_token_unit(self):
        logits = np.asarray([0.1, 3.0, -1.0, 2.9], np.float32)
        assert sample_token(logits, None, None) == 1
        assert sample_token(logits, SamplingParams(), None) == 1
        p1 = SamplingParams(temperature=0.7, top_k=1, seed=0)
        assert sample_token(logits, p1, make_rng(p1, 0)) == 1  # top-1 == argmax
        pk = SamplingParams(temperature=5.0, top_k=2, seed=0)
        rng = make_rng(pk, 0)
        draws = {sample_token(logits, pk, rng) for _ in range(64)}
        assert draws == {1, 3}  # support restricted to the top-2 logits

    def test_validation(self):
        with pytest.raises(ValueError, match="temperature"):
            SamplingParams(temperature=-1.0).validate()
        with pytest.raises(ValueError, match="top_k"):
            SamplingParams(top_k=-2).validate()

    def test_greedy_engine_rejects_temperature(self, gemma):
        cfg, params = gemma
        eng = ContinuousLMEngine(cfg, params, n_slots=2, max_len=32, max_prompt_len=16)
        svc = LMService(eng)
        with pytest.raises(ValueError, match="sampling=True"):
            svc.submit(np.zeros(4, np.int32), 2, temperature=0.8)

    def test_sampling_engine_greedy_is_bit_identical(self, gemma):
        cfg, params = gemma
        spec = _prompts(cfg, SPEC)
        want = _oracle(cfg, params, spec, max_len=48)
        outs, _ = _run_service(cfg, params, spec, sampling=True)
        for w, o in zip(want, outs):
            np.testing.assert_array_equal(o, w)

    def test_sampled_decode_reproducible_per_seed(self, gemma):
        cfg, params = gemma
        spec = _prompts(cfg, SPEC[:4])

        def run():
            eng = ContinuousLMEngine(
                cfg, params, n_slots=4, max_len=48, max_prompt_len=24,
                paged=True, page_size=16, sampling=True,
            )
            svc = LMService(eng)
            svc.warmup()
            futs = [
                svc.submit(t, m, temperature=0.8, top_k=8, seed=100 + i)
                for i, (t, m) in enumerate(spec)
            ]
            svc.drain()
            return [f.result(timeout=10) for f in futs]

        a, b = run(), run()
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)
        # and at least one request diverged from greedy (temperature bites)
        want = _oracle(cfg, params, spec, max_len=48)
        assert any(not np.array_equal(x, w[: len(x)]) for x, w in zip(a, want))
