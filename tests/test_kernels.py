"""Per-kernel validation: shape/dtype sweeps, allclose vs the ref.py
pure-jnp oracles, and gradient agreement (interpret mode on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import regularizers as regs
from repro.kernels.grouped_sumvec import ops as gops, ref as gref
from repro.kernels.sumvec_fft import ops as fops, ref as fref
from repro.kernels.xcorr_offdiag import ops as xops, ref as xref


def _views(n, d, dtype=jnp.float32, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return (
        jax.random.normal(k1, (n, d)).astype(dtype),
        jax.random.normal(k2, (n, d)).astype(dtype),
    )


GROUPED_CASES = [
    (8, 16, 4, 1), (8, 16, 4, 2), (16, 40, 8, 2), (16, 40, 7, 1),
    (4, 64, 16, 2), (32, 24, 24, 2), (5, 33, 8, 2),
]


class TestGroupedSumvecKernel:
    @pytest.mark.parametrize("n,d,b,q", GROUPED_CASES)
    def test_matches_oracle(self, n, d, b, q):
        z1, z2 = _views(n, d)
        got = gops.r_sum_kernel(z1, z2, block_size=b, q=q, scale=n)
        want = gref.r_sum_grouped_ref(z1, z2, b, q=q, scale=n)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        z1, z2 = _views(8, 32, dtype)
        got = gops.r_sum_kernel(z1, z2, block_size=8, q=2, scale=8)
        want = gref.r_sum_grouped_ref(z1.astype(jnp.float32), z2.astype(jnp.float32), 8, q=2, scale=8)
        tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
        np.testing.assert_allclose(got, want, rtol=tol, atol=tol)

    @pytest.mark.parametrize("q", [1, 2])
    def test_grads_match_pure_jnp(self, q):
        n, d, b = 8, 24, 8
        z1, z2 = _views(n, d, seed=3)
        gk = jax.grad(lambda a, c: gops.r_sum_kernel(a, c, block_size=b, q=q, scale=n), argnums=(0, 1))(z1, z2)
        gj = jax.grad(lambda a, c: regs.r_sum_grouped(a, c, b, q=q, scale=n), argnums=(0, 1))(z1, z2)
        np.testing.assert_allclose(gk[0], gj[0], atol=1e-4)
        np.testing.assert_allclose(gk[1], gj[1], atol=1e-4)

    def test_block_covering_d_matches_ungrouped(self):
        z1, z2 = _views(8, 16)
        got = gops.r_sum_kernel(z1, z2, block_size=None, q=2, scale=8)
        want = gref.r_sum_ref(z1, z2, q=2, scale=8)
        np.testing.assert_allclose(got, want, rtol=1e-4)


FOURSTEP_CASES = [(4, 12), (8, 24), (16, 36), (8, 64), (3, 25)]


class TestFourStepKernel:
    @pytest.mark.parametrize("n,d", FOURSTEP_CASES)
    def test_spectrum_layout(self, n, d):
        d1, d2 = fops.choose_factors(d)
        x = jax.random.normal(jax.random.PRNGKey(0), (n, d))
        fr, fi = fops.four_step_fft(x, d1, d2)
        ours = (fr + 1j * fi).transpose(0, 2, 1).reshape(n, d)
        np.testing.assert_allclose(ours, fref.spectrum_ref(x), atol=1e-3)

    @pytest.mark.parametrize("n,d", FOURSTEP_CASES)
    @pytest.mark.parametrize("q", [1, 2])
    def test_r_sum_matches_oracle(self, n, d, q):
        z1, z2 = _views(n, d, seed=1)
        got = fops.r_sum_fourstep(z1, z2, q=q, scale=n)
        want = fref.r_sum_ref(z1, z2, q=q, scale=n)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    def test_sumvec_values(self):
        z1, z2 = _views(8, 40, seed=2)
        np.testing.assert_allclose(
            fops.sumvec_fourstep(z1, z2, scale=8),
            fref.sumvec_ref(z1, z2, scale=8),
            atol=1e-4,
        )

    def test_grads_match_pure_jnp(self):
        n, d = 8, 24
        z1, z2 = _views(n, d, seed=4)
        gk = jax.grad(lambda a, b: fops.r_sum_fourstep(a, b, q=2, scale=n), argnums=(0, 1))(z1, z2)
        gj = jax.grad(lambda a, b: regs.r_sum(a, b, q=2, scale=n), argnums=(0, 1))(z1, z2)
        np.testing.assert_allclose(gk[0], gj[0], atol=1e-4)
        np.testing.assert_allclose(gk[1], gj[1], atol=1e-4)

    def test_ifft_roundtrip(self):
        d1, d2 = 4, 6
        s = jax.random.normal(jax.random.PRNGKey(5), (1, 24))
        fr, fi = fops.four_step_fft(s, d1, d2)
        back = fops.four_step_ifft(fr[0], fi[0], d1, d2)
        np.testing.assert_allclose(back.reshape(-1), s[0], atol=1e-5)


XCORR_CASES = [(8, 16), (16, 40), (64, 16), (24, 128), (7, 33)]


class TestXCorrKernel:
    @pytest.mark.parametrize("n,d", XCORR_CASES)
    def test_matches_oracle(self, n, d):
        z1, z2 = _views(n, d, seed=6)
        got = xops.off_diagonal_sq_sum(z1, z2, scale=n)
        want = xref.off_diagonal_sq_sum_ref(z1, z2, scale=n)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("n,d", [(8, 16), (32, 8)])
    def test_grads_both_gram_branches(self, n, d):
        z1, z2 = _views(n, d, seed=7)
        gk = jax.grad(lambda a, b: xops.off_diagonal_sq_sum(a, b, scale=n), argnums=(0, 1))(z1, z2)
        gr = jax.grad(lambda a, b: xref.off_diagonal_sq_sum_ref(a, b, scale=n), argnums=(0, 1))(z1, z2)
        np.testing.assert_allclose(gk[0], gr[0], atol=1e-4)
        np.testing.assert_allclose(gk[1], gr[1], atol=1e-4)

    def test_gram_forward(self):
        z1, z2 = _views(16, 48, seed=8)
        np.testing.assert_allclose(
            xops.r_off_gram(z1, z2, scale=16.0),
            xref.off_diagonal_sq_sum_ref(z1, z2, scale=16.0),
            rtol=1e-4,
        )

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        z1, z2 = _views(16, 32, dtype, seed=9)
        got = xops.off_diagonal_sq_sum(z1, z2, scale=16.0)
        assert bool(jnp.isfinite(got))


class TestKernelLossIntegration:
    def test_bt_loss_with_kernels(self):
        from repro.core import losses as L

        z1, z2 = _views(16, 32, seed=10)
        cfg_k = L.DecorrConfig(style="bt", reg="sum", block_size=8, q=2, use_kernel=True, permute=False)
        cfg_j = L.DecorrConfig(style="bt", reg="sum", block_size=8, q=2, use_kernel=False, permute=False)
        lk, _ = L.barlow_twins_loss(z1, z2, cfg_k)
        lj, _ = L.barlow_twins_loss(z1, z2, cfg_j)
        np.testing.assert_allclose(lk, lj, rtol=1e-4)

    def test_bt_loss_baseline_kernel(self):
        from repro.core import losses as L

        z1, z2 = _views(16, 32, seed=11)
        cfg_k = L.DecorrConfig(style="bt", reg="off", use_kernel=True)
        cfg_j = L.DecorrConfig(style="bt", reg="off", use_kernel=False)
        lk, _ = L.barlow_twins_loss(z1, z2, cfg_k)
        lj, _ = L.barlow_twins_loss(z1, z2, cfg_j)
        np.testing.assert_allclose(lk, lj, rtol=1e-4)
