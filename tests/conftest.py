import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running CPU training tests")


@pytest.fixture(autouse=True, scope="session")
def _isolated_tune_cache(tmp_path_factory):
    """Point the repro.tune JSON cache at a throwaway dir for the whole run:
    tests never read a developer's pre-tuned cache nor write to ~/.cache."""
    import os

    path = tmp_path_factory.mktemp("repro-tune-cache")
    old = os.environ.get("REPRO_TUNE_CACHE")
    os.environ["REPRO_TUNE_CACHE"] = str(path)
    yield
    if old is None:
        os.environ.pop("REPRO_TUNE_CACHE", None)
    else:
        os.environ["REPRO_TUNE_CACHE"] = old
