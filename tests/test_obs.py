"""repro.obs — the unified telemetry stack.

Primitive-level contracts first (registry types, exposition grammar, alert
edge-triggering, flight-recorder wraparound, tracer export), then the
integration the subsystem exists for: a mixed LM workload whose legacy
``metrics()`` dict, Prometheus scrape, Chrome trace and flight-recorder dump
all tell the same story — and a synthetic probe-drift crossing that fires
its alert exactly once and clears on recovery."""

import json
import math
import urllib.request

import numpy as np
import pytest

from repro.obs import (
    AlertManager,
    AlertRule,
    ExecTimer,
    FlightRecorder,
    MetricsRegistry,
    Obs,
    Profiler,
    Tracer,
    default_serve_rules,
    default_train_rules,
    quantile_from_buckets,
    reconstruct_request,
    sanitize_name,
)


# ---------------------------------------------------------------------------
# Registry primitives
# ---------------------------------------------------------------------------


class TestRegistry:
    def test_counter_monotone(self):
        reg = MetricsRegistry()
        c = reg.counter("requests_total")
        c.inc()
        c.inc(2.5)
        assert c.value == 3.5
        with pytest.raises(ValueError):
            c.inc(-1)

    def test_gauge_set_inc_dec(self):
        g = MetricsRegistry().gauge("depth")
        g.set(4)
        g.inc()
        g.dec(2)
        assert g.value == 3.0

    def test_histogram_bucket_boundaries(self):
        h = MetricsRegistry().histogram("lat", buckets=(0.1, 1.0, 10.0))
        for v in (0.1, 0.05, 0.5, 5.0, 50.0):  # 0.1 lands IN le=0.1 (<=)
            h.observe(v)
        cum = h._default_child().bucket_counts()
        assert [(le, c) for le, c in cum] == [
            (0.1, 2), (1.0, 3), (10.0, 4), (math.inf, 5)
        ]
        assert h.count == 5 and h.sum == pytest.approx(55.65)

    def test_histogram_rejects_bad_buckets(self):
        reg = MetricsRegistry()
        with pytest.raises(ValueError):
            reg.histogram("h1", buckets=())
        with pytest.raises(ValueError):
            reg.histogram("h2", buckets=(1.0, 1.0))

    def test_label_cardinality_guard(self):
        reg = MetricsRegistry(max_label_sets=3)
        c = reg.counter("hits", labelnames=("path",))
        for i in range(3):
            c.labels(path=f"/p{i}").inc()
        c.labels(path="/p0").inc()  # existing set: fine
        with pytest.raises(ValueError, match="cardinality"):
            c.labels(path="/p3")

    def test_type_conflict_raises(self):
        reg = MetricsRegistry()
        reg.counter("x")
        with pytest.raises(ValueError, match="already registered"):
            reg.gauge("x")
        with pytest.raises(ValueError, match="labelnames"):
            reg.counter("x", labelnames=("a",))

    def test_sanitize_name(self):
        assert sanitize_name("heartbeat_age_s:serve.dispatch") == \
            "heartbeat_age_s_serve_dispatch"
        assert sanitize_name("9lives") == "_9lives"

    def test_publish_and_value(self):
        reg = MetricsRegistry()
        reg.publish({"tok_per_s": 12.5, "decorr.r_off": 0.1})
        assert reg.value("tok_per_s") == 12.5
        assert reg.value("decorr_r_off") == 0.1
        assert reg.value("missing") is None

    def test_exposition_round_trip(self):
        reg = MetricsRegistry()
        reg.counter("served_total", "requests served").inc(7)
        reg.gauge("queue_depth").set(3)
        reg.histogram("step_s", buckets=(0.5,)).observe(0.2)
        g = reg.gauge("err", labelnames=("kind",))
        g.labels(kind='dev"ice\n').set(1)
        text = reg.exposition()
        assert "# HELP served_total requests served" in text
        assert "# TYPE served_total counter" in text
        assert "served_total 7" in text.splitlines()
        assert 'step_s_bucket{le="0.5"} 1' in text
        assert 'step_s_bucket{le="+Inf"} 1' in text
        assert "step_s_count 1" in text.splitlines()
        assert 'err{kind="dev\\"ice\\n"} 1' in text.splitlines()
        # every sample line parses as <name>[{labels}] <float>
        for line in text.splitlines():
            if line.startswith("#") or not line:
                continue
            name, value = line.rsplit(" ", 1)
            float(value.replace("+Inf", "inf"))
            assert sanitize_name(name.split("{")[0]) == name.split("{")[0]

    def test_as_dict_matches_values(self):
        reg = MetricsRegistry()
        reg.publish({"a": 1.0, "b": 2.0})
        reg.histogram("h").observe(0.3)
        d = reg.as_dict()
        assert d["a"] == 1.0 and d["b"] == 2.0
        assert d["h_count"] == 1.0 and "h_bucket" not in str(sorted(d))

    def test_quantile_from_buckets_interpolates(self):
        bounds = (1.0, 2.0, 4.0)
        # 2 obs in (0,1], 2 in (1,2], none in (2,4], 0 in +Inf
        counts = (2, 2, 0, 0)
        # rank q*total from 0 at the holding bucket's LOWER bound
        assert quantile_from_buckets(bounds, counts, 0.5) == pytest.approx(1.0)
        assert quantile_from_buckets(bounds, counts, 0.25) == pytest.approx(0.5)
        assert quantile_from_buckets(bounds, counts, 0.75) == pytest.approx(1.5)
        assert quantile_from_buckets(bounds, counts, 1.0) == pytest.approx(2.0)

    def test_quantile_from_buckets_edges(self):
        assert quantile_from_buckets((1.0, 2.0), (0, 0, 0), 0.99) == 0.0  # empty
        # everything in the +Inf bucket clamps to the top finite bound
        assert quantile_from_buckets((1.0, 2.0), (0, 0, 5), 0.99) == 2.0
        with pytest.raises(ValueError, match="quantile"):
            quantile_from_buckets((1.0,), (1, 0), 1.5)
        with pytest.raises(ValueError, match="quantile"):
            quantile_from_buckets((1.0,), (1, 0), -0.1)

    def test_quantile_from_buckets_single_bucket(self):
        # one finite bucket holding all the mass: every quantile interpolates
        # within (0, bound]
        assert quantile_from_buckets((2.0,), (4, 0), 0.0) == pytest.approx(0.0)
        assert quantile_from_buckets((2.0,), (4, 0), 0.5) == pytest.approx(1.0)
        assert quantile_from_buckets((2.0,), (4, 0), 1.0) == pytest.approx(2.0)
        # a single observation degenerates to the bucket's upper bound at q=1
        assert quantile_from_buckets((2.0,), (1, 0), 1.0) == pytest.approx(2.0)

    def test_label_cardinality_overflow_keeps_existing_children(self):
        reg = MetricsRegistry(max_label_sets=2)
        c = reg.counter("hits", labelnames=("path",))
        c.labels(path="/a").inc()
        c.labels(path="/b").inc(2)
        with pytest.raises(ValueError, match="cardinality"):
            c.labels(path="/c")
        # the overflow attempt must not corrupt or evict live children
        c.labels(path="/a").inc()
        assert reg.value("hits", {"path": "/a"}) == 2.0
        assert reg.value("hits", {"path": "/b"}) == 2.0
        text = reg.exposition()
        assert 'hits{path="/a"} 2' in text and 'hits{path="/c"}' not in text
        # and a second overflow attempt still raises (no partial registration)
        with pytest.raises(ValueError, match="cardinality"):
            c.labels(path="/c")

    def test_histogram_quantile_and_derived_gauges(self):
        reg = MetricsRegistry()
        h = reg.histogram("step_s", buckets=(0.1, 1.0, 10.0))
        for v in (0.05, 0.2, 0.4, 0.9, 20.0):
            h.observe(v)
        assert 0.1 < h.quantile(0.5) < 1.0
        derived = reg.quantile_gauges()
        assert derived["step_s_p50"] == pytest.approx(h.quantile(0.5))
        assert derived["step_s_p99"] == 10.0  # +Inf rank clamps to top bound
        # labelled histograms are skipped (cross-series aggregation is out of
        # scope), unlabelled non-histograms contribute nothing
        lab = reg.histogram("lat_s", labelnames=("path",))
        lab.labels(path="/a").observe(0.3)
        reg.gauge("depth").set(2)
        assert set(reg.quantile_gauges()) == {"step_s_p50", "step_s_p99"}

    def test_scrape_derives_quantiles_and_fires_ttft_alert(self):
        obs = Obs(alerts=AlertManager(default_serve_rules()))
        h = obs.registry.histogram("serve_ttft_seconds", "ttft")
        for _ in range(4):
            h.observe(30.0)  # p99 lands far above the 5s threshold
        rule = next(r for r in default_serve_rules() if r.name == "ttft_p99_high")
        for _ in range(rule.window):
            obs.scrape()
        assert "ttft_p99_high" in obs.alerts.active()
        assert obs.registry.value("serve_ttft_seconds_p99") > 5.0


# ---------------------------------------------------------------------------
# Alerts: edge-triggered threshold rules
# ---------------------------------------------------------------------------


class TestAlerts:
    def test_fire_once_per_crossing_and_clear(self):
        events = []
        am = AlertManager(
            [AlertRule("drift", "m", ">", 1.0)], sink=events.append
        )
        for v in (2.0, 3.0, 4.0):  # one crossing, three breaching scrapes
            am.evaluate({"m": v})
        assert [e["type"] for e in events] == ["fire"]
        am.evaluate({"m": 0.5})  # recovery: single clear
        am.evaluate({"m": 0.5})
        assert [e["type"] for e in events] == ["fire", "clear"]
        am.evaluate({"m": 9.0})  # re-crossing fires again
        assert [e["type"] for e in events] == ["fire", "clear", "fire"]
        st = am.state("drift")
        assert st.fired == 2 and st.cleared == 1

    def test_window_needs_consecutive_breaches(self):
        events = []
        am = AlertManager(
            [AlertRule("w", "m", ">", 1.0, window=3)], sink=events.append
        )
        am.evaluate({"m": 2.0})
        am.evaluate({"m": 2.0})
        am.evaluate({"m": 0.0})  # streak broken before the window filled
        am.evaluate({"m": 2.0})
        am.evaluate({"m": 2.0})
        assert events == []
        am.evaluate({"m": 2.0})  # third consecutive breach
        assert [e["type"] for e in events] == ["fire"]

    def test_missing_metric_leaves_rule_untouched(self):
        events = []
        am = AlertManager([AlertRule("a", "m", ">", 1.0)], sink=events.append)
        am.evaluate({"m": 5.0})
        am.evaluate({"other": 0.0})  # m absent: no false clear
        assert [e["type"] for e in events] == ["fire"]
        assert am.active() == ["a"]

    def test_from_config_and_validation(self, tmp_path):
        rules = [{"name": "r1", "metric": "m", "op": "<", "threshold": 0.1,
                  "window": 2, "severity": "critical"}]
        am = AlertManager.from_config(json.dumps(rules))
        assert am.rules[0].severity == "critical"
        path = tmp_path / "alerts.json"
        path.write_text(json.dumps(rules))
        assert AlertManager.from_config(str(path)).rules[0].window == 2
        with pytest.raises(ValueError, match="comparator"):
            AlertRule("bad", "m", "~", 1.0).validate()
        with pytest.raises(ValueError, match="duplicate"):
            AlertManager([AlertRule("x", "m", ">", 1), AlertRule("x", "m", ">", 2)])

    def test_publish_labelled_gauges(self):
        reg = MetricsRegistry()
        am = AlertManager([AlertRule("drift", "m", ">", 1.0)])
        am.evaluate({"m": 2.0})
        am.publish(reg)
        assert reg.value("alert_active", {"alert": "drift"}) == 1.0
        assert reg.value("alert_fired_total", {"alert": "drift"}) == 1.0
        assert reg.value("alerts_active") == 1.0

    def test_fired_counter_survives_clears_between_scrapes(self):
        reg = MetricsRegistry()
        am = AlertManager([AlertRule("flap", "m", ">", 1.0)])
        am.publish(reg)  # zero-valued series exists before any firing
        assert reg.value("obs_alerts_fired_total", {"rule": "flap"}) == 0.0
        for _ in range(3):  # three full fire/clear flaps
            am.evaluate({"m": 5.0})
            am.evaluate({"m": 0.0})
        am.publish(reg)
        # the gauge view says "not active" but the counter keeps the history
        assert reg.value("alert_active", {"alert": "flap"}) == 0.0
        assert reg.value("obs_alerts_fired_total", {"rule": "flap"}) == 3.0
        am.publish(reg)  # republish without new firings must not double-count
        assert reg.value("obs_alerts_fired_total", {"rule": "flap"}) == 3.0

    def test_default_train_rules_target_health_gauges(self):
        rules = {r.name: r for r in default_train_rules()}
        assert rules["train_variance_collapse"].metric == "train_decorr_feat_var_ema"
        assert rules["train_variance_collapse"].severity == "critical"
        assert (rules["train_relaxation_gap_blowup"].metric
                == "train_decorr_relaxation_gap_ema")
        for r in rules.values():
            r.validate()

    def test_default_serve_rules_target_live_gauges(self):
        names = {r.metric for r in default_serve_rules()}
        assert "decorr_r_sum_norm_ema" in names
        assert "heartbeat_stale" in names
        # TTFT alerts read the scrape-derived histogram quantile gauge, not
        # the service's parallel rolling-window percentile
        assert "serve_ttft_seconds_p99" in names
        assert "paged_pages_utilization" in names


# ---------------------------------------------------------------------------
# Flight recorder
# ---------------------------------------------------------------------------


class TestFlightRecorder:
    def test_ring_wraparound_keeps_newest(self):
        rec = FlightRecorder(capacity=4)
        for i in range(10):
            rec.record("tick", i=i)
        assert len(rec) == 4 and rec.recorded_total == 10 and rec.dropped == 6
        evs = rec.events()
        assert [e["i"] for e in evs] == [6, 7, 8, 9]
        assert [e["seq"] for e in evs] == [6, 7, 8, 9]  # seq survives the wrap

    def test_disabled_recorder_is_noop(self):
        rec = FlightRecorder(capacity=0)
        rec.record("tick")
        assert len(rec) == 0 and rec.events() == [] and not rec.enabled

    def test_filter_counts_dump(self, tmp_path):
        rec = FlightRecorder(capacity=16)
        rec.record("admit", slot=0)
        rec.record("retire", slot=0)
        rec.record("admit", slot=1)
        assert rec.counts() == {"admit": 2, "retire": 1}
        assert [e["slot"] for e in rec.events("admit")] == [0, 1]
        path = rec.dump_json(str(tmp_path / "fr.json"))
        dump = json.loads(open(path).read())
        assert dump["recorded_total"] == 3 and len(dump["events"]) == 3


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------


class TestTracer:
    def test_request_lifecycle_spans(self):
        t = Tracer()
        rt = t.start_request("lm", prompt_len=8)
        rt.mark_admit(slot=0)
        rt.mark_first()
        rt.tick(); rt.tick(); rt.tick()
        rt.mark_done()
        rec = reconstruct_request(t.to_chrome(), rt.rid)
        assert rec["phases"] == ["queue", "prefill", "decode"]
        assert rec["ticks"] == 3 and rec["retired"] and rec["status"] == "ok"
        assert rt.latency_s >= rt.ttft_s >= rt.queue_s >= 0

    def test_reconstruct_missing_request_raises(self):
        t = Tracer()
        with pytest.raises(KeyError):
            reconstruct_request(t.to_chrome(), 99)

    def test_disabled_tracer_marks_still_time(self):
        t = Tracer(enabled=False)
        rt = t.start_request("lm")
        rt.mark_admit(); rt.mark_first(); rt.mark_done()
        assert rt.latency_s is not None  # marks are the timing source
        assert len(t) == 0  # but no events buffered

    def test_write_chrome_json(self, tmp_path):
        t = Tracer()
        with t.span("decode_step", lanes=4):
            pass
        t.instant("retire", request_id=0)
        path = t.write(str(tmp_path / "trace.json"))
        dump = json.loads(open(path).read())
        names = [e["name"] for e in dump["traceEvents"]]
        assert names == ["decode_step", "retire"]
        assert dump["traceEvents"][0]["ph"] == "X"

    def test_bounded_buffer_drops_oldest(self):
        t = Tracer(capacity=2)
        for i in range(5):
            t.instant("e", i=i)
        assert len(t) == 2 and t.dropped_events == 3


# ---------------------------------------------------------------------------
# Obs bundle + HTTP endpoint + profiler
# ---------------------------------------------------------------------------


class TestObsBundle:
    def test_scrape_evaluates_rules_and_dumps_recorder(self, tmp_path):
        obs = Obs(alerts=AlertManager(default_serve_rules()),
                  dump_dir=str(tmp_path))
        obs.recorder.record("tick", i=1)
        bad = {"decorr_r_sum_norm_ema": 0.9}
        for _ in range(3):  # window=3 on the drift rule
            text = obs.scrape(lambda: bad)
        assert obs.alerts.active() == ["probe_r_sum_drift"]
        dumps = list(tmp_path.glob("flightrec_probe_r_sum_drift_*.json"))
        assert len(dumps) == 1  # edge-triggered: one fire, one dump
        assert json.loads(dumps[0].read_text())["events"][0]["kind"] == "tick"
        assert 'alert_active{alert="probe_r_sum_drift"} 1' in text
        obs.scrape(lambda: {"decorr_r_sum_norm_ema": 0.0})
        assert obs.alerts.active() == []

    def test_disabled_obs_turns_hot_paths_off(self):
        obs = Obs.disabled()
        assert not obs.tracer.enabled and not obs.recorder.enabled
        rt = obs.tracer.start_request("lm")
        rt.mark_done()
        assert rt.latency_s is not None and len(obs.tracer) == 0
        assert obs.metrics()["obs_enabled"] == 0.0

    def test_http_endpoint(self):
        obs = Obs(alerts=AlertManager([AlertRule("a", "m", ">", 1.0)]))
        server = obs.start_server(port=0, metrics_fn=lambda: {"m": 5.0})
        try:
            base = server.url
            text = urllib.request.urlopen(base + "/metrics", timeout=10).read().decode()
            assert "m 5" in text and "alerts_fired_total 1" in text
            alerts = json.loads(
                urllib.request.urlopen(base + "/alerts", timeout=10).read()
            )
            assert alerts[0]["alert"] == "a" and alerts[0]["active"]
            assert urllib.request.urlopen(base + "/healthz", timeout=10).read() == b"ok\n"
            with pytest.raises(urllib.error.HTTPError):
                urllib.request.urlopen(base + "/nope", timeout=10)
        finally:
            server.stop()

    def test_profiler_noop_without_dir(self):
        p = Profiler()
        assert p.start() is False and p.stop() is None
        assert p.metrics()["profiler_active"] == 0.0

    def test_perf_and_flight_endpoints(self):
        obs = Obs()
        obs.perf.attach_analysis("decode", flops=2e9, hbm_bytes=1e8)
        obs.perf.observe("decode", 0.004)
        obs.perf.observe("decode", 0.002)
        obs.recorder.record("admit", slot=1)
        server = obs.start_server(port=0)
        try:
            base = server.url
            perf = json.loads(urllib.request.urlopen(base + "/perf", timeout=10).read())
            assert perf["executables"] == 1 and perf["observed_total"] == 2
            row = perf["top"][0]
            assert row["executable"] == "decode" and row["calls"] == 2
            assert 0.0 < row["roofline_utilization"] <= 1.0
            assert row["best_s"] == pytest.approx(0.002)
            flight = json.loads(urllib.request.urlopen(base + "/flight", timeout=10).read())
            assert flight["recorded_total"] == 1
            assert flight["events"][0]["kind"] == "admit"
            # the scrape path mirrors the roofline join as labelled gauges
            urllib.request.urlopen(base + "/metrics", timeout=10).read()
            assert obs.registry.value(
                "exec_roofline_utilization", {"executable": "decode"}
            ) == pytest.approx(row["roofline_utilization"])
        finally:
            server.stop()


# ---------------------------------------------------------------------------
# ExecTimer: per-executable attribution + the analytic roofline join
# ---------------------------------------------------------------------------


class TestExecTimer:
    def test_observe_tracks_calls_total_best(self):
        t = ExecTimer()
        for s in (0.03, 0.01, 0.02):
            t.observe("step", s)
        (row,) = t.snapshot()
        assert row["calls"] == 3
        assert row["total_s"] == pytest.approx(0.06)
        assert row["best_s"] == pytest.approx(0.01)
        assert row["mean_s"] == pytest.approx(0.02)
        assert "roofline_utilization" not in row  # no analysis attached yet
        assert t.registry.get("exec_seconds").labels(executable="step").count == 3

    def test_analysis_join_derives_roofline_fields(self):
        t = ExecTimer()
        t.attach_analysis("step", flops=1e9, hbm_bytes=4e6, compile_s=0.5)
        t.observe("step", 1e-3)
        (row,) = t.snapshot()
        # achieved rates come from the BEST measured time
        assert row["achieved_gflops"] == pytest.approx(1e9 / 1e-3 / 1e9)
        assert row["achieved_gbps"] == pytest.approx(4e6 / 1e-3 / 1e9)
        assert 0.0 < row["roofline_utilization"] <= 1.0
        # measured/analytic disagreement: CPU-measured vs TPU-analytic >> 1
        assert row["disagreement"] == pytest.approx(
            1e-3 / row["bound_s"]
        )
        assert row["compile_s"] == 0.5
        assert row["dominant"] in ("compute", "memory", "collective")

    def test_utilization_clamps_to_one(self):
        t = ExecTimer()
        # analytic bound far ABOVE the measured time (pessimistic model):
        # the gauge clamps at 1.0 instead of reporting >100% of roofline
        t.attach_analysis("fast", flops=0.0, hbm_bytes=0.0, bound_s=10.0)
        t.observe("fast", 1e-3)
        (row,) = t.snapshot()
        assert row["roofline_utilization"] == 1.0

    def test_snapshot_sorts_by_total_and_top_k(self):
        t = ExecTimer()
        t.observe("minor", 0.001)
        for _ in range(5):
            t.observe("major", 0.1)
        rows = t.snapshot()
        assert [r["executable"] for r in rows] == ["major", "minor"]
        assert [r["executable"] for r in t.snapshot(top_k=1)] == ["major"]
        rep = t.report(top_k=1)
        assert rep["executables"] == 2 and len(rep["top"]) == 1

    def test_publish_emits_labelled_gauges(self):
        reg = MetricsRegistry()
        t = ExecTimer(reg)
        t.attach_analysis("step", flops=1e9, hbm_bytes=1e6)
        t.observe("step", 0.01)
        t.publish()
        lbl = {"executable": "step"}
        assert reg.value("exec_wall_seconds_total", lbl) == pytest.approx(0.01)
        assert reg.value("exec_calls_total", lbl) == 1.0
        assert 0.0 < reg.value("exec_roofline_utilization", lbl) <= 1.0
        assert reg.value("exec_analytic_disagreement", lbl) > 1.0

    def test_cache_hit_miss_counters(self):
        t = ExecTimer()
        t.cache_miss("embed_b32")
        t.cache_hit("embed_b32")
        t.cache_hit("embed_b32")
        assert t.registry.value(
            "exec_cache_hits_total", {"executable": "embed_b32"}) == 2.0
        assert t.registry.value(
            "exec_cache_misses_total", {"executable": "embed_b32"}) == 1.0

    def test_disabled_timer_is_inert(self):
        t = ExecTimer(enabled=False)
        t.observe("x", 1.0)
        t.cache_hit("x")
        t.attach_analysis("x", flops=1.0, hbm_bytes=1.0)
        assert t.snapshot() == [] and t.analyzed == 0
        assert t.metrics()["perf_observed_total"] == 0.0

    def test_attach_jit_parses_real_hlo(self):
        import jax
        import jax.numpy as jnp

        fn = jax.jit(lambda a, b: a @ b)
        x = jnp.ones((32, 32), jnp.float32)
        t = ExecTimer()
        assert t.attach_jit("matmul", fn, x, x)
        t.observe("matmul", 1e-3)
        (row,) = t.snapshot()
        assert row["flops"] > 0 and row["bound_s"] > 0
        assert 0.0 < row["roofline_utilization"] <= 1.0
        assert row["compile_s"] > 0  # the AOT lower+compile was timed
        # idempotent: re-attaching the same name is a no-op that reports True
        assert t.attach_jit("matmul", fn, x, x)

    def test_attach_compiled_tolerates_bad_backends(self):
        class NoText:
            def as_text(self):
                raise RuntimeError("no HLO here")

        t = ExecTimer()
        assert t.attach_compiled("weird", NoText()) is False
        assert t.analyzed == 0


# ---------------------------------------------------------------------------
# DecorrHealthMonitor: the train-side collapse watchdog
# ---------------------------------------------------------------------------


class TestDecorrHealthMonitor:
    def _monitor(self, **kw):
        from repro.obs import DecorrHealthMonitor

        # ema=0 -> every indicator tracks the latest batch exactly, so a
        # synthetic collapse registers on the first observation
        kw.setdefault("ema", 0.0)
        return DecorrHealthMonitor(**kw)

    def test_healthy_stream_reports_unit_variance(self):
        mon = self._monitor()
        rng = np.random.default_rng(0)
        m = mon.observe(rng.standard_normal((64, 16)).astype(np.float32))
        assert m["train_decorr_feat_var_ema"] > 0.5
        assert m["train_decorr_collapsed_frac"] == 0.0
        assert "train_decorr_relaxation_gap" in m  # d=16 affords exact R_off
        assert m["train_decorr_updates"] == 1.0

    def test_collapse_indicators_and_histogram(self):
        reg = MetricsRegistry()
        mon = self._monitor()
        z = np.ones((32, 16), np.float32)  # zero-variance features: collapse
        m = mon.observe(z, registry=reg)
        assert m["train_decorr_feat_var_ema"] < 1e-6
        assert m["train_decorr_collapsed_frac"] == 1.0
        assert m["train_decorr_feat_var_min_ema"] < 1e-6
        h = reg.get("train_feat_var")
        assert h.count == 16  # one sample per feature
        assert reg.value("train_decorr_feat_var_ema") == pytest.approx(
            m["train_decorr_feat_var_ema"], abs=1e-9
        )

    def test_update_embeds_with_params(self):
        mon = self._monitor(embed_fn=lambda params, batch: batch * params)

        class State:
            params = 2.0

        rng = np.random.default_rng(1)
        m = mon.update(State(), rng.standard_normal((16, 8)).astype(np.float32), step=5)
        assert m["train_decorr_step"] == 5.0 and mon.updates == 1
        with pytest.raises(ValueError, match="embed_fn"):
            self._monitor().update(State(), np.ones((4, 4), np.float32))

    def test_variance_collapse_alert_fires_once_and_clears(self):
        """The acceptance scenario: a synthetic variance-collapse training
        stream fires train_variance_collapse exactly once (edge-triggered,
        window=3) and clears on recovery."""
        obs = Obs(alerts=AlertManager(default_train_rules()))
        mon = self._monitor()
        fired = []
        obs.alerts.sink = fired.append
        rule = next(r for r in default_train_rules()
                    if r.name == "train_variance_collapse")
        # constant features: zero variance (collapse) but modest mean, so the
        # mean-drift rule stays quiet and exactly one rule breaches
        collapsed = np.full((32, 16), 0.25, np.float32)
        for _ in range(rule.window + 1):  # extra scrape must NOT refire
            mon.observe(collapsed, registry=obs.registry)
            obs.scrape()
        assert [e["type"] for e in fired] == ["fire"]
        assert fired[0]["alert"] == "train_variance_collapse"
        assert fired[0]["severity"] == "critical"
        assert obs.registry.value(
            "obs_alerts_fired_total", {"rule": "train_variance_collapse"}) == 1.0
        # recovery: healthy unit-variance embeddings clear the alert
        rng = np.random.default_rng(2)
        mon.observe(rng.standard_normal((32, 16)).astype(np.float32),
                    registry=obs.registry)
        obs.scrape()
        assert [e["type"] for e in fired] == ["fire", "clear"]
        assert obs.alerts.active() == []
        # the firing history survives the clear
        assert obs.registry.value(
            "obs_alerts_fired_total", {"rule": "train_variance_collapse"}) == 1.0


# ---------------------------------------------------------------------------
# Train-loop registry integration (no model needed: duck-typed state)
# ---------------------------------------------------------------------------


def test_train_loop_publishes_registry():
    from repro.train.loop import LoopConfig, run_training

    class State:
        step = 0

    def train_step(state, batch):
        state.step += 1
        return state, {"loss": 0.25}

    reg = MetricsRegistry()
    run_training(State(), train_step, lambda step: None,
                 LoopConfig(total_steps=7, log_interval=2), registry=reg)
    assert reg.value("train_steps_total") == 7.0
    assert reg.get("train_step_seconds").count == 7
    assert reg.value("train_loss") == 0.25
    assert reg.value("train_stragglers") == 0.0
    assert reg.value("train_step_seconds_median") > 0.0


def test_train_loop_phase_timing_perf_and_monitor():
    from repro.obs import DecorrHealthMonitor
    from repro.train.loop import LoopConfig, run_training

    class State:
        step = 0

    def train_step(state, batch):
        state.step += 1
        return state, {"loss": 0.5}

    rng = np.random.default_rng(0)

    def batch_fn(step):
        return rng.standard_normal((16, 8)).astype(np.float32)

    reg = MetricsRegistry()
    perf = ExecTimer(reg)
    # embed_fn ignores the duck-typed state and probes the batch directly
    monitor = DecorrHealthMonitor(lambda params, batch: batch, ema=0.0)
    run_training(State(), train_step, batch_fn,
                 LoopConfig(total_steps=6, log_interval=2),
                 registry=reg, monitor=monitor, perf=perf)
    # every step lands in the phase histograms and the perf attribution
    assert reg.get("train_batch_seconds").count == 6
    assert reg.get("train_publish_seconds").count == 3  # log steps 2, 4, 6
    (row,) = [r for r in perf.snapshot() if r["executable"] == "train_step"]
    assert row["calls"] == 6 and row["total_s"] > 0
    # the health monitor probed at each log interval and published its gauges
    assert monitor.updates == 3
    assert reg.value("train_decorr_updates") == 3.0
    assert reg.value("train_decorr_step") == 6.0
    assert reg.value("train_decorr_feat_var_ema") > 0.5
    assert reg.get("train_feat_var").count == 8 * 3  # d observations per probe


# ---------------------------------------------------------------------------
# Serve integration: one workload, four consistent telemetry views
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def gemma():
    import jax

    from repro.configs import get_config
    from repro.models import init_params

    cfg = get_config("gemma2-2b").reduced()
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


class TestLMServiceObs:
    def _service(self, gemma, obs, **kw):
        from repro.serve import ContinuousLMEngine, LMService

        cfg, params = gemma
        eng = ContinuousLMEngine(
            cfg, params, n_slots=4, max_len=64, max_prompt_len=24,
            paged=True, page_size=16, **kw,
        )
        return LMService(eng, obs=obs)

    def _run(self, svc, cfg, n=6, new_tokens=4, seed=0):
        rng = np.random.default_rng(seed)
        futs = [
            svc.submit(rng.integers(0, cfg.vocab_size, 8).astype(np.int32), new_tokens)
            for _ in range(n)
        ]
        svc.drain()
        for f in futs:
            f.result(timeout=60)
        return futs

    def test_legacy_dict_equals_registry_view(self, gemma):
        obs = Obs()
        svc = self._service(gemma, obs)
        self._run(svc, gemma[0])
        m = svc.metrics()
        # every legacy gauge the PR-5 scrape exported is still present...
        for k in ("queue_depth", "dispatch_errors", "tokens_total", "tok_per_s",
                  "ttft_p50_ms", "ttft_p99_ms", "slots_total", "slots_occupancy",
                  "slots_admitted_total", "slots_retired_total", "latency_p50_ms",
                  "latency_p99_ms", "served_total", "throughput_rps",
                  "heartbeat_stale", "admission_deferred", "paged_pages_in_use",
                  "paged_pages_utilization"):
            assert k in m, f"legacy key {k} vanished from metrics()"
        # ...and the registry mirrors the flat dict, key for key — except the
        # per-name heartbeat ages, which the registry carries as label
        # children of ONE family (heartbeat_age_s{name=}) instead of a
        # family per component
        for k, v in m.items():
            if k.startswith("heartbeat_age_s_"):
                continue
            assert obs.registry.value(k) == pytest.approx(v), k
        assert obs.registry.value("heartbeat_age_s_serve_lm_decode") is None
        hb = svc.heartbeat
        for name in hb._last:
            assert obs.registry.value("heartbeat_age_s", {"name": name}) is not None

    def test_scrape_and_trace_tell_one_story(self, gemma, tmp_path):
        obs = Obs(alerts=AlertManager(default_serve_rules()))
        svc = self._service(gemma, obs)
        futs = self._run(svc, gemma[0])
        text = svc.scrape()
        assert "# TYPE tok_per_s gauge" in text
        assert 'heartbeat_age_s{name="serve.lm_decode"}' in text
        assert "serve_decode_step_seconds_bucket" in text  # step-time histogram
        # the trace reconstructs a full lifecycle: queue -> prefill ->
        # >=1 decode tick -> retire
        path = obs.tracer.write(str(tmp_path / "trace.json"))
        trace = json.loads(open(path).read())
        rec = reconstruct_request(trace, futs[0].trace.rid)
        assert rec["phases"] == ["queue", "prefill", "decode"]
        assert rec["ticks"] >= 1 and rec["retired"]
        # timing unification: the service TTFT gauges come from the same
        # marks the futures carry
        ttfts = sorted(f.trace.ttft_s for f in futs)
        m = svc.metrics()
        assert m["ttft_p50_ms"] == pytest.approx(
            float(np.percentile(np.asarray(ttfts), 50) * 1e3), rel=1e-6
        )
        # flight recorder saw the whole schedule, page churn included
        counts = obs.recorder.counts()
        assert counts["admit"] == len(futs) and counts["retire"] == len(futs)
        assert counts["page_alloc"] >= 1 and counts["page_free"] >= 1

    def test_probe_drift_alert_fires_once_and_clears(self, gemma):
        obs = Obs(alerts=AlertManager(default_serve_rules()))
        svc = self._service(gemma, obs)
        self._run(svc, gemma[0])
        fired = []
        obs.alerts.sink = fired.append
        base = svc.metrics()
        drifted = dict(base, decorr_r_sum_norm_ema=0.9)  # synthetic crossing
        for _ in range(4):  # rule window = 3; extra scrape must NOT refire
            obs.check_alerts(drifted)
        assert [e["type"] for e in fired] == ["fire"]
        assert fired[0]["alert"] == "probe_r_sum_drift"
        obs.check_alerts(dict(base, decorr_r_sum_norm_ema=0.0))
        assert [e["type"] for e in fired] == ["fire", "clear"]
        assert obs.alerts.active() == []

    def test_perf_attribution_joins_serve_executables(self, gemma):
        obs = Obs()
        svc = self._service(gemma, obs)
        assert svc.engine.perf is obs.perf  # service wires the shared timer
        svc.warmup()
        self._run(svc, gemma[0])
        rows = {r["executable"]: r for r in obs.perf.snapshot()}
        for name in ("decode_step", "prefill_b8"):
            assert rows[name]["calls"] >= 1, name
            assert rows[name]["total_s"] > 0, name
            assert 0.0 < rows[name]["roofline_utilization"] <= 1.0, name
        # warmup's AOT lower+compile was timed, and the 8-token prompts all
        # hit the pre-warmed prefill bucket
        assert rows["prefill_b8"]["compile_s"] > 0
        assert obs.registry.value(
            "exec_cache_hits_total", {"executable": "prefill_b8"}) >= 1.0
        # the scrape path mirrors the same derived values as labelled gauges
        svc.scrape()
        assert obs.registry.value(
            "exec_roofline_utilization", {"executable": "decode_step"}
        ) == pytest.approx(rows["decode_step"]["roofline_utilization"])
        assert obs.registry.value(
            "exec_calls_total", {"executable": "decode_step"}
        ) == float(rows["decode_step"]["calls"])

    def test_disabled_obs_serves_identically(self, gemma):
        on = self._run(self._service(gemma, Obs()), gemma[0], seed=3)
        obs = Obs.disabled()
        svc = self._service(gemma, obs)
        assert svc.engine.perf is None  # hot path keeps its sync profile
        off = self._run(svc, gemma[0], seed=3)
        for a, b in zip(on, off):
            assert np.array_equal(a.result(timeout=5), b.result(timeout=5))
        assert len(obs.tracer) == 0 and len(obs.recorder) == 0
        m = svc.metrics()  # the scrape contract holds with telemetry off
        assert "tok_per_s" in m and m["obs_enabled"] == 0.0


class TestEmbeddingServiceObs:
    def test_metrics_registry_and_trace(self):
        import jax

        from repro.serve import EmbeddingService, ServeEngine
        from repro.train.ssl import SSLModelConfig, init_ssl_params

        model = SSLModelConfig(input_dim=8, backbone_widths=(16,),
                               projector_widths=(16, 16))
        params = init_ssl_params(jax.random.PRNGKey(0), model)
        obs = Obs()
        svc = EmbeddingService(ServeEngine(model, params), obs=obs)
        futs = [svc.submit(np.ones(8, np.float32)) for _ in range(3)]
        while svc.run_pending():
            pass
        for f in futs:
            f.result(timeout=10)
        m = svc.metrics()
        for k in ("queue_depth", "compiled_buckets", "latency_p50_ms",
                  "served_total", "heartbeat_stale"):
            assert k in m and obs.registry.value(k) == pytest.approx(m[k]), k
        rec = reconstruct_request(obs.tracer.to_chrome(), futs[0].trace.rid)
        assert rec["phases"] == ["queue", "dispatch"] and rec["retired"]
        assert obs.recorder.counts()["dispatch"] >= 1
