"""Speculative decoding: the n-gram drafter in isolation (suffix-table
hit/miss, self-match skip, budget truncation at the request boundary), the
scratch-page lifecycle on the paging manager (begin/commit/rollback — rollback
must restore the block table and free inventory EXACTLY, property-style over
random accept prefixes), the allocator's pinned-scratch primitives, and the
end-to-end oracle: speculative greedy decode emits BIT-IDENTICAL tokens to
plain paged greedy decode (itself pinned to whole-request ``greedy_generate``).
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.serve import ContinuousLMEngine, LMService
from repro.serve.paging import PageAllocator, PagedKVManager
from repro.serve.spec import (
    SlotDraft,
    SpecConfig,
    SpecStats,
    accept_length,
    draft_budget,
)


@pytest.fixture(scope="module")
def gemma():
    cfg = get_config("gemma2-2b").reduced()
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


# ---------------------------------------------------------------------------
# Drafter (pure python, no jax)
# ---------------------------------------------------------------------------


class TestSlotDraft:
    def test_repeated_ngram_hits_and_continues(self):
        d = SlotDraft(SpecConfig(draft_k=4), [1, 2, 3, 9, 1, 2, 3])
        # suffix (2, 3) matched at its earlier occurrence -> continuation 9 1 2 3
        assert d.propose(4) == [9, 1, 2, 3]
        assert d.propose(2) == [9, 1]
        assert d.draft_hits == 2 and d.drafts == 2
        assert d.hit_rate == 1.0

    def test_miss_on_unseen_suffix(self):
        d = SlotDraft(SpecConfig(), [1, 2, 3, 4, 5])
        assert d.propose(4) == []
        assert d.draft_hits == 0 and d.drafts == 1
        assert d.hit_rate == 0.0

    def test_self_match_is_skipped(self):
        # every n-gram occurs exactly once: the query suffix only matches
        # itself, which must not count as a hit
        d = SlotDraft(SpecConfig(ngram_max=2), [1, 2, 3, 4])
        assert d.propose(3) == []
        # ... but a genuine earlier occurrence of the same suffix does
        d.push(3)
        d.push(4)
        # earlier (3, 4) continues at ctx[4:] = [3, 4]; the third token wraps
        # around the period-2 cycle the match implies
        assert d.propose(3) == [3, 4, 3]

    def test_longest_ngram_wins(self):
        # suffix (7, 8) has an earlier occurrence continuing with 100;
        # suffix (8,) alone also occurs earlier continuing with 200 — the
        # longer match must win
        d = SlotDraft(SpecConfig(ngram_max=2), [7, 8, 100, 8, 200, 7, 8])
        assert d.propose(1) == [100]

    def test_push_after_accept_extends_table(self):
        d = SlotDraft(SpecConfig(ngram_max=1), [5])
        assert d.propose(2) == []
        d.push(6)
        d.push(5)  # now 5 has an earlier occurrence followed by 6
        assert d.propose(2) == [6, 5]
        d.observe_accept(2)
        assert d.accepted_total == 2

    def test_propose_zero_budget_is_a_miss(self):
        d = SlotDraft(SpecConfig(), [1, 1, 1, 1])
        assert d.propose(0) == []

    def test_config_validation(self):
        with pytest.raises(ValueError, match="draft_k"):
            SpecConfig(draft_k=0)
        with pytest.raises(ValueError, match="ngram_min"):
            SpecConfig(ngram_min=3, ngram_max=2)


class TestBudgetAndAcceptance:
    def test_budget_truncates_at_request_boundary(self):
        # k+1 emits must never exceed the remaining token budget — the same
        # bound that keeps verify writes inside rows = prompt + max_new - 1
        assert draft_budget(4, 10, 0) == 4
        assert draft_budget(4, 10, 5) == 4
        assert draft_budget(4, 10, 6) == 3  # only 4 tokens left -> k <= 3
        assert draft_budget(4, 10, 8) == 1
        assert draft_budget(4, 10, 9) == 0  # last token: plain decode
        assert draft_budget(4, 10, 10) == 0  # never negative

    def test_accept_length_prefix_rule(self):
        assert accept_length([5, 6, 7], [5, 6, 7, 8]) == 3  # full accept
        assert accept_length([5, 6, 7], [5, 9, 7, 8]) == 1  # mismatch at 1
        assert accept_length([5, 6, 7], [4, 6, 7, 8]) == 0  # reject all
        assert accept_length([], [4]) == 0  # no draft -> bonus token only
        # outputs shorter than proposed+1 bounds the accept
        assert accept_length([5, 6, 7], [5, 6]) == 1

    def test_stats_metrics_shape(self):
        s = SpecStats()
        s.verify_steps, s.tokens_emitted = 4, 10
        s.tokens_proposed, s.tokens_accepted = 8, 6
        s.drafts, s.draft_hits = 10, 8
        m = s.metrics()
        assert m["spec_accepted_tokens"] == pytest.approx(2.5)
        assert m["spec_acceptance_rate"] == pytest.approx(0.75)
        assert m["spec_draft_hit_rate"] == pytest.approx(0.8)


# ---------------------------------------------------------------------------
# Allocator scratch primitives
# ---------------------------------------------------------------------------


class TestAllocatorScratch:
    def test_alloc_pinned_excluded_from_reservable(self):
        a = PageAllocator(9, 8, 4, 4)  # 8 usable
        scratch = a.alloc_pinned(2)
        assert len(scratch) == 2 and a.pinned_pages == 2
        assert a.can_reserve(48)  # 6 pages still reservable
        assert not a.can_reserve(56)  # 7 would collide with the pinned pair
        with pytest.raises(RuntimeError, match="scratch pages"):
            a.alloc_pinned(7)

    def test_swap_page_transfers_pin_and_page(self):
        a = PageAllocator(9, 8, 4, 4)
        a.reserve(0, 16)
        a.ensure(0, 16)  # table [1, 2]
        [s] = a.alloc_pinned(1)  # page 3, pinned
        old = a.swap_page(0, 1, s)
        assert old == 2 and a.table(0) == [1, 3]
        # pin moved: the displaced page is pinned (it is now scratch), the
        # swapped-in page is live in the table and unpinned
        assert a.pinned_pages == 1
        a.unpin_page(old)  # cannot swap an unpinned page in
        with pytest.raises(RuntimeError, match="pinned"):
            a.swap_page(0, 0, old)


# ---------------------------------------------------------------------------
# Manager scratch lifecycle: begin / commit / rollback
# ---------------------------------------------------------------------------


def _spec_manager(gemma, page=8, draft_k=4):
    cfg, _ = gemma
    return PagedKVManager(cfg, n_slots=2, max_len=32, page=page,
                          spec_draft_k=draft_k)


class TestManagerSpecLifecycle:
    def test_scratch_reserved_at_construction(self, gemma):
        m = _spec_manager(gemma)
        assert m.spec_blocks_per_slot == 2  # page-1+k rows can straddle 2 pages
        assert len(m._spec_free) == 2 * m.spec_blocks_per_slot
        met = m.metrics()
        assert met["paged_spec_scratch_pages"] == len(m._spec_free)
        assert met["paged_spec_scratch_free"] == len(m._spec_free)

    def test_begin_remaps_and_boundary_copy(self, gemma):
        m = _spec_manager(gemma)
        m.admit(0, prompt_len=10, max_new_tokens=8)
        m.ensure_rows(0, 10)
        before = m.table_row(0).copy()
        # pos 9 mid-page: block 1 holds committed rows 8..9 -> must pre-copy
        ticket, copies = m.spec_begin(0, pos=9, k_eff=4)
        assert ticket.blocks == [1]
        assert copies == [(int(before[1]), ticket.scratch[0])]
        # the remap lives on the ticket's private row; the REAL table is
        # untouched until commit, which is what makes rollback exact
        assert ticket.row[1] == ticket.scratch[0]
        np.testing.assert_array_equal(m.table_row(0), before)
        m.spec_rollback(ticket)

    def test_begin_page_aligned_needs_no_copy(self, gemma):
        m = _spec_manager(gemma)
        m.admit(0, prompt_len=8, max_new_tokens=8)
        m.ensure_rows(0, 8)
        ticket, copies = m.spec_begin(0, pos=8, k_eff=4)
        assert copies == []  # block 1 has no committed rows
        m.spec_rollback(ticket)

    def test_rollback_restores_exactly_random_prefixes(self, gemma):
        # property-style: whatever pos/k the verify used, rollback must put
        # the block table AND the scratch inventory back bit-for-bit
        m = _spec_manager(gemma)
        m.admit(0, prompt_len=10, max_new_tokens=20)
        rng = np.random.default_rng(7)
        for _ in range(50):
            pos = int(rng.integers(10, 25))
            m.ensure_rows(0, pos)
            k = int(rng.integers(1, 5))
            table_before = m.table_row(0).copy()
            free_before = sorted(m._spec_free)
            ticket, _ = m.spec_begin(0, pos, k)
            assert len(m._spec_free) == len(free_before) - len(ticket.scratch)
            m.spec_rollback(ticket)
            np.testing.assert_array_equal(m.table_row(0), table_before)
            assert sorted(m._spec_free) == free_before

    def test_commit_swaps_scratch_in_and_keeps_inventory(self, gemma):
        m = _spec_manager(gemma)
        m.admit(0, prompt_len=10, max_new_tokens=20)
        rng = np.random.default_rng(11)
        pos = 10
        n_scratch = len(m._spec_free)
        while pos < 28:
            m.ensure_rows(0, pos)
            k = min(4, 28 - pos)
            ticket, _ = m.spec_begin(0, pos, k)
            a = int(rng.integers(0, k + 1))  # random accepted prefix
            m.spec_commit(ticket, a + 1)
            # committed rows live on the swapped-in (former scratch) pages
            row = m.table_row(0)
            last_block = (pos + a) // m.page
            for b, s in zip(ticket.blocks, ticket.scratch):
                if b <= last_block:
                    assert row[b] == s
            # zero-copy commit never leaks or grows the scratch pool
            assert len(m._spec_free) == n_scratch
            pos += a + 1
        m.release(0)
        # only the permanent scratch pool survives retirement
        assert m.alloc.in_use == len(m._spec_free)
        assert m.alloc.reserved_total == 0


# ---------------------------------------------------------------------------
# End to end: speculative greedy == plain paged greedy == greedy_generate
# ---------------------------------------------------------------------------


SPEC = [(4, 12), (9, 8), (13, 8), (24, 6), (1, 10), (7, 7)]


def _prompts(cfg, spec, seed=0):
    rng = np.random.default_rng(seed)
    return [
        (rng.integers(0, cfg.vocab_size, s).astype(np.int32), m) for s, m in spec
    ]


def _run_service(cfg, params, spec, **engine_kw):
    eng = ContinuousLMEngine(cfg, params, n_slots=4, max_len=48,
                             max_prompt_len=24, **engine_kw)
    svc = LMService(eng)
    svc.warmup(prompt_lens=[len(t) for t, _ in spec])
    futs = [svc.submit(t, m) for t, m in spec]
    svc.drain()
    return [f.result(timeout=10) for f in futs], svc


class TestSpeculativeBitIdentity:
    def test_matches_oracle_and_speculates(self, gemma):
        from repro.train.serve import greedy_generate
        import jax.numpy as jnp

        cfg, params = gemma
        spec = _prompts(cfg, SPEC)
        want = [
            np.asarray(greedy_generate(params, cfg, jnp.asarray(t[None]), m,
                                       max_len=48))[0]
            for t, m in spec
        ]
        outs, svc = _run_service(cfg, params, spec, paged=True, page_size=8,
                                 speculative=True, draft_k=4)
        for w, o in zip(want, outs):
            np.testing.assert_array_equal(o, w)
        m = svc.metrics()
        assert m["spec_verify_steps"] > 0
        assert m["spec_tokens_accepted"] > 0  # random-init loops: drafts land
        # every page accounted for after retirement, scratch intact
        assert m["paged_pages_in_use"] == m["paged_spec_scratch_pages"] == \
            m["paged_spec_scratch_free"]
        assert m["paged_pages_reserved"] == 0.0

    def test_gating_requires_paged_greedy_attention(self, gemma):
        cfg, params = gemma
        with pytest.raises(ValueError, match="paged"):
            ContinuousLMEngine(cfg, params, n_slots=2, max_len=32,
                               max_prompt_len=16, speculative=True)
        with pytest.raises(ValueError, match="greedy"):
            ContinuousLMEngine(cfg, params, n_slots=2, max_len=32,
                               max_prompt_len=16, paged=True, page_size=8,
                               speculative=True, sampling=True)
