"""repro.serve subsystem: buckets, micro-batcher, engine (checkpoint
round-trip, compile cache, sharded execution), online decorrelation probes
(training-oracle agreement, local AND sharded), and the end-to-end service.

Multi-device cases run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (same pattern as
test_decorr_engine) so the main pytest process keeps one CPU device."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import save_checkpoint
from repro.decorr import probe_metrics
from repro.decorr.config import DecorrConfig
from repro.serve import (
    Backpressure,
    BucketPolicy,
    DecorrProbe,
    EmbeddingService,
    LMServeEngine,
    MicroBatcher,
    ServeEngine,
    bucket_for,
    bucket_sizes,
)
from repro.train.ssl import SSLModelConfig, embed, init_ssl_params

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MODEL = SSLModelConfig(input_dim=24, backbone_widths=(32,), projector_widths=(48, 48))


def _params(seed=0):
    return init_ssl_params(jax.random.PRNGKey(seed), MODEL)


# ---------------------------------------------------------------------------
# Buckets
# ---------------------------------------------------------------------------


class TestBuckets:
    def test_ladder_is_geometric_and_aligned(self):
        p = BucketPolicy(max_batch=64, align=8)
        assert bucket_sizes(p) == (8, 16, 32, 64)
        for b in bucket_sizes(p):
            assert b % p.align == 0

    def test_non_power_of_two_max_batch_rounds_up(self):
        p = BucketPolicy(max_batch=50, align=8)
        assert bucket_sizes(p)[-1] == 56
        assert bucket_for(50, p) == 56

    def test_bucket_for_is_smallest_cover(self):
        p = BucketPolicy(max_batch=64, align=8)
        assert bucket_for(1, p) == 8
        assert bucket_for(8, p) == 8
        assert bucket_for(9, p) == 16
        assert bucket_for(64, p) == 64
        assert bucket_for(1000, p) == 64  # clamped to the top bucket


# ---------------------------------------------------------------------------
# Micro-batcher
# ---------------------------------------------------------------------------


class TestMicroBatcher:
    def test_coalesces_fifo_up_to_max_batch(self):
        mb = MicroBatcher(BucketPolicy(max_batch=4, max_wait_ms=0.0))
        futs = [mb.submit(np.full((3,), i, np.float32)) for i in range(6)]
        first = mb.next_batch(timeout=0.0)
        assert [int(r.x[0]) for r in first] == [0, 1, 2, 3]
        second = mb.next_batch(timeout=0.0)
        assert [int(r.x[0]) for r in second] == [4, 5]
        assert mb.next_batch(timeout=0.0) == []
        assert all(not f.done() for f in futs)

    def test_backpressure_raises_when_full(self):
        mb = MicroBatcher(BucketPolicy(max_queue=2, max_wait_ms=0.0))
        mb.submit(np.zeros(3))
        mb.submit(np.zeros(3))
        with pytest.raises(Backpressure):
            mb.submit(np.zeros(3))

    def test_shutdown_flushes_then_signals(self):
        mb = MicroBatcher(BucketPolicy(max_batch=8, max_wait_ms=0.0))
        mb.submit(np.zeros(3))
        mb.shutdown()
        batch = mb.next_batch(timeout=0.0)
        assert len(batch) == 1
        assert mb.next_batch(timeout=0.0) is None

    def test_shutdown_with_full_queue_never_blocks_and_drains(self):
        """Regression: shutdown used to enqueue a sentinel with a blocking
        put — on a full queue that deadlocked the dispatch loop."""
        mb = MicroBatcher(BucketPolicy(max_batch=2, max_queue=2, max_wait_ms=0.0))
        mb.submit(np.zeros(3))
        mb.submit(np.zeros(3))
        mb.shutdown()  # queue full: must return immediately, not block
        with pytest.raises(Backpressure):
            mb.submit(np.zeros(3))  # no admissions after shutdown
        assert len(mb.next_batch(timeout=0.0)) == 2  # queued work still flushes
        assert mb.next_batch(timeout=0.0) is None

    def test_multi_row_requests_counted_by_rows(self):
        mb = MicroBatcher(BucketPolicy(max_batch=4, max_wait_ms=0.0))
        mb.submit(np.zeros((3, 2), np.float32))
        mb.submit(np.zeros((3, 2), np.float32))
        mb.submit(np.zeros((3, 2), np.float32))
        batch = mb.next_batch(timeout=0.0)
        # 3 + 3 >= max_batch: the second request is admitted, the third waits
        assert len(batch) == 2
        assert len(mb.next_batch(timeout=0.0)) == 1


# ---------------------------------------------------------------------------
# Engine: compile cache, padding correctness, checkpoint round-trip
# ---------------------------------------------------------------------------


class TestServeEngine:
    def test_padded_encode_matches_direct_forward(self):
        params = _params()
        eng = ServeEngine(MODEL, params, policy=BucketPolicy(max_batch=16, align=8))
        x = jax.random.normal(jax.random.PRNGKey(1), (5, MODEL.input_dim))
        np.testing.assert_allclose(
            np.asarray(eng.encode(x)), np.asarray(embed(params, x)), rtol=2e-5, atol=2e-6
        )

    def test_compile_cache_bounded_by_ladder(self):
        params = _params()
        eng = ServeEngine(MODEL, params, policy=BucketPolicy(max_batch=16, align=8))
        for n in (1, 3, 8, 9, 11, 16):
            eng.encode(jnp.zeros((n, MODEL.input_dim)))
        assert set(eng.compiled_buckets()) <= set(bucket_sizes(eng.policy))

    def test_oversize_batch_chunks_through_top_bucket(self):
        params = _params()
        eng = ServeEngine(MODEL, params, policy=BucketPolicy(max_batch=8, align=8))
        x = jax.random.normal(jax.random.PRNGKey(2), (19, MODEL.input_dim))
        np.testing.assert_allclose(
            np.asarray(eng.encode(x)), np.asarray(embed(params, x)), rtol=2e-5, atol=2e-6
        )

    def test_warmup_precompiles_every_bucket(self):
        eng = ServeEngine(MODEL, _params(), policy=BucketPolicy(max_batch=16, align=8))
        assert eng.compiled_buckets() == ()
        eng.warmup()
        assert eng.compiled_buckets() == bucket_sizes(eng.policy)

    def test_checkpoint_roundtrip_params_tree(self, tmp_path):
        params = _params(3)
        save_checkpoint(str(tmp_path), 7, params)
        eng = ServeEngine.from_checkpoint(str(tmp_path), MODEL)
        x = jax.random.normal(jax.random.PRNGKey(4), (6, MODEL.input_dim))
        np.testing.assert_allclose(
            np.asarray(eng.encode(x)), np.asarray(embed(params, x)), rtol=2e-5, atol=2e-6
        )

    def test_checkpoint_roundtrip_train_state(self, tmp_path):
        """The train loop's own checkpoint layout serves directly."""
        from repro.optim import adamw
        from repro.train import create_train_state

        params = _params(5)
        state = create_train_state(params, adamw())
        save_checkpoint(str(tmp_path), 42, state)
        eng = ServeEngine.from_checkpoint(str(tmp_path), MODEL)
        x = jax.random.normal(jax.random.PRNGKey(6), (4, MODEL.input_dim))
        np.testing.assert_allclose(
            np.asarray(eng.encode(x)), np.asarray(embed(params, x)), rtol=2e-5, atol=2e-6
        )

    def test_missing_checkpoint_raises(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            ServeEngine.from_checkpoint(str(tmp_path), MODEL)


# ---------------------------------------------------------------------------
# Probes: training-oracle agreement + streaming bookkeeping
# ---------------------------------------------------------------------------


class TestProbes:
    @pytest.mark.parametrize("style,q,block", [("bt", 2, None), ("vic", 1, 16), ("vic", 2, None)])
    def test_probe_matches_training_path_oracle(self, style, q, block):
        """probe r_off/r_sum == the repro.decorr engine computation with the
        training normalization + permutation semantics."""
        from repro.core import permutation as perm_lib
        from repro.core import regularizers as regs
        from repro.decorr import engine as dengine

        cfg = DecorrConfig(style=style, reg="sum", q=q, block_size=block)
        key = jax.random.PRNGKey(9)
        z1 = jax.random.normal(jax.random.PRNGKey(10), (32, 48))
        z2 = jax.random.normal(jax.random.PRNGKey(11), (32, 48))
        same = style == "vic"
        m = probe_metrics(z1, None if same else z2, cfg, key)

        n = z1.shape[0]
        if style == "bt":
            a, b = dengine.standardize(z1, cfg), dengine.standardize(z2, cfg)
            scale = float(n)
        else:
            a = dengine.center(z1, cfg)
            b = a
            scale = float(n - 1)
        ap, bp = perm_lib.permute_views(key, a, b)
        want_sum = regs.r_sum_auto(ap, bp, q=q, block_size=block, scale=scale)
        want_off = regs.r_off(regs.cross_correlation_matrix(a, b, scale=scale))
        np.testing.assert_allclose(float(m["r_sum"]), float(want_sum), rtol=1e-5)
        np.testing.assert_allclose(float(m["r_off"]), float(want_off), rtol=1e-5)

    def test_probe_streaming_window_and_ema(self):
        probe = DecorrProbe(DecorrConfig(style="vic", reg="sum", q=2), sample_rows=16, ema=0.5)
        rng = np.random.default_rng(0)
        # 3 batches of 8 rows -> one 16-row probe fires, 8 rows remain buffered
        fired = [probe.observe(rng.standard_normal((8, 48)).astype(np.float32)) for _ in range(3)]
        assert sum(fired) == 1 and probe.steps == 1
        m = probe.metrics()
        assert m["decorr_probe_steps"] == 1.0
        assert "decorr_r_sum" in m and "decorr_r_sum_ema" in m
        mean, var = probe.feature_moments()
        assert mean.shape == (48,) and var.shape == (48,)

    def test_probe_permutation_reproducible(self):
        """Step t of the stream equals an offline probe with the same folded key."""
        cfg = DecorrConfig(style="vic", reg="sum", q=2)
        z = np.asarray(jax.random.normal(jax.random.PRNGKey(12), (16, 48)), np.float32)
        probe = DecorrProbe(cfg, sample_rows=16, perm_seed=3)
        batch = probe.update(z)
        key = jax.random.fold_in(jax.random.PRNGKey(3), jnp.uint32(0))
        want = probe_metrics(jnp.asarray(z), cfg=cfg, perm_key=key)
        np.testing.assert_allclose(batch["r_sum"], float(want["r_sum"]), rtol=1e-5)


# ---------------------------------------------------------------------------
# Service end to end
# ---------------------------------------------------------------------------


class TestEmbeddingService:
    def _service(self, **kw):
        eng = ServeEngine(MODEL, _params(), policy=BucketPolicy(max_batch=8, align=8, max_wait_ms=0.0))
        return EmbeddingService(eng, probe=DecorrProbe(DecorrConfig(style="vic")), **kw)

    def test_synchronous_roundtrip_and_metrics(self):
        svc = self._service()
        svc.warmup()
        xs = np.asarray(jax.random.normal(jax.random.PRNGKey(13), (11, MODEL.input_dim)))
        futs = [svc.submit(x) for x in xs]
        while any(not f.done() for f in futs):
            assert svc.run_pending(timeout=0.0) > 0
        got = np.stack([f.result() for f in futs])
        np.testing.assert_allclose(got, np.asarray(embed(svc.engine.params, xs)), rtol=2e-5, atol=2e-6)
        m = svc.metrics()
        assert m["served_total"] == 11.0
        assert m["batches_total"] == 2.0  # 8 + 3
        assert m["queue_depth"] == 0.0
        assert m["heartbeat_stale"] == 0.0
        assert m["latency_p99_ms"] >= m["latency_p50_ms"] >= 0.0

    def test_threaded_service(self):
        svc = self._service().start()
        try:
            xs = np.asarray(jax.random.normal(jax.random.PRNGKey(14), (20, MODEL.input_dim)))
            futs = [svc.submit(x) for x in xs]
            got = np.stack([f.result(timeout=30.0) for f in futs])
        finally:
            svc.stop()
        np.testing.assert_allclose(got, np.asarray(embed(svc.engine.params, xs)), rtol=2e-5, atol=2e-6)
        # probe saw full sample windows of served embeddings
        assert svc.probe.steps >= 1

    def test_service_feeds_heartbeat(self):
        t = {"now": 0.0}
        from repro.ft.watchdog import HeartbeatMonitor

        hb = HeartbeatMonitor(clock=lambda: t["now"])
        svc = self._service(heartbeat=hb, heartbeat_timeout_s=5.0)
        svc.submit(np.zeros(MODEL.input_dim, np.float32))
        t["now"] = 10.0
        assert "serve.dispatch" in hb.stale()
        svc.run_pending(timeout=0.0)  # dispatch beats
        assert hb.stale() == {}


# ---------------------------------------------------------------------------
# Sharded execution: encode + global-mode probe vs single-device oracle
# ---------------------------------------------------------------------------


def test_sharded_serve_matches_local_oracle():
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses, json
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.decorr import probe_metrics
        from repro.decorr.config import DecorrConfig
        from repro.serve import ServeEngine, BucketPolicy
        from repro.train.ssl import SSLModelConfig, init_ssl_params

        mesh = jax.make_mesh((8,), ("data",))
        model = SSLModelConfig(input_dim=24, backbone_widths=(32,), projector_widths=(48, 48))
        params = init_ssl_params(jax.random.PRNGKey(0), model)
        pol = BucketPolicy(max_batch=32, align=8)
        local = ServeEngine(model, params, policy=pol)
        sharded = ServeEngine(model, params, policy=pol, mesh=mesh)
        x = np.random.default_rng(0).standard_normal((20, 24)).astype(np.float32)
        enc_err = float(jnp.max(jnp.abs(local.encode(x) - sharded.encode(x))))

        out = {"enc_err": enc_err}
        key = jax.random.PRNGKey(5)
        z = jax.random.normal(jax.random.PRNGKey(7), (64, 48))
        for style, q, block in (("bt", 2, 16), ("vic", 2, None)):
            cfg_l = DecorrConfig(style=style, reg="sum", q=q, block_size=block)
            cfg_g = dataclasses.replace(cfg_l, distributed="global", axis_name="data")
            oracle = probe_metrics(z, cfg=cfg_l, perm_key=key)
            f = shard_map(lambda zz, k: probe_metrics(zz, cfg=cfg_g, perm_key=k),
                          mesh=mesh, in_specs=(P("data"), P()), out_specs=P())
            got = f(z, key)
            out[style] = max(
                abs(float(oracle[k]) - float(got[k])) / max(abs(float(oracle[k])), 1e-6)
                for k in oracle
            )
        print(json.dumps(out))
        """
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=420
    )
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-3000:]}"
    res = json.loads(proc.stdout.strip().splitlines()[-1])
    assert res["enc_err"] < 1e-5, res
    assert res["bt"] < 1e-4, res
    assert res["vic"] < 1e-4, res


# ---------------------------------------------------------------------------
# LM serving engine (prefill/decode factories shared with train.serve)
# ---------------------------------------------------------------------------


def test_lm_serve_engine_matches_greedy_generate():
    from repro.configs import get_config
    from repro.models import init_params
    from repro.train.serve import greedy_generate

    cfg = get_config("rwkv6-3b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    eng = LMServeEngine(cfg)
    a = eng.generate(params, prompt, 5)
    b = greedy_generate(params, cfg, prompt, 5)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # second call reuses the cached jitted steps
    np.testing.assert_array_equal(np.asarray(eng.generate(params, prompt, 5)), np.asarray(a))
