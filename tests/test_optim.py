"""Optimizers, schedules, clipping, gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (
    adamw,
    clip_by_global_norm,
    global_norm,
    lars,
    sgd_momentum,
    warmup_cosine,
)


def _quadratic_losses(opt, lr=0.1, steps=60, dim=8):
    target = jnp.linspace(-1, 1, dim)
    params = {"w": jnp.zeros((dim, dim)) + 0.5, "b": jnp.zeros((dim,))}
    state = opt.init(params)
    losses = []

    def loss_fn(p):
        return jnp.sum((p["w"] @ target + p["b"] - target) ** 2)

    for i in range(steps):
        loss, g = jax.value_and_grad(loss_fn)(params)
        params, state = opt.update(g, state, params, jnp.asarray(lr))
        losses.append(float(loss))
    return losses


@pytest.mark.parametrize(
    "opt,lr",
    [(adamw(weight_decay=0.0), 0.05), (lars(weight_decay=0.0), 0.2), (sgd_momentum(), 0.01)],
)
def test_optimizers_converge_on_quadratic(opt, lr):
    losses = _quadratic_losses(opt, lr)
    assert losses[-1] < 0.1 * losses[0], losses[::10]


def test_adamw_bf16_moments_still_converge():
    opt = adamw(moment_dtype=jnp.bfloat16, weight_decay=0.0)
    losses = _quadratic_losses(opt, 0.05)
    assert losses[-1] < 0.2 * losses[0]


def test_lars_excludes_bias_from_adaptation():
    opt = lars()
    params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    state = opt.init(params)
    grads = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    new_params, _ = opt.update(grads, state, params, jnp.asarray(1.0))
    # bias uses raw lr (delta 1.0); weight is trust-scaled (much smaller)
    db = float(jnp.max(jnp.abs(new_params["b"] - params["b"])))
    dw = float(jnp.max(jnp.abs(new_params["w"] - params["w"])))
    assert db > 0.9
    assert dw < 0.1


def test_clip_by_global_norm():
    grads = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    np.testing.assert_allclose(global_norm(clipped), 1.0, rtol=1e-5)
    assert float(norm) > 100.0


def test_warmup_cosine_shape():
    sched = warmup_cosine(1.0, warmup_steps=10, total_steps=100)
    assert float(sched(0)) == 0.0
    np.testing.assert_allclose(float(sched(10)), 1.0, rtol=1e-5)
    assert float(sched(55)) < 1.0
    assert float(sched(100)) <= float(sched(55))
    np.testing.assert_allclose(float(sched(5)), 0.5, rtol=1e-5)


def test_int8_quantize_roundtrip_error_bounded():
    from repro.optim.compression import _quantize_int8

    x = jax.random.normal(jax.random.PRNGKey(0), (1000,))
    q, scale = _quantize_int8(x)
    err = jnp.abs(q.astype(jnp.float32) * scale - x)
    assert float(jnp.max(err)) <= float(scale) * 0.51
