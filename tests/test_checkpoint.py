"""Checkpointing: roundtrip (incl. bf16), atomicity, retention, resume."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (
    CheckpointManager,
    latest_step,
    list_steps,
    restore_checkpoint,
    save_checkpoint,
)


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {
            "w": jax.random.normal(k, (4, 6), jnp.float32),
            "emb": jax.random.normal(k, (8, 4)).astype(jnp.bfloat16),
        },
        "step": jnp.asarray(7, jnp.int32),
        "nested": [{"m": jnp.ones((3,), jnp.float32)}],
    }


def test_roundtrip_bf16(tmp_path):
    state = _state()
    save_checkpoint(str(tmp_path), 7, state)
    restored = restore_checkpoint(str(tmp_path), 7, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_uncommitted_checkpoint_ignored(tmp_path):
    state = _state()
    save_checkpoint(str(tmp_path), 5, state)
    d = save_checkpoint(str(tmp_path), 10, state)
    os.remove(os.path.join(d, "COMMIT"))  # simulate torn write
    assert latest_step(str(tmp_path)) == 5


def test_manager_keep_n(tmp_path):
    mgr = CheckpointManager(str(tmp_path), interval=1, keep=2, use_async=False)
    state = _state()
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert list_steps(str(tmp_path)) == [3, 4]


def test_manager_restore_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), interval=1, keep=3, use_async=False)
    state = _state()
    mgr.save(3, state)
    restored, step = mgr.restore_latest(state)
    assert step == 3
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]), np.asarray(state["params"]["w"])
    )


def test_restore_empty_dir_returns_none(tmp_path):
    mgr = CheckpointManager(str(tmp_path), use_async=False)
    restored, step = mgr.restore_latest(_state())
    assert restored is None and step == 0


def test_async_checkpointer_ordered(tmp_path):
    mgr = CheckpointManager(str(tmp_path), interval=1, keep=10, use_async=True)
    state = _state()
    for s in range(1, 6):
        mgr.save(s, state)
    mgr.wait()
    assert list_steps(str(tmp_path)) == [1, 2, 3, 4, 5]


def test_elastic_restore_new_mesh(tmp_path):
    from jax.sharding import PartitionSpec as P

    from repro.ft.elastic import elastic_restore

    state = _state()
    save_checkpoint(str(tmp_path), 2, state)
    mesh = jax.make_mesh((1,), ("data",))
    restored = elastic_restore(str(tmp_path), 2, state, mesh, spec_fn=lambda p, l: P())
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]), np.asarray(state["params"]["w"])
    )
