"""Prefix-sharing radix cache over the paged KV pool.

Bottom-up: allocator refcount/pin/shared-credit edge cases (double free
raises, COW charged to the reservation, eviction never touches pinned
pages), the radix tree itself (page-granular match/insert, splits only at
page boundaries, first-writer-wins, LRU tail-truncation eviction), the
manager's hit quantization to the chunk grid — then the oracle the feature
stands on: warm requests resuming chunked prefill over shared pages emit
BIT-IDENTICAL greedy tokens to the unshared chunk-all engine, through the
copy-on-write boundary page, under pool pressure with on-demand eviction,
with sampling, and with the in-flight decorrelation probe oracle-exact."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.decorr.config import DecorrConfig
from repro.models import init_params
from repro.serve import ContinuousLMEngine, DecorrProbe, LMService
from repro.serve.loadgen import lm_probe_oracle_err
from repro.serve.paging import PageAllocator, PagedKVManager, RadixCache


@pytest.fixture(scope="module")
def gemma():
    cfg = get_config("gemma2-2b").reduced()
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


# ---------------------------------------------------------------------------
# PageAllocator: refcounts, pins, shared-credit reservations
# ---------------------------------------------------------------------------


class TestAllocatorSharing:
    def _alloc(self, total=9, page=8, n_slots=4, nb=4):
        return PageAllocator(total, page, n_slots, nb)

    def test_retain_release_refcounts(self):
        a = self._alloc()
        a.reserve(0, 8)
        (_, phys), = a.ensure(0, 8)
        assert a.refcount(phys) == 1
        a.retain(phys)  # a second owner (the cache)
        assert a.refcount(phys) == 2
        a.release(0)  # the slot drops out; the page survives
        assert a.in_use == 1 and a.refcount(phys) == 1
        assert a.release_page(phys)  # last owner: freed
        assert a.in_use == 0 and a.refcount(phys) == 0
        with pytest.raises(RuntimeError, match="double free"):
            a.release_page(phys)
        with pytest.raises(RuntimeError, match="retain of unallocated"):
            a.retain(phys)

    def test_pin_unpin_edges(self):
        a = self._alloc()
        a.reserve(0, 8)
        (_, phys), = a.ensure(0, 8)
        with pytest.raises(RuntimeError, match="pin of unallocated"):
            a.pin_page(99)
        with pytest.raises(RuntimeError, match="unpin of unpinned"):
            a.unpin_page(phys)
        a.pin_page(phys)
        a.pin_page(phys)
        assert a.pin_count(phys) == 2 and a.pinned_pages == 1
        a.unpin_page(phys)
        a.unpin_page(phys)
        assert a.pinned_pages == 0

    def test_can_reserve_shared_credit_and_pins(self):
        a = self._alloc(total=5)  # 4 usable
        # 5 pages of rows don't fit cold, but with 2 shared prefix pages the
        # slot only needs the 3-page unshared tail
        assert not a.can_reserve(40)
        assert a.can_reserve(40, shared_pages=2)
        # pages the plan would newly pin count against the same budget
        assert not a.can_reserve(40, shared_pages=2, new_pins=2)
        a.reserve(0, 40, shared_pages=2)
        assert a.reserved_total == 3

    def test_bind_shared_not_charged_cow_is(self):
        a = self._alloc(total=9)
        a.reserve(0, 24)  # 3 pages
        a.ensure(0, 24)
        shared = a.table(0)
        for p in shared:
            a.retain(p)  # the radix cache's ownership
        a.release(0)
        assert a.in_use == 3  # pages survive under the cache

        # warm slot: 2 full shared pages + COW of the third + 1-page tail
        a.reserve(1, 32, shared_pages=2)  # 4 pages of rows, 2 shared
        assert a._reserved[1] == 2
        a.bind_shared(1, shared[:2])
        assert a.refcount(shared[0]) == 2
        dst = a.cow_bind(1, shared[2])
        assert dst not in shared and a.refcount(dst) == 1
        a.ensure(1, 32)  # the tail page fits the remaining reservation
        with pytest.raises(RuntimeError, match="> reservation"):
            a.ensure(1, 33)
        a.release(1)
        assert a.in_use == 3  # COW + tail freed, shared pages retained
        assert a.refcount(shared[0]) == 1

    def test_cow_beyond_reservation_raises(self):
        a = self._alloc(total=9)
        a.reserve(0, 8)  # 1 page reserved
        a.ensure(0, 8)
        with pytest.raises(RuntimeError, match="COW exceeds reservation"):
            a.cow_bind(0, a.table(0)[0])

    def test_alloc_evicts_unpinned_cache_pages_on_demand(self):
        a = self._alloc(total=4, page=8)  # 3 usable
        a.reserve(0, 16)
        a.ensure(0, 16)
        cached = a.table(0)
        for p in cached:
            a.retain(p)
        a.release(0)  # 2 pages held only by the "cache"
        freed = []
        a.evict_hook = lambda need: freed.extend(
            p for p in list(cached) if a.release_page(p)
        ) or len(freed)
        a.reserve(1, 24)  # 3 pages: heap has only 1 free
        assert a.table(1) == [] and len(a.ensure(1, 24)) == 3
        assert sorted(freed) == sorted(cached)  # eviction ran on demand

    def test_exhaustion_without_hook_still_raises(self):
        a = self._alloc(total=4, page=8)  # 3 usable
        a.reserve(0, 16)
        a.ensure(0, 16)
        a.reserve(1, 8)
        # bookkeeping bug territory: force the heap dry with no evictor
        a._free.clear()
        with pytest.raises(RuntimeError, match="exhausted"):
            a.ensure(1, 8)

    def test_compaction_never_moves_shared_or_pinned(self):
        a = self._alloc(total=9)
        a.reserve(0, 16)
        a.ensure(0, 16)  # pages 1, 2
        a.reserve(1, 16)
        a.ensure(1, 16)  # pages 3, 4
        a.retain(a.table(1)[1])  # page 4 shared
        a.pin_page(a.table(1)[0])  # page 3 pinned
        a.release(0)  # holes at 1, 2
        assert a.plan_compaction(max_moves=4) == []  # nothing movable
        a.unpin_page(3)
        a.release_page(4)
        assert a.plan_compaction(max_moves=4) == [(4, 1), (3, 2)]

    def test_metrics_expose_sharing(self):
        a = self._alloc()
        a.reserve(0, 8)
        (_, phys), = a.ensure(0, 8)
        a.retain(phys)
        a.pin_page(phys)
        m = a.metrics()
        assert m["pages_shared"] == 1.0 and m["pages_pinned"] == 1.0


# ---------------------------------------------------------------------------
# RadixCache: page-granular prefix tree
# ---------------------------------------------------------------------------


def _cached_alloc(total=33, page=4, n_slots=4, nb=8):
    """Allocator + a helper that allocates n pages owned by 'slot 0' then
    transfers them to the radix cache (insert retains, release drops)."""
    a = PageAllocator(total, page, n_slots, nb)
    r = RadixCache(page, a)

    def intern(tokens):
        n = len(tokens) // page
        a.reserve(0, n * page)
        a.ensure(0, n * page)
        pages = a.table(0)
        kept = r.insert(list(tokens[: n * page]), pages[:n])
        a.release(0)
        return kept

    return a, r, intern


class TestRadixCache:
    def test_match_full_and_partial_pages(self):
        a, r, intern = _cached_alloc()
        pages = intern(range(8))
        m = r.match(list(range(8)) + [99])
        assert m.pages == pages and m.tokens == 8 and m.partial is None
        m = r.match([0, 1, 2, 3, 4, 5, 99])  # diverges inside page 2
        assert m.pages == pages[:1] and m.tokens == 6 and m.partial == pages[1]
        assert r.match([7, 7, 7]).tokens == 0  # no first-page entry

    def test_insert_len_must_cover_pages(self):
        a, r, _ = _cached_alloc()
        a.reserve(0, 4)
        a.ensure(0, 4)
        with pytest.raises(AssertionError):
            r.insert([1, 2, 3], a.table(0))  # 3 tokens < 1 page of 4

    def test_split_only_at_page_boundary(self):
        a, r, intern = _cached_alloc()
        intern([0, 1, 2, 3, 10, 11, 12, 13])
        assert r.nodes == 1 and r.splits_total == 0  # one chain node
        intern([0, 1, 2, 3, 20, 21, 22, 23])  # diverges at page boundary 1
        assert r.splits_total == 1 and r.nodes == 3  # split + new branch
        assert r.cached_pages == 3  # shared first page + two tails
        for tail in (10, 20):
            m = r.match([0, 1, 2, 3, tail, tail + 1, tail + 2, tail + 3])
            assert m.tokens == 8
        # node keys stay page-aligned through the split
        stack = [r.root]
        while stack:
            n = stack.pop()
            assert len(n.key) == len(n.pages) * 4
            stack.extend(n.children.values())

    def test_first_writer_wins(self):
        a, r, intern = _cached_alloc()
        first = intern(range(8))
        dup = intern(range(8))  # same content, different physical pages
        assert dup == []  # duplicate donation refused: nothing retained
        assert r.cached_pages == 2 and r.match(list(range(8))).pages == first
        assert a.in_use == 2  # the duplicate's pages went straight back

    def test_extension_keeps_existing_prefix_pages(self):
        a, r, intern = _cached_alloc()
        first = intern(range(8))
        longer = intern(range(12))  # same first 8 tokens, one more page
        assert len(longer) == 1  # only the extension page was retained
        m = r.match(list(range(12)))
        assert m.tokens == 12 and m.pages[:2] == first

    def test_lru_eviction_truncates_tail_first(self):
        a, r, intern = _cached_alloc()
        intern([0, 1, 2, 3, 10, 11, 12, 13])
        intern([0, 1, 2, 3, 20, 21, 22, 23])
        r.match([0, 1, 2, 3, 20, 21, 22, 23])  # touch the 20-branch: MRU
        in_use0 = a.in_use
        assert r.evict(1) == 1
        assert a.in_use == in_use0 - 1
        assert r.match([0, 1, 2, 3, 20, 21, 22, 23]).tokens == 8  # MRU intact
        assert r.match([0, 1, 2, 3, 10, 11, 12, 13]).tokens == 4  # LRU gone
        # draining everything unlinks the emptied nodes too
        r.evict(99)
        assert r.cached_pages == 0 and a.in_use == 0 and r.nodes == 0

    def test_eviction_skips_pinned_pages(self):
        a, r, intern = _cached_alloc()
        pages = intern(range(8))
        a.pin_page(pages[-1])
        assert r.evict(2) == 0  # leaf tail pinned: nothing freeable
        assert r.cached_pages == 2
        a.unpin_page(pages[-1])
        assert r.evict(2) == 2

    def test_pinned_boundary_page_splits_eviction(self):
        a, r, intern = _cached_alloc()
        pages = intern(range(12))
        a.pin_page(pages[0])  # an admitted slot shares only the first page
        assert r.evict(99) == 2  # tail truncates down to the pinned page
        assert r.cached_pages == 1
        assert r.match(list(range(12))).tokens == 4


# ---------------------------------------------------------------------------
# PagedKVManager: plan quantization + admission accounting
# ---------------------------------------------------------------------------


class TestPrefixPlanning:
    def _mgr(self, cfg, page=8, chunk=4, **kw):
        return PagedKVManager(
            cfg, n_slots=4, max_len=48, page=page,
            prefix_cache=True, prefix_chunk=chunk, **kw,
        )

    def test_requires_chunk(self, gemma):
        cfg, _ = gemma
        with pytest.raises(ValueError, match="prefix_chunk"):
            PagedKVManager(cfg, n_slots=2, max_len=32, page=8, prefix_cache=True)

    def test_hit_quantized_to_chunk_and_capped(self, gemma):
        cfg, _ = gemma
        mgr = self._mgr(cfg)
        toks = np.arange(24, dtype=np.int32)
        assert mgr.admit(0, prompt_len=24, max_new_tokens=4,
                         plan=mgr.plan_prefix(toks, 24)) == 0  # cold: miss
        mgr.ensure_rows(0, 24)
        assert mgr.donate(0, toks) == 3
        mgr.release(0)

        plan = mgr.plan_prefix(toks, 24)
        # 24 cached tokens, but the last prompt token must be recomputed:
        # min(24, 23) floored to the chunk grid -> 20, mid-page -> COW
        assert plan.hit == 20 and plan.matched_tokens == 24
        assert len(plan.shared) == 2 and plan.cow_src is not None
        warm = np.concatenate([toks[:21], [99, 99, 99]]).astype(np.int32)
        p2 = mgr.plan_prefix(warm, 24)
        assert p2.hit == 20 and p2.matched_tokens == 21  # partial page match

        hit = mgr.admit(1, prompt_len=24, max_new_tokens=4, plan=plan)
        assert hit == 20 and mgr.prefix_hits == 1 and mgr.prefix_cow_total == 1
        # shared pages pinned for the request's lifetime, COW page exclusive
        for phys in plan.shared:
            assert mgr.alloc.pin_count(phys) == 1
        src, dst = mgr.cow_moves(1)
        assert src[0] == plan.cow_src and dst[0] == mgr.alloc.table(1)[2]
        assert mgr.cow_moves(1) is None  # one-shot
        # the scatter row masks the read-only shared blocks
        srow = mgr.scatter_row(1)
        assert (srow[:2] == 0).all() and srow[2] > 0
        mgr.release(1)
        assert mgr.alloc.pinned_pages == 0

    def test_admission_charges_only_unshared_tail(self, gemma):
        cfg, _ = gemma
        mgr = self._mgr(cfg, total_pages=7)  # 6 usable pages of 8
        toks = np.arange(24, dtype=np.int32)
        mgr.admit(0, prompt_len=24, max_new_tokens=1)
        mgr.ensure_rows(0, 24)
        mgr.donate(0, toks)
        mgr.release(0)  # 3 pages live on in the radix cache, unpinned
        # unpinned cache pages never block admission (they are reclaimable
        # on demand), so a cold 5-page request still fits the budget...
        assert mgr.can_admit(40, 1)
        # ...but the pool ceiling itself does bind
        assert not mgr.can_admit(49, 1)  # 7 pages > 6 usable
        plan = mgr.plan_prefix(toks, 24)
        # warm: 5 pages of rows, 2 shared (uncharged) -> 3 reserved, plus
        # 3 newly pinned (2 shared + the COW source) = exactly the pool
        assert mgr.can_admit(24, 17, plan=plan)
        assert mgr.admit(1, 24, 17, plan=plan) == 20
        # the plan consumed the whole budget: nothing else is admissible
        assert not mgr.alloc.can_reserve(8)
        mgr.ensure_rows(1, 40)  # grows to the reservation, evicting nothing
        assert mgr.alloc.in_use == 6 and mgr.radix.cached_pages == 3
        mgr.release(1)
        assert mgr.alloc.pinned_pages == 0 and mgr.alloc.in_use == 3


# ---------------------------------------------------------------------------
# End to end: warm == cold == unshared, bit for bit
# ---------------------------------------------------------------------------


# page 8 / chunk 4 / 21-token prefix: a cold tail extends the donated pages
# past the prefix (24 tokens = 3 pages), so warm hits land mid-page (h = 20,
# 20 % 8 = 4) and exercise copy-on-write, not just whole-page binding
E2E = dict(
    n_slots=4, max_len=48, max_prompt_len=26,
    paged=True, page_size=8, prefill_chunk=4, chunk_all=True,
)
PREFIX_LEN = 21
TAILS = [(3, 4), (2, 6), (5, 3), (4, 5)]  # (tail_len, max_new); [0] is cold


def _prefix_spec(cfg, prefix_len=PREFIX_LEN, tails=TAILS, seed=0):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, prefix_len).astype(np.int32)
    return [
        (np.concatenate([prefix, rng.integers(0, cfg.vocab_size, t).astype(np.int32)]), m)
        for t, m in tails
    ]


def _run_two_phase(cfg, params, spec, *, n_cold=1, probe=None, record=False,
                   submit_kw=None, **engine_kw):
    """Cold requests first (drained, so their retire donates), then the rest
    as a burst — the warm phase when ``prefix_cache=True``."""
    kw = dict(E2E)
    kw.update(engine_kw)
    eng = ContinuousLMEngine(cfg, params, **kw)
    svc = LMService(eng, probe=probe, record_probe_rows=record)
    svc.warmup(prompt_lens=[len(t) for t, _ in spec])
    futs = []
    for i, (t, m) in enumerate(spec):
        futs.append(svc.submit(t, m, **((submit_kw or (lambda i: {}))(i))))
        if i < n_cold:
            svc.drain()
    svc.drain()
    return [f.result(timeout=10) for f in futs], svc


class TestPrefixSharingEndToEnd:
    def test_gating_errors(self, gemma):
        cfg, params = gemma
        with pytest.raises(ValueError, match="paged"):
            ContinuousLMEngine(cfg, params, n_slots=2, max_len=32, prefix_cache=True)
        # chunk_all (which prefix_cache implies) needs the chunked machinery
        with pytest.raises(ValueError, match="chunk_all"):
            ContinuousLMEngine(cfg, params, n_slots=2, max_len=32, chunk_all=True)

    def test_warm_bit_identical_with_cow(self, gemma):
        cfg, params = gemma
        spec = _prefix_spec(cfg)
        want, _ = _run_two_phase(cfg, params, spec, prefix_cache=False)
        outs, svc = _run_two_phase(cfg, params, spec, prefix_cache=True)
        for w, o in zip(want, outs):
            np.testing.assert_array_equal(o, w)
        m = svc.metrics()
        assert m["paged_prefix_hits_total"] == 3.0  # every warm request hit
        assert m["paged_prefix_misses_total"] == 1.0
        assert m["paged_prefix_cow_total"] >= 1.0  # the mid-page boundary
        assert m["paged_prefix_hit_tokens_total"] >= 3 * 20
        # all slots retired: reservations returned, only the cache holds pages
        assert m["paged_pages_reserved"] == 0.0
        assert m["paged_pages_in_use"] == m["paged_radix_cached_pages"] > 0

    def test_flight_recorder_sees_prefix_events(self, gemma):
        cfg, params = gemma
        spec = _prefix_spec(cfg)
        # the service wires the engine's page-table narration into its own
        # flight-recorder ring; read it back from there
        _, svc = _run_two_phase(cfg, params, spec, prefix_cache=True)
        counts = svc.obs.recorder.counts()
        assert counts.get("page_share", 0) >= 1  # donation + warm binding
        assert counts.get("prefix_hit", 0) == 3
        assert counts.get("page_cow", 0) >= 1
        admits = svc.obs.recorder.events("admit")
        assert any(e["prefix_hit"] >= 20 for e in admits)

    def test_sampling_rides_prefix_cache(self, gemma):
        cfg, params = gemma
        spec = _prefix_spec(cfg)
        kw = lambda i: dict(temperature=0.8, top_k=8, seed=100 + i)  # noqa: E731

        def run(prefix_cache):
            outs, _ = _run_two_phase(
                cfg, params, spec, prefix_cache=prefix_cache,
                sampling=True, submit_kw=kw,
            )
            return outs

        a, b = run(True), run(True)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)  # per-seed reproducible
        for x, y in zip(a, run(False)):
            np.testing.assert_array_equal(x, y)  # logits bit-identical too

    def test_tiny_pool_evicts_and_completes(self, gemma):
        cfg, params = gemma
        # two prefix families so the tree outgrows an 8-page pool
        spec = _prefix_spec(cfg, seed=0)[:3] + _prefix_spec(cfg, seed=7)[:3]
        order = [0, 3, 1, 4, 2, 5]  # cold A, cold B, then interleaved warms
        spec = [spec[i] for i in order]
        want, _ = _run_two_phase(cfg, params, spec, n_cold=2, prefix_cache=False,
                                 total_pages=9)
        outs, svc = _run_two_phase(cfg, params, spec, n_cold=2, prefix_cache=True,
                                   total_pages=9)
        for w, o in zip(want, outs):
            np.testing.assert_array_equal(o, w)
        m = svc.metrics()
        assert m["paged_radix_evicted_pages_total"] > 0  # pressure evicted
        assert m["paged_pages_peak"] <= 8.0  # never past the usable pool
        assert m["paged_pages_reserved"] == 0.0

    def test_probe_oracle_exact_under_sharing(self, gemma):
        cfg, params = gemma
        probe = DecorrProbe(DecorrConfig(style="vic", reg="sum", q=2))
        outs, svc = _run_two_phase(
            cfg, params, _prefix_spec(cfg), prefix_cache=True,
            probe=probe, record=True,
        )
        assert probe.steps >= 1
        err = lm_probe_oracle_err(svc)
        assert err is not None and err < 1e-3
