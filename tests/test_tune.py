"""repro.tune subsystem tests: candidate legality, cache round-trip +
schema invalidation, dispatch precedence, tuner guarantees, and numerical
equivalence of tuned vs default configs against the kernel oracles."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import tune
from repro.kernels.pallas_utils import LANE, SUBLANE
from repro.kernels.sumvec_fft import kernel as fkernel
from repro.kernels.sumvec_fft import ops as fops
from repro.kernels.sumvec_fft import ref as fref
from repro.kernels.xcorr_offdiag import kernel as xkernel
from repro.kernels.xcorr_offdiag import ref as xref
from repro.tune import cache as tcache
from repro.tune import dispatch as tdispatch
from repro.tune import space as tspace

SHAPES = {
    "xcorr_offdiag": (24, 200),
    "cmatmul": (40, 24, 72),
    "pmatmul": (40, 24, 72),
    "ctwiddle": (24, 200),
    "freq_outer": (9, 48, 24),
    "freq_mat": (9, 48, 24, 24),
    "sumvec_fft_plan": (101,),
    "grouped_block_plan": (24, 48),
    "paged_attention": (4, 48, 2, 16),
}


def _views(n, d, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return jax.random.normal(k1, (n, d)), jax.random.normal(k2, (n, d))


# ---------------------------------------------------------------------------
# Candidate enumeration
# ---------------------------------------------------------------------------


class TestSpace:
    @pytest.mark.parametrize("kernel", tspace.KERNELS)
    def test_candidates_nonempty_and_legal(self, kernel):
        shape = SHAPES[kernel]
        cands = tspace.candidates(kernel, shape)
        assert cands
        for cfg in cands:
            assert tspace.is_legal(kernel, shape, cfg), (kernel, cfg)
            assert tspace.vmem_bytes(kernel, shape, cfg) <= tspace.VMEM_BUDGET_BYTES

    def test_tile_alignment(self):
        for cfg in tspace.candidates("xcorr_offdiag", (64, 512)):
            assert cfg["tile_d"] % LANE == 0
            assert cfg["tile_n"] % SUBLANE == 0
        for cfg in tspace.candidates("pmatmul", (300, 300, 300)):
            assert cfg["tm"] % SUBLANE == 0
            assert cfg["tn"] % LANE == 0 and cfg["tk"] % LANE == 0

    @pytest.mark.parametrize("kernel", tspace.KERNELS)
    def test_default_config_is_candidate(self, kernel):
        shape = SHAPES[kernel]
        canon = tdispatch.canonical_shape(kernel, shape)
        assert tspace.default_config(kernel, canon) in tspace.candidates(kernel, canon)

    def test_vmem_budget_excludes_oversized(self):
        # a 2048^2 f32 scratch alone is 16 MiB — must never be enumerated
        for cfg in tspace.candidates("xcorr_offdiag", (256, 4096)):
            assert cfg["tile_d"] <= 1024

    def test_plan_candidates_prime_are_padded_and_safe(self):
        cands = tspace.candidates("sumvec_fft_plan", (101,))
        padded = [c for c in cands if c["dp"] > 101]
        assert padded, "prime d must get padded fallback plans"
        for c in padded:
            assert c["dp"] >= 2 * 101 - 1  # linear-correlation safe
            assert c["d1"] > 1 and c["d1"] * c["d2"] == c["dp"]

    def test_grouped_block_size_candidates(self):
        bs = tspace.grouped_block_size_candidates(2048)
        assert bs == sorted(bs) and bs[-1] == 2048 and 128 in bs
        assert tspace.grouped_block_size_candidates(24)[-1] == 24

    def test_auto_block_size(self):
        from repro.kernels.grouped_sumvec.ops import auto_block_size

        assert auto_block_size(2048) == 128  # paper's sweet spot
        assert auto_block_size(100) == 100  # below prefer: ungrouped
        assert auto_block_size(192) == 128
        assert auto_block_size(8) == 8


# ---------------------------------------------------------------------------
# Persistent cache
# ---------------------------------------------------------------------------


class TestCache:
    def test_round_trip(self, tmp_path):
        cfg = {"tile_n": 64, "tile_d": 256}
        assert tcache.store(
            "xcorr_offdiag", (64, 256), "float32", "cpu", cfg,
            source="dry", cost={"flops": 1.0}, directory=tmp_path,
        )
        entry = tcache.lookup("xcorr_offdiag", (64, 256), "float32", "cpu", directory=tmp_path)
        assert entry["config"] == cfg
        assert entry["source"] == "dry"
        # different backend / shape / dtype are distinct keys
        assert tcache.lookup("xcorr_offdiag", (64, 256), "float32", "tpu", directory=tmp_path) is None
        assert tcache.lookup("xcorr_offdiag", (64, 512), "float32", "cpu", directory=tmp_path) is None

    def test_schema_version_invalidates(self, tmp_path):
        cfg = {"tile_n": 64, "tile_d": 256}
        tcache.store("xcorr_offdiag", (64, 256), "float32", "cpu", cfg, directory=tmp_path)
        path = tmp_path / "cpu.json"
        data = json.loads(path.read_text())
        data["schema"] = tcache.SCHEMA_VERSION + 1
        path.write_text(json.dumps(data))
        assert tcache.lookup("xcorr_offdiag", (64, 256), "float32", "cpu", directory=tmp_path) is None

    def test_corrupt_file_is_a_miss(self, tmp_path):
        (tmp_path / "cpu.json").write_text("{not json")
        assert tcache.lookup("x", (1,), "float32", "cpu", directory=tmp_path) is None
        # and store still recovers the file
        assert tcache.store("x", (8, 128), "float32", "cpu", {"tn": 8}, directory=tmp_path)

    def test_concurrent_stores_keep_all_entries(self, tmp_path):
        # the flock around read-modify-write must prevent lost updates
        import threading

        def work(i):
            tcache.store("pmatmul", (8 * i, 128, 128), "float32", "cpu", {"tm": 8}, directory=tmp_path)

        threads = [threading.Thread(target=work, args=(i,)) for i in range(1, 9)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert len(tcache.load_all("cpu", directory=tmp_path)) == 8


# ---------------------------------------------------------------------------
# Dispatch precedence + memoization
# ---------------------------------------------------------------------------


class TestDispatch:
    def test_cache_hit_skips_search(self, monkeypatch):
        calls = {"n": 0}
        real = tdispatch._analytic_search

        def counting(kernel, shape):
            calls["n"] += 1
            return real(kernel, shape)

        monkeypatch.setattr(tdispatch, "_analytic_search", counting)
        tdispatch.clear_memory_cache()
        a = tune.best_config("xcorr_offdiag", (56, 408))
        b = tune.best_config("xcorr_offdiag", (56, 408))
        assert a == b and calls["n"] == 1
        # logically different shape, same canonical padding -> still one search
        tune.best_config("xcorr_offdiag", (51, 400))
        assert calls["n"] == 1

    def test_disk_cache_consulted(self, monkeypatch, tmp_path):
        monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path))
        tdispatch.clear_memory_cache()
        canon = tune.canonical_shape("xcorr_offdiag", (16, 384))
        pinned = {"tile_n": 8, "tile_d": 128}
        tcache.store("xcorr_offdiag", canon, "float32", jax.default_backend(), pinned, source="dry")
        assert tune.best_config("xcorr_offdiag", (16, 384)) == pinned

    def test_override_beats_cache(self, monkeypatch, tmp_path):
        monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path))
        tdispatch.clear_memory_cache()
        canon = tune.canonical_shape("xcorr_offdiag", (16, 384))
        tcache.store(
            "xcorr_offdiag", canon, "float32", jax.default_backend(),
            {"tile_n": 8, "tile_d": 128}, source="dry",
        )
        with tune.override("xcorr_offdiag", tile_d=256):
            cfg = tune.best_config("xcorr_offdiag", (16, 384))
            assert cfg["tile_d"] == 256  # the override
            assert cfg["tile_n"] == 16  # merged from the default, not the cache
        assert tune.best_config("xcorr_offdiag", (16, 384))["tile_d"] == 128

    def test_illegal_cached_entry_falls_back(self, monkeypatch, tmp_path):
        monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path))
        tdispatch.clear_memory_cache()
        canon = tune.canonical_shape("xcorr_offdiag", (16, 384))
        tcache.store(
            "xcorr_offdiag", canon, "float32", jax.default_backend(),
            {"tile_n": 3, "tile_d": 100}, source="dry",  # violates alignment
        )
        cfg = tune.best_config("xcorr_offdiag", (16, 384))
        assert tspace.is_legal("xcorr_offdiag", canon, cfg)

    def test_cached_entry_with_wrong_keys_is_a_miss(self, monkeypatch, tmp_path):
        # a schema-valid entry whose config lacks the kernel's keys (hand
        # edit, or a future key rename without a schema bump) must degrade
        # to a miss, not KeyError out of the first kernel call
        monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path))
        tdispatch.clear_memory_cache()
        canon = tune.canonical_shape("xcorr_offdiag", (24, 200))
        tcache.store("xcorr_offdiag", canon, "float32", jax.default_backend(), {"tm": 128})
        cfg = tune.best_config("xcorr_offdiag", (24, 200))
        assert tspace.is_legal("xcorr_offdiag", canon, cfg)

    def test_no_legal_candidates_falls_back_to_default(self):
        # freq_mat's full (npad, n2pad) operand block alone busts the VMEM
        # budget at nb = 2048 — there is no "legal" candidate, but the
        # kernel must keep running with the clamped legacy default (it did
        # before tuning existed).
        shape = (2, 16, 2048, 2048)
        assert tspace.candidates("freq_mat", shape) == []
        cfg = tune.best_config("freq_mat", shape)
        assert cfg == tspace.default_config("freq_mat", tune.canonical_shape("freq_mat", shape))

    def test_best_impl(self):
        assert tune.best_impl("r_sum", backend="tpu") == "pallas"
        assert tune.best_impl("r_sum", backend="cpu") == "jnp"
        with tune.override("r_sum", impl="pallas"):
            assert tune.best_impl("r_sum", backend="cpu") == "pallas"


# ---------------------------------------------------------------------------
# Tuner (dry mode): determinism + never-worse-than-default guarantee
# ---------------------------------------------------------------------------


class TestTuner:
    def test_dry_mode_guards_default(self, monkeypatch, tmp_path):
        monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path))
        res = tune.tune("pmatmul", (24, 40, 24), mode="dry", max_candidates=4)
        default = res.candidate_for(res.default)
        best = res.candidate_for(res.best)
        assert best.cost["flops"] <= default.cost["flops"]
        assert best.cost["hbm_bytes"] <= default.cost["hbm_bytes"]

    def test_dry_mode_deterministic_and_persists(self, monkeypatch, tmp_path):
        monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path))
        r1 = tune.tune("xcorr_offdiag", (16, 128), mode="dry", max_candidates=4)
        r2 = tune.tune("xcorr_offdiag", (16, 128), mode="dry", max_candidates=4)
        assert r1.best == r2.best
        entry = tcache.lookup(
            "xcorr_offdiag", r1.shape, "float32", jax.default_backend()
        )
        assert entry is not None and entry["config"] == r1.best
        # ... and dispatch serves the tuned entry from then on
        tdispatch.clear_memory_cache()
        assert tune.best_config("xcorr_offdiag", (16, 128)) == r1.best

    def test_measure_mode_times_each_candidate_once(self):
        res = tune.tune("pmatmul", (16, 16, 16), mode="measure", persist=False,
                        max_candidates=2, repeats=1)
        assert all(c.time_us is not None and c.time_us > 0 for c in res.candidates)

    def test_analytic_mode_instant(self):
        res = tune.tune("cmatmul", (40, 24, 72), mode="analytic", persist=False)
        assert res.best in [c.config for c in res.candidates]

    def test_analytic_rank_avoids_degenerate_tiles(self):
        # m = 520: tm = 8 has zero padding but 65 grid rows; the roofline
        # ranking must not let padding-free flops pick the degenerate tile
        cfg = tune.best_config("cmatmul", (520, 64, 64))
        assert cfg["tm"] >= 64, cfg


# ---------------------------------------------------------------------------
# Numerical equivalence: tuned/default/any-legal configs agree with oracles
# ---------------------------------------------------------------------------


class TestNumericalEquivalence:
    def test_xcorr_tiles_match_oracle(self):
        n, d = 24, 72
        z1, z2 = _views(n, d, seed=1)
        want = xref.off_diagonal_sq_sum_ref(z1, z2)
        canon = tune.canonical_shape("xcorr_offdiag", (n, d))
        tuned = tune.best_config("xcorr_offdiag", (n, d))
        default = tune.default_config("xcorr_offdiag", canon)
        for cfg in (tuned, default, {"tile_n": 8, "tile_d": 128}):
            got = xkernel.off_diagonal_sq_sum_raw(
                z1, z2, tile_d=cfg["tile_d"], tile_n=cfg["tile_n"]
            )
            np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_cmatmul_tiles_match_numpy(self):
        m, k, n = 24, 40, 24
        ar, ai = _views(m, k, seed=2)
        br, bi = _views(k, n, seed=3)
        a = np.asarray(ar) + 1j * np.asarray(ai)
        b = np.asarray(br) + 1j * np.asarray(bi)
        want = a @ b
        for cfg in ({"tm": 8, "tn": 128, "tk": 128}, {"tm": 32, "tn": 128, "tk": 128}):
            cr, ci = fkernel._cmatmul_raw(ar, ai, br, bi, **cfg)
            np.testing.assert_allclose(np.asarray(cr) + 1j * np.asarray(ci), want, atol=1e-4)

    def test_r_sum_grouped_impl_consistent_when_b_exceeds_d(self):
        # b > d pads d up to b (the matrix-oracle semantics); the loss value
        # must not depend on which backend the impl dispatch picked.
        from repro.core import regularizers as regs

        z1, z2 = _views(8, 24, seed=7)
        a = regs.r_sum_grouped(z1, z2, 32, scale=8.0, impl="jnp")
        b = regs.r_sum_grouped(z1, z2, 32, scale=8.0, impl="pallas")
        np.testing.assert_allclose(a, b, rtol=1e-5)

    def test_partial_plan_override_is_completed(self):
        # pinning dp alone must not hand back an inconsistent (dp, d1, d2)
        with tune.override("sumvec_fft_plan", dp=48):
            plan = fops.fft_plan(24)
        assert (plan.dp, plan.d1, plan.d2) == (48, 6, 8)
        with tune.override("sumvec_fft_plan", d1=4, d2=6):
            assert fops.fft_plan(24).dp == 24
        # one factor alone: completed against the default dp
        with tune.override("sumvec_fft_plan", d1=16):
            plan = fops.fft_plan(2048)
        assert (plan.dp, plan.d1, plan.d2) == (2048, 16, 128)
        # dp plus one factor: the pinned factor must survive
        with tune.override("sumvec_fft_plan", dp=48, d1=4):
            plan = fops.fft_plan(24)
        assert (plan.dp, plan.d1, plan.d2) == (48, 4, 12)

    def test_unsatisfiable_plan_override_raises_valueerror(self):
        with tune.override("sumvec_fft_plan", d1=5):  # 5 does not divide 24
            with pytest.raises(ValueError):
                fops.fft_plan(24)
        with tune.override("sumvec_fft_plan", dp=30):  # 24 < 30 < 2*24 - 1
            with pytest.raises(ValueError):
                fops.fft_plan(24)
        with tune.override("sumvec_fft_plan", dp=48, d1=4, d2=6):  # 4*6 != 48
            with pytest.raises(ValueError):
                fops.fft_plan(24)

    def test_unknown_impl_rejected(self):
        from repro.core import regularizers as regs

        z1, z2 = _views(4, 8)
        with pytest.raises(ValueError):
            regs.r_sum(z1, z2, impl="palas")
        with pytest.raises(ValueError):
            regs.r_sum_grouped(z1, z2, 4, impl="Pallas")

    def test_invalid_fftplan_raises_not_asserts(self):
        # a plan violating its invariants must raise even under python -O
        with pytest.raises(ValueError):
            fops.FFTPlan(d=100, dp=150, d1=10, d2=15)  # aliased fold
        with pytest.raises(ValueError):
            fops.FFTPlan(d=24, dp=24, d1=5, d2=5)  # d1*d2 != dp

    def test_invalid_q_rejected(self):
        # q outside {1, 2} would otherwise compute sum-of-squares on the jnp
        # route but sum-of-abs on the pallas route — reject it outright
        from repro.core import regularizers as regs

        z1, z2 = _views(4, 8)
        for impl in ("jnp", "pallas"):
            with pytest.raises(ValueError):
                regs.r_sum(z1, z2, q=3, impl=impl)
            with pytest.raises(ValueError):
                regs.r_sum_grouped(z1, z2, 4, q=0, impl=impl)

    def test_padded_plan_equals_exact_plan(self):
        # composite d: both the exact plan and a padded fallback must agree
        n, d = 8, 24
        z1, z2 = _views(n, d, seed=4)
        exact = fops.FFTPlan(d=d, dp=24, d1=4, d2=6)
        padded = fops.FFTPlan(d=d, dp=48, d1=6, d2=8)
        for q in (1, 2):
            want = fref.r_sum_ref(z1, z2, q=q, scale=float(n))
            for plan in (exact, padded):
                got = fops.r_sum_fourstep(z1, z2, q=q, scale=float(n), plan=plan)
                np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(
            fops.sumvec_fourstep(z1, z2, scale=float(n), plan=padded),
            fref.sumvec_ref(z1, z2, scale=float(n)),
            atol=1e-4,
        )


# ---------------------------------------------------------------------------
# Regression: prime / near-prime d no longer degrades to the O(d^2) DFT
# ---------------------------------------------------------------------------


class TestPrimeDRegression:
    def test_choose_factors_still_exact(self):
        assert fops.choose_factors(101) == (1, 101)
        assert fops.choose_factors(24) == (4, 6)

    @pytest.mark.parametrize("d", [101, 127])
    def test_plan_pads_prime_d(self, d):
        plan = fops.fft_plan(d)
        assert plan.padded and plan.dp >= 2 * d - 1
        assert plan.d1 > 1 and plan.d2 < d  # genuinely balanced, not (1, dp)

    @pytest.mark.parametrize("q", [1, 2])
    def test_prime_d_matches_oracle(self, q):
        n, d = 6, 101
        z1, z2 = _views(n, d, seed=5)
        got = fops.r_sum_fourstep(z1, z2, q=q, scale=float(n))
        want = fref.r_sum_ref(z1, z2, q=q, scale=float(n))
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    def test_prime_d_sumvec_matches_oracle(self):
        n, d = 6, 101
        z1, z2 = _views(n, d, seed=6)
        np.testing.assert_allclose(
            fops.sumvec_fourstep(z1, z2, scale=float(n)),
            fref.sumvec_ref(z1, z2, scale=float(n)),
            atol=1e-4,
        )


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


class TestCLI:
    def test_analytic_pretune_writes_cache(self, monkeypatch, tmp_path, capsys):
        from repro.tune import cli

        monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path))
        rc = cli.main(["--analytic", "--shape", "8x32", "--cache-dir", str(tmp_path)])
        assert rc == 0
        entries = tcache.load_all(jax.default_backend(), directory=tmp_path)
        assert any(k.startswith("sumvec_fft_plan|") for k in entries)
        assert any(k.startswith("xcorr_offdiag|") for k in entries)
        out = capsys.readouterr().out
        assert "tuned" in out


# ---------------------------------------------------------------------------
# grouped_block_plan: the block size b searched as a plan config
# ---------------------------------------------------------------------------


class TestGroupedBlockPlan:
    def test_space_enumerates_every_legal_b(self):
        shape = (64, 48)
        cands = tspace.candidates("grouped_block_plan", shape)
        assert [c["b"] for c in cands] == tspace.grouped_block_size_candidates(48)
        # default mirrors auto_block_size: largest legal b <= 128
        assert tspace.default_config("grouped_block_plan", shape) == {"b": 48}
        assert tspace.default_config("grouped_block_plan", (64, 2048)) == {"b": 128}
        assert not tspace.is_legal("grouped_block_plan", shape, {"b": 1})
        assert not tspace.is_legal("grouped_block_plan", shape, {"b": 96})

    def test_dry_tune_compiles_real_pipeline(self):
        res = tune.tune(
            "grouped_block_plan", (16, 16), mode="dry",
            max_candidates=2, persist=False,
        )
        assert res.best["b"] in tspace.grouped_block_size_candidates(16)
        for c in res.candidates:
            assert c.cost["flops"] > 0  # compiled, not just modelled

    def test_jobs_for_searches_b_when_unpinned(self):
        from repro.tune.cli import jobs_for

        plans, jobs = jobs_for(16, 16, mode="analytic", persist=False)
        assert [p.kernel for p in plans] == ["sumvec_fft_plan", "grouped_block_plan"]
        b = plans[-1].best["b"]
        assert b in tspace.grouped_block_size_candidates(16)
        # the searched winner drives the derived grouped shapes
        nb = -(-16 // b)
        nf = b // 2 + 1
        assert ("pmatmul", (16 * nb, b, 2 * nf)) in jobs
        # a caller-pinned b skips the search entirely (b is loss-defining)
        plans_pinned, _ = jobs_for(16, 16, block_size=8, mode="analytic", persist=False)
        assert [p.kernel for p in plans_pinned] == ["sumvec_fft_plan"]
