"""Decorrelation-engine tests: mode routing, the tp misconfiguration guard,
and 8-virtual-device agreement of the shard_map SSL step with the
single-device oracle — losses AND gradients — across
{local, global, tp} x {bt, vic} x {q=1,2} x {grouped, ungrouped}.

Multi-device cases run in a SUBPROCESS with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the main pytest
process keeps its single CPU device."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.core.losses import DecorrConfig, ssl_loss
from repro.decorr import engine

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_in_subprocess(*parts: str, n_devices: int = 8) -> dict:
    code = textwrap.dedent(
        f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_devices}"
        import json
        import jax
        import jax.numpy as jnp
        import numpy as np
        """
    ) + "".join(textwrap.dedent(p) for p in parts)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=420
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}\nstdout:\n{out.stdout[-1000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


_COMMON = """
    from repro.core.losses import DecorrConfig, ssl_loss
    from repro.train.ssl import (SSLModelConfig, init_ssl_params, embed,
                                 make_sharded_ssl_train_step, shard_ssl_batch)
    from repro.optim import adamw, warmup_cosine
    from repro.train import create_train_state

    model = SSLModelConfig(input_dim=16, backbone_widths=(24,), projector_widths=(32, 32))
    params = init_ssl_params(jax.random.PRNGKey(0), model)
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    batch = {"view1": jax.random.normal(k1, (32, 16)),
             "view2": jax.random.normal(k2, (32, 16))}
    rng = jax.random.PRNGKey(3)

    def oracle(cfg_local, params, batch, rng):
        def lf(p):
            return ssl_loss(embed(p, batch["view1"]), embed(p, batch["view2"]),
                            cfg_local, perm_key=rng)[0]
        l, g = jax.value_and_grad(lf)(params)
        return l, g

    def max_grad_err(ga, gb):
        pairs = zip(jax.tree.leaves(ga), jax.tree.leaves(gb))
        return max(float(jnp.max(jnp.abs(a - b))) for a, b in pairs)
"""


# ---------------------------------------------------------------------------
# Satellite regression: tp must not silently fall through to the local path
# ---------------------------------------------------------------------------


class TestTpMisconfigGuard:
    def test_ssl_loss_tp_without_model_axis_raises(self):
        z = jax.random.normal(jax.random.PRNGKey(0), (8, 16))
        cfg = DecorrConfig(style="bt", reg="sum", distributed="tp")
        with pytest.raises(ValueError, match="model_axis"):
            ssl_loss(z, z + 0.1, cfg)

    def test_regularizer_tp_without_model_axis_raises(self):
        z = jax.random.normal(jax.random.PRNGKey(0), (8, 16))
        cfg = DecorrConfig(style="vic", reg="sum", distributed="tp")
        with pytest.raises(ValueError, match="model_axis"):
            engine.regularizer(z, z, cfg, scale=7.0)

    def test_tp_with_model_axis_passes_validation(self):
        cfg = DecorrConfig(distributed="tp", model_axis="model")
        assert engine.effective_mode(cfg) == "tp"

    def test_tp_rejects_matrix_only_regs(self):
        # R_off / block_size<=1 need the cross-shard d x d matrix
        cfg = DecorrConfig(style="bt", reg="off", distributed="tp", model_axis="m")
        z = jax.random.normal(jax.random.PRNGKey(0), (8, 16))
        with pytest.raises(NotImplementedError):
            engine.regularizer(z, z, cfg, scale=8.0)


# ---------------------------------------------------------------------------
# Single-device shims: engine == historical local behavior
# ---------------------------------------------------------------------------


class TestLocalShims:
    def test_global_mode_without_axis_degrades_to_local(self):
        z1 = jax.random.normal(jax.random.PRNGKey(0), (16, 12))
        z2 = jax.random.normal(jax.random.PRNGKey(1), (16, 12))
        la, _ = ssl_loss(z1, z2, DecorrConfig(style="bt", distributed="local"),
                         jax.random.PRNGKey(2))
        lb, _ = ssl_loss(z1, z2, DecorrConfig(style="bt", distributed="global"),
                         jax.random.PRNGKey(2))
        assert abs(float(la) - float(lb)) < 1e-6

    def test_sharded_step_on_trivial_mesh_matches_unsharded(self):
        # a (1,)-device mesh exercises the shard_map plumbing end to end
        from repro.optim import adamw, warmup_cosine
        from repro.train import create_train_state
        from repro.train.ssl import (
            SSLModelConfig,
            init_ssl_params,
            make_sharded_ssl_train_step,
            make_ssl_train_step,
        )

        model = SSLModelConfig(input_dim=8, backbone_widths=(12,), projector_widths=(16, 16))
        cfg = DecorrConfig(style="bt", reg="sum", q=2, block_size=8, distributed="global")
        opt, sched = adamw(), warmup_cosine(1e-3, 1, 10)
        mesh = jax.make_mesh((1,), ("data",))
        step_s, _ = make_sharded_ssl_train_step(model, cfg, opt, sched, mesh)
        step_u, _ = make_ssl_train_step(
            model, DecorrConfig(style="bt", reg="sum", q=2, block_size=8), opt, sched
        )
        params = init_ssl_params(jax.random.PRNGKey(0), model)
        state = create_train_state(params, opt)
        batch = {
            "view1": jax.random.normal(jax.random.PRNGKey(1), (16, 8)),
            "view2": jax.random.normal(jax.random.PRNGKey(2), (16, 8)),
        }
        _, ms = jax.jit(step_s)(state, batch)
        _, mu = jax.jit(step_u)(state, batch)
        assert abs(float(ms["bt_loss"]) - float(mu["bt_loss"])) < 1e-5


# ---------------------------------------------------------------------------
# 8-device oracle agreement (losses + grads through the shard_map step)
# ---------------------------------------------------------------------------


def test_global_and_tp_sharded_step_match_single_device_oracle():
    res = run_in_subprocess(
        _COMMON,
        """
        errs = {}
        for style in ("bt", "vic"):
            for q in (1, 2):
                for block in (None, 8):
                    for mode in ("global", "tp"):
                        mesh = (jax.make_mesh((8,), ("data",)) if mode == "global"
                                else jax.make_mesh((2, 4), ("data", "model")))
                        cfg = DecorrConfig(style=style, reg="sum", q=q,
                                           block_size=block, distributed=mode)
                        _, lag = make_sharded_ssl_train_step(
                            model, cfg, adamw(), warmup_cosine(1e-3, 1, 10), mesh)
                        loss, metrics, grads = jax.jit(lag)(
                            params, shard_ssl_batch(batch, mesh), rng)
                        cfg_l = DecorrConfig(style=style, reg="sum", q=q,
                                             block_size=block, distributed="local")
                        lo, go = oracle(cfg_l, params, batch, rng)
                        key = f"{style}/q{q}/b{block}/{mode}"
                        errs[key] = [
                            abs(float(loss) - float(lo)) / max(abs(float(lo)), 1e-6),
                            max_grad_err(grads, go),
                        ]
        print(json.dumps(errs))
        """
    )
    for key, (loss_err, grad_err) in res.items():
        assert loss_err < 5e-4, (key, loss_err)
        assert grad_err < 5e-4, (key, grad_err)


def test_local_sharded_step_matches_per_shard_oracle():
    """DDP semantics: sharded 'local' loss/grads == mean over the 8 batch
    slices of the single-device loss/grads."""
    res = run_in_subprocess(
        _COMMON,
        """
        errs = {}
        mesh = jax.make_mesh((8,), ("data",))
        for style in ("bt", "vic"):
            for block in (None, 8):
                cfg = DecorrConfig(style=style, reg="sum", q=2,
                                   block_size=block, distributed="local")
                _, lag = make_sharded_ssl_train_step(
                    model, cfg, adamw(), warmup_cosine(1e-3, 1, 10), mesh)
                loss, metrics, grads = jax.jit(lag)(
                    params, shard_ssl_batch(batch, mesh), rng)
                n = batch["view1"].shape[0]
                losses, gsum = [], None
                for i in range(8):
                    sl = slice(i * n // 8, (i + 1) * n // 8)
                    sub = {k: v[sl] for k, v in batch.items()}
                    l, g = oracle(cfg, params, sub, rng)
                    losses.append(float(l))
                    gsum = g if gsum is None else jax.tree.map(jnp.add, gsum, g)
                want = sum(losses) / 8.0
                gmean = jax.tree.map(lambda x: x / 8.0, gsum)
                key = f"{style}/b{block}"
                errs[key] = [abs(float(loss) - want) / max(abs(want), 1e-6),
                             max_grad_err(grads, gmean)]
        print(json.dumps(errs))
        """
    )
    for key, (loss_err, grad_err) in res.items():
        assert loss_err < 5e-4, (key, loss_err)
        assert grad_err < 5e-4, (key, grad_err)


def test_vic_global_uses_global_moments():
    """Satellite regression: the VICReg 'global' variance hinge + centering
    must come from psum'd moments.  Build shards with wildly different local
    means — shard-local moments give a visibly different (wrong) loss."""
    res = run_in_subprocess(
        _COMMON,
        """
        from functools import partial
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        mesh = jax.make_mesh((8,), ("data",))
        n, d = 64, 12
        shift = jnp.repeat(jnp.arange(8.0), n // 8)[:, None] * 3.0
        z1 = jax.random.normal(jax.random.PRNGKey(0), (n, d)) + shift
        z2 = jax.random.normal(jax.random.PRNGKey(1), (n, d)) + shift
        cfg = DecorrConfig(style="vic", reg="sum", q=2, distributed="global",
                           axis_name="data", permute=False)

        @partial(shard_map, mesh=mesh, in_specs=(P("data"), P("data")), out_specs=P())
        def sharded(a, b):
            return ssl_loss(a, b, cfg)[0][None]

        got = float(sharded(z1, z2)[0])
        cfg_l = DecorrConfig(style="vic", reg="sum", q=2, permute=False)
        want = float(ssl_loss(z1, z2, cfg_l)[0])
        # and what the old shard-local-moments bug would have computed
        locals_ = [float(ssl_loss(z1[i*8:(i+1)*8], z2[i*8:(i+1)*8], cfg_l)[0])
                   for i in range(8)]
        buggy = sum(locals_) / 8.0
        print(json.dumps({"got": got, "want": want, "buggy": buggy}))
        """
    )
    assert abs(res["got"] - res["want"]) < 1e-3 * max(abs(res["want"]), 1)
    assert abs(res["buggy"] - res["want"]) > 1e-2 * abs(res["want"])  # bug was visible


def test_regularizer_global_ddof_uses_exact_effective_scale():
    """engine.regularizer(ddof=1) must normalize by n_global - 1 (the LM aux
    path), not the historical (n_local - 1) * P."""
    res = run_in_subprocess(
        """
        from functools import partial
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.core import regularizers as regs
        from repro.decorr import engine
        from repro.core.losses import DecorrConfig

        mesh = jax.make_mesh((8,), ("data",))
        n, d = 64, 16
        z = jax.random.normal(jax.random.PRNGKey(0), (n, d))
        zc = z - jnp.mean(z, axis=0, keepdims=True)
        cfg = DecorrConfig(style="vic", reg="sum", q=2, distributed="global",
                           axis_name="data", permute=False)

        @partial(shard_map, mesh=mesh, in_specs=(P("data"),), out_specs=P())
        def sharded(a):
            scale = float(max(a.shape[0] - 1, 1))
            return engine.regularizer(a, a, cfg, scale, ddof=1)[None]

        got = float(sharded(zc)[0])
        want = float(regs.r_sum_auto(zc, zc, q=2, scale=float(n - 1)))
        legacy = float(regs.r_sum_auto(zc, zc, q=2, scale=float((n // 8 - 1) * 8)))
        print(json.dumps({"got": got, "want": want, "legacy": legacy}))
        """
    )
    assert abs(res["got"] - res["want"]) < 1e-3 * abs(res["want"])
    assert abs(res["legacy"] - res["want"]) > 1e-3 * abs(res["want"])


def test_sharded_train_step_updates_params():
    res = run_in_subprocess(
        _COMMON,
        """
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = DecorrConfig(style="bt", reg="sum", q=2, block_size=8, distributed="tp")
        step, _ = make_sharded_ssl_train_step(
            model, cfg, adamw(), warmup_cosine(1e-3, 1, 10), mesh, clip_norm=1.0)
        state = create_train_state(params, adamw())
        step = jax.jit(step)
        sb = shard_ssl_batch(batch, mesh)
        state1, m1 = step(state, sb)
        state2, m2 = step(state1, sb)
        delta = max(float(jnp.max(jnp.abs(a - b))) for a, b in
                    zip(jax.tree.leaves(state2.params), jax.tree.leaves(params)))
        print(json.dumps({"loss1": float(m1["bt_loss"]), "loss2": float(m2["bt_loss"]),
                          "step": int(state2.step), "delta": delta,
                          "finite": bool(jnp.isfinite(m2["bt_loss"]))}))
        """
    )
    assert res["finite"] and res["step"] == 2 and res["delta"] > 0.0
