"""Full loss functions (Eq. 14 / Eq. 15): values, baseline recovery, grads."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import losses as L
from repro.core import regularizers as regs


def _views(n=32, d=24, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    base = jax.random.normal(k1, (n, d))
    return base + 0.1 * jax.random.normal(k2, (n, d)), base


class TestBarlowTwins:
    def test_baseline_matches_manual(self):
        z1, z2 = _views()
        cfg = L.DecorrConfig(style="bt", reg="off", lam=0.005)
        loss, m = L.barlow_twins_loss(z1, z2, cfg)
        s1, s2 = L.standardize(z1), L.standardize(z2)
        c = regs.cross_correlation_matrix(s1, s2)
        manual = jnp.sum((1 - jnp.diagonal(c)) ** 2) + 0.005 * regs.r_off(c)
        np.testing.assert_allclose(loss, manual, rtol=1e-4)

    def test_proposed_b1_q2_equals_baseline(self):
        z1, z2 = _views()
        base = L.barlow_twins_loss(z1, z2, L.DecorrConfig(style="bt", reg="off"))[0]
        prop = L.barlow_twins_loss(
            z1, z2, L.DecorrConfig(style="bt", reg="sum", block_size=1, q=2, permute=False)
        )[0]
        np.testing.assert_allclose(base, prop, rtol=1e-5)

    def test_identical_views_minimize_invariance(self):
        z, _ = _views()
        cfg = L.DecorrConfig(style="bt", reg="sum")
        _, m = L.barlow_twins_loss(z, z, cfg)
        np.testing.assert_allclose(m["bt_invariance"], 0.0, atol=1e-6)

    def test_gradients_finite(self):
        z1, z2 = _views()
        for cfg in (
            L.DecorrConfig(style="bt", reg="off"),
            L.DecorrConfig(style="bt", reg="sum", q=1),
            L.DecorrConfig(style="bt", reg="sum", block_size=8, q=2),
        ):
            g = jax.grad(lambda a, b: L.barlow_twins_loss(a, b, cfg, jax.random.PRNGKey(0))[0], argnums=(0, 1))(z1, z2)
            assert all(bool(jnp.all(jnp.isfinite(x))) for x in g)

    def test_permutation_leaves_loss_distribution(self):
        # permuting features does not change R_off; R_sum changes (weaker reg)
        z1, z2 = _views()
        cfg_off = L.DecorrConfig(style="bt", reg="off")
        perm = jax.random.permutation(jax.random.PRNGKey(3), 24)
        a = L.barlow_twins_loss(z1, z2, cfg_off)[0]
        b = L.barlow_twins_loss(z1[:, perm], z2[:, perm], cfg_off)[0]
        np.testing.assert_allclose(a, b, rtol=1e-4)


class TestVICReg:
    def test_baseline_matches_manual(self):
        z1, z2 = _views()
        cfg = L.DecorrConfig(style="vic", reg="off", alpha=25.0, mu=25.0, nu=1.0)
        loss, _ = L.vicreg_loss(z1, z2, cfg)
        n, d = z1.shape
        inv = jnp.mean(jnp.sum((z1 - z2) ** 2, axis=-1))
        c1, c2 = L.center(z1), L.center(z2)
        k1 = regs.cross_correlation_matrix(c1, c1, scale=n - 1)
        k2 = regs.cross_correlation_matrix(c2, c2, scale=n - 1)
        manual = (
            25.0 * inv
            + (25.0 / d) * (regs.r_var_from_embeddings(c1) + regs.r_var_from_embeddings(c2))
            + (1.0 / d) * (regs.r_off(k1) + regs.r_off(k2))
        )
        np.testing.assert_allclose(loss, manual, rtol=1e-4)

    @pytest.mark.parametrize("q", [1, 2])
    def test_proposed_runs_and_differentiates(self, q):
        z1, z2 = _views()
        cfg = L.DecorrConfig(style="vic", reg="sum", q=q, block_size=8)
        g = jax.grad(lambda a: L.vicreg_loss(a, z2, cfg, jax.random.PRNGKey(0))[0])(z1)
        assert bool(jnp.all(jnp.isfinite(g)))


class TestEvalMetrics:
    def test_normalized_regularizers_bounded(self):
        z1, z2 = _views()
        v = float(L.normalized_bt_regularizer(z1, z2))
        assert 0.0 <= v <= 1.5  # mean squared correlation
        w = float(L.normalized_vic_regularizer(z1, z2))
        assert w >= 0.0

    def test_decorrelated_embeddings_score_near_zero(self):
        # large-n iid gaussian features are ~uncorrelated
        z = jax.random.normal(jax.random.PRNGKey(0), (4096, 8))
        v = float(L.normalized_bt_regularizer(z, z + 0.0))
        assert v < 0.01
