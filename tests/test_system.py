"""End-to-end behaviour tests: the paper's central claims on a real (small)
training run, plus the decorrelation aux loss wired through an assigned LM.

These are the CPU-scale versions of the paper's Tables 5/6: permutation is
what makes R_sum actually decorrelate (as measured by the *baseline's own*
normalized metric, Eq. 16)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.losses import DecorrConfig, normalized_bt_regularizer
from repro.data import SSLDataConfig, ssl_batch
from repro.optim import adamw, warmup_cosine
from repro.train import create_train_state
from repro.train.ssl import SSLModelConfig, embed, init_ssl_params, make_ssl_train_step

MODEL = SSLModelConfig(input_dim=256, backbone_widths=(128,), projector_widths=(64, 64))
DATA = SSLDataConfig(input_dim=256, batch=128, noise=0.05, mask_prob=0.15, jitter=0.1)


def _train(loss_cfg: DecorrConfig, steps: int = 120, seed: int = 0):
    params = init_ssl_params(jax.random.PRNGKey(seed), MODEL)
    opt = adamw(weight_decay=0.0)
    state = create_train_state(params, opt, seed=seed)
    step_fn, _ = make_ssl_train_step(MODEL, loss_cfg, opt, warmup_cosine(2e-3, 10, steps))
    step_fn = jax.jit(step_fn)
    for i in range(steps):
        v1, v2 = ssl_batch(DATA, i)
        state, metrics = step_fn(state, {"view1": jnp.asarray(v1), "view2": jnp.asarray(v2)})
    # decorrelation quality by the BASELINE metric (Eq. 16) on fresh data
    v1, v2 = ssl_batch(DATA, 10_000)
    z1 = embed(state.params, jnp.asarray(v1))
    z2 = embed(state.params, jnp.asarray(v2))
    return float(normalized_bt_regularizer(z1, z2)), float(metrics["bt_loss" if loss_cfg.style == "bt" else "vic_loss"])


@pytest.mark.slow
def test_proposed_with_permutation_decorrelates_like_baseline():
    """Table 6 behaviour: proposed + permutation reaches a normalized R_off
    in the same ballpark as the baseline; proposed WITHOUT permutation is
    substantially worse (local minima of the relaxation)."""
    q_base, _ = _train(DecorrConfig(style="bt", reg="off", lam=0.01))
    q_perm, _ = _train(DecorrConfig(style="bt", reg="sum", q=2, lam=0.01, permute=True))
    q_nope, _ = _train(DecorrConfig(style="bt", reg="sum", q=2, lam=0.01, permute=False))
    # permutation must close most of the gap to the baseline
    assert q_perm < 2.5 * q_base + 1e-3, (q_base, q_perm, q_nope)
    # and beat the no-permutation ablation clearly
    assert q_perm < q_nope, (q_perm, q_nope)


@pytest.mark.slow
def test_grouped_variant_trains():
    q, loss = _train(DecorrConfig(style="bt", reg="sum", q=2, block_size=16, lam=0.01), steps=60)
    assert np.isfinite(loss) and q < 1.0


@pytest.mark.slow
def test_vicreg_style_trains():
    q, loss = _train(DecorrConfig(style="vic", reg="sum", q=1, nu=1.0), steps=60)
    assert np.isfinite(loss)


def test_lm_decorr_aux_reduces_hidden_correlation():
    """The framework feature: VICReg-style R_sum aux on an assigned arch's
    hidden states lowers feature correlation vs the same run without it."""
    from repro.configs import get_config
    from repro.core.decorrelation import LMDecorrConfig
    from repro.data import LMDataConfig, lm_batch
    from repro.models import forward, init_params
    from repro.train import make_train_step

    def run(enabled):
        cfg = get_config("codeqwen1.5-7b").reduced()
        cfg = dataclasses.replace(
            cfg,
            decorr=LMDecorrConfig(
                enabled=enabled,
                decorr=DecorrConfig(style="vic", reg="sum", q=2),
                mu=1.0,
                nu=2.0,
                tokens_per_seq=16,
            ),
        )
        params = init_params(jax.random.PRNGKey(0), cfg)
        opt = adamw(weight_decay=0.0)
        state = create_train_state(params, opt)
        step = jax.jit(make_train_step(cfg, opt, warmup_cosine(3e-3, 5, 80)))
        dcfg = LMDataConfig(vocab_size=cfg.vocab_size, batch=8, seq_len=32)
        for i in range(80):
            state, m = step(state, {k: jnp.asarray(v) for k, v in lm_batch(dcfg, i).items()})
        out = forward(state.params, cfg, tokens=jnp.asarray(lm_batch(dcfg, 999)["tokens"]))
        h = out.hidden.reshape(-1, cfg.d_model)
        return float(normalized_bt_regularizer(h, h + 0.0)), float(m["ce"])

    q_on, ce_on = run(True)
    q_off, ce_off = run(False)
    assert q_on < q_off, (q_on, q_off)  # aux loss decorrelates hidden features
    assert ce_on < ce_off * 1.25  # without wrecking the LM loss
