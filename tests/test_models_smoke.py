"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of the same family runs one forward and one train step on CPU with correct
shapes and no NaNs."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.core.decorrelation import LMDecorrConfig
from repro.models import forward, init_caches, init_params
from repro.optim import adamw, warmup_cosine
from repro.train import create_train_state, make_train_step

ARCHS = list_archs()


def _inputs(cfg, key, b=2, s=16, with_labels=False):
    out = {}
    if cfg.frontend == "vision_stub":
        out["embeds"] = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32) * 0.02
        pos = jnp.arange(s, dtype=jnp.int32)[None, None, :]
        out["positions"] = jnp.broadcast_to(pos, (3, b, s))
        if with_labels:
            out["labels"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    elif cfg.frontend == "audio_codes":
        out["tokens"] = jax.random.randint(key, (b, s, cfg.n_codebooks), 0, cfg.vocab_size)
        if with_labels:
            out["labels"] = jax.random.randint(key, (b, s, cfg.n_codebooks), 0, cfg.vocab_size)
    else:
        out["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
        if with_labels:
            out["labels"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 16
    out = forward(params, cfg, **_inputs(cfg, jax.random.PRNGKey(1), b, s))
    if cfg.frontend == "audio_codes":
        assert out.logits.shape == (b, s, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert out.logits.shape == (b, s, cfg.vocab_size)
    assert out.hidden.shape == (b, s, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(out.logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, decorr=LMDecorrConfig(enabled=True, nu=0.001))
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw()
    state = create_train_state(params, opt)
    step = jax.jit(make_train_step(cfg, opt, warmup_cosine(1e-3, 2, 10)))
    batch = _inputs(cfg, jax.random.PRNGKey(2), 2, 16, with_labels=True)
    new_state, metrics = step(state, batch)
    # two steps: warmup lr at step 0 is exactly 0 by design
    new_state, metrics = step(new_state, batch)
    assert int(new_state.step) == 2
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["decorr_aux"]))
    # params actually changed
    changed = any(
        not bool(jnp.allclose(a, b))
        for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(new_state.params))
    )
    assert changed


@pytest.mark.parametrize("arch", ["gemma2-2b", "rwkv6-3b", "jamba-v0.1-52b", "musicgen-large"])
def test_prefill_then_decode_runs(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 12
    caches = init_caches(cfg, b, s + 4)
    inp = _inputs(cfg, jax.random.PRNGKey(1), b, s)
    out = forward(params, cfg, **inp, caches=caches, cache_len=jnp.asarray(0, jnp.int32))
    assert out.caches is not None
    dec_inp = _inputs(cfg, jax.random.PRNGKey(2), b, 1)
    out2 = forward(params, cfg, **dec_inp, caches=out.caches, cache_len=jnp.asarray(s, jnp.int32))
    assert bool(jnp.all(jnp.isfinite(out2.logits)))


def test_param_counts_match_nominal_sizes():
    expected = {
        "qwen1.5-110b": (100e9, 125e9),
        "nemotron-4-340b": (320e9, 360e9),
        "arctic-480b": (450e9, 500e9),
        "jamba-v0.1-52b": (48e9, 56e9),
        "llama4-scout-17b-a16e": (100e9, 115e9),  # 109B total / 17B active
        "rwkv6-3b": (1.3e9, 3.5e9),
        "gemma2-2b": (1.8e9, 3.2e9),
        "codeqwen1.5-7b": (6e9, 9e9),
        "qwen2-vl-2b": (1.2e9, 2.2e9),
        "musicgen-large": (1.8e9, 3.4e9),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.1f}B outside [{lo/1e9}, {hi/1e9}]"


def test_active_params_moe():
    cfg = get_config("llama4-scout-17b-a16e")
    active = cfg.active_param_count()
    assert 12e9 <= active <= 22e9  # "17B active"
    cfg2 = get_config("arctic-480b")
    assert cfg2.active_param_count() < 0.15 * cfg2.param_count()
