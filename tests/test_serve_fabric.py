"""repro.serve.fabric — router policy, failover determinism, tp forward.

The failover gate is the one that matters: a replica killed mid-decode must
have every stranded request requeued and the final greedy token streams stay
BIT-IDENTICAL to a run that never saw the failure.  Everything runs on a
fake clock (nothing sleeps); the tp-forward oracle runs in a subprocess with
forced host devices (same pattern as test_serve_system).
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.ft.watchdog import HeartbeatMonitor
from repro.models import init_params
from repro.obs import Obs
from repro.serve import ContinuousLMEngine, EmbeddingService, LMService, ServeEngine
from repro.serve.fabric import (
    FabricConfig,
    FailoverController,
    Replica,
    Router,
    ServeFabric,
    make_replica_mesh,
    prefix_key,
)
from repro.train.ssl import SSLModelConfig, init_ssl_params

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MODEL = SSLModelConfig(input_dim=24, backbone_widths=(32,), projector_widths=(48, 48))


# ---------------------------------------------------------------------------
# Router: pure policy over replica snapshots
# ---------------------------------------------------------------------------


class FakeReplica:
    def __init__(self, name, occ=0.0, queue=0.0, ttft=0.0, slots=4.0, alive=True):
        self.name = name
        self.alive = alive
        self._snap = {
            "slots_total": slots,
            "slots_occupancy": occ,
            "queue_depth": queue,
            "serve_ttft_seconds_p99": ttft,
        }

    def snapshot(self):
        return dict(self._snap)


class TestRouter:
    def test_least_occupancy_prefers_idle_replica(self):
        r = Router("least_occupancy", affinity_tokens=0)
        a, b = FakeReplica("a", occ=0.75), FakeReplica("b", occ=0.25)
        chosen, how = r.pick([a, b])
        assert chosen is b and how == "least_occupancy"

    def test_queue_depth_breaks_equal_occupancy(self):
        r = Router("least_occupancy", affinity_tokens=0)
        a = FakeReplica("a", occ=0.5, queue=8.0)
        b = FakeReplica("b", occ=0.5, queue=1.0)
        assert r.pick([a, b])[0] is b

    def test_weighted_ttft_sheds_slow_replica(self):
        r = Router("weighted_ttft", affinity_tokens=0)
        a = FakeReplica("a", occ=0.5, ttft=0.500)  # slow admitter
        b = FakeReplica("b", occ=0.6, ttft=0.001)  # busier but fast
        assert r.pick([a, b])[0] is b

    def test_weighted_ttft_cold_degrades_to_occupancy(self):
        r = Router("weighted_ttft", affinity_tokens=0)
        a, b = FakeReplica("a", occ=0.75), FakeReplica("b", occ=0.25)
        assert r.pick([a, b])[0] is b  # both ttft=0: floor keeps ordering

    def test_affinity_sticks_then_remaps_on_death(self):
        r = Router("least_occupancy", affinity_tokens=4)
        a, b = FakeReplica("a", occ=0.0), FakeReplica("b", occ=0.9)
        tokens = np.arange(8, dtype=np.int32)
        first, how1 = r.pick([a, b], tokens=tokens)
        assert first is a and how1 == "least_occupancy"
        # load inverts, but the shared prefix stays sticky
        a._snap["slots_occupancy"], b._snap["slots_occupancy"] = 0.9, 0.0
        again, how2 = r.pick([a, b], tokens=tokens)
        assert again is a and how2 == "affinity"
        # a dies: mapping dropped, rerouted by load, re-recorded
        a.alive = False
        r.forget("a")
        third, how3 = r.pick([a, b], tokens=tokens)
        assert third is b and how3 == "least_occupancy"
        assert r.pick([a, b], tokens=tokens) == (b, "affinity")

    def test_prefix_key_only_hashes_leading_tokens(self):
        base = np.arange(32, dtype=np.int32)
        other = base.copy()
        other[20:] += 7  # tail differs
        assert prefix_key(base, 16) == prefix_key(other, 16)
        assert prefix_key(base, 32) != prefix_key(other, 32)

    def test_no_healthy_replica_raises(self):
        r = Router()
        with pytest.raises(RuntimeError, match="no healthy replica"):
            r.pick([FakeReplica("a", alive=False)])

    def test_unknown_policy_rejected(self):
        with pytest.raises(ValueError, match="unknown routing policy"):
            Router("round_robin")


# ---------------------------------------------------------------------------
# Failover controller: edge-triggered staleness on an injectable clock
# ---------------------------------------------------------------------------


class TestFailoverController:
    def test_newly_dead_reports_each_replica_once(self):
        t = {"now": 0.0}
        fc = FailoverController(
            HeartbeatMonitor(default_timeout_s=5.0, clock=lambda: t["now"]),
            timeout_s=5.0,
        )
        fc.register("r0")
        fc.register("r1")
        t["now"] = 3.0
        fc.beat("r1")
        t["now"] = 6.0  # r0 stale (6s), r1 fresh (3s)
        assert fc.newly_dead(["r0", "r1"]) == ["r0"]
        assert fc.newly_dead(["r0", "r1"]) == []  # edge-triggered
        assert fc.is_dead("r0") and not fc.is_dead("r1")
        assert fc.metrics() == {"fabric_replicas_dead": 1.0}

    def test_revive_rearms_detection(self):
        t = {"now": 0.0}
        fc = FailoverController(
            HeartbeatMonitor(default_timeout_s=2.0, clock=lambda: t["now"]),
            timeout_s=2.0,
        )
        fc.register("r0")
        t["now"] = 3.0
        assert fc.newly_dead(["r0"]) == ["r0"]
        fc.revive("r0")
        assert not fc.is_dead("r0")
        t["now"] = 6.0
        assert fc.newly_dead(["r0"]) == ["r0"]  # dies again after re-join


# ---------------------------------------------------------------------------
# ServeFabric end-to-end (synchronous drive, fake clock)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def gemma():
    cfg = get_config("gemma2-2b").reduced()
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


def _lm_factory(gemma):
    cfg, params = gemma

    def factory(name):
        eng = ContinuousLMEngine(
            cfg, params, n_slots=4, max_len=64, max_prompt_len=24,
            paged=True, page_size=16,
        )
        return LMService(eng, obs=Obs())

    return factory


def _embed_factory():
    params = init_ssl_params(jax.random.PRNGKey(1), MODEL)

    def factory(name):
        return EmbeddingService(ServeEngine(MODEL, params), obs=Obs())

    return factory, params


def _prompts(cfg, n=6, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, 8).astype(np.int32) for _ in range(n)]


class TestServeFabric:
    def test_config_validation(self):
        with pytest.raises(ValueError, match="at least one replica"):
            FabricConfig(replicas=0).validate()
        with pytest.raises(ValueError, match="unknown policy"):
            FabricConfig(policy="nope").validate()
        with pytest.raises(ValueError, match="lm_factory"):
            ServeFabric(FabricConfig())

    def test_replica_requires_a_service(self):
        with pytest.raises(ValueError, match="at least one service"):
            Replica("empty")

    def test_kill_rejects_threaded_replicas(self):
        r = Replica("x", lm=object())
        r.started = True  # as if start() ran
        with pytest.raises(RuntimeError, match="synchronous"):
            r.kill()

    def test_failover_requeues_and_tokens_stay_bit_identical(self, gemma):
        cfg, _ = gemma
        factory = _lm_factory(gemma)
        prompts = _prompts(cfg)

        # single-engine greedy oracle
        oracle_svc = factory("oracle")
        ofuts = [oracle_svc.submit(p, 6) for p in prompts]
        oracle_svc.drain()
        oracle = [f.result(timeout=60) for f in ofuts]

        t = {"now": 0.0}
        obs = Obs()
        fab = ServeFabric(
            FabricConfig(replicas=2, heartbeat_timeout_s=5.0),
            lm_factory=factory, obs=obs, clock=lambda: t["now"],
        )
        futs = [fab.submit_lm(p, 6) for p in prompts]
        for _ in range(3):  # both replicas admit + decode a few ticks
            fab.step()
        fab.kill("r0")
        t["now"] += 10.0  # heartbeat goes stale; step() declares r0 dead
        fab.drain()

        outs = [f.result(timeout=60) for f in futs]
        assert all(np.array_equal(a, b) for a, b in zip(outs, oracle))
        assert fab.requeued_total >= 1 and fab.dead_total == 1
        assert not fab.replica("r0").alive and fab.replica("r1").alive

        counts = obs.recorder.counts()
        assert counts["replica_join"] == 2
        assert counts["replica_dead"] == 1
        assert counts["route"] == len(prompts)
        assert counts["requeue"] == fab.requeued_total

    def test_requests_finished_before_crash_are_delivered(self, gemma):
        cfg, _ = gemma
        factory = _lm_factory(gemma)
        (prompt,) = _prompts(cfg, n=1)
        t = {"now": 0.0}
        fab = ServeFabric(
            FabricConfig(replicas=2, heartbeat_timeout_s=5.0),
            lm_factory=factory, clock=lambda: t["now"],
        )
        fut = fab.submit_lm(prompt, 2)
        tracked = next(iter(fab._inflight.values()))
        owner = fab.replica(tracked.replica)
        while not tracked.inner.done():  # finish the decode BEFORE the crash lands
            owner.tick()
        fab.kill(owner.name)
        t["now"] += 10.0
        fab.step()  # _on_dead sees a done inner future: deliver, don't requeue
        assert fab.dead_total == 1 and fab.requeued_total == 0
        assert len(fut.result(timeout=0)) == 2

    def test_mixed_embed_and_lm_routing(self, gemma):
        cfg, _ = gemma
        embed_factory, eparams = _embed_factory()
        fab = ServeFabric(
            FabricConfig(replicas=2, heartbeat_timeout_s=5.0),
            lm_factory=_lm_factory(gemma), embed_factory=embed_factory,
        )
        x = np.random.default_rng(3).standard_normal((4, 24)).astype(np.float32)
        efut = fab.submit_embed(x)
        lfut = fab.submit_lm(_prompts(cfg, n=1)[0], 3)
        fab.drain()
        ref = np.asarray(ServeEngine(MODEL, eparams).encode(x))
        np.testing.assert_allclose(np.asarray(efut.result(timeout=60)), ref, atol=1e-5)
        assert len(lfut.result(timeout=60)) == 3

    def test_dead_replica_replacement_rejoins(self, gemma):
        cfg, _ = gemma
        factory = _lm_factory(gemma)
        t = {"now": 0.0}
        fab = ServeFabric(
            FabricConfig(replicas=2, heartbeat_timeout_s=5.0),
            lm_factory=factory, clock=lambda: t["now"],
        )
        with pytest.raises(ValueError, match="already joined"):
            fab.add_replica(Replica("r0", lm=factory("dup")))
        fab.kill("r0")
        t["now"] += 10.0
        fab.step()
        assert fab.replica("r0").alive is False
        fab.add_replica(Replica("r0", lm=factory("r0b")))
        assert fab.replica("r0").alive
        fut = fab.submit_lm(_prompts(cfg, n=1)[0], 2)
        fab.drain()
        assert len(fut.result(timeout=60)) == 2
        assert len(fab.replicas) == 2

    def test_metrics_labelled_and_legacy_views(self, gemma):
        obs = Obs()
        fab = ServeFabric(
            FabricConfig(replicas=2, heartbeat_timeout_s=5.0),
            lm_factory=_lm_factory(gemma), obs=obs,
        )
        fab.step()
        m = fab.metrics()
        # flat aggregates + legacy per-name heartbeat keys stay in the dict
        assert m["fabric_replicas"] == 2.0 and m["fabric_replicas_alive"] == 2.0
        assert "heartbeat_age_s_fabric_replica_r0" in m
        # the registry carries labelled children, not per-name families
        ad = obs.registry.as_dict()
        assert 'fabric_replica_alive{replica="r0"}' in ad
        assert 'heartbeat_age_s{name="fabric.replica.r1"}' in ad
        assert "heartbeat_age_s_fabric_replica_r0" not in ad
        assert obs.registry.value("fabric_replicas") == 2.0
        per = fab.replica_metrics()
        assert set(per) == {"r0", "r1"} and per["r0"]["replica_alive"] == 1.0

    def test_kill_is_undetected_until_stale(self, gemma):
        t = {"now": 0.0}
        fab = ServeFabric(
            FabricConfig(replicas=2, heartbeat_timeout_s=5.0),
            lm_factory=_lm_factory(gemma), clock=lambda: t["now"],
        )
        fab.kill("r1")
        fab.step()
        assert fab.replica("r1").alive  # crashed but not yet declared
        t["now"] += 10.0
        fab.step()
        assert not fab.replica("r1").alive and fab.dead_total == 1


# ---------------------------------------------------------------------------
# Heartbeat publish_metrics: one labelled family, legacy keys claimed
# ---------------------------------------------------------------------------


class TestHeartbeatLabels:
    def test_publish_metrics_claims_legacy_keys(self):
        from repro.obs.registry import MetricsRegistry

        t = {"now": 0.0}
        hb = HeartbeatMonitor(default_timeout_s=5.0, clock=lambda: t["now"])
        hb.register("serve.dispatch")
        hb.register("serve.lm_decode")
        t["now"] = 1.5
        reg = MetricsRegistry()
        claimed = hb.publish_metrics(reg)
        assert claimed == {
            "heartbeat_age_s_serve_dispatch",
            "heartbeat_age_s_serve_lm_decode",
        }
        assert reg.value("heartbeat_age_s", {"name": "serve.dispatch"}) == 1.5
        assert reg.value("heartbeat_components") == 2.0
        ad = reg.as_dict()
        assert 'heartbeat_age_s{name="serve.lm_decode"}' in ad
        assert "heartbeat_age_s_serve_dispatch" not in ad
        # the dict view keeps the legacy name-suffixed keys for callers
        assert hb.metrics()["heartbeat_age_s_serve_dispatch"] == 1.5

    def test_collect_metrics_skips_claimed_keys_in_registry(self):
        from repro.obs.registry import MetricsRegistry
        from repro.serve.service import collect_metrics

        t = {"now": 0.0}
        hb = HeartbeatMonitor(default_timeout_s=5.0, clock=lambda: t["now"])
        hb.register("serve.dispatch")
        reg = MetricsRegistry()
        out = collect_metrics({"queue_depth": 3.0}, hb, registry=reg)
        assert out["queue_depth"] == 3.0
        assert "heartbeat_age_s_serve_dispatch" in out  # dict view: legacy
        assert reg.value("queue_depth") == 3.0
        assert reg.get("heartbeat_age_s_serve_dispatch") is None  # labelled only


# ---------------------------------------------------------------------------
# tp forward: feature-sharded replica matches the single-device oracle
# ---------------------------------------------------------------------------


def test_make_replica_mesh_single_device_is_none():
    assert make_replica_mesh(tp=1) is None
    with pytest.raises(ValueError, match="devices"):
        make_replica_mesh(tp=64)


def test_tp_forward_matches_single_device_oracle():
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax
        from repro.serve.fabric import make_replica_mesh
        from repro.serve.loadgen import tp_oracle_err
        from repro.train.ssl import SSLModelConfig, init_ssl_params

        model = SSLModelConfig(input_dim=24, backbone_widths=(32,), projector_widths=(48, 48))
        params = init_ssl_params(jax.random.PRNGKey(0), model)
        out = {"tp2": tp_oracle_err(model, params, tp=2),
               "tp4": tp_oracle_err(model, params, tp=4)}
        mesh = make_replica_mesh(tp=2, offset=2)
        out["mesh_axes"] = list(mesh.axis_names)
        out["mesh_shape"] = [mesh.shape[a] for a in mesh.axis_names]
        print(json.dumps(out))
        """
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=420
    )
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-3000:]}"
    res = json.loads(proc.stdout.strip().splitlines()[-1])
    assert res["tp2"] < 1e-5, res
    assert res["tp4"] < 1e-5, res
    assert res["mesh_axes"] == ["data", "model"] and res["mesh_shape"] == [1, 2]
