"""Unit tests for the paper's core algebra (Eq. 5-12)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sumvec as sv
from repro.core import regularizers as regs


def _views(n=16, d=24, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return jax.random.normal(k1, (n, d)), jax.random.normal(k2, (n, d))


class TestInvolution:
    def test_definition(self):
        x = jnp.arange(6.0)
        out = sv.involution(x)
        # [x]_{(d-i) mod d}
        np.testing.assert_allclose(out, jnp.asarray([0.0, 5, 4, 3, 2, 1]))

    def test_involution_is_self_inverse(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (11,))
        np.testing.assert_allclose(sv.involution(sv.involution(x)), x)

    def test_fourier_conjugation(self):
        # F(inv(x)) == conj(F(x))  (the identity Eq. 11 relies on)
        x = jax.random.normal(jax.random.PRNGKey(1), (16,))
        lhs = jnp.fft.fft(sv.involution(x))
        rhs = jnp.conj(jnp.fft.fft(x))
        np.testing.assert_allclose(lhs, rhs, atol=1e-5)


class TestCircularOps:
    def test_convolution_theorem(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (12,))
        y = jax.random.normal(jax.random.PRNGKey(1), (12,))
        direct = sv.circular_convolve(x, y)
        via_fft = jnp.fft.ifft(jnp.fft.fft(x) * jnp.fft.fft(y)).real
        np.testing.assert_allclose(direct, via_fft, atol=1e-5)

    def test_circular_correlation_identity(self):
        # inv(x) * y == circular correlation (Appendix A)
        x = jax.random.normal(jax.random.PRNGKey(2), (10,))
        y = jax.random.normal(jax.random.PRNGKey(3), (10,))
        lhs = sv.circular_convolve(sv.involution(x), y)
        rhs = sv.circular_correlate_naive(x[None], y[None])[0]
        np.testing.assert_allclose(lhs, rhs, atol=1e-5)


class TestSumvec:
    @pytest.mark.parametrize("d", [8, 13, 24, 64])
    def test_fft_equals_matrix_route(self, d):
        z1, z2 = _views(d=d)
        c = regs.cross_correlation_matrix(z1, z2, scale=16)
        fft = sv.sumvec_fft(z1, z2, scale=16.0)
        mat = sv.sumvec_from_matrix(c)
        np.testing.assert_allclose(fft, mat, atol=1e-4)

    def test_direct_equals_fft(self):
        z1, z2 = _views()
        np.testing.assert_allclose(
            sv.sumvec_direct(z1, z2), sv.sumvec_fft(z1, z2), atol=1e-3
        )

    def test_zeroth_is_trace(self):
        z1, z2 = _views()
        c = regs.cross_correlation_matrix(z1, z2)
        svec = sv.sumvec_from_matrix(c)
        np.testing.assert_allclose(svec[0], jnp.trace(c), rtol=1e-5)

    def test_components_partition_matrix(self):
        # every element of C appears in exactly one component (paper §4.1)
        z1, z2 = _views(d=8)
        c = regs.cross_correlation_matrix(z1, z2)
        svec = sv.sumvec_from_matrix(c)
        np.testing.assert_allclose(jnp.sum(svec), jnp.sum(c), rtol=1e-4)


class TestGrouped:
    @pytest.mark.parametrize("b", [4, 7, 8, 24])
    def test_grouped_fft_equals_matrix_blocks(self, b):
        z1, z2 = _views(d=24)
        c = regs.cross_correlation_matrix(z1, z2, scale=16)
        got = sv.grouped_sumvec_fft(z1, z2, b, scale=16.0)
        want = sv.grouped_sumvec_from_matrix(c, b)
        np.testing.assert_allclose(got, want, atol=1e-4)

    def test_padding_contributes_zero(self):
        # d=10 with b=4 pads 2 dummy features; they must not change block
        # sums that exclude them
        z1, z2 = _views(d=10)
        g = sv.grouped_sumvec_fft(z1, z2, 4)
        assert g.shape == (3, 3, 4)
        assert bool(jnp.all(jnp.isfinite(g)))


class TestParseval:
    @pytest.mark.parametrize("d", [8, 9, 16, 33])
    def test_sq_sum_and_zeroth(self, d):
        s = jax.random.normal(jax.random.PRNGKey(0), (d,))
        g = jnp.fft.rfft(s)
        sq, s0 = sv.sq_sum_and_zeroth_from_freq(g, d)
        np.testing.assert_allclose(sq, jnp.sum(s**2), rtol=1e-5)
        np.testing.assert_allclose(s0, s[0], atol=1e-5)
