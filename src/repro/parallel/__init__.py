from repro.parallel.sharding import sharding_context, shard, logical_to_spec, named_sharding
