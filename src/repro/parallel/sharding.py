"""Logical-axis sharding rules.

Models annotate activations with *logical* axes, e.g.
``shard(x, ("batch", "seq", "embed"))``; the launcher installs a rule table
mapping logical axes to mesh axes.  Outside any installed context the
annotations are no-ops, so unit tests and single-device runs never touch
device state.

Default rule table (DESIGN.md §4):
  batch    -> ("pod", "data")   activations data-parallel
  embed    -> None              residual stream replicated (SP variant: "seq"
                                logical axis mapped to "model")
  heads    -> "model"           attention TP (archs with heads % tp == 0)
  kv_heads -> None              small; replicated within a model row
  ff       -> "model"           MLP TP
  experts  -> "model"           expert parallelism
  vocab    -> "model"           embedding/LM-head TP
  kv_seq   -> "model"           decode KV caches seq-sharded (flash-decoding)
  fsdp     -> "data"            parameter/optimizer-state sharding
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

_STATE = threading.local()

DEFAULT_RULES: Dict[str, Optional[Tuple[str, ...]]] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": ("model",),
    "kv_heads": None,
    "head_dim": None,
    "ff": ("model",),
    "feature": ("model",),  # TP projector output (decorr engine 'tp' mode)
    "experts": ("model",),
    "vocab": ("model",),
    "kv_seq": ("model",),
    "fsdp": ("data",),
    "stack": None,  # stacked-layer leading dim
}


def current_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


def current_rules() -> Dict[str, Optional[Tuple[str, ...]]]:
    return getattr(_STATE, "rules", DEFAULT_RULES)


@contextlib.contextmanager
def sharding_context(mesh: Mesh, rules: Optional[Dict] = None):
    """Install mesh + logical rules for model-internal annotations."""
    prev_mesh = getattr(_STATE, "mesh", None)
    prev_rules = getattr(_STATE, "rules", None)
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    _STATE.mesh = mesh
    _STATE.rules = merged
    try:
        yield
    finally:
        _STATE.mesh = prev_mesh
        if prev_rules is None:
            if hasattr(_STATE, "rules"):
                del _STATE.rules
        else:
            _STATE.rules = prev_rules


def logical_to_spec(axes: Sequence[Optional[str]]) -> PartitionSpec:
    """Map logical axis names to a PartitionSpec under the current rules,
    dropping mesh axes that don't exist in the current mesh (e.g. "pod" on
    the single-pod mesh)."""
    mesh = current_mesh()
    rules = current_rules()
    mesh_axes = set(mesh.axis_names) if mesh is not None else set()
    spec = []
    used: set = set()
    for ax in axes:
        if ax is None:
            spec.append(None)
            continue
        mapped = rules.get(ax)
        if mapped is None:
            spec.append(None)
            continue
        keep = tuple(m for m in mapped if m in mesh_axes and m not in used)
        used.update(keep)
        if not keep:
            spec.append(None)
        elif len(keep) == 1:
            spec.append(keep[0])
        else:
            spec.append(keep)
    return PartitionSpec(*spec)


def shard(x, axes: Sequence[Optional[str]]):
    """with_sharding_constraint by logical axes; no-op without a context.

    Axes whose dimension is not divisible by (or is smaller than) the mapped
    mesh-axis product are dropped per-axis — e.g. an 8-head attention on a
    16-way model axis falls back to replicated heads instead of forcing
    GSPMD into involuntary full rematerialization."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = logical_to_spec(axes)
    parts = list(spec) + [None] * (x.ndim - len(spec))
    fixed = []
    for dim, part in zip(x.shape, parts):
        if part is None:
            fixed.append(None)
            continue
        mesh_axes = part if isinstance(part, tuple) else (part,)
        n = 1
        for a in mesh_axes:
            n *= mesh.shape[a]
        fixed.append(part if (dim % n == 0 and dim >= n) else None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, PartitionSpec(*fixed)))


def named_sharding(axes: Sequence[Optional[str]]) -> Optional[NamedSharding]:
    mesh = current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_to_spec(axes))
