"""Optimizers (self-contained — no optax dependency).

* ``lars``  — Layer-wise Adaptive Rate Scaling [arXiv:1708.03888], the
  optimizer used by the paper (and Barlow Twins / VICReg).  Bias/norm
  parameters (ndim < 2) are excluded from adaptation + weight decay, as in
  the reference implementations.
* ``adamw`` — decoupled weight decay Adam; moment dtype configurable
  (bf16 moments halve optimizer HBM for the 100B+ archs — DESIGN.md §7).
* ``sgd_momentum``.

Interface: ``opt.init(params) -> state``; ``opt.update(grads, state, params,
lr) -> (new_params, new_state)``.  All pure pytree maps — shard-agnostic
(optimizer state inherits parameter sharding under pjit).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree, Array], tuple[PyTree, PyTree]]
    name: str = "optimizer"


def _tree_zeros_like(params, dtype=None):
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=dtype or p.dtype), params)


def _is_adaptive(p: Array) -> bool:
    """LARS adaptation / weight decay applies to matrices, not bias/norm."""
    return p.ndim >= 2


# ---------------------------------------------------------------------------
# LARS (the paper's optimizer)
# ---------------------------------------------------------------------------


def lars(
    momentum: float = 0.9,
    weight_decay: float = 1e-4,
    trust_coefficient: float = 0.001,
    eps: float = 1e-8,
) -> Optimizer:
    def init(params):
        return {"mu": _tree_zeros_like(params, jnp.float32)}

    def update(grads, state, params, lr):
        def one(g, mu, p):
            g = g.astype(jnp.float32)
            p32 = p.astype(jnp.float32)
            if _is_adaptive(p):
                g = g + weight_decay * p32
                w_norm = jnp.linalg.norm(p32)
                g_norm = jnp.linalg.norm(g)
                trust = jnp.where(
                    (w_norm > 0) & (g_norm > 0),
                    trust_coefficient * w_norm / (g_norm + eps),
                    1.0,
                )
            else:
                trust = 1.0
            mu = momentum * mu + trust * g
            new_p = p32 - lr * mu
            return new_p.astype(p.dtype), mu

        flat = jax.tree.map(one, grads, state["mu"], params)
        new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"mu": new_mu}

    return Optimizer(init=init, update=update, name="lars")


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw(
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    moment_dtype=jnp.float32,
) -> Optimizer:
    def init(params):
        return {
            "m": _tree_zeros_like(params, moment_dtype),
            "v": _tree_zeros_like(params, moment_dtype),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        count = state["count"] + 1
        c1 = 1.0 - b1 ** count.astype(jnp.float32)
        c2 = 1.0 - b2 ** count.astype(jnp.float32)

        def one(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
            mh = m32 / c1
            vh = v32 / c2
            upd = mh / (jnp.sqrt(vh) + eps)
            if _is_adaptive(p):
                upd = upd + weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * upd
            return new_p.astype(p.dtype), m32.astype(moment_dtype), v32.astype(moment_dtype)

        flat = jax.tree.map(one, grads, state["m"], state["v"], params)
        pick = lambda i: jax.tree.map(lambda t: t[i], flat, is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), {"m": pick(1), "v": pick(2), "count": count}

    return Optimizer(init=init, update=update, name="adamw")


def sgd_momentum(momentum: float = 0.9, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {"mu": _tree_zeros_like(params, jnp.float32)}

    def update(grads, state, params, lr):
        def one(g, mu, p):
            g32 = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
            mu = momentum * mu + g32
            return (p.astype(jnp.float32) - lr * mu).astype(p.dtype), mu

        flat = jax.tree.map(one, grads, state["mu"], params)
        pick = lambda i: jax.tree.map(lambda t: t[i], flat, is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), {"mu": pick(1)}

    return Optimizer(init=init, update=update, name="sgd_momentum")


# ---------------------------------------------------------------------------
# Gradient utilities
# ---------------------------------------------------------------------------


def global_norm(tree) -> Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def warmup_cosine(lr: float, warmup_steps: int, total_steps: int, min_ratio: float = 0.01):
    """Linear warmup + cosine decay — the paper's schedule."""

    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / max(warmup_steps, 1)
        prog = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)

    return schedule
