from repro.optim.optimizers import (
    Optimizer,
    lars,
    adamw,
    sgd_momentum,
    clip_by_global_norm,
    global_norm,
    warmup_cosine,
)
from repro.optim.compression import bf16_psum, int8_psum_ef, init_error_feedback
