"""Gradient compression for cross-pod all-reduce (DESIGN.md §7).

Two schemes, both used inside ``shard_map`` train steps where the gradient
reduction is explicit (under plain pjit the all-reduce is implicit and XLA
chooses the dtype of the collective):

* ``bf16_psum``          — cast to bf16 before psum (2x volume reduction);
                           unbiased for mean-reduction at our batch sizes.
* ``int8_psum_ef``       — per-leaf int8 quantization with error feedback
                           [1-bit Adam lineage]: the quantization residual is
                           carried to the next step, making the compressed
                           SGD trajectory track the uncompressed one.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def bf16_psum(grads, axis_name: str):
    return jax.tree.map(
        lambda g: jax.lax.psum(g.astype(jnp.bfloat16), axis_name).astype(jnp.float32),
        grads,
    )


def _quantize_int8(x: Array, scale: Array = None) -> Tuple[Array, Array]:
    if scale is None:
        scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_psum_ef(grads, errors, axis_name: str):
    """Compressed psum with error feedback.

    All shards quantize against a COMMON per-leaf scale (one scalar pmax —
    negligible traffic) so the int32 psum of quantized values is exact:
    sum_i q_i * s == (sum_i q_i) * s.  Per-shard quantization residuals are
    carried in ``errors`` and added to the next step's gradient (error
    feedback), so the compressed trajectory tracks the exact one.

    grads/errors: matching pytrees.  Returns (reduced_sum_f32, new_errors).
    """

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        local_max = jnp.max(jnp.abs(g32))
        scale = jax.lax.pmax(local_max, axis_name) / 127.0 + 1e-12
        q, _ = _quantize_int8(g32, scale)
        deq = q.astype(jnp.float32) * scale
        new_e = g32 - deq
        total = jax.lax.psum(q.astype(jnp.int32), axis_name).astype(jnp.float32)
        return total * scale, new_e

    flat = jax.tree.map(one, grads, errors)
    pick = lambda i: jax.tree.map(lambda t: t[i], flat, is_leaf=lambda x: isinstance(x, tuple))
    return pick(0), pick(1)


def init_error_feedback(grads_template):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_template)
