"""Host data pipeline: background prefetch + device placement with the
global-batch sharding.

``ShardedPrefetcher`` wraps any numpy-batch iterator: a worker thread keeps
``depth`` batches ahead (overlapping host data generation with device step
time), and each batch is ``jax.device_put`` with the batch NamedSharding so
per-device slices are laid out before the step is dispatched.  On multi-host
pods the same code path uses ``jax.make_array_from_process_local_data``.
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Iterator, Optional

import jax
from jax.sharding import NamedSharding


class ShardedPrefetcher:
    def __init__(
        self,
        it: Iterator[Any],
        sharding: Optional[NamedSharding] = None,
        depth: int = 2,
    ):
        self._it = it
        self._sharding = sharding
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._err: Optional[BaseException] = None
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _place(self, batch):
        if self._sharding is None:
            return batch
        if jax.process_count() > 1:  # multi-host path
            return jax.tree.map(
                lambda x: jax.make_array_from_process_local_data(self._sharding, x),
                batch,
            )
        return jax.tree.map(lambda x: jax.device_put(x, self._sharding), batch)

    def _worker(self):
        try:
            for batch in self._it:
                if self._stop.is_set():
                    return
                self._q.put(self._place(batch))
        except BaseException as e:  # surfaced on next __next__
            self._err = e
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
