"""Deterministic synthetic data (no external datasets in this container).

Everything is keyed by (seed, step) fold-ins: restart-safe, host-count
independent, reproducible — a data-loader failure or elastic re-mesh resumes
with bit-identical batches.

* LM stream: Zipf-ish token ids with a planted bigram structure so the
  cross-entropy actually decreases during the examples' training runs.
* SSL stream: latent-factor vectors rendered to "images"; two views are
  produced by the paper's augmentation *semantics* (crop -> coordinate mask,
  color jitter -> channel scale/shift, noise) in vector form.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import jax
import numpy as np

Array = jax.Array


# ---------------------------------------------------------------------------
# LM token stream
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LMDataConfig:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    n_codebooks: int = 0  # musicgen: tokens (B, S, n_q)


def lm_batch(cfg: LMDataConfig, step: int) -> Dict[str, np.ndarray]:
    """Markov-ish synthetic tokens: t_{i+1} = (a * t_i + noise) % V."""
    rng = np.random.default_rng(np.uint64(cfg.seed * 1_000_003 + step))
    shape = (cfg.batch, cfg.seq_len + 1)
    if cfg.n_codebooks:
        shape = shape + (cfg.n_codebooks,)
    first = rng.integers(0, cfg.vocab_size, size=(cfg.batch, 1) + shape[2:])
    noise = rng.integers(0, 17, size=shape)
    toks = np.empty(shape, np.int64)
    toks[:, 0] = first[:, 0]
    mult = 31
    for i in range(1, shape[1]):
        toks[:, i] = (toks[:, i - 1] * mult + noise[:, i]) % cfg.vocab_size
    toks = toks.astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def lm_iterator(cfg: LMDataConfig, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield lm_batch(cfg, step)
        step += 1


# ---------------------------------------------------------------------------
# SSL two-view stream (the paper's setting)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SSLDataConfig:
    input_dim: int = 3072
    latent_dim: int = 64
    batch: int = 256
    seed: int = 0
    noise: float = 0.1
    mask_prob: float = 0.25  # "random crop" analogue
    jitter: float = 0.2  # "color jitter" analogue


def _render(latents: np.ndarray, w: np.ndarray) -> np.ndarray:
    return np.tanh(latents @ w)


def ssl_batch(cfg: SSLDataConfig, step: int) -> Tuple[np.ndarray, np.ndarray]:
    """Returns two augmented views (B, input_dim) of the same latents."""
    rng = np.random.default_rng(np.uint64(cfg.seed * 7_000_003 + step))
    w_rng = np.random.default_rng(np.uint64(cfg.seed + 12345))  # fixed decoder
    w = w_rng.normal(size=(cfg.latent_dim, cfg.input_dim)).astype(np.float32)
    w /= np.sqrt(cfg.latent_dim)
    latents = rng.normal(size=(cfg.batch, cfg.latent_dim)).astype(np.float32)
    base = _render(latents, w)

    views = []
    for _ in range(2):
        v = base.copy()
        # channel jitter (scale + shift)
        scale = 1.0 + cfg.jitter * rng.uniform(-1, 1, size=(cfg.batch, 1)).astype(np.float32)
        shift = cfg.jitter * rng.uniform(-1, 1, size=(cfg.batch, 1)).astype(np.float32)
        v = v * scale + shift
        # random coordinate mask ("crop")
        mask = rng.random(size=v.shape) > cfg.mask_prob
        v = v * mask.astype(np.float32)
        # pixel noise
        v = v + cfg.noise * rng.normal(size=v.shape).astype(np.float32)
        views.append(v)
    return views[0], views[1]


def ssl_iterator(cfg: SSLDataConfig, start_step: int = 0):
    step = start_step
    while True:
        yield ssl_batch(cfg, step)
        step += 1
