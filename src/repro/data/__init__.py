from repro.data.synthetic import LMDataConfig, SSLDataConfig, lm_batch, ssl_batch, lm_iterator, ssl_iterator
from repro.data.pipeline import ShardedPrefetcher
