"""Elastic re-mesh: restore a checkpoint onto a *different* device count /
mesh shape.

Checkpoints store dtype/shape-preserving host buffers (checkpoint/), so the
mesh geometry is a restore-time decision: we rebuild the sharding pytree for
the new mesh from the same logical rules and ``jax.device_put`` each leaf.
Divisibility mismatches on the new mesh fall back to replication for that
leaf (GSPMD also tolerates uneven shards, but explicit fallback keeps the
behavior predictable).

At 1000-node scale the same logic runs per-host over addressable shards; the
logical-axis indirection (parallel/sharding.py) is what makes the checkpoint
mesh-geometry-free.
"""

from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.checkpoint.checkpointer import restore_checkpoint


def _divisible(shape, spec: PartitionSpec, mesh: Mesh) -> bool:
    for dim, part in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if part is None:
            continue
        axes = part if isinstance(part, tuple) else (part,)
        n = int(np.prod([mesh.shape[a] for a in axes]))
        if dim % n != 0:
            return False
    return True


def reshard_to_mesh(state: Any, mesh: Mesh, spec_fn: Callable[[tuple, Any], PartitionSpec]):
    """Place every leaf of ``state`` on ``mesh`` using ``spec_fn(path, leaf)``."""

    def place(path, leaf):
        arr = np.asarray(jax.device_get(leaf))
        spec = spec_fn(path, arr)
        if spec is None or not _divisible(arr.shape, spec, mesh):
            spec = PartitionSpec()
        return jax.device_put(arr, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map_with_path(place, state)


def elastic_restore(
    ckpt_dir: str,
    step: int,
    template: Any,
    new_mesh: Mesh,
    spec_fn: Optional[Callable] = None,
):
    """Restore a checkpoint written under any previous mesh onto new_mesh."""
    host_state = restore_checkpoint(ckpt_dir, step, template)
    if spec_fn is None:
        spec_fn = lambda path, leaf: PartitionSpec()
    return reshard_to_mesh(host_state, new_mesh, spec_fn)
