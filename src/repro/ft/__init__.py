from repro.ft.watchdog import (
    HeartbeatMonitor,
    PreemptionSignal,
    StragglerWatchdog,
    with_retries,
)
from repro.ft.elastic import reshard_to_mesh, elastic_restore
