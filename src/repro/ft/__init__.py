from repro.ft.watchdog import StragglerWatchdog, PreemptionSignal, with_retries
from repro.ft.elastic import reshard_to_mesh, elastic_restore
