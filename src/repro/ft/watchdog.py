"""Fault-tolerance runtime: straggler detection, preemption handling,
transient-failure retry.

On real pods the heartbeat store is a distributed KV (or jax coordination
service); here it is process-local but the policy logic — rolling-median
step-time outlier detection, preemption-flag draining, bounded retry with
backoff — is exactly what the loop would run at scale.
"""

from __future__ import annotations

import os
import time
from collections import deque
from typing import Callable, Dict, Optional


class StragglerWatchdog:
    """Flags steps slower than ``factor`` x rolling median (straggler
    mitigation hook: at scale the action is to re-shard around the slow
    host / trigger elastic re-mesh; here we count and expose the signal)."""

    def __init__(self, window: int = 32, factor: float = 3.0, min_samples: int = 8):
        self.durations: deque = deque(maxlen=window)
        self.factor = factor
        self.min_samples = min_samples
        self.straggler_events = 0
        self._t0: Optional[float] = None

    def step_start(self):
        self._t0 = time.perf_counter()

    def step_end(self) -> bool:
        """Returns True if this step was a straggler."""
        dt = time.perf_counter() - self._t0
        is_straggler = False
        if len(self.durations) >= self.min_samples:
            med = sorted(self.durations)[len(self.durations) // 2]
            if dt > self.factor * med:
                self.straggler_events += 1
                is_straggler = True
        self.durations.append(dt)
        return is_straggler

    @property
    def median(self) -> float:
        if not self.durations:
            return 0.0
        return sorted(self.durations)[len(self.durations) // 2]


class HeartbeatMonitor:
    """Liveness tracking for long-running components (serve dispatch loop,
    train loop, checkpoint writer).  Components ``register`` with a timeout
    and ``beat`` on every unit of progress; ``stale()`` reports the ones
    whose last beat is older than their timeout.  Transitions fresh->stale
    are counted once each (``missed_events``), so a flapping component shows
    up as many events rather than one long one.

    ``clock`` is injectable (monotonic seconds) so tests — and deterministic
    replay of an incident — never sleep.
    """

    def __init__(self, default_timeout_s: float = 10.0, clock: Callable[[], float] = time.monotonic):
        self.default_timeout_s = default_timeout_s
        self._clock = clock
        self._last: Dict[str, float] = {}
        self._timeout: Dict[str, float] = {}
        self._was_stale: Dict[str, bool] = {}
        self.missed_events = 0

    def register(self, name: str, timeout_s: Optional[float] = None):
        self._timeout[name] = self.default_timeout_s if timeout_s is None else float(timeout_s)
        self._last[name] = self._clock()
        self._was_stale[name] = False

    def beat(self, name: str):
        if name not in self._last:
            self.register(name)
        self._last[name] = self._clock()
        self._was_stale[name] = False

    def stale(self) -> Dict[str, float]:
        """{name: seconds since last beat} for every overdue component.
        Fresh->stale transitions increment ``missed_events``."""
        now = self._clock()
        out: Dict[str, float] = {}
        for name, last in self._last.items():
            age = now - last
            if age > self._timeout[name]:
                out[name] = age
                if not self._was_stale[name]:
                    self._was_stale[name] = True
                    self.missed_events += 1
        return out

    def age(self, name: str) -> float:
        return self._clock() - self._last[name]

    def metrics(self, prefix: str = "heartbeat_") -> Dict[str, float]:
        """Flat gauge dict for scraping alongside the serve metrics.  Per-name
        age gauges use exposition-safe names (``heartbeat_age_s_serve_dispatch``)
        so alert rules can target them directly."""
        from repro.obs.registry import sanitize_name

        overdue = self.stale()
        out = {
            f"{prefix}components": float(len(self._last)),
            f"{prefix}stale": float(len(overdue)),
            f"{prefix}missed_events": float(self.missed_events),
        }
        for name in self._last:
            out[sanitize_name(f"{prefix}age_s_{name}")] = self.age(name)
        return out

    def publish_metrics(self, registry, prefix: str = "heartbeat_") -> set:
        """Registry view of the scrape surface: ONE labelled age gauge
        (``heartbeat_age_s{name="serve.dispatch"}``) instead of a metric
        family per component — N fabric replicas add N label children, not N
        families — plus the flat aggregate gauges.  Returns the legacy
        name-suffixed keys this publish *claims*: they stay in the
        ``metrics()`` dict view for existing callers, but the caller
        (``serve.collect_metrics``) must not ALSO publish them flat, or the
        family namespace would grow per component again."""
        from repro.obs.registry import sanitize_name

        m = self.metrics(prefix)
        gauge = registry.gauge(
            f"{prefix}age_s",
            "seconds since a component's last heartbeat",
            labelnames=("name",),
        )
        claimed = set()
        for name in self._last:
            gauge.labels(name=name).set(self.age(name))
            claimed.add(sanitize_name(f"{prefix}age_s_{name}"))
        registry.publish({k: v for k, v in m.items() if k not in claimed})
        return claimed


class PreemptionSignal:
    """File-flag preemption notice (SIGTERM handler writes it; tests touch
    it).  The train loop checks every step and exits through a final
    checkpoint when raised."""

    def __init__(self, flag_path: str):
        self.flag_path = flag_path

    def raised(self) -> bool:
        return os.path.exists(self.flag_path)

    def set(self):
        with open(self.flag_path, "w") as f:
            f.write("preempt")

    def clear(self):
        if os.path.exists(self.flag_path):
            os.remove(self.flag_path)


def with_retries(
    fn: Callable,
    max_retries: int = 3,
    backoff_s: float = 0.05,
    retryable=(RuntimeError,),
    on_retry: Optional[Callable[[int, BaseException], None]] = None,
):
    """Bounded-retry wrapper for transient device/step failures."""

    def wrapped(*args, **kwargs):
        attempt = 0
        while True:
            try:
                return fn(*args, **kwargs)
            except retryable as e:
                attempt += 1
                if attempt > max_retries:
                    raise
                if on_retry:
                    on_retry(attempt, e)
                time.sleep(backoff_s * (2 ** (attempt - 1)))

    return wrapped
