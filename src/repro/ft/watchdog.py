"""Fault-tolerance runtime: straggler detection, preemption handling,
transient-failure retry.

On real pods the heartbeat store is a distributed KV (or jax coordination
service); here it is process-local but the policy logic — rolling-median
step-time outlier detection, preemption-flag draining, bounded retry with
backoff — is exactly what the loop would run at scale.
"""

from __future__ import annotations

import os
import time
from collections import deque
from typing import Callable, Optional


class StragglerWatchdog:
    """Flags steps slower than ``factor`` x rolling median (straggler
    mitigation hook: at scale the action is to re-shard around the slow
    host / trigger elastic re-mesh; here we count and expose the signal)."""

    def __init__(self, window: int = 32, factor: float = 3.0, min_samples: int = 8):
        self.durations: deque = deque(maxlen=window)
        self.factor = factor
        self.min_samples = min_samples
        self.straggler_events = 0
        self._t0: Optional[float] = None

    def step_start(self):
        self._t0 = time.perf_counter()

    def step_end(self) -> bool:
        """Returns True if this step was a straggler."""
        dt = time.perf_counter() - self._t0
        is_straggler = False
        if len(self.durations) >= self.min_samples:
            med = sorted(self.durations)[len(self.durations) // 2]
            if dt > self.factor * med:
                self.straggler_events += 1
                is_straggler = True
        self.durations.append(dt)
        return is_straggler

    @property
    def median(self) -> float:
        if not self.durations:
            return 0.0
        return sorted(self.durations)[len(self.durations) // 2]


class PreemptionSignal:
    """File-flag preemption notice (SIGTERM handler writes it; tests touch
    it).  The train loop checks every step and exits through a final
    checkpoint when raised."""

    def __init__(self, flag_path: str):
        self.flag_path = flag_path

    def raised(self) -> bool:
        return os.path.exists(self.flag_path)

    def set(self):
        with open(self.flag_path, "w") as f:
            f.write("preempt")

    def clear(self):
        if os.path.exists(self.flag_path):
            os.remove(self.flag_path)


def with_retries(
    fn: Callable,
    max_retries: int = 3,
    backoff_s: float = 0.05,
    retryable=(RuntimeError,),
    on_retry: Optional[Callable[[int, BaseException], None]] = None,
):
    """Bounded-retry wrapper for transient device/step failures."""

    def wrapped(*args, **kwargs):
        attempt = 0
        while True:
            try:
                return fn(*args, **kwargs)
            except retryable as e:
                attempt += 1
                if attempt > max_retries:
                    raise
                if on_retry:
                    on_retry(attempt, e)
                time.sleep(backoff_s * (2 ** (attempt - 1)))

    return wrapped
