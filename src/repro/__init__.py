"""repro — FFT-decorrelation training/serving framework for TPU pods.

Reproduction + TPU-native extension of "Learning Decorrelated Representations
Efficiently Using Fast Fourier Transform" (Shigeto et al., 2023).
"""

__version__ = "1.0.0"
