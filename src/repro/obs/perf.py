"""Per-executable device-time attribution with a roofline join.

The serve and train stacks compile a handful of executables (per-bucket
embedding forwards, per-bucket prefills, the batched decode tick, the
chunked-prefill step, the probe update, the train step) and until now the
telemetry only gated the AGGREGATE — tok/s — so a regression in one
executable hid behind the others.  ``ExecTimer`` is the attribution layer:

  * **wall time** — a labelled ``exec_seconds{executable=...}`` histogram
    plus host-side calls/total/best stats per executable (the ``/perf``
    endpoint and the bench ``perf`` section read these);
  * **compile time** — ``exec_compile_seconds{executable=...}`` gauges set
    when an executable is AOT lowered+compiled at warmup;
  * **compile-cache traffic** — ``exec_cache_{hits,misses}_total`` counters
    from the engines' bucket caches;
  * **the roofline join** — ``attach_compiled``/``attach_jit`` parse the
    optimized HLO through ``repro.launch.hlo_cost`` (trip-exact FLOPs/bytes,
    the same analyzer the tune dry tier uses) and every snapshot derives
    achieved GFLOP/s, achieved GB/s, a roofline-utilization gauge
    ``min(1, analytic_bound_s / best_measured_s)`` and the analytic-vs-
    measured disagreement ratio ``best_measured_s / analytic_bound_s`` —
    directly feeding the ROADMAP debt "analytic tier favors large pages —
    validate against wall time".

Everything is lazy and failure-tolerant: the HLO analyzer import happens
only when something attaches (the analytic tier never pays it), a backend
without ``as_text()`` simply yields no join, and a disabled timer
(``Obs.disabled()``) costs one attribute read per hot-path check because the
engines hold ``perf = None`` instead of a disabled object.
"""

from __future__ import annotations

import math
import threading
import time
from typing import Any, Dict, List, Optional

from repro.obs.registry import DEFAULT_BUCKETS, MetricsRegistry

# executable steps on a warm pool run well under the latency ladder's 100us
# floor on real accelerators — extend the default buckets downward
EXEC_BUCKETS = (1e-5, 2.5e-5, 5e-5) + DEFAULT_BUCKETS


class _ExecStat:
    __slots__ = ("calls", "total_s", "best_s")

    def __init__(self):
        self.calls = 0
        self.total_s = 0.0
        self.best_s = math.inf


class ExecTimer:
    """Labelled wall-time attribution + analytic-cost join per executable."""

    def __init__(
        self,
        registry: Optional[MetricsRegistry] = None,
        *,
        enabled: bool = True,
        clock=time.perf_counter,
    ):
        self.enabled = bool(enabled)
        self.registry = registry if registry is not None else MetricsRegistry()
        self._clock = clock
        self._lock = threading.Lock()
        self._stats: Dict[str, _ExecStat] = {}
        self._analysis: Dict[str, Dict[str, Any]] = {}
        self._compile_s: Dict[str, float] = {}
        self.observed_total = 0
        r = self.registry
        self._h_exec = r.histogram(
            "exec_seconds", "per-executable wall time",
            labelnames=("executable",), buckets=EXEC_BUCKETS,
        )
        self._g_compile = r.gauge(
            "exec_compile_seconds", "AOT lower+compile wall time",
            labelnames=("executable",),
        )
        self._c_hits = r.counter(
            "exec_cache_hits_total", "compile-cache hits",
            labelnames=("executable",),
        )
        self._c_misses = r.counter(
            "exec_cache_misses_total", "compile-cache misses",
            labelnames=("executable",),
        )

    # -- hot path -------------------------------------------------------------
    # engines guard every call with `if self.perf is not None`, so a disabled
    # bundle never reaches these; the methods themselves still honor
    # `enabled` so a shared timer can be switched off without re-wiring.

    def start(self) -> float:
        return self._clock()

    def elapsed(self, t0: float) -> float:
        return self._clock() - t0

    def observe(self, name: str, seconds: float):
        """Fold one executable invocation's wall time into the stream."""
        if not self.enabled:
            return
        s = float(seconds)
        with self._lock:
            st = self._stats.get(name)
            if st is None:
                st = self._stats[name] = _ExecStat()
            st.calls += 1
            st.total_s += s
            if s < st.best_s:
                st.best_s = s
            self.observed_total += 1
        self._h_exec.labels(executable=name).observe(s)

    def cache_hit(self, name: str):
        if self.enabled:
            self._c_hits.labels(executable=name).inc()

    def cache_miss(self, name: str):
        if self.enabled:
            self._c_misses.labels(executable=name).inc()

    # -- the analytic join ----------------------------------------------------

    def record_compile(self, name: str, seconds: float):
        if not self.enabled:
            return
        with self._lock:
            self._compile_s[name] = float(seconds)
        self._g_compile.labels(executable=name).set(float(seconds))

    def attach_analysis(
        self,
        name: str,
        *,
        flops: float,
        hbm_bytes: float,
        collective_bytes: float = 0.0,
        bound_s: Optional[float] = None,
        dominant: Optional[str] = None,
        compile_s: Optional[float] = None,
    ):
        """Attach analytic costs directly (tests; callers with their own
        cost model).  ``bound_s`` defaults to the hlo_cost roofline bound."""
        if not self.enabled:
            return
        if bound_s is None:
            from repro.launch.hlo_cost import HBM_BW, ICI_BW, PEAK_FLOPS

            terms = {
                "compute": flops / PEAK_FLOPS,
                "memory": hbm_bytes / HBM_BW,
                "collective": collective_bytes / ICI_BW,
            }
            dominant = dominant or max(terms, key=terms.get)
            bound_s = max(terms.values())
        with self._lock:
            self._analysis[name] = {
                "flops": float(flops),
                "hbm_bytes": float(hbm_bytes),
                "collective_bytes": float(collective_bytes),
                "bound_s": float(bound_s),
                "dominant": dominant,
            }
        if compile_s is not None:
            self.record_compile(name, compile_s)

    def attach_compiled(self, name: str, compiled, compile_s: Optional[float] = None) -> bool:
        """Join one AOT-compiled executable: parse its optimized HLO for
        trip-exact FLOPs/bytes and store the roofline terms.  Idempotent per
        name; returns False (and attaches nothing) when the backend exposes
        no HLO text or the analyzer cannot parse it."""
        if not self.enabled:
            return False
        with self._lock:
            if name in self._analysis:
                return True
        try:
            hlo = compiled.as_text()
            from repro.launch.hlo_cost import analyze_hlo, roofline_terms

            a = analyze_hlo(hlo)
            terms = roofline_terms(a)
        except Exception:
            return False
        self.attach_analysis(
            name,
            flops=a.flops,
            hbm_bytes=a.hbm_bytes,
            collective_bytes=a.total_collective_bytes,
            bound_s=terms["bound_s"],
            dominant=terms["dominant"],
            compile_s=compile_s,
        )
        return True

    def attach_jit(self, name: str, fn, *args, **kw) -> bool:
        """AOT lower+compile a jitted callable purely for attribution (the
        caller keeps executing its own jit cache) and join the result.
        Records the lower+compile wall time as the compile gauge."""
        if not self.enabled:
            return False
        with self._lock:
            if name in self._analysis:
                return True
        t0 = self._clock()
        try:
            compiled = fn.lower(*args, **kw).compile()
        except Exception:
            return False
        return self.attach_compiled(name, compiled, compile_s=self._clock() - t0)

    @property
    def analyzed(self) -> int:
        with self._lock:
            return len(self._analysis)

    # -- read side ------------------------------------------------------------

    def snapshot(self, top_k: Optional[int] = None) -> List[Dict[str, Any]]:
        """Per-executable rows, slowest total first: measured stats joined
        with the analytic roofline (achieved GFLOP/s and GB/s from the BEST
        measured time — the least-noisy invocation; utilization clamped into
        (0, 1]; ``disagreement`` = measured/analytic, >= 1 by construction,
        the validate-against-wall-time ratio)."""
        with self._lock:
            stats = {n: (s.calls, s.total_s, s.best_s) for n, s in self._stats.items()}
            analysis = dict(self._analysis)
            compile_s = dict(self._compile_s)
        rows: List[Dict[str, Any]] = []
        for name, (calls, total_s, best_s) in stats.items():
            row: Dict[str, Any] = {
                "executable": name,
                "calls": calls,
                "total_s": total_s,
                "best_s": best_s,
                "mean_s": total_s / max(calls, 1),
            }
            if name in compile_s:
                row["compile_s"] = compile_s[name]
            a = analysis.get(name)
            if a is not None:
                best = max(best_s, 1e-9)
                bound = a["bound_s"]
                row.update(
                    flops=a["flops"],
                    hbm_bytes=a["hbm_bytes"],
                    bound_s=bound,
                    dominant=a["dominant"],
                    achieved_gflops=a["flops"] / best / 1e9,
                    achieved_gbps=a["hbm_bytes"] / best / 1e9,
                    roofline_utilization=min(1.0, bound / best) if bound > 0 else 0.0,
                    disagreement=(best / bound) if bound > 0 else None,
                )
            rows.append(row)
        rows.sort(key=lambda r: r["total_s"], reverse=True)
        return rows[:top_k] if top_k else rows

    def report(self, top_k: int = 10) -> Dict[str, Any]:
        """The ``/perf`` endpoint payload: top-k slowest executables with
        their utilization, plus the aggregate counts."""
        return {
            "executables": len(self._stats),
            "analyzed": self.analyzed,
            "observed_total": self.observed_total,
            "top": self.snapshot(top_k),
        }

    def publish(self, registry: Optional[MetricsRegistry] = None):
        """Mirror the derived roofline values as labelled gauges (scrape
        path: called by ``Obs.scrape`` each cycle, like quantile gauges)."""
        if not self.enabled:
            return
        r = registry if registry is not None else self.registry
        g_total = r.gauge("exec_wall_seconds_total", "summed executable wall time",
                          labelnames=("executable",))
        g_calls = r.gauge("exec_calls_total", "executable invocations",
                          labelnames=("executable",))
        g_util = r.gauge("exec_roofline_utilization",
                         "analytic roofline bound / best measured time, clamped to 1",
                         labelnames=("executable",))
        g_gflops = r.gauge("exec_achieved_gflops", "FLOPs / best measured second / 1e9",
                           labelnames=("executable",))
        g_gbps = r.gauge("exec_achieved_gbps", "HBM bytes / best measured second / 1e9",
                         labelnames=("executable",))
        g_dis = r.gauge("exec_analytic_disagreement",
                        "best measured time / analytic roofline bound",
                        labelnames=("executable",))
        for row in self.snapshot():
            lbl = {"executable": row["executable"]}
            g_total.labels(**lbl).set(row["total_s"])
            g_calls.labels(**lbl).set(float(row["calls"]))
            if "roofline_utilization" in row:
                g_util.labels(**lbl).set(row["roofline_utilization"])
                g_gflops.labels(**lbl).set(row["achieved_gflops"])
                g_gbps.labels(**lbl).set(row["achieved_gbps"])
                if row["disagreement"] is not None:
                    g_dis.labels(**lbl).set(row["disagreement"])

    def metrics(self, prefix: str = "perf_") -> Dict[str, float]:
        with self._lock:
            return {
                f"{prefix}executables": float(len(self._stats)),
                f"{prefix}analyzed": float(len(self._analysis)),
                f"{prefix}observed_total": float(self.observed_total),
            }
