"""Train-side decorrelation-health monitor.

The FFT relaxation (R_sum over circulant off-diagonal sums) is what makes
large-d training affordable, but the paper is explicit about its failure
mode: the relaxed objective admits undesirable minima — feature collapse
and shifted-identity cross-correlations — that the exact off-diagonal
penalty would reject.  Barlow Twins and VICReg frame their regularizers as
collapse defenses; a production train loop therefore needs the collapse
signals on the scrape path, not in a notebook.

``DecorrHealthMonitor`` wraps the serve-side streaming :class:`DecorrProbe`
for the train loop:

  * **relaxation gap** — ``|R_sum_norm - R_off_norm|`` (exact vs relaxed),
    the direct estimate of how far the FFT relaxation has drifted from the
    objective it stands in for.  Only emitted when the probe computes the
    exact term (small d or ``include_off=True``); when absent, the gap rules
    simply never trigger (absent metrics leave alert rules untouched).
  * **per-feature variance histogram** — the cross-section of the embedding
    stream, so a scrape can distinguish "all features dying" from "a few
    dead channels".
  * **EMA collapse indicators** — min/mean EMA feature variance and the
    fraction of features below a collapse floor.

The monitor is pull-based and cheap: call :meth:`update` from the train
loop's log-interval branch (not every step) with the current params and a
batch; it embeds, probes, and publishes ``train_decorr_*`` gauges that the
new :func:`repro.obs.alerts.default_train_rules` evaluate on scrape.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional

from repro.obs.registry import MetricsRegistry

# log-spaced buckets for per-feature variance: collapse shows up as mass
# piling below ~1e-4, healthy BN-normalized features sit near 1.0
VAR_BUCKETS = (1e-8, 1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 0.5, 1.0, 2.0, 10.0, 100.0)

COLLAPSE_FLOOR = 1e-4


class DecorrHealthMonitor:
    """Streaming decorrelation-health probe for the training loop.

    Parameters
    ----------
    embed_fn:
        ``embed_fn(params, batch) -> z`` mapping the train state's params and
        a batch to the (n, d) embedding matrix to probe.  Optional — callers
        that already hold embeddings can use :meth:`observe` directly.
    cfg, ema, sample_rows, include_off:
        forwarded to :class:`repro.serve.probes.DecorrProbe`.  ``ema=0.0``
        makes every indicator track the latest batch exactly (useful in
        tests); the default keeps a short memory so one noisy batch doesn't
        fire an alert on its own (window smoothing happens again in the
        alert rules).
    """

    def __init__(
        self,
        embed_fn: Optional[Callable[[Any, Any], Any]] = None,
        *,
        cfg=None,
        ema: float = 0.9,
        sample_rows: Optional[int] = None,
        include_off: Optional[bool] = None,
    ):
        # lazy import: repro.obs must stay importable without the serve stack
        from repro.serve.probes import DecorrProbe

        self.embed_fn = embed_fn
        kw: Dict[str, Any] = {"ema": ema}
        if sample_rows is not None:
            kw["sample_rows"] = sample_rows
        if include_off is not None:
            kw["include_off"] = include_off
        self.probe = DecorrProbe(cfg, **kw) if cfg is not None else DecorrProbe(**kw)
        self.updates = 0
        self._gap_ema: Optional[float] = None
        self._ema = float(ema)

    def observe(self, z, *, registry: Optional[MetricsRegistry] = None) -> Dict[str, float]:
        """Probe one embedding matrix and return (and optionally publish)
        the ``train_decorr_*`` health metrics."""
        import numpy as np

        self.probe.update(z)
        self.updates += 1
        m = self.probe.metrics(prefix="train_decorr_")

        r_sum = m.get("train_decorr_r_sum_norm")
        r_off = m.get("train_decorr_r_off_norm")
        if r_sum is not None and r_off is not None:
            gap = abs(float(r_sum) - float(r_off))
            m["train_decorr_relaxation_gap"] = gap
            prev = self._gap_ema
            self._gap_ema = gap if prev is None else self._ema * prev + (1.0 - self._ema) * gap
            m["train_decorr_relaxation_gap_ema"] = self._gap_ema

        feat_var = None
        moments = getattr(self.probe, "feature_moments", None)
        if callable(moments):
            try:
                _, feat_var = moments()
            except Exception:
                feat_var = None
        if feat_var is not None:
            v = np.asarray(feat_var, dtype=np.float64).ravel()
            if v.size:
                m["train_decorr_feat_var_min_ema"] = float(v.min())
                m["train_decorr_collapsed_frac"] = float((v < COLLAPSE_FLOOR).mean())

        m["train_decorr_updates"] = float(self.updates)

        if registry is not None:
            registry.publish(m)
            if feat_var is not None and v.size:
                h = registry.histogram(
                    "train_feat_var",
                    "per-feature EMA variance of the probed embedding stream",
                    buckets=VAR_BUCKETS,
                )
                for val in v:
                    h.observe(float(val))
        return m

    def update(
        self,
        state_or_params,
        batch,
        *,
        step: Optional[int] = None,
        registry: Optional[MetricsRegistry] = None,
    ) -> Dict[str, float]:
        """Embed a batch with the current params and probe the result.

        Accepts either a train state (anything with ``.params``) or bare
        params.  ``step`` is recorded as a gauge when given.
        """
        if self.embed_fn is None:
            raise ValueError("DecorrHealthMonitor needs embed_fn to use update(); "
                             "call observe(z) with precomputed embeddings instead")
        params = getattr(state_or_params, "params", state_or_params)
        z = self.embed_fn(params, batch)
        m = self.observe(z, registry=registry)
        if step is not None:
            m["train_decorr_step"] = float(step)
            if registry is not None:
                registry.publish({"train_decorr_step": float(step)})
        return m

    def metrics(self) -> Dict[str, float]:
        """Latest probe view without a new update (scrape-side read)."""
        m = self.probe.metrics(prefix="train_decorr_")
        if self._gap_ema is not None:
            m["train_decorr_relaxation_gap_ema"] = self._gap_ema
        m["train_decorr_updates"] = float(self.updates)
        return m
