"""Opt-in profiling hooks: ``jax.profiler`` trace capture behind a tiny
start/stop API.

Profiling is the one telemetry layer that is NOT always-on — a profiler
trace costs real overhead and disk, so capture is explicit: the service API
(``LMService.start_profiling``), the CLI (``--profile-dir``), or a direct
``Profiler`` call.  Everything degrades to a no-op when ``jax.profiler`` is
unavailable or the capture fails (CI containers without libtpu, double
starts) — profiling must never take the serving path down.

The cheap always-on counterpart — per-executable step-time histograms for
prefill / chunked-prefill / decode — lives in the metrics registry
(``serve_*_seconds``), fed by the service tick; this module only owns the
heavyweight trace capture.
"""

from __future__ import annotations

import logging
from typing import Dict, Optional

log = logging.getLogger("repro.obs.profiling")


class Profiler:
    """Start/stop ``jax.profiler`` traces into a directory."""

    def __init__(self, trace_dir: Optional[str] = None):
        self.trace_dir = trace_dir
        self.active = False
        self.sessions = 0
        self.errors = 0

    def start(self, trace_dir: Optional[str] = None) -> bool:
        """Begin a capture; returns False (and stays inert) when profiling
        cannot start — no directory configured, already active, or the
        backend refuses."""
        trace_dir = trace_dir or self.trace_dir
        if trace_dir is None or self.active:
            return False
        try:
            import jax.profiler

            jax.profiler.start_trace(trace_dir)
        except Exception as e:  # pragma: no cover - backend-dependent
            self.errors += 1
            log.warning("jax.profiler trace did not start: %s", e)
            return False
        self.trace_dir = trace_dir
        self.active = True
        return True

    def stop(self) -> Optional[str]:
        """End the capture; returns the trace directory, or None if no
        capture was running."""
        if not self.active:
            return None
        self.active = False
        try:
            import jax.profiler

            jax.profiler.stop_trace()
        except Exception as e:  # pragma: no cover - backend-dependent
            self.errors += 1
            log.warning("jax.profiler trace did not stop cleanly: %s", e)
            return None
        self.sessions += 1
        return self.trace_dir

    def metrics(self, prefix: str = "profiler_") -> Dict[str, float]:
        return {
            f"{prefix}active": 1.0 if self.active else 0.0,
            f"{prefix}sessions_total": float(self.sessions),
            f"{prefix}errors_total": float(self.errors),
        }
