"""The ``Obs`` bundle: one object carrying the whole telemetry stack.

Every service takes an optional ``obs``; the default is a fully-enabled
bundle (registry + tracer + flight recorder + alert manager + profiler), and
``Obs.disabled()`` is the telemetry-off configuration the overhead bench
compares against (event export, recording and step-time histograms all
skipped on the hot path; the registry still exists so ``metrics()`` keeps
its compatibility contract either way).

``scrape()`` is the exposition entry point the HTTP endpoint calls: refresh
the gauges (via the bound ``metrics_fn``), evaluate the alert rules on the
fresh values, publish alert state, auto-dump the flight recorder when a rule
fires (``dump_dir``), and render the registry as Prometheus text.
"""

from __future__ import annotations

import os
from typing import Any, Callable, Dict, List, Optional

from repro.obs.alerts import AlertManager
from repro.obs.http import MetricsServer
from repro.obs.perf import ExecTimer
from repro.obs.profiling import Profiler
from repro.obs.recorder import FlightRecorder
from repro.obs.registry import MetricsRegistry
from repro.obs.tracing import Tracer


class Obs:
    """Registry + tracer + flight recorder + alerts + profiler, one handle."""

    def __init__(
        self,
        *,
        enabled: bool = True,
        registry: Optional[MetricsRegistry] = None,
        tracer: Optional[Tracer] = None,
        recorder: Optional[FlightRecorder] = None,
        alerts: Optional[AlertManager] = None,
        profiler: Optional[Profiler] = None,
        perf: Optional[ExecTimer] = None,
        dump_dir: Optional[str] = None,
        recorder_capacity: int = 4096,
    ):
        self.enabled = bool(enabled)
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else Tracer(enabled=enabled)
        self.recorder = recorder if recorder is not None else FlightRecorder(
            capacity=recorder_capacity if enabled else 0
        )
        self.alerts = alerts if alerts is not None else AlertManager()
        self.profiler = profiler if profiler is not None else Profiler()
        self.perf = perf if perf is not None else ExecTimer(self.registry, enabled=enabled)
        self.dump_dir = dump_dir
        self._dumps = 0

    @classmethod
    def disabled(cls) -> "Obs":
        """Telemetry-off: no trace events, no flight recording, no step-time
        histogram observes.  The registry (and ``metrics()``) still work."""
        return cls(enabled=False)

    # -- scrape path -----------------------------------------------------------

    def check_alerts(self, metrics: Dict[str, float]) -> List[Dict[str, Any]]:
        """Evaluate the rules on one scrape dict; publish alert gauges; dump
        the flight recorder on every newly-fired alert (anomaly auto-dump)."""
        events = self.alerts.evaluate(metrics)
        self.alerts.publish(self.registry)
        if self.dump_dir:
            for ev in events:
                if ev["type"] != "fire" or not self.recorder.enabled:
                    continue
                os.makedirs(self.dump_dir, exist_ok=True)
                self._dumps += 1
                self.recorder.dump_json(os.path.join(
                    self.dump_dir, f"flightrec_{ev['alert']}_{self._dumps}.json"
                ))
        return events

    def scrape(self, metrics_fn: Optional[Callable[[], Dict[str, float]]] = None) -> str:
        """Refresh -> alert -> render.  ``metrics_fn`` is typically a
        service's ``metrics`` (which republishes its gauges as a side
        effect); without one, rules run over the registry's current view."""
        if metrics_fn is not None:
            m = dict(metrics_fn())
            self.registry.publish(m)  # idempotent for callers that publish
        else:
            m = self.registry.as_dict()
        # derive histogram-quantile gauges (serve_ttft_seconds_p99, ...) from
        # bucket counts BEFORE rule evaluation, so alert rules read the same
        # stream the service observes into — not a parallel percentile gauge
        derived = self.registry.quantile_gauges()
        if derived:
            self.registry.publish(derived)
            m.update(derived)
        # per-executable roofline gauges (exec_roofline_utilization{...}) are
        # derived views over the perf stats, refreshed like quantile gauges
        self.perf.publish(self.registry)
        m.update(self.perf.metrics())
        self.check_alerts(m)
        return self.registry.exposition()

    def start_server(
        self,
        port: int = 0,
        metrics_fn: Optional[Callable[[], Dict[str, float]]] = None,
        host: str = "127.0.0.1",
    ) -> MetricsServer:
        """Serve ``/metrics`` (exposition + alert evaluation), ``/alerts``,
        ``/perf`` (executable attribution), ``/flight`` (recent flight-
        recorder events) and ``/healthz`` on a daemon thread; returns the
        started server (read ``.port`` when asking for an ephemeral one)."""
        return MetricsServer(
            lambda: self.scrape(metrics_fn),
            alerts_fn=lambda: [
                {"alert": n, **vars_of(self.alerts.state(n))} for n in self.alerts.active()
            ],
            perf_fn=self.perf.report,
            flight_fn=self.recorder.dump,
            host=host,
            port=port,
        ).start()

    # -- the bundle's own gauges ----------------------------------------------

    def metrics(self) -> Dict[str, float]:
        out = {"obs_enabled": 1.0 if self.enabled else 0.0}
        out.update(self.tracer.metrics())
        out.update(self.recorder.metrics())
        out.update(self.alerts.metrics())
        out.update(self.profiler.metrics())
        out.update(self.perf.metrics())
        return out


def vars_of(state) -> Dict[str, Any]:
    """__slots__-safe vars() for alert rule state."""
    return {k: getattr(state, k) for k in state.__slots__}
