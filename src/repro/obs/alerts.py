"""Threshold-rule alerting over the scrape surface.

``AlertRule`` is config-shaped on purpose — metric name, comparator,
threshold, consecutive-breach window, severity — so a deployment's rules are
a JSON list, not code.  ``AlertManager.evaluate`` runs the rules against one
flat ``metrics()`` dict (the scrape path calls it on every scrape) and is
**edge-triggered**: an alert fires exactly once per threshold crossing (after
``window`` consecutive breaching evaluations) and emits a single ``clear``
event on recovery — a flapping metric shows up as many fire/clear pairs, a
steady breach as one.  Events go to an optional sink callback (and the
manager's own log); ``repro.obs.Obs`` wires the sink to the flight-recorder
auto-dump.

``default_serve_rules`` encodes the standing ROADMAP debt: decorrelation
probe drift (R_off/R_sum redundancy climbing), heartbeat staleness, TTFT
p99, and page-pool occupancy, targeting the uniform gauge names the services
now publish.
"""

from __future__ import annotations

import dataclasses
import json
import logging
import operator
import os
import time
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Union

log = logging.getLogger("repro.obs.alerts")

_OPS: Dict[str, Callable[[float, float], bool]] = {
    ">": operator.gt,
    ">=": operator.ge,
    "<": operator.lt,
    "<=": operator.le,
    "==": operator.eq,
    "!=": operator.ne,
}

SEVERITIES = ("info", "warning", "critical")


@dataclasses.dataclass(frozen=True)
class AlertRule:
    """One threshold rule: fire when ``metric op threshold`` holds for
    ``window`` consecutive evaluations."""

    name: str
    metric: str
    op: str
    threshold: float
    window: int = 1
    severity: str = "warning"

    def validate(self) -> "AlertRule":
        if self.op not in _OPS:
            raise ValueError(f"alert {self.name}: unknown comparator {self.op!r} "
                             f"(one of {sorted(_OPS)})")
        if self.window < 1:
            raise ValueError(f"alert {self.name}: window must be >= 1")
        if self.severity not in SEVERITIES:
            raise ValueError(f"alert {self.name}: severity {self.severity!r} "
                             f"not in {SEVERITIES}")
        return self

    def breached(self, value: float) -> bool:
        return _OPS[self.op](float(value), float(self.threshold))

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "AlertRule":
        return cls(
            name=str(d["name"]),
            metric=str(d["metric"]),
            op=str(d.get("op", ">")),
            threshold=float(d["threshold"]),
            window=int(d.get("window", 1)),
            severity=str(d.get("severity", "warning")),
        ).validate()


class _RuleState:
    __slots__ = ("breaches", "active", "fired", "cleared", "last_value")

    def __init__(self):
        self.breaches = 0
        self.active = False
        self.fired = 0
        self.cleared = 0
        self.last_value: Optional[float] = None


class AlertManager:
    """Edge-triggered evaluation of a rule set against scrape dicts."""

    def __init__(
        self,
        rules: Sequence[AlertRule] = (),
        *,
        sink: Optional[Callable[[Dict[str, Any]], None]] = None,
        clock=time.time,
    ):
        self.rules: List[AlertRule] = []
        self._state: Dict[str, _RuleState] = {}
        self.sink = sink
        self._clock = clock
        self.events_total = 0
        # monotone fire counts already mirrored into a registry Counter, so
        # publish() can inc by delta (counters reject going backwards)
        self._published_fired: Dict[str, int] = {}
        for r in rules:
            self.add_rule(r)

    @classmethod
    def from_config(
        cls, config: Union[str, Sequence[Mapping[str, Any]]], **kw
    ) -> "AlertManager":
        """Build from a list of rule dicts, a JSON string, or a JSON file
        path (``[{"name": ..., "metric": ..., "op": ">", "threshold": ...,
        "window": 1, "severity": "warning"}, ...]``)."""
        if isinstance(config, str):
            if os.path.exists(config):
                with open(config) as f:
                    config = json.load(f)
            else:
                config = json.loads(config)
        return cls([AlertRule.from_dict(d) for d in config], **kw)

    def add_rule(self, rule: AlertRule):
        rule.validate()
        if rule.name in self._state:
            raise ValueError(f"duplicate alert rule name {rule.name!r}")
        self.rules.append(rule)
        self._state[rule.name] = _RuleState()

    # -- evaluation ------------------------------------------------------------

    def evaluate(self, metrics: Mapping[str, float]) -> List[Dict[str, Any]]:
        """Run every rule against one scrape dict; returns the edge events
        (``type`` "fire" | "clear") this evaluation produced.  Metrics absent
        from the dict leave their rules untouched (no false clears while a
        component is not exporting)."""
        events: List[Dict[str, Any]] = []
        now = self._clock()
        for rule in self.rules:
            if rule.metric not in metrics:
                continue
            st = self._state[rule.name]
            v = float(metrics[rule.metric])
            st.last_value = v
            if rule.breached(v):
                st.breaches += 1
                if not st.active and st.breaches >= rule.window:
                    st.active = True
                    st.fired += 1
                    events.append(self._event("fire", rule, v, now))
            else:
                st.breaches = 0
                if st.active:
                    st.active = False
                    st.cleared += 1
                    events.append(self._event("clear", rule, v, now))
        for ev in events:
            lvl = logging.WARNING if ev["type"] == "fire" else logging.INFO
            log.log(lvl, "alert %(type)s: %(alert)s (%(metric)s=%(value)s %(op)s %(threshold)s)", ev)
            if self.sink is not None:
                self.sink(ev)
        self.events_total += len(events)
        return events

    def _event(self, typ: str, rule: AlertRule, value: float, now: float) -> Dict[str, Any]:
        return {
            "type": typ,
            "alert": rule.name,
            "metric": rule.metric,
            "op": rule.op,
            "threshold": rule.threshold,
            "value": value,
            "severity": rule.severity,
            "t": now,
        }

    # -- read side -------------------------------------------------------------

    def state(self, name: str) -> _RuleState:
        return self._state[name]

    def active(self) -> List[str]:
        return [r.name for r in self.rules if self._state[r.name].active]

    def metrics(self, prefix: str = "alerts_") -> Dict[str, float]:
        fired = sum(s.fired for s in self._state.values())
        cleared = sum(s.cleared for s in self._state.values())
        return {
            f"{prefix}rules": float(len(self.rules)),
            f"{prefix}active": float(len(self.active())),
            f"{prefix}fired_total": float(fired),
            f"{prefix}cleared_total": float(cleared),
        }

    def publish(self, registry):
        """Per-rule active/fired gauges (labelled) + the aggregate counters."""
        registry.publish(self.metrics())
        g_active = registry.gauge("alert_active", "1 while the rule is firing",
                                  labelnames=("alert",))
        g_fired = registry.gauge("alert_fired_total", "threshold crossings",
                                 labelnames=("alert",))
        # a true Counter (not a gauge): firing history survives edge-triggered
        # clears between scrapes even if the gauge view is reset or sampled
        # mid-flap — Prometheus rate() needs the monotone series
        c_fired = registry.counter("obs_alerts_fired_total",
                                   "cumulative alert firings", labelnames=("rule",))
        for rule in self.rules:
            st = self._state[rule.name]
            g_active.labels(alert=rule.name).set(1.0 if st.active else 0.0)
            g_fired.labels(alert=rule.name).set(float(st.fired))
            delta = st.fired - self._published_fired.get(rule.name, 0)
            if delta > 0:
                c_fired.labels(rule=rule.name).inc(float(delta))
                self._published_fired[rule.name] = st.fired
            elif rule.name not in self._published_fired:
                c_fired.labels(rule=rule.name).inc(0.0)
                self._published_fired[rule.name] = st.fired


def default_serve_rules() -> List[AlertRule]:
    """The ROADMAP's probe-triggered alerting debt, as config: decorr probe
    drift, heartbeat staleness, TTFT p99, and page-pool pressure."""
    return [
        AlertRule("probe_r_sum_drift", "decorr_r_sum_norm_ema", ">", 0.5,
                  window=3, severity="warning"),
        AlertRule("probe_r_off_drift", "decorr_r_off_norm_ema", ">", 0.5,
                  window=3, severity="warning"),
        AlertRule("probe_feature_variance_collapse", "decorr_feat_var_ema", "<", 1e-4,
                  window=3, severity="critical"),
        AlertRule("heartbeat_stale", "heartbeat_stale", ">", 0.0,
                  severity="critical"),
        # reads the scrape-time histogram-derived quantile gauge
        # (registry.quantile_gauges over serve_ttft_seconds buckets), not the
        # service's own rolling-window percentile — one TTFT stream of record
        AlertRule("ttft_p99_high", "serve_ttft_seconds_p99", ">", 5.0,
                  window=2, severity="warning"),
        AlertRule("page_pool_pressure", "paged_pages_utilization", ">", 0.95,
                  window=3, severity="warning"),
    ]


def default_train_rules() -> List[AlertRule]:
    """Decorrelation-health rules for the training loop, matched to the
    ``train_decorr_*`` gauges :class:`repro.obs.health.DecorrHealthMonitor`
    publishes.  The relaxation-gap rule watches the FFT relaxation drifting
    away from the exact off-diagonal objective (the paper's undesirable-
    minima failure mode); the variance rules watch for feature collapse
    (Barlow-Twins/VICReg's motivating pathology).  Gap rules only evaluate
    when the probe affords the exact R_off term — absent metrics leave
    their rules untouched."""
    return [
        AlertRule("train_relaxation_gap_blowup", "train_decorr_relaxation_gap_ema",
                  ">", 0.5, window=3, severity="warning"),
        AlertRule("train_variance_collapse", "train_decorr_feat_var_ema", "<", 1e-4,
                  window=3, severity="critical"),
        AlertRule("train_feature_mean_drift", "train_decorr_feat_mean_abs_ema",
                  ">", 1.0, window=3, severity="warning"),
    ]
