"""Scheduler flight recorder: a bounded ring buffer of per-tick events.

The continuous-batching scheduler makes dozens of micro-decisions per tick
(admit, defer, retire, page binds, compaction moves); when something goes
wrong — a stall, an OOM-shaped deferral pile-up, a probe drift alert — the
aggregate gauges say *that* it happened but not *what the scheduler was
doing*.  The recorder keeps the last N events (ring buffer, O(1) append,
drop-oldest) so the window leading up to an anomaly is always dumpable:
on demand (``dump`` / ``dump_json``) or automatically when an alert fires
(``repro.obs.Obs`` wires the alert sink to ``dump_json``).

``capacity=0`` disables recording entirely (the telemetry-off bench path);
``record`` is then a no-op costing one attribute read.
"""

from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional


class FlightRecorder:
    """Bounded ring buffer of ``(seq, t, kind, fields)`` events."""

    def __init__(self, capacity: int = 4096, clock=time.perf_counter):
        self.capacity = int(capacity)
        self.enabled = self.capacity > 0
        self._clock = clock
        self._ring: deque = deque(maxlen=max(self.capacity, 1))
        self._lock = threading.Lock()
        self.recorded_total = 0

    def record(self, kind: str, **fields):
        if not self.enabled:
            return
        with self._lock:
            self._ring.append((self.recorded_total, self._clock(), kind, fields))
            self.recorded_total += 1

    # -- read side ------------------------------------------------------------

    def __len__(self) -> int:
        return len(self._ring) if self.enabled else 0

    @property
    def dropped(self) -> int:
        return self.recorded_total - len(self)

    def events(self, kind: Optional[str] = None) -> List[Dict[str, Any]]:
        """Oldest-first event dicts, optionally filtered by kind."""
        with self._lock:
            rows = list(self._ring) if self.enabled else []
        return [
            {"seq": seq, "t": t, "kind": k, **fields}
            for seq, t, k, fields in rows
            if kind is None or k == kind
        ]

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for ev in self.events():
            out[ev["kind"]] = out.get(ev["kind"], 0) + 1
        return out

    def clear(self):
        with self._lock:
            self._ring.clear()

    # -- dumps -----------------------------------------------------------------

    def dump(self) -> Dict[str, Any]:
        return {
            "capacity": self.capacity,
            "recorded_total": self.recorded_total,
            "dropped": self.dropped,
            "events": self.events(),
        }

    def dump_json(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.dump(), f, default=float)
        return path

    def metrics(self, prefix: str = "flightrec_") -> Dict[str, float]:
        return {
            f"{prefix}events": float(len(self)),
            f"{prefix}recorded_total": float(self.recorded_total),
            f"{prefix}dropped": float(self.dropped),
        }
