"""Stdlib-HTTP scrape endpoint for the metrics registry.

One daemon thread, ``http.server`` only (no new dependencies):

  * ``GET /metrics``  -> Prometheus text exposition (the scrape callback is
    where services refresh their gauges AND where alert rules are evaluated
    — scrape-path alerting, so an unscrapped process alerts nobody falsely);
  * ``GET /alerts``   -> JSON of currently-active alerts;
  * ``GET /perf``     -> JSON of the top-k slowest executables with their
    roofline utilization (``ExecTimer.report``);
  * ``GET /flight``   -> JSON dump of the flight recorder's recent events
    (previously only reachable via alert-triggered auto-dump);
  * ``GET /healthz``  -> 200 "ok" liveness.

``port=0`` binds an ephemeral port (tests; the bound port is on
``server.port`` after ``start``).
"""

from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Optional

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


class MetricsServer:
    """Threaded scrape endpoint over a ``scrape_fn() -> exposition text``."""

    def __init__(
        self,
        scrape_fn: Callable[[], str],
        *,
        alerts_fn: Optional[Callable[[], list]] = None,
        perf_fn: Optional[Callable[[], dict]] = None,
        flight_fn: Optional[Callable[[], dict]] = None,
        host: str = "127.0.0.1",
        port: int = 0,
    ):
        self.scrape_fn = scrape_fn
        self.alerts_fn = alerts_fn
        self.perf_fn = perf_fn
        self.flight_fn = flight_fn
        self.host = host
        self.port = int(port)
        self._server: Optional[ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None
        self.scrapes_total = 0

    def start(self) -> "MetricsServer":
        if self._server is not None:
            raise RuntimeError("metrics server already started")
        outer = self

        class Handler(BaseHTTPRequestHandler):
            def log_message(self, *a):  # keep the serve logs clean
                pass

            def _send(self, code: int, body: bytes, ctype: str):
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def do_GET(self):
                path = self.path.split("?", 1)[0]
                try:
                    if path == "/metrics":
                        outer.scrapes_total += 1
                        self._send(200, outer.scrape_fn().encode(), CONTENT_TYPE)
                    elif path == "/alerts" and outer.alerts_fn is not None:
                        body = json.dumps(outer.alerts_fn(), default=float).encode()
                        self._send(200, body, "application/json")
                    elif path == "/perf" and outer.perf_fn is not None:
                        body = json.dumps(outer.perf_fn(), default=float).encode()
                        self._send(200, body, "application/json")
                    elif path == "/flight" and outer.flight_fn is not None:
                        body = json.dumps(outer.flight_fn(), default=float).encode()
                        self._send(200, body, "application/json")
                    elif path == "/healthz":
                        self._send(200, b"ok\n", "text/plain")
                    else:
                        self._send(404, b"not found\n", "text/plain")
                except Exception as e:  # scrape failure must not kill the server
                    self._send(500, f"scrape error: {e}\n".encode(), "text/plain")

        self._server = ThreadingHTTPServer((self.host, self.port), Handler)
        self._server.daemon_threads = True
        self.port = self._server.server_address[1]
        self._thread = threading.Thread(
            target=self._server.serve_forever, name="obs-metrics-http", daemon=True
        )
        self._thread.start()
        return self

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def stop(self, timeout: float = 5.0):
        if self._server is None:
            return
        self._server.shutdown()
        self._server.server_close()
        self._thread.join(timeout)
        self._server = self._thread = None
