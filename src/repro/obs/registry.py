"""Typed metrics registry: Counter / Gauge / Histogram with labels, and
Prometheus text exposition.

The registry is the one scrape surface every layer publishes into.  Design
points, all driven by how the serve/train loops use it:

  * **get-or-create is the API.**  ``registry.counter(name)`` returns the
    existing metric when the name is already registered (and raises on a
    *type* conflict), so hot loops can look metrics up by name without
    threading objects around.  ``registry.publish(flat_dict)`` turns a legacy
    ``metrics()`` gauge dict into registry gauges in one call — that is how
    the services stay scrape-compatible while the registry becomes the
    source of truth.
  * **Label cardinality is bounded.**  Every labelled metric caps its
    distinct label sets (``max_label_sets``); the cap raises instead of
    silently growing, because unbounded label cardinality is the classic way
    a metrics pipeline OOMs itself at production traffic.
  * **Names are sanitized, not rejected.**  Legacy keys (``heartbeat_age_s``
    per component, probe metrics) may carry dots/colons; ``sanitize_name``
    maps them onto the Prometheus grammar ``[a-zA-Z_][a-zA-Z0-9_]*`` so one
    naming scheme serves the flat dicts AND the exposition format.
  * **Everything is process-local and lock-guarded** — the dispatch thread
    beats while the scrape thread reads.
"""

from __future__ import annotations

import math
import re
import threading
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

_INVALID = re.compile(r"[^a-zA-Z0-9_]")

# latency-shaped default buckets (seconds): 100us .. 10s, roughly log-spaced
DEFAULT_BUCKETS = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)


def sanitize_name(name: str) -> str:
    """Map an arbitrary gauge key onto the Prometheus metric-name grammar."""
    out = _INVALID.sub("_", str(name))
    if not out or out[0].isdigit():
        out = "_" + out
    return out


def _escape_label(value: str) -> str:
    return str(value).replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _label_key(labelnames: Sequence[str], labels: Mapping[str, str]) -> Tuple[str, ...]:
    if set(labels) != set(labelnames):
        raise ValueError(f"expected labels {tuple(labelnames)}, got {tuple(labels)}")
    return tuple(str(labels[n]) for n in labelnames)


def format_labels(labelnames: Sequence[str], values: Sequence[str]) -> str:
    if not labelnames:
        return ""
    inner = ",".join(
        f'{n}="{_escape_label(v)}"' for n, v in zip(labelnames, values)
    )
    return "{" + inner + "}"


class Metric:
    """Base: one named metric family, children keyed by label values."""

    kind = "untyped"

    def __init__(
        self,
        name: str,
        help: str = "",
        labelnames: Sequence[str] = (),
        *,
        max_label_sets: int = 64,
        lock: Optional[threading.RLock] = None,
    ):
        self.name = sanitize_name(name)
        self.help = help
        self.labelnames = tuple(labelnames)
        for ln in self.labelnames:
            if sanitize_name(ln) != ln:
                raise ValueError(f"invalid label name {ln!r} on metric {self.name}")
        self.max_label_sets = int(max_label_sets)
        self._lock = lock or threading.RLock()
        self._children: Dict[Tuple[str, ...], object] = {}

    def _new_child(self):
        raise NotImplementedError

    def labels(self, **labels):
        """The child for one label set (cardinality-guarded get-or-create)."""
        key = _label_key(self.labelnames, labels)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                if len(self._children) >= self.max_label_sets:
                    raise ValueError(
                        f"metric {self.name}: label cardinality cap "
                        f"({self.max_label_sets}) exceeded; aggregate before export"
                    )
                child = self._new_child()
                self._children[key] = child
            return child

    def _default_child(self):
        if self.labelnames:
            raise ValueError(f"metric {self.name} is labelled; call .labels(...) first")
        with self._lock:
            if () not in self._children:
                self._children[()] = self._new_child()
            return self._children[()]

    def samples(self) -> List[Tuple[str, Tuple[str, ...], float]]:
        """Flat (suffix, label values, value) rows for exposition/as_dict."""
        with self._lock:
            out = []
            for key, child in sorted(self._children.items()):
                out.extend(child.samples(key))
            return out


class _Value:
    __slots__ = ("_v", "_lock")

    def __init__(self, lock):
        self._v = 0.0
        self._lock = lock

    @property
    def value(self) -> float:
        return self._v


class _CounterChild(_Value):
    def inc(self, amount: float = 1.0):
        if amount < 0:
            raise ValueError(f"counters are monotone; inc({amount}) is not allowed")
        with self._lock:
            self._v += float(amount)

    def samples(self, key):
        return [("", key, self._v)]


class _GaugeChild(_Value):
    def set(self, value: float):
        with self._lock:
            self._v = float(value)

    def inc(self, amount: float = 1.0):
        with self._lock:
            self._v += float(amount)

    def dec(self, amount: float = 1.0):
        self.inc(-amount)

    def samples(self, key):
        return [("", key, self._v)]


class _HistogramChild:
    __slots__ = ("_lock", "bounds", "counts", "sum", "count")

    def __init__(self, lock, bounds):
        self._lock = lock
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)  # last bucket is +Inf
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float):
        v = float(value)
        with self._lock:
            i = 0
            for i, b in enumerate(self.bounds):
                if v <= b:
                    break
            else:
                i = len(self.bounds)
            self.counts[i] += 1
            self.sum += v
            self.count += 1

    def bucket_counts(self) -> List[Tuple[float, int]]:
        """Cumulative (le, count) pairs, ending at +Inf."""
        with self._lock:
            acc, out = 0, []
            for b, c in zip(list(self.bounds) + [math.inf], self.counts):
                acc += c
                out.append((b, acc))
            return out

    def samples(self, key):
        rows = [
            ("_bucket", key + (("+Inf" if math.isinf(le) else repr(float(le))),), float(c))
            for le, c in self.bucket_counts()
        ]
        rows.append(("_sum", key, self.sum))
        rows.append(("_count", key, float(self.count)))
        return rows


def quantile_from_buckets(bounds: Sequence[float], counts: Sequence[int],
                          q: float) -> float:
    """``histogram_quantile``-style estimate from per-bucket counts.

    ``bounds``: finite ascending upper bounds; ``counts``: per-bucket (NOT
    cumulative) observation counts with the +Inf bucket last
    (``len(counts) == len(bounds) + 1``).  Linear interpolation inside the
    bucket holding the target rank (from 0 at the bucket's lower bound);
    ranks in the +Inf bucket clamp to the highest finite bound, matching
    Prometheus.  Returns 0.0 for an empty histogram."""
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile must be in [0, 1], got {q}")
    total = sum(counts)
    if total == 0:
        return 0.0
    rank = q * total
    acc, lo = 0.0, 0.0
    for b, c in zip(bounds, counts):
        if c > 0 and acc + c >= rank:
            return lo + (b - lo) * max(rank - acc, 0.0) / c
        acc += c
        lo = b
    return float(bounds[-1])


class Counter(Metric):
    kind = "counter"

    def _new_child(self):
        return _CounterChild(self._lock)

    def inc(self, amount: float = 1.0):
        self._default_child().inc(amount)

    @property
    def value(self) -> float:
        return self._default_child().value


class Gauge(Metric):
    kind = "gauge"

    def _new_child(self):
        return _GaugeChild(self._lock)

    def set(self, value: float):
        self._default_child().set(value)

    def inc(self, amount: float = 1.0):
        self._default_child().inc(amount)

    def dec(self, amount: float = 1.0):
        self._default_child().dec(amount)

    @property
    def value(self) -> float:
        return self._default_child().value


class Histogram(Metric):
    kind = "histogram"

    def __init__(self, name, help="", labelnames=(), *, buckets=DEFAULT_BUCKETS, **kw):
        bounds = tuple(sorted(float(b) for b in buckets))
        if not bounds:
            raise ValueError("histogram needs at least one bucket boundary")
        if any(b2 <= b1 for b1, b2 in zip(bounds, bounds[1:])):
            raise ValueError(f"bucket boundaries must be strictly increasing: {bounds}")
        self.buckets = bounds
        super().__init__(name, help, labelnames, **kw)

    def _new_child(self):
        return _HistogramChild(self._lock, self.buckets)

    def observe(self, value: float):
        self._default_child().observe(value)

    @property
    def count(self) -> int:
        return self._default_child().count

    @property
    def sum(self) -> float:
        return self._default_child().sum

    def quantile(self, q: float) -> float:
        """Bucket-estimated quantile of everything observed so far."""
        child = self._default_child()
        with child._lock:
            counts = list(child.counts)
        return quantile_from_buckets(self.buckets, counts, q)


class MetricsRegistry:
    """Process-local metric store + Prometheus text exposition."""

    _KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}

    def __init__(self, *, max_label_sets: int = 64):
        self._lock = threading.RLock()
        self._metrics: Dict[str, Metric] = {}
        self.max_label_sets = int(max_label_sets)

    # -- get-or-create --------------------------------------------------------

    def _get_or_create(self, cls, name: str, help: str, labelnames, **kw) -> Metric:
        name = sanitize_name(name)
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if not isinstance(m, cls):
                    raise ValueError(
                        f"metric {name} already registered as {m.kind}, "
                        f"not {cls.kind}"
                    )
                if tuple(labelnames) != m.labelnames:
                    raise ValueError(
                        f"metric {name} labelnames {m.labelnames} != {tuple(labelnames)}"
                    )
                return m
            m = cls(
                name, help, labelnames, max_label_sets=self.max_label_sets, **kw
            )
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "", labelnames=()) -> Counter:
        return self._get_or_create(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "", labelnames=()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labelnames)

    def histogram(
        self, name: str, help: str = "", labelnames=(), *, buckets=DEFAULT_BUCKETS
    ) -> Histogram:
        return self._get_or_create(Histogram, name, help, labelnames, buckets=buckets)

    # -- bulk publishing ------------------------------------------------------

    def publish(self, metrics: Mapping[str, float], help: str = ""):
        """Set one gauge per key of a flat ``metrics()`` dict (the legacy
        scrape shape) — keys are sanitized, values coerced to float."""
        for k, v in metrics.items():
            self.gauge(k, help).set(float(v))

    # -- read side ------------------------------------------------------------

    def metrics(self) -> Iterable[Metric]:
        with self._lock:
            return list(self._metrics.values())

    def get(self, name: str) -> Optional[Metric]:
        return self._metrics.get(sanitize_name(name))

    def value(self, name: str, labels: Optional[Mapping[str, str]] = None) -> Optional[float]:
        """Current value of a counter/gauge (None when unregistered)."""
        m = self.get(name)
        if m is None:
            return None
        child = m.labels(**labels) if labels else m._default_child()
        return child.value

    def quantile_gauges(self, quantiles: Sequence[float] = (0.5, 0.99)) -> Dict[str, float]:
        """Derived ``<hist>_p50``/``<hist>_p99``-style gauges from every
        UNLABELLED histogram's bucket counts (labelled children need
        cross-series aggregation — out of scope).  The scrape path publishes
        these each cycle so alert rules can target histogram quantiles
        directly: one observation stream, no parallel percentile state."""
        out: Dict[str, float] = {}
        for m in self.metrics():
            if not isinstance(m, Histogram) or m.labelnames:
                continue
            with m._lock:
                child = m._children.get(())
                counts = list(child.counts) if child is not None else None
            if counts is None:
                continue
            for q in quantiles:
                suffix = f"_p{round(q * 100):g}"
                out[f"{m.name}{suffix}"] = quantile_from_buckets(m.buckets, counts, q)
        return out

    def as_dict(self) -> Dict[str, float]:
        """Flat ``{exposition sample name: value}`` view of everything.
        Histograms contribute their ``_sum``/``_count`` (not the buckets)."""
        out: Dict[str, float] = {}
        for m in self.metrics():
            hist = isinstance(m, Histogram)
            for suffix, key, value in m.samples():
                if hist and suffix == "_bucket":
                    continue
                names = m.labelnames
                out[f"{m.name}{suffix}{format_labels(names, key[: len(names)])}"] = value
        return out

    def exposition(self) -> str:
        """Prometheus text format 0.0.4."""
        lines: List[str] = []
        for m in sorted(self.metrics(), key=lambda m: m.name):
            if m.help:
                lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            for suffix, key, value in m.samples():
                if suffix == "_bucket":
                    names = m.labelnames + ("le",)
                else:
                    names, key = m.labelnames, key[: len(m.labelnames)]
                lines.append(f"{m.name}{suffix}{format_labels(names, key)} {_fmt(value)}")
        return "\n".join(lines) + "\n"


def _fmt(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


_DEFAULT: Optional[MetricsRegistry] = None
_DEFAULT_LOCK = threading.Lock()


def default_registry() -> MetricsRegistry:
    """The process-global registry (one scrape surface per process)."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        if _DEFAULT is None:
            _DEFAULT = MetricsRegistry()
        return _DEFAULT
