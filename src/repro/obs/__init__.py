"""repro.obs — unified telemetry for the train + serve stack.

One subsystem, five layers (one module each):

  * ``registry``  — typed Counter/Gauge/Histogram primitives with labels, a
                    cardinality guard, Prometheus text exposition, and a
                    process-global default registry;
  * ``tracing``   — per-request lifecycle spans (submit -> queue -> admit ->
                    prefill -> decode ticks -> retire) + pool-level
                    executable spans, exportable as Chrome ``trace_event``
                    JSON (``reconstruct_request`` rebuilds one request's
                    story from a dump);
  * ``recorder``  — the scheduler flight recorder: a bounded ring buffer of
                    per-tick events (admit/defer/retire/page moves/
                    backpressure), dumpable on demand or on alert;
  * ``alerts``    — config-driven threshold rules over the scrape surface,
                    edge-triggered (fire once per crossing, clear on
                    recovery), wired to the decorr probe gauges, heartbeat
                    ages, TTFT and page-pool occupancy;
  * ``profiling`` — opt-in ``jax.profiler`` capture behind start/stop;
  * ``perf``      — per-executable wall-time attribution joined with the
                    analytic HLO roofline (achieved GFLOP/s and GB/s,
                    roofline-utilization and analytic-disagreement gauges,
                    compile-time gauges, compile-cache hit/miss counters);
  * ``health``    — the train-side decorrelation-health monitor (exact-vs-
                    relaxed gap, per-feature variance histograms, EMA
                    collapse indicators) feeding ``default_train_rules``;
  * ``http``      — the stdlib scrape endpoint (``/metrics`` evaluates the
                    alert rules on every scrape; ``/perf`` and ``/flight``
                    expose executable attribution and the flight recorder).

``Obs`` bundles all of it; services accept ``obs=`` and default to a fully
enabled bundle (``Obs.disabled()`` is the telemetry-off bench baseline).

    from repro.obs import Obs
    from repro.obs.alerts import AlertManager, default_serve_rules

    obs = Obs(alerts=AlertManager(default_serve_rules()))
    svc = LMService(engine, obs=obs)
    server = obs.start_server(port=9100, metrics_fn=svc.metrics)
    ...
    obs.tracer.write("trace.json")          # chrome://tracing
    obs.recorder.dump_json("flightrec.json")
"""

from repro.obs.alerts import (
    AlertManager,
    AlertRule,
    default_serve_rules,
    default_train_rules,
)
from repro.obs.context import Obs
from repro.obs.health import DecorrHealthMonitor
from repro.obs.http import MetricsServer
from repro.obs.perf import ExecTimer
from repro.obs.profiling import Profiler
from repro.obs.recorder import FlightRecorder
from repro.obs.registry import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    default_registry,
    quantile_from_buckets,
    sanitize_name,
)
from repro.obs.tracing import RequestTrace, Tracer, reconstruct_request

__all__ = [
    "AlertManager",
    "AlertRule",
    "Counter",
    "DecorrHealthMonitor",
    "ExecTimer",
    "FlightRecorder",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "MetricsServer",
    "Obs",
    "Profiler",
    "RequestTrace",
    "Tracer",
    "default_registry",
    "default_serve_rules",
    "default_train_rules",
    "quantile_from_buckets",
    "reconstruct_request",
    "sanitize_name",
]
