"""Request tracing: span objects threaded through the serving lifecycle,
exportable as Chrome ``trace_event`` JSON (chrome://tracing / Perfetto).

Two layers:

  * ``RequestTrace`` — per-request lifecycle marks (submit -> admit ->
    first token -> done, plus decode-tick counting).  It is ALWAYS created,
    even with tracing disabled, because it is the one timing source the
    service, the load generator and the bench all read (TTFT/latency come
    from these marks, not from private ``time.perf_counter()`` bookkeeping
    scattered per caller).  The marks are four floats — cheap enough to keep
    on every request at full traffic.
  * ``Tracer`` — the bounded event buffer behind it.  When enabled, each
    completed ``RequestTrace`` folds into Chrome complete ("X") spans —
    ``queue`` (submit->admit, with queue-depth attributes), ``prefill``
    (admit->first token), ``decode`` (first token->done, with the tick
    count) — on the request's own track (tid = request id), plus whatever
    pool-level executable spans (``decode_step``, ``prefill``,
    ``prefill_chunk``, ``dispatch``) the service adds.  ``write()`` dumps
    the standard ``{"traceEvents": [...]}`` JSON; ``reconstruct_request``
    rebuilds one request's lifecycle from a dump (the acceptance check: a
    single slow request must be explainable post-hoc).

Timestamps are ``time.perf_counter()`` microseconds relative to the
tracer's construction (Chrome wants monotonic us).
"""

from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional


class RequestTrace:
    """Lifecycle marks for one request (LM or embedding)."""

    __slots__ = ("rid", "kind", "t_submit", "t_admit", "t_first", "t_done",
                 "ticks", "status", "args", "_tracer")

    def __init__(self, rid: int, kind: str, tracer: Optional["Tracer"], **args):
        self.rid = rid
        self.kind = kind
        self.t_submit = time.perf_counter()
        self.t_admit: Optional[float] = None
        self.t_first: Optional[float] = None
        self.t_done: Optional[float] = None
        self.ticks = 0
        self.status = "ok"
        self.args = args
        self._tracer = tracer

    # -- lifecycle marks -----------------------------------------------------

    def mark_admit(self, **args):
        self.t_admit = time.perf_counter()
        self.args.update(args)

    def mark_first(self):
        self.t_first = time.perf_counter()

    def tick(self):
        self.ticks += 1

    def mark_done(self, status: str = "ok"):
        self.t_done = time.perf_counter()
        self.status = status
        tr = self._tracer
        if tr is not None and tr.enabled:
            tr._emit_request(self)

    # -- derived timings (the one instrumentation path) ----------------------

    @property
    def latency_s(self) -> Optional[float]:
        return None if self.t_done is None else self.t_done - self.t_submit

    @property
    def ttft_s(self) -> Optional[float]:
        return None if self.t_first is None else self.t_first - self.t_submit

    @property
    def queue_s(self) -> Optional[float]:
        return None if self.t_admit is None else self.t_admit - self.t_submit


class Tracer:
    """Bounded trace-event buffer with Chrome JSON export."""

    def __init__(self, *, enabled: bool = True, capacity: int = 65536):
        self.enabled = bool(enabled)
        self.capacity = int(capacity)
        self._events: deque = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._rid = 0
        self._t0 = time.perf_counter()
        self.requests_total = 0
        self.events_total = 0

    # -- low-level events ----------------------------------------------------

    def _us(self, t: float) -> float:
        return (t - self._t0) * 1e6

    def _push(self, ev: Dict[str, Any]):
        with self._lock:
            self.events_total += 1
            self._events.append(ev)

    def add_span(self, name: str, t0: float, t1: float, *, cat: str = "serve",
                 tid: int = 0, **args):
        """One Chrome complete ("X") span from perf_counter endpoints."""
        if not self.enabled:
            return
        self._push({
            "name": name, "cat": cat, "ph": "X", "pid": 0, "tid": tid,
            "ts": self._us(t0), "dur": max(self._us(t1) - self._us(t0), 0.0),
            "args": args,
        })

    def instant(self, name: str, *, cat: str = "serve", tid: int = 0, **args):
        if not self.enabled:
            return
        self._push({
            "name": name, "cat": cat, "ph": "i", "s": "t", "pid": 0, "tid": tid,
            "ts": self._us(time.perf_counter()), "args": args,
        })

    def span(self, name: str, *, cat: str = "serve", tid: int = 0, **args):
        """Context manager sugar over ``add_span``."""
        return _SpanCtx(self, name, cat, tid, args)

    # -- request lifecycle ---------------------------------------------------

    def start_request(self, kind: str = "lm", **args) -> RequestTrace:
        """Always returns a ``RequestTrace`` (marks are the timing source of
        record even when event export is off)."""
        with self._lock:
            rid = self._rid
            self._rid += 1
            self.requests_total += 1
        return RequestTrace(rid, kind, self, **args)

    def _emit_request(self, rt: RequestTrace):
        base = dict(rt.args, request_id=rt.rid, kind=rt.kind, status=rt.status)
        t_admit = rt.t_admit if rt.t_admit is not None else rt.t_done
        self.add_span("queue", rt.t_submit, t_admit, tid=rt.rid, **base)
        if rt.t_first is not None and rt.t_admit is not None:
            self.add_span("prefill", rt.t_admit, rt.t_first, tid=rt.rid, **base)
        if rt.t_first is not None and rt.t_done is not None and rt.kind == "lm":
            self.add_span("decode", rt.t_first, rt.t_done, tid=rt.rid,
                          ticks=rt.ticks, **base)
        if rt.t_admit is not None and rt.t_done is not None and rt.kind != "lm":
            self.add_span("dispatch", rt.t_admit, rt.t_done, tid=rt.rid, **base)
        self.instant("retire", tid=rt.rid, request_id=rt.rid, status=rt.status,
                     ticks=rt.ticks)

    # -- export --------------------------------------------------------------

    def __len__(self) -> int:
        return len(self._events)

    @property
    def dropped_events(self) -> int:
        return self.events_total - len(self._events)

    def to_chrome(self) -> Dict[str, Any]:
        with self._lock:
            events = list(self._events)
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, default=float)
        return path

    def metrics(self, prefix: str = "trace_") -> Dict[str, float]:
        return {
            f"{prefix}events": float(len(self._events)),
            f"{prefix}events_total": float(self.events_total),
            f"{prefix}events_dropped": float(self.dropped_events),
            f"{prefix}requests_total": float(self.requests_total),
        }


class _SpanCtx:
    __slots__ = ("_tracer", "_name", "_cat", "_tid", "_args", "_t0")

    def __init__(self, tracer, name, cat, tid, args):
        self._tracer, self._name, self._cat, self._tid, self._args = (
            tracer, name, cat, tid, args
        )

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._tracer.add_span(
            self._name, self._t0, time.perf_counter(),
            cat=self._cat, tid=self._tid, **self._args,
        )
        return False


def reconstruct_request(trace: Dict[str, Any], request_id: int) -> Dict[str, Any]:
    """Rebuild one request's lifecycle from a Chrome trace dump.

    Returns ``{"phases": [span names in time order], "ticks": n,
    "span_s": {name: duration seconds}, "status": ...}`` — the post-hoc
    answer to "why was request X slow".  Raises ``KeyError`` when the
    request never appears in the dump.
    """
    spans = [
        ev for ev in trace["traceEvents"]
        if ev.get("ph") == "X" and ev.get("args", {}).get("request_id") == request_id
    ]
    if not spans:
        raise KeyError(f"request {request_id} not present in trace")
    spans.sort(key=lambda ev: ev["ts"])
    ticks = max((ev["args"].get("ticks", 0) for ev in spans), default=0)
    retired = any(
        ev.get("ph") == "i" and ev.get("name") == "retire"
        and ev.get("args", {}).get("request_id") == request_id
        for ev in trace["traceEvents"]
    )
    return {
        "phases": [ev["name"] for ev in spans],
        "ticks": int(ticks),
        "span_s": {ev["name"]: ev["dur"] / 1e6 for ev in spans},
        "status": spans[-1]["args"].get("status", "ok"),
        "retired": retired,
    }
