"""repro.serve — the batched embedding-serving subsystem.

Layers (one module each):

  * ``buckets``  — shape buckets + admission policy (``BucketPolicy``), padded
                   to the Pallas tile boundaries ``repro.tune`` enumerates;
  * ``batcher``  — dynamic micro-batcher: bounded FIFO + futures +
                   max-latency/max-batch coalescing + backpressure;
  * ``engine``   — ``ServeEngine``: per-bucket jit cache over the SSL
                   encoder+projector, ``repro.checkpoint`` loading, optional
                   shard_map execution; ``LMServeEngine`` (whole-request) and
                   ``ContinuousLMEngine`` (slot-pool continuous batching) for
                   token models;
  * ``slots``    — decode-step-granular slot pool (``SlotPool`` /
                   ``LMRequest``): free-list admission, per-slot positions,
                   occupancy accounting for continuous batching;
  * ``paging``   — paged KV cache: ``PageAllocator`` (free-list of token
                   pages, OOM-safe reservations, copy-on-retire compaction)
                   + ``PagedKVManager`` (block tables, byte accounting) for
                   ``ContinuousLMEngine(paged=True)``;
  * ``sampling`` — per-request temperature/top-k decoding
                   (``SamplingParams``; temp 0 == bit-exact greedy);
  * ``probes``   — ``DecorrProbe``: streaming (EMA) feature moments + the
                   training-oracle-exact R_off/R_sum health metrics via
                   ``repro.decorr.probe_metrics``;
  * ``service``  — ``EmbeddingService`` / ``LMService``: dispatch loops
                   wiring batcher, engine, probe, latency stats and the
                   ``repro.ft`` heartbeat into one scrapeable object (the LM
                   loop ticks per decode step: admit, decode, retire); both
                   take an ``obs=`` bundle (``repro.obs``) for request
                   tracing, flight recording, alerting and the Prometheus
                   exposition — ``collect_metrics`` keeps the legacy
                   ``metrics()`` dict and the registry in lockstep;
  * ``loadgen``  — deterministic load generation + naive-vs-micro-batched
                   policy comparison (the bench/CLI core);
  * ``fabric``   — mesh-sharded serving fabric: ``ServeFabric`` routes over N
                   isolated ``Replica`` stacks (least-occupancy /
                   weighted-TTFT + prefix affinity) with heartbeat-driven
                   drain-and-requeue failover; ``FabricConfig(tp=M)`` gives
                   each replica a feature-sharded multi-device forward;
  * ``common``   — shared token-model helpers (prompt construction,
                   warmup-then-time generation);
  * ``cli``      — ``python -m repro.serve.cli`` (``--smoke`` in CI).

    from repro import serve
    engine = serve.ServeEngine.from_checkpoint(ckpt_dir, model_cfg)
    svc = serve.EmbeddingService(engine, probe=serve.DecorrProbe()).start()
    z = svc.submit(x).result()
    svc.metrics()   # latency/throughput/probe/heartbeat gauges
"""

from repro.serve.batcher import Backpressure, MicroBatcher, ServeFuture
from repro.serve.buckets import BucketPolicy, bucket_for, bucket_shapes, bucket_sizes
from repro.serve.engine import ContinuousLMEngine, LMServeEngine, ServeEngine
from repro.serve.fabric import (
    FabricConfig,
    Replica,
    Router,
    ServeFabric,
    make_replica_mesh,
)
from repro.serve.loadgen import (
    FabricLoadConfig,
    LMLoadConfig,
    LoadConfig,
    compare_fabric,
    compare_lm_policies,
    compare_paged_dense,
    compare_policies,
    run_microbatched,
    run_naive,
    tp_oracle_err,
)
from repro.serve.paging import PageAllocator, PagedKVManager
from repro.serve.probes import DecorrProbe
from repro.serve.sampling import SamplingParams
from repro.serve.service import EmbeddingService, LMService, collect_metrics
from repro.serve.slots import LMRequest, SlotPool

__all__ = [
    "Backpressure",
    "BucketPolicy",
    "ContinuousLMEngine",
    "DecorrProbe",
    "EmbeddingService",
    "FabricConfig",
    "FabricLoadConfig",
    "LMLoadConfig",
    "LMRequest",
    "LMServeEngine",
    "LMService",
    "LoadConfig",
    "MicroBatcher",
    "PageAllocator",
    "PagedKVManager",
    "Replica",
    "Router",
    "SamplingParams",
    "ServeEngine",
    "ServeFabric",
    "ServeFuture",
    "SlotPool",
    "bucket_for",
    "bucket_shapes",
    "bucket_sizes",
    "collect_metrics",
    "compare_fabric",
    "compare_lm_policies",
    "compare_paged_dense",
    "compare_policies",
    "make_replica_mesh",
    "run_microbatched",
    "run_naive",
    "tp_oracle_err",
]
