"""repro.serve — the batched embedding-serving subsystem.

Layers (one module each):

  * ``buckets``  — shape buckets + admission policy (``BucketPolicy``), padded
                   to the Pallas tile boundaries ``repro.tune`` enumerates;
  * ``batcher``  — dynamic micro-batcher: bounded FIFO + futures +
                   max-latency/max-batch coalescing + backpressure;
  * ``engine``   — ``ServeEngine``: per-bucket jit cache over the SSL
                   encoder+projector, ``repro.checkpoint`` loading, optional
                   shard_map execution; ``LMServeEngine`` for token models;
  * ``probes``   — ``DecorrProbe``: streaming (EMA) feature moments + the
                   training-oracle-exact R_off/R_sum health metrics via
                   ``repro.decorr.probe_metrics``;
  * ``service``  — ``EmbeddingService``: dispatch loop wiring batcher,
                   engine, probe, latency stats and the ``repro.ft``
                   heartbeat into one scrapeable object;
  * ``loadgen``  — deterministic load generation + naive-vs-micro-batched
                   policy comparison (the bench/CLI core);
  * ``common``   — shared token-model helpers (prompt construction,
                   warmup-then-time generation);
  * ``cli``      — ``python -m repro.serve.cli`` (``--smoke`` in CI).

    from repro import serve
    engine = serve.ServeEngine.from_checkpoint(ckpt_dir, model_cfg)
    svc = serve.EmbeddingService(engine, probe=serve.DecorrProbe()).start()
    z = svc.submit(x).result()
    svc.metrics()   # latency/throughput/probe/heartbeat gauges
"""

from repro.serve.batcher import Backpressure, MicroBatcher, ServeFuture
from repro.serve.buckets import BucketPolicy, bucket_for, bucket_shapes, bucket_sizes
from repro.serve.engine import LMServeEngine, ServeEngine
from repro.serve.loadgen import LoadConfig, compare_policies, run_microbatched, run_naive
from repro.serve.probes import DecorrProbe
from repro.serve.service import EmbeddingService

__all__ = [
    "Backpressure",
    "BucketPolicy",
    "DecorrProbe",
    "EmbeddingService",
    "LMServeEngine",
    "LoadConfig",
    "MicroBatcher",
    "ServeEngine",
    "ServeFuture",
    "bucket_for",
    "bucket_shapes",
    "bucket_sizes",
    "compare_policies",
    "run_microbatched",
    "run_naive",
]
