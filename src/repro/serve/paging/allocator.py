"""Host-side page allocator + block tables for the paged KV cache.

Pure bookkeeping (no jax): a free list of fixed-size token pages over one
physical pool, per-slot block tables (logical block j -> physical page id),
reservation-based admission accounting, and copy-on-retire compaction
planning.  The tensor half — the (repeats, P, page, kv, hd) device pools and
the gather/scatter decode — lives in ``repro.serve.paging.manager`` and
``repro.models.attention``.

Design points:

  * **Sentinel page 0.**  Physical page 0 is never allocated; unassigned
    block-table entries point at it.  Gathers through those entries read
    arbitrary bytes that the decode mask zeroes exactly (probability mass
    underflows to 0.0 at NEG_INF), so a partially-filled table is always
    safe to hand to the kernel.
  * **Reservation accounting (OOM-safe admission).**  ``reserve`` charges a
    request's worst case — ceil((prompt + max_new - 1) / page) pages — before
    its slot is admitted; physical pages are drawn lazily as tokens are
    written (``ensure``), but never beyond the reservation, so a mid-decode
    allocation can never fail.  When a reservation does not fit, admission
    is deferred (the service keeps the request queued) and ``submit`` raises
    ``Backpressure`` once the queue itself fills — requests shed, never OOM.
  * **Refcounted sharing (prefix cache).**  A physical page may be mapped by
    several block tables at once (shared prefix pages) and by the radix cache
    itself; ``retain``/``release_page`` count the owners and a page returns
    to the free list only at refcount 0.  Shared pages bound via
    ``bind_shared`` are NOT charged to the slot's reservation — only the
    unshared tail is — which is exactly why warm-prefix admission stops
    over-reserving.  ``pin_page`` marks pages an in-flight request depends on
    so eviction can never free them; the admission invariant becomes
    ``reserved_total + pinned_pages <= usable_pages`` (every unpinned
    cache-exclusive page is reclaimable on demand through ``evict_hook``,
    so lazy ``ensure`` stays infallible).
  * **Low-id pressure + compaction.**  The free list is a min-heap, so
    allocation always takes the lowest free id and the in-use *frontier*
    (highest id + 1) stays tight on its own; ``plan_compaction`` additionally
    relocates the highest in-use pages into lower free holes after a retire
    (copy-on-retire), handing back (src, dst) moves for the device-side copy
    and rewriting the block tables to match.  Shared or pinned pages are
    never relocated (the radix cache holds their physical ids).
"""

from __future__ import annotations

import heapq
from typing import Callable, Dict, List, Optional, Tuple

SENTINEL = 0


def pages_for(n_tokens: int, page: int) -> int:
    """Pages needed to hold ``n_tokens`` at ``page`` tokens per page."""
    return -(-max(int(n_tokens), 0) // page)


class PageAllocator:
    """Free-list allocator of fixed-size KV pages with per-slot block tables."""

    def __init__(self, total_pages: int, page: int, n_slots: int, blocks_per_slot: int):
        assert total_pages >= 2, "need at least the sentinel plus one usable page"
        assert page >= 1 and n_slots >= 1 and blocks_per_slot >= 1
        self.page = int(page)
        self.total_pages = int(total_pages)
        self.n_slots = int(n_slots)
        self.blocks_per_slot = int(blocks_per_slot)
        self._free: List[int] = list(range(1, total_pages))  # 0 is the sentinel
        heapq.heapify(self._free)
        self._tables: List[List[int]] = [[] for _ in range(n_slots)]
        self._reserved: List[int] = [0] * n_slots
        # leading entries of _tables[slot] that are shared (radix) pages,
        # refcounted rather than charged against the slot's reservation
        self._shared_count: List[int] = [0] * n_slots
        self._refcount: Dict[int, int] = {}  # phys -> owner count (allocated pages)
        self._pins: Dict[int, int] = {}  # phys -> pin count (in-flight dependents)
        # called with the number of pages needed when the free heap runs dry;
        # returns how many it actually freed (radix LRU eviction plugs in here)
        self.evict_hook: Optional[Callable[[int], int]] = None
        self.reserved_total = 0
        self.in_use = 0  # distinct allocated pages
        self.peak_pages = 0  # high-water mark of concurrently allocated pages
        self.alloc_total = 0
        self.compaction_moves = 0

    # -- capacity / admission accounting -------------------------------------

    @property
    def usable_pages(self) -> int:
        """Allocatable pages (total minus the sentinel page 0)."""
        return self.total_pages - 1  # minus the sentinel

    @property
    def pinned_pages(self) -> int:
        """Pages with at least one pin."""
        return len(self._pins)

    @property
    def shared_pages(self) -> int:
        """Pages mapped by two or more owners."""
        return sum(1 for c in self._refcount.values() if c >= 2)

    def free_pages(self) -> int:
        """Pages currently on the free list."""
        return len(self._free)

    def pages_for_tokens(self, n_tokens: int) -> int:
        """Pages needed for ``n_tokens`` at this pool's page size."""
        return pages_for(n_tokens, self.page)

    def refcount(self, phys: int) -> int:
        """Current owner count of a physical page."""
        return self._refcount.get(phys, 0)

    def pin_count(self, phys: int) -> int:
        """Current pin count of a physical page."""
        return self._pins.get(phys, 0)

    def can_reserve(self, n_tokens: int, *, shared_pages: int = 0,
                    new_pins: int = 0) -> bool:
        """Would a reservation for ``n_tokens`` rows fit right now, charging
        only the unshared tail and keeping ``reserved + pinned <= usable``?
        ``new_pins`` counts plan pages not currently pinned by anyone."""
        need = max(self.pages_for_tokens(n_tokens) - int(shared_pages), 0)
        return (self.reserved_total + need + self.pinned_pages + int(new_pins)
                <= self.usable_pages)

    def fits_ever(self, n_tokens: int) -> bool:
        """Could the request be served by an EMPTY pool (submit-time check)?"""
        need = self.pages_for_tokens(n_tokens)
        return need <= min(self.usable_pages, self.blocks_per_slot)

    def reserve(self, slot: int, n_tokens: int, *, shared_pages: int = 0) -> int:
        """Charge the slot's worst-case UNSHARED page need against the pool;
        the caller must have checked ``can_reserve`` (admission is deferred
        otherwise).  ``shared_pages`` prefix pages are refcount-owned via
        ``bind_shared`` instead."""
        need = max(self.pages_for_tokens(n_tokens) - int(shared_pages), 0)
        if self.reserved_total + need + self.pinned_pages > self.usable_pages:
            raise RuntimeError(
                f"page reservation overflow: {need} pages requested, "
                f"{self.usable_pages - self.reserved_total - self.pinned_pages} unreserved"
            )
        assert self._reserved[slot] == 0 and not self._tables[slot], slot
        self._reserved[slot] = need
        self.reserved_total += need
        return need

    # -- refcounts / pins ------------------------------------------------------

    def retain(self, phys: int):
        """Add an owner to an already-allocated page."""
        if phys == SENTINEL or self._refcount.get(phys, 0) < 1:
            raise RuntimeError(f"retain of unallocated page {phys}")
        self._refcount[phys] += 1

    def release_page(self, phys: int) -> bool:
        """Drop one owner; frees the page (returns True) at refcount 0.
        Releasing an unallocated page — a double free — raises."""
        count = self._refcount.get(phys, 0)
        if phys == SENTINEL or count < 1:
            raise RuntimeError(f"double free of page {phys}")
        if count == 1:
            del self._refcount[phys]
            heapq.heappush(self._free, phys)
            self.in_use -= 1
            return True
        self._refcount[phys] = count - 1
        return False

    def pin_page(self, phys: int):
        """Mark a page as depended on by an in-flight request: eviction must
        never free it (the admission check counted it)."""
        if self._refcount.get(phys, 0) < 1:
            raise RuntimeError(f"pin of unallocated page {phys}")
        self._pins[phys] = self._pins.get(phys, 0) + 1

    def unpin_page(self, phys: int):
        """Drop one pin from a page (raises if it is not pinned)."""
        count = self._pins.get(phys, 0)
        if count < 1:
            raise RuntimeError(f"unpin of unpinned page {phys}")
        if count == 1:
            del self._pins[phys]
        else:
            self._pins[phys] = count - 1

    # -- allocation -----------------------------------------------------------

    def table(self, slot: int) -> List[int]:
        """Copy of a slot's block table (physical page per block)."""
        return list(self._tables[slot])

    def shared_count(self, slot: int) -> int:
        """How many of a slot's mapped pages are shared."""
        return self._shared_count[slot]

    def _alloc_page(self) -> int:
        """Pop the lowest free page, evicting unpinned cache pages on demand.
        Never fails under the ``reserved + pinned <= usable`` invariant."""
        if not self._free and self.evict_hook is not None:
            self.evict_hook(1)
        if not self._free:
            raise RuntimeError("page pool exhausted despite reservation accounting")
        phys = heapq.heappop(self._free)
        self._refcount[phys] = 1
        self.in_use += 1
        self.alloc_total += 1
        self.peak_pages = max(self.peak_pages, self.in_use)
        return phys

    def bind_shared(self, slot: int, pages: List[int]):
        """Map already-cached prefix pages into the slot's table (read-only
        sharing): retained, not charged to the reservation.  Must run before
        any ``ensure``/``cow_bind`` growth."""
        tbl = self._tables[slot]
        assert not tbl, f"slot {slot} table must be empty before bind_shared"
        for phys in pages:
            self.retain(phys)
            tbl.append(phys)
        self._shared_count[slot] = len(tbl)

    def cow_bind(self, slot: int, src: int) -> int:
        """Allocate a fresh page for a copy-on-write of shared page ``src``
        and append it to the slot's table (charged to the reservation).  The
        device copy itself is the caller's batched gather/scatter."""
        tbl = self._tables[slot]
        if len(tbl) + 1 - self._shared_count[slot] > self._reserved[slot]:
            raise RuntimeError(
                f"slot {slot} COW exceeds reservation {self._reserved[slot]}"
            )
        dst = self._alloc_page()
        tbl.append(dst)
        return dst

    def ensure(self, slot: int, n_tokens: int) -> List[Tuple[int, int]]:
        """Grow slot's table to cover ``n_tokens`` written rows.  Returns the
        newly bound (logical_block, physical_page) pairs.  Never exceeds the
        slot's reservation (shared prefix blocks are not counted against it),
        so the allocation cannot fail."""
        tbl = self._tables[slot]
        need = self.pages_for_tokens(n_tokens)
        if need - self._shared_count[slot] > self._reserved[slot]:
            raise RuntimeError(
                f"slot {slot} needs {need - self._shared_count[slot]} pages "
                f"> reservation {self._reserved[slot]}"
            )
        added = []
        while len(tbl) < need:
            phys = self._alloc_page()
            added.append((len(tbl), phys))
            tbl.append(phys)
        return added

    def alloc_pinned(self, n: int) -> List[int]:
        """Allocate ``n`` pages OUTSIDE any slot table and pin them — the
        speculative scratch pool.  Pinning charges them against the
        ``reserved + pinned <= usable`` admission invariant permanently, so
        speculation can never OOM an admitted slot: every scratch page was
        subtracted from admission capacity up front."""
        if self.reserved_total + self.pinned_pages + int(n) > self.usable_pages:
            raise RuntimeError(
                f"cannot pin {n} scratch pages: only "
                f"{self.usable_pages - self.reserved_total - self.pinned_pages} "
                "unreserved pages available"
            )
        pages = []
        for _ in range(int(n)):
            phys = self._alloc_page()
            self.pin_page(phys)
            pages.append(phys)
        return pages

    def swap_page(self, slot: int, block: int, new_phys: int) -> int:
        """Swap pinned out-of-table page ``new_phys`` into the slot's table
        at ``block``, returning the displaced page (which inherits the pin —
        the speculative commit: scratch becomes the slot's tail page, the old
        tail page becomes scratch).  Refcounts, the free list, and the total
        pin count are all unchanged, so every admission invariant survives.
        Only exclusive, unpinned table pages may be displaced."""
        tbl = self._tables[slot]
        old = tbl[block]
        if block < self._shared_count[slot]:
            raise RuntimeError(f"swap of shared block {block} in slot {slot}")
        if self._refcount.get(old, 0) != 1 or old in self._pins:
            raise RuntimeError(
                f"swap target page {old} is shared or pinned (slot {slot} block {block})"
            )
        if self._refcount.get(new_phys, 0) != 1 or new_phys not in self._pins:
            raise RuntimeError(f"swap source {new_phys} must be an exclusive pinned page")
        tbl[block] = new_phys
        self.unpin_page(new_phys)
        self.pin_page(old)
        return old

    def release(self, slot: int):
        """Drop the slot's ownership of its pages and return its reservation.
        Shared pages survive under their remaining owners (radix cache or
        other slots); exclusively-owned pages go back to the free list."""
        for phys in self._tables[slot]:
            self.release_page(phys)
        self._tables[slot] = []
        self._shared_count[slot] = 0
        self.reserved_total -= self._reserved[slot]
        self._reserved[slot] = 0

    # -- compaction -----------------------------------------------------------

    def frontier(self) -> int:
        """One past the highest allocated physical page id (the pool's live
        extent; what a shrinkable backing allocation would have to cover)."""
        top = SENTINEL
        for phys in self._refcount:
            top = max(top, phys)
        return top + 1

    def plan_compaction(self, max_moves: int) -> List[Tuple[int, int]]:
        """Relocate up to ``max_moves`` of the highest in-use pages into the
        lowest free holes below them.  Rewrites the block tables and the free
        list; returns the (src, dst) physical moves the device pools must
        apply (``manager.apply_moves``).  No-op when already compact.  Only
        exclusively-owned, unpinned pages move: the radix cache addresses
        shared pages by physical id, so they must stay put."""
        # position index: physical page -> (slot, logical block)
        where: Dict[int, Tuple[int, int]] = {}
        for s, tbl in enumerate(self._tables):
            for j, phys in enumerate(tbl):
                if self._refcount.get(phys, 0) == 1 and phys not in self._pins:
                    where[phys] = (s, j)
        moves: List[Tuple[int, int]] = []
        while len(moves) < max_moves and self._free and where:
            dst = self._free[0]
            src = max(where)
            if dst >= src:
                break  # every free hole is above every movable page: compact
            heapq.heappop(self._free)
            s, j = where.pop(src)
            self._tables[s][j] = dst
            where[dst] = (s, j)
            self._refcount[dst] = self._refcount.pop(src)
            heapq.heappush(self._free, src)
            moves.append((src, dst))
        self.compaction_moves += len(moves)
        return moves

    # -- scrape surface -------------------------------------------------------

    def metrics(self, prefix: str = "pages_") -> Dict[str, float]:
        """Flat gauge dict of pool occupancy/sharing counters."""
        return {
            f"{prefix}total": float(self.usable_pages),
            f"{prefix}in_use": float(self.in_use),
            f"{prefix}reserved": float(self.reserved_total),
            f"{prefix}peak": float(self.peak_pages),
            f"{prefix}frontier": float(self.frontier() - 1),
            f"{prefix}alloc_total": float(self.alloc_total),
            f"{prefix}compaction_moves": float(self.compaction_moves),
            f"{prefix}shared": float(self.shared_pages),
            f"{prefix}pinned": float(self.pinned_pages),
        }
