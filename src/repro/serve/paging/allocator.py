"""Host-side page allocator + block tables for the paged KV cache.

Pure bookkeeping (no jax): a free list of fixed-size token pages over one
physical pool, per-slot block tables (logical block j -> physical page id),
reservation-based admission accounting, and copy-on-retire compaction
planning.  The tensor half — the (repeats, P, page, kv, hd) device pools and
the gather/scatter decode — lives in ``repro.serve.paging.manager`` and
``repro.models.attention``.

Design points:

  * **Sentinel page 0.**  Physical page 0 is never allocated; unassigned
    block-table entries point at it.  Gathers through those entries read
    arbitrary bytes that the decode mask zeroes exactly (probability mass
    underflows to 0.0 at NEG_INF), so a partially-filled table is always
    safe to hand to the kernel.
  * **Reservation accounting (OOM-safe admission).**  ``reserve`` charges a
    request's worst case — ceil((prompt + max_new - 1) / page) pages — before
    its slot is admitted; physical pages are drawn lazily as tokens are
    written (``ensure``), but never beyond the reservation, so a mid-decode
    allocation can never fail.  When a reservation does not fit, admission
    is deferred (the service keeps the request queued) and ``submit`` raises
    ``Backpressure`` once the queue itself fills — requests shed, never OOM.
  * **Low-id pressure + compaction.**  The free list is a min-heap, so
    allocation always takes the lowest free id and the in-use *frontier*
    (highest id + 1) stays tight on its own; ``plan_compaction`` additionally
    relocates the highest in-use pages into lower free holes after a retire
    (copy-on-retire), handing back (src, dst) moves for the device-side copy
    and rewriting the block tables to match.
"""

from __future__ import annotations

import heapq
from typing import Dict, List, Tuple

SENTINEL = 0


def pages_for(n_tokens: int, page: int) -> int:
    return -(-max(int(n_tokens), 0) // page)


class PageAllocator:
    """Free-list allocator of fixed-size KV pages with per-slot block tables."""

    def __init__(self, total_pages: int, page: int, n_slots: int, blocks_per_slot: int):
        assert total_pages >= 2, "need at least the sentinel plus one usable page"
        assert page >= 1 and n_slots >= 1 and blocks_per_slot >= 1
        self.page = int(page)
        self.total_pages = int(total_pages)
        self.n_slots = int(n_slots)
        self.blocks_per_slot = int(blocks_per_slot)
        self._free: List[int] = list(range(1, total_pages))  # 0 is the sentinel
        heapq.heapify(self._free)
        self._tables: List[List[int]] = [[] for _ in range(n_slots)]
        self._reserved: List[int] = [0] * n_slots
        self.reserved_total = 0
        self.in_use = 0
        self.peak_pages = 0  # high-water mark of concurrently allocated pages
        self.alloc_total = 0
        self.compaction_moves = 0

    # -- capacity / admission accounting -------------------------------------

    @property
    def usable_pages(self) -> int:
        return self.total_pages - 1  # minus the sentinel

    def free_pages(self) -> int:
        return len(self._free)

    def pages_for_tokens(self, n_tokens: int) -> int:
        return pages_for(n_tokens, self.page)

    def can_reserve(self, n_tokens: int) -> bool:
        """Would a worst-case reservation for ``n_tokens`` rows fit right now?"""
        return self.reserved_total + self.pages_for_tokens(n_tokens) <= self.usable_pages

    def fits_ever(self, n_tokens: int) -> bool:
        """Could the request be served by an EMPTY pool (submit-time check)?"""
        need = self.pages_for_tokens(n_tokens)
        return need <= min(self.usable_pages, self.blocks_per_slot)

    def reserve(self, slot: int, n_tokens: int) -> int:
        """Charge the slot's worst-case page need against the pool; the caller
        must have checked ``can_reserve`` (admission is deferred otherwise)."""
        need = self.pages_for_tokens(n_tokens)
        if self.reserved_total + need > self.usable_pages:
            raise RuntimeError(
                f"page reservation overflow: {need} pages requested, "
                f"{self.usable_pages - self.reserved_total} unreserved"
            )
        assert self._reserved[slot] == 0 and not self._tables[slot], slot
        self._reserved[slot] = need
        self.reserved_total += need
        return need

    # -- allocation -----------------------------------------------------------

    def table(self, slot: int) -> List[int]:
        return list(self._tables[slot])

    def ensure(self, slot: int, n_tokens: int) -> List[Tuple[int, int]]:
        """Grow slot's table to cover ``n_tokens`` written rows.  Returns the
        newly bound (logical_block, physical_page) pairs.  Never exceeds the
        slot's reservation, so the heap pop cannot fail."""
        tbl = self._tables[slot]
        need = self.pages_for_tokens(n_tokens)
        if need > self._reserved[slot]:
            raise RuntimeError(
                f"slot {slot} needs {need} pages > reservation {self._reserved[slot]}"
            )
        added = []
        while len(tbl) < need:
            phys = heapq.heappop(self._free)
            added.append((len(tbl), phys))
            tbl.append(phys)
            self.in_use += 1
            self.alloc_total += 1
        self.peak_pages = max(self.peak_pages, self.in_use)
        return added

    def release(self, slot: int):
        """Return the slot's pages and reservation to the pool (retirement)."""
        for phys in self._tables[slot]:
            heapq.heappush(self._free, phys)
        self.in_use -= len(self._tables[slot])
        self._tables[slot] = []
        self.reserved_total -= self._reserved[slot]
        self._reserved[slot] = 0

    # -- compaction -----------------------------------------------------------

    def frontier(self) -> int:
        """One past the highest in-use physical page id (the pool's live
        extent; what a shrinkable backing allocation would have to cover)."""
        top = SENTINEL
        for tbl in self._tables:
            for phys in tbl:
                top = max(top, phys)
        return top + 1

    def plan_compaction(self, max_moves: int) -> List[Tuple[int, int]]:
        """Relocate up to ``max_moves`` of the highest in-use pages into the
        lowest free holes below them.  Rewrites the block tables and the free
        list; returns the (src, dst) physical moves the device pools must
        apply (``manager.apply_moves``).  No-op when already compact."""
        # position index: physical page -> (slot, logical block)
        where: Dict[int, Tuple[int, int]] = {}
        for s, tbl in enumerate(self._tables):
            for j, phys in enumerate(tbl):
                where[phys] = (s, j)
        moves: List[Tuple[int, int]] = []
        while len(moves) < max_moves and self._free and where:
            dst = self._free[0]
            src = max(where)
            if dst >= src:
                break  # every free hole is above every in-use page: compact
            heapq.heappop(self._free)
            s, j = where.pop(src)
            self._tables[s][j] = dst
            where[dst] = (s, j)
            heapq.heappush(self._free, src)
            moves.append((src, dst))
        self.compaction_moves += len(moves)
        return moves

    # -- scrape surface -------------------------------------------------------

    def metrics(self, prefix: str = "pages_") -> Dict[str, float]:
        return {
            f"{prefix}total": float(self.usable_pages),
            f"{prefix}in_use": float(self.in_use),
            f"{prefix}reserved": float(self.reserved_total),
            f"{prefix}peak": float(self.peak_pages),
            f"{prefix}frontier": float(self.frontier() - 1),
            f"{prefix}alloc_total": float(self.alloc_total),
            f"{prefix}compaction_moves": float(self.compaction_moves),
        }
