"""Prefix-sharing radix cache over the paged KV pool (vLLM/SGLang-style).

Token prefixes are interned at PAGE granularity: each node's key is a run of
whole pages (``len(key) == len(pages) * page``) and its ``pages`` list holds
the refcounted physical ids whose KV rows hold exactly those tokens.  The
tree answers two questions:

  * ``match(tokens)`` — the longest cached prefix of a new prompt: the run
    of fully-matched pages (mappable into a block table with zero copies)
    plus, when the match ends mid-page, the physical page holding the
    partially-matching rows (the copy-on-write source).
  * ``insert(tokens, pages)`` — donate a retired prompt's pages.  First
    writer wins: extents already cached are NOT replaced (the donor's
    duplicate pages stay slot-owned and free at retire), only genuinely new
    suffix pages are attached and retained on behalf of the tree.

Structure is maintained by splitting nodes at page boundaries when an insert
diverges mid-node, so sibling keys always differ in their first page and
child lookup is a dict hit on that page's token tuple.

Eviction is LRU **tail truncation** over unpinned leaf pages: under pool
pressure the least-recently-matched leaf gives up trailing pages one at a
time (a node with a truncated tail is still a valid cache entry for its
remaining prefix), and empty nodes unlink from their parents.  Pinned pages
(some in-flight request depends on them) are never popped, and because a
consumer pins a path *prefix*, pinned pages always form a prefix of any
node's page run — the unpinned suffix stays reachable by truncation.  The
allocator's ``evict_hook`` calls into :meth:`RadixCache.evict` so a dry free
heap reclaims cache pages on demand and lazy allocation stays infallible.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from .allocator import PageAllocator


class RadixNode:
    """One page-granular tree node: token key, donated pages, children."""
    __slots__ = ("key", "pages", "children", "parent", "last_used")

    def __init__(self, key: Tuple[int, ...], pages: List[int],
                 parent: Optional["RadixNode"]):
        self.key = key
        self.pages = pages
        self.children: Dict[Tuple[int, ...], RadixNode] = {}
        self.parent = parent
        self.last_used = 0


class PrefixMatch:
    """Result of a longest-prefix lookup."""

    __slots__ = ("pages", "tokens", "partial")

    def __init__(self, pages: List[int], tokens: int, partial: Optional[int]):
        self.pages = pages  # fully-matched pages, in prefix order
        self.tokens = tokens  # matched token count (may end mid-page)
        self.partial = partial  # page holding the trailing partial match


class RadixCache:
    """Page-granularity radix tree over retired prompts' KV pages.

    First writer wins; lookups share pages by refcount; eviction
    truncates LRU leaf tails under pool pressure (module docstring).
    """
    def __init__(self, page: int, alloc: PageAllocator):
        assert page >= 1
        self.page = int(page)
        self.alloc = alloc
        self.root = RadixNode((), [], None)
        self.cached_pages = 0
        self.nodes = 0
        self.splits_total = 0
        self.evicted_pages_total = 0
        self._tick = 0

    # -- lookup ----------------------------------------------------------------

    def _match_tail(self, node: RadixNode, tokens: Sequence[int], i: int,
                    j: int) -> int:
        """Token-level match length inside page ``j`` of ``node`` from
        absolute token offset ``i`` (strictly less than ``page``)."""
        base = j * self.page
        limit = min(self.page, len(node.key) - base, len(tokens) - i)
        n = 0
        while n < limit and node.key[base + n] == tokens[i + n]:
            n += 1
        return n

    def match(self, tokens: Sequence[int]) -> PrefixMatch:
        """Longest cached prefix of ``tokens``; touches every node on the
        matched path for LRU."""
        tokens = [int(t) for t in tokens]
        self._tick += 1
        cur = self.root
        i = 0
        pages: List[int] = []
        partial: Optional[int] = None
        while True:
            child = None
            if len(tokens) - i >= self.page:
                child = cur.children.get(tuple(tokens[i:i + self.page]))
            if child is None:
                # no full-page child: the best we can do is a partial match
                # inside some child's first page
                best, best_n = None, 0
                for c in cur.children.values():
                    n = self._match_tail(c, tokens, i, 0)
                    if n > best_n:
                        best, best_n = c, n
                if best is not None:
                    best.last_used = self._tick
                    partial = best.pages[0]
                    i += best_n
                break
            child.last_used = self._tick
            done = False
            j = 0
            while j < len(child.pages):
                lo = j * self.page
                if (len(tokens) - i >= self.page
                        and tuple(tokens[i:i + self.page]) == child.key[lo:lo + self.page]):
                    pages.append(child.pages[j])
                    i += self.page
                    j += 1
                    continue
                n = self._match_tail(child, tokens, i, j)
                if n > 0:
                    partial = child.pages[j]
                    i += n
                done = True
                break
            if done:
                break
            cur = child
        return PrefixMatch(pages=pages, tokens=i, partial=partial)

    # -- insertion ---------------------------------------------------------------

    def _split(self, node: RadixNode, j: int):
        """Split ``node`` at page boundary ``j`` (0 < j < len(pages)): the
        node keeps its first ``j`` pages, a new child inherits the rest along
        with the node's children.  Physical ids and refcounts are untouched,
        so in-flight consumers of either half are unaffected."""
        page = self.page
        tail = RadixNode(node.key[j * page:], node.pages[j:], node)
        tail.children = node.children
        for c in tail.children.values():
            c.parent = tail
        tail.last_used = node.last_used
        node.children = {tail.key[:page]: tail}
        node.key = node.key[:j * page]
        node.pages = node.pages[:j]
        self.nodes += 1
        self.splits_total += 1

    def insert(self, tokens: Sequence[int], pages: Sequence[int]) -> List[int]:
        """Intern ``pages`` (whole pages of ``tokens``) into the tree.  Only
        pages beyond the already-cached extent are attached; those are
        retained on behalf of the tree and returned.  First writer wins —
        a duplicate donation attaches nothing."""
        page = self.page
        tokens = [int(t) for t in tokens]
        n = len(pages)
        assert len(tokens) >= n * page, "insert needs whole pages of tokens"
        if n == 0:
            return []
        self._tick += 1
        cur = self.root
        i = 0  # page index into our donation
        while i < n:
            key_page = tuple(tokens[i * page:(i + 1) * page])
            child = cur.children.get(key_page)
            if child is None:
                node = RadixNode(tuple(tokens[i * page:n * page]),
                                 list(pages[i:]), cur)
                node.last_used = self._tick
                cur.children[key_page] = node
                self.nodes += 1
                new = list(pages[i:])
                for phys in new:
                    self.alloc.retain(phys)
                self.cached_pages += len(new)
                return new
            child.last_used = self._tick
            j = 0
            while (j < len(child.pages) and i + j < n
                   and tuple(tokens[(i + j) * page:(i + j + 1) * page])
                   == child.key[j * page:(j + 1) * page]):
                j += 1
            if j == len(child.pages):
                cur = child
                i += j
                continue
            if i + j == n:
                return []  # our donation is a prefix of cached content
            self._split(child, j)
            cur = child
            i += j
        return []

    # -- eviction ----------------------------------------------------------------

    def _leaves(self) -> List[RadixNode]:
        out, stack = [], [self.root]
        while stack:
            nd = stack.pop()
            if nd is not self.root and not nd.children:
                out.append(nd)
            stack.extend(nd.children.values())
        return out

    def _unlink(self, node: RadixNode):
        parent = node.parent
        for k, v in list(parent.children.items()):
            if v is node:
                del parent.children[k]
                break
        self.nodes -= 1

    def evict(self, need: int) -> int:
        """Free at least ``need`` pages by LRU tail truncation of unpinned
        leaf pages; returns how many actually went back to the free list
        (pages still mapped by a live slot drop out of the tree without
        freeing).  Stops early when every remaining leaf tail is pinned."""
        freed = 0
        while freed < need:
            candidates = [nd for nd in self._leaves()
                          if nd.pages and self.alloc.pin_count(nd.pages[-1]) == 0]
            if not candidates:
                break
            victim = min(candidates, key=lambda nd: nd.last_used)
            while (victim.pages and freed < need
                   and self.alloc.pin_count(victim.pages[-1]) == 0):
                phys = victim.pages.pop()
                victim.key = victim.key[:len(victim.pages) * self.page]
                self.cached_pages -= 1
                self.evicted_pages_total += 1
                if self.alloc.release_page(phys):
                    freed += 1
            if not victim.pages:
                self._unlink(victim)
        return freed

    # -- scrape surface ----------------------------------------------------------

    def metrics(self, prefix: str = "radix_") -> Dict[str, float]:
        """Flat gauge dict of cache size / hit / eviction counters."""
        return {
            f"{prefix}cached_pages": float(self.cached_pages),
            f"{prefix}nodes": float(self.nodes),
            f"{prefix}splits_total": float(self.splits_total),
            f"{prefix}evicted_pages_total": float(self.evicted_pages_total),
        }
