"""repro.serve.paging — paged KV cache for the continuous-batching slot pool.

The dense pool (PR 4) reserves one ``max_len`` cache row-strip per slot, so a
single long request dictates memory for the whole pool.  This package
replaces that with fixed-size token *pages* handed out by a free-list
allocator and addressed through per-slot block tables:

  * ``allocator`` — host-side bookkeeping: ``PageAllocator`` (min-heap free
    list, reservation-based OOM-safe admission, copy-on-retire compaction
    planning), sentinel page 0 for unassigned table entries;
  * ``manager``   — ``PagedKVManager``: the (n_slots, NB) block-table array
    the decode step consumes, device-pool construction via
    ``models.transformer.init_paged_caches``, and the byte accounting the
    bench gate compares against the dense pool.

The tensor half lives in ``models/attention.py`` (block-table gather/scatter
decode, Pallas kernel in ``kernels/paged_attention`` on TPU), the jitted slot
surgery in ``repro.train.serve`` (``insert_slot_state_paged`` /
``reset_slot_state_paged`` / ``apply_page_moves``), and the scheduling in
``serve.ContinuousLMEngine(paged=True)`` / ``serve.LMService``.
"""

from repro.serve.paging.allocator import SENTINEL, PageAllocator, pages_for
from repro.serve.paging.manager import (
    PagedKVManager,
    attn_kv_bytes_per_row,
    dense_cache_bytes,
)

__all__ = [
    "PageAllocator",
    "PagedKVManager",
    "SENTINEL",
    "attn_kv_bytes_per_row",
    "dense_cache_bytes",
    "pages_for",
]
