"""repro.serve.paging — paged KV cache for the continuous-batching slot pool.

The dense pool (PR 4) reserves one ``max_len`` cache row-strip per slot, so a
single long request dictates memory for the whole pool.  This package
replaces that with fixed-size token *pages* handed out by a free-list
allocator and addressed through per-slot block tables:

  * ``allocator`` — host-side bookkeeping: ``PageAllocator`` (min-heap free
    list, reservation-based OOM-safe admission, refcount/pin accounting for
    shared pages, copy-on-retire compaction planning), sentinel page 0 for
    unassigned table entries;
  * ``manager``   — ``PagedKVManager``: the (n_slots, NB) block-table array
    the decode step consumes, device-pool construction via
    ``models.transformer.init_paged_caches``, prefix-plan admission, and the
    byte accounting the bench gate compares against the dense pool;
  * ``radix``     — ``RadixCache``: page-granular prefix interning of retired
    prompts with LRU tail-truncation eviction (the prefix-sharing cache
    behind ``ContinuousLMEngine(prefix_cache=True)``).

The tensor half lives in ``models/attention.py`` (block-table gather/scatter
decode, Pallas kernel in ``kernels/paged_attention`` on TPU), the jitted slot
surgery in ``repro.train.serve`` (``insert_slot_state_paged`` /
``reset_slot_state_paged`` / ``apply_page_moves`` /
``load_template_from_pages``), and the scheduling in
``serve.ContinuousLMEngine(paged=True)`` / ``serve.LMService``.
"""

from repro.serve.paging.allocator import SENTINEL, PageAllocator, pages_for
from repro.serve.paging.manager import (
    PagedKVManager,
    PrefixPlan,
    attn_kv_bytes_per_row,
    dense_cache_bytes,
)
from repro.serve.paging.radix import PrefixMatch, RadixCache, RadixNode

__all__ = [
    "PageAllocator",
    "PagedKVManager",
    "PrefixMatch",
    "PrefixPlan",
    "RadixCache",
    "RadixNode",
    "SENTINEL",
    "attn_kv_bytes_per_row",
    "dense_cache_bytes",
    "pages_for",
]
