"""PagedKVManager: the bridge between the host-side ``PageAllocator`` and
the device-side page pools.

Owns the (n_slots, NB) block-table array the decode step consumes, the
admission/reservation bookkeeping per slot, and the byte accounting the
bench gate compares against the dense pool.  The device trees themselves are
built by ``models.transformer.init_paged_caches`` (attention positions get
page pools, recurrent state stays dense) and mutated by the jitted surgery
in ``repro.train.serve`` (``insert_slot_state_paged`` / ``reset_slot_state_paged``
/ ``apply_page_moves``) — the manager only decides WHICH pages those touch.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serve.paging.allocator import SENTINEL, PageAllocator


def attn_kv_bytes_per_row(cfg) -> int:
    """Bytes of K+V cache per context row across the whole layer stack
    (attention pattern positions only — recurrent state has no row axis)."""
    n_attn = sum(1 for spec in cfg.pattern if spec.mixer == "attn")
    dtype_bytes = np.dtype(cfg.compute_dtype).itemsize
    return 2 * n_attn * cfg.repeats * cfg.n_kv_heads * cfg.hd * dtype_bytes


def dense_cache_bytes(cfg, n_slots: int, max_len: int) -> int:
    """What the PR 4 dense pool permanently holds for its attention caches."""
    return attn_kv_bytes_per_row(cfg) * n_slots * max_len


class PagedKVManager:
    """Block tables + reservation accounting for one slot pool."""

    def __init__(
        self,
        cfg,
        n_slots: int,
        max_len: int,
        page: int,
        total_pages: Optional[int] = None,
    ):
        assert max_len % page == 0, (
            f"max_len={max_len} must be a multiple of the page size {page} "
            "(the engine rounds up at construction)"
        )
        if not any(spec.mixer == "attn" for spec in cfg.pattern):
            raise ValueError(
                "paged KV cache needs at least one attention position in the "
                "pattern; SSM/RWKV state is O(1) per slot and is never paged"
            )
        self.cfg = cfg
        self.n_slots = int(n_slots)
        self.max_len = int(max_len)
        self.page = int(page)
        self.blocks_per_slot = max_len // page
        # +1: the sentinel page.  The default pool matches dense capacity —
        # the memory win comes from sizing total_pages to the workload (the
        # bench does) while reservation accounting keeps admission OOM-safe.
        self.total_pages = int(total_pages or (self.n_slots * self.blocks_per_slot + 1))
        self.alloc = PageAllocator(self.total_pages, page, n_slots, self.blocks_per_slot)

    # -- device tree construction --------------------------------------------

    def init_caches(self):
        from repro.models.transformer import init_paged_caches

        return init_paged_caches(self.cfg, self.n_slots, self.total_pages, self.page)

    # -- block tables ---------------------------------------------------------

    def table_row(self, slot: int) -> np.ndarray:
        """(NB,) int32 physical page ids for one slot, sentinel-padded."""
        row = np.full((self.blocks_per_slot,), SENTINEL, np.int32)
        tbl = self.alloc.table(slot)
        row[: len(tbl)] = tbl
        return row

    def block_tables(self) -> np.ndarray:
        """(n_slots, NB) int32 — what every paged decode step consumes."""
        return np.stack([self.table_row(s) for s in range(self.n_slots)], axis=0)

    # -- admission / growth / retirement --------------------------------------

    def rows_needed(self, prompt_len: int, max_new_tokens: int) -> int:
        # the final emitted token is never written (same row accounting as
        # the dense pool's admission check)
        return prompt_len + max_new_tokens - 1

    def fits_ever(self, prompt_len: int, max_new_tokens: int) -> bool:
        return self.alloc.fits_ever(self.rows_needed(prompt_len, max_new_tokens))

    def can_admit(self, prompt_len: int, max_new_tokens: int) -> bool:
        return self.alloc.can_reserve(self.rows_needed(prompt_len, max_new_tokens))

    def admit(self, slot: int, prompt_len: int, max_new_tokens: int):
        self.alloc.reserve(slot, self.rows_needed(prompt_len, max_new_tokens))

    def ensure_rows(self, slot: int, n_rows: int) -> List[Tuple[int, int]]:
        """Guarantee the slot's table covers ``n_rows`` written rows."""
        return self.alloc.ensure(slot, n_rows)

    def release(self, slot: int):
        self.alloc.release(slot)

    def plan_compaction(self) -> Tuple[np.ndarray, np.ndarray]:
        """Fixed-width (src, dst) move vectors (identity-padded) for
        ``train.serve.apply_page_moves``; empty arrays when already compact."""
        moves = self.alloc.plan_compaction(self.blocks_per_slot)
        if not moves:
            return np.zeros((0,), np.int32), np.zeros((0,), np.int32)
        src = np.full((self.blocks_per_slot,), SENTINEL, np.int32)
        dst = np.full((self.blocks_per_slot,), SENTINEL, np.int32)
        for i, (s, d) in enumerate(moves):
            src[i], dst[i] = s, d
        return src, dst

    # -- byte accounting -------------------------------------------------------

    @property
    def page_bytes(self) -> int:
        return attn_kv_bytes_per_row(self.cfg) * self.page

    def peak_cache_bytes(self) -> int:
        """High-water mark of concurrently allocated page bytes — the paged
        counterpart of the dense pool's permanent n_slots * max_len rows."""
        return self.alloc.peak_pages * self.page_bytes

    def pool_cache_bytes(self) -> int:
        return self.alloc.usable_pages * self.page_bytes

    def dense_equiv_bytes(self) -> int:
        return dense_cache_bytes(self.cfg, self.n_slots, self.max_len)

    def metrics(self, prefix: str = "paged_") -> Dict[str, float]:
        out = {f"{prefix}{k}": v for k, v in self.alloc.metrics(prefix="pages_").items()}
        # derived occupancy ratio so threshold alert rules (page_pool_pressure)
        # can target one gauge instead of dividing two
        out[f"{prefix}pages_utilization"] = (
            self.alloc.in_use / self.alloc.usable_pages if self.alloc.usable_pages else 0.0
        )
        out[f"{prefix}page_tokens"] = float(self.page)
        out[f"{prefix}peak_cache_bytes"] = float(self.peak_cache_bytes())
        out[f"{prefix}pool_cache_bytes"] = float(self.pool_cache_bytes())
        out[f"{prefix}dense_equiv_bytes"] = float(self.dense_equiv_bytes())
        return out
