"""PagedKVManager: the bridge between the host-side ``PageAllocator`` and
the device-side page pools.

Owns the (n_slots, NB) block-table array the decode step consumes, the
admission/reservation bookkeeping per slot, and the byte accounting the
bench gate compares against the dense pool.  The device trees themselves are
built by ``models.transformer.init_paged_caches`` (attention positions get
page pools, recurrent state stays dense) and mutated by the jitted surgery
in ``repro.train.serve`` (``insert_slot_state_paged`` / ``reset_slot_state_paged``
/ ``apply_page_moves``) — the manager only decides WHICH pages those touch.

With ``prefix_cache=True`` the manager additionally runs a
:class:`~repro.serve.paging.radix.RadixCache` over retired prompts:

  * ``plan_prefix`` matches a new prompt against the tree and quantizes the
    hit down to the engine's chunk grid (and to ``prompt_len - 1`` — the
    last prompt token must always be recomputed to produce first-token
    logits), so a warm request resumes chunked prefill exactly at a chunk
    boundary the cold run would also have hit: bit-identical tokens.
  * ``admit`` binds the matched pages into the slot's block table without
    copying, pins them for the request's lifetime, and — when the hit ends
    mid-page — charges one reservation page for a copy-on-write of the
    boundary page (``cow_moves`` hands the engine the batched device copy).
  * ``donate`` interns a completed prompt's full pages back into the tree at
    retirement (first writer wins).
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.serve.paging.allocator import SENTINEL, PageAllocator
from repro.serve.paging.radix import RadixCache


def attn_kv_bytes_per_row(cfg) -> int:
    """Bytes of K+V cache per context row across the whole layer stack
    (attention pattern positions only — recurrent state has no row axis)."""
    n_attn = sum(1 for spec in cfg.pattern if spec.mixer == "attn")
    dtype_bytes = np.dtype(cfg.compute_dtype).itemsize
    return 2 * n_attn * cfg.repeats * cfg.n_kv_heads * cfg.hd * dtype_bytes


def dense_cache_bytes(cfg, n_slots: int, max_len: int) -> int:
    """What the PR 4 dense pool permanently holds for its attention caches."""
    return attn_kv_bytes_per_row(cfg) * n_slots * max_len


class PrefixPlan:
    """Admission-time plan from one radix lookup: what to share, what to COW,
    and where chunked prefill may resume."""

    __slots__ = ("hit", "shared", "cow_src", "matched_tokens")

    def __init__(self, hit: int, shared: List[int], cow_src: Optional[int],
                 matched_tokens: int):
        self.hit = hit  # chunk-aligned cached rows (prefill resumes here)
        self.shared = shared  # fully-covered pages to bind read-only
        self.cow_src = cow_src  # page to copy when the hit ends mid-page
        self.matched_tokens = matched_tokens  # raw (unquantized) match length

    @property
    def pin_pages(self) -> List[int]:
        """All pages this plan must pin (shared pages + the COW source)."""
        return self.shared + ([self.cow_src] if self.cow_src is not None else [])


class SpecTicket:
    """One in-flight speculative verify for one slot: which logical blocks
    were remapped to scratch pages, and the scratch-mapped table row the
    verify forward reads/writes through.  Produced by
    :meth:`PagedKVManager.spec_begin`, consumed by exactly one of
    :meth:`PagedKVManager.spec_commit` / :meth:`PagedKVManager.spec_rollback`."""

    __slots__ = ("slot", "pos", "k_eff", "blocks", "scratch", "row")

    def __init__(self, slot: int, pos: int, k_eff: int, blocks: List[int],
                 scratch: List[int], row: np.ndarray):
        self.slot = slot
        self.pos = pos          # next write row (the slot's cache_len)
        self.k_eff = k_eff      # draft tokens actually scored this tick
        self.blocks = blocks    # logical blocks remapped to scratch
        self.scratch = scratch  # scratch physical ids, parallel to blocks
        self.row = row          # (NB,) table row with blocks -> scratch


class PagedKVManager:
    """Block tables + reservation accounting for one slot pool."""

    def __init__(
        self,
        cfg,
        n_slots: int,
        max_len: int,
        page: int,
        total_pages: Optional[int] = None,
        prefix_cache: bool = False,
        prefix_chunk: Optional[int] = None,
        spec_draft_k: int = 0,
    ):
        assert max_len % page == 0, (
            f"max_len={max_len} must be a multiple of the page size {page} "
            "(the engine rounds up at construction)"
        )
        if not any(spec.mixer == "attn" for spec in cfg.pattern):
            raise ValueError(
                "paged KV cache needs at least one attention position in the "
                "pattern; SSM/RWKV state is O(1) per slot and is never paged"
            )
        self.cfg = cfg
        self.n_slots = int(n_slots)
        self.max_len = int(max_len)
        self.page = int(page)
        self.blocks_per_slot = max_len // page
        # speculative scratch: a verify touching rows [pos, pos + k] spans at
        # most ceil((page - 1 + k) / page) + 1 blocks (worst case pos at the
        # last row of a page), per slot, per tick
        self.spec_draft_k = int(spec_draft_k)
        self.spec_blocks_per_slot = (
            (page - 1 + self.spec_draft_k) // page + 1 if self.spec_draft_k else 0
        )
        n_scratch = self.n_slots * self.spec_blocks_per_slot
        # +1: the sentinel page.  The default pool matches dense capacity —
        # the memory win comes from sizing total_pages to the workload (the
        # bench does) while reservation accounting keeps admission OOM-safe.
        # Speculation adds its scratch pages ON TOP of the default so the
        # admission capacity seen by requests is unchanged.
        self.total_pages = int(
            total_pages or (self.n_slots * self.blocks_per_slot + 1 + n_scratch)
        )
        self.alloc = PageAllocator(self.total_pages, page, n_slots, self.blocks_per_slot)
        # scratch pages are allocated + pinned up front: the pin charges them
        # against the `reserved + pinned <= usable` invariant, so speculative
        # writes can never OOM an admitted slot
        self._spec_free: List[int] = (
            self.alloc.alloc_pinned(n_scratch) if n_scratch else []
        )
        self.prefix_cache = bool(prefix_cache)
        self.radix: Optional[RadixCache] = None
        # flight-recorder tap the engine installs (kind, **fields)
        self.event_sink: Optional[Callable[..., None]] = None
        self.prefix_hits = 0
        self.prefix_misses = 0
        self.prefix_hit_tokens = 0
        self.prefix_cow_total = 0
        if self.prefix_cache:
            if not prefix_chunk or int(prefix_chunk) < 1:
                raise ValueError(
                    "prefix_cache quantizes hits to the chunked-prefill grid; "
                    "pass prefix_chunk (the engine's prefill_chunk)"
                )
            self.prefix_chunk = int(prefix_chunk)
            self.radix = RadixCache(self.page, self.alloc)
            self.alloc.evict_hook = self._evict_for
        # per-slot prefix state (only populated under prefix_cache)
        self._plans: Dict[int, PrefixPlan] = {}
        self._pins: Dict[int, List[int]] = {}
        self._cow: Dict[int, Optional[Tuple[int, int]]] = {}

    # -- device tree construction --------------------------------------------

    def init_caches(self):
        """Allocate the pool's paged KV caches (all-sentinel tables)."""
        from repro.models.transformer import init_paged_caches

        return init_paged_caches(self.cfg, self.n_slots, self.total_pages, self.page)

    # -- block tables ---------------------------------------------------------

    def table_row(self, slot: int) -> np.ndarray:
        """(NB,) int32 physical page ids for one slot, sentinel-padded."""
        row = np.full((self.blocks_per_slot,), SENTINEL, np.int32)
        tbl = self.alloc.table(slot)
        row[: len(tbl)] = tbl
        return row

    def block_tables(self) -> np.ndarray:
        """(n_slots, NB) int32 — what every paged decode step consumes."""
        return np.stack([self.table_row(s) for s in range(self.n_slots)], axis=0)

    def scatter_row(self, slot: int) -> np.ndarray:
        """Table row for the final-chunk scatter: fully-shared prefix blocks
        are masked to the sentinel so the insert never rewrites a read-only
        shared page (the duplicate writes land harmlessly on page 0)."""
        row = self.table_row(slot)
        plan = self._plans.get(slot)
        if plan is not None:
            row[: len(plan.shared)] = SENTINEL
        return row

    def reset_row(self, slot: int) -> np.ndarray:
        """Table row for the retire-time zeroing: any page some other owner
        still maps (shared prefixes, donated pages) is masked out — only the
        slot's exclusive pages are scrubbed."""
        row = self.table_row(slot)
        for j, phys in enumerate(self.alloc.table(slot)):
            if self.alloc.refcount(phys) > 1:
                row[j] = SENTINEL
        return row

    # -- admission / growth / retirement --------------------------------------

    def rows_needed(self, prompt_len: int, max_new_tokens: int) -> int:
        """Cache rows a request needs: ``prompt + max_new - 1``."""
        # the final emitted token is never written (same row accounting as
        # the dense pool's admission check)
        return prompt_len + max_new_tokens - 1

    def fits_ever(self, prompt_len: int, max_new_tokens: int) -> bool:
        """True if the request could ever fit an empty pool."""
        return self.alloc.fits_ever(self.rows_needed(prompt_len, max_new_tokens))

    def plan_prefix(self, tokens, prompt_len: int) -> PrefixPlan:
        """Match a prompt against the radix tree and quantize the hit to the
        chunk grid (never past ``prompt_len - 1``: the final prompt token is
        always recomputed so the first emitted token gets real logits)."""
        m = self.radix.match(tokens[:prompt_len])
        hit = min(m.tokens, prompt_len - 1)
        hit = (hit // self.prefix_chunk) * self.prefix_chunk
        full = hit // self.page
        shared = m.pages[:full]
        cow_src = None
        if hit % self.page:
            # hit covers part of page `full`; a matched page must exist there
            cow_src = m.pages[full] if full < len(m.pages) else m.partial
            assert cow_src is not None, (hit, m.tokens, len(m.pages))
        return PrefixPlan(hit, shared, cow_src, m.tokens)

    def can_admit(self, prompt_len: int, max_new_tokens: int,
                  plan: Optional[PrefixPlan] = None) -> bool:
        """True if the unshared reservation fits the pool right now."""
        rows = self.rows_needed(prompt_len, max_new_tokens)
        if plan is None:
            return self.alloc.can_reserve(rows)
        new_pins = sum(1 for p in plan.pin_pages if self.alloc.pin_count(p) == 0)
        return self.alloc.can_reserve(rows, shared_pages=len(plan.shared),
                                      new_pins=new_pins)

    def admit(self, slot: int, prompt_len: int, max_new_tokens: int,
              plan: Optional[PrefixPlan] = None) -> int:
        """Reserve + (under prefix caching) bind/pin the plan's pages.
        Returns the row the slot's chunked prefill may resume at (0 cold).
        Pin before the COW allocation so on-demand eviction inside
        ``cow_bind`` can never free a page this plan depends on."""
        rows = self.rows_needed(prompt_len, max_new_tokens)
        if plan is None:
            self.alloc.reserve(slot, rows)
            if self.prefix_cache:
                self.prefix_misses += 1
            return 0
        self.alloc.reserve(slot, rows, shared_pages=len(plan.shared))
        pins = plan.pin_pages
        for phys in pins:
            self.alloc.pin_page(phys)
        self._pins[slot] = pins
        self.alloc.bind_shared(slot, plan.shared)
        cow = None
        if plan.cow_src is not None:
            dst = self.alloc.cow_bind(slot, plan.cow_src)
            cow = (plan.cow_src, dst)
            self.prefix_cow_total += 1
        self._cow[slot] = cow
        self._plans[slot] = plan
        if plan.hit > 0:
            self.prefix_hits += 1
            self.prefix_hit_tokens += plan.hit
            self._emit("prefix_hit", slot=slot, tokens=plan.hit,
                       shared_pages=len(plan.shared), cow=cow is not None)
        else:
            self.prefix_misses += 1
        return plan.hit

    def prefix_hit(self, slot: int) -> int:
        """Cached rows the slot's prefill skipped (0 when cold/unshared)."""
        plan = self._plans.get(slot)
        return plan.hit if plan is not None else 0

    def cow_moves(self, slot: int) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """One-shot fixed-width (src, dst) vectors for the slot's pending
        copy-on-write (``apply_page_moves`` layout), or None.  Consumed on
        first call — the copy runs once, before the first warm chunk."""
        cow = self._cow.get(slot)
        if cow is None:
            return None
        self._cow[slot] = None
        src = np.full((self.blocks_per_slot,), SENTINEL, np.int32)
        dst = np.full((self.blocks_per_slot,), SENTINEL, np.int32)
        src[0], dst[0] = cow
        return src, dst

    def ensure_rows(self, slot: int, n_rows: int) -> List[Tuple[int, int]]:
        """Guarantee the slot's table covers ``n_rows`` written rows."""
        return self.alloc.ensure(slot, n_rows)

    # -- speculative scratch lifecycle ----------------------------------------
    #
    # A verify tick for one slot must read committed rows < pos and write the
    # k_eff + 1 lane inputs at rows [pos, pos + k_eff] WITHOUT dirtying the
    # slot's real pages (a truncated draft must leave no trace).  spec_begin
    # remaps every touched logical block to a scratch page — copying the one
    # partially-committed boundary page so reads stay bit-identical — and the
    # verify forward runs through that remapped row.  spec_commit then SWAPS
    # the scratch pages into the block table (the displaced pages become the
    # new scratch inventory: zero copies on the accept path); spec_rollback
    # just returns the scratch pages, leaving table and positions untouched.

    def spec_begin(self, slot: int, pos: int,
                   k_eff: int) -> Tuple[SpecTicket, List[Tuple[int, int]]]:
        """Open a speculative verify window for ``slot`` at row ``pos``.

        Returns the ticket plus (src, dst) physical page copies the engine
        must apply (batched ``apply_page_moves``) BEFORE the verify forward:
        only the boundary block containing committed rows needs copying —
        blocks whose rows are all >= ``pos`` hold no live data (stale
        speculative writes there are masked by ``cache_len``).
        """
        b0 = pos // self.page
        b1 = (pos + k_eff) // self.page
        blocks = list(range(b0, b1 + 1))
        if len(blocks) > self.spec_blocks_per_slot:
            raise RuntimeError(
                f"verify spans {len(blocks)} blocks > scratch budget "
                f"{self.spec_blocks_per_slot} (k_eff={k_eff})"
            )
        scratch = [self._spec_free.pop() for _ in blocks]
        row = self.table_row(slot)
        copies: List[Tuple[int, int]] = []
        if pos % self.page:
            copies.append((int(row[b0]), scratch[0]))
        for b, s in zip(blocks, scratch):
            row[b] = s
        return SpecTicket(slot, pos, k_eff, blocks, scratch, row), copies

    def spec_commit(self, ticket: SpecTicket, n_written: int):
        """Promote a verified span into the slot's block table.

        ``n_written`` is the accepted input rows (``1 + accepted_draft`` —
        lane 0's write at ``pos`` is the one plain decode would have done, so
        this is always >= 1).  Blocks covering those rows swap their scratch
        page in (the displaced page returns to the scratch pool — a pure
        table edit, no device copy); scratch beyond the written span is
        returned unused.  Real pages for newly covered blocks are ensured
        here, never in spec_begin, so rollback stays an exact no-op."""
        assert n_written >= 1, n_written
        self.ensure_rows(ticket.slot, ticket.pos + n_written)
        last_block = (ticket.pos + n_written - 1) // self.page
        for b, s in zip(ticket.blocks, ticket.scratch):
            if b <= last_block:
                self._spec_free.append(self.alloc.swap_page(ticket.slot, b, s))
            else:
                self._spec_free.append(s)

    def spec_rollback(self, ticket: SpecTicket):
        """Discard a speculative window: scratch pages return to the pool and
        the block table / reservations are exactly as before ``spec_begin``
        (nothing was ensured, nothing swapped — stale device writes on the
        scratch pages are dead data)."""
        self._spec_free.extend(ticket.scratch)

    def donate(self, slot: int, tokens) -> int:
        """Intern the slot's full prompt pages into the radix tree at the end
        of prefill (first writer wins).  Returns pages newly cached."""
        if self.radix is None:
            return 0
        prompt_len = len(tokens)
        full = prompt_len // self.page
        if full == 0:
            return 0
        pages = self.alloc.table(slot)[:full]
        new = self.radix.insert(tokens[: full * self.page], pages)
        if new:
            self._emit("page_share", slot=slot, donated_pages=len(new))
        return len(new)

    def release(self, slot: int):
        """Return a slot's pages, pins and reservation to the pool."""
        for phys in self._pins.pop(slot, []):
            self.alloc.unpin_page(phys)
        self._cow.pop(slot, None)
        self._plans.pop(slot, None)
        self.alloc.release(slot)

    def _evict_for(self, need: int) -> int:
        freed = self.radix.evict(need)
        self._emit("prefix_evict", need=need, freed=freed,
                   cached_pages=self.radix.cached_pages)
        return freed

    def _emit(self, kind: str, **fields):
        if self.event_sink is not None:
            self.event_sink(kind, **fields)

    def plan_compaction(self) -> Tuple[np.ndarray, np.ndarray]:
        """Fixed-width (src, dst) move vectors (identity-padded) for
        ``train.serve.apply_page_moves``; empty arrays when already compact."""
        moves = self.alloc.plan_compaction(self.blocks_per_slot)
        if not moves:
            return np.zeros((0,), np.int32), np.zeros((0,), np.int32)
        src = np.full((self.blocks_per_slot,), SENTINEL, np.int32)
        dst = np.full((self.blocks_per_slot,), SENTINEL, np.int32)
        for i, (s, d) in enumerate(moves):
            src[i], dst[i] = s, d
        return src, dst

    # -- byte accounting -------------------------------------------------------

    @property
    def page_bytes(self) -> int:
        """Bytes of KV state one page holds across all attention layers."""
        return attn_kv_bytes_per_row(self.cfg) * self.page

    def peak_cache_bytes(self) -> int:
        """High-water mark of concurrently allocated page bytes — the paged
        counterpart of the dense pool's permanent n_slots * max_len rows."""
        return self.alloc.peak_pages * self.page_bytes

    def pool_cache_bytes(self) -> int:
        """Total bytes of the paged pool's usable pages."""
        return self.alloc.usable_pages * self.page_bytes

    def dense_equiv_bytes(self) -> int:
        """Bytes the dense per-slot pool would reserve instead."""
        return dense_cache_bytes(self.cfg, self.n_slots, self.max_len)

    def metrics(self, prefix: str = "paged_") -> Dict[str, float]:
        """Allocator counters plus paged/prefix/spec gauges, one flat dict."""
        out = {f"{prefix}{k}": v for k, v in self.alloc.metrics(prefix="pages_").items()}
        # derived occupancy ratio so threshold alert rules (page_pool_pressure)
        # can target one gauge instead of dividing two
        out[f"{prefix}pages_utilization"] = (
            self.alloc.in_use / self.alloc.usable_pages if self.alloc.usable_pages else 0.0
        )
        out[f"{prefix}page_tokens"] = float(self.page)
        if self.spec_draft_k:
            out[f"{prefix}spec_scratch_pages"] = float(
                self.n_slots * self.spec_blocks_per_slot
            )
            out[f"{prefix}spec_scratch_free"] = float(len(self._spec_free))
        out[f"{prefix}peak_cache_bytes"] = float(self.peak_cache_bytes())
        out[f"{prefix}pool_cache_bytes"] = float(self.pool_cache_bytes())
        out[f"{prefix}dense_equiv_bytes"] = float(self.dense_equiv_bytes())
        if self.prefix_cache:
            lookups = self.prefix_hits + self.prefix_misses
            out[f"{prefix}prefix_hit_rate"] = (
                self.prefix_hits / lookups if lookups else 0.0
            )
            out[f"{prefix}shared_pages"] = float(self.alloc.shared_pages)
            out[f"{prefix}prefix_hits_total"] = float(self.prefix_hits)
            out[f"{prefix}prefix_misses_total"] = float(self.prefix_misses)
            out[f"{prefix}prefix_hit_tokens_total"] = float(self.prefix_hit_tokens)
            out[f"{prefix}prefix_cow_total"] = float(self.prefix_cow_total)
            for k, v in self.radix.metrics(prefix=f"{prefix}radix_").items():
                out[k] = v
        return out
