"""Self-drafting speculative decoding: the n-gram prompt-lookup drafter.

Per-slot decode on the paged pool is batch-1-like and memory-bound — every
tick streams the whole KV working set to produce ONE token per slot.
Speculative decoding converts that slack into tokens/step: a cheap drafter
proposes ``k`` candidate tokens, a single batched *verify* forward scores
all ``k + 1`` positions at once, and the longest prefix of the draft that
matches the model's own greedy choices is accepted.  Greedy verification
makes the emitted stream BIT-IDENTICAL to plain sequential greedy decode —
the standing serve acceptance gate — because every accepted token is, by
construction, exactly the token the model would have produced.

This module is the pure-python half: the drafter and the acceptance rule.
No jax, no KV pages — the engine (``ContinuousLMEngine``) owns the verify
forward and the scratch-page bookkeeping, the paging manager owns the
commit/rollback of speculative rows.

The drafter is a *prompt-lookup* / n-gram table (PAPERS.md 2304.04487
family): each slot keeps a suffix table over its own context (prompt +
every accepted token) mapping the last ``n`` tokens to positions where that
n-gram occurred before; a draft is simply the continuation of the most
recent earlier occurrence.  There is no draft model and therefore no draft
KV to page — the only accelerator cost speculation adds is the verify
forward, which replaces (not augments) the plain decode tick.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class SpecConfig:
    """Tunables for the self-drafting speculative decoder.

    ``draft_k`` is the maximum tokens proposed per tick (the verify forward
    scores ``draft_k + 1`` lanes per slot).  ``ngram_max``/``ngram_min``
    bound the suffix lengths tried by the prompt-lookup table, longest
    first — longer matches are rarer but much more likely to extend.
    """

    draft_k: int = 4
    ngram_max: int = 3
    ngram_min: int = 1

    def __post_init__(self):
        if self.draft_k < 1:
            raise ValueError(f"draft_k must be >= 1, got {self.draft_k}")
        if not (1 <= self.ngram_min <= self.ngram_max):
            raise ValueError(
                f"need 1 <= ngram_min <= ngram_max, got "
                f"[{self.ngram_min}, {self.ngram_max}]"
            )


class SlotDraft:
    """Per-slot prompt-lookup drafter: suffix table over prompt + emits.

    The table maps each n-gram (``ngram_min <= n <= ngram_max``) to the
    *end positions* of its most recent occurrences — ``j`` such that
    ``context[j - n : j] == ngram`` — keeping the last two.  Two, not one:
    pushing token ``t`` registers the context's new suffix at its own end
    position ``len(context)``, which at draft time IS the query n-gram and
    has no continuation yet.  Keeping the penultimate occurrence as well
    lets ``propose`` skip that self-match and still find the useful earlier
    one in O(1).
    """

    __slots__ = ("cfg", "context", "_table", "drafts", "draft_hits",
                 "proposed_total", "accepted_total")

    def __init__(self, cfg: SpecConfig, prompt: Sequence[int]):
        self.cfg = cfg
        self.context: List[int] = []
        # ngram tuple -> up to two most recent end positions, ascending
        self._table: Dict[Tuple[int, ...], List[int]] = {}
        self.drafts = 0            # propose() calls
        self.draft_hits = 0        # propose() calls that returned tokens
        self.proposed_total = 0    # tokens proposed across all drafts
        self.accepted_total = 0    # tokens accepted across all drafts
        for t in prompt:
            self.push(int(t))

    def push(self, token: int):
        """Append one context token (prompt feed or an accepted emit)."""
        self.context.append(int(token))
        end = len(self.context)
        for n in range(self.cfg.ngram_min, self.cfg.ngram_max + 1):
            if n > end:
                break
            key = tuple(self.context[end - n:end])
            slots = self._table.get(key)
            if slots is None:
                self._table[key] = [end]
            else:
                if len(slots) == 2:
                    slots.pop(0)
                slots.append(end)

    def propose(self, k: int) -> List[int]:
        """Draft ``k`` tokens continuing the current context.

        Tries suffix lengths from ``ngram_max`` down to ``ngram_min``; the
        first n-gram with an earlier occurrence wins and the draft is the
        tokens that followed it.  When the match sits fewer than ``k`` tokens
        from the context end — the common case once greedy decode settles
        into a cycle, where the nearest match is exactly one period back —
        the draft wraps around the matched continuation (period
        ``length - j``), extrapolating the cycle.  The verify forward scores
        a fixed ``draft_k + 1`` lanes either way, so over-proposing is free:
        wrong wrapped tokens are simply rejected.  Returns ``[]`` on a miss
        (the tick falls back to plain one-token decode for this slot).
        """
        self.drafts += 1
        ctx = self.context
        length = len(ctx)
        if k < 1 or length == 0:
            return []
        for n in range(min(self.cfg.ngram_max, length), self.cfg.ngram_min - 1, -1):
            key = tuple(ctx[length - n:length])
            positions = self._table.get(key)
            if not positions:
                continue
            # skip the self-match: the current suffix registered itself at
            # end position == length when its last token was pushed
            j: Optional[int] = None
            for cand in reversed(positions):
                if cand < length:
                    j = cand
                    break
            if j is None:
                continue
            period = length - j
            draft = [ctx[j + (i % period)] for i in range(k)]
            self.draft_hits += 1
            self.proposed_total += len(draft)
            return draft
        return []

    def observe_accept(self, n_accepted: int):
        """Record how many of the last draft's tokens the verify kept."""
        self.accepted_total += int(n_accepted)

    @property
    def hit_rate(self) -> float:
        """Fraction of propose() calls that produced a non-empty draft."""
        return self.draft_hits / self.drafts if self.drafts else 0.0


def draft_budget(draft_k: int, max_new_tokens: int, emitted: int) -> int:
    """Draft tokens scorable this tick without outrunning the request.

    A verify with ``k`` draft tokens can emit up to ``k + 1`` tokens and
    writes cache rows up to ``pos + k``; capping ``k`` at
    ``max_new_tokens - emitted - 1`` keeps both within the request's budget
    and its page reservation (``rows = prompt + max_new - 1``), so the
    boundary truncation IS the OOM-safety argument — no write can ever land
    past the reserved rows.
    """
    return max(0, min(int(draft_k), int(max_new_tokens) - int(emitted) - 1))


def accept_length(proposed: Sequence[int], outputs: Sequence[int]) -> int:
    """Longest accepted prefix of ``proposed`` under greedy verification.

    ``outputs[j]`` is the model's greedy next-token at position ``pos + j``
    — lane 0's input is the slot's last real token, lane ``j >= 1``'s input
    is ``proposed[j - 1]``.  A draft token is accepted while it equals the
    model's own choice at that position, so the emitted span is
    ``outputs[: a + 1]``: the ``a`` accepted draft tokens (which equal
    ``outputs[:a]``) plus the model's bonus token ``outputs[a]``.  This is
    exactly the sequential greedy stream, which is what makes speculative
    greedy decode bit-identical to plain decode.
    """
    a = 0
    limit = min(len(proposed), len(outputs) - 1)
    while a < limit and int(proposed[a]) == int(outputs[a]):
        a += 1
    return a


@dataclass
class SpecStats:
    """Service-level speculation counters (aggregated across slots)."""

    verify_steps: int = 0          # verify forwards executed
    plain_steps: int = 0           # ticks that fell back to plain decode
    tokens_emitted: int = 0        # tokens emitted by verify steps
    tokens_proposed: int = 0       # draft tokens scored by verify steps
    tokens_accepted: int = 0       # draft tokens accepted
    drafts: int = 0                # per-slot propose() calls
    draft_hits: int = 0            # ... that returned a non-empty draft
    rejects: int = 0               # verifies that truncated a draft
    slot_lanes: int = 0            # slot-lanes ridden on verify steps
    per_slot: Dict[int, int] = field(default_factory=dict)

    def accepted_per_step(self) -> float:
        """Mean tokens emitted per verify step (> 1 means speculation pays)."""
        return self.tokens_emitted / self.verify_steps if self.verify_steps else 0.0

    def acceptance_rate(self) -> float:
        """Fraction of proposed draft tokens the verify accepted."""
        return self.tokens_accepted / self.tokens_proposed if self.tokens_proposed else 0.0

    def hit_rate(self) -> float:
        """Fraction of propose() calls that produced a draft."""
        return self.draft_hits / self.drafts if self.drafts else 0.0

    def metrics(self, prefix: str = "spec_") -> Dict[str, float]:
        """Flat metrics dict merged into the service scrape."""
        return {
            f"{prefix}verify_steps": float(self.verify_steps),
            f"{prefix}plain_steps": float(self.plain_steps),
            f"{prefix}tokens_emitted": float(self.tokens_emitted),
            f"{prefix}tokens_proposed": float(self.tokens_proposed),
            f"{prefix}tokens_accepted": float(self.tokens_accepted),
            f"{prefix}rejects": float(self.rejects),
            f"{prefix}accepted_tokens": self.accepted_per_step(),
            f"{prefix}acceptance_rate": self.acceptance_rate(),
            f"{prefix}draft_hit_rate": self.hit_rate(),
        }
