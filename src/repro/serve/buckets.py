"""Shape buckets + admission policy for the serving micro-batcher.

Every distinct batch size the engine sees is one compiled XLA executable, so
requests are coalesced into a bounded geometric ladder of batch buckets
(each a multiple of the Pallas sublane tile, so the padded shapes are
exactly the tile boundaries ``repro.tune`` enumerates).  A request batch of
n rows is padded up to ``bucket_for(n)`` rows and the padding sliced off the
result — the compile cache can hold at most ``len(bucket_sizes(policy))``
variants, all pre-warmable offline (``repro.tune.cli --serve`` /
``ServeEngine.warmup``).
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

from repro.kernels.pallas_utils import SUBLANE, next_multiple


@dataclasses.dataclass(frozen=True)
class BucketPolicy:
    """Admission policy of the dynamic micro-batcher.

    max_batch:    largest bucket (requests per dispatch cap)
    max_wait_ms:  latency budget — after the first queued request, dispatch
                  no later than this even if the bucket is not full
    max_queue:    backpressure bound — ``submit`` refuses beyond this depth
    align:        bucket granularity; defaults to the f32 sublane tile (8)
                  so padded batches land on the tuned tile boundaries.  Under
                  a mesh it must also be a multiple of the data-axis size.
    """

    max_batch: int = 64
    max_wait_ms: float = 2.0
    max_queue: int = 1024
    align: int = SUBLANE

    def validate(self) -> "BucketPolicy":
        """Sanity-check the knobs; returns self for chaining."""
        assert self.max_batch >= 1 and self.align >= 1, (self.max_batch, self.align)
        assert self.max_wait_ms >= 0.0, self.max_wait_ms
        assert self.max_queue >= 1, self.max_queue
        return self


def bucket_sizes(policy: BucketPolicy) -> Tuple[int, ...]:
    """The geometric ladder of batch buckets: align, 2*align, ... >= max_batch."""
    policy.validate()
    sizes: List[int] = []
    b = policy.align
    while b < policy.max_batch:
        sizes.append(b)
        b *= 2
    sizes.append(next_multiple(policy.max_batch, policy.align))
    return tuple(sizes)


def bucket_for(n: int, policy: BucketPolicy) -> int:
    """Smallest bucket holding n rows (n is clamped to max_batch upstream)."""
    assert n >= 1, n
    for b in bucket_sizes(policy):
        if b >= n:
            return b
    return bucket_sizes(policy)[-1]


def bucket_shapes(policy: BucketPolicy, d: int) -> List[Tuple[int, int]]:
    """(bucket, d) pairs — the pre-tune / warmup job list for one width."""
    return [(b, d) for b in bucket_sizes(policy)]
