"""Serve CLI: load-generate against the embedding service and print the
scrape metrics.

    # reduced end-to-end smoke (CI): naive vs micro-batched + probes
    PYTHONPATH=src python -m repro.serve.cli --smoke

    # bigger sweep, explicit knobs
    PYTHONPATH=src python -m repro.serve.cli --requests 1024 --d 2048 \
        --max-batch 64 --max-wait-ms 2

    # token-model serving demo (prefill/decode path, shared helpers)
    PYTHONPATH=src python -m repro.serve.cli --lm-arch rwkv6-3b

    # continuous batching vs whole-request generate + probe oracle gate (CI)
    PYTHONPATH=src python -m repro.serve.cli --smoke --lm-arch gemma2-2b --continuous

``--pretune`` warms the repro.tune cache for the serve bucket shapes first —
the same job list ``python -m repro.tune.cli --serve`` persists offline.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.serve.buckets import BucketPolicy, bucket_sizes


def _build_engine(args):
    import jax

    from repro.decorr.config import DecorrConfig
    from repro.serve.engine import ServeEngine
    from repro.serve.probes import DecorrProbe
    from repro.train.ssl import SSLModelConfig, init_ssl_params

    model = SSLModelConfig(
        input_dim=args.input_dim,
        backbone_widths=(args.backbone,),
        projector_widths=(args.d, args.d),
    )
    policy = BucketPolicy(
        max_batch=args.max_batch,
        max_wait_ms=args.max_wait_ms,
        max_queue=args.max_queue,
    )

    def engine_fn():
        if args.ckpt_dir:
            return ServeEngine.from_checkpoint(args.ckpt_dir, model, policy=policy)
        params = init_ssl_params(jax.random.PRNGKey(args.seed), model)
        return ServeEngine(model, params, policy=policy)

    probe_cfg = DecorrConfig(
        style=args.probe_style, reg="sum", q=2, block_size=args.probe_block
    )
    return model, policy, engine_fn, lambda: DecorrProbe(probe_cfg)


def _run_embedding(args) -> int:
    from repro.serve.loadgen import LoadConfig, compare_policies

    model, policy, engine_fn, probe_fn = _build_engine(args)

    if args.pretune != "off":
        from repro import tune
        from repro.tune.cli import jobs_for

        n_jobs = 0
        for b in bucket_sizes(policy):
            _, jobs = jobs_for(
                b, args.d, block_size=args.probe_block, forward_only=True,
                mode=args.pretune, persist=False,
            )
            n_jobs += 1 + len(jobs)
            for kernel, shape in jobs:
                tune.tune(kernel, shape, mode=args.pretune, persist=False)
        print(f"[serve] pre-tuned {n_jobs} forward bucket shapes ({args.pretune})")

    load = LoadConfig(
        n_requests=args.requests,
        input_dim=args.input_dim,
        arrival_rps=args.arrival_rps,
        seed=args.seed,
    )
    print(
        f"[serve] d={args.d} requests={load.n_requests} "
        f"buckets={list(bucket_sizes(policy))} max_wait={policy.max_wait_ms}ms"
    )
    report = compare_policies(engine_fn, load, policy, probe_fn=probe_fn)
    for name in ("naive", "microbatch"):
        r = report[name]
        print(
            f"[serve] {name:>10}: p50={r['p50_ms']:.2f}ms p99={r['p99_ms']:.2f}ms "
            f"throughput={r['throughput_rps']:.0f} req/s"
        )
    g = report["gate"]
    print(f"[serve] micro-batching speedup: {g['speedup']:.2f}x "
          f"(beats naive: {g['microbatch_beats_naive']})")
    if args.json:
        print(json.dumps(report, indent=2, sort_keys=True, default=float))
    else:
        m = report["service_metrics"]
        probes = {k: round(v, 6) for k, v in m.items() if k.startswith("decorr_")}
        print(f"[serve] probe metrics: {probes}")
        print(f"[serve] heartbeat stale={m['heartbeat_stale']:.0f} "
              f"missed={m['heartbeat_missed_events']:.0f}")
    return 0 if g["microbatch_beats_naive"] or not args.gate else 1


def _run_lm(args) -> int:
    import jax

    from repro.configs import get_config
    from repro.models import init_params
    from repro.serve.common import make_prompt, timed_generate
    from repro.serve.engine import LMServeEngine

    cfg = get_config(args.lm_arch).reduced()
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    if args.continuous:
        return _run_lm_continuous(args, cfg, params)
    engine = LMServeEngine(cfg)
    prompt = make_prompt(cfg, jax.random.PRNGKey(args.seed + 1), args.max_batch, args.prompt_len)
    out, stats = timed_generate(
        params, cfg, prompt, args.new_tokens, steps=engine.steps
    )
    print(
        f"[serve] lm arch={cfg.name} (reduced): batch={prompt.shape[0]} "
        f"prompt={args.prompt_len} -> {args.new_tokens} tokens in "
        f"{stats['seconds']:.2f}s ({stats['tok_per_s']:.1f} tok/s)"
    )
    print("sample:", out[0].tolist()[:8])
    return 0


def _run_lm_continuous(args, cfg, params) -> int:
    """Continuous batching vs whole-request generate on a mixed-length
    workload, with the in-flight decorrelation probe replayed against the
    offline oracle."""
    from repro.decorr.config import DecorrConfig
    from repro.serve.loadgen import LMLoadConfig, compare_lm_policies
    from repro.serve.probes import DecorrProbe

    load = LMLoadConfig(n_requests=args.requests, seed=args.seed)
    probe_cfg = DecorrConfig(style=args.probe_style, reg="sum", q=2, block_size=args.probe_block)
    report = compare_lm_policies(
        cfg,
        params,
        load,
        n_slots=args.slots,
        probe_fn=lambda: DecorrProbe(probe_cfg),
        record_probe_rows=True,
    )
    for name in ("whole_request", "continuous"):
        r = report[name]
        print(
            f"[serve] {name:>14}: p50={r['p50_ms']:.2f}ms p99={r['p99_ms']:.2f}ms "
            f"{r['tok_per_s']:.0f} tok/s ({r['requests']:.0f} requests)"
        )
    g = report["gate"]
    m = report["service_metrics"]
    print(
        f"[serve] continuous-batching speedup: {g['speedup']:.2f}x "
        f"(beats whole-request: {g['continuous_beats_whole_request']}, "
        f"token mismatches: {g['token_mismatches']:.0f})"
    )
    print(
        f"[serve] occupancy={m['slots_occupancy']:.2f} "
        f"ttft_p50={m['ttft_p50_ms']:.2f}ms probe_steps={m.get('decorr_probe_steps', 0):.0f} "
        f"probe_oracle_rel_err={g.get('probe_oracle_rel_err', float('nan')):.2e}"
    )
    if args.json:
        print(json.dumps(report, indent=2, sort_keys=True, default=float))
    # fail-closed like benchmarks/compare.py: a probe that never fired a
    # full window means the oracle check did NOT run — that fails the gate
    probe_err = g.get("probe_oracle_rel_err")
    ok = (
        g["continuous_beats_whole_request"]
        and g["token_mismatches"] == 0
        and probe_err is not None
        and probe_err < 1e-3
    )
    return 0 if ok or not args.gate else 1


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="repro.serve.cli", description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="reduced config + few requests (CI smoke; implies --gate)")
    p.add_argument("--requests", type=int, default=512)
    p.add_argument("--input-dim", type=int, default=128)
    p.add_argument("--backbone", type=int, default=256)
    p.add_argument("--d", type=int, default=512, help="projector/embedding width")
    p.add_argument("--max-batch", type=int, default=32)
    p.add_argument("--max-wait-ms", type=float, default=2.0)
    p.add_argument("--max-queue", type=int, default=4096)
    p.add_argument("--arrival-rps", type=float, default=None,
                   help="open-loop arrival rate (default: closed-loop burst)")
    p.add_argument("--ckpt-dir", default=None,
                   help="serve params from a repro.checkpoint directory")
    p.add_argument("--probe-style", default="vic", choices=["bt", "vic"])
    p.add_argument("--probe-block", type=int, default=None)
    p.add_argument("--pretune", default="off",
                   choices=["off", "analytic", "dry", "measure"],
                   help="warm the repro.tune cache for the serve buckets first")
    p.add_argument("--gate", action="store_true",
                   help="exit 1 unless micro-batched throughput beats naive")
    p.add_argument("--json", action="store_true", help="dump the full report as JSON")
    p.add_argument("--seed", type=int, default=0)
    # token-model demo path
    p.add_argument("--lm-arch", default=None,
                   help="serve a token model instead (e.g. rwkv6-3b, gemma2-2b)")
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--new-tokens", type=int, default=8)
    p.add_argument("--continuous", action="store_true",
                   help="with --lm-arch: continuous batching vs whole-request "
                        "generate on a mixed-length workload")
    p.add_argument("--slots", type=int, default=8,
                   help="continuous-batching decode slot pool size")
    args = p.parse_args(argv)

    if args.smoke:
        args.requests = min(args.requests, 192)
        args.input_dim, args.backbone, args.d = 32, 64, 256
        args.max_batch = min(args.max_batch, 32)
        args.gate = True
        if args.lm_arch and args.continuous:
            args.requests = min(args.requests, 24)

    if args.lm_arch:
        return _run_lm(args)
    return _run_embedding(args)


if __name__ == "__main__":
    sys.exit(main())
