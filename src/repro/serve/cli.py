"""Serve CLI: load-generate against the embedding service and print the
scrape metrics.

    # reduced end-to-end smoke (CI): naive vs micro-batched + probes
    PYTHONPATH=src python -m repro.serve.cli --smoke

    # bigger sweep, explicit knobs
    PYTHONPATH=src python -m repro.serve.cli --requests 1024 --d 2048 \
        --max-batch 64 --max-wait-ms 2

    # token-model serving demo (prefill/decode path, shared helpers)
    PYTHONPATH=src python -m repro.serve.cli --lm-arch rwkv6-3b

    # continuous batching vs whole-request generate + probe oracle gate (CI)
    PYTHONPATH=src python -m repro.serve.cli --smoke --lm-arch gemma2-2b --continuous

    # paged KV cache + chunked prefill + sampled decoding smoke (CI)
    PYTHONPATH=src python -m repro.serve.cli --smoke --lm-arch gemma2-2b \
        --continuous --paged --block-size 16 --prefill-chunk 16 \
        --temperature 0.8 --top-k 8

    # + the prefix-sharing radix cache gate (warm TTFT / peak pages / tokens)
    PYTHONPATH=src python -m repro.serve.cli --smoke --lm-arch gemma2-2b \
        --continuous --paged --block-size 16 --prefill-chunk 16 --prefix-cache

    # + the speculative-decoding gate (bit-identical tokens, accepted/step)
    PYTHONPATH=src python -m repro.serve.cli --smoke --lm-arch gemma2-2b \
        --continuous --paged --block-size 16 --speculative --draft-k 4

    # + the fabric failover gate (kill one of N replicas mid-decode; the
    # requeued requests must stay bit-identical to a 1-replica run)
    PYTHONPATH=src python -m repro.serve.cli --smoke --lm-arch gemma2-2b \
        --continuous --fabric --replicas 2

``--pretune`` warms the repro.tune cache for the serve bucket shapes first —
the same job list ``python -m repro.tune.cli --serve`` persists offline.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.serve.buckets import BucketPolicy, bucket_sizes


def _build_obs(args):
    """The CLI's telemetry bundle: default serve alert rules unless
    ``--alerts`` points at a JSON rule list (file path or inline)."""
    from repro.obs import AlertManager, Obs, default_serve_rules

    alerts = (
        AlertManager.from_config(args.alerts)
        if args.alerts
        else AlertManager(default_serve_rules())
    )
    return Obs(alerts=alerts)


def _finish_obs(args, obs, report_metrics) -> bool:
    """Post-run telemetry outputs: self-scrape the HTTP endpoint
    (``--metrics-port``; asserts every legacy ``metrics()`` key survived into
    the exposition), dump the Chrome trace (``--trace-out``), the metrics
    exposition text (``--metrics-out``) and the flight recorder
    (``--flight-out``)."""
    from repro.obs.registry import sanitize_name

    ok = True
    exposition = None
    if args.metrics_port is not None:
        import urllib.request

        server = obs.start_server(port=args.metrics_port)
        text = urllib.request.urlopen(f"{server.url}/metrics", timeout=10).read().decode()
        exposition = text
        exposed = {
            line.split("{")[0].split(" ")[0]
            for line in text.splitlines()
            if line and not line.startswith("#")
        }
        missing = []
        for k in report_metrics:
            s = sanitize_name(k)
            if s in exposed:
                continue
            # per-name heartbeat ages are claimed by the labelled family
            # (heartbeat_age_s{name=...}); the legacy name-suffixed keys only
            # live in the metrics() dict view
            if s.startswith("heartbeat_age_s_") and "heartbeat_age_s" in exposed:
                continue
            missing.append(k)
        print(
            f"[obs] scrape {server.url}/metrics: {len(text.splitlines())} lines, "
            f"{len(exposed)} series, active_alerts={obs.alerts.active()}"
        )
        if missing:
            print(f"[obs] MISSING from exposition: {missing[:8]}")
            ok = False
        server.stop()
    top = obs.perf.snapshot(top_k=3)
    if top:
        slowest = ", ".join(
            f"{r['executable']} ({r['calls']}x, {r['total_s']:.3f}s"
            + (f", util={r['roofline_utilization']:.3g}" if "roofline_utilization" in r else "")
            + ")"
            for r in top
        )
        print(f"[obs] slowest executables: {slowest}")
    if args.trace_out:
        obs.tracer.write(args.trace_out)
        print(f"[obs] trace: {len(obs.tracer)} events -> {args.trace_out}")
    if getattr(args, "metrics_out", None):
        if exposition is None:
            exposition = obs.scrape()
        with open(args.metrics_out, "w") as f:
            f.write(exposition)
        print(f"[obs] exposition -> {args.metrics_out}")
    if getattr(args, "flight_out", None):
        obs.recorder.dump_json(args.flight_out)
        print(f"[obs] flight recorder: {len(obs.recorder)} events -> {args.flight_out}")
    return ok


def _build_engine(args):
    import jax

    from repro.decorr.config import DecorrConfig
    from repro.serve.engine import ServeEngine
    from repro.serve.probes import DecorrProbe
    from repro.train.ssl import SSLModelConfig, init_ssl_params

    model = SSLModelConfig(
        input_dim=args.input_dim,
        backbone_widths=(args.backbone,),
        projector_widths=(args.d, args.d),
    )
    policy = BucketPolicy(
        max_batch=args.max_batch,
        max_wait_ms=args.max_wait_ms,
        max_queue=args.max_queue,
    )

    def engine_fn():
        if args.ckpt_dir:
            return ServeEngine.from_checkpoint(args.ckpt_dir, model, policy=policy)
        params = init_ssl_params(jax.random.PRNGKey(args.seed), model)
        return ServeEngine(model, params, policy=policy)

    probe_cfg = DecorrConfig(
        style=args.probe_style, reg="sum", q=2, block_size=args.probe_block
    )
    return model, policy, engine_fn, lambda: DecorrProbe(probe_cfg)


def _run_embedding(args) -> int:
    from repro.serve.loadgen import LoadConfig, compare_policies

    model, policy, engine_fn, probe_fn = _build_engine(args)

    if args.pretune != "off":
        from repro import tune
        from repro.tune.cli import jobs_for

        n_jobs = 0
        for b in bucket_sizes(policy):
            _, jobs = jobs_for(
                b, args.d, block_size=args.probe_block, forward_only=True,
                mode=args.pretune, persist=False,
            )
            n_jobs += 1 + len(jobs)
            for kernel, shape in jobs:
                tune.tune(kernel, shape, mode=args.pretune, persist=False)
        print(f"[serve] pre-tuned {n_jobs} forward bucket shapes ({args.pretune})")

    load = LoadConfig(
        n_requests=args.requests,
        input_dim=args.input_dim,
        arrival_rps=args.arrival_rps,
        seed=args.seed,
    )
    print(
        f"[serve] d={args.d} requests={load.n_requests} "
        f"buckets={list(bucket_sizes(policy))} max_wait={policy.max_wait_ms}ms"
    )
    obs = _build_obs(args)
    if args.profile_dir:
        obs.profiler.start(args.profile_dir)
    report = compare_policies(engine_fn, load, policy, probe_fn=probe_fn, obs=obs)
    if args.profile_dir and obs.profiler.stop():
        print(f"[obs] profiler trace -> {args.profile_dir}")
    for name in ("naive", "microbatch"):
        r = report[name]
        print(
            f"[serve] {name:>10}: p50={r['p50_ms']:.2f}ms p99={r['p99_ms']:.2f}ms "
            f"throughput={r['throughput_rps']:.0f} req/s"
        )
    g = report["gate"]
    print(f"[serve] micro-batching speedup: {g['speedup']:.2f}x "
          f"(beats naive: {g['microbatch_beats_naive']})")
    if args.json:
        print(json.dumps(report, indent=2, sort_keys=True, default=float))
    else:
        m = report["service_metrics"]
        probes = {k: round(v, 6) for k, v in m.items() if k.startswith("decorr_")}
        print(f"[serve] probe metrics: {probes}")
        print(f"[serve] heartbeat stale={m['heartbeat_stale']:.0f} "
              f"missed={m['heartbeat_missed_events']:.0f}")
    obs_ok = _finish_obs(args, obs, report["service_metrics"])
    ok = g["microbatch_beats_naive"] and obs_ok
    return 0 if ok or not args.gate else 1


def _run_lm(args) -> int:
    import jax

    from repro.configs import get_config
    from repro.models import init_params
    from repro.serve.common import make_prompt, timed_generate
    from repro.serve.engine import LMServeEngine

    cfg = get_config(args.lm_arch).reduced()
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    if args.continuous:
        return _run_lm_continuous(args, cfg, params)
    engine = LMServeEngine(cfg)
    prompt = make_prompt(cfg, jax.random.PRNGKey(args.seed + 1), args.max_batch, args.prompt_len)
    out, stats = timed_generate(
        params, cfg, prompt, args.new_tokens, steps=engine.steps
    )
    print(
        f"[serve] lm arch={cfg.name} (reduced): batch={prompt.shape[0]} "
        f"prompt={args.prompt_len} -> {args.new_tokens} tokens in "
        f"{stats['seconds']:.2f}s ({stats['tok_per_s']:.1f} tok/s)"
    )
    print("sample:", out[0].tolist()[:8])
    return 0


def _run_lm_continuous(args, cfg, params) -> int:
    """Continuous batching vs whole-request generate on a mixed-length
    workload, with the in-flight decorrelation probe replayed against the
    offline oracle.  ``--paged`` routes the slot pool through the block-table
    KV cache (page size from ``--block-size`` or the repro.tune winner) and
    additionally gates paged-vs-dense peak cache bytes; ``--temperature`` /
    ``--top-k`` run a sampled demo batch after the greedy gates."""
    from repro.decorr.config import DecorrConfig
    from repro.serve.loadgen import LMLoadConfig, compare_lm_policies
    from repro.serve.probes import DecorrProbe

    engine_kw = {}
    if args.paged:
        # no prefill_chunk here: this comparison hard-gates BIT-identical
        # tokens vs the whole-request oracle, and chunked prefill is only
        # argmax-stable (different prefill einsum shapes) — chunking is
        # exercised in _gate_paged's report-only pass instead
        engine_kw = dict(paged=True, page_size=args.block_size)
    load = LMLoadConfig(n_requests=args.requests, seed=args.seed)
    probe_cfg = DecorrConfig(style=args.probe_style, reg="sum", q=2, block_size=args.probe_block)
    obs = _build_obs(args)
    if args.profile_dir:
        obs.profiler.start(args.profile_dir)
    report = compare_lm_policies(
        cfg,
        params,
        load,
        n_slots=args.slots,
        probe_fn=lambda: DecorrProbe(probe_cfg),
        record_probe_rows=True,
        engine_kw=engine_kw,
        obs=obs,
    )
    if args.profile_dir and obs.profiler.stop():
        print(f"[obs] profiler trace -> {args.profile_dir}")
    for name in ("whole_request", "continuous"):
        r = report[name]
        print(
            f"[serve] {name:>14}: p50={r['p50_ms']:.2f}ms p99={r['p99_ms']:.2f}ms "
            f"{r['tok_per_s']:.0f} tok/s ({r['requests']:.0f} requests)"
        )
    g = report["gate"]
    m = report["service_metrics"]
    print(
        f"[serve] continuous-batching speedup: {g['speedup']:.2f}x "
        f"(beats whole-request: {g['continuous_beats_whole_request']}, "
        f"token mismatches: {g['token_mismatches']:.0f})"
    )
    print(
        f"[serve] occupancy={m['slots_occupancy']:.2f} "
        f"ttft_p50={m['ttft_p50_ms']:.2f}ms probe_steps={m.get('decorr_probe_steps', 0):.0f} "
        f"probe_oracle_rel_err={g.get('probe_oracle_rel_err', float('nan')):.2e}"
    )
    paged_ok = True
    if args.paged:
        paged_ok = _gate_paged(args, cfg, params, load)
    prefix_ok = True
    if args.prefix_cache:
        prefix_ok = _gate_prefix(args, cfg, params)
    spec_ok = True
    if args.speculative:
        spec_ok = _gate_speculative(args, cfg, params)
    fabric_ok = True
    if args.fabric:
        fabric_ok = _gate_fabric(args, cfg, params)
    if args.temperature or args.top_k:
        _demo_sampling(args, cfg, params)
    if args.json:
        print(json.dumps(report, indent=2, sort_keys=True, default=float))
    obs_ok = _finish_obs(args, obs, report["service_metrics"])
    # fail-closed like benchmarks/compare.py: a probe that never fired a
    # full window means the oracle check did NOT run — that fails the gate
    probe_err = g.get("probe_oracle_rel_err")
    ok = (
        g["continuous_beats_whole_request"]
        and g["token_mismatches"] == 0
        and probe_err is not None
        and probe_err < 1e-3
        and paged_ok
        and prefix_ok
        and spec_ok
        and fabric_ok
        and obs_ok
    )
    return 0 if ok or not args.gate else 1


def _gate_paged(args, cfg, params, load) -> bool:
    """Dense vs paged at the same workload: identical tokens + peak cache
    bytes strictly below the dense pool's permanent reservation."""
    from repro.serve.loadgen import compare_paged_dense

    rep = compare_paged_dense(
        cfg, params, load,
        n_slots=args.slots,
        page_size=args.block_size or 16,
        prefill_chunk=args.prefill_chunk,
    )
    g = rep["gate"]
    print(
        f"[serve] paged vs dense: peak_cache_bytes_ratio={g['peak_cache_bytes_ratio']:.3f} "
        f"(paged<dense: {g['paged_peak_lt_dense']}, "
        f"token mismatches: {g['token_mismatches']:.0f}, "
        f"tok/s ratio {g['tok_per_s_ratio']:.2f})"
    )
    return bool(g["paged_peak_lt_dense"]) and g["token_mismatches"] == 0


def _gate_prefix(args, cfg, params) -> bool:
    """Prefix sharing on vs off over the same paged chunk-all engine on a
    shared-prefix fan-out workload: bit-identical tokens, warm-phase TTFT and
    peak pool pages both strictly below the unshared run.  The engine shape
    is pinned (4 slots, page 16, chunk 8) to match the workload defaults —
    this is a regression gate over a known-stressing shape (the chunk must
    halve the page or copy-on-write never triggers), not a knob explorer."""
    from repro.serve.loadgen import SharedPrefixLoadConfig, compare_prefix_sharing

    load = SharedPrefixLoadConfig(seed=args.seed)
    rep = compare_prefix_sharing(
        cfg, params, load, n_slots=4, page_size=16, prefill_chunk=8,
    )
    g = rep["gate"]
    print(
        f"[serve] prefix cache: hit_rate={g['prefix_hit_rate']:.2f} "
        f"warm_ttft_ratio={g['warm_ttft_ratio']:.3f} "
        f"peak_pages_ratio={g['peak_pages_ratio']:.3f} "
        f"(token mismatches: {g['token_mismatches']:.0f})"
    )
    return (
        g["token_mismatches"] == 0
        and bool(g["warm_ttft_lt_unshared"])
        and bool(g["peak_pages_lt_unshared"])
        and g["prefix_hit_rate"] > 0
    )


def _gate_speculative(args, cfg, params) -> bool:
    """Plain paged vs self-drafting speculative decode on a decode-heavy
    workload: bit-identical greedy tokens (the hard gate — a smoke-sized run
    is too short to gate CPU wall clock) and more than one token emitted per
    verify slot-lane, i.e. the drafter is actually accepting tokens."""
    from repro.serve.loadgen import LMLoadConfig, compare_speculative

    load = LMLoadConfig(
        n_requests=min(args.requests, 16),
        prompt_lens=(4, 6, 8), new_tokens=(24, 32), seed=args.seed,
    )
    rep = compare_speculative(
        cfg, params, load,
        n_slots=args.slots,
        page_size=args.block_size or 16,
        draft_k=args.draft_k,
    )
    g = rep["gate"]
    print(
        f"[serve] speculative: accepted/step={g['accepted_tokens_per_step']:.2f} "
        f"tokens/lane={g['tokens_per_lane']:.2f} "
        f"hit_rate={g['draft_hit_rate']:.2f} "
        f"tok/s ratio {g['tok_per_s_ratio']:.2f} "
        f"(token mismatches: {g['token_mismatches']:.0f})"
    )
    return g["token_mismatches"] == 0 and g["tokens_per_lane"] > 1


def _gate_fabric(args, cfg, params) -> bool:
    """Kill-one-replica failover on a synchronous N-replica fabric (fake
    clock: the smoke never sleeps).  The hard gate is LOSSLESS determinism:
    every request — including the ones stranded on the killed replica and
    requeued — must emit the exact greedy token stream of a 1-replica run,
    and the kill must actually strand work (``requeued > 0``).  On failure
    every replica's flight recorder is dumped to
    ``flightrec_replica_<name>.json`` (CI uploads ``flightrec_*.json``)."""
    import numpy as np

    from repro.obs import Obs
    from repro.serve.fabric import FabricConfig
    from repro.serve.loadgen import FabricLoadConfig, LMLoadConfig, make_lm_fabric

    load = FabricLoadConfig(
        lm=LMLoadConfig(
            n_requests=min(args.requests, 12),
            prompt_lens=(4, 8, 14),
            new_tokens=(8, 16),
            seed=args.seed,
        )
    )
    kw = dict(n_slots=args.slots, page_size=args.block_size or 16)

    def submit_all(fab):
        stream = load.lm.request_stream(cfg.vocab_size)
        return [fab.submit_lm(tok, mn) for tok, mn in stream]

    oracle_fab, _ = make_lm_fabric(
        cfg, params, FabricConfig(replicas=1, heartbeat_timeout_s=5.0), load, **kw
    )
    ofuts = submit_all(oracle_fab)
    oracle_fab.drain()
    oracle = [f.result(timeout=60) for f in ofuts]

    t = {"now": 0.0}
    fab_obs = Obs()
    fab, _ = make_lm_fabric(
        cfg, params,
        FabricConfig(replicas=args.replicas, heartbeat_timeout_s=5.0),
        load, obs=fab_obs, clock=lambda: t["now"], **kw,
    )
    futs = submit_all(fab)
    for _ in range(3):  # let every replica admit + decode a few ticks
        fab.step()
    fab.kill("r0")
    t["now"] += 10.0  # heartbeat goes stale; the next step drains r0
    fab.drain()
    outs = [f.result(timeout=60) for f in futs]
    mismatches = sum(
        1 for a, b in zip(oracle, outs) if not np.array_equal(a, b)
    )
    counts = fab_obs.recorder.counts()
    print(
        f"[serve] fabric: replicas={args.replicas} "
        f"requeued={fab.requeued_total} dead={fab.dead_total} "
        f"routes={counts.get('route', 0)} "
        f"(requeue token mismatches: {mismatches})"
    )
    ok = mismatches == 0 and fab.requeued_total > 0 and fab.dead_total == 1
    if not ok:
        fab_obs.recorder.dump_json("flightrec_fabric.json")
        for r in fab.replicas:
            if r.lm is not None:
                r.lm.obs.recorder.dump_json(f"flightrec_replica_{r.name}.json")
        print("[serve] fabric gate FAILED; flight dumps -> flightrec_fabric.json, "
              "flightrec_replica_*.json")
    return ok


def _demo_sampling(args, cfg, params):
    """A short sampled batch through the paged/dense pool: per-request
    temperature/top-k/seed, reproducibility printed for two replays."""
    from repro.serve.engine import ContinuousLMEngine
    from repro.serve.service import LMService

    import numpy as np

    def run():
        eng = ContinuousLMEngine(
            cfg, params, n_slots=args.slots, max_len=64, max_prompt_len=24,
            paged=args.paged, page_size=args.block_size if args.paged else None,
            sampling=True,
        )
        svc = LMService(eng)
        svc.warmup()
        rng = np.random.default_rng(args.seed)
        futs = [
            svc.submit(
                rng.integers(0, cfg.vocab_size, 8).astype(np.int32), 8,
                temperature=args.temperature or 0.0, top_k=args.top_k, seed=i,
            )
            for i in range(4)
        ]
        svc.drain()
        return [f.result(timeout=30).tolist() for f in futs]

    a, b = run(), run()
    print(
        f"[serve] sampled decode (T={args.temperature}, top_k={args.top_k}): "
        f"sample={a[0][:8]} reproducible={a == b}"
    )


def main(argv=None) -> int:
    """Argparse entry point (see the module docstring for usage)."""
    p = argparse.ArgumentParser(prog="repro.serve.cli", description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="reduced config + few requests (CI smoke; implies --gate)")
    p.add_argument("--requests", type=int, default=512)
    p.add_argument("--input-dim", type=int, default=128)
    p.add_argument("--backbone", type=int, default=256)
    p.add_argument("--d", type=int, default=512, help="projector/embedding width")
    p.add_argument("--max-batch", type=int, default=32)
    p.add_argument("--max-wait-ms", type=float, default=2.0)
    p.add_argument("--max-queue", type=int, default=4096)
    p.add_argument("--arrival-rps", type=float, default=None,
                   help="open-loop arrival rate (default: closed-loop burst)")
    p.add_argument("--ckpt-dir", default=None,
                   help="serve params from a repro.checkpoint directory")
    p.add_argument("--probe-style", default="vic", choices=["bt", "vic"])
    p.add_argument("--probe-block", type=int, default=None)
    p.add_argument("--pretune", default="off",
                   choices=["off", "analytic", "dry", "measure"],
                   help="warm the repro.tune cache for the serve buckets first")
    p.add_argument("--gate", action="store_true",
                   help="exit 1 unless micro-batched throughput beats naive")
    p.add_argument("--json", action="store_true", help="dump the full report as JSON")
    p.add_argument("--seed", type=int, default=0)
    # token-model demo path
    p.add_argument("--lm-arch", default=None,
                   help="serve a token model instead (e.g. rwkv6-3b, gemma2-2b)")
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--new-tokens", type=int, default=8)
    p.add_argument("--continuous", action="store_true",
                   help="with --lm-arch: continuous batching vs whole-request "
                        "generate on a mixed-length workload")
    p.add_argument("--slots", type=int, default=8,
                   help="continuous-batching decode slot pool size")
    p.add_argument("--paged", action="store_true",
                   help="with --continuous: paged (block-table) KV cache; also "
                        "gates paged peak cache bytes < dense")
    p.add_argument("--block-size", type=int, default=None,
                   help="KV page size in tokens (default: the repro.tune winner "
                        "for the pool shape, fragmentation-capped)")
    p.add_argument("--prefill-chunk", type=int, default=None,
                   help="with --paged: prefill long prompts N tokens per decode "
                        "tick instead of stalling the pool")
    p.add_argument("--speculative", action="store_true",
                   help="with --paged: also gate self-drafting speculative "
                        "decoding (bit-identical greedy tokens, more than one "
                        "token emitted per verify slot-lane)")
    p.add_argument("--draft-k", type=int, default=4,
                   help="speculative draft tokens proposed per verify tick")
    p.add_argument("--fabric", action="store_true",
                   help="with --continuous: also gate the replica-router "
                        "failover path (kill one replica mid-decode on a fake "
                        "clock; requeued requests must emit bit-identical "
                        "tokens to a 1-replica run)")
    p.add_argument("--replicas", type=int, default=2,
                   help="fabric size for --fabric")
    p.add_argument("--prefix-cache", action="store_true",
                   help="with --paged: also gate the prefix-sharing radix "
                        "cache (bit-identical tokens + warm TTFT and peak "
                        "pages strictly below the unshared paged run)")
    p.add_argument("--temperature", type=float, default=0.0,
                   help="run a sampled demo batch after the greedy gates "
                        "(0 = greedy only)")
    p.add_argument("--top-k", type=int, default=None,
                   help="restrict sampled decoding to the k highest logits")
    # telemetry (repro.obs)
    p.add_argument("--metrics-port", type=int, default=None,
                   help="serve /metrics over HTTP after the run and self-scrape "
                        "it (0 = ephemeral port); the gate fails if any legacy "
                        "metrics() key is missing from the exposition")
    p.add_argument("--trace-out", default=None,
                   help="write the Chrome trace_event JSON of the run here")
    p.add_argument("--metrics-out", default=None,
                   help="write the final Prometheus exposition text here "
                        "(CI failure artifact)")
    p.add_argument("--flight-out", default=None,
                   help="write the flight recorder's event ring as JSON here")
    p.add_argument("--profile-dir", default=None,
                   help="capture a jax.profiler trace of the run into this dir")
    p.add_argument("--alerts", default=None,
                   help="alert rules as a JSON file path or inline JSON list "
                        "(default: the built-in serve rules)")
    args = p.parse_args(argv)

    if args.fabric and not (args.lm_arch and args.continuous):
        p.error("--fabric routes continuous LM replicas; it requires "
                "--lm-arch and --continuous")
    if args.prefix_cache and not args.paged:
        p.error("--prefix-cache shares KV pages; it requires --paged")
    if args.speculative and not args.paged:
        p.error("--speculative verifies through scratch pages; it requires --paged")

    if args.smoke:
        args.requests = min(args.requests, 192)
        args.input_dim, args.backbone, args.d = 32, 64, 256
        args.max_batch = min(args.max_batch, 32)
        args.gate = True
        if args.lm_arch and args.continuous:
            args.requests = min(args.requests, 24)

    if args.lm_arch:
        return _run_lm(args)
    return _run_embedding(args)


if __name__ == "__main__":
    sys.exit(main())
