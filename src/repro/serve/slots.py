"""Decode-step-granular slot pool for continuous LM batching.

Whole-request serving (PR 3's ``LMServeEngine.generate``) holds the entire
batch until the *longest* request finishes: short requests pad out dead decode
steps and new arrivals wait for a full drain.  Continuous batching instead
gives the engine a fixed pool of N *slots*; every decode step runs all slots
batched, and any slot whose request retired (EOS / token budget) is handed
back and refilled from the queue on the very next step — admission happens at
decode-step granularity, not request granularity.

This module is the pure bookkeeping half (no jax): slot lifecycle
(free -> active -> retired -> free), per-slot decode positions
(``cache_lens``), last-emitted tokens (``last_tokens``), and occupancy
accounting for the scrape surface.  The tensor half — KV/SSM cache surgery,
the batched decode step — lives in ``repro.serve.engine.ContinuousLMEngine``
on top of ``repro.train.serve.insert_slot_state`` / ``make_decode_step``.

Slot lifecycle::

    admit(request)            # free slot claimed; prefill token already emitted
      ├─ step(): slot decodes one token per engine step, batched with the pool
      ├─ eos_id emitted OR max_new_tokens reached
    retire(slot)              # future completed, slot back on the free list
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.serve.sampling import SamplingParams, make_rng


@dataclasses.dataclass
class LMRequest:
    """One queued generation request (the batcher payload).

    ``tokens``: 1-D int prompt; ``max_new_tokens`` >= 1 caps generation;
    ``eos_id`` (optional) retires the request early when emitted;
    ``sampling`` (optional) carries the per-request temperature/top-k/seed —
    None means greedy through the argmax path.
    """

    tokens: np.ndarray
    max_new_tokens: int
    eos_id: Optional[int] = None
    sampling: Optional[SamplingParams] = None

    @property
    def prompt_len(self) -> int:
        """Prompt length in tokens."""
        return int(np.shape(self.tokens)[0])

    @property
    def rows_needed(self) -> int:
        """Cache rows the request can ever write: the prompt plus every
        generated token EXCEPT the last (it is emitted, never written)."""
        return self.prompt_len + self.max_new_tokens - 1


class ActiveSlot:
    """Bookkeeping for one in-flight request bound to a pool slot."""

    __slots__ = (
        "request", "future", "index", "pos", "last_token", "emitted", "t_admit",
        "rng", "prefill_pos", "draft",
    )

    def __init__(self, request: LMRequest, future, index: int, seq: int = 0):
        self.request = request
        self.future = future
        self.index = index
        # pos == the slot's cache_len for its next decode step: the position
        # the last emitted token gets WRITTEN at.  Prefill fills rows
        # [0, prompt_len) and emits the first token without writing it, so
        # after that emit pos == prompt_len (greedy_generate's `pos = s`).
        self.pos = request.prompt_len - 1
        self.last_token: int = 0
        self.emitted: List[int] = []
        self.t_admit: Optional[float] = None
        # per-request PRNG stream (None for greedy); the pool's admission
        # counter seeds requests that did not pin their own seed
        self.rng = make_rng(request.sampling, fallback_seed=seq)
        # chunked prefill progress: prompt tokens already written to the
        # cache.  >= prompt_len (or no chunking) means the slot is decoding.
        self.prefill_pos: int = request.prompt_len
        # speculative drafter (serve.spec.SlotDraft) when the engine runs
        # with speculation; duck-typed here so this module stays jax-free
        self.draft = None

    @property
    def prefilling(self) -> bool:
        """True while the prompt is still prefilling (chunked path)."""
        return self.prefill_pos < self.request.prompt_len

    def emit(self, token: int) -> bool:
        """Record one generated token; True when the request is finished."""
        self.emitted.append(int(token))
        self.last_token = int(token)
        self.pos += 1
        if self.draft is not None:
            self.draft.push(int(token))
        if self.request.eos_id is not None and int(token) == int(self.request.eos_id):
            return True
        return len(self.emitted) >= self.request.max_new_tokens


class SlotPool:
    """Fixed pool of decode slots with free-list admission and occupancy
    accounting.  Purely host-side state; index arrays (``cache_lens`` /
    ``last_tokens``) are what the engine feeds the batched decode step."""

    def __init__(self, n_slots: int, max_len: int):
        assert n_slots >= 1 and max_len >= 2, (n_slots, max_len)
        self.n_slots = int(n_slots)
        self.max_len = int(max_len)
        self._slots: List[Optional[ActiveSlot]] = [None] * n_slots
        self._free: List[int] = list(range(n_slots - 1, -1, -1))  # pop() -> slot 0 first
        # occupancy accounting: active-slot-steps / slot-steps since start
        self.steps = 0
        self.active_slot_steps = 0
        self.admitted_total = 0
        self.retired_total = 0

    # -- lifecycle ----------------------------------------------------------

    def free_slots(self) -> int:
        """Slots currently free."""
        return len(self._free)

    def active(self) -> List[ActiveSlot]:
        """The active slots, in pool order."""
        return [s for s in self._slots if s is not None]

    def active_indices(self) -> List[int]:
        """Indices of the active slots, ascending."""
        return [i for i, s in enumerate(self._slots) if s is not None]

    def decoding_indices(self) -> List[int]:
        """Active slots actually decoding this step (chunked prefill keeps a
        slot occupied but out of the batched decode until its prompt is in)."""
        return [i for i, s in enumerate(self._slots) if s is not None and not s.prefilling]

    def __getitem__(self, i: int) -> Optional[ActiveSlot]:
        return self._slots[i]

    def admit(self, request: LMRequest, future) -> ActiveSlot:
        """Claim a free slot for a request (caller guarantees capacity and
        that the request's written rows fit ``max_len``)."""
        if not self._free:
            raise RuntimeError("no free slot; check free_slots() before admit")
        # rows_needed, not prompt + max_new: the final emitted token is never
        # written, so a request that exactly fills the cache must be admitted
        need = request.rows_needed
        if need > self.max_len:
            raise ValueError(
                f"request needs {need} cache rows > pool max_len={self.max_len}"
            )
        slot = ActiveSlot(request, future, self._free.pop(), seq=self.admitted_total)
        self._slots[slot.index] = slot
        self.admitted_total += 1
        return slot

    def retire(self, index: int) -> ActiveSlot:
        """Free a slot and return its final state."""
        slot = self._slots[index]
        assert slot is not None, f"slot {index} is not active"
        self._slots[index] = None
        self._free.append(index)
        self.retired_total += 1
        return slot

    # -- batched decode inputs ----------------------------------------------

    def cache_lens(self) -> np.ndarray:
        """(N,) int32 per-slot decode positions (0 for free AND still-
        prefilling slots — their lane still computes, masked to a single
        valid row; output discarded and, in paged mode, the masked write
        lands on the sentinel page)."""
        return np.asarray(
            [0 if s is None or s.prefilling else s.pos for s in self._slots], np.int32
        )

    def last_tokens(self) -> np.ndarray:
        """(N,) int32 per-slot last emitted token (decode-step input)."""
        return np.asarray(
            [0 if s is None else s.last_token for s in self._slots], np.int32
        )

    # -- accounting ----------------------------------------------------------

    def observe_step(self):
        """Called once per engine decode step, BEFORE that step's
        retirements: counts the lanes that decoded a live request (slots
        still chunk-prefilling occupy a lane but do not decode)."""
        self.steps += 1
        self.active_slot_steps += len(self.decoding_indices())

    def occupancy(self) -> float:
        """Mean fraction of slots doing useful work per decode step."""
        denom = self.steps * self.n_slots
        return self.active_slot_steps / denom if denom else 0.0

    def metrics(self, prefix: str = "slots_") -> dict:
        """Flat gauge dict of pool occupancy and throughput counters."""
        return {
            f"{prefix}total": float(self.n_slots),
            f"{prefix}active": float(self.n_slots - len(self._free)),
            f"{prefix}occupancy": self.occupancy(),
            f"{prefix}admitted_total": float(self.admitted_total),
            f"{prefix}retired_total": float(self.retired_total),
            f"{prefix}decode_steps": float(self.steps),
        }
