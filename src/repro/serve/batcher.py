"""Dynamic micro-batcher: coalesce queued requests into shape buckets.

The batcher owns the REQUEST side of serving: a bounded FIFO queue with
backpressure, per-request futures, and the admission policy (dispatch when a
full ``max_batch`` is waiting, or ``max_wait_ms`` after the first request
arrived — whichever comes first).  It is engine-agnostic: a dispatch loop
(``repro.serve.service``) pops coalesced batches with ``next_batch`` and
completes the futures.  All math (padding to the bucket, the forward pass)
happens downstream.
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Any, List, Optional

from repro.serve.buckets import BucketPolicy


class Backpressure(RuntimeError):
    """The request queue is full — the caller must shed load or retry."""


class ServeFuture:
    """Minimal thread-safe future for one request's embedding."""

    def __init__(self):
        self._done = threading.Event()
        self._value: Any = None
        self._error: Optional[BaseException] = None
        self.t_submit = time.perf_counter()
        self.t_done: Optional[float] = None

    def set_result(self, value: Any):
        """Resolve the future with the request's result."""
        self._value = value
        self.t_done = time.perf_counter()
        self._done.set()

    def set_exception(self, err: BaseException):
        """Fail the future; ``result()`` re-raises the error."""
        self._error = err
        self.t_done = time.perf_counter()
        self._done.set()

    def done(self) -> bool:
        """True once a result or an exception has been set."""
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None) -> Any:
        """Block until resolved; return the value or re-raise."""
        if not self._done.wait(timeout):
            raise TimeoutError("serve request did not complete in time")
        if self._error is not None:
            raise self._error
        return self._value

    @property
    def latency_s(self) -> Optional[float]:
        """Submit-to-done wall time in seconds (None while pending)."""
        return None if self.t_done is None else self.t_done - self.t_submit


class Request:
    """One queued request: the payload plus its future.

    The payload is opaque to the batcher — embedding traffic queues input
    row arrays (coalesced by ``rows``), LM traffic queues
    ``repro.serve.slots.LMRequest`` prompts (each counts as one row; slot
    admission pops them with ``next_requests``)."""

    __slots__ = ("x", "future")

    def __init__(self, x):
        self.x = x
        self.future = ServeFuture()

    @property
    def rows(self) -> int:
        """Input rows this request contributes to a batch."""
        return 1 if getattr(self.x, "ndim", 1) == 1 else int(self.x.shape[0])


_SHUTDOWN = object()


class MicroBatcher:
    """Bounded request queue + coalescing admission policy."""

    def __init__(self, policy: BucketPolicy = BucketPolicy()):
        self.policy = policy.validate()
        self._q: "queue.Queue" = queue.Queue(maxsize=policy.max_queue)
        self._shutdown = threading.Event()

    # -- producer side ------------------------------------------------------

    def submit(self, x, *, block: bool = False, timeout: Optional[float] = None) -> ServeFuture:
        """Enqueue one request.  Non-blocking by default: raises
        ``Backpressure`` when the queue is at ``max_queue`` (the caller is
        expected to 429 / shed load); ``block=True`` waits up to ``timeout``.
        Raises ``Backpressure`` unconditionally after ``shutdown``."""
        if self._shutdown.is_set():
            raise Backpressure("serve queue is shutting down; not accepting requests")
        req = Request(x)
        try:
            self._q.put(req, block=block, timeout=timeout)
        except queue.Full:
            raise Backpressure(
                f"serve queue full ({self.policy.max_queue} pending); shed load"
            ) from None
        return req.future

    def depth(self) -> int:
        """Requests currently waiting in the queue."""
        return self._q.qsize()

    def shutdown(self):
        """Stop admitting requests; ``next_batch`` drains what is queued and
        then returns None.  The signal is an event, not a queued sentinel, so
        shutting down never blocks on a full queue — the best-effort sentinel
        below only wakes a dispatch loop blocked in an indefinite get."""
        self._shutdown.set()
        try:
            self._q.put_nowait(_SHUTDOWN)
        except queue.Full:
            pass  # queue non-empty -> a blocked get cannot exist

    # -- consumer side ------------------------------------------------------

    def next_batch(self, timeout: Optional[float] = None) -> Optional[List[Request]]:
        """Block up to ``timeout`` for the first request, then coalesce FIFO
        until ``max_batch`` rows are gathered or ``max_wait_ms`` has elapsed
        since the first request was popped.  Returns [] on timeout with an
        empty queue and None once ``shutdown`` was called and the queue has
        drained (queued requests are always flushed first)."""
        try:
            first = self._q.get(block=timeout != 0.0, timeout=timeout)
        except queue.Empty:
            return None if self._shutdown.is_set() else []
        if first is _SHUTDOWN:
            # the wake-up sentinel; anything still queued drains on the next
            # call (submit is already refusing new work)
            return None if self._q.empty() else []
        batch = [first]
        rows = first.rows
        deadline = time.perf_counter() + self.policy.max_wait_ms / 1e3
        while rows < self.policy.max_batch:
            remaining = deadline - time.perf_counter()
            try:
                nxt = self._q.get(block=remaining > 0, timeout=max(remaining, 0) or None)
            except queue.Empty:
                break
            if nxt is _SHUTDOWN:
                # flush this batch; the event flag carries the signal onward
                break
            batch.append(nxt)
            rows += nxt.rows
        return batch

    def next_requests(self, max_n: int, timeout: Optional[float] = None) -> Optional[List[Request]]:
        """Pop up to ``max_n`` whole requests — continuous-batching
        admission: a freed decode slot takes the next queued request NOW, it
        never waits to coalesce a full batch (``max_wait_ms`` is a coalescing
        knob and does not apply).  Returns [] when nothing is queued within
        ``timeout`` (or ``max_n == 0``) and None once ``shutdown`` was called
        and the queue has drained."""
        if max_n <= 0:
            return None if self._shutdown.is_set() and self._q.empty() else []
        try:
            first = self._q.get(block=timeout != 0.0, timeout=timeout)
        except queue.Empty:
            return None if self._shutdown.is_set() else []
        if first is _SHUTDOWN:
            return None if self._q.empty() else []
        batch = [first]
        while len(batch) < max_n:
            try:
                nxt = self._q.get_nowait()
            except queue.Empty:
                break
            if nxt is _SHUTDOWN:
                break
            batch.append(nxt)
        return batch
