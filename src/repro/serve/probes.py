"""Online decorrelation probes for the serve path.

Wraps ``repro.decorr.probe_metrics`` (training-oracle-exact R_off / R_sum on
a served batch) in a streaming monitor: per-batch values are folded into
exponential moving averages, and per-feature first/second moments are EMA'd
as full length-d vectors so serving can detect *which* features drift, not
just that something did.  The permutation key follows the training
construction (``permutation_for_step``: fold the probe step count into a
fixed seed key) so a probe reading at step t is reproducible offline.

``metrics()`` exports one flat ``{str: float}`` dict — the scrape surface
(Prometheus-shaped: gauges only, no nesting).
"""

from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.decorr.config import DecorrConfig
from repro.decorr.probe import probe_metrics

Array = jax.Array


class DecorrProbe:
    """Streaming representation-health monitor for served embeddings."""

    def __init__(
        self,
        cfg: DecorrConfig = DecorrConfig(style="vic", reg="sum", q=2),
        *,
        ema: float = 0.99,
        perm_seed: int = 0,
        include_off: Optional[bool] = None,
        sample_rows: Optional[int] = None,
    ):
        self.cfg = cfg.validate()
        self.ema = float(ema)
        self._seed_key = jax.random.PRNGKey(perm_seed)
        self._include_off = include_off
        # observe() coalesces rows into fixed (sample_rows, d) probes so the
        # jitted probe compiles ONCE — dynamic micro-batches have ragged row
        # counts and per-shape retraces would land in the dispatch loop.
        self.sample_rows = sample_rows
        self._buf: list = []
        self._buf_rows = 0
        self._step = 0
        self._last: Dict[str, float] = {}
        self._avg: Dict[str, float] = {}
        self._mean_ema: Optional[Array] = None
        self._m2_ema: Optional[Array] = None
        # one jitted probe per (shape, two-view?) — cfg/include_off are fixed
        self._probe = jax.jit(
            functools.partial(probe_metrics, cfg=cfg, include_off=include_off)
        )
        self._moments = jax.jit(lambda z: (jnp.mean(z, axis=0), jnp.mean(z * z, axis=0)))
        # per-executable attribution (repro.obs.ExecTimer); services attach
        # obs.perf when telemetry is enabled
        self.perf = None

    # -- streaming update ---------------------------------------------------

    def update(self, z1: Array, z2: Optional[Array] = None) -> Dict[str, float]:
        """Fold one served batch into the stream; returns this batch's metrics."""
        # same key construction as training (see core/permutation.py): the
        # engine samples the permutation itself from this step-folded key.
        perm_key = jax.random.fold_in(self._seed_key, jnp.uint32(self._step))
        t0 = self.perf.start() if self.perf is not None else 0.0
        vals = self._probe(z1, z2, perm_key=perm_key)
        m1, m2 = self._moments(jnp.asarray(z1, jnp.float32))

        # one host transfer for everything; EMAs fold in numpy so the stream
        # update costs no further device dispatches.
        vals, m1, m2 = jax.device_get((vals, m1, m2))
        if self.perf is not None:  # device_get above is the sync point
            self.perf.observe("probe_update", self.perf.elapsed(t0))
        batch = {k: float(v) for k, v in vals.items()}
        a = self.ema
        for k, v in batch.items():
            self._avg[k] = v if k not in self._avg else a * self._avg[k] + (1 - a) * v
        self._mean_ema = m1 if self._mean_ema is None else a * self._mean_ema + (1 - a) * m1
        self._m2_ema = m2 if self._m2_ema is None else a * self._m2_ema + (1 - a) * m2
        self._last = batch
        self._step += 1
        return batch

    def warmup(self, d: int):
        """Compile the probe/moment kernels for the pinned sample shape
        without folding anything into the stream (no EMA/step side effects)."""
        n = self.sample_rows or 8
        zero = jnp.zeros((n, d), jnp.float32)
        key = jax.random.fold_in(self._seed_key, jnp.uint32(0))
        if self.perf is not None:
            self.perf.attach_jit("probe_update", self._probe, zero, None, perm_key=key)
        jax.block_until_ready(self._probe(zero, None, perm_key=key))
        jax.block_until_ready(self._moments(zero))

    def observe(self, z: Array) -> int:
        """Streaming entry point: buffer served rows, fold a probe update for
        every full ``sample_rows`` window.  With ``sample_rows=None`` each
        call probes immediately (exact per-batch semantics, one compiled
        variant per distinct row count).  Returns probe updates fired."""
        if self.sample_rows is None:
            self.update(z)
            return 1
        self._buf.append(np.asarray(z, np.float32))
        self._buf_rows += int(z.shape[0])
        fired = 0
        while self._buf_rows >= self.sample_rows:
            flat = np.concatenate(self._buf, axis=0)
            sample, rest = flat[: self.sample_rows], flat[self.sample_rows :]
            self._buf = [rest] if rest.size else []
            self._buf_rows = int(rest.shape[0]) if rest.size else 0
            self.update(sample)
            fired += 1
        return fired

    # -- scrape surface -----------------------------------------------------

    @property
    def steps(self) -> int:
        """Probe updates observed so far."""
        return self._step

    def feature_moments(self):
        """(EMA mean, EMA var) per feature — length-d drift vectors."""
        if self._mean_ema is None:
            return None, None
        var = np.maximum(self._m2_ema - self._mean_ema**2, 0.0)
        return self._mean_ema, var

    def metrics(self, prefix: str = "decorr_") -> Dict[str, float]:
        """Latest probe values as flat ``decorr_*`` gauges."""
        out = {f"{prefix}probe_steps": float(self._step)}
        for k, v in self._last.items():
            out[f"{prefix}{k}"] = v
        for k, v in self._avg.items():
            out[f"{prefix}{k}_ema"] = v
        mean, var = self.feature_moments()
        if mean is not None:
            out[f"{prefix}feat_mean_abs_ema"] = float(np.mean(np.abs(mean)))
            out[f"{prefix}feat_var_ema"] = float(np.mean(var))
        return out
