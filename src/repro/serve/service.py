"""The embedding service: batcher + engine + probes + liveness, one object.

``EmbeddingService`` runs the dispatch loop — pop a coalesced batch from the
``MicroBatcher``, pad-and-encode through the ``ServeEngine``, fan results
back out to the request futures, feed the ``DecorrProbe`` and the
``repro.ft`` heartbeat — either on a background thread (``start``/``stop``,
the production shape) or synchronously (``run_pending``, what tests and the
closed-loop benchmark drive).  ``metrics()`` is the scrape surface: latency
percentiles, throughput, queue depth, batch-shape histogram, probe health,
heartbeat ages — all flat float gauges.
"""

from __future__ import annotations

import collections
import threading
import time
from typing import Dict, List, Optional

import numpy as np

from repro.ft.watchdog import HeartbeatMonitor
from repro.serve.batcher import MicroBatcher, Request, ServeFuture
from repro.serve.buckets import BucketPolicy
from repro.serve.engine import ServeEngine
from repro.serve.probes import DecorrProbe

HEARTBEAT_NAME = "serve.dispatch"


class LatencyStats:
    """Rolling per-request latency window + monotone served counter."""

    def __init__(self, window: int = 4096):
        self._lat = collections.deque(maxlen=window)
        self.served = 0
        self.batches = 0
        self._t_start = time.perf_counter()

    def reset_clock(self):
        """Restart the throughput window (called when serving actually
        starts, so warmup compilation and pre-start idle time don't deflate
        the scraped rate)."""
        self._t_start = time.perf_counter()

    def observe_batch(self, latencies_s: List[float]):
        self._lat.extend(latencies_s)
        self.served += len(latencies_s)
        self.batches += 1

    def percentile(self, q: float) -> float:
        if not self._lat:
            return 0.0
        return float(np.percentile(np.asarray(self._lat), q))

    def metrics(self, prefix: str = "latency_") -> Dict[str, float]:
        dt = max(time.perf_counter() - self._t_start, 1e-9)
        return {
            f"{prefix}p50_ms": self.percentile(50) * 1e3,
            f"{prefix}p99_ms": self.percentile(99) * 1e3,
            "served_total": float(self.served),
            "batches_total": float(self.batches),
            "mean_batch": self.served / max(self.batches, 1),
            "throughput_rps": self.served / dt,
        }


class EmbeddingService:
    """Batched embedding serving with online representation-health probes."""

    def __init__(
        self,
        engine: ServeEngine,
        *,
        policy: Optional[BucketPolicy] = None,
        probe: Optional[DecorrProbe] = None,
        heartbeat: Optional[HeartbeatMonitor] = None,
        heartbeat_timeout_s: float = 10.0,
    ):
        self.engine = engine
        self.policy = (policy or engine.policy).validate()
        self.batcher = MicroBatcher(self.policy)
        self.probe = probe
        if probe is not None and probe.sample_rows is None:
            # pin the probe to one compiled shape: the largest bucket
            from repro.serve.buckets import bucket_sizes

            probe.sample_rows = bucket_sizes(self.policy)[-1]
        self.stats = LatencyStats()
        self.heartbeat = heartbeat or HeartbeatMonitor()
        self.heartbeat.register(HEARTBEAT_NAME, heartbeat_timeout_s)
        self._thread: Optional[threading.Thread] = None
        self._errors = 0

    # -- request side -------------------------------------------------------

    def submit(self, x, **kw) -> ServeFuture:
        """Queue one request (a single input row or a small row-batch).
        Raises ``repro.serve.batcher.Backpressure`` when the queue is full."""
        return self.batcher.submit(np.asarray(x), **kw)

    # -- dispatch loop ------------------------------------------------------

    def _dispatch(self, requests: List[Request]):
        rows = [r.x if r.x.ndim == 2 else r.x[None] for r in requests]
        x = np.concatenate(rows, axis=0)
        try:
            z = self.engine.encode(x)
            z.block_until_ready()
        except Exception as e:  # pragma: no cover - device failure path
            self._errors += 1
            for r in requests:
                r.future.set_exception(e)
            return
        # one device->host transfer, then numpy fan-out: per-request device
        # slices would each compile their own XLA gather and dispatch 1/row.
        z_host = np.asarray(z)
        if self.probe is not None:
            self.probe.observe(z_host)
        off = 0
        for r in requests:
            n = r.x.shape[0] if r.x.ndim == 2 else 1
            out = z_host[off] if r.x.ndim == 1 else z_host[off : off + n]
            r.future.set_result(out)
            off += n
        self.stats.observe_batch(
            [r.future.latency_s for r in requests if r.future.latency_s is not None]
        )
        self.heartbeat.beat(HEARTBEAT_NAME)

    def run_pending(self, timeout: float = 0.0) -> int:
        """Synchronously serve one admission batch; returns requests served.
        (The deterministic entry point — tests and the closed-loop bench.)"""
        batch = self.batcher.next_batch(timeout=timeout)
        if not batch:
            return 0
        self._dispatch(batch)
        return len(batch)

    def _loop(self):
        while True:
            batch = self.batcher.next_batch(timeout=0.05)
            if batch is None:  # shutdown sentinel
                return
            if batch:
                self._dispatch(batch)
            else:
                # idle tick still beats: staleness must mean a wedged loop,
                # not an empty queue.
                self.heartbeat.beat(HEARTBEAT_NAME)

    def warmup(self) -> "EmbeddingService":
        """Pre-compile every engine bucket AND the probe sample shape, so the
        dispatch loop never traces while requests wait."""
        self.engine.warmup()
        if self.probe is not None:
            self.probe.warmup(self.engine.d)
        self.stats.reset_clock()
        return self

    def start(self) -> "EmbeddingService":
        if self._thread is not None:
            raise RuntimeError("service already started")
        self._thread = threading.Thread(target=self._loop, name="serve-dispatch", daemon=True)
        self.stats.reset_clock()
        self._thread.start()
        return self

    def stop(self, timeout: float = 10.0):
        if self._thread is None:
            return
        self.batcher.shutdown()
        self._thread.join(timeout)
        self._thread = None

    # -- scrape surface -----------------------------------------------------

    def metrics(self) -> Dict[str, float]:
        out = {
            "queue_depth": float(self.batcher.depth()),
            "dispatch_errors": float(self._errors),
            "compiled_buckets": float(len(self.engine.compiled_buckets())),
        }
        out.update(self.stats.metrics())
        out.update(self.heartbeat.metrics())
        if self.probe is not None:
            out.update(self.probe.metrics())
        return out
