"""The serving services: batcher + engine + probes + liveness, one object.

``EmbeddingService`` runs the embedding dispatch loop — pop a coalesced
batch from the ``MicroBatcher``, pad-and-encode through the ``ServeEngine``,
fan results back out to the request futures, feed the ``DecorrProbe`` and
the ``repro.ft`` heartbeat — either on a background thread (``start``/
``stop``, the production shape) or synchronously (``run_pending``, what
tests and the closed-loop benchmark drive).

``LMService`` is the continuous-batching LM counterpart over the same
machinery: the SAME bounded ``MicroBatcher`` admission/backpressure, the
same heartbeat monitor, the same flat-gauge scrape shape — but its loop
ticks at decode-step granularity (``step``): admit queued prompts into freed
slots, run one batched decode over the pool, retire finished requests, and
feed the probe from the in-flight slots' hidden rows.

``metrics()`` on both is the scrape surface: latency percentiles,
throughput, queue depth, slot occupancy, time-to-first-token, probe health,
heartbeat ages — all flat float gauges.
"""

from __future__ import annotations

import collections
import threading
import time
from typing import Dict, List, Mapping, Optional

import numpy as np

from repro.ft.watchdog import HeartbeatMonitor
from repro.obs import Obs
from repro.serve.batcher import Backpressure, MicroBatcher, Request, ServeFuture
from repro.serve.buckets import BucketPolicy
from repro.serve.engine import ContinuousLMEngine, ServeEngine
from repro.serve.probes import DecorrProbe
from repro.serve.sampling import SamplingParams, sample_token
from repro.serve.slots import LMRequest

HEARTBEAT_NAME = "serve.dispatch"
HEARTBEAT_LM = "serve.lm_decode"


def collect_metrics(*parts, registry=None) -> Dict[str, float]:
    """Merge metric sources (flat dicts or objects with ``.metrics()``) into
    one scrape dict, optionally mirroring every key into a registry as
    gauges.  Both services assemble their scrape surface through this one
    helper, so the legacy flat dict and the registry view cannot drift.

    A part exposing ``publish_metrics(registry) -> set`` (the
    ``HeartbeatMonitor``) owns its own registry representation — typically a
    LABELLED family instead of a name-suffixed family per component — and
    returns the legacy flat keys it claims: those stay in the returned dict
    (the ``metrics()`` compatibility view) but are excluded from the flat
    ``registry.publish``, so per-component gauges do not explode the metric
    family namespace once a fabric runs many replicas."""
    out: Dict[str, float] = {}
    claimed: set = set()
    for part in parts:
        if part is None:
            continue
        out.update(part if isinstance(part, Mapping) else part.metrics())
        if registry is not None and hasattr(part, "publish_metrics"):
            claimed |= part.publish_metrics(registry)
    if registry is not None:
        if claimed:
            registry.publish({k: v for k, v in out.items() if k not in claimed})
        else:
            registry.publish(out)
    return out


def _trace_of(future) -> Optional["object"]:
    return getattr(future, "trace", None)


class _ObsAPI:
    """Telemetry surface shared by both services (``self.obs`` is an
    ``repro.obs.Obs`` bundle set in the subclass ``__init__``)."""

    obs: Obs

    def start_metrics_server(self, port: int = 0, host: str = "127.0.0.1"):
        """Expose this service's scrape surface over HTTP (``/metrics``,
        ``/alerts``, ``/healthz``); returns the started server."""
        return self.obs.start_server(port=port, metrics_fn=self.metrics, host=host)

    def scrape(self) -> str:
        """One Prometheus exposition of this service (also evaluates the
        alert rules — scrape-path alerting)."""
        return self.obs.scrape(self.metrics)

    def start_profiling(self, trace_dir: Optional[str] = None) -> bool:
        return self.obs.profiler.start(trace_dir)

    def stop_profiling(self) -> Optional[str]:
        return self.obs.profiler.stop()


class LatencyStats:
    """Rolling per-request latency window + monotone served counter."""

    def __init__(self, window: int = 4096):
        self._lat = collections.deque(maxlen=window)
        self.served = 0
        self.batches = 0
        self._t_start = time.perf_counter()

    def reset_clock(self):
        """Restart the throughput window (called when serving actually
        starts, so warmup compilation and pre-start idle time don't deflate
        the scraped rate)."""
        self._t_start = time.perf_counter()

    def observe_batch(self, latencies_s: List[float]):
        """Fold one dispatched batch's per-request latencies in."""
        self._lat.extend(latencies_s)
        self.served += len(latencies_s)
        self.batches += 1

    def percentile(self, q: float) -> float:
        """Latency percentile (seconds) over the rolling window."""
        if not self._lat:
            return 0.0
        return float(np.percentile(np.asarray(self._lat), q))

    def metrics(self, prefix: str = "latency_") -> Dict[str, float]:
        """Flat latency/throughput gauges for the scrape surface."""
        dt = max(time.perf_counter() - self._t_start, 1e-9)
        return {
            f"{prefix}p50_ms": self.percentile(50) * 1e3,
            f"{prefix}p99_ms": self.percentile(99) * 1e3,
            "served_total": float(self.served),
            "batches_total": float(self.batches),
            "mean_batch": self.served / max(self.batches, 1),
            "throughput_rps": self.served / dt,
        }


class EmbeddingService(_ObsAPI):
    """Batched embedding serving with online representation-health probes."""

    def __init__(
        self,
        engine: ServeEngine,
        *,
        policy: Optional[BucketPolicy] = None,
        probe: Optional[DecorrProbe] = None,
        heartbeat: Optional[HeartbeatMonitor] = None,
        heartbeat_timeout_s: float = 10.0,
        obs: Optional[Obs] = None,
    ):
        self.engine = engine
        self.obs = obs or Obs()
        # executable attribution stays off (perf=None) when telemetry is
        # disabled so the hot path never pays an extra device sync
        engine.perf = self.obs.perf if self.obs.perf.enabled else None
        self._h_encode = self.obs.registry.histogram(
            "serve_encode_seconds", "embedding batch encode wall time"
        )
        self.policy = (policy or engine.policy).validate()
        self.batcher = MicroBatcher(self.policy)
        self.probe = probe
        if probe is not None:
            probe.perf = engine.perf
        if probe is not None and probe.sample_rows is None:
            # pin the probe to one compiled shape: the largest bucket
            from repro.serve.buckets import bucket_sizes

            probe.sample_rows = bucket_sizes(self.policy)[-1]
        self.stats = LatencyStats()
        self.heartbeat = heartbeat or HeartbeatMonitor()
        self.heartbeat.register(HEARTBEAT_NAME, heartbeat_timeout_s)
        self._thread: Optional[threading.Thread] = None
        self._errors = 0

    # -- request side -------------------------------------------------------

    def submit(self, x, **kw) -> ServeFuture:
        """Queue one request (a single input row or a small row-batch).
        Rejects empty/malformed inputs with ``ValueError`` immediately (a
        zero-row request would otherwise occupy queue+dispatch for nothing);
        raises ``repro.serve.batcher.Backpressure`` when the queue is full."""
        x = np.asarray(x)
        if x.ndim not in (1, 2):
            raise ValueError(f"expected a (d,) row or (n, d) row-batch, got shape {x.shape}")
        if x.size == 0:
            raise ValueError(f"empty request (shape {x.shape}); nothing to embed")
        tr = self.obs.tracer.start_request("embed", rows=int(x.shape[0] if x.ndim == 2 else 1))
        try:
            fut = self.batcher.submit(x, **kw)
        except Backpressure:
            self.obs.recorder.record("backpressure", traffic="embed",
                                     queue_depth=self.batcher.depth())
            raise
        fut.trace = tr
        return fut

    # -- dispatch loop ------------------------------------------------------

    def _dispatch(self, requests: List[Request]):
        depth = self.batcher.depth()
        for r in requests:
            tr = _trace_of(r.future)
            if tr is not None:
                tr.mark_admit(batch=len(requests), queue_depth=depth)
        rows = [r.x if r.x.ndim == 2 else r.x[None] for r in requests]
        x = np.concatenate(rows, axis=0)
        t0 = time.perf_counter()
        try:
            z = self.engine.encode(x)
            z.block_until_ready()
        except Exception as e:  # pragma: no cover - device failure path
            self._errors += 1
            for r in requests:
                r.future.set_exception(e)
                tr = _trace_of(r.future)
                if tr is not None:
                    tr.mark_done("error")
            self.obs.recorder.record("error", traffic="embed", batch=len(requests))
            return
        t1 = time.perf_counter()
        if self.obs.enabled:
            self._h_encode.observe(t1 - t0)
            self.obs.tracer.add_span("encode", t0, t1, cat="exec",
                                     rows=int(x.shape[0]))
        self.obs.recorder.record("dispatch", requests=len(requests),
                                 rows=int(x.shape[0]), queue_depth=depth)
        # one device->host transfer, then numpy fan-out: per-request device
        # slices would each compile their own XLA gather and dispatch 1/row.
        z_host = np.asarray(z)
        if self.probe is not None:
            self.probe.observe(z_host)
        off = 0
        latencies = []
        for r in requests:
            n = r.x.shape[0] if r.x.ndim == 2 else 1
            out = z_host[off] if r.x.ndim == 1 else z_host[off : off + n]
            r.future.set_result(out)
            off += n
            tr = _trace_of(r.future)
            if tr is not None:
                tr.mark_done()
                latencies.append(tr.latency_s)
            elif r.future.latency_s is not None:
                latencies.append(r.future.latency_s)
        self.stats.observe_batch(latencies)
        self.heartbeat.beat(HEARTBEAT_NAME)

    def run_pending(self, timeout: float = 0.0) -> int:
        """Synchronously serve one admission batch; returns requests served.
        (The deterministic entry point — tests and the closed-loop bench.)"""
        batch = self.batcher.next_batch(timeout=timeout)
        if not batch:
            return 0
        self._dispatch(batch)
        return len(batch)

    def _loop(self):
        while True:
            batch = self.batcher.next_batch(timeout=0.05)
            if batch is None:  # shutdown sentinel
                return
            if batch:
                self._dispatch(batch)
            else:
                # idle tick still beats: staleness must mean a wedged loop,
                # not an empty queue.
                self.heartbeat.beat(HEARTBEAT_NAME)

    def warmup(self) -> "EmbeddingService":
        """Pre-compile every engine bucket AND the probe sample shape, so the
        dispatch loop never traces while requests wait."""
        self.engine.warmup()
        if self.probe is not None:
            self.probe.warmup(self.engine.d)
        self.stats.reset_clock()
        return self

    def start(self) -> "EmbeddingService":
        """Run the dispatch loop on a daemon thread; returns self."""
        if self._thread is not None:
            raise RuntimeError("service already started")
        self._thread = threading.Thread(target=self._loop, name="serve-dispatch", daemon=True)
        self.stats.reset_clock()
        self._thread.start()
        return self

    def stop(self, timeout: float = 10.0):
        """Shut the dispatch thread down (queue sentinel, then join)."""
        if self._thread is None:
            return
        self.batcher.shutdown()
        self._thread.join(timeout)
        self._thread = None

    # -- scrape surface -----------------------------------------------------

    def metrics(self) -> Dict[str, float]:
        """The embedding service's full flat-gauge scrape surface."""
        return collect_metrics(
            {
                "queue_depth": float(self.batcher.depth()),
                "dispatch_errors": float(self._errors),
                "compiled_buckets": float(len(self.engine.compiled_buckets())),
            },
            self.stats,
            self.heartbeat,
            self.probe,
            self.obs,
            registry=self.obs.registry,
        )


# ---------------------------------------------------------------------------
# Continuous-batching LM service
# ---------------------------------------------------------------------------


class LMService(_ObsAPI):
    """Continuous-batching LM serving over a ``ContinuousLMEngine``.

    Shares the embedding path's machinery end to end: the bounded
    ``MicroBatcher`` owns admission and ``Backpressure``, the
    ``HeartbeatMonitor`` owns liveness (one beat per decode tick, idle
    included), ``DecorrProbe`` streams representation health from the
    in-flight slots' hidden rows, and ``metrics()`` exports the same flat
    float-gauge scrape shape — plus the LM-specific gauges: per-slot
    occupancy and time-to-first-token percentiles.

    The loop ticks at decode-step granularity (``step``): admit queued
    prompts into freed slots (prefill-insert), one batched decode over the
    pool, retire EOS/budget-complete requests.  ``step``/``drain`` are the
    synchronous entry points (tests, the closed-loop bench);
    ``start``/``stop`` run the same tick on a background thread.
    """

    def __init__(
        self,
        engine: ContinuousLMEngine,
        *,
        max_queue: int = 1024,
        probe: Optional[DecorrProbe] = None,
        heartbeat: Optional[HeartbeatMonitor] = None,
        heartbeat_timeout_s: float = 10.0,
        record_probe_rows: bool = False,
        obs: Optional[Obs] = None,
    ):
        self.engine = engine
        self.obs = obs or Obs()
        # the engine narrates page-table activity into the same ring buffer
        engine.recorder = self.obs.recorder
        # executable attribution stays off (perf=None) when telemetry is
        # disabled so the decode tick keeps its current sync profile
        engine.perf = self.obs.perf if self.obs.perf.enabled else None
        if probe is not None:
            probe.perf = engine.perf
        reg = self.obs.registry
        self._h_prefill = reg.histogram(
            "serve_prefill_seconds", "whole-prompt insert wall time"
        )
        self._h_chunk = reg.histogram(
            "serve_chunk_prefill_seconds", "one chunked-prefill step wall time"
        )
        self._h_decode = reg.histogram(
            "serve_decode_step_seconds", "one batched decode step wall time"
        )
        # the histogram is the TTFT source of record for alerting: the scrape
        # path derives serve_ttft_seconds_p50/_p99 gauges from its buckets
        # (registry.quantile_gauges), so alert rules read the same stream the
        # service observes — not a parallel percentile bookkeeping
        self._h_ttft = reg.histogram(
            "serve_ttft_seconds", "time to first token (queue + prefill)"
        )
        self._h_verify = reg.histogram(
            "serve_verify_step_seconds",
            "one lane-batched speculative verify forward wall time",
        )
        # speculative-decoding counters (zeroed/no-op unless the engine was
        # built with speculative=True)
        from repro.serve.spec import SpecStats

        self.spec_stats = SpecStats()
        n_slots = engine.pool.n_slots
        self.batcher = MicroBatcher(
            BucketPolicy(max_batch=n_slots, max_wait_ms=0.0, max_queue=max_queue)
        )
        self.probe = probe
        if probe is not None and probe.sample_rows is None:
            # fixed probe window so the probe kernel compiles once: at least
            # one full pool of slot rows, sublane-aligned
            from repro.kernels.pallas_utils import SUBLANE, next_multiple

            probe.sample_rows = max(next_multiple(n_slots, SUBLANE), SUBLANE)
        self.stats = LatencyStats()
        self._ttft = collections.deque(maxlen=4096)
        self.tokens_total = 0
        self._t0 = time.perf_counter()
        self.heartbeat = heartbeat or HeartbeatMonitor()
        self.heartbeat.register(HEARTBEAT_LM, heartbeat_timeout_s)
        self._thread: Optional[threading.Thread] = None
        self._errors = 0
        # head-of-line buffer for paged admission: requests popped from the
        # queue whose page reservation does not fit yet wait here in FIFO
        # order (deferred, never dropped or reordered past)
        self._pending: List[Request] = []
        # bench/test hook: keep the exact rows fed to the probe, in order,
        # so probe readings can be replayed against the offline oracle
        self.record_probe_rows = record_probe_rows
        self.probe_rows: List[np.ndarray] = []

    # -- request side -------------------------------------------------------

    def submit(
        self,
        tokens,
        max_new_tokens: int,
        *,
        eos_id: Optional[int] = None,
        temperature: float = 0.0,
        top_k: Optional[int] = None,
        seed: Optional[int] = None,
        block: bool = False,
        timeout: Optional[float] = None,
    ) -> ServeFuture:
        """Queue one generation request.  Raises ``ValueError`` immediately
        for unservable requests (empty prompt, prompt beyond the largest
        bucket, cache/page-pool overflow, sampling on a greedy-only engine)
        — reject, never hang — and ``Backpressure`` when the queue is at
        ``max_queue``.  ``temperature``/``top_k``/``seed`` select per-request
        sampled decoding (temperature 0 = greedy, bit-identical to the
        argmax path)."""
        tokens = np.asarray(tokens, np.int32)
        if tokens.ndim != 1:
            raise ValueError(f"prompt must be a 1-D token id array, got shape {tokens.shape}")
        self.engine.validate_request(int(tokens.shape[0]), int(max_new_tokens))
        sampling = None
        if temperature or top_k or seed is not None:
            sampling = SamplingParams(
                temperature=float(temperature), top_k=top_k, seed=seed
            ).validate()
            if not sampling.greedy and not self.engine.sampling_enabled:
                raise ValueError(
                    "temperature > 0 needs an engine built with sampling=True "
                    "(the greedy engine keeps argmax inside the decode executable)"
                )
        req = LMRequest(
            tokens=tokens, max_new_tokens=int(max_new_tokens), eos_id=eos_id, sampling=sampling
        )
        tr = self.obs.tracer.start_request(
            "lm", prompt_len=int(tokens.shape[0]), max_new_tokens=int(max_new_tokens)
        )
        try:
            fut = self.batcher.submit(req, block=block, timeout=timeout)
        except Backpressure:
            self.obs.recorder.record("backpressure", traffic="lm",
                                     queue_depth=self.batcher.depth())
            raise
        fut.trace = tr
        return fut

    # -- decode-step tick ---------------------------------------------------

    def _feed_probe(self, rows: np.ndarray):
        if rows.shape[0] == 0:
            return
        if self.record_probe_rows:
            self.probe_rows.append(rows)
        if self.probe is not None:
            self.probe.observe(rows)

    def _finish(self, slot):
        slot.future.set_result(np.asarray(slot.emitted, np.int32))
        tr = _trace_of(slot.future)
        if tr is not None:
            tr.mark_done()
        self.tokens_total += len(slot.emitted)
        lat = tr.latency_s if tr is not None else slot.future.latency_s
        self.stats.observe_batch([lat])
        eos = slot.request.eos_id is not None and slot.emitted \
            and slot.emitted[-1] == slot.request.eos_id
        self.obs.recorder.record("retire", slot=slot.index,
                                 tokens=len(slot.emitted),
                                 reason="eos" if eos else "budget")
        self.engine.release(slot.index)

    def _fail(self, slot_or_req_future, exc):
        """Common error tail: reject the future, close its trace, log the
        anomaly to the flight recorder."""
        self._errors += 1
        slot_or_req_future.set_exception(exc)
        tr = _trace_of(slot_or_req_future)
        if tr is not None:
            tr.mark_done("error")
        self.obs.recorder.record("error", traffic="lm", error=type(exc).__name__)

    def _pick_token(self, slot, out) -> int:
        """out: a token id (greedy engine) or a (V,) logits row (sampling
        engine) — drawn with the request's own params + PRNG stream."""
        if not self.engine.sampling_enabled:
            return int(out)
        return sample_token(out, slot.request.sampling, slot.rng)

    def _emit_first(self, slot, out, hidden_row):
        """Common tail of whole-prompt insert and final-chunk completion:
        TTFT, probe feed, first-token emit, possible immediate retirement."""
        tr = _trace_of(slot.future)
        if tr is not None:
            tr.mark_first()
            ttft = tr.ttft_s
        else:
            ttft = time.perf_counter() - slot.future.t_submit
        self._ttft.append(ttft)
        if self.obs.enabled:
            self._h_ttft.observe(ttft)
        self._feed_probe(hidden_row)
        if slot.emit(self._pick_token(slot, out)):
            self._finish(self.engine.pool.retire(slot.index))

    def _spec_tick(self, active: List[int]) -> bool:
        """One speculative decode tick over the decoding slots.

        Drafts per slot (host-side n-gram lookup, attributed as ``draft``),
        then — when at least one slot produced a draft — runs ONE lane-batched
        verify forward for the whole pool (undrafted slots ride their plain
        lane 0), accepts the longest matching prefix per slot and emits the
        accepted span plus the model's bonus token.  Returns False when no
        slot drafted, signalling the caller to run the plain decode step
        (cheaper: batch ``n_slots`` instead of ``n_slots * (k + 1)``).
        """
        from repro.serve.spec import accept_length, draft_budget

        pool = self.engine.pool
        rec = self.obs.recorder
        stats = self.spec_stats
        perf = self.engine.perf
        t0 = perf.start() if perf is not None else 0.0
        drafts = []
        any_draft = False
        for i in active:
            s = pool[i]
            budget = draft_budget(
                self.engine.spec_cfg.draft_k, s.request.max_new_tokens, len(s.emitted)
            )
            d = s.draft.propose(budget) if budget > 0 else []
            stats.drafts += 1
            if d:
                stats.draft_hits += 1
                any_draft = True
                rec.record("spec_draft", slot=i, k=len(d))
            drafts.append((i, d))
        if perf is not None:
            perf.observe("draft", perf.elapsed(t0))
        if not any_draft:
            stats.plain_steps += 1
            return False
        t0 = time.perf_counter()
        out, hidden, tickets = self.engine.spec_verify(drafts)
        if self.obs.enabled:
            t1 = time.perf_counter()
            self._h_verify.observe(t1 - t0)
            self.obs.tracer.add_span("verify_step", t0, t1, cat="exec",
                                     lanes=len(active))
        stats.verify_steps += 1
        stats.slot_lanes += len(active)
        pool.observe_step()
        for i, d in drafts:
            s = pool[i]
            k_eff = len(d)
            lane_out = out[i]
            a = accept_length(d, lane_out[: k_eff + 1]) if k_eff else 0
            ticket = tickets.get(i)
            if ticket is not None:
                # commit ALWAYS: lane 0's write at pos is the one plain
                # decode would have done, even when the whole draft missed
                self.engine.spec_commit(ticket, a + 1)
            if k_eff:
                s.draft.observe_accept(a)
                stats.tokens_proposed += k_eff
                stats.tokens_accepted += a
                if a < k_eff:
                    stats.rejects += 1
                    rec.record("spec_reject", slot=i, k=k_eff, accepted=a)
                rec.record("spec_accept", slot=i, k=k_eff, accepted=a,
                           emitted=a + 1)
            n_emitted = 0
            done = False
            tr = _trace_of(s.future)
            for j in range(a + 1):
                if tr is not None:
                    tr.tick()
                done = s.emit(self._pick_token(s, lane_out[j]))
                n_emitted += 1
                if done:
                    break
            stats.tokens_emitted += n_emitted
            stats.per_slot[i] = stats.per_slot.get(i, 0) + n_emitted
            # one hidden row per emitted token — the same rows, in the same
            # per-slot order, that sequential decode would have fed the probe
            self._feed_probe(hidden[i, :n_emitted])
            if done:
                self._finish(pool.retire(i))
        return True

    def step(self, timeout: float = 0.0) -> Optional[int]:
        """One scheduler tick: admit into freed slots (deferring requests
        whose page reservation does not fit yet), advance at most one chunk
        of an in-progress chunked prefill, decode the pool once, retire
        finished requests.  Returns in-flight work after the tick, or None
        once ``shutdown`` has been signalled and everything drained."""
        from repro.decorr.probe import slot_probe_rows

        pool = self.engine.pool
        rec = self.obs.recorder
        want = max(pool.free_slots() - len(self._pending), 0)
        reqs = self.batcher.next_requests(want, timeout=timeout)
        shutting_down = reqs is None
        self._pending.extend(reqs or [])
        while self._pending and pool.free_slots():
            if not self.engine.can_admit(self._pending[0].x):
                # FIFO: later arrivals must not starve the head
                rec.record("defer", prompt_len=self._pending[0].x.prompt_len,
                           pending=len(self._pending))
                break
            r = self._pending.pop(0)
            slot = pool.admit(r.x, r.future)
            hit = self.engine.admit_slot(slot)
            tr = _trace_of(r.future)
            if tr is not None:
                tr.mark_admit(slot=slot.index, queue_depth=self.batcher.depth(),
                              prefix_hit=hit)
            rec.record("admit", slot=slot.index, prompt_len=r.x.prompt_len,
                       chunked=slot.prefilling, prefix_hit=hit,
                       queue_depth=self.batcher.depth())
            if slot.prefilling:
                continue  # chunked: first token arrives when the prompt is in
            t0 = time.perf_counter()
            try:
                out, hidden_row = self.engine.insert(slot)
            except Exception as e:  # pragma: no cover - device failure path
                self.engine.abort_slot(slot.index)
                pool.retire(slot.index)
                self._fail(r.future, e)
                continue
            if self.obs.enabled:
                t1 = time.perf_counter()
                self._h_prefill.observe(t1 - t0)
                self.obs.tracer.add_span("prefill_exec", t0, t1, cat="exec",
                                         slot=slot.index, prompt_len=r.x.prompt_len)
            self._emit_first(slot, out, hidden_row)
        chunk_slot = self.engine.prefilling_slot() if self.engine.prefill_chunk else None
        if chunk_slot is not None:
            before = chunk_slot.prefill_pos
            t0 = time.perf_counter()
            try:
                res = self.engine.advance_prefill(chunk_slot)
            except Exception as e:  # pragma: no cover - device failure path
                self.engine.abort_slot(chunk_slot.index)
                self._fail(pool.retire(chunk_slot.index).future, e)
            else:
                if self.obs.enabled:
                    t1 = time.perf_counter()
                    self._h_chunk.observe(t1 - t0)
                    # offset/wrote/cached make the Chrome trace show per-chunk
                    # progress: a warm prefix's first span starts at offset ==
                    # cached > 0 (the skipped rows) instead of 0
                    cached = (self.engine.pager.prefix_hit(chunk_slot.index)
                              if self.engine.paged and self.engine.prefix_cache else 0)
                    self.obs.tracer.add_span(
                        "prefill_chunk", t0, t1, cat="exec",
                        slot=chunk_slot.index, offset=before,
                        wrote=chunk_slot.prefill_pos - before,
                        prompt_len=chunk_slot.request.prompt_len, cached=cached)
                if res is not None:
                    self._emit_first(chunk_slot, *res)
        active = pool.decoding_indices()
        spec_ran = False
        if active and self.engine.speculative:
            try:
                spec_ran = self._spec_tick(active)
            except Exception as e:  # pragma: no cover - device failure path
                for i in pool.active_indices():
                    self.engine.abort_slot(i)
                    self._fail(pool.retire(i).future, e)
                spec_ran = True  # slots failed; no plain decode this tick
        if active and not spec_ran:
            t0 = time.perf_counter()
            try:
                next_out, hidden = self.engine.decode_step()
            except Exception as e:  # pragma: no cover - device failure path
                for i in pool.active_indices():
                    self.engine.abort_slot(i)
                    self._fail(pool.retire(i).future, e)
            else:
                if self.obs.enabled:
                    t1 = time.perf_counter()
                    self._h_decode.observe(t1 - t0)
                    self.obs.tracer.add_span("decode_step", t0, t1, cat="exec",
                                             lanes=len(active))
                # occupancy counts the lanes that actually decoded this step
                # (retirement happens after), matching the probe's row feed
                pool.observe_step()
                self._feed_probe(slot_probe_rows(hidden, active))
                for i in active:
                    s = pool[i]
                    tr = _trace_of(s.future)
                    if tr is not None:
                        tr.tick()
                    if s.emit(self._pick_token(s, next_out[i])):
                        self._finish(pool.retire(i))
        if active or self._pending or reqs:
            rec.record("tick", decoded=len(active), free=pool.free_slots(),
                       pending=len(self._pending), queue_depth=self.batcher.depth())
        self.heartbeat.beat(HEARTBEAT_LM)
        if shutting_down and not pool.active() and not self._pending:
            return None
        return len(self._pending) + len(pool.active())

    def outstanding(self) -> int:
        """Requests queued, deferred or holding a slot — the load signal the
        fabric router reads at dispatch time."""
        return self.batcher.depth() + len(self._pending) + len(self.engine.pool.active())

    def drain(self, max_steps: int = 1_000_000) -> int:
        """Synchronously tick until the queue and the pool are empty (the
        deterministic closed-loop entry point).  Returns ticks run."""
        ran = 0
        while ran < max_steps and (
            self.batcher.depth() or self._pending or self.engine.pool.active()
        ):
            self.step(timeout=0.0)
            ran += 1
        return ran

    def _loop(self):
        while True:
            if self.step(timeout=0.05) is None:
                return

    def warmup(self, prompt_lens=None) -> "LMService":
        """AOT-compile every prompt bucket, the pool decode step and the
        probe window, so no admitted request ever traces (``prompt_lens``:
        exact lengths to warm for recurrent archs; see engine.warmup)."""
        self.engine.warmup(prompt_lens=prompt_lens)
        if self.probe is not None:
            self.probe.warmup(self.engine.cfg.d_model)
        self.stats.reset_clock()
        self._t0 = time.perf_counter()
        return self

    def start(self) -> "LMService":
        """Run the decode-tick loop on a daemon thread; returns self."""
        if self._thread is not None:
            raise RuntimeError("service already started")
        self._thread = threading.Thread(target=self._loop, name="serve-lm-decode", daemon=True)
        self.stats.reset_clock()
        self._t0 = time.perf_counter()
        self._thread.start()
        return self

    def stop(self, timeout: float = 30.0):
        """Stop the tick thread (in-flight requests keep their state)."""
        if self._thread is None:
            return
        self.batcher.shutdown()
        self._thread.join(timeout)
        self._thread = None

    # -- scrape surface -----------------------------------------------------

    def metrics(self) -> Dict[str, float]:
        """The LM service's full flat-gauge scrape surface."""
        dt = max(time.perf_counter() - self._t0, 1e-9)
        ttft = np.asarray(self._ttft) if self._ttft else np.zeros((1,))
        own = {
            "queue_depth": float(self.batcher.depth()),
            "dispatch_errors": float(self._errors),
            "tokens_total": float(self.tokens_total),
            "tok_per_s": self.tokens_total / dt,
            "ttft_p50_ms": float(np.percentile(ttft, 50) * 1e3),
            "ttft_p99_ms": float(np.percentile(ttft, 99) * 1e3),
        }
        paged = None
        if self.engine.paged:
            paged = dict(self.engine.pager.metrics(),
                         admission_deferred=float(len(self._pending)))
        spec = self.spec_stats.metrics() if self.engine.speculative else None
        return collect_metrics(
            own,
            self.engine.pool,
            paged,
            spec,
            self.stats,
            self.heartbeat,
            self.probe,
            self.obs,
            registry=self.obs.registry,
        )
