"""Shared token-model serving helpers.

``launch/serve.py``, ``examples/serve_demo.py`` and the serve CLI all used
to carry their own copies of the frontend-aware prompt construction and the
warmup-then-time generate loop; this module is the single home for both.
"""

from __future__ import annotations

import time
from typing import Dict, Tuple

import jax

Array = jax.Array


def make_prompt(cfg, key: Array, batch: int, prompt_len: int) -> Array:
    """Random token prompt with the frontend-correct shape: (B, S) for token
    models, (B, S, n_codebooks) for audio-code models."""
    if cfg.frontend == "audio_codes":
        shape = (batch, prompt_len, cfg.n_codebooks)
    else:
        shape = (batch, prompt_len)
    return jax.random.randint(key, shape, 0, cfg.vocab_size)


def timed_generate(
    params,
    cfg,
    prompt: Array,
    new_tokens: int,
    *,
    warmup_tokens: int = 2,
    steps=None,
) -> Tuple[Array, Dict[str, float]]:
    """Warm (compile prefill + decode), then time one generate call.

    Returns (tokens, stats) with ``seconds``, ``tokens`` (new tokens emitted
    across the batch) and ``tok_per_s`` batch throughput.
    """
    from repro.train.serve import greedy_generate, make_decode_step, make_prefill_step

    if steps is None:
        # jit once here: greedy_generate's own per-call jits would retrace on
        # the timed call, and the warmup below would warm nothing.
        steps = (jax.jit(make_prefill_step(cfg)), jax.jit(make_decode_step(cfg)))
    max_len = prompt.shape[1] + new_tokens
    if warmup_tokens > 0:
        out = greedy_generate(
            params, cfg, prompt, min(warmup_tokens, new_tokens), max_len=max_len, steps=steps
        )
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    out = greedy_generate(params, cfg, prompt, new_tokens, max_len=max_len, steps=steps)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    n_tok = int(prompt.shape[0]) * new_tokens
    return out, {"seconds": dt, "tokens": float(n_tok), "tok_per_s": n_tok / max(dt, 1e-9)}
