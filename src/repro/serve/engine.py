"""The serving engine: bucketed, jit-cached embedding forward passes.

``ServeEngine`` wraps the SSL encoder+projector (``repro.train.ssl.embed``)
behind a per-bucket compile cache: inputs are padded to the request bucket
(``repro.serve.buckets``), each bucket compiles exactly once, and ``warmup``
pre-compiles the whole ladder so no request pays a trace.  Parameters come
either in-memory or from a ``repro.checkpoint`` directory (the training
loop's own format — the round trip is pinned by tests).  Under a mesh the
forward runs data-parallel inside ``shard_map`` (batch sharded over the
``data`` axis, params replicated) — the same execution regime as
``train/ssl.make_sharded_ssl_train_step``, minus the gradients.

``LMServeEngine`` is the token-model counterpart: it consumes the
prefill/decode step factories from ``repro.train.serve`` and caches their
jitted forms across requests, so repeated generate calls of one shape
compile once.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.checkpoint.checkpointer import latest_step, restore_checkpoint
from repro.serve.buckets import BucketPolicy, bucket_for, bucket_sizes
from repro.train.ssl import SSLModelConfig, embed, init_ssl_params

Array = jax.Array


class ServeEngine:
    """Embedding forward with a bounded per-bucket compile cache."""

    def __init__(
        self,
        model_cfg: SSLModelConfig,
        params,
        *,
        policy: BucketPolicy = BucketPolicy(),
        mesh: Optional[Mesh] = None,
        data_axis: str = "data",
        dtype=jnp.float32,
    ):
        self.model_cfg = model_cfg
        self.params = params
        self.policy = policy.validate()
        self.mesh = mesh
        self.data_axis = data_axis
        self.dtype = dtype
        if mesh is not None:
            dp = int(mesh.shape[data_axis])
            if policy.align % dp:
                raise ValueError(
                    f"BucketPolicy.align={policy.align} must be a multiple of "
                    f"the {data_axis!r} mesh axis ({dp}) so every bucket shards evenly"
                )
        self._compiled: Dict[int, callable] = {}

    # -- construction -------------------------------------------------------

    @classmethod
    def from_checkpoint(
        cls,
        ckpt_dir: str,
        model_cfg: SSLModelConfig,
        *,
        step: Optional[int] = None,
        **kw,
    ) -> "ServeEngine":
        """Load encoder+projector params saved by the training loop.

        Training checkpoints a ``TrainState`` whose params live under the
        ``params`` key; a bare params tree (e.g. an exported snapshot) is
        accepted too.  ``step=None`` takes the newest committed step.
        """
        if step is None:
            step = latest_step(ckpt_dir)
            if step is None:
                raise FileNotFoundError(f"no committed checkpoint under {ckpt_dir}")
        template = init_ssl_params(jax.random.PRNGKey(0), model_cfg)
        try:
            params = restore_checkpoint(ckpt_dir, step, template)
        except KeyError:
            # TrainState layout: restore just the params subtree by wrapping
            # the template the way the train loop nests it.
            from repro.train.train_state import TrainState

            state = restore_checkpoint(
                ckpt_dir, step, TrainState(0, template, None, None)
            )
            params = state.params
        return cls(model_cfg, params, **kw)

    # -- compile cache ------------------------------------------------------

    @property
    def d(self) -> int:
        return int(self.model_cfg.projector_widths[-1])

    def _embed_fn(self, bucket: int):
        fn = self._compiled.get(bucket)
        if fn is not None:
            return fn
        if self.mesh is None:
            fn = jax.jit(embed)
        else:
            sharded = shard_map(
                embed,
                mesh=self.mesh,
                in_specs=(P(), P(self.data_axis)),
                out_specs=P(self.data_axis),
            )
            fn = jax.jit(sharded)
        self._compiled[bucket] = fn
        return fn

    def warmup(self) -> Tuple[int, ...]:
        """Pre-compile every bucket (AOT) so no request pays a trace."""
        for b in bucket_sizes(self.policy):
            shape = jax.ShapeDtypeStruct((b, self.model_cfg.input_dim), self.dtype)
            fn = self._embed_fn(b)
            self._compiled[b] = fn.lower(self.params, shape).compile()
        return bucket_sizes(self.policy)

    def compiled_buckets(self) -> Tuple[int, ...]:
        return tuple(sorted(self._compiled))

    # -- serving forward ----------------------------------------------------

    def encode(self, x: Array) -> Array:
        """(n, input_dim) -> (n, d): pad to the bucket, run, strip padding.

        n must be <= ``policy.max_batch`` rows (the batcher guarantees it);
        rows are independent through the MLP so zero-padding never leaks into
        real outputs.
        """
        x = jnp.asarray(x, self.dtype)
        if x.ndim == 1:
            x = x[None, :]
        n = x.shape[0]
        top = bucket_sizes(self.policy)[-1]
        if n > top:
            # coalescing can overshoot max_batch by one multi-row request
            # (and the naive bench feeds arbitrary n): chunk at the largest
            # bucket so every executable stays within the warmed ladder.
            parts = [self.encode(x[i : i + top]) for i in range(0, n, top)]
            return jnp.concatenate(parts, axis=0)
        b = bucket_for(n, self.policy)
        if n < b:
            x = jnp.concatenate([x, jnp.zeros((b - n, x.shape[1]), self.dtype)], axis=0)
        z = self._embed_fn(b)(self.params, x)
        return z[:n]


# ---------------------------------------------------------------------------
# Token-model serving: prefill/decode factories from repro.train.serve
# ---------------------------------------------------------------------------


class LMServeEngine:
    """Greedy generation with the prefill/decode steps compiled once.

    ``repro.train.serve.greedy_generate`` builds (and jits) its step
    functions per call; this engine owns them across requests, keyed by
    nothing — prefill/decode are shape-polymorphic in batch via retrace, and
    XLA's jit cache bounds the variants to the distinct (batch, prompt_len)
    shapes actually served.
    """

    def __init__(self, arch_cfg):
        from repro.train.serve import make_decode_step, make_prefill_step

        self.cfg = arch_cfg
        self.steps = (
            jax.jit(make_prefill_step(arch_cfg)),
            jax.jit(make_decode_step(arch_cfg)),
        )

    def generate(self, params, prompt_tokens: Array, max_new_tokens: int) -> Array:
        from repro.train.serve import greedy_generate

        return greedy_generate(
            params, self.cfg, prompt_tokens, max_new_tokens, steps=self.steps
        )
