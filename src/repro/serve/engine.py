"""The serving engine: bucketed, jit-cached embedding forward passes.

``ServeEngine`` wraps the SSL encoder+projector (``repro.train.ssl.embed``)
behind a per-bucket compile cache: inputs are padded to the request bucket
(``repro.serve.buckets``), each bucket compiles exactly once, and ``warmup``
pre-compiles the whole ladder so no request pays a trace.  Parameters come
either in-memory or from a ``repro.checkpoint`` directory (the training
loop's own format — the round trip is pinned by tests).  Under a mesh the
forward runs data-parallel inside ``shard_map`` (batch sharded over the
``data`` axis, params replicated) — the same execution regime as
``train/ssl.make_sharded_ssl_train_step``, minus the gradients.  Passing
``model_axis`` additionally feature-shards the forward (tp mode): the
projector's output layer splits over the ``feature`` logical axis exactly as
``train/ssl.ssl_param_specs`` shards it for tp training, each device computes
its (n, d/M) feature block, and the decorr engine's
``all_to_all_features`` exchange re-assembles full-width rows — so one
serving replica can span M devices (``fabric.FabricConfig(tp=M)``).  The
last projector layer is a pure affine map (no activation), so the
column-sharded forward is numerically identical to the single-device one.

``LMServeEngine`` is the token-model counterpart: it consumes the
prefill/decode step factories from ``repro.train.serve`` and caches their
jitted forms across requests, so repeated generate calls of one shape
compile once.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.checkpoint.checkpointer import latest_step, restore_checkpoint
from repro.serve.buckets import BucketPolicy, bucket_for, bucket_sizes
from repro.train.ssl import SSLModelConfig, embed, init_ssl_params

Array = jax.Array


class ServeEngine:
    """Embedding forward with a bounded per-bucket compile cache."""

    def __init__(
        self,
        model_cfg: SSLModelConfig,
        params,
        *,
        policy: BucketPolicy = BucketPolicy(),
        mesh: Optional[Mesh] = None,
        data_axis: str = "data",
        model_axis: Optional[str] = None,
        dtype=jnp.float32,
    ):
        self.model_cfg = model_cfg
        self.params = params
        self.policy = policy.validate()
        self.mesh = mesh
        self.data_axis = data_axis
        self.model_axis = model_axis
        self.dtype = dtype
        self._tp_specs = None
        if model_axis is not None and mesh is None:
            raise ValueError("model_axis (tp mode) needs a mesh carrying that axis")
        if mesh is not None:
            dp = int(mesh.shape[data_axis])
            mp = int(mesh.shape[model_axis]) if model_axis is not None else 1
            if policy.align % (dp * mp):
                # tp buckets split over BOTH axes: the all_to_all exchange
                # turns (n/dp, d/mp) shards into (n/(dp*mp), d) rows
                raise ValueError(
                    f"BucketPolicy.align={policy.align} must be a multiple of the "
                    f"mesh extent ({dp}x{mp}={dp * mp}) so every bucket shards evenly"
                )
            if model_axis is not None:
                if self.d % mp:
                    raise ValueError(
                        f"embedding width d={self.d} must split evenly over the "
                        f"{model_axis!r} axis ({mp} devices)"
                    )
                self._tp_specs = self._make_tp_specs()
                # place params once (projector output layer feature-sharded,
                # everything else replicated) so encode never re-shards
                from jax.sharding import NamedSharding

                self.params = jax.tree_util.tree_map(
                    lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
                    self.params, self._tp_specs,
                )
        self._compiled: Dict[int, callable] = {}
        # per-executable attribution; services attach obs.perf (None keeps
        # encode() fully async — no block_until_ready on the hot path)
        self.perf = None

    # -- construction -------------------------------------------------------

    @classmethod
    def from_checkpoint(
        cls,
        ckpt_dir: str,
        model_cfg: SSLModelConfig,
        *,
        step: Optional[int] = None,
        **kw,
    ) -> "ServeEngine":
        """Load encoder+projector params saved by the training loop.

        Training checkpoints a ``TrainState`` whose params live under the
        ``params`` key; a bare params tree (e.g. an exported snapshot) is
        accepted too.  ``step=None`` takes the newest committed step.
        """
        if step is None:
            step = latest_step(ckpt_dir)
            if step is None:
                raise FileNotFoundError(f"no committed checkpoint under {ckpt_dir}")
        template = init_ssl_params(jax.random.PRNGKey(0), model_cfg)
        try:
            params = restore_checkpoint(ckpt_dir, step, template)
        except KeyError:
            # TrainState layout: restore just the params subtree by wrapping
            # the template the way the train loop nests it.
            from repro.train.train_state import TrainState

            state = restore_checkpoint(
                ckpt_dir, step, TrainState(0, template, None, None)
            )
            params = state.params
        return cls(model_cfg, params, **kw)

    # -- compile cache ------------------------------------------------------

    @property
    def d(self) -> int:
        """Embedding width (the projector's output dimension)."""
        return int(self.model_cfg.projector_widths[-1])

    def _make_tp_specs(self):
        """Param placement for tp mode, mirroring ``train/ssl.ssl_param_specs``:
        everything replicated except the projector's output layer, which
        splits over the ``feature`` logical axis (mapped onto
        ``self.model_axis``)."""
        import repro.parallel.sharding as shd

        rules = {"feature": (self.model_axis,)}
        with shd.sharding_context(self.mesh, rules):
            w_spec = shd.logical_to_spec((None, "feature"))
            b_spec = shd.logical_to_spec(("feature",))
        specs = {
            "backbone": [{"w": P(), "b": P()} for _ in self.params["backbone"]],
            "projector": [{"w": P(), "b": P()} for _ in self.params["projector"]],
        }
        specs["projector"][-1] = {"w": w_spec, "b": b_spec}
        return specs

    def _embed_fn(self, bucket: int):
        fn = self._compiled.get(bucket)
        if fn is not None:
            if self.perf is not None:
                self.perf.cache_hit(f"embed_b{bucket}")
            return fn
        if self.perf is not None:
            self.perf.cache_miss(f"embed_b{bucket}")
        if self.mesh is None:
            fn = jax.jit(embed)
        elif self.model_axis is None:
            sharded = shard_map(
                embed,
                mesh=self.mesh,
                in_specs=(P(), P(self.data_axis)),
                out_specs=P(self.data_axis),
            )
            fn = jax.jit(sharded)
        else:
            # tp: each device computes its (n/dp, d/mp) feature block of the
            # projector output, then the decorr engine's exchange transposes
            # feature shards into full-width row shards — the output lands
            # batch-sharded over BOTH mesh axes
            from repro.decorr.modes import all_to_all_features

            model_axis = self.model_axis

            def tp_embed(p, x):
                """Feature-sharded forward + all_to_all row re-assembly."""
                return all_to_all_features(embed(p, x), model_axis)

            sharded = shard_map(
                tp_embed,
                mesh=self.mesh,
                in_specs=(self._tp_specs, P(self.data_axis)),
                out_specs=P((self.data_axis, self.model_axis)),
            )
            fn = jax.jit(sharded)
        self._compiled[bucket] = fn
        return fn

    def warmup(self) -> Tuple[int, ...]:
        """Pre-compile every bucket (AOT) so no request pays a trace."""
        import time as _time

        for b in bucket_sizes(self.policy):
            shape = jax.ShapeDtypeStruct((b, self.model_cfg.input_dim), self.dtype)
            fn = self._embed_fn(b)
            t0 = _time.perf_counter()
            compiled = fn.lower(self.params, shape).compile()
            self._compiled[b] = compiled
            if self.perf is not None:
                name = f"embed_b{b}"
                self.perf.record_compile(name, _time.perf_counter() - t0)
                self.perf.attach_compiled(name, compiled)
        return bucket_sizes(self.policy)

    def compiled_buckets(self) -> Tuple[int, ...]:
        """Batch sizes with a compiled executable, ascending."""
        return tuple(sorted(self._compiled))

    # -- serving forward ----------------------------------------------------

    def encode(self, x: Array) -> Array:
        """(n, input_dim) -> (n, d): pad to the bucket, run, strip padding.

        n must be <= ``policy.max_batch`` rows (the batcher guarantees it);
        rows are independent through the MLP so zero-padding never leaks into
        real outputs.
        """
        x = jnp.asarray(x, self.dtype)
        if x.ndim == 1:
            x = x[None, :]
        n = x.shape[0]
        top = bucket_sizes(self.policy)[-1]
        if n > top:
            # coalescing can overshoot max_batch by one multi-row request
            # (and the naive bench feeds arbitrary n): chunk at the largest
            # bucket so every executable stays within the warmed ladder.
            parts = [self.encode(x[i : i + top]) for i in range(0, n, top)]
            return jnp.concatenate(parts, axis=0)
        b = bucket_for(n, self.policy)
        if n < b:
            x = jnp.concatenate([x, jnp.zeros((b - n, x.shape[1]), self.dtype)], axis=0)
        fn = self._embed_fn(b)
        if self.perf is None:
            return fn(self.params, x)[:n]
        # attribution path: block so the wall time covers device execution
        # (the default perf=None path stays fully async)
        t0 = self.perf.start()
        z = fn(self.params, x)
        jax.block_until_ready(z)
        self.perf.observe(f"embed_b{b}", self.perf.elapsed(t0))
        return z[:n]


# ---------------------------------------------------------------------------
# Token-model serving: prefill/decode factories from repro.train.serve
# ---------------------------------------------------------------------------


class LMServeEngine:
    """Greedy generation with the prefill/decode steps compiled once.

    ``repro.train.serve.greedy_generate`` builds (and jits) its step
    functions per call; this engine owns them across requests, keyed by
    nothing — prefill/decode are shape-polymorphic in batch via retrace, and
    XLA's jit cache bounds the variants to the distinct (batch, prompt_len)
    shapes actually served.
    """

    def __init__(self, arch_cfg):
        from repro.train.serve import make_decode_step, make_prefill_step

        self.cfg = arch_cfg
        self.steps = (
            jax.jit(make_prefill_step(arch_cfg)),
            jax.jit(make_decode_step(arch_cfg)),
        )

    def generate(self, params, prompt_tokens: Array, max_new_tokens: int) -> Array:
        """Whole-request greedy generation (the non-continuous path)."""
        from repro.train.serve import greedy_generate

        return greedy_generate(
            params, self.cfg, prompt_tokens, max_new_tokens, steps=self.steps
        )


# ---------------------------------------------------------------------------
# Continuous batching: decode-step-granular slot scheduling
# ---------------------------------------------------------------------------


class ContinuousLMEngine:
    """Continuous-batching LM engine over a fixed pool of decode slots.

    Instead of whole-request ``generate`` calls (the batch drains only when
    its longest request finishes), the pool's N slots all advance one token
    per ``decode_step`` — with a *vector* ``cache_len``, each slot at its own
    position — and a freed slot admits the next queued request on the very
    next step via ``insert`` (prefill the prompt at batch 1, scatter its
    KV/SSM state into the slot's row of the cache pool).

    Compile discipline mirrors ``ServeEngine``: prompts are right-padded to a
    geometric length ladder (``prompt_bucket_sizes``) so prefill compiles
    once per bucket, decode compiles ONCE for the whole pool, and ``warmup``
    AOT-compiles all of it so no request pays a trace.  Right-padding is only
    numerics-safe for attention patterns (causality masks the pad rows);
    recurrent mixers (SSM/RWKV) fold padding into their state, so those archs
    prefill at the exact prompt length — one compile per distinct length
    actually served.

    The decode step also returns the final hidden state of each slot's new
    token; the service samples the in-flight rows from it for the online
    decorrelation probes (``repro.decorr.probe.slot_probe_rows``).

    Orthogonal extensions over the PR 4 dense engine (each off by default,
    leaving the dense greedy path's compiled graphs untouched):

      * ``paged=True`` — the per-slot dense KV strips become fixed-size token
        pages addressed through block tables (``repro.serve.paging``): decode
        reads/writes gather/scatter over the tables (Pallas kernel on TPU via
        ``kernels/paged_attention``), admission reserves pages OOM-safely,
        retirement returns them and compacts.  SSM/RWKV state stays dense —
        paging is attention-only, dispatched per pattern position.  Greedy
        tokens are bit-identical to the dense engine when NB * page ==
        max_len (the engine rounds max_len up to a page multiple).
      * ``prefill_chunk=N`` (paged, attention-only patterns) — prompts longer
        than N prefill N tokens per service tick into the batch-1 template,
        interleaved with pool decode, so a long prompt no longer stalls
        in-flight slots for a whole prefill; the finished prompt is scattered
        into its pages like any other insert.
      * ``sampling=True`` — prefill/decode executables return LOGITS instead
        of in-jit argmax; the service draws tokens host-side per request
        (``repro.serve.sampling``: temperature/top-k, per-request PRNG;
        temperature 0 stays bit-identical greedy).
      * ``prefix_cache=True`` (paged) — retired prompts donate their full KV
        pages to a radix tree (``repro.serve.paging.radix``); a warm request
        binds the matched pages into its block table read-only (refcounted,
        reservation charges only the unshared tail), copy-on-writes the
        boundary page when the hit ends mid-page, and resumes chunked
        prefill at the hit — skipping the shared prefix's FLOPs entirely.
        Forces ``chunk_all`` (every prompt runs the chunked-prefill
        executable, warm or cold, resuming on the same chunk grid), which is
        what keeps tokens bit-identical to unshared paging: the hit is
        quantized DOWN to a chunk boundary (and to ``prompt_len - 1``), so a
        warm prefill replays the exact executables on the exact values the
        cold run would produce from that boundary on.
      * ``speculative=True`` (paged, greedy, attention-only) — each tick a
        per-slot n-gram drafter (``repro.serve.spec``) proposes up to
        ``draft_k`` tokens and ONE lane-batched verify forward (the decode
        executable at batch ``n_slots * (draft_k + 1)``) scores all draft
        positions at once; the longest draft prefix matching the model's own
        argmax is accepted, advancing a slot several tokens per tick.
        Speculative writes land on pinned scratch pages
        (``PagedKVManager.spec_begin``) so a rejected draft leaves no trace
        and speculation can never OOM an admitted slot; an accepted span
        commits by SWAPPING scratch into the block table — no device copy.
    """

    def __init__(
        self,
        arch_cfg,
        params,
        *,
        n_slots: int = 8,
        max_len: int = 128,
        max_prompt_len: Optional[int] = None,
        prompt_align: int = 8,
        reset_on_retire: bool = True,
        paged: bool = False,
        page_size: Optional[int] = None,
        total_pages: Optional[int] = None,
        prefill_chunk: Optional[int] = None,
        sampling: bool = False,
        compact_on_retire: bool = True,
        prefix_cache: bool = False,
        chunk_all: bool = False,
        speculative: bool = False,
        draft_k: int = 4,
        spec_ngram_max: int = 3,
        spec_ngram_min: int = 1,
    ):
        from repro.models.transformer import init_caches
        from repro.serve.slots import SlotPool
        from repro.train.serve import (
            apply_page_moves,
            insert_slot_state,
            insert_slot_state_paged,
            load_template_from_pages,
            make_chunked_prefill_step,
            make_decode_step,
            make_prefill_at_step,
            make_verify_step,
            reset_slot_state,
            reset_slot_state_paged,
        )

        if arch_cfg.frontend == "audio_codes":
            raise NotImplementedError(
                "continuous batching serves flat token streams; audio-code "
                "models ((B, S, n_q) tokens) go through LMServeEngine.generate"
            )
        self.cfg = arch_cfg
        self.params = params
        self.sampling_enabled = bool(sampling)
        self.reset_on_retire = reset_on_retire
        self.compact_on_retire = compact_on_retire
        # right-padded prompt buckets only where causality hides the padding
        self.pad_prompts = all(spec.mixer == "attn" for spec in arch_cfg.pattern)
        # optional flight recorder (repro.obs.FlightRecorder); the service
        # attaches its own so page-table churn lands in the same ring buffer
        # as the scheduler's admit/retire events
        self.recorder = None
        # per-executable attribution (repro.obs.ExecTimer); the service
        # attaches obs.perf when telemetry is enabled
        self.perf = None
        self._warmed_prefill: set = set()

        self.paged = bool(paged)
        self.prefix_cache = bool(prefix_cache)
        # chunk_all: every prompt (even <= one chunk) runs the chunked-prefill
        # executable.  Prefix caching forces it — warm resumption must land on
        # the same chunk grid the cold run used, or tokens drift.
        self.chunk_all = bool(chunk_all) or self.prefix_cache
        if self.prefix_cache and not self.paged:
            raise ValueError("prefix_cache shares KV pages; pass paged=True")
        self.speculative = bool(speculative)
        self.spec_cfg = None
        if self.speculative:
            from repro.serve.spec import SpecConfig

            if not self.paged:
                raise ValueError(
                    "speculative decoding verifies through scratch pages; pass paged=True"
                )
            if self.sampling_enabled:
                raise ValueError(
                    "speculative decoding is greedy-only: acceptance compares the "
                    "draft against argmax outputs (sampling would need rejection "
                    "sampling over the verify logits)"
                )
            if not self.pad_prompts:
                raise ValueError(
                    "speculative decoding needs attention-only patterns: SSM/RWKV "
                    "per-slot state cannot advance k+1 positions independently in "
                    "one forward"
                )
            self.spec_cfg = SpecConfig(
                draft_k=int(draft_k), ngram_max=int(spec_ngram_max),
                ngram_min=int(spec_ngram_min),
            )
        self.pager = None
        if self.paged:
            from repro.kernels.paged_attention.ops import auto_page_size
            from repro.kernels.pallas_utils import next_multiple
            from repro.serve.paging import PagedKVManager

            page = int(
                page_size
                or auto_page_size(n_slots, max_len, arch_cfg.n_kv_heads, arch_cfg.hd)
            )
            # NB * page == max_len keeps the gathered context view the exact
            # shape of the dense cache — that (plus masked rows' probability
            # mass underflowing to 0.0) is what makes paged greedy decode
            # bit-identical to the dense engine
            max_len = next_multiple(max_len, page)
            if self.prefix_cache and not prefill_chunk:
                prefill_chunk = page  # hit grid == page grid: COW only on cap
            self.pager = PagedKVManager(
                arch_cfg, n_slots, max_len, page, total_pages=total_pages,
                prefix_cache=self.prefix_cache,
                prefix_chunk=int(prefill_chunk) if self.prefix_cache else None,
                spec_draft_k=self.spec_cfg.draft_k if self.speculative else 0,
            )
            if self.prefix_cache:
                self.pager.event_sink = self._record

        self.prefill_chunk = int(prefill_chunk) if prefill_chunk else None
        if self.chunk_all and self.prefill_chunk is None:
            raise ValueError(
                "chunk_all rides chunked prefill; pass prefill_chunk (paged)"
            )
        if self.prefill_chunk is not None:
            if not self.paged:
                raise ValueError("prefill_chunk rides the paged machinery; pass paged=True")
            if not self.pad_prompts:
                raise ValueError(
                    "chunked prefill needs attention-only patterns (recurrent "
                    "mixers fold chunk padding into their state)"
                )
            if self.prefill_chunk < 1:
                raise ValueError(f"prefill_chunk must be >= 1, got {prefill_chunk}")

        self.pool = SlotPool(n_slots, max_len)
        max_prompt = int(max_prompt_len or max(max_len // 2, prompt_align))
        if max_prompt >= max_len:
            raise ValueError(f"max_prompt_len={max_prompt} must leave decode room (< max_len={max_len})")
        self._prompt_policy = BucketPolicy(max_batch=max_prompt, align=prompt_align, max_wait_ms=0.0)
        if self.pad_prompts and bucket_sizes(self._prompt_policy)[-1] > max_len:
            # the ladder rounds max_prompt_len UP to the alignment: a padded
            # prefill of the top bucket must still fit the slot's cache rows
            raise ValueError(
                f"padded prompt bucket {bucket_sizes(self._prompt_policy)[-1]} "
                f"(max_prompt_len={max_prompt} rounded up to align={prompt_align}) "
                f"exceeds max_len={max_len}; lower max_prompt_len or raise max_len"
            )
        if self.prefill_chunk is not None:
            tail = -(-max_prompt // self.prefill_chunk) * self.prefill_chunk
            if tail > max_len:
                raise ValueError(
                    f"chunked prefill of a max_prompt_len={max_prompt} prompt pads "
                    f"to {tail} template rows > max_len={max_len}; shrink prefill_chunk"
                )

        self.caches = self.pager.init_caches() if self.paged else init_caches(
            arch_cfg, n_slots, max_len
        )
        self._caches1 = init_caches(arch_cfg, 1, max_len)  # prefill template

        decode = make_decode_step(arch_cfg, return_hidden=True)

        def _pick(logits):
            if sampling:
                return logits  # host-side sampler draws per request
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)

        def _step(params, caches, cache_len, tokens):
            logits, hidden, caches = decode(params, caches, cache_len, tokens=tokens[:, None])
            return _pick(logits), hidden, caches

        def _step_paged(params, caches, cache_len, tokens, block_tables):
            logits, hidden, caches = decode(
                params, caches, cache_len, tokens=tokens[:, None], block_tables=block_tables
            )
            return _pick(logits), hidden, caches

        prefill_at = make_prefill_at_step(arch_cfg)

        def _pre(params, caches1, tokens, true_len):
            logits, hidden, caches1 = prefill_at(params, caches1, tokens, true_len)
            return _pick(logits), hidden, caches1

        # one decode executable for the whole pool; prefill one per bucket
        # (the jit caches below ARE the AOT cache `warmup` fills)
        self._decode = jax.jit(_step_paged if self.paged else _step, donate_argnums=(1,))
        self._prefill = jax.jit(_pre)
        if self.speculative:
            verify = make_verify_step(arch_cfg, return_hidden=True)

            def _verify_paged(params, caches, cache_len, tokens, block_tables,
                              move_src, move_dst):
                # boundary-page copies (one per drafted slot, sentinel
                # identity moves as padding) fused into the verify
                # executable: one device dispatch per tick, not two
                caches = apply_page_moves(caches, move_src, move_dst)
                logits, hidden, caches = verify(
                    params, caches, cache_len, tokens=tokens[:, None],
                    block_tables=block_tables,
                )
                return _pick(logits), hidden, caches

            self._verify = jax.jit(_verify_paged, donate_argnums=(1,))
        if self.paged:
            self._insert = jax.jit(insert_slot_state_paged, donate_argnums=(0,))
            self._reset = jax.jit(reset_slot_state_paged, donate_argnums=(0,))
            self._moves = jax.jit(apply_page_moves, donate_argnums=(0,))
            if self.prefix_cache:
                # warm-template gather (no donation: pool and template live on)
                self._loadtpl = jax.jit(load_template_from_pages)
                # one-deep plan memo from can_admit to admit_slot (same tick,
                # same head-of-line request — no allocation happens between)
                self._plan_stash: Tuple[Optional[int], Optional[object]] = (None, None)
        else:
            self._insert = jax.jit(insert_slot_state, donate_argnums=(0,))
            self._reset = jax.jit(reset_slot_state, donate_argnums=(0,))
        # chunked prefill: ONE in-progress (slot_index, live batch-1 tree) at
        # a time — chunks of different prompts serialize, decode interleaves
        self._chunk_live: Optional[list] = None
        if self.prefill_chunk is not None:
            chunk_step = make_chunked_prefill_step(arch_cfg)

            def _chunk(params, caches1, tokens, offset, last):
                logits, hidden, caches1 = chunk_step(params, caches1, tokens, offset, last)
                return _pick(logits), hidden, caches1

            self._chunk_step = jax.jit(_chunk)

    # -- admission-side shape policy ----------------------------------------

    def prompt_bucket_sizes(self) -> Tuple[int, ...]:
        """Prompt-padding bucket ladder, ascending."""
        return bucket_sizes(self._prompt_policy)

    @property
    def max_prompt_len(self) -> int:
        """Largest admissible prompt length (the top bucket)."""
        return self.prompt_bucket_sizes()[-1]

    def _prompt_bucket(self, n: int) -> int:
        return bucket_for(n, self._prompt_policy) if self.pad_prompts else n

    def validate_request(self, prompt_len: int, max_new_tokens: int):
        """Submit-time admission check: reject (never hang) what cannot be
        scheduled — empty prompts, prompts beyond the largest bucket, and
        requests that cannot fit the slot's cache rows (or, paged, could not
        get their pages even from an empty pool)."""
        if prompt_len < 1:
            raise ValueError("empty prompt: prompt_len must be >= 1")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if prompt_len > self.max_prompt_len:
            raise ValueError(
                f"prompt_len={prompt_len} exceeds the largest prompt bucket "
                f"({self.max_prompt_len}); rejecting instead of queueing unservable work"
            )
        # rows actually written: the final emitted token never lands in the
        # cache, so a request that exactly fills it is admissible
        rows = prompt_len + max_new_tokens - 1
        if rows > self.pool.max_len:
            raise ValueError(
                f"prompt_len + max_new_tokens - 1 = {rows} "
                f"exceeds the slot cache ({self.pool.max_len} rows)"
            )
        if self.paged and not self.pager.fits_ever(prompt_len, max_new_tokens):
            raise ValueError(
                f"request needs {self.pager.alloc.pages_for_tokens(rows)} pages "
                f"> the pool's {self.pager.alloc.usable_pages} usable pages; "
                "rejecting instead of queueing unservable work"
            )

    def can_admit(self, request) -> bool:
        """Decode-tick admission check beyond a free slot: paged pools also
        need the request's worst-case page reservation to fit RIGHT NOW
        (deferred, not rejected, otherwise — OOM-safe admission)."""
        if not self.paged:
            return True
        if self.prefix_cache:
            plan = self.pager.plan_prefix(request.tokens, request.prompt_len)
            self._plan_stash = (id(request), plan)
            return self.pager.can_admit(
                request.prompt_len, request.max_new_tokens, plan=plan
            )
        return self.pager.can_admit(request.prompt_len, request.max_new_tokens)

    # -- compile cache -------------------------------------------------------

    def warmup(self, prompt_lens=None) -> Tuple[int, ...]:
        """AOT-compile every prompt-bucket prefill variant, the pool decode
        step, the slot insert/reset (and, paged, the page-move / chunk-step
        executables) — so no admitted request traces.

        Attention-only patterns warm the whole padded bucket ladder.
        Recurrent patterns prefill at exact lengths, so callers that know
        their workload pass ``prompt_lens`` (distinct lengths to warm);
        unknown lengths still compile lazily at admission."""
        if self.pad_prompts:
            buckets = self.prompt_bucket_sizes()
        else:
            buckets = tuple(sorted(set(int(n) for n in prompt_lens or ())) or (1,))
        for length in buckets:
            toks = jnp.zeros((1, length), jnp.int32)
            if self.perf is not None:
                # AOT lower purely for attribution (HLO costs + compile
                # gauge); the executing jit cache below is untouched
                self.perf.attach_jit(
                    f"prefill_b{length}", self._prefill,
                    self.params, self._caches1, toks, np.int32(1),
                )
            _, _, one = self._prefill(self.params, self._caches1, toks, np.int32(1))
            self._warmed_prefill.add(int(length))
        nb = 0 if not self.paged else self.pager.blocks_per_slot
        if self.paged:
            # all-sentinel table rows: warmup writes land on the scratch page
            bt_row = jnp.zeros((nb,), jnp.int32)
            self.caches = self._insert(self.caches, one, np.int32(0), bt_row)
        else:
            self.caches = self._insert(self.caches, one, np.int32(0))
        lens = jnp.zeros((self.pool.n_slots,), jnp.int32)
        toks = jnp.zeros((self.pool.n_slots,), jnp.int32)
        if self.paged:
            bt = jnp.zeros((self.pool.n_slots, nb), jnp.int32)
            if self.perf is not None:
                self.perf.attach_jit(
                    "decode_step", self._decode, self.params, self.caches, lens, toks, bt
                )
            _, _, self.caches = self._decode(self.params, self.caches, lens, toks, bt)
            self.caches = self._reset(self.caches, np.int32(0), bt_row)
            if self.compact_on_retire or self.prefix_cache:
                # compaction AND the prefix COW reuse the same executable
                idx = jnp.zeros((nb,), jnp.int32)
                self.caches = self._moves(self.caches, idx, idx)
            if self.prefix_cache:
                # warm-template gather (all-sentinel row reads scratch rows)
                self._loadtpl(self.caches, self._caches1, bt_row)
            if self.speculative:
                # the verify executable is the SAME jitted decode step at the
                # lane-batched shape n_slots * (draft_k + 1): each lane is a
                # plain one-token decode at its own (cache_len, table row)
                width = self.spec_cfg.draft_k + 1
                vb = self.pool.n_slots * width
                vlens = jnp.zeros((vb,), jnp.int32)
                vtoks = jnp.zeros((vb,), jnp.int32)
                vbt = jnp.zeros((vb, nb), jnp.int32)
                # boundary-page copies ride inside the verify executable,
                # one (sentinel-padded) move per slot
                sidx = jnp.zeros((self.pool.n_slots,), jnp.int32)
                if self.perf is not None:
                    self.perf.attach_jit(
                        "verify_step", self._verify,
                        self.params, self.caches, vlens, vtoks, vbt, sidx, sidx,
                    )
                _, _, self.caches = self._verify(
                    self.params, self.caches, vlens, vtoks, vbt, sidx, sidx
                )
        else:
            if self.perf is not None:
                self.perf.attach_jit(
                    "decode_step", self._decode, self.params, self.caches, lens, toks
                )
            _, _, self.caches = self._decode(self.params, self.caches, lens, toks)
            self.caches = self._reset(self.caches, np.int32(0))
        if self.prefill_chunk is not None:
            ctoks = jnp.zeros((1, self.prefill_chunk), jnp.int32)
            if self.perf is not None:
                self.perf.attach_jit(
                    "chunk_prefill", self._chunk_step,
                    self.params, self._caches1, ctoks, np.int32(0), np.int32(0),
                )
            self._chunk_step(self.params, self._caches1, ctoks, np.int32(0), np.int32(0))
        return buckets

    # -- slot mechanics ------------------------------------------------------

    def needs_chunking(self, prompt_len: int) -> bool:
        """True when this prompt prefills chunk-at-a-time."""
        if self.prefill_chunk is None:
            return False
        return self.chunk_all or prompt_len > self.prefill_chunk

    def admit_slot(self, slot) -> int:
        """Post-``pool.admit`` hook: charge the paged reservation (binding +
        pinning any matched prefix pages) and flag chunked prompts as
        still-prefilling.  Returns the prefix-cache hit in rows — chunked
        prefill resumes there (0 cold/unshared)."""
        req = slot.request
        hit = 0
        if self.paged:
            if self.prefix_cache:
                key, plan = self._plan_stash
                if key != id(req):
                    plan = self.pager.plan_prefix(req.tokens, req.prompt_len)
                self._plan_stash = (None, None)
                hit = self.pager.admit(
                    slot.index, req.prompt_len, req.max_new_tokens, plan=plan
                )
            else:
                self.pager.admit(slot.index, req.prompt_len, req.max_new_tokens)
        if self.needs_chunking(req.prompt_len):
            slot.prefill_pos = hit
        if self.speculative:
            from repro.serve.spec import SlotDraft

            slot.draft = SlotDraft(self.spec_cfg, np.asarray(req.tokens).tolist())
        return hit

    def _record(self, kind: str, **fields):
        if self.recorder is not None:
            self.recorder.record(kind, **fields)

    def _scatter_insert(self, slot, one):
        if self.paged:
            added = self.pager.ensure_rows(slot.index, slot.request.prompt_len)
            if added:
                self._record("page_alloc", slot=slot.index, pages=len(added),
                             in_use=self.pager.alloc.in_use)
            if self.prefix_cache:
                # shared prefix blocks are masked to the sentinel: the insert
                # must never rewrite a read-only shared page
                row = self.pager.scatter_row(slot.index)
            else:
                row = self.pager.table_row(slot.index)
            bt_row = jnp.asarray(row)
            self.caches = self._insert(self.caches, one, np.int32(slot.index), bt_row)
            if self.prefix_cache:
                # the pages now hold the final prompt KV: intern the full
                # prompt pages for future warm requests (first writer wins)
                donated = self.pager.donate(slot.index, slot.request.tokens)
                if donated:
                    self._record("page_donate", slot=slot.index, pages=donated)
        else:
            self.caches = self._insert(self.caches, one, np.int32(slot.index))

    def _first_output(self, out, hidden):
        first = np.asarray(out)[0] if self.sampling_enabled else int(out[0])
        return first, np.asarray(hidden, np.float32)

    def insert(self, slot):
        """Prefill an admitted request and scatter its state into the slot.
        Returns (first output, its hidden-state row (1, d_model)) — the
        prefill already emits the request's first token (TTFT point); with
        ``sampling`` the first output is the (V,) logits row the service
        samples from instead of the token id."""
        req = slot.request
        n = req.prompt_len
        length = self._prompt_bucket(n)
        perf = self.perf
        if perf is not None:
            name = f"prefill_b{length}"
            if int(length) in self._warmed_prefill:
                perf.cache_hit(name)
            else:
                perf.cache_miss(name)
                self._warmed_prefill.add(int(length))
            t0 = perf.start()
        padded = np.zeros((1, length), np.int32)
        padded[0, :n] = np.asarray(req.tokens, np.int32)
        out, hidden, one = self._prefill(
            self.params, self._caches1, jnp.asarray(padded), np.int32(n)
        )
        self._scatter_insert(slot, one)
        result = self._first_output(out, hidden)  # np.asarray syncs the device
        if perf is not None:
            perf.observe(f"prefill_b{length}", perf.elapsed(t0))
        return result

    def advance_prefill(self, slot):
        """Run ONE chunk of the slot's incremental prefill.  Returns None
        while the prompt is still streaming in; on the final chunk, scatters
        the finished state into the slot's pages and returns the same
        (first output, hidden row) contract as ``insert``.

        Only one chunked prefill is live at a time (the batch-1 work tree);
        other still-prefilling slots wait their turn while decode proceeds.
        """
        req = slot.request
        n, c = req.prompt_len, self.prefill_chunk
        if self._chunk_live is None:
            tree = self._caches1
            if self.prefix_cache:
                moves = self.pager.cow_moves(slot.index)
                if moves is not None:
                    # copy-on-write of the boundary page BEFORE the template
                    # gather reads it: writes never land on shared pages
                    src, dst = moves
                    self.caches = self._moves(
                        self.caches, jnp.asarray(src), jnp.asarray(dst)
                    )
                    self._record("page_cow", slot=slot.index,
                                 src=int(src[0]), dst=int(dst[0]))
                if slot.prefill_pos > 0:
                    # warm start: seed the batch-1 template with the shared
                    # prefix's KV rows so chunks attend over them unrecomputed
                    row = jnp.asarray(self.pager.table_row(slot.index))
                    tree = self._loadtpl(self.caches, self._caches1, row)
                    self._record("page_share", slot=slot.index,
                                 rows=slot.prefill_pos,
                                 pages=self.pager.alloc.shared_count(slot.index))
            self._chunk_live = [slot.index, tree]
        if self._chunk_live[0] != slot.index:
            return None  # another prompt owns the work tree this tick
        perf = self.perf
        t0 = perf.start() if perf is not None else 0.0
        off = slot.prefill_pos
        take = min(c, n - off)
        padded = np.zeros((1, c), np.int32)
        padded[0, :take] = np.asarray(req.tokens[off : off + take], np.int32)
        out, hidden, tree = self._chunk_step(
            self.params, self._chunk_live[1], jnp.asarray(padded),
            np.int32(off), np.int32(take - 1),
        )
        self._chunk_live[1] = tree
        slot.prefill_pos = off + take
        if slot.prefilling:
            if perf is not None:
                jax.block_until_ready(tree)  # mid-prompt chunks return no host value
                perf.observe("chunk_prefill", perf.elapsed(t0))
            return None
        self._scatter_insert(slot, tree)
        self._chunk_live = None
        result = self._first_output(out, hidden)
        if perf is not None:
            perf.observe("chunk_prefill", perf.elapsed(t0))
        return result

    def prefilling_slot(self):
        """The still-prefilling slot whose chunk should advance this tick:
        the owner of the live work tree, else the oldest waiting one."""
        waiting = [s for s in self.pool.active() if s.prefilling]
        if not waiting:
            return None
        if self._chunk_live is not None:
            for s in waiting:
                if s.index == self._chunk_live[0]:
                    return s
        return waiting[0]

    def decode_step(self) -> Tuple[np.ndarray, np.ndarray]:
        """One batched decode over the whole pool.  Returns (next output per
        slot — (N,) token ids, or (N, V) logits under ``sampling`` — and
        hidden rows (N, d_model)); free-slot and still-prefilling lanes are
        garbage the caller must mask by ``pool.decoding_indices()``."""
        perf = self.perf
        t0 = perf.start() if perf is not None else 0.0
        lens = jnp.asarray(self.pool.cache_lens())
        toks = jnp.asarray(self.pool.last_tokens())
        if self.paged:
            for i in self.pool.decoding_indices():
                # lazy page growth: bind the write target's page (cannot
                # fail — admission reserved the worst case)
                added = self.pager.ensure_rows(i, self.pool[i].pos + 1)
                if added:
                    self._record("page_alloc", slot=i, pages=len(added),
                                 in_use=self.pager.alloc.in_use)
            tables = self.pager.block_tables()
            if self.prefix_cache:
                # still-prefilling slots decode at lane position 0, and the
                # paged write path unconditionally scatters each lane's k/v at
                # block_tables[slot, 0] row 0.  Unshared, those tables are
                # still empty (the write lands on the sentinel); with prefix
                # pages bound at admission it would CORRUPT a shared page —
                # mask every non-decoding lane's row to the sentinel.
                decoding = set(self.pool.decoding_indices())
                for i in range(self.pool.n_slots):
                    if i not in decoding:
                        tables[i, :] = 0  # SENTINEL
            bt = jnp.asarray(tables)
            out, hidden, self.caches = self._decode(self.params, self.caches, lens, toks, bt)
        else:
            out, hidden, self.caches = self._decode(self.params, self.caches, lens, toks)
        result = (np.asarray(out), np.asarray(hidden, np.float32))  # host sync
        if perf is not None:
            perf.observe("decode_step", perf.elapsed(t0))
        return result

    # -- speculative decoding -------------------------------------------------

    def spec_verify(self, drafts):
        """One lane-batched speculative verify over the whole pool.

        ``drafts`` is a list of ``(slot_index, draft_tokens)`` covering every
        decoding slot this tick (``draft_tokens`` may be empty: that slot
        rides lane 0 only, which is exactly its plain decode step).  Lane
        ``(s, j)`` of the fixed ``n_slots * (draft_k + 1)`` batch decodes
        slot ``s`` at ``cache_len = pos + j`` with input token ``last_token``
        (j = 0) or ``draft[j - 1]`` — per-lane math identical to the pool
        decode step, which is what keeps greedy outputs bit-identical to
        sequential decode.  Drafted slots read/write through scratch-mapped
        table rows (``PagedKVManager.spec_begin``); unused lanes are masked
        like free pool lanes (cache_len 0, sentinel rows).

        Returns ``(out, hidden, tickets)``: ``(n_slots, draft_k + 1)`` token
        ids, ``(n_slots, draft_k + 1, d_model)`` hidden rows, and the per-slot
        scratch tickets the caller must settle via ``spec_commit`` (always —
        lane 0's write is real even when the whole draft is rejected) or
        ``spec_rollback`` (error/abort paths only).
        """
        width = self.spec_cfg.draft_k + 1
        nb = self.pager.blocks_per_slot
        n = self.pool.n_slots
        lens = np.zeros((n * width,), np.int32)
        toks = np.zeros((n * width,), np.int32)
        tables = np.zeros((n * width, nb), np.int32)  # sentinel-masked lanes
        tickets = {}
        copies = []
        for slot_index, draft in drafts:
            s = self.pool[slot_index]
            k_eff = len(draft)
            if k_eff:
                ticket, moves = self.pager.spec_begin(slot_index, s.pos, k_eff)
                tickets[slot_index] = ticket
                copies.extend(moves)
                row = ticket.row
            else:
                # undrafted slot: plain decode through its REAL table row
                added = self.pager.ensure_rows(slot_index, s.pos + 1)
                if added:
                    self._record("page_alloc", slot=slot_index, pages=len(added),
                                 in_use=self.pager.alloc.in_use)
                row = self.pager.table_row(slot_index)
            base = slot_index * width
            for j in range(k_eff + 1):
                lens[base + j] = s.pos + j
                toks[base + j] = s.last_token if j == 0 else draft[j - 1]
                tables[base + j] = row
        perf = self.perf
        t0 = perf.start() if perf is not None else 0.0
        # boundary-page copies, one lane per slot (zeros are sentinel ->
        # sentinel identity no-ops), fused into the verify executable
        src = np.zeros((n,), np.int32)
        dst = np.zeros((n,), np.int32)
        for i, (a, b) in enumerate(copies):
            src[i], dst[i] = a, b
        try:
            out, hidden, self.caches = self._verify(
                self.params, self.caches, jnp.asarray(lens), jnp.asarray(toks),
                jnp.asarray(tables), jnp.asarray(src), jnp.asarray(dst),
            )
        except Exception:
            # a failed device step must not leak the scratch inventory
            for ticket in tickets.values():
                self.pager.spec_rollback(ticket)
            raise
        result = (
            np.asarray(out).reshape(n, width),
            np.asarray(hidden, np.float32).reshape(n, width, -1),
            tickets,
        )
        if perf is not None:
            perf.observe("verify_step", perf.elapsed(t0))
        return result

    def spec_commit(self, ticket, n_written: int):
        """Promote ``n_written`` verified rows into the slot's block table
        (pure table swap — no device copy on the accept path)."""
        self.pager.spec_commit(ticket, n_written)

    def spec_rollback(self, ticket):
        """Discard a speculative window, restoring table state exactly."""
        self.pager.spec_rollback(ticket)

    def abort_slot(self, index: int):
        """Host-side-only cleanup for a slot whose device step failed: drop
        any in-progress chunked prefill it owns and hand back its pages +
        reservation.  No device ops — the device may be wedged, and a stale
        ``_chunk_live`` would otherwise wedge every later chunked prefill
        on a reused slot index."""
        if self._chunk_live is not None and self._chunk_live[0] == index:
            self._chunk_live = None
        if self.paged:
            before = self.pager.alloc.in_use
            self.pager.release(index)
            self._record("page_free", slot=index, abort=True,
                         pages=before - self.pager.alloc.in_use,
                         in_use=self.pager.alloc.in_use)

    def release(self, index: int):
        """Retire a slot: zero its cache rows (hygiene; decode masks them),
        return its pages + reservation, and compact the page pool
        (copy-on-retire: the highest in-use pages relocate into the freed
        low holes, keeping the live frontier tight)."""
        if self._chunk_live is not None and self._chunk_live[0] == index:
            self._chunk_live = None
        if self.paged:
            if self.reset_on_retire:
                # under prefix caching, pages other owners still map (shared
                # prefixes, donated pages) are masked out of the zeroing
                row = (self.pager.reset_row(index) if self.prefix_cache
                       else self.pager.table_row(index))
                self.caches = self._reset(self.caches, np.int32(index), jnp.asarray(row))
            before = self.pager.alloc.in_use
            self.pager.release(index)
            self._record("page_free", slot=index,
                         pages=before - self.pager.alloc.in_use,
                         in_use=self.pager.alloc.in_use)
            if self.compact_on_retire:
                src, dst = self.pager.plan_compaction()
                if src.size:
                    self._record("page_compact", moves=int((src != dst).sum()))
                    self.caches = self._moves(
                        self.caches, jnp.asarray(src), jnp.asarray(dst)
                    )
            return
        if self.reset_on_retire:
            self.caches = self._reset(self.caches, np.int32(index))
