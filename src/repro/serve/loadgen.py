"""Load generators + policy comparisons for the serving subsystem.

Deterministic synthetic traffic (seeded inputs, seeded exponential
inter-arrivals) driven through competing serving policies:

Embedding path (``compare_policies``):

  * ``naive``       — one engine call per request, no coalescing: the
    baseline ``launch/serve.py``-style loop every request pays alone;
  * ``microbatch``  — requests submitted to the ``EmbeddingService`` and
    coalesced by the admission policy into bucketed batches.

LM path (``compare_lm_policies``), on a mixed-length workload:

  * ``whole_request`` — PR 3's ``LMServeEngine.generate`` loop: each request
    generates end-to-end on its own before the next one starts;
  * ``continuous``    — the same requests through ``LMService`` /
    ``ContinuousLMEngine``: slot-pool decode-step interleaving.

All report per-request p50/p99 latency and sustained throughput; the bench
harness (``benchmarks/bench_serve.py``) and the CLI smoke
(``python -m repro.serve.cli``) are thin wrappers over these.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serve.buckets import BucketPolicy
from repro.serve.engine import ServeEngine
from repro.serve.probes import DecorrProbe
from repro.serve.service import EmbeddingService


@dataclasses.dataclass(frozen=True)
class LoadConfig:
    """Closed-loop embedding workload knobs (deterministic by seed)."""
    n_requests: int = 256
    input_dim: int = 64
    arrival_rps: Optional[float] = None  # None = closed-loop burst (max load)
    seed: int = 0


def request_stream(cfg: LoadConfig):
    """Deterministic (inputs, inter-arrival gaps) for one load run."""
    rng = np.random.default_rng(cfg.seed)
    xs = rng.standard_normal((cfg.n_requests, cfg.input_dim)).astype(np.float32)
    if cfg.arrival_rps:
        gaps = rng.exponential(1.0 / cfg.arrival_rps, cfg.n_requests)
    else:
        gaps = np.zeros(cfg.n_requests)
    return xs, gaps


def _summary(latencies_s: List[float], wall_s: float) -> Dict[str, float]:
    lat = np.asarray(latencies_s)
    return {
        "requests": float(len(lat)),
        "p50_ms": float(np.percentile(lat, 50) * 1e3),
        "p99_ms": float(np.percentile(lat, 99) * 1e3),
        "throughput_rps": len(lat) / max(wall_s, 1e-9),
        "wall_s": wall_s,
    }


def _trace_latencies(futures) -> List[float]:
    """Per-request latency read from the request's ``RequestTrace`` marks —
    the one timing source the service, the tracer export and this load
    generator all share (falls back to the future's own stamp only for
    futures that never went through a service ``submit``)."""
    out = []
    for f in futures:
        tr = getattr(f, "trace", None)
        lat = tr.latency_s if tr is not None else f.latency_s
        if lat is not None:
            out.append(lat)
    return out


def _trace_ttfts(futures) -> List[float]:
    out = []
    for f in futures:
        tr = getattr(f, "trace", None)
        if tr is not None and tr.ttft_s is not None:
            out.append(tr.ttft_s)
    return out


def run_naive(engine: ServeEngine, load: LoadConfig, probe: Optional[DecorrProbe] = None) -> Dict[str, float]:
    """Per-request serving: every request is its own (bucket-1) dispatch."""
    xs, gaps = request_stream(load)
    # warm the single-row bucket so compile time is not billed to requests
    engine.encode(xs[0]).block_until_ready()
    lat: List[float] = []
    t_run = time.perf_counter()
    for i in range(load.n_requests):
        if gaps[i]:
            time.sleep(gaps[i])
        t0 = time.perf_counter()
        z = engine.encode(xs[i])
        z.block_until_ready()
        lat.append(time.perf_counter() - t0)
        if probe is not None and (i + 1) % 64 == 0:
            probe.update(z)
    return _summary(lat, time.perf_counter() - t_run)


def run_microbatched(
    service: EmbeddingService, load: LoadConfig, timeout_s: float = 120.0
) -> Dict[str, float]:
    """Open-loop submission into the started service's dispatch thread."""
    xs, gaps = request_stream(load)
    # warm every bucket + the probe so no request pays a trace
    service.warmup()
    futures = []
    t_run = time.perf_counter()
    for i in range(load.n_requests):
        if gaps[i]:
            time.sleep(gaps[i])
        futures.append(service.submit(xs[i], block=True, timeout=timeout_s))
    results = [f.result(timeout=timeout_s) for f in futures]
    wall = time.perf_counter() - t_run
    assert all(r.shape == (service.engine.d,) for r in results)
    out = _summary(_trace_latencies(futures), wall)
    out["mean_batch"] = service.stats.served / max(service.stats.batches, 1)
    out["batches"] = float(service.stats.batches)
    return out


def compare_policies(
    engine_fn,
    load: LoadConfig,
    policy: BucketPolicy,
    probe_fn=None,
    obs=None,
) -> Dict[str, Dict[str, float]]:
    """Run naive then micro-batched on FRESH engines (cold, comparable compile
    caches).  ``engine_fn() -> ServeEngine``; ``probe_fn() -> DecorrProbe``
    (optional; the micro-batched run feeds it every dispatched batch);
    ``obs`` an ``repro.obs.Obs`` bundle for the micro-batched service."""
    naive = run_naive(engine_fn(), load)

    probe = probe_fn() if probe_fn is not None else None
    service = EmbeddingService(engine_fn(), policy=policy, probe=probe, obs=obs).start()
    try:
        micro = run_microbatched(service, load)
        metrics = service.metrics()
    finally:
        service.stop()
    out = {"naive": naive, "microbatch": micro, "service_metrics": metrics}
    out["gate"] = {
        "microbatch_beats_naive": micro["throughput_rps"] >= naive["throughput_rps"],
        "speedup": micro["throughput_rps"] / max(naive["throughput_rps"], 1e-9),
    }
    return out


# ---------------------------------------------------------------------------
# LM path: whole-request generate vs continuous batching
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LMLoadConfig:
    """Mixed-length LM workload: request i draws its prompt length and token
    budget round-robin from the ladders below (deterministic given seed)."""

    n_requests: int = 24
    prompt_lens: Tuple[int, ...] = (4, 8, 14, 24)
    new_tokens: Tuple[int, ...] = (4, 12, 20)
    seed: int = 0

    def request_stream(self, vocab_size: int) -> List[Tuple[np.ndarray, int]]:
        """Deterministic ``(tokens, max_new)`` request list."""
        rng = np.random.default_rng(self.seed)
        out = []
        for i in range(self.n_requests):
            s = self.prompt_lens[i % len(self.prompt_lens)]
            m = self.new_tokens[(i // len(self.prompt_lens)) % len(self.new_tokens)]
            out.append((rng.integers(0, vocab_size, size=s).astype(np.int32), int(m)))
        return out

    @property
    def max_request_len(self) -> int:
        """Worst-case rows one request needs (prompt + new tokens)."""
        return max(self.prompt_lens) + max(self.new_tokens)


def _lm_summary(latencies_s: List[float], tokens: int, wall_s: float) -> Dict[str, float]:
    out = _summary(latencies_s, wall_s)
    out["tokens"] = float(tokens)
    out["tok_per_s"] = tokens / max(wall_s, 1e-9)
    return out


def run_whole_request(
    engine, params, load: LMLoadConfig, max_len: int
) -> Tuple[Dict[str, float], List[np.ndarray]]:
    """The PR 3 LM serving regime: each request runs ``greedy_generate`` to
    completion (batch 1) before the next starts.  ``max_len`` is pinned for
    every request so the decode step compiles once (same cache shape the
    continuous engine uses); a full untimed pass warms all prompt shapes."""
    import jax
    import jax.numpy as jnp

    from repro.train.serve import greedy_generate

    stream = load.request_stream(engine.cfg.vocab_size)

    def one(tokens: np.ndarray, max_new: int):
        """Whole-request greedy oracle for a single prompt."""
        return greedy_generate(
            params, engine.cfg, jnp.asarray(tokens[None]), max_new,
            max_len=max_len, steps=engine.steps,
        )

    for tokens, max_new in stream:  # warm every (prompt_len,) prefill variant
        jax.block_until_ready(one(tokens, max_new))
    lat, outs, n_tok = [], [], 0
    t_run = time.perf_counter()
    for tokens, max_new in stream:
        t0 = time.perf_counter()
        out = one(tokens, max_new)
        jax.block_until_ready(out)
        lat.append(time.perf_counter() - t0)
        outs.append(np.asarray(out[0]))
        n_tok += int(out.shape[1])
    return _lm_summary(lat, n_tok, time.perf_counter() - t_run), outs


def run_continuous(service, load: LMLoadConfig, timeout_s: float = 300.0):
    """The same workload through the continuous-batching service: all
    requests submitted up front (closed-loop burst), drained by synchronous
    decode-step ticks.  Returns (summary, per-request outputs)."""
    stream = load.request_stream(service.engine.cfg.vocab_size)
    service.warmup(prompt_lens=[t.shape[0] for t, _ in stream])
    futures = []
    t_run = time.perf_counter()
    for tokens, max_new in stream:
        futures.append(service.submit(tokens, max_new, block=True, timeout=timeout_s))
    service.drain()
    outs = [f.result(timeout=timeout_s) for f in futures]
    wall = time.perf_counter() - t_run
    n_tok = sum(len(o) for o in outs)
    summary = _lm_summary(_trace_latencies(futures), n_tok, wall)
    ttfts = _trace_ttfts(futures)
    if ttfts:
        summary["ttft_p50_ms"] = float(np.percentile(ttfts, 50) * 1e3)
        summary["ttft_p99_ms"] = float(np.percentile(ttfts, 99) * 1e3)
    return summary, outs


def compare_lm_policies(
    arch_cfg,
    params,
    load: LMLoadConfig,
    *,
    n_slots: int = 8,
    max_len: Optional[int] = None,
    probe_fn=None,
    record_probe_rows: bool = False,
    engine_kw: Optional[Dict] = None,
    obs=None,
) -> Dict[str, Dict[str, float]]:
    """Whole-request generate vs continuous batching on one mixed-length
    workload.  Also cross-checks correctness: both policies must emit
    IDENTICAL token streams per request (greedy decoding is deterministic;
    slot interleaving must not change any request's result).  ``engine_kw``
    forwards continuous-engine extensions (``paged=True``, ``page_size``,
    ``prefill_chunk``, ...) — the token-identity gate applies to them too."""
    from repro.serve.engine import ContinuousLMEngine, LMServeEngine
    from repro.serve.service import LMService

    max_len = int(max_len or max(load.max_request_len + 8, 32))
    engine = ContinuousLMEngine(
        arch_cfg, params, n_slots=n_slots, max_len=max_len,
        max_prompt_len=max(load.prompt_lens), **(engine_kw or {}),
    )
    # the paged engine rounds max_len up to a page multiple; the oracle must
    # decode at the SAME cache extent or reduction shapes (and, potentially,
    # last-ulp tie-breaks) diverge from the bit-identity the gate demands
    max_len = engine.pool.max_len
    whole_engine = LMServeEngine(arch_cfg)
    whole, whole_outs = run_whole_request(whole_engine, params, load, max_len)

    probe = probe_fn() if probe_fn is not None else None
    service = LMService(engine, probe=probe, record_probe_rows=record_probe_rows, obs=obs)
    cont, cont_outs = run_continuous(service, load)
    metrics = service.metrics()

    mismatches = sum(
        1 for a, b in zip(whole_outs, cont_outs) if not np.array_equal(a, b)
    )
    out = {
        "whole_request": whole,
        "continuous": cont,
        "service_metrics": metrics,
        "gate": {
            "continuous_beats_whole_request": cont["tok_per_s"] >= whole["tok_per_s"],
            "speedup": cont["tok_per_s"] / max(whole["tok_per_s"], 1e-9),
            "token_mismatches": float(mismatches),
        },
    }
    if record_probe_rows:
        err = lm_probe_oracle_err(service)
        if err is not None:
            out["gate"]["probe_oracle_rel_err"] = err
    return out


def compare_paged_dense(
    arch_cfg,
    params,
    load: LMLoadConfig,
    *,
    n_slots: int = 8,
    max_len: Optional[int] = None,
    page_size: int = 16,
    prefill_chunk: Optional[int] = None,
) -> Dict[str, Dict[str, float]]:
    """Dense vs paged continuous batching on one (typically length-skewed)
    workload: identical greedy tokens per request, tok/s for both, and the
    memory story — the paged pool's PEAK allocated cache bytes against the
    dense pool's permanent ``n_slots * max_len`` row reservation.  A second
    paged run with chunked prefill reports its own tokens/mismatches (the
    chunk boundary changes prefill einsum shapes, so that run is argmax-
    stable rather than bit-pinned — mismatches are reported, the hard gate
    rides the unchunked run)."""
    from repro.serve.engine import ContinuousLMEngine
    from repro.serve.paging import dense_cache_bytes
    from repro.serve.service import LMService

    max_len = int(max_len or max(load.max_request_len + 8, 32))
    max_len = -(-max_len // page_size) * page_size  # identical shapes both ways

    def run(**engine_kw):
        """One continuous-batching measurement with the given engine knobs."""
        engine = ContinuousLMEngine(
            arch_cfg, params, n_slots=n_slots, max_len=max_len,
            max_prompt_len=max(load.prompt_lens), **engine_kw,
        )
        service = LMService(engine)
        summary, outs = run_continuous(service, load)
        return summary, outs, service

    dense, dense_outs, _ = run()
    paged, paged_outs, paged_svc = run(paged=True, page_size=page_size)
    mismatches = sum(
        1 for a, b in zip(dense_outs, paged_outs) if not np.array_equal(a, b)
    )
    dense_bytes = dense_cache_bytes(arch_cfg, n_slots, max_len)
    peak_bytes = paged_svc.engine.pager.peak_cache_bytes()
    out = {
        "dense": dict(dense, cache_bytes=float(dense_bytes)),
        "paged": dict(paged, **paged_svc.engine.pager.metrics()),
        "gate": {
            "token_mismatches": float(mismatches),
            "paged_peak_lt_dense": bool(peak_bytes < dense_bytes),
            "peak_cache_bytes_ratio": peak_bytes / max(dense_bytes, 1),
            "tok_per_s_ratio": paged["tok_per_s"] / max(dense["tok_per_s"], 1e-9),
        },
    }
    if prefill_chunk:
        chunked, chunked_outs, chunked_svc = run(
            paged=True, page_size=page_size, prefill_chunk=prefill_chunk
        )
        out["paged_chunked"] = dict(
            chunked,
            token_mismatches=float(
                sum(1 for a, b in zip(dense_outs, chunked_outs) if not np.array_equal(a, b))
            ),
            ttft_p50_ms=chunked_svc.metrics()["ttft_p50_ms"],
        )
    return out


def compare_speculative(
    arch_cfg,
    params,
    load: LMLoadConfig,
    *,
    n_slots: int = 8,
    max_len: Optional[int] = None,
    page_size: int = 16,
    draft_k: int = 4,
    spec_ngram_max: int = 3,
    spec_ngram_min: int = 1,
    obs=None,
) -> Dict[str, Dict[str, float]]:
    """Plain paged vs self-drafting speculative decode on one decode-heavy
    workload.  Both runs execute the same paged engine; the speculative run
    adds the n-gram drafter and the lane-batched verify forward.  Greedy
    verification means tokens must be BIT-IDENTICAL per request — that is the
    hard gate — while the perf story is tokens/step: a verify that accepts
    draft tokens emits more than one token per tick, so ``accepted_tokens``
    (mean tokens per verify step) above 1 plus tok/s at least matching the
    plain run is what speculation must deliver to pay for itself."""
    from repro.serve.engine import ContinuousLMEngine
    from repro.serve.service import LMService

    max_len = int(max_len or max(load.max_request_len + 8, 32))
    max_len = -(-max_len // page_size) * page_size  # identical shapes both ways

    def build(**engine_kw):
        """Construct a paged service (plain or speculative) for one run."""
        engine = ContinuousLMEngine(
            arch_cfg, params, n_slots=n_slots, max_len=max_len,
            max_prompt_len=max(load.prompt_lens), paged=True,
            page_size=page_size, **engine_kw,
        )
        return LMService(engine, obs=obs if engine_kw else None)

    plain_svc = build()
    spec_svc = build(
        speculative=True, draft_k=draft_k,
        spec_ngram_max=spec_ngram_max, spec_ngram_min=spec_ngram_min,
    )
    # interleaved best-of-3: CPU wall clock is noisy at this scale and
    # drifts over a run — alternating passes samples both policies under the
    # same load conditions, and tokens are deterministic on every pass
    plain = spec = plain_outs = spec_outs = None
    for _ in range(3):
        p, p_outs = run_continuous(plain_svc, load)
        if plain is None or p["tok_per_s"] > plain["tok_per_s"]:
            plain, plain_outs = p, p_outs
        s, s_outs = run_continuous(spec_svc, load)
        if spec is None or s["tok_per_s"] > spec["tok_per_s"]:
            spec, spec_outs = s, s_outs
    mismatches = sum(
        1 for a, b in zip(plain_outs, spec_outs) if not np.array_equal(a, b)
    )
    sm = spec_svc.spec_stats
    out = {
        "plain": plain,
        "speculative": dict(spec, **sm.metrics()),
        "gate": {
            "token_mismatches": float(mismatches),
            "spec_beats_plain": bool(spec["tok_per_s"] >= plain["tok_per_s"]),
            "tok_per_s_ratio": spec["tok_per_s"] / max(plain["tok_per_s"], 1e-9),
            "accepted_tokens_per_step": sm.accepted_per_step(),
            # per slot-lane: > 1 means a slot on a verify tick emitted more
            # than the single token plain decode would have
            "tokens_per_lane": sm.tokens_emitted / max(sm.slot_lanes, 1),
            "draft_hit_rate": sm.hit_rate(),
            "acceptance_rate": sm.acceptance_rate(),
        },
    }
    return out


@dataclasses.dataclass(frozen=True)
class SharedPrefixLoadConfig:
    """Shared-prefix LM workload (the RAG / few-shot / system-prompt shape):
    ``n_prefixes`` distinct long prefixes, each fanned out to ``fan_out``
    requests appending a short unique tail.  The stream is two-phase: one
    COLD request per prefix first (its retire donates the prefix pages to the
    radix cache when sharing is on), then the WARM fan-out whose TTFT the
    comparison gates on."""

    # Defaults are shaped so the comparison actually stresses sharing: decode
    # long enough (vs the serialized chunk-at-a-time prefill) that slots
    # overlap in BOTH runs, and a prefix long enough that the per-slot pages
    # saved by sharing dominate what the radix cache retains.  prefix_len=92
    # with page 16 / chunk 8 also exercises copy-on-write: a cold tail can
    # extend the donated pages past the common prefix, so a warm hit lands
    # mid-page (h=88) and must COW the boundary page.
    n_prefixes: int = 2
    fan_out: int = 7
    prefix_len: int = 92
    tail_lens: Tuple[int, ...] = (3, 5, 9)
    new_tokens: Tuple[int, ...] = (32, 48)
    seed: int = 0

    def request_stream(
        self, vocab_size: int
    ) -> Tuple[List[Tuple[np.ndarray, int]], List[Tuple[np.ndarray, int]]]:
        """Deterministic (cold, warm) request lists of ``(tokens, max_new)``."""
        rng = np.random.default_rng(self.seed)
        cold, warm = [], []
        for p in range(self.n_prefixes):
            prefix = rng.integers(0, vocab_size, size=self.prefix_len).astype(np.int32)
            for f in range(self.fan_out):
                i = p * self.fan_out + f
                t = int(self.tail_lens[i % len(self.tail_lens)])
                m = int(self.new_tokens[i % len(self.new_tokens)])
                tail = rng.integers(0, vocab_size, size=t).astype(np.int32)
                (cold if f == 0 else warm).append((np.concatenate([prefix, tail]), m))
        return cold, warm

    @property
    def prompt_lens(self) -> Tuple[int, ...]:
        """Distinct total prompt lengths in the two-phase stream."""
        return tuple(sorted({self.prefix_len + t for t in self.tail_lens}))

    @property
    def max_request_len(self) -> int:
        """Worst-case rows one request needs (prefix + tail + new tokens)."""
        return self.prefix_len + max(self.tail_lens) + max(self.new_tokens)


def run_prefix_workload(service, load: SharedPrefixLoadConfig, timeout_s: float = 300.0):
    """Cold phase, drained (so retiring prompts can donate pages to the radix
    cache), then the warm fan-out as a closed-loop burst.  Returns
    ``(summary, outs)`` with ``outs`` ordered cold-then-warm; the summary's
    ``warm_ttft_*`` percentiles cover the warm phase only — that is the
    latency the prefix cache is supposed to cut."""
    cold, warm = load.request_stream(service.engine.cfg.vocab_size)
    service.warmup(prompt_lens=[t.shape[0] for t, _ in cold + warm])
    t_run = time.perf_counter()
    cold_futs = [service.submit(t, m, block=True, timeout=timeout_s) for t, m in cold]
    service.drain()
    warm_futs = [service.submit(t, m, block=True, timeout=timeout_s) for t, m in warm]
    service.drain()
    outs = [f.result(timeout=timeout_s) for f in cold_futs + warm_futs]
    wall = time.perf_counter() - t_run
    n_tok = sum(len(o) for o in outs)
    summary = _lm_summary(_trace_latencies(cold_futs + warm_futs), n_tok, wall)
    ttfts = _trace_ttfts(warm_futs)
    if ttfts:
        summary["warm_ttft_p50_ms"] = float(np.percentile(ttfts, 50) * 1e3)
        summary["warm_ttft_p99_ms"] = float(np.percentile(ttfts, 99) * 1e3)
    return summary, outs


def compare_prefix_sharing(
    arch_cfg,
    params,
    load: SharedPrefixLoadConfig,
    *,
    n_slots: int = 8,
    max_len: Optional[int] = None,
    page_size: int = 16,
    prefill_chunk: int = 8,
    total_pages: Optional[int] = None,
    probe_fn=None,
    record_probe_rows: bool = False,
    obs=None,
) -> Dict[str, Dict[str, float]]:
    """Prefix sharing ON vs OFF over the same paged chunk-all engine on the
    same two-phase workload.  The OFF run uses ``chunk_all=True`` too, so
    both runs execute identical chunked-prefill/decode executables on
    identical values — greedy tokens must be BIT-IDENTICAL per request (the
    hard gate).  The perf story: warm-phase TTFT and the pool's peak
    allocated pages must both be strictly lower with sharing on."""
    from repro.serve.engine import ContinuousLMEngine
    from repro.serve.service import LMService

    max_len = int(max_len or max(load.max_request_len + 8, 32))
    max_len = -(-max_len // page_size) * page_size  # identical shapes both ways

    def run(prefix_cache: bool):
        """One measured pass with prefix sharing on or off."""
        engine = ContinuousLMEngine(
            arch_cfg, params, n_slots=n_slots, max_len=max_len,
            max_prompt_len=max(load.prompt_lens), paged=True,
            page_size=page_size, prefill_chunk=prefill_chunk, chunk_all=True,
            prefix_cache=prefix_cache, total_pages=total_pages,
        )
        probe = probe_fn() if (probe_fn is not None and prefix_cache) else None
        service = LMService(
            engine, probe=probe,
            record_probe_rows=record_probe_rows and prefix_cache,
            obs=obs if prefix_cache else None,
        )
        summary, outs = run_prefix_workload(service, load)
        return summary, outs, service

    base, base_outs, base_svc = run(prefix_cache=False)
    shared, shared_outs, shared_svc = run(prefix_cache=True)
    mismatches = sum(
        1 for a, b in zip(base_outs, shared_outs) if not np.array_equal(a, b)
    )
    base_peak = base_svc.engine.pager.alloc.peak_pages
    shared_peak = shared_svc.engine.pager.alloc.peak_pages
    pm = shared_svc.engine.pager.metrics()
    out = {
        "unshared": dict(base, peak_pages=float(base_peak)),
        "shared": dict(shared, peak_pages=float(shared_peak), **pm),
        "gate": {
            "token_mismatches": float(mismatches),
            "warm_ttft_lt_unshared": bool(
                shared["warm_ttft_p50_ms"] < base["warm_ttft_p50_ms"]
            ),
            "warm_ttft_ratio": shared["warm_ttft_p50_ms"] / max(base["warm_ttft_p50_ms"], 1e-9),
            "peak_pages_lt_unshared": bool(shared_peak < base_peak),
            "peak_pages_ratio": shared_peak / max(base_peak, 1),
            "prefix_hit_rate": pm["paged_prefix_hit_rate"],
        },
    }
    if record_probe_rows:
        err = lm_probe_oracle_err(shared_svc)
        if err is not None:
            out["gate"]["probe_oracle_rel_err"] = err
    return out


def lm_probe_oracle_err(service) -> Optional[float]:
    """Replay the last full probe window against the offline training-path
    oracle (``decorr.probe_metrics`` with the same step-folded permutation
    key).  Requires ``record_probe_rows=True`` and a fired probe; returns the
    max relative error across all exported metrics, or None."""
    import jax
    import jax.numpy as jnp

    from repro.decorr.probe import probe_metrics

    probe = service.probe
    if probe is None or probe.steps == 0 or not service.probe_rows:
        return None
    w = probe.sample_rows
    flat = np.concatenate(service.probe_rows, axis=0)
    step = probe.steps - 1
    window = flat[step * w : (step + 1) * w]
    key = jax.random.fold_in(probe._seed_key, jnp.uint32(step))
    oracle = probe_metrics(
        jnp.asarray(window), cfg=probe.cfg, perm_key=key, include_off=probe._include_off
    )
    got = probe.metrics()
    return max(
        abs(got[f"decorr_{k}"] - float(v)) / max(abs(float(v)), 1e-6)
        for k, v in oracle.items()
    )


# ---------------------------------------------------------------------------
# Fabric: replica scaling, deterministic failover, tp-forward oracle
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FabricLoadConfig:
    """Mixed fabric workload: the LM request ladder routed across replicas
    plus an embedding side-channel (both deterministic by seed).  The LM
    stream is what the scaling and failover gates measure; the embedding
    stream rides along to exercise per-kind routing."""

    lm: LMLoadConfig = LMLoadConfig(n_requests=16, prompt_lens=(4, 8, 14),
                                    new_tokens=(8, 16))
    n_embed: int = 0
    embed_rows: int = 4
    input_dim: int = 24
    seed: int = 0

    def embed_stream(self) -> List[np.ndarray]:
        """Deterministic embedding request list (empty when n_embed=0)."""
        rng = np.random.default_rng(self.seed + 1)
        return [
            rng.standard_normal((self.embed_rows, self.input_dim)).astype(np.float32)
            for _ in range(self.n_embed)
        ]


def make_lm_fabric(
    arch_cfg,
    params,
    fabric_cfg,
    load: FabricLoadConfig,
    *,
    n_slots: int = 4,
    max_len: Optional[int] = None,
    page_size: int = 16,
    embed_cfg=None,
    embed_params=None,
    obs=None,
    clock=None,
    engine_kw: Optional[Dict] = None,
):
    """Stand up a ``ServeFabric`` whose every replica runs a FRESH paged
    continuous engine (and, when ``embed_cfg`` is given, a fresh embedding
    service) over shared read-only params.  Returns ``(fabric, max_len)`` —
    the pinned cache extent a bit-identity oracle must decode at."""
    import time as _time

    from repro.obs import Obs
    from repro.serve.engine import ContinuousLMEngine, ServeEngine
    from repro.serve.fabric import ServeFabric
    from repro.serve.service import EmbeddingService, LMService

    lm_load = load.lm
    max_len = int(max_len or max(lm_load.max_request_len + 8, 32))
    max_len = -(-max_len // page_size) * page_size

    def lm_factory(name):
        engine = ContinuousLMEngine(
            arch_cfg, params, n_slots=n_slots, max_len=max_len,
            max_prompt_len=max(lm_load.prompt_lens), paged=True,
            page_size=page_size, **(engine_kw or {}),
        )
        return LMService(engine, obs=Obs())

    embed_factory = None
    if embed_cfg is not None:
        def embed_factory(name):
            return EmbeddingService(ServeEngine(embed_cfg, embed_params), obs=Obs())

    fabric = ServeFabric(
        fabric_cfg,
        lm_factory=lm_factory,
        embed_factory=embed_factory,
        obs=obs,
        clock=clock or _time.monotonic,
    )
    return fabric, max_len


def run_fabric(fabric, load: FabricLoadConfig, *, timeout_s: float = 300.0):
    """Drive one closed-loop burst through the fabric (threaded when
    ``fabric.start()`` was called, synchronous ticking otherwise).  Returns
    ``(summary, lm_outs, embed_outs)`` — outputs in submit order, so two runs
    over the same load compare stream-for-stream."""
    lm_svc = next(r.lm for r in fabric.replicas if r.lm is not None)
    stream = load.lm.request_stream(lm_svc.engine.cfg.vocab_size)
    lm_futs, em_futs = [], []
    t_run = time.perf_counter()
    for tokens, max_new in stream:
        lm_futs.append(fabric.submit_lm(tokens, max_new))
    for x in load.embed_stream():
        em_futs.append(fabric.submit_embed(x))
    fabric.drain(timeout_s=timeout_s)
    lm_outs = [f.result(timeout=timeout_s) for f in lm_futs]
    em_outs = [np.asarray(f.result(timeout=timeout_s)) for f in em_futs]
    wall = time.perf_counter() - t_run
    n_tok = sum(len(o) for o in lm_outs)
    summary = _lm_summary([f.latency_s for f in lm_futs], n_tok, wall)
    return summary, lm_outs, em_outs


def compare_fabric(
    arch_cfg,
    params,
    load: FabricLoadConfig,
    *,
    replicas: int = 2,
    n_slots: int = 4,
    page_size: int = 16,
    embed_cfg=None,
    embed_params=None,
    heartbeat_timeout_s: float = 5.0,
    repeats: int = 3,
    obs=None,
) -> Dict[str, Dict[str, float]]:
    """Three-leg fabric comparison on one deterministic workload:

      * ``single`` / ``multi`` — threaded 1-replica vs N-replica fabrics,
        interleaved best-of-``repeats`` (XLA releases the GIL during device
        execution, so N engine threads decode in parallel); the gate is
        aggregate tok/s scaling AND route-independent token identity;
      * ``failover`` — a synchronous 2-replica fabric on a FAKE clock: one
        replica is killed mid-decode, the clock jumps past the heartbeat
        timeout, and every requeued request must still emit the exact
        single-replica token stream (``requeue_token_mismatches == 0``).
    """
    from repro.serve.fabric import FabricConfig

    def build(n, clock=None, fab_obs=None):
        return make_lm_fabric(
            arch_cfg, params, FabricConfig(
                replicas=n, heartbeat_timeout_s=heartbeat_timeout_s,
            ), load,
            n_slots=n_slots, page_size=page_size,
            embed_cfg=embed_cfg, embed_params=embed_params,
            obs=fab_obs, clock=clock,
        )

    prompt_lens = [int(t.shape[0]) for t, _ in
                   load.lm.request_stream(arch_cfg.vocab_size)]
    single_fab, _ = build(1)
    multi_fab, _ = build(replicas, fab_obs=obs)
    for fab in (single_fab, multi_fab):
        fab.warmup(prompt_lens=prompt_lens).start()
    # interleaved best-of-N: CPU wall clock is noisy and drifts over a run —
    # alternating passes samples both fabrics under like conditions, and the
    # token streams are deterministic on every pass
    single = multi = single_outs = multi_outs = single_em = multi_em = None
    try:
        for _ in range(max(1, repeats)):
            s, s_outs, s_em = run_fabric(single_fab, load)
            if single is None or s["tok_per_s"] > single["tok_per_s"]:
                single, single_outs, single_em = s, s_outs, s_em
            m, m_outs, m_em = run_fabric(multi_fab, load)
            if multi is None or m["tok_per_s"] > multi["tok_per_s"]:
                multi, multi_outs, multi_em = m, m_outs, m_em
    finally:
        single_fab.stop()
        multi_fab.stop()
    route_mismatches = sum(
        1 for a, b in zip(single_outs, multi_outs) if not np.array_equal(a, b)
    )
    embed_err = 0.0
    for a, b in zip(single_em, multi_em):
        embed_err = max(embed_err, float(np.max(np.abs(a - b))))

    # failover leg: synchronous ticking on a fake clock so the kill is
    # mid-decode by construction and detection never sleeps
    t = {"now": 0.0}
    fail_fab, _ = build(2, clock=lambda: t["now"])
    fail_fab.warmup(prompt_lens=prompt_lens)
    stream = load.lm.request_stream(arch_cfg.vocab_size)
    futs = [fail_fab.submit_lm(tok, mn) for tok, mn in stream]
    for _ in range(3):  # let both replicas admit + decode a few ticks
        fail_fab.step()
    fail_fab.kill("r0")
    t["now"] += heartbeat_timeout_s * 2
    fail_fab.drain()
    fail_outs = [f.result(timeout=0) for f in futs]
    requeue_mismatches = sum(
        1 for a, b in zip(single_outs, fail_outs) if not np.array_equal(a, b)
    )
    degraded = _lm_summary(
        [f.latency_s for f in futs], sum(len(o) for o in fail_outs), 1.0
    )

    return {
        "single": single,
        "multi": multi,
        "failover": {
            "requeued": float(fail_fab.requeued_total),
            "replicas_dead": float(fail_fab.dead_total),
            "degraded_p99_ms": degraded["p99_ms"],
        },
        "fabric_metrics": multi_fab.metrics(),
        "gate": {
            "replicas": float(replicas),
            "scaling_x": multi["tok_per_s"] / max(single["tok_per_s"], 1e-9),
            "token_mismatches": float(route_mismatches),
            "embed_max_abs_err": embed_err,
            "requeue_token_mismatches": float(requeue_mismatches),
            "requeued": float(fail_fab.requeued_total),
        },
    }


def tp_oracle_err(model_cfg, params, *, tp: int = 2, n: int = 24, seed: int = 0) -> float:
    """Max relative error between the feature-sharded tp forward
    (``ServeEngine(model_axis=...)`` over a ``(1, tp)`` mesh) and the
    single-device oracle on one deterministic batch.  Needs ``tp`` visible
    devices (tests force host devices via XLA_FLAGS in a subprocess)."""
    import jax
    from jax.sharding import Mesh

    from repro.serve.engine import ServeEngine

    devs = jax.devices()
    if len(devs) < tp:
        raise ValueError(f"tp={tp} needs {tp} devices; {len(devs)} visible")
    x = np.random.default_rng(seed).standard_normal(
        (n, model_cfg.input_dim)
    ).astype(np.float32)
    ref = np.asarray(ServeEngine(model_cfg, params).encode(x))
    mesh = Mesh(np.array(devs[:tp]).reshape(1, tp), ("data", "model"))
    got = np.asarray(
        ServeEngine(model_cfg, params, mesh=mesh, model_axis="model").encode(x)
    )
    return float(np.max(np.abs(got - ref)) / (np.max(np.abs(ref)) + 1e-12))
