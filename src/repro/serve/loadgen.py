"""Load generator + policy comparison for the embedding service.

Deterministic synthetic traffic (seeded inputs, seeded exponential
inter-arrivals) driven through two serving policies:

  * ``naive``       — one engine call per request, no coalescing: the
    baseline ``launch/serve.py``-style loop every request pays alone;
  * ``microbatch``  — requests submitted to the ``EmbeddingService`` and
    coalesced by the admission policy into bucketed batches.

Both report per-request p50/p99 latency and sustained throughput; the bench
harness (``benchmarks/bench_serve.py``) and the CLI smoke
(``python -m repro.serve.cli``) are thin wrappers over ``compare_policies``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.serve.buckets import BucketPolicy
from repro.serve.engine import ServeEngine
from repro.serve.probes import DecorrProbe
from repro.serve.service import EmbeddingService


@dataclasses.dataclass(frozen=True)
class LoadConfig:
    n_requests: int = 256
    input_dim: int = 64
    arrival_rps: Optional[float] = None  # None = closed-loop burst (max load)
    seed: int = 0


def request_stream(cfg: LoadConfig):
    """Deterministic (inputs, inter-arrival gaps) for one load run."""
    rng = np.random.default_rng(cfg.seed)
    xs = rng.standard_normal((cfg.n_requests, cfg.input_dim)).astype(np.float32)
    if cfg.arrival_rps:
        gaps = rng.exponential(1.0 / cfg.arrival_rps, cfg.n_requests)
    else:
        gaps = np.zeros(cfg.n_requests)
    return xs, gaps


def _summary(latencies_s: List[float], wall_s: float) -> Dict[str, float]:
    lat = np.asarray(latencies_s)
    return {
        "requests": float(len(lat)),
        "p50_ms": float(np.percentile(lat, 50) * 1e3),
        "p99_ms": float(np.percentile(lat, 99) * 1e3),
        "throughput_rps": len(lat) / max(wall_s, 1e-9),
        "wall_s": wall_s,
    }


def run_naive(engine: ServeEngine, load: LoadConfig, probe: Optional[DecorrProbe] = None) -> Dict[str, float]:
    """Per-request serving: every request is its own (bucket-1) dispatch."""
    xs, gaps = request_stream(load)
    # warm the single-row bucket so compile time is not billed to requests
    engine.encode(xs[0]).block_until_ready()
    lat: List[float] = []
    t_run = time.perf_counter()
    for i in range(load.n_requests):
        if gaps[i]:
            time.sleep(gaps[i])
        t0 = time.perf_counter()
        z = engine.encode(xs[i])
        z.block_until_ready()
        lat.append(time.perf_counter() - t0)
        if probe is not None and (i + 1) % 64 == 0:
            probe.update(z)
    return _summary(lat, time.perf_counter() - t_run)


def run_microbatched(
    service: EmbeddingService, load: LoadConfig, timeout_s: float = 120.0
) -> Dict[str, float]:
    """Open-loop submission into the started service's dispatch thread."""
    xs, gaps = request_stream(load)
    # warm every bucket + the probe so no request pays a trace
    service.warmup()
    futures = []
    t_run = time.perf_counter()
    for i in range(load.n_requests):
        if gaps[i]:
            time.sleep(gaps[i])
        futures.append(service.submit(xs[i], block=True, timeout=timeout_s))
    results = [f.result(timeout=timeout_s) for f in futures]
    wall = time.perf_counter() - t_run
    assert all(r.shape == (service.engine.d,) for r in results)
    out = _summary([f.latency_s for f in futures], wall)
    out["mean_batch"] = service.stats.served / max(service.stats.batches, 1)
    out["batches"] = float(service.stats.batches)
    return out


def compare_policies(
    engine_fn,
    load: LoadConfig,
    policy: BucketPolicy,
    probe_fn=None,
) -> Dict[str, Dict[str, float]]:
    """Run naive then micro-batched on FRESH engines (cold, comparable compile
    caches).  ``engine_fn() -> ServeEngine``; ``probe_fn() -> DecorrProbe``
    (optional; the micro-batched run feeds it every dispatched batch)."""
    naive = run_naive(engine_fn(), load)

    probe = probe_fn() if probe_fn is not None else None
    service = EmbeddingService(engine_fn(), policy=policy, probe=probe).start()
    try:
        micro = run_microbatched(service, load)
        metrics = service.metrics()
    finally:
        service.stop()
    out = {"naive": naive, "microbatch": micro, "service_metrics": metrics}
    out["gate"] = {
        "microbatch_beats_naive": micro["throughput_rps"] >= naive["throughput_rps"],
        "speedup": micro["throughput_rps"] / max(naive["throughput_rps"], 1e-9),
    }
    return out
