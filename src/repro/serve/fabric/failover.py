"""Heartbeat-driven replica death detection for the serving fabric.

The fabric registers every replica with ONE fabric-level
``ft.watchdog.HeartbeatMonitor`` (injectable clock — the kill-one-replica
gate advances a fake clock instead of sleeping) and beats it on each
replica's behalf whenever that replica demonstrably made progress (a
synchronous ``tick``, or — threaded — a fresh service-level heartbeat
relayed by ``relay_beat``).  ``newly_dead`` is the edge-trigger: a replica
whose beat goes stale is reported EXACTLY once, at which point the fabric
drains it — every in-flight request is re-submitted from its prompt to a
healthy replica (``ServeFabric._on_dead``), partial decode discarded, so the
final greedy stream is bit-identical to a run that never saw the failure.

``revive`` re-arms detection when a replaced/restarted replica joins.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Set

from repro.ft.watchdog import HeartbeatMonitor


def _hb_name(name: str) -> str:
    return f"fabric.replica.{name}"


class FailoverController:
    """Edge-triggered stale-replica detection over a ``HeartbeatMonitor``."""

    def __init__(self, monitor: Optional[HeartbeatMonitor] = None, timeout_s: float = 10.0):
        self.monitor = monitor or HeartbeatMonitor(default_timeout_s=timeout_s)
        self.timeout_s = float(timeout_s)
        self._dead: Set[str] = set()

    def register(self, name: str):
        """Start liveness tracking for a (new) replica."""
        self.monitor.register(_hb_name(name), self.timeout_s)
        self._dead.discard(name)

    def beat(self, name: str):
        """Record one unit of replica progress."""
        self.monitor.beat(_hb_name(name))

    def relay_beat(self, replica) -> bool:
        """Threaded replicas beat their OWN service monitors from their loop
        threads; relay that into the fabric monitor when every service
        heartbeat is fresh.  Returns True when a beat was relayed."""
        for svc in replica.services():
            hb = svc.heartbeat
            if any(hb.age(n) > hb._timeout[n] for n in hb._timeout):
                return False
        self.beat(replica.name)
        return True

    def age(self, name: str) -> float:
        """Seconds since the replica's last (relayed) beat."""
        return self.monitor.age(_hb_name(name))

    def is_dead(self, name: str) -> bool:
        """True once ``newly_dead`` has reported the replica."""
        return name in self._dead

    def newly_dead(self, names: List[str]) -> List[str]:
        """Replicas whose heartbeat JUST went stale, each reported once."""
        stale = self.monitor.stale()
        out = []
        for name in names:
            if _hb_name(name) in stale and name not in self._dead:
                self._dead.add(name)
                out.append(name)
        return out

    def revive(self, name: str):
        """Re-arm detection for a replica that re-joined the fabric."""
        self.register(name)

    def metrics(self) -> Dict[str, float]:
        """Failover bookkeeping (the monitor's own gauges ride separately)."""
        return {"fabric_replicas_dead": float(len(self._dead))}
