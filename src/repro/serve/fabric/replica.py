"""One serving replica: engine(s) + service(s) + its own telemetry island.

A ``Replica`` owns a complete single-engine serving stack — an ``LMService``
(slot pool, page pool, micro-batcher) and/or an ``EmbeddingService``, each
with its OWN ``repro.obs.Obs`` bundle (registry, flight recorder, heartbeat)
— and gives the fabric a uniform handle over it: route-relevant load gauges
(``snapshot``), a synchronous scheduler tick (``tick``), thread lifecycle
(``start``/``stop``) and a crash simulator (``kill``).

Isolation is the point: replicas share nothing but (read-only) params, so a
dead replica's state can simply be abandoned — its in-flight requests are
re-submitted elsewhere from their prompts (``fabric.failover``) and greedy
decode re-derives the identical token stream.

``make_replica_mesh`` is the tp-sizing helper: ``FabricConfig(tp=M)`` gives
each replica an M-device mesh whose ``model`` axis feature-shards the
embedding forward (``ServeEngine(model_axis=...)``).
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np


def make_replica_mesh(tp: int = 1, data: int = 1, offset: int = 0):
    """Build one replica's ``(data, model)`` device mesh from the local
    devices (``None`` when the replica is single-device).  ``offset`` skips
    devices claimed by earlier replicas so fabrics can tile a host."""
    if tp <= 1 and data <= 1:
        return None
    import jax
    from jax.sharding import Mesh

    need = data * tp
    devs = jax.devices()
    if offset + need > len(devs):
        raise ValueError(
            f"replica mesh needs devices [{offset}, {offset + need}) but only "
            f"{len(devs)} are visible"
        )
    grid = np.array(devs[offset : offset + need]).reshape(data, tp)
    return Mesh(grid, ("data", "model"))


class Replica:
    """A named single-engine serving stack the fabric routes into."""

    def __init__(self, name: str, *, lm=None, embed=None):
        if lm is None and embed is None:
            raise ValueError("a replica needs at least one service (lm= or embed=)")
        self.name = str(name)
        self.lm = lm
        self.embed = embed
        self.alive = True
        self.crashed = False
        self.started = False

    def services(self) -> List:
        """The replica's services, LM first."""
        return [s for s in (self.lm, self.embed) if s is not None]

    # -- lifecycle ----------------------------------------------------------

    def warmup(self, prompt_lens=None) -> "Replica":
        """AOT-compile both services' executables (no request ever traces)."""
        if self.lm is not None:
            self.lm.warmup(prompt_lens=prompt_lens)
        if self.embed is not None:
            self.embed.warmup()
        return self

    def tick(self) -> int:
        """One synchronous scheduler pass over both services (the fabric's
        deterministic drive mode); returns in-flight work remaining."""
        if self.crashed or not self.alive:
            return 0
        work = 0
        if self.lm is not None:
            work += self.lm.step(timeout=0.0) or 0
        if self.embed is not None:
            self.embed.run_pending(timeout=0.0)
            work += self.embed.batcher.depth()
        return work

    def start(self) -> "Replica":
        """Run each service's scheduler loop on its own daemon thread."""
        for s in self.services():
            s.start()
        self.started = True
        return self

    def stop(self):
        """Stop the service threads (graceful: queued work drains first)."""
        for s in self.services():
            s.stop()
        self.started = False

    def kill(self):
        """Simulate a crash: the replica stops ticking (and stops feeding the
        fabric heartbeat), WITHOUT completing or failing its in-flight
        requests — exactly what a dead host looks like from the router.  It
        stays ``alive`` (routable) until the stale heartbeat gets it declared
        dead: that detection gap is the thing failover exists to close.  Only
        meaningful under the synchronous drive mode; a started replica's
        threads would keep serving."""
        if self.started:
            raise RuntimeError("kill() models a crash under synchronous ticking; "
                               "stop() the threaded replica instead")
        self.crashed = True

    # -- router-facing load signals -----------------------------------------

    def occupancy(self) -> float:
        """Instantaneous slot occupancy (active / total) — the
        ``slots_occupancy`` signal at routing time rather than the pool's
        time-averaged gauge."""
        if self.lm is None:
            return 0.0
        pool = self.lm.engine.pool
        return (pool.n_slots - pool.free_slots()) / pool.n_slots

    def outstanding(self) -> int:
        """Requests queued or in flight across both services."""
        n = 0
        if self.lm is not None:
            n += self.lm.outstanding()
        if self.embed is not None:
            n += self.embed.batcher.depth()
        return n

    def ttft_p99_s(self) -> float:
        """``serve_ttft_seconds_p99`` derived from this replica's OWN TTFT
        histogram (0.0 cold, or when the replica runs ``Obs.disabled()`` —
        weighted-TTFT routing then degrades to pure least-occupancy)."""
        if self.lm is None:
            return 0.0
        return self.lm.obs.registry.quantile_gauges().get("serve_ttft_seconds_p99", 0.0)

    def snapshot(self) -> Dict[str, float]:
        """The routing-relevant gauge subset, one read per dispatch."""
        slots = float(self.lm.engine.pool.n_slots) if self.lm is not None else 1.0
        return {
            "slots_total": slots,
            "slots_occupancy": self.occupancy(),
            "queue_depth": float(self.outstanding()),
            "serve_ttft_seconds_p99": self.ttft_p99_s(),
        }

    def metrics(self) -> Dict[str, float]:
        """The replica's merged flat scrape surface (both services)."""
        out: Dict[str, float] = {"replica_alive": 1.0 if self.alive else 0.0}
        for s in self.services():
            out.update(s.metrics())
        return out
