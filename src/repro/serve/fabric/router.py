"""Load-aware replica selection: least-occupancy, weighted-TTFT, affinity.

The router is pure policy — no queues, no threads.  Each ``pick`` reads one
``Replica.snapshot()`` per candidate (the ``slots_occupancy`` /
``queue_depth`` / ``serve_ttft_seconds_p99`` gauges the services already
export) and returns the replica to dispatch to, with a reason string the
fabric narrates into its flight recorder.

Policies:

  * ``least_occupancy`` — minimize ``slots_occupancy + queue_depth /
    slots_total``: instantaneous pool load plus normalized queued backlog,
    deterministic index tie-break.
  * ``weighted_ttft``   — the same load score weighted by each replica's
    observed ``serve_ttft_seconds_p99`` (+1 ms floor, so cold replicas and
    ``Obs.disabled()`` replicas — whose TTFT histogram never observes —
    degrade to pure least-occupancy): a replica that admits fast keeps
    earning traffic, a slow one sheds it.

Consistent-prefix affinity rides on top of either policy for LM traffic:
the CRC of the prompt's leading ``affinity_tokens`` ids maps shared-prefix
fan-out onto ONE replica, the one whose radix cache holds the warm prefix
pages (``docs/fabric.md``).  A mapping is dropped the moment its replica is
unhealthy — the next request re-routes by load and re-warms the cache there.
"""

from __future__ import annotations

import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

POLICIES = ("least_occupancy", "weighted_ttft")

# floor added to the observed TTFT p99 before weighting: keeps the score
# finite/ordered for cold (0.0) readings and bounds how hard one slow
# observation can starve a replica
_TTFT_FLOOR_S = 1e-3


def prefix_key(tokens, k: int) -> int:
    """Stable affinity key: CRC32 of the first ``k`` prompt token ids (the
    whole prompt when shorter) — deterministic across processes, unlike
    ``hash``."""
    head = np.asarray(tokens, np.int32)[: max(int(k), 1)]
    return zlib.crc32(head.tobytes())


class Router:
    """Stateless load scoring + the sticky prefix-affinity map."""

    def __init__(self, policy: str = "least_occupancy", affinity_tokens: int = 16):
        if policy not in POLICIES:
            raise ValueError(f"unknown routing policy {policy!r}; pick one of {POLICIES}")
        self.policy = policy
        self.affinity_tokens = int(affinity_tokens)
        self._affinity: Dict[int, str] = {}

    # -- scoring ------------------------------------------------------------

    def score(self, snap: Dict[str, float]) -> float:
        """Lower is better; see the module docstring for the formulas."""
        load = snap["slots_occupancy"] + snap["queue_depth"] / max(snap["slots_total"], 1.0)
        if self.policy == "least_occupancy":
            return load
        return load * (snap["serve_ttft_seconds_p99"] + _TTFT_FLOOR_S)

    def _pick_load(self, healthy: List) -> "object":
        scored = [(self.score(r.snapshot()), i) for i, r in enumerate(healthy)]
        return healthy[min(scored)[1]]

    # -- dispatch -----------------------------------------------------------

    def pick(self, replicas: List, tokens=None) -> Tuple["object", str]:
        """Choose a healthy replica for one request; returns ``(replica,
        reason)`` with reason ``"affinity"`` (sticky prefix hit) or the
        policy name.  Raises ``RuntimeError`` when every replica is dead."""
        healthy = [r for r in replicas if r.alive]
        if not healthy:
            raise RuntimeError("serving fabric has no healthy replica")
        key: Optional[int] = None
        if tokens is not None and self.affinity_tokens > 0:
            key = prefix_key(tokens, self.affinity_tokens)
            name = self._affinity.get(key)
            if name is not None:
                for r in healthy:
                    if r.name == name:
                        return r, "affinity"
                del self._affinity[key]  # mapped replica died; remap below
        chosen = self._pick_load(healthy)
        if key is not None:
            self._affinity[key] = chosen.name
        return chosen, self.policy

    def forget(self, name: str):
        """Drop every affinity mapping onto ``name`` (replica death): the
        warm pages died with it, so stickiness would only pile cold traffic
        onto the requeue target."""
        self._affinity = {k: v for k, v in self._affinity.items() if v != name}

    def metrics(self) -> Dict[str, float]:
        """Router bookkeeping gauges."""
        return {"fabric_affinity_entries": float(len(self._affinity))}
