"""repro.serve.fabric — a replica router in front of N serving engines.

The single-host engine stack (PRs 3–9) scales *down* one request's cost;
the fabric scales *out*: ``FabricConfig(replicas=N, tp=M)`` stands up N
isolated ``Replica`` stacks (each its own engine, slot/page pools, ``Obs``
registry and heartbeat; each optionally spanning M devices via the
feature-sharded tp forward, ``ServeEngine(model_axis=...)``) behind one
submit surface:

  * ``router``   — load-aware dispatch (least-occupancy / weighted-TTFT over
                   the replicas' own ``slots_occupancy`` and
                   ``serve_ttft_seconds_p99`` gauges) with consistent-prefix
                   affinity so shared-prefix traffic keeps hitting the
                   replica whose radix cache is warm;
  * ``replica``  — the per-replica wrapper (tick/start/stop/kill + the
                   routing gauge snapshot) and the tp mesh helper;
  * ``failover`` — heartbeat-driven drain-and-requeue: a replica that stops
                   beating is declared dead ONCE, its in-flight requests are
                   re-submitted from their prompts to healthy replicas
                   (idempotent by request id, partial decode discarded), and
                   greedy decode makes the re-run bit-identical to a
                   single-engine run.

Two drive modes: synchronous (``step``/``drain`` — deterministic, what the
failover gate and tests use, with an injectable clock so nothing sleeps) and
threaded (``start``/``stop`` — every replica's service loops on its own
daemon thread; XLA releases the GIL during device execution, so replicas
decode in parallel).  Flight events ``route`` / ``requeue`` /
``replica_dead`` / ``replica_join`` narrate every routing decision into the
fabric's recorder; ``metrics()`` exports per-replica labelled gauges
(``fabric_replica_occupancy{replica=}``, ``heartbeat_age_s{name=}``).

    fabric = ServeFabric(FabricConfig(replicas=2), lm_factory=make_service)
    fut = fabric.submit_lm(tokens, max_new_tokens=16)
    fabric.drain()
    tokens = fut.result()

See ``docs/fabric.md`` for router policies, failover semantics, tp sizing.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.ft.watchdog import HeartbeatMonitor
from repro.obs import Obs
from repro.serve.batcher import ServeFuture
from repro.serve.fabric.failover import FailoverController
from repro.serve.fabric.replica import Replica, make_replica_mesh
from repro.serve.fabric.router import POLICIES, Router, prefix_key

__all__ = [
    "FabricConfig",
    "FailoverController",
    "POLICIES",
    "Replica",
    "Router",
    "ServeFabric",
    "make_replica_mesh",
    "prefix_key",
]


@dataclasses.dataclass(frozen=True)
class FabricConfig:
    """Fabric sizing + routing knobs.

    ``replicas``: independent engine stacks behind the router; ``tp``:
    devices each replica's feature-sharded forward spans (1 = single-device
    replicas; the factory passes ``make_replica_mesh(tp, offset=...)`` into
    its engines); ``policy``: one of ``router.POLICIES``;
    ``affinity_tokens``: prompt prefix length the sticky-routing key hashes
    (0 disables affinity); ``heartbeat_timeout_s``: how long a replica may
    go without progress before failover drains it.
    """

    replicas: int = 2
    tp: int = 1
    policy: str = "least_occupancy"
    affinity_tokens: int = 16
    heartbeat_timeout_s: float = 10.0

    def validate(self) -> "FabricConfig":
        """Fail fast on unservable configurations."""
        if self.replicas < 1:
            raise ValueError(f"need at least one replica, got {self.replicas}")
        if self.tp < 1:
            raise ValueError(f"tp must be >= 1, got {self.tp}")
        if self.policy not in POLICIES:
            raise ValueError(f"unknown policy {self.policy!r}; pick one of {POLICIES}")
        if self.heartbeat_timeout_s <= 0:
            raise ValueError("heartbeat_timeout_s must be > 0")
        return self


class _Tracked:
    """Fabric-side bookkeeping for one in-flight request: the payload (for
    idempotent requeue), the caller-facing future, and the replica-side
    future currently carrying it."""

    __slots__ = ("kind", "payload", "future", "replica", "inner")

    def __init__(self, kind: str, payload, future: ServeFuture, replica: str, inner):
        self.kind = kind
        self.payload = payload
        self.future = future
        self.replica = replica
        self.inner = inner


class ServeFabric:
    """Replica router + failover controller over N serving stacks."""

    def __init__(
        self,
        cfg: FabricConfig,
        *,
        lm_factory: Optional[Callable[[str], Any]] = None,
        embed_factory: Optional[Callable[[str], Any]] = None,
        obs: Optional[Obs] = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        """``lm_factory(name) -> LMService`` / ``embed_factory(name) ->
        EmbeddingService`` build each replica's FRESH service stack (own
        engine, own ``Obs``); ``obs`` is the fabric's OWN bundle (router
        flight events, per-replica labelled gauges) and ``clock`` feeds the
        fabric heartbeat monitor (injectable: the failover gate advances a
        fake clock instead of sleeping)."""
        if lm_factory is None and embed_factory is None:
            raise ValueError("pass lm_factory= and/or embed_factory=")
        self.cfg = cfg.validate()
        self.obs = obs or Obs()
        self.router = Router(cfg.policy, cfg.affinity_tokens)
        self.monitor = HeartbeatMonitor(
            default_timeout_s=cfg.heartbeat_timeout_s, clock=clock
        )
        self.failover = FailoverController(self.monitor, timeout_s=cfg.heartbeat_timeout_s)
        self.replicas: List[Replica] = []
        self._by_name: Dict[str, Replica] = {}
        self._inflight: Dict[str, _Tracked] = {}
        self._seq = 0
        self._threaded = False
        self.routed_total = 0
        self.requeued_total = 0
        self.dead_total = 0
        for i in range(cfg.replicas):
            name = f"r{i}"
            self.add_replica(Replica(
                name,
                lm=lm_factory(name) if lm_factory is not None else None,
                embed=embed_factory(name) if embed_factory is not None else None,
            ))

    # -- membership ---------------------------------------------------------

    def add_replica(self, replica: Replica) -> Replica:
        """Join a replica into the fabric (initial build AND elastic grow /
        replacement after a death — detection is re-armed either way)."""
        if replica.name in self._by_name and self._by_name[replica.name].alive:
            raise ValueError(f"replica {replica.name!r} already joined")
        if replica.name in self._by_name:  # replacement for a dead replica
            self.replicas = [r for r in self.replicas if r.name != replica.name]
        self._by_name[replica.name] = replica
        self.replicas.append(replica)
        self.failover.revive(replica.name)
        self.obs.recorder.record("replica_join", replica=replica.name,
                                 replicas=len(self.replicas))
        if self._threaded and not replica.started:
            replica.start()
        return replica

    def replica(self, name: str) -> Replica:
        """Look a replica up by name."""
        return self._by_name[name]

    def _candidates(self, kind: str) -> List[Replica]:
        svc = (lambda r: r.lm) if kind == "lm" else (lambda r: r.embed)
        return [r for r in self.replicas if svc(r) is not None]

    # -- request side -------------------------------------------------------

    def _route(self, kind: str, payload, tokens=None) -> ServeFuture:
        req_id = f"{kind}-{self._seq}"
        self._seq += 1
        fut = ServeFuture()
        tracked = _Tracked(kind, payload, fut, "", None)
        self._dispatch(req_id, tracked, tokens=tokens, via="route")
        self._inflight[req_id] = tracked
        return fut

    def _dispatch(self, req_id: str, tracked: _Tracked, *, tokens, via: str):
        """(Re)submit one tracked request to the best healthy replica.  A
        submit-time rejection (``ValueError``/``Backpressure``) fails the
        caller's future — the fabric never silently drops work."""
        replica, how = self.router.pick(self._candidates(tracked.kind), tokens=tokens)
        if tracked.kind == "lm":
            tokens_arr, max_new, kw = tracked.payload
            tracked.inner = replica.lm.submit(tokens_arr, max_new, **kw)
        else:
            tracked.inner = replica.embed.submit(tracked.payload)
        tracked.replica = replica.name
        self.routed_total += 1
        self.obs.recorder.record(via, request=req_id, replica=replica.name,
                                 policy=how, traffic=tracked.kind)

    def submit_lm(
        self,
        tokens,
        max_new_tokens: int,
        *,
        eos_id: Optional[int] = None,
        temperature: float = 0.0,
        top_k: Optional[int] = None,
        seed: Optional[int] = None,
    ) -> ServeFuture:
        """Route one generation request (the ``LMService.submit`` contract);
        prefix affinity keeps shared-prefix fan-out on one replica's warm
        radix cache.  Returns a fabric-level future that survives replica
        death: failover re-submits the prompt elsewhere."""
        tokens = np.asarray(tokens, np.int32)
        kw = dict(eos_id=eos_id, temperature=temperature, top_k=top_k, seed=seed)
        return self._route("lm", (tokens, int(max_new_tokens), kw), tokens=tokens)

    def submit_embed(self, x) -> ServeFuture:
        """Route one embedding request by load (no affinity — the embedding
        path has no per-replica warm state worth chasing)."""
        return self._route("embed", np.asarray(x))

    def outstanding(self) -> int:
        """Fabric-level in-flight request count."""
        return len(self._inflight)

    # -- scheduler ----------------------------------------------------------

    def _settle(self, req_id: str, tracked: _Tracked):
        del self._inflight[req_id]
        try:
            tracked.future.set_result(tracked.inner.result(timeout=0))
        except BaseException as e:  # noqa: BLE001 - relay ANY failure to the caller
            tracked.future.set_exception(e)

    def poll(self) -> int:
        """Copy completed replica-side futures into the fabric futures;
        returns how many settled this pass."""
        done = [(rid, t) for rid, t in self._inflight.items() if t.inner.done()]
        for rid, t in done:
            self._settle(rid, t)
        return len(done)

    def _on_dead(self, replica: Replica):
        """Drain-and-requeue: abandon the dead replica's state, deliver what
        it finished, and re-submit everything else from its prompt to the
        healthy replicas (idempotent: the request id and the caller's future
        are reused; the partial decode is simply discarded — greedy decode
        re-derives the identical stream)."""
        replica.alive = False
        self.dead_total += 1
        self.router.forget(replica.name)
        stranded = [(rid, t) for rid, t in self._inflight.items()
                    if t.replica == replica.name]
        self.obs.recorder.record("replica_dead", replica=replica.name,
                                 age_s=self.failover.age(replica.name),
                                 inflight=len(stranded))
        for rid, t in stranded:
            if t.inner.done():  # finished before the crash landed: deliver
                self._settle(rid, t)
                continue
            src = t.replica
            tokens = t.payload[0] if t.kind == "lm" else None
            try:
                self._dispatch(rid, t, tokens=tokens, via="requeue")
            except BaseException as e:  # noqa: BLE001 - no healthy target / rejected
                del self._inflight[rid]
                t.future.set_exception(e)
                continue
            self.requeued_total += 1
            self.obs.recorder.record("requeue_done", request=rid, src=src,
                                     dst=t.replica)

    def step(self) -> int:
        """One fabric tick: advance every live replica (synchronous mode),
        feed the heartbeat monitor, fail over newly-stale replicas, settle
        completed requests.  Returns fabric-level in-flight work."""
        for r in self.replicas:
            if not r.alive or r.crashed:
                continue
            if self._threaded:
                self.failover.relay_beat(r)
            else:
                r.tick()
                self.failover.beat(r.name)
        dead = self.failover.newly_dead(
            [r.name for r in self.replicas if r.alive]
        )
        for name in dead:
            self._on_dead(self._by_name[name])
        self.poll()
        return len(self._inflight)

    def drain(self, max_steps: int = 1_000_000, timeout_s: float = 300.0) -> int:
        """Tick until every fabric future settled (or limits hit); the
        deterministic closed-loop entry point.  Returns ticks run."""
        t0 = time.monotonic()
        ran = 0
        while self._inflight and ran < max_steps:
            self.step()
            ran += 1
            if self._threaded and self._inflight:
                if time.monotonic() - t0 > timeout_s:
                    raise TimeoutError(
                        f"fabric drain timed out with {len(self._inflight)} in flight"
                    )
                time.sleep(1e-3)  # replica threads own the scheduling
        return ran

    # -- lifecycle ----------------------------------------------------------

    def warmup(self, prompt_lens=None) -> "ServeFabric":
        """AOT-compile every replica's executables."""
        for r in self.replicas:
            r.warmup(prompt_lens=prompt_lens)
        return self

    def start(self) -> "ServeFabric":
        """Threaded mode: every replica's services loop on daemon threads;
        ``drain``/``poll`` then only settle futures and relay heartbeats."""
        self._threaded = True
        for r in self.replicas:
            if r.alive and not r.started:
                r.start()
        return self

    def stop(self):
        """Stop every replica's service threads (graceful drain)."""
        for r in self.replicas:
            if r.started:
                r.stop()
        self._threaded = False

    def kill(self, name: str):
        """Crash-simulate one replica (synchronous mode): it stops ticking
        and beating; once its heartbeat exceeds the timeout, ``step``
        declares it dead and requeues its in-flight work."""
        self._by_name[name].kill()

    # -- scrape surface -----------------------------------------------------

    def metrics(self) -> Dict[str, float]:
        """Fabric scrape surface: flat aggregates + per-replica LABELLED
        gauges (``fabric_replica_occupancy{replica=}`` etc.; the heartbeat
        ages ride the monitor's own ``heartbeat_age_s{name=}`` family)."""
        from repro.serve.service import collect_metrics

        own = {
            "fabric_replicas": float(len(self.replicas)),
            "fabric_replicas_alive": float(sum(r.alive for r in self.replicas)),
            "fabric_inflight": float(len(self._inflight)),
            "fabric_routed_total": float(self.routed_total),
            "fabric_requeued_total": float(self.requeued_total),
            "fabric_replicas_dead_total": float(self.dead_total),
        }
        reg = self.obs.registry
        g_occ = reg.gauge("fabric_replica_occupancy",
                          "per-replica instantaneous slot occupancy",
                          labelnames=("replica",))
        g_out = reg.gauge("fabric_replica_outstanding",
                          "per-replica queued + in-flight requests",
                          labelnames=("replica",))
        g_alive = reg.gauge("fabric_replica_alive",
                            "1 while the replica is routable",
                            labelnames=("replica",))
        for r in self.replicas:
            g_occ.labels(replica=r.name).set(r.occupancy())
            g_out.labels(replica=r.name).set(float(r.outstanding()))
            g_alive.labels(replica=r.name).set(1.0 if r.alive else 0.0)
        return collect_metrics(
            own,
            self.router.metrics(),
            self.failover.metrics(),
            self.monitor,
            self.obs,
            registry=reg,
        )

    def replica_metrics(self) -> Dict[str, Dict[str, float]]:
        """Each replica's own flat scrape surface, keyed by name (the
        per-replica flight recorders ride ``replica(name).lm.obs``)."""
        return {r.name: r.metrics() for r in self.replicas}
