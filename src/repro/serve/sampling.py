"""Temperature / top-k sampling for the continuous-batching decode loop.

Sampling happens host-side on the logits row the decode step already
returns: the pool's one batched executable stays sampling-agnostic (it emits
logits; greedy-only engines keep the PR 4 argmax-in-jit executable, so that
path's compiled graph — and its tokens — are untouched), while each request
carries its own ``SamplingParams`` and its own PRNG stream.

Determinism contract:

  * ``temperature == 0`` is EXACT greedy: ``np.argmax`` over the transferred
    logits row, which is bit-identical to the in-jit ``jnp.argmax`` (same f32
    values, both break ties toward the lowest index) — the PR 4 oracle path.
  * ``temperature > 0`` uses the Gumbel-max trick on the temperature-scaled,
    top-k-masked logits with a per-request ``np.random.Generator`` seeded
    from ``SamplingParams.seed``; a request replayed with the same seed and
    the same logits stream reproduces its tokens regardless of how slot
    interleaving schedules it.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding policy.

    temperature: 0 = greedy (the default; bit-identical to the argmax path);
                 > 0 softens the distribution before sampling.
    top_k:       keep only the k highest logits (None/0 = full vocab).
    seed:        per-request PRNG seed; None derives one from the service's
                 admission counter so replays are still deterministic.
    """

    temperature: float = 0.0
    top_k: Optional[int] = None
    seed: Optional[int] = None

    def validate(self) -> "SamplingParams":
        """Range-check the knobs; returns self for chaining."""
        if self.temperature < 0.0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k is not None and self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0/None = full vocab), got {self.top_k}")
        return self

    @property
    def greedy(self) -> bool:
        """True when temperature 0 makes sampling exact argmax."""
        return self.temperature == 0.0


GREEDY = SamplingParams()


def make_rng(params: Optional[SamplingParams], fallback_seed: int) -> Optional[np.random.Generator]:
    """The request's private PRNG stream (None for greedy requests — greedy
    must not consume entropy, so its path has no generator to drift)."""
    if params is None or params.greedy:
        return None
    seed = params.seed if params.seed is not None else fallback_seed
    return np.random.default_rng(int(seed))


def sample_token(
    logits: np.ndarray,
    params: Optional[SamplingParams],
    rng: Optional[np.random.Generator],
) -> int:
    """Draw the next token id from one (V,) f32 logits row."""
    if params is None or params.greedy:
        return int(np.argmax(logits))
    z = np.asarray(logits, np.float64) / params.temperature
    if params.top_k:
        k = min(int(params.top_k), z.shape[0])
        # mask everything below the k-th largest logit; ties at the cut keep
        # their first-k occurrences (argpartition is enough — only membership
        # matters, Gumbel noise breaks any remaining symmetry)
        keep = np.argpartition(z, -k)[-k:]
        masked = np.full_like(z, -np.inf)
        masked[keep] = z[keep]
        z = masked
    gumbel = -np.log(-np.log(rng.uniform(low=np.finfo(np.float64).tiny, high=1.0, size=z.shape)))
    return int(np.argmax(z + gumbel))
