from repro.checkpoint.checkpointer import (
    save_checkpoint,
    restore_checkpoint,
    latest_step,
    list_steps,
    AsyncCheckpointer,
)
from repro.checkpoint.manager import CheckpointManager
