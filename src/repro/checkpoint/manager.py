"""Checkpoint lifecycle: keep-N retention, interval policy, auto-resume."""

from __future__ import annotations

import os
import shutil
from typing import Any, Optional, Tuple

from repro.checkpoint.checkpointer import (
    AsyncCheckpointer,
    latest_step,
    list_steps,
    restore_checkpoint,
    save_checkpoint,
)


class CheckpointManager:
    def __init__(
        self,
        ckpt_dir: str,
        interval: int = 100,
        keep: int = 3,
        use_async: bool = True,
    ):
        self.ckpt_dir = ckpt_dir
        self.interval = interval
        self.keep = keep
        self._async = AsyncCheckpointer() if use_async else None
        os.makedirs(ckpt_dir, exist_ok=True)
        # clean torn writes from a previous crashed process (safe here:
        # no saves of ours are in flight yet)
        for name in os.listdir(ckpt_dir):
            if name.endswith(".tmp"):
                shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)

    # -- save ---------------------------------------------------------------

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.interval == 0

    def save(self, step: int, state, force: bool = False):
        if not (force or self.should_save(step)):
            return None
        if self._async is not None:
            fut = self._async.save(self.ckpt_dir, step, state)
        else:
            fut = save_checkpoint(self.ckpt_dir, step, state)
        self._gc()
        return fut

    def _gc(self):
        steps = list_steps(self.ckpt_dir)
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s}"), ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def restore_latest(self, template, shardings=None) -> Tuple[Optional[Any], int]:
        step = latest_step(self.ckpt_dir)
        if step is None:
            return None, 0
        state = restore_checkpoint(self.ckpt_dir, step, template, shardings)
        return state, step

    def wait(self):
        if self._async is not None:
            self._async.wait()
