"""Sharded, async, atomically-committed checkpointing (numpy-free-form,
bf16-safe via raw bytes + ml_dtypes).

Layout of a checkpoint:
    <dir>/step_<N>.tmp/            during write
    <dir>/step_<N>/                after atomic rename
        manifest.json              treedef paths, shapes, dtypes
        leaf_00000.bin ...         raw little-endian buffers
        COMMIT                     written last — absence marks a torn write

Failure model: a crash mid-save leaves either a ``.tmp`` dir or a dir
without COMMIT; both are ignored by ``latest_step`` and garbage-collected.
Saving is async (single worker thread — ordered) so the train loop overlaps
serialization with the next steps; ``wait()`` drains before exit.

At 1000-node scale each host writes only the leaves it owns (addressable
shards) — here (single host) we write full arrays; elastic re-mesh
(ft/elastic.py) re-places them on any new mesh at restore.
"""

from __future__ import annotations

import concurrent.futures as cf
import json
import os
import re
import shutil
from typing import Any, Optional

import jax
import jax.numpy as jnp
import ml_dtypes  # noqa: F401  (registers bfloat16 etc. with numpy)
import numpy as np

PyTree = Any

_STEP_RE = re.compile(r"^step_(\d+)$")


def _path_str(path) -> str:
    return jax.tree_util.keystr(path)


def save_checkpoint(ckpt_dir: str, step: int, state: PyTree) -> str:
    """Synchronous atomic save. Returns the committed directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(state)
    manifest = {"step": int(step), "leaves": []}
    for i, (path, leaf) in enumerate(leaves_with_paths):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.bin"
        with open(os.path.join(tmp, fname), "wb") as f:
            f.write(arr.tobytes())
        manifest["leaves"].append(
            {
                "path": _path_str(path),
                "file": fname,
                "dtype": arr.dtype.name,
                "shape": list(arr.shape),
            }
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def _is_committed(d: str) -> bool:
    return os.path.isfile(os.path.join(d, "COMMIT"))


def list_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        m = _STEP_RE.match(name)
        if m and _is_committed(os.path.join(ckpt_dir, name)):
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(
    ckpt_dir: str, step: int, template: PyTree, shardings: Optional[PyTree] = None
) -> PyTree:
    """Restore into the template's treedef.  ``shardings`` (same structure)
    optionally places each leaf — this is the elastic re-mesh entry point."""
    d = os.path.join(ckpt_dir, f"step_{step}")
    if not _is_committed(d):
        raise FileNotFoundError(f"no committed checkpoint at {d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    shard_leaves = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None else [None] * len(leaves_with_paths)
    )

    out = []
    for (path, leaf), shard in zip(leaves_with_paths, shard_leaves):
        entry = by_path.get(_path_str(path))
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {_path_str(path)}")
        with open(os.path.join(d, entry["file"]), "rb") as f:
            buf = f.read()
        arr = np.frombuffer(buf, dtype=np.dtype(entry["dtype"])).reshape(entry["shape"])
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class AsyncCheckpointer:
    """Single-worker async save queue (ordered, last-error surfaced)."""

    def __init__(self):
        self._pool = cf.ThreadPoolExecutor(max_workers=1)
        self._pending: list[cf.Future] = []

    def save(self, ckpt_dir: str, step: int, state: PyTree) -> cf.Future:
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        fut = self._pool.submit(save_checkpoint, ckpt_dir, step, host_state)
        self._pending.append(fut)
        return fut

    def wait(self):
        for fut in self._pending:
            fut.result()
        self._pending.clear()
