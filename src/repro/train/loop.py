"""Production train loop: checkpoint/restart, preemption, stragglers, retry.

The loop is deliberately host-side-thin: all math lives in the jitted step.
What it adds is the operational envelope a 1000-node run needs:
  * auto-resume from the newest committed checkpoint,
  * interval + final + preemption-triggered checkpoints (async, atomic),
  * straggler watchdog (rolling-median outlier detection),
  * bounded retry of transient step failures (fault injection in tests),
  * deterministic data (batches keyed by step — a restart replays nothing
    and skips nothing).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional


from repro.checkpoint.manager import CheckpointManager
from repro.ft.watchdog import PreemptionSignal, StragglerWatchdog, with_retries
from repro.train.train_state import TrainState


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_interval: int = 50
    ckpt_keep: int = 3
    log_interval: int = 10
    preempt_flag: Optional[str] = None
    max_step_retries: int = 2


def run_training(
    state: TrainState,
    train_step: Callable,
    batch_fn: Callable[[int], Any],
    cfg: LoopConfig,
    log_fn: Callable[[int, Dict], None] = None,
    fault_hook: Optional[Callable[[int], None]] = None,
    registry=None,
) -> TrainState:
    """batch_fn(step) -> device-ready batch (deterministic per step).
    fault_hook(step) may raise RuntimeError to simulate transient faults.
    ``registry`` (an ``repro.obs.MetricsRegistry``) gets a per-step wall-time
    histogram + step counter every step, and ``train_``-prefixed gauges of
    the training metrics at each log interval (where they are already
    host-synced — never on the hot path)."""
    mgr = (
        CheckpointManager(cfg.ckpt_dir, interval=cfg.ckpt_interval, keep=cfg.ckpt_keep)
        if cfg.ckpt_dir
        else None
    )
    preempt = PreemptionSignal(cfg.preempt_flag) if cfg.preempt_flag else None
    watchdog = StragglerWatchdog()
    h_step = c_steps = None
    if registry is not None:
        h_step = registry.histogram("train_step_seconds", "one train step wall time")
        c_steps = registry.counter("train_steps_total", "train steps run")

    # auto-resume
    start_step = int(state.step)
    if mgr is not None:
        restored, step = mgr.restore_latest(state)
        if restored is not None:
            state = restored
            start_step = step

    def one_step(step: int, state: TrainState):
        if fault_hook is not None:
            fault_hook(step)
        batch = batch_fn(step)
        return train_step(state, batch)

    step_with_retry = with_retries(one_step, max_retries=cfg.max_step_retries)

    metrics: Dict = {}
    for step in range(start_step, cfg.total_steps):
        watchdog.step_start()
        state, metrics = step_with_retry(step, state)
        watchdog.step_end()
        if registry is not None:
            h_step.observe(watchdog.durations[-1])
            c_steps.inc()

        if (step + 1) % cfg.log_interval == 0 and (log_fn is not None or registry is not None):
            host_metrics = {k: float(v) for k, v in metrics.items()}
            host_metrics["stragglers"] = watchdog.straggler_events
            if registry is not None:
                registry.publish(
                    {f"train_{k}": v for k, v in host_metrics.items()}
                )
                registry.gauge("train_step_seconds_median").set(watchdog.median)
            if log_fn is not None:
                log_fn(step + 1, host_metrics)

        if mgr is not None:
            mgr.save(int(state.step), state)

        if preempt is not None and preempt.raised():
            if mgr is not None:
                mgr.save(int(state.step), state, force=True)
                mgr.wait()
            break

    if mgr is not None:
        mgr.save(int(state.step), state, force=True)
        mgr.wait()
    return state
