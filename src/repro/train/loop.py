"""Production train loop: checkpoint/restart, preemption, stragglers, retry.

The loop is deliberately host-side-thin: all math lives in the jitted step.
What it adds is the operational envelope a 1000-node run needs:
  * auto-resume from the newest committed checkpoint,
  * interval + final + preemption-triggered checkpoints (async, atomic),
  * straggler watchdog (rolling-median outlier detection),
  * bounded retry of transient step failures (fault injection in tests),
  * deterministic data (batches keyed by step — a restart replays nothing
    and skips nothing).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional


from repro.checkpoint.manager import CheckpointManager
from repro.ft.watchdog import PreemptionSignal, StragglerWatchdog, with_retries
from repro.train.train_state import TrainState


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_interval: int = 50
    ckpt_keep: int = 3
    log_interval: int = 10
    preempt_flag: Optional[str] = None
    max_step_retries: int = 2


def run_training(
    state: TrainState,
    train_step: Callable,
    batch_fn: Callable[[int], Any],
    cfg: LoopConfig,
    log_fn: Callable[[int, Dict], None] = None,
    fault_hook: Optional[Callable[[int], None]] = None,
    registry=None,
    monitor=None,
    perf=None,
) -> TrainState:
    """batch_fn(step) -> device-ready batch (deterministic per step).
    fault_hook(step) may raise RuntimeError to simulate transient faults.
    ``registry`` (an ``repro.obs.MetricsRegistry``) gets per-phase wall-time
    histograms (batch fetch / train step / log-interval publish) + a step
    counter every step, and ``train_``-prefixed gauges of the training
    metrics plus a global param-norm gauge at each log interval (where they
    are already host-synced — never on the hot path).
    ``monitor`` (an ``repro.obs.DecorrHealthMonitor``) probes the current
    params against the step's batch at each log interval, publishing the
    ``train_decorr_*`` health gauges its alert rules read.
    ``perf`` (an ``repro.obs.ExecTimer``) attributes the train-step
    executable's wall time per invocation."""
    mgr = (
        CheckpointManager(cfg.ckpt_dir, interval=cfg.ckpt_interval, keep=cfg.ckpt_keep)
        if cfg.ckpt_dir
        else None
    )
    preempt = PreemptionSignal(cfg.preempt_flag) if cfg.preempt_flag else None
    watchdog = StragglerWatchdog()
    h_step = c_steps = h_batch = h_publish = None
    if registry is not None:
        h_step = registry.histogram("train_step_seconds", "one train step wall time")
        c_steps = registry.counter("train_steps_total", "train steps run")
        h_batch = registry.histogram("train_batch_seconds", "batch fetch wall time")
        h_publish = registry.histogram(
            "train_publish_seconds", "log-interval publish + health-probe wall time"
        )

    # auto-resume
    start_step = int(state.step)
    if mgr is not None:
        restored, step = mgr.restore_latest(state)
        if restored is not None:
            state = restored
            start_step = step

    # phase timings land in a cell so one_step keeps the (state, metrics)
    # return contract with_retries wraps
    phase = {"batch_s": 0.0, "step_s": 0.0}

    def one_step(step: int, state: TrainState):
        if fault_hook is not None:
            fault_hook(step)
        t0 = time.perf_counter()
        batch = batch_fn(step)
        t1 = time.perf_counter()
        out = train_step(state, batch)
        t2 = time.perf_counter()
        phase["batch_s"] = t1 - t0
        phase["step_s"] = t2 - t1
        return out

    step_with_retry = with_retries(one_step, max_retries=cfg.max_step_retries)

    metrics: Dict = {}
    for step in range(start_step, cfg.total_steps):
        watchdog.step_start()
        state, metrics = step_with_retry(step, state)
        watchdog.step_end()
        if registry is not None:
            h_step.observe(watchdog.durations[-1])
            h_batch.observe(phase["batch_s"])
            c_steps.inc()
        if perf is not None:
            perf.observe("train_step", phase["step_s"])

        at_log = (step + 1) % cfg.log_interval == 0
        if at_log and (log_fn is not None or registry is not None or monitor is not None):
            t_pub = time.perf_counter()
            host_metrics = {k: float(v) for k, v in metrics.items()}
            host_metrics["stragglers"] = watchdog.straggler_events
            if registry is not None:
                registry.publish(
                    {f"train_{k}": v for k, v in host_metrics.items()}
                )
                registry.gauge("train_step_seconds_median").set(watchdog.median)
                _publish_param_norm(registry, state)
            if monitor is not None:
                monitor.update(state, batch_fn(step), step=step + 1, registry=registry)
            if log_fn is not None:
                log_fn(step + 1, host_metrics)
            if h_publish is not None:
                h_publish.observe(time.perf_counter() - t_pub)

        if mgr is not None:
            mgr.save(int(state.step), state)

        if preempt is not None and preempt.raised():
            if mgr is not None:
                mgr.save(int(state.step), state, force=True)
                mgr.wait()
            break

    if mgr is not None:
        mgr.save(int(state.step), state, force=True)
        mgr.wait()
    return state


def _publish_param_norm(registry, state):
    """Global L2 norm of the params as a gauge.  Tolerant of duck-typed
    states (tests pass step-only stand-ins) — publishes nothing then."""
    params = getattr(state, "params", None)
    if params is None:
        return
    try:
        import jax
        import jax.numpy as jnp

        leaves = jax.tree_util.tree_leaves(params)
        if not leaves:
            return
        sq = sum(float(jnp.vdot(x, x).real) for x in leaves)
        registry.gauge("train_param_norm", "global L2 norm of the params").set(sq ** 0.5)
    except Exception:
        return
