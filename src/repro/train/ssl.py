"""The paper's own setting: Siamese MLP backbone + projector, trained with
Barlow Twins-style / VICReg-style losses (baseline R_off or proposed R_sum).

The backbone is deliberately simple (the paper's contribution is the loss,
not the ResNet); the projector is the standard 3-layer MLP with BN-like
standardization handled inside the loss.  ``make_ssl_train_step`` plugs into
the same optimizer/checkpoint machinery as the LM path.

``make_sharded_ssl_train_step`` is the mesh-aware variant: the loss+grad
computation runs under ``shard_map`` with the batch data-parallel over the
``data`` axis and — in the engine's ``tp`` mode — the projector OUTPUT layer
feature-sharded over the ``model`` axis, so each shard only materializes
(n_local, d / P) projections and the engine's all_to_all transpose does the
rest.  Partition specs come from ``parallel/sharding.py`` logical axes
("batch", "feature").
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.losses import DecorrConfig, ssl_loss
from repro.optim.optimizers import Optimizer, clip_by_global_norm
from repro.parallel import sharding as shd
from repro.train.train_state import TrainState

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SSLModelConfig:
    input_dim: int = 3072
    backbone_widths: Tuple[int, ...] = (512, 512)
    projector_widths: Tuple[int, ...] = (2048, 2048, 2048)


def init_ssl_params(key: Array, cfg: SSLModelConfig) -> Dict:
    params = {"backbone": [], "projector": []}
    dims_b = (cfg.input_dim,) + cfg.backbone_widths
    dims_p = (cfg.backbone_widths[-1],) + cfg.projector_widths
    keys = jax.random.split(key, len(dims_b) + len(dims_p))
    ki = 0
    for i in range(len(dims_b) - 1):
        w = jax.random.normal(keys[ki], (dims_b[i], dims_b[i + 1]), jnp.float32)
        params["backbone"].append(
            {"w": w / jnp.sqrt(dims_b[i]), "b": jnp.zeros((dims_b[i + 1],))}
        )
        ki += 1
    for i in range(len(dims_p) - 1):
        w = jax.random.normal(keys[ki], (dims_p[i], dims_p[i + 1]), jnp.float32)
        params["projector"].append(
            {"w": w / jnp.sqrt(dims_p[i]), "b": jnp.zeros((dims_p[i + 1],))}
        )
        ki += 1
    return params


def backbone_apply(params: Dict, x: Array) -> Array:
    h = x
    for layer in params["backbone"]:
        h = jax.nn.relu(h @ layer["w"] + layer["b"])
    return h


def projector_apply(params: Dict, h: Array) -> Array:
    n = len(params["projector"])
    for i, layer in enumerate(params["projector"]):
        h = h @ layer["w"] + layer["b"]
        if i < n - 1:
            h = jax.nn.relu(h)
    return h


def embed(params: Dict, x: Array) -> Array:
    return projector_apply(params, backbone_apply(params, x))


def make_ssl_train_step(
    model_cfg: SSLModelConfig,
    loss_cfg: DecorrConfig,
    optimizer: Optimizer,
    schedule,
    clip_norm=None,
):
    def loss_fn(params, batch, rng):
        v1, v2 = batch["view1"], batch["view2"]
        z1 = embed(params, v1)
        z2 = embed(params, v2)
        loss, metrics = ssl_loss(z1, z2, loss_cfg, perm_key=rng)
        return loss, metrics

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        rng = jax.random.fold_in(state.rng, state.step)
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params, batch, rng
        )
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
            metrics["grad_norm"] = gnorm
        lr = schedule(state.step)
        metrics["lr"] = lr
        new_params, new_opt = optimizer.update(grads, state.opt_state, state.params, lr)
        return TrainState(state.step + 1, new_params, new_opt, state.rng), metrics

    return train_step, loss_fn


# ---------------------------------------------------------------------------
# Mesh-aware variant: loss + grads under shard_map
# ---------------------------------------------------------------------------


def ssl_param_specs(model_cfg: SSLModelConfig, loss_cfg: DecorrConfig, mesh: Mesh):
    """PartitionSpec tree for ``init_ssl_params`` output.

    Everything is replicated except — in ``tp`` mode — the projector OUTPUT
    layer, whose weight columns / bias are feature-sharded over the logical
    "feature" axis (-> "model" mesh axis per ``parallel/sharding.py`` rules).
    """
    with shd.sharding_context(mesh):
        w_spec = shd.logical_to_spec((None, "feature"))
        b_spec = shd.logical_to_spec(("feature",))
    specs = {
        "backbone": [{"w": P(), "b": P()} for _ in model_cfg.backbone_widths],
        "projector": [{"w": P(), "b": P()} for _ in model_cfg.projector_widths],
    }
    if loss_cfg.distributed == "tp":
        specs["projector"][-1] = {"w": w_spec, "b": b_spec}
    return specs


def make_sharded_ssl_train_step(
    model_cfg: SSLModelConfig,
    loss_cfg: DecorrConfig,
    optimizer: Optimizer,
    schedule,
    mesh: Mesh,
    clip_norm=None,
    data_axis: str = "data",
    model_axis: str = "model",
):
    """``make_ssl_train_step`` running end-to-end under ``shard_map``.

    The batch is data-parallel over ``data_axis`` in every mode.  The loss
    semantics follow ``loss_cfg.distributed``:

      * ``local``  — each data shard computes the paper-faithful shard-local
        loss; grads (and reported metrics) are the DDP mean over shards.
      * ``global`` — the engine psums the O(d) accumulators, so loss and
        grads equal a single-device run on the full concatenated batch.
      * ``tp``     — additionally the projector output layer (and hence z)
        is feature-sharded over ``model_axis``; the engine's all_to_all
        transpose + psums reassemble the exact unsharded loss.

    The permutation key is computed OUTSIDE shard_map and passed in
    replicated, so every shard applies the identical feature permutation.
    Returns ``(train_step, loss_and_grads)`` where ``loss_and_grads(params,
    batch, rng) -> (loss, metrics, grads)`` (grads already cross-shard
    reduced; jit it for repeated use).
    """
    if data_axis not in mesh.axis_names:
        raise ValueError(f"mesh {mesh.axis_names} has no data axis {data_axis!r}")
    tp = loss_cfg.distributed == "tp"
    if tp:
        if model_axis not in mesh.axis_names:
            raise ValueError(f"mesh {mesh.axis_names} has no model axis {model_axis!r}")
        d_out = model_cfg.projector_widths[-1]
        p_model = int(mesh.shape[model_axis])
        if d_out % p_model:
            raise ValueError(f"projector width {d_out} not divisible by model={p_model}")

    cfg = loss_cfg
    if cfg.distributed in ("global", "tp"):
        cfg = dataclasses.replace(cfg, axis_name=data_axis)
    if tp:
        cfg = dataclasses.replace(cfg, model_axis=model_axis)
    mode = cfg.distributed

    pspecs = ssl_param_specs(model_cfg, loss_cfg, mesh)
    with shd.sharding_context(mesh):
        batch_spec = shd.logical_to_spec(("batch", None))

    @partial(
        shard_map,
        mesh=mesh,
        in_specs=(pspecs, {"view1": batch_spec, "view2": batch_spec}, P()),
        out_specs=(P(), P()),
    )
    def sharded_loss(params, batch, rng):
        z1 = embed(params, batch["view1"])
        z2 = embed(params, batch["view2"])
        loss, metrics = ssl_loss(z1, z2, cfg, perm_key=rng)
        if mode == "local":
            # DDP objective: the mean over shard-local losses.
            loss, metrics = jax.tree.map(
                lambda x: jax.lax.pmean(x, data_axis), (loss, metrics)
            )
        # metrics are reporting-only; detaching them keeps shard_map's
        # transpose free of symbolic-Zero cotangents on collective outputs.
        return loss, jax.lax.stop_gradient(metrics)

    def loss_and_grads(params, batch, rng):
        # Differentiating THROUGH shard_map (rather than per-shard inside it)
        # makes JAX's collective transposes accumulate each parameter's
        # cotangent across shards with exactly the loss's own semantics — no
        # hand-rolled grad psums to keep in sync with the engine's modes.
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: sharded_loss(p, batch, rng), has_aux=True
        )(params)
        return loss, metrics, grads

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        rng = jax.random.fold_in(state.rng, state.step)
        loss, metrics, grads = loss_and_grads(state.params, batch, rng)
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
            metrics["grad_norm"] = gnorm
        lr = schedule(state.step)
        metrics["lr"] = lr
        new_params, new_opt = optimizer.update(grads, state.opt_state, state.params, lr)
        return TrainState(state.step + 1, new_params, new_opt, state.rng), metrics

    return train_step, loss_and_grads


def shard_ssl_batch(batch: Dict[str, Array], mesh: Mesh) -> Dict[str, Array]:
    """device_put a {view1, view2} batch with its data-parallel sharding."""
    with shd.sharding_context(mesh):
        spec = shd.logical_to_spec(("batch", None))
    sh = NamedSharding(mesh, spec)
    return {k: jax.device_put(v, sh) for k, v in batch.items()}
