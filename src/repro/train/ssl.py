"""The paper's own setting: Siamese MLP backbone + projector, trained with
Barlow Twins-style / VICReg-style losses (baseline R_off or proposed R_sum).

The backbone is deliberately simple (the paper's contribution is the loss,
not the ResNet); the projector is the standard 3-layer MLP with BN-like
standardization handled inside the loss.  ``make_ssl_train_step`` plugs into
the same optimizer/checkpoint machinery as the LM path.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.losses import DecorrConfig, ssl_loss
from repro.optim.optimizers import Optimizer, clip_by_global_norm
from repro.train.train_state import TrainState

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SSLModelConfig:
    input_dim: int = 3072
    backbone_widths: Tuple[int, ...] = (512, 512)
    projector_widths: Tuple[int, ...] = (2048, 2048, 2048)


def init_ssl_params(key: Array, cfg: SSLModelConfig) -> Dict:
    params = {"backbone": [], "projector": []}
    dims_b = (cfg.input_dim,) + cfg.backbone_widths
    dims_p = (cfg.backbone_widths[-1],) + cfg.projector_widths
    keys = jax.random.split(key, len(dims_b) + len(dims_p))
    ki = 0
    for i in range(len(dims_b) - 1):
        w = jax.random.normal(keys[ki], (dims_b[i], dims_b[i + 1]), jnp.float32)
        params["backbone"].append(
            {"w": w / jnp.sqrt(dims_b[i]), "b": jnp.zeros((dims_b[i + 1],))}
        )
        ki += 1
    for i in range(len(dims_p) - 1):
        w = jax.random.normal(keys[ki], (dims_p[i], dims_p[i + 1]), jnp.float32)
        params["projector"].append(
            {"w": w / jnp.sqrt(dims_p[i]), "b": jnp.zeros((dims_p[i + 1],))}
        )
        ki += 1
    return params


def backbone_apply(params: Dict, x: Array) -> Array:
    h = x
    for layer in params["backbone"]:
        h = jax.nn.relu(h @ layer["w"] + layer["b"])
    return h


def projector_apply(params: Dict, h: Array) -> Array:
    n = len(params["projector"])
    for i, layer in enumerate(params["projector"]):
        h = h @ layer["w"] + layer["b"]
        if i < n - 1:
            h = jax.nn.relu(h)
    return h


def embed(params: Dict, x: Array) -> Array:
    return projector_apply(params, backbone_apply(params, x))


def make_ssl_train_step(
    model_cfg: SSLModelConfig,
    loss_cfg: DecorrConfig,
    optimizer: Optimizer,
    schedule,
    clip_norm=None,
):
    def loss_fn(params, batch, rng):
        v1, v2 = batch["view1"], batch["view2"]
        z1 = embed(params, v1)
        z2 = embed(params, v2)
        loss, metrics = ssl_loss(z1, z2, loss_cfg, perm_key=rng)
        return loss, metrics

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        rng = jax.random.fold_in(state.rng, state.step)
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params, batch, rng
        )
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
            metrics["grad_norm"] = gnorm
        lr = schedule(state.step)
        metrics["lr"] = lr
        new_params, new_opt = optimizer.update(grads, state.opt_state, state.params, lr)
        return TrainState(state.step + 1, new_params, new_opt, state.rng), metrics

    return train_step, loss_fn
