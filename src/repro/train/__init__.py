from repro.train.train_state import TrainState, create_train_state
from repro.train.step import make_train_step, cross_entropy
from repro.train.loop import LoopConfig, run_training
from repro.train.serve import make_prefill_step, make_decode_step, greedy_generate
