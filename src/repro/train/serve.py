"""Serving: prefill + decode step factories and a batched greedy generator.

``serve_step`` (the decode step) is what the ``decode_*`` / ``long_*``
dry-run shapes lower: one new token against a KV cache (or SSM state) of
``seq_len`` context.  Caches are sequence-sharded over the ``model`` axis
(attention) per DESIGN.md §4; SSM states are O(1) in context length.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig
from repro.models.transformer import forward, init_caches

Array = jax.Array


def make_prefill_step(cfg: ArchConfig):
    def prefill(params, caches, tokens=None, embeds=None, positions=None):
        out = forward(
            params,
            cfg,
            tokens=tokens,
            embeds=embeds,
            positions=positions,
            caches=caches,
            cache_len=jnp.asarray(0, jnp.int32),
        )
        return out.logits[:, -1:], out.caches

    return prefill


def make_decode_step(cfg: ArchConfig):
    def decode(params, caches, cache_len, tokens=None, embeds=None, positions=None):
        out = forward(
            params,
            cfg,
            tokens=tokens,
            embeds=embeds,
            positions=positions,
            caches=caches,
            cache_len=cache_len,
        )
        return out.logits[:, 0], out.caches

    return decode


def greedy_generate(
    params,
    cfg: ArchConfig,
    prompt_tokens: Array,
    max_new_tokens: int,
    max_len: Optional[int] = None,
    steps: Optional[Tuple] = None,
) -> Array:
    """Host-loop batched greedy decoding (token-id models).

    ``steps``: optional pre-jitted ``(prefill, decode)`` pair (e.g. from
    ``repro.serve.engine.LMServeEngine``) so repeated calls share one compile
    cache; by default each call jits its own.
    """
    b, s = prompt_tokens.shape[:2]
    max_len = max_len or (s + max_new_tokens)
    caches = init_caches(cfg, b, max_len)
    if steps is None:
        steps = (jax.jit(make_prefill_step(cfg)), jax.jit(make_decode_step(cfg)))
    prefill, decode = steps

    logits, caches = prefill(params, caches, tokens=prompt_tokens)
    if cfg.frontend == "audio_codes":
        next_tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)  # (B, n_q)
        toks = [next_tok[:, None, :]]
    else:
        next_tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)  # (B,)
        toks = [next_tok[:, None]]

    pos = s
    for _ in range(max_new_tokens - 1):
        inp = toks[-1]
        logits, caches = decode(
            params, caches, jnp.asarray(pos, jnp.int32), tokens=inp
        )
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        toks.append(nxt[:, None, :] if cfg.frontend == "audio_codes" else nxt[:, None])
        pos += 1
    return jnp.concatenate(toks, axis=1)
