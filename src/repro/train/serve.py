"""Serving: prefill + decode step factories and a batched greedy generator.

``serve_step`` (the decode step) is what the ``decode_*`` / ``long_*``
dry-run shapes lower: one new token against a KV cache (or SSM state) of
``seq_len`` context.  Caches are sequence-sharded over the ``model`` axis
(attention) per DESIGN.md §4; SSM states are O(1) in context length.

Continuous batching (``repro.serve.engine.ContinuousLMEngine``) drives the
same decode step with a *vector* ``cache_len`` — one position per batch row,
so every slot of the pool advances independently — and manages per-slot
state with ``insert_slot_state`` / ``reset_slot_state`` (tree-wide writes on
the batch axis of the cache pool) plus ``make_prefill_at_step`` (prefill a
right-padded prompt, read logits/hidden at the true last token).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig
from repro.models.transformer import forward, init_caches

Array = jax.Array


def make_prefill_step(cfg: ArchConfig):
    def prefill(params, caches, tokens=None, embeds=None, positions=None):
        out = forward(
            params,
            cfg,
            tokens=tokens,
            embeds=embeds,
            positions=positions,
            caches=caches,
            cache_len=jnp.asarray(0, jnp.int32),
        )
        return out.logits[:, -1:], out.caches

    return prefill


def make_decode_step(cfg: ArchConfig, return_hidden: bool = False):
    """One-token decode step.  ``cache_len`` may be a scalar (whole-batch
    position, the ``greedy_generate`` regime) or a (B,) vector of per-slot
    positions (continuous batching).  ``block_tables`` routes the paged
    (block-table) attention path when the caches are page pools.  With
    ``return_hidden`` the step also yields the final hidden state of the new
    token — the decorrelation probes' sampling target for in-flight slots."""

    def decode(
        params, caches, cache_len, tokens=None, embeds=None, positions=None, block_tables=None
    ):
        out = forward(
            params,
            cfg,
            tokens=tokens,
            embeds=embeds,
            positions=positions,
            caches=caches,
            cache_len=cache_len,
            block_tables=block_tables,
        )
        if return_hidden:
            return out.logits[:, 0], out.hidden[:, 0], out.caches
        return out.logits[:, 0], out.caches

    return decode


def make_verify_step(cfg: ArchConfig, return_hidden: bool = False):
    """Multi-token speculative verify — the decode step at a lane-batched
    shape.

    The verify scores a slot's ``k`` drafted tokens (plus the bonus
    position) by laying the ``k + 1`` positions out on the BATCH axis, not
    the sequence axis: lane ``j`` carries ``cache_len = pos + j``, input
    token ``last_token`` (j = 0) or ``draft[j - 1]``, and the slot's
    (scratch-remapped) block-table row.  Every lane is then EXACTLY a
    one-token paged decode — the same einsum shapes, the same
    ``_decode_attention`` reduction — which is what keeps greedy speculative
    outputs bit-identical to sequential decode (the chunked-prefill
    sequence-axis path is only argmax-stable, so it cannot carry this
    guarantee).  Scatter-before-gather inside ``_paged_decode`` makes lane
    ``j`` see the writes of lanes ``< j``: they share the table row, and the
    rows they write (``pos .. pos + j - 1``) are inside lane ``j``'s
    ``cache_len`` window.

    The returned callable IS ``make_decode_step``'s — one factory, one
    contract, two batch shapes (``n_slots`` for the pool tick,
    ``n_slots * (k + 1)`` for the verify)."""
    return make_decode_step(cfg, return_hidden=return_hidden)


def make_prefill_at_step(cfg: ArchConfig):
    """Prefill a right-padded prompt and read the step outputs at the TRUE
    last prompt token (``true_len - 1``), not the padded end.

    Causal attention never lets position ``true_len - 1`` see the padding
    rows, so the returned logits/hidden are exactly the unpadded prefill's;
    the cache rows the padding wrote beyond ``true_len`` are masked out by
    the per-slot ``cache_len`` during decode and overwritten as the slot
    advances.  (Recurrent mixers — SSM/RWKV — integrate padding into their
    state, so ``ContinuousLMEngine`` only uses padded prompt buckets for
    attention-only patterns and exact-length prefill otherwise.)
    """

    def prefill_at(params, caches, tokens, true_len):
        out = forward(
            params,
            cfg,
            tokens=tokens,
            caches=caches,
            cache_len=jnp.asarray(0, jnp.int32),
        )
        last = jnp.maximum(true_len - 1, 0)
        logits = jax.lax.dynamic_index_in_dim(out.logits, last, axis=1, keepdims=False)
        hidden = jax.lax.dynamic_index_in_dim(out.hidden, last, axis=1, keepdims=False)
        return logits, hidden, out.caches

    return prefill_at


def make_chunked_prefill_step(cfg: ArchConfig):
    """One chunk of an incremental prefill at batch 1: write the chunk's KV
    at rows [offset, offset + C), attend causally across the already-written
    prefix AND within the chunk, and read logits/hidden at the chunk's true
    last token (``last``, chunk-local — only meaningful on the final chunk;
    earlier chunks run for their cache writes).

    Chunks are fixed-width C so the step compiles once; only the FINAL chunk
    may be right-padded (its pad rows write garbage KV beyond the prompt,
    masked by ``cache_len`` during decode and overwritten as the slot
    advances — the same argument as padded whole-prompt prefill).  Attention
    patterns only: recurrent mixers fold chunk padding into their state.
    """

    def prefill_chunk(params, caches, tokens, offset, last):
        out = forward(
            params,
            cfg,
            tokens=tokens,
            caches=caches,
            cache_len=offset,
            chunked_prefill=True,
        )
        logits = jax.lax.dynamic_index_in_dim(out.logits, last, axis=1, keepdims=False)
        hidden = jax.lax.dynamic_index_in_dim(out.hidden, last, axis=1, keepdims=False)
        return logits, hidden, out.caches

    return prefill_chunk


# ---------------------------------------------------------------------------
# Per-slot cache pool surgery (continuous batching)
# ---------------------------------------------------------------------------
#
# Every cache leaf is laid out (repeats, batch, ...) — axis 1 is the slot
# axis — so inserting a prefilled single-request cache (batch=1 leaves) or
# resetting a retired slot is one tree-wide write.  Both take a *traced* slot
# index: jit them once and reuse for every slot.


def insert_slot_state(pool, one, slot):
    """Write a batch-1 cache/state tree ``one`` into slot ``slot`` of the
    batched ``pool`` (leaf shapes (repeats, 1, ...) -> (repeats, B, ...))."""
    return jax.tree.map(
        lambda p, o: jax.lax.dynamic_update_slice_in_dim(p, o.astype(p.dtype), slot, axis=1),
        pool,
        one,
    )


def reset_slot_state(pool, slot):
    """Zero slot ``slot`` across every cache/state leaf.  Decode masks freed
    slots out by ``cache_len`` anyway; resetting keeps retired KV/SSM state
    from lingering in memory dumps and makes slot reuse order-independent."""
    return jax.tree.map(lambda p: p.at[:, slot].set(jnp.zeros((), p.dtype)), pool)


# ---------------------------------------------------------------------------
# Paged cache pool surgery (block-table continuous batching)
# ---------------------------------------------------------------------------
#
# Paged pools mix two leaf layouts per pattern position: attention holds
# page pools (repeats, P, page, kv, hd) addressed through block tables, and
# recurrent state stays slot-major (repeats, B, ...) like the dense pool.
# All three helpers below take traced indices, so one jitted instance serves
# every slot; the host-side allocator (`repro.serve.paging`) owns which
# physical pages each table row names.


def _is_paged(leafs) -> bool:
    return isinstance(leafs, dict) and "k_pages" in leafs


def insert_slot_state_paged(pool, one, slot, bt_row):
    """Scatter a prefilled batch-1 DENSE cache tree ``one`` into the paged
    pool: attention rows [j * page, (j + 1) * page) land in physical page
    ``bt_row[j]`` (unassigned table entries point at the sentinel page, which
    absorbs the template's padding rows), recurrent state is a dense
    per-slot write.  ``bt_row``: (NB,) int32 with NB * page == the template's
    max_len."""
    out = {}
    for name, leafs in pool.items():
        if _is_paged(leafs):
            page = leafs["k_pages"].shape[2]
            nb = bt_row.shape[0]
            out[name] = {}
            for key, src in (("k_pages", "k"), ("v_pages", "v")):
                rows = one[name][src][:, 0]  # (repeats, L, kv, hd), L == nb * page
                rows = rows.reshape(rows.shape[0], nb, page, *rows.shape[2:])
                out[name][key] = leafs[key].at[:, bt_row].set(rows.astype(leafs[key].dtype))
        else:
            out[name] = jax.tree.map(
                lambda p, o: jax.lax.dynamic_update_slice_in_dim(
                    p, o.astype(p.dtype), slot, axis=1
                ),
                leafs,
                one[name],
            )
    return out


def reset_slot_state_paged(pool, slot, bt_row):
    """Zero a retired slot's pages (and its dense recurrent state).  Same
    hygiene contract as ``reset_slot_state``; sentinel entries in ``bt_row``
    get zeroed too, which is harmless (the sentinel is never read unmasked)."""
    out = {}
    for name, leafs in pool.items():
        if _is_paged(leafs):
            out[name] = {
                key: leafs[key].at[:, bt_row].set(jnp.zeros((), leafs[key].dtype))
                for key in ("k_pages", "v_pages")
            }
        else:
            out[name] = jax.tree.map(lambda p: p.at[:, slot].set(jnp.zeros((), p.dtype)), leafs)
    return out


def load_template_from_pages(pool, one, bt_row):
    """Inverse of ``insert_slot_state_paged`` for one slot: gather physical
    pages ``bt_row`` out of the paged pool into a batch-1 DENSE template tree
    (attention rows [j * page, (j + 1) * page) read page ``bt_row[j]``).  A
    warm prefix-cache request seeds its chunked-prefill template this way, so
    the chunk step attends over the shared prefix's exact KV rows without
    recomputing them.  Sentinel entries gather scratch-page bytes — callers
    mask those rows via ``cache_len``, the same contract as padded prefill.
    Recurrent leaves pass through from ``one`` (prefix caching is
    attention-only)."""
    out = {}
    for name, leafs in pool.items():
        if _is_paged(leafs):
            nb = bt_row.shape[0]
            page = leafs["k_pages"].shape[2]
            out[name] = {}
            for key, dst in (("k_pages", "k"), ("v_pages", "v")):
                rows = leafs[key][:, bt_row]  # (repeats, nb, page, kv, hd)
                rows = rows.reshape(rows.shape[0], 1, nb * page, *rows.shape[3:])
                out[name][dst] = rows.astype(one[name][dst].dtype)
        else:
            out[name] = one[name]
    return out


def apply_page_moves(pool, src, dst):
    """Copy physical pages ``src[i] -> dst[i]`` across every paged leaf (the
    device half of allocator compaction).  Identity moves (src == dst) are
    no-ops, so the host can pad its move list to a fixed width and this jits
    once."""
    out = {}
    for name, leafs in pool.items():
        if _is_paged(leafs):
            out[name] = {
                key: leafs[key].at[:, dst].set(leafs[key][:, src])
                for key in ("k_pages", "v_pages")
            }
        else:
            out[name] = leafs
    return out


def greedy_generate(
    params,
    cfg: ArchConfig,
    prompt_tokens: Array,
    max_new_tokens: int,
    max_len: Optional[int] = None,
    steps: Optional[Tuple] = None,
) -> Array:
    """Host-loop batched greedy decoding (token-id models).

    ``steps``: optional pre-jitted ``(prefill, decode)`` pair (e.g. from
    ``repro.serve.engine.LMServeEngine``) so repeated calls share one compile
    cache; by default each call jits its own.
    """
    b, s = prompt_tokens.shape[:2]
    max_len = max_len or (s + max_new_tokens)
    caches = init_caches(cfg, b, max_len)
    if steps is None:
        steps = (jax.jit(make_prefill_step(cfg)), jax.jit(make_decode_step(cfg)))
    prefill, decode = steps

    logits, caches = prefill(params, caches, tokens=prompt_tokens)
    if cfg.frontend == "audio_codes":
        next_tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)  # (B, n_q)
        toks = [next_tok[:, None, :]]
    else:
        next_tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)  # (B,)
        toks = [next_tok[:, None]]

    pos = s
    for _ in range(max_new_tokens - 1):
        inp = toks[-1]
        logits, caches = decode(
            params, caches, jnp.asarray(pos, jnp.int32), tokens=inp
        )
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        toks.append(nxt[:, None, :] if cfg.frontend == "audio_codes" else nxt[:, None])
        pos += 1
    return jnp.concatenate(toks, axis=1)
