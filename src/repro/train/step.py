"""Train-step factories.

``make_train_step`` builds the jit-able LM step:
  loss = CE + router-balance aux (MoE) + decorrelation aux (the paper's
  regularizer on final hidden states, core/decorrelation.py)

Features:
  * gradient accumulation: ``num_microbatches`` splits the per-step batch and
    accumulates grads in f32 under one ``lax.scan`` (required to fit the
    100B+ archs' activations; see DESIGN.md §7),
  * global-norm clipping,
  * deterministic per-step RNG (fold_in of step — restart-safe),
  * all cross-device reduction is implicit through pjit shardings; the
    explicit shard_map variant with compressed gradient all-reduce lives in
    ``make_compressed_dp_step``.
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.decorrelation import lm_decorrelation_loss
from repro.models.common import ArchConfig
from repro.models.transformer import forward
from repro.optim.optimizers import Optimizer, clip_by_global_norm
from repro.train.train_state import TrainState

Array = jax.Array


def cross_entropy(logits: Array, labels: Array) -> Array:
    """Mean CE. logits (..., V) f32; labels (...) int32 (extra dims ok)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def _lm_loss_fn(params, batch, cfg: ArchConfig, rng: Array):
    kwargs = {}
    if "embeds" in batch:
        kwargs["embeds"] = batch["embeds"]
    else:
        kwargs["tokens"] = batch["tokens"]
    if "positions" in batch:
        kwargs["positions"] = batch["positions"]
    out = forward(params, cfg, **kwargs)
    ce = cross_entropy(out.logits, batch["labels"])
    decorr, dmetrics = lm_decorrelation_loss(out.hidden, cfg.decorr, perm_key=rng)
    moe_aux = out.aux["moe_aux"] * cfg.router_aux_weight
    loss = ce + decorr + moe_aux
    metrics = {"loss": loss, "ce": ce, "moe_aux": moe_aux, **dmetrics}
    return loss, metrics


def make_train_step(
    cfg: ArchConfig,
    optimizer: Optimizer,
    schedule: Callable[[Array], Array],
    num_microbatches: int = 1,
    clip_norm: Optional[float] = 1.0,
    loss_fn=None,
    grad_shardings=None,
):
    """``grad_shardings``: optional pytree of NamedShardings (matching
    params) to constrain the gradient ACCUMULATOR under microbatching.
    Without it the accumulator is replicated and GSPMD all-reduces every
    microbatch's full gradient; with it each microbatch reduce-scatters into
    the FSDP shards — 2x(data-1)/data less collective volume per microbatch
    (EXPERIMENTS.md §Perf, arctic cell)."""
    loss_fn = loss_fn or functools.partial(_lm_loss_fn, cfg=cfg)

    def _constrain(tree):
        if grad_shardings is None:
            return tree
        return jax.tree.map(
            lambda x, sh: jax.lax.with_sharding_constraint(x, sh), tree, grad_shardings
        )

    def train_step(state: TrainState, batch: Dict[str, Array]) -> Tuple[TrainState, Dict]:
        rng = jax.random.fold_in(state.rng, state.step)

        if num_microbatches <= 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state.params, batch, rng=rng
            )
        else:
            def split(x):
                return x.reshape((num_microbatches, x.shape[0] // num_microbatches) + x.shape[1:])

            micro = jax.tree.map(split, batch)
            zero_g = _constrain(
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            )

            def body(acc, mb):
                g_acc, m_acc = acc
                (_, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, mb, rng=rng
                )
                g_acc = _constrain(
                    jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                )
                m_acc = jax.tree.map(lambda a, b: a + b, m_acc, m)
                return (g_acc, m_acc), None

            mb0 = jax.tree.map(lambda x: x[0], micro)
            m_shapes = jax.eval_shape(
                lambda p, b: loss_fn(p, b, rng=rng)[1], state.params, mb0
            )
            zero_m = jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32), m_shapes)
            (grads, metrics), _ = jax.lax.scan(body, (zero_g, zero_m), micro)
            grads = jax.tree.map(lambda g: g / num_microbatches, grads)
            metrics = jax.tree.map(lambda m: m / num_microbatches, metrics)

        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
            metrics["grad_norm"] = gnorm
        lr = schedule(state.step)
        metrics["lr"] = lr
        new_params, new_opt = optimizer.update(grads, state.opt_state, state.params, lr)
        return (
            TrainState(
                step=state.step + 1,
                params=new_params,
                opt_state=new_opt,
                rng=state.rng,
            ),
            metrics,
        )

    return train_step


# ---------------------------------------------------------------------------
# Explicit-DP variant with compressed gradient all-reduce (shard_map)
# ---------------------------------------------------------------------------


def make_compressed_dp_step(
    loss_fn,
    optimizer: Optimizer,
    schedule,
    axis_name: str = "data",
    compression: str = "int8_ef",  # none | bf16 | int8_ef
):
    """Per-shard loss + explicit compressed psum of grads.  Used inside
    shard_map over the data axis; state.opt_state carries the error-feedback
    buffers for int8_ef."""
    from repro.optim import compression as comp

    def step(state: TrainState, batch, ef_errors):
        rng = jax.random.fold_in(state.rng, state.step)
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params, batch, rng=rng
        )
        if compression == "bf16":
            grads = comp.bf16_psum(grads, axis_name)
            grads = jax.tree.map(lambda g: g / jax.lax.psum(1, axis_name), grads)
        elif compression == "int8_ef":
            grads, ef_errors = comp.int8_psum_ef(grads, ef_errors, axis_name)
            grads = jax.tree.map(lambda g: g / jax.lax.psum(1, axis_name), grads)
        else:
            grads = jax.tree.map(lambda g: jax.lax.pmean(g, axis_name), grads)
        lr = schedule(state.step)
        new_params, new_opt = optimizer.update(grads, state.opt_state, state.params, lr)
        new_state = TrainState(state.step + 1, new_params, new_opt, state.rng)
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, axis_name), metrics)
        return new_state, metrics, ef_errors

    return step
