"""Train state pytree."""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class TrainState(NamedTuple):
    step: jax.Array  # int32 scalar
    params: Any
    opt_state: Any
    rng: jax.Array  # PRNG key


def create_train_state(params, optimizer, seed: int = 0) -> TrainState:
    return TrainState(
        step=jnp.zeros((), jnp.int32),
        params=params,
        opt_state=optimizer.init(params),
        rng=jax.random.PRNGKey(seed),
    )
