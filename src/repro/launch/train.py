"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --reduced \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Full-size configs target the production mesh (run under a real TPU runtime;
on this container use --reduced, which runs the same code path on 1 CPU
device).  The paper's decorrelation aux loss is enabled with --decorr.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.decorrelation import LMDecorrConfig
from repro.core.losses import DecorrConfig
from repro.data import LMDataConfig, lm_batch
from repro.launch.obs_args import (
    add_obs_args,
    attach_train_step,
    build_train_obs,
    finish_train_obs,
)
from repro.models import init_params
from repro.optim import adamw, warmup_cosine
from repro.train import LoopConfig, create_train_state, make_train_step, run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-interval", type=int, default=50)
    ap.add_argument("--decorr", action="store_true", help="enable the paper's aux loss")
    ap.add_argument("--decorr-block", type=int, default=None)
    ap.add_argument(
        "--pretune",
        default="analytic",
        choices=["off", "analytic", "dry", "measure"],
        help="warm the repro.tune cache for the decorr kernel shapes before "
        "the first step is traced (ROADMAP: tune-cache warm-up hook)",
    )
    ap.add_argument("--seed", type=int, default=0)
    add_obs_args(ap)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.decorr:
        cfg = dataclasses.replace(
            cfg,
            decorr=LMDecorrConfig(
                enabled=True,
                decorr=DecorrConfig(style="vic", reg="sum", block_size=args.decorr_block, q=2),
                nu=0.04,
            ),
        )

    print(f"[train] arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"devices={len(jax.devices())}")
    if args.decorr and args.pretune != "off":
        from repro.decorr import warmup_tune_cache

        # the aux-loss statistic has batch * tokens_per_seq rows of width
        # d_model — pre-tune those shapes so the first jitted step is warm.
        t_tune = time.time()
        n_jobs = len(warmup_tune_cache(
            args.batch * cfg.decorr.tokens_per_seq, cfg.d_model, cfg.decorr.decorr,
            mode=args.pretune,
        ))
        print(f"[train] pre-tuned {n_jobs} decorr kernel shapes "
              f"({args.pretune}, {time.time()-t_tune:.1f}s)")
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    opt = adamw()
    sched = warmup_cosine(args.lr, max(args.steps // 10, 1), args.steps)
    state = create_train_state(params, opt, seed=args.seed)
    step_fn = jax.jit(make_train_step(cfg, opt, sched, num_microbatches=args.microbatches))

    dcfg = LMDataConfig(
        vocab_size=cfg.vocab_size,
        batch=args.batch,
        seq_len=args.seq,
        seed=args.seed,
        n_codebooks=cfg.n_codebooks if cfg.frontend == "audio_codes" else 0,
    )

    def batch_fn(step):
        b = lm_batch(dcfg, step)
        out = {k: jnp.asarray(v) for k, v in b.items()}
        if cfg.frontend == "vision_stub":
            # frontend stub: tokens -> pseudo patch embeddings + M-RoPE ids
            tok = out.pop("tokens")
            key = jax.random.fold_in(jax.random.PRNGKey(dcfg.seed), step)
            out["embeds"] = jax.random.normal(key, (*tok.shape, cfg.d_model), jnp.float32) * 0.02
            pos = jnp.arange(tok.shape[1], dtype=jnp.int32)[None, None, :]
            out["positions"] = jnp.broadcast_to(pos, (3, *tok.shape))
        return out

    lcfg = LoopConfig(
        total_steps=args.steps,
        ckpt_dir=args.ckpt_dir,
        ckpt_interval=args.ckpt_interval,
        log_interval=max(args.steps // 10, 1),
    )

    t0 = time.time()

    def log_fn(step, m):
        print(f"  step {step:5d} loss={m.get('loss', 0):.4f} ce={m.get('ce', 0):.4f} "
              f"decorr={m.get('decorr_aux', 0):.5f} ({time.time()-t0:.1f}s)")

    obs = build_train_obs(args)
    if obs is not None:
        attach_train_step(obs, step_fn, state, batch_fn(0))
    state = run_training(
        state, step_fn, batch_fn, lcfg, log_fn=log_fn,
        registry=obs.registry if obs is not None else None,
        perf=obs.perf if obs is not None else None,
    )
    print(f"[train] done at step {int(state.step)} in {time.time()-t0:.1f}s")
    finish_train_obs(args, obs)


if __name__ == "__main__":
    main()
