"""Optimized-HLO cost analyzer — exact roofline inputs.

``compiled.cost_analysis()`` counts each ``while`` body ONCE, so any graph
with scan-over-layers / gradient-accumulation / chunked-attention scans
undercounts FLOPs, bytes and collective traffic by the trip counts.  This
module parses the *partitioned, optimized* HLO text instead:

  1. symbol table: every ``%name = dtype[dims]...`` definition + computation
     header parameters,
  2. computation segmentation + call graph (while body/condition, fusion
     ``calls=``, ``to_apply=``),
  3. trip-count extraction from while condition regions (max integer
     constant — scan lowers to ``i < N``),
  4. execution-count multipliers propagated from ENTRY through the graph,
  5. cost sums:
       * flops        — 2 * prod(result) * K for every dot (batch dims via
                        result shape), times multiplier,
       * collectives  — per-op traffic from result shapes with ring-model
                        factors (all-reduce 2x, others 1x), times multiplier,
       * hbm_bytes    — sum of (result + distinct operand) bytes of
                        top-level ops (fusion internals excluded: a kLoop
                        fusion is one read-modify-write), times multiplier.
                        An upper-bound traffic model: assumes no cross-op
                        fusion beyond what XLA:CPU already fused.

Everything is computed per-device (the HLO is the per-device SPMD program).
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([^\s=]+)\s*=\s*(.*)$")
_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([^\s(]+)\s*\((.*)\)\s*->")
_PARAM_RE = re.compile(r"([^\s:,()]+):\s*([a-z0-9]+\[[0-9,]*\])")
_ATTR_RE = {
    "body": re.compile(r"body=%?([^\s,)]+)"),
    "condition": re.compile(r"condition=%?([^\s,)]+)"),
    "calls": re.compile(r"calls=%?([^\s,)]+)"),
    "to_apply": re.compile(r"to_apply=%?([^\s,)]+)"),
    "branches": re.compile(r"branch_computations=\{([^}]*)\}"),
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")
# ring-model traffic factor applied to the RESULT size
_COLL_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "partition-id", "replica-id",
    "opt-barrier",
}


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _first_shape(text: str) -> Optional[Tuple[str, Tuple[int, ...]]]:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    dims = tuple(int(d) for d in m.group(2).split(",") if d)
    return m.group(1), dims


def _all_shapes_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        total += _shape_elems(m.group(2)) * _DTYPE_BYTES.get(m.group(1), 4)
    return total


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    result_text: str  # everything between '=' and opcode
    operands: List[str]
    attrs_text: str
    line: str


@dataclasses.dataclass
class HLOAnalysis:
    flops: float
    hbm_bytes: float
    collective_bytes: Dict[str, float]
    dot_flops_by_meta: Dict[str, float]
    trip_counts: Dict[str, int]
    n_ops: int
    cost_flops_unscaled: float = 0.0

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


_OPCODE_RE = re.compile(
    r"^(?:\(?[a-z0-9]+\[[0-9,]*\][^)]*\)?[^ ]*\s+)?([a-z][a-z0-9-]*)\("
)


def _parse_op(line: str) -> Optional[Op]:
    m = _DEF_RE.match(line)
    if not m:
        return None
    name, rhs = m.group(1), m.group(2)
    # opcode = the token immediately before the first '(' that isn't a shape
    # rhs looks like: "f32[16,2]{1,0} dot(%a, %b), attrs" or
    # "(f32[..], f32[..]) while(%t), condition=..., body=..."
    paren = rhs.find("(")
    # skip a leading tuple-type "( ... )" result
    if paren == 0:
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    paren = rhs.find("(", i + 1)
                    break
    if paren < 0:
        return None
    # token before '('
    head = rhs[:paren].rstrip()
    sp = head.rfind(" ")
    opcode = head[sp + 1:]
    result_text = head[:sp + 1] if sp >= 0 else ""
    # operand section: balanced parens from `paren`
    depth = 0
    end = paren
    for i in range(paren, len(rhs)):
        if rhs[i] == "(":
            depth += 1
        elif rhs[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    operand_text = rhs[paren + 1:end]
    operands = re.findall(r"%([^\s,()]+)", operand_text)
    attrs = rhs[end + 1:]
    return Op(name=name, opcode=opcode, result_text=result_text, operands=operands, attrs_text=attrs, line=line)


def analyze_hlo(hlo: str) -> HLOAnalysis:
    # ---- segmentation + symbol table ------------------------------------
    computations: Dict[str, List[Op]] = {}
    shapes: Dict[str, Tuple[str, Tuple[int, ...]]] = {}
    entry: Optional[str] = None
    current: Optional[str] = None

    for raw in hlo.splitlines():
        line = raw.rstrip()
        if line.endswith("{") and ("->" in line or line.lstrip().startswith("ENTRY")):
            h = _HDR_RE.match(line.strip())
            if h:
                current = h.group(2)
                computations[current] = []
                if h.group(1):
                    entry = current
                for pm in _PARAM_RE.finditer(h.group(3)):
                    sh = _first_shape(pm.group(2))
                    if sh:
                        shapes[pm.group(1)] = sh
                continue
        if line.strip() == "}":
            continue
        op = _parse_op(line)
        if op is None or current is None:
            continue
        computations[current].append(op)
        sh = _first_shape(op.result_text)
        if sh:
            shapes[op.name] = sh

    # ---- call graph + trip counts ----------------------------------------
    def trip_count(cond_name: str) -> int:
        best = 1
        for op in computations.get(cond_name, []):
            if op.opcode == "constant":
                mm = re.search(r"constant\((-?\d+)\)", op.line)
                if mm:
                    best = max(best, int(mm.group(1)))
        return best

    mult: Dict[str, float] = defaultdict(float)
    trips: Dict[str, int] = {}
    if entry is None:
        entry = next(iter(computations), None)
    if entry is None:
        return HLOAnalysis(0, 0, {c: 0.0 for c in _COLLECTIVES}, {}, {}, 0)

    # BFS propagate execution multipliers
    pending = [(entry, 1.0)]
    seen_pairs = set()
    fusion_comps = set()
    while pending:
        comp, m = pending.pop()
        if m <= mult[comp]:
            continue
        mult[comp] = m
        for op in computations.get(comp, []):
            if op.opcode == "while":
                b = _ATTR_RE["body"].search(op.attrs_text)
                c = _ATTR_RE["condition"].search(op.attrs_text)
                if b and c:
                    t = trip_count(c.group(1))
                    trips[b.group(1)] = t
                    pending.append((b.group(1), m * t))
                    pending.append((c.group(1), m * (t + 1)))
            elif op.opcode == "conditional":
                br = _ATTR_RE["branches"].search(op.attrs_text)
                if br:
                    for b in re.findall(r"%?([^\s,]+)", br.group(1)):
                        pending.append((b, m))
            else:
                for key in ("calls", "to_apply"):
                    a = _ATTR_RE[key].search(op.attrs_text)
                    if a:
                        if key == "calls":
                            fusion_comps.add(a.group(1))
                        pending.append((a.group(1), m))

    # ---- cost sums --------------------------------------------------------
    flops = 0.0
    hbm = 0.0
    coll = {c: 0.0 for c in _COLLECTIVES}
    dot_by_meta: Dict[str, float] = defaultdict(float)
    n_ops = 0

    for comp, ops in computations.items():
        m = mult.get(comp, 0.0)
        if m == 0.0:
            continue
        in_fusion = comp in fusion_comps
        for op in ops:
            n_ops += 1
            res = _first_shape(op.result_text)
            # FLOPs: dots can live inside fusions on some backends — count
            # them wherever they appear.
            if op.opcode == "dot" and res is not None:
                k = 1.0
                lc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs_text)
                if lc and op.operands:
                    lhs = shapes.get(op.operands[0])
                    if lhs:
                        for idx in lc.group(1).split(","):
                            if idx:
                                k *= lhs[1][int(idx)]
                f = 2.0 * _shape_elems(",".join(map(str, res[1]))) * k
                flops += m * f
                meta = re.search(r'op_name="([^"]*)"', op.attrs_text)
                dot_by_meta[meta.group(1) if meta else op.name] += m * f
            if op.opcode in ("convolution",) and res is not None:
                # depthwise/standard conv: 2 * out_elems * kernel_elems
                kshape = shapes.get(op.operands[1]) if len(op.operands) > 1 else None
                kelems = _shape_elems(",".join(map(str, kshape[1]))) if kshape else 1
                flops += m * 2.0 * _shape_elems(",".join(map(str, res[1]))) * kelems
            if op.opcode == "fft" and res is not None:
                # 5 N log2 N per length-N transform (standard FFT cost model)
                fl = re.search(r"fft_length=\{([0-9,]+)\}", op.attrs_text)
                if fl:
                    n_fft = 1
                    for d in fl.group(1).split(","):
                        n_fft *= int(d)
                    total_elems = _shape_elems(",".join(map(str, res[1])))
                    rows = max(1, total_elems // max(res[1][-1], 1))
                    flops += m * 5.0 * rows * n_fft * max(math.log2(n_fft), 1.0)

            if in_fusion:
                continue  # bytes of fusion internals don't touch HBM

            if op.opcode in _SKIP_BYTES_OPS:
                continue
            # collectives
            if op.opcode.rstrip("-start").rstrip("-done") in _COLLECTIVES or op.opcode in _COLLECTIVES:
                base = op.opcode.replace("-start", "").replace("-done", "")
                if base in _COLLECTIVES and not op.opcode.endswith("-done"):
                    size = _all_shapes_bytes(op.result_text)
                    coll[base] += m * size * _COLL_FACTOR[base]
                continue
            # HBM traffic: result write + operand reads.  Slicing ops touch
            # only the slice, not the full operand (a scan reading one step
            # of a stacked array must not be charged the whole stack):
            #   dynamic-slice / slice / gather : read+write = 2 x result
            #   dynamic-update-slice / scatter : read+write = 2 x update
            size = _all_shapes_bytes(op.result_text)
            if op.opcode in ("dynamic-slice", "slice", "gather"):
                hbm += m * 2.0 * size
                continue
            if op.opcode in ("dynamic-update-slice", "scatter"):
                upd = shapes.get(op.operands[1]) if len(op.operands) > 1 else None
                ub = (
                    _shape_elems(",".join(map(str, upd[1]))) * _DTYPE_BYTES.get(upd[0], 4)
                    if upd
                    else size
                )
                hbm += m * 2.0 * ub
                continue
            opnd = 0
            for o in op.operands:
                sh = shapes.get(o)
                if sh:
                    opnd += _shape_elems(",".join(map(str, sh[1]))) * _DTYPE_BYTES.get(sh[0], 4)
            hbm += m * (size + opnd)

    return HLOAnalysis(
        flops=flops,
        hbm_bytes=hbm,
        collective_bytes=coll,
        dot_flops_by_meta=dict(dot_by_meta),
        trip_counts=trips,
        n_ops=n_ops,
    )


# ---------------------------------------------------------------------------
# Roofline terms (TPU v5e constants from the assignment)
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link


def roofline_terms(analysis: HLOAnalysis) -> Dict[str, float]:
    t_compute = analysis.flops / PEAK_FLOPS
    t_memory = analysis.hbm_bytes / HBM_BW
    t_coll = analysis.total_collective_bytes / ICI_BW
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    return {
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_coll,
        "dominant": dominant,
        "bound_s": max(t_compute, t_memory, t_coll),
    }
