import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape x
mesh) cell; record memory_analysis / cost_analysis / collective-bytes.

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun_results

The first two lines of this file (before ANY other import) force 512
placeholder CPU devices so ``jax.make_mesh`` can build the production
meshes; nothing is ever allocated — inputs are ShapeDtypeStructs.
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Dict  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config, list_archs  # noqa: E402
from repro.launch import specs as S  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.optim import adamw, warmup_cosine  # noqa: E402
from repro.parallel.sharding import sharding_context  # noqa: E402
from repro.train.serve import make_decode_step, make_prefill_step  # noqa: E402
from repro.train.step import make_train_step  # noqa: E402
from repro.train.train_state import TrainState  # noqa: E402

def num_microbatches_for(cfg, shape: S.ShapeSpec, mesh) -> int:
    if shape.kind != "train":
        return 1
    n_data = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n_data *= mesh.shape[a]
    per_dev = max(1, shape.global_batch // n_data)
    params_b = cfg.param_count() / 1e9
    target_per_dev = 1 if params_b > 30 else (4 if params_b > 4 else per_dev)
    micro = max(1, per_dev // target_per_dev)
    while shape.global_batch % micro != 0:
        micro -= 1
    return micro


def build_cell(arch: str, shape_name: str, multi_pod: bool):
    """Returns (fn, example_args, meta) ready for jit(fn).lower(*args)."""
    cfg = get_config(arch)
    shape = S.SHAPES[shape_name]
    ok, why = S.cell_applicable(cfg, shape)
    if not ok:
        return None, None, {"skip": why}
    mesh = make_production_mesh(multi_pod=multi_pod)

    moment_dtype = jnp.bfloat16 if str(cfg.optimizer_moment_dtype) in ("bfloat16", "bf16") else jnp.float32
    opt = adamw(moment_dtype=moment_dtype)
    sched = warmup_cosine(3e-4, 2000, 100_000)

    with sharding_context(mesh):
        params = S.params_spec_tree(cfg, mesh)
        meta = {"mesh_shape": dict(mesh.shape), "params": int(cfg.param_count())}

        if shape.kind == "train":
            micro = num_microbatches_for(cfg, shape, mesh)
            meta["num_microbatches"] = micro
            step = make_train_step(cfg, opt, sched, num_microbatches=micro)
            state = TrainState(
                step=S.scalar_spec(mesh),
                params=params,
                opt_state=S.opt_state_spec_tree(opt.init, params, mesh),
                rng=S.rng_spec(mesh),
            )
            batch = S.batch_specs(cfg, shape, mesh)

            def fn(state, batch):
                with sharding_context(mesh):
                    return step(state, batch)

            return fn, (state, batch), meta

        if shape.kind == "prefill":
            caches = S.cache_specs(cfg, shape.global_batch, shape.seq_len, mesh)
            toks = S.batch_specs(cfg, shape, mesh)
            toks.pop("labels")
            step = make_prefill_step(cfg)

            def fn(params, caches, inputs):
                with sharding_context(mesh):
                    return step(params, caches, **inputs)

            return fn, (params, caches, toks), meta

        # decode: one new token against a seq_len cache
        caches = S.cache_specs(cfg, shape.global_batch, shape.seq_len, mesh)
        toks = S.decode_token_specs(cfg, shape.global_batch, mesh)
        step = make_decode_step(cfg)

        def fn(params, caches, cache_len, inputs):
            with sharding_context(mesh):
                return step(params, caches, cache_len, **inputs)

        return fn, (params, caches, S.scalar_spec(mesh), toks), meta


def model_flops(cfg, shape: S.ShapeSpec) -> float:
    """6*N_active*tokens (train) / 2*N_active*tokens (inference)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: one token per row


def run_cell(arch: str, shape_name: str, multi_pod: bool, keep_hlo: bool = False) -> Dict:
    rec: Dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "n_devices": 512 if multi_pod else 256,
    }
    try:
        fn, args, meta = build_cell(arch, shape_name, multi_pod)
        if fn is None:
            rec.update(status="skipped", reason=meta["skip"])
            return rec
        rec.update(meta)
        t0 = time.time()
        lowered = jax.jit(fn).lower(*args)
        rec["lower_s"] = round(time.time() - t0, 2)
        t0 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 2)

        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
        }
        cost = compiled.cost_analysis()
        rec["cost_flops_body_once"] = float(cost.get("flops", 0.0))
        rec["cost_bytes_body_once"] = float(cost.get("bytes accessed", 0.0))

        hlo = compiled.as_text()
        from repro.launch import hlo_cost

        analysis = hlo_cost.analyze_hlo(hlo)
        rec["flops"] = analysis.flops  # per-device, while-trip-exact
        rec["hbm_bytes"] = analysis.hbm_bytes
        rec["collectives"] = {k: float(v) for k, v in analysis.collective_bytes.items()}
        rec["collectives"]["total"] = float(analysis.total_collective_bytes)
        rec["trip_counts"] = analysis.trip_counts
        rec["roofline"] = hlo_cost.roofline_terms(analysis)
        rec["hlo_lines"] = hlo.count("\n")
        cfg = get_config(arch)
        n_dev = rec["n_devices"]
        rec["model_flops_total"] = model_flops(cfg, S.SHAPES[shape_name])
        rec["model_flops_per_device"] = rec["model_flops_total"] / n_dev
        rec["useful_flops_ratio"] = (
            rec["model_flops_per_device"] / analysis.flops if analysis.flops else 0.0
        )
        rec["status"] = "ok"
        if keep_hlo:
            rec["hlo"] = hlo
    except Exception as e:  # recorded, not raised — the sweep continues
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(S.SHAPES) + [None])
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="directory for per-cell JSON records")
    args = ap.parse_args()

    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(S.SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    if args.out:
        os.makedirs(args.out, exist_ok=True)

    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                mesh_name = "multi" if multi else "single"
                out_path = (
                    os.path.join(args.out, f"{arch}__{shape}__{mesh_name}.json")
                    if args.out
                    else None
                )
                if out_path and os.path.exists(out_path):
                    print(f"[cached] {arch} {shape} {mesh_name}")
                    continue
                rec = run_cell(arch, shape, multi)
                keys = ("arch", "shape", "mesh", "status", "lower_s", "compile_s", "flops", "error")
                line = {k: rec.get(k) for k in keys}
                print(json.dumps(line), flush=True)
                if rec.get("status") == "ok":
                    print("  memory:", rec["memory"])
                    print("  collectives:", {k: f"{v:.3g}" for k, v in rec["collectives"].items()})
                    roof = {k: (f"{v:.3g}" if isinstance(v, float) else v) for k, v in rec["roofline"].items()}
                    print("  roofline:", roof)
                if out_path:
                    with open(out_path, "w") as f:
                        json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
