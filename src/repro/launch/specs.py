"""ShapeDtypeStruct input specs + parameter/optimizer sharding rules for
every (architecture x input shape) dry-run cell.

Nothing here allocates device memory: params, optimizer state, caches and
batches are all ``jax.ShapeDtypeStruct`` stand-ins carrying NamedShardings.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ArchConfig
from repro.models.transformer import init_caches, init_params

# ---------------------------------------------------------------------------
# Assigned input shapes (assignment block)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# archs whose decode cost is sub-quadratic in context => run long_500k
LONG_CONTEXT_ARCHS = ("rwkv6-3b", "jamba-v0.1-52b")


def cell_applicable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    if shape.name == "long_500k" and cfg.name not in LONG_CONTEXT_ARCHS:
        return False, (
            "skipped: full/global attention is quadratic in a 524k cache; "
            "run for SSM/hybrid archs only (DESIGN.md §5)"
        )
    return True, ""


# ---------------------------------------------------------------------------
# Parameter sharding rules (path-name based)
# ---------------------------------------------------------------------------

# stacked block leaves: name -> spec for (rep, *dims); non-stacked handled
# separately.  "data" = FSDP axis, "model" = TP/EP axis.
_BLOCK_RULES: Dict[str, Tuple] = {
    "wq": (None, "data", "model"),
    "wk": (None, "data", "model"),
    "wv": (None, "data", "model"),
    "wo": (None, "model", "data"),
    "bq": (None, "model"),
    "bk": (None, "model"),
    "bv": (None, "model"),
    "w_gate": (None, "data", "model"),
    "router": (None, "data", None),
    "in_proj": (None, "data", "model"),
    "conv_w": (None, None, "model"),
    "conv_b": (None, "model"),
    "x_proj": (None, "model", None),
    "dt_proj": (None, None, "model"),
    "dt_bias": (None, "model"),
    "a_log": (None, "model", None),
    "d_skip": (None, "model"),
    "out_proj": (None, "model", "data"),
    "w_r": (None, "data", "model"),
    "w_k": (None, "data", "model"),
    "w_v": (None, "data", "model"),
    "w_g": (None, "data", "model"),
    "w_o": (None, "model", "data"),
    "cmix_wk": (None, "data", "model"),
    "cmix_wv": (None, "model", "data"),
    "cmix_wr": (None, "data", "model"),
    "lora_a": (None, "data", None),
    "lora_b": (None, None, None, "data"),
    "decay_lora_a": (None, "data", None),
    "decay_lora_b": (None, None, "data"),
}

# rank-dependent (dense MLP (rep,d,ff) vs MoE experts (rep,E,d,ff))
_W_IN_LIKE = {"w_in"}
_W_OUT_LIKE = {"w_out"}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
    return ""


def param_spec(path, leaf) -> P:
    name = _leaf_name(path)
    ndim = len(leaf.shape)
    if name == "embed":
        if ndim == 3:  # (n_q, V, d) audio
            return P(None, "model", "data")
        return P("model", "data")
    if name in ("lm_head", "heads"):
        return P("data", "model")
    if name in _W_IN_LIKE:
        return P(None, "model", "data", None) if ndim == 4 else P(None, "data", "model")
    if name in _W_OUT_LIKE:
        return P(None, "model", None, "data") if ndim == 4 else P(None, "model", "data")
    if name == "w_gate" and ndim == 4:
        return P(None, "model", "data", None)
    rule = _BLOCK_RULES.get(name)
    if rule is not None and len(rule) == ndim:
        return P(*rule)
    return P()  # norms, scalars, small adapters: replicated


def _divisible(shape, spec: P, mesh: Mesh) -> bool:

    parts = tuple(spec) + (None,) * (len(shape) - len(spec))
    for dim, part in zip(shape, parts):
        if part is None:
            continue
        axes = part if isinstance(part, tuple) else (part,)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        if dim % n != 0:
            return False
    return True


def param_sharding(path, leaf, mesh: Mesh) -> NamedSharding:
    spec = param_spec(path, leaf)
    if not _divisible(leaf.shape, spec, mesh):
        spec = P()
    return NamedSharding(mesh, spec)


def params_spec_tree(cfg: ArchConfig, mesh: Mesh):
    """ShapeDtypeStructs (with shardings) for params — via eval_shape."""
    shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    return jax.tree_util.tree_map_with_path(
        lambda p, s: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=param_sharding(p, s, mesh)),
        shapes,
    )


def opt_state_spec_tree(opt_init, params_specs, mesh: Mesh):
    """Optimizer-state ShapeDtypeStructs; moments inherit the param spec
    (the path tail inside m/v mirrors the param path)."""
    shapes = jax.eval_shape(opt_init, params_specs)

    def place(path, s):
        return jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=param_sharding(path, s, mesh)
        )

    return jax.tree_util.tree_map_with_path(place, shapes)


# ---------------------------------------------------------------------------
# Batch / cache input specs
# ---------------------------------------------------------------------------


def _batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _batch_spec(mesh: Mesh, batch: int, extra: Tuple = ()) -> NamedSharding:
    axes = _batch_axes(mesh)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    first = axes if (batch % n == 0 and batch >= n) else None
    return NamedSharding(mesh, P(first, *extra))


def batch_specs(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh) -> Dict[str, Any]:
    """Train-batch ShapeDtypeStructs for this arch."""
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if cfg.frontend == "vision_stub":
        return {
            "embeds": sds((b, s, cfg.d_model), jnp.bfloat16, sharding=_batch_spec(mesh, b, (None, None))),
            "positions": sds(
                (3, b, s),
                jnp.int32,
                sharding=NamedSharding(mesh, P(None, _batch_axes(mesh) or None, None)),
            ),
            "labels": sds((b, s), jnp.int32, sharding=_batch_spec(mesh, b, (None,))),
        }
    if cfg.frontend == "audio_codes":
        return {
            "tokens": sds((b, s, cfg.n_codebooks), jnp.int32, sharding=_batch_spec(mesh, b, (None, None))),
            "labels": sds((b, s, cfg.n_codebooks), jnp.int32, sharding=_batch_spec(mesh, b, (None, None))),
        }
    return {
        "tokens": sds((b, s), jnp.int32, sharding=_batch_spec(mesh, b, (None,))),
        "labels": sds((b, s), jnp.int32, sharding=_batch_spec(mesh, b, (None,))),
    }


def decode_token_specs(cfg: ArchConfig, batch: int, mesh: Mesh) -> Dict[str, Any]:
    sds = jax.ShapeDtypeStruct
    if cfg.frontend == "vision_stub":
        return {
            "embeds": sds((batch, 1, cfg.d_model), jnp.bfloat16, sharding=_batch_spec(mesh, batch, (None, None))),
            "positions": sds(
                (3, batch, 1),
                jnp.int32,
                sharding=NamedSharding(mesh, P(None, _batch_axes(mesh) or None, None)),
            ),
        }
    if cfg.frontend == "audio_codes":
        return {"tokens": sds((batch, 1, cfg.n_codebooks), jnp.int32, sharding=_batch_spec(mesh, batch, (None, None)))}
    return {"tokens": sds((batch, 1), jnp.int32, sharding=_batch_spec(mesh, batch, (None,)))}


def cache_specs(cfg: ArchConfig, batch: int, max_len: int, mesh: Mesh):
    """Decode-state ShapeDtypeStructs; attention KV seq-sharded over model."""
    from repro.models.transformer import cache_shardings_logical
    from repro.parallel.sharding import logical_to_spec, sharding_context

    shapes = jax.eval_shape(lambda: init_caches(cfg, batch, max_len))
    with sharding_context(mesh):
        logical = cache_shardings_logical(cfg)

        def place(path, s):
            # find logical axes by path: pos name then leaf name
            pos = None
            name = None
            for entry in path:
                if isinstance(entry, jax.tree_util.DictKey):
                    if str(entry.key).startswith("pos"):
                        pos = str(entry.key)
                    else:
                        name = str(entry.key)
            axes = list(logical.get(pos, {}).get(name, (None,) * len(s.shape)))
            # batch axis: only shard when divisible
            bax = _batch_axes(mesh)
            n = 1
            for a in bax:
                n *= mesh.shape[a]
            if "batch" in axes and (batch % n != 0 or batch < n):
                axes[axes.index("batch")] = None
            spec = logical_to_spec(axes)
            if not _divisible(s.shape, spec, mesh):
                spec = P()
            return jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=NamedSharding(mesh, spec))

        return jax.tree_util.tree_map_with_path(place, shapes)


def scalar_spec(mesh: Mesh, dtype=jnp.int32):
    return jax.ShapeDtypeStruct((), dtype, sharding=NamedSharding(mesh, P()))


def rng_spec(mesh: Mesh):
    return jax.ShapeDtypeStruct((2,), jnp.uint32, sharding=NamedSharding(mesh, P()))
