"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then calls these.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_mesh_for_devices(n_devices: int, model_parallel: int = 1) -> Mesh:
    """Elastic helper: best (data, model) mesh for an arbitrary device count."""
    assert n_devices % model_parallel == 0
    return jax.make_mesh(
        (n_devices // model_parallel, model_parallel),
        ("data", "model"),
        axis_types=(AxisType.Auto, AxisType.Auto),
    )
