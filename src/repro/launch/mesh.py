"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then calls these.

``AxisType`` landed after jax 0.4.37; on older runtimes the meshes are built
without explicit axis types (the default is Auto there anyway).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # jax >= 0.4.38
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def _make_mesh(shape, axes) -> Mesh:
    if AxisType is not None:
        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh_for_devices(n_devices: int, model_parallel: int = 1) -> Mesh:
    """Elastic helper: best (data, model) mesh for an arbitrary device count."""
    assert n_devices % model_parallel == 0
    return _make_mesh((n_devices // model_parallel, model_parallel), ("data", "model"))
