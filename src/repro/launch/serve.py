"""Serving launcher: batched prefill + greedy decode.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --reduced \
        --batch 4 --prompt-len 32 --new-tokens 16

For the production embedding-serving path (dynamic micro-batching, online
decorrelation probes, load generation) see ``python -m repro.serve.cli``.
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.serve.common import make_prompt, timed_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    from repro.models import init_params

    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    prompt = make_prompt(cfg, jax.random.PRNGKey(args.seed + 1), args.batch, args.prompt_len)

    out, stats = timed_generate(params, cfg, prompt, args.new_tokens, warmup_tokens=0)
    print(f"[serve] arch={cfg.name} generated {out.shape} in {stats['seconds']:.2f}s "
          f"({stats['tok_per_s']:.1f} tok/s batch throughput)")
    print("first row:", out[0, :10].tolist())


if __name__ == "__main__":
    main()
