"""Serving launcher: batched prefill + greedy decode.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --reduced \
        --batch 4 --prompt-len 32 --new-tokens 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.train.serve import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    from repro.models import init_params

    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    key = jax.random.PRNGKey(args.seed + 1)
    if cfg.frontend == "audio_codes":
        prompt = jax.random.randint(key, (args.batch, args.prompt_len, cfg.n_codebooks), 0, cfg.vocab_size)
    else:
        prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)

    t0 = time.time()
    out = greedy_generate(params, cfg, prompt, args.new_tokens)
    dt = time.time() - t0
    n_tok = args.batch * args.new_tokens
    print(f"[serve] arch={cfg.name} generated {out.shape} in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s batch throughput)")
    print("first row:", out[0, :10].tolist())


if __name__ == "__main__":
    main()
