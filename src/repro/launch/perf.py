import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf-iteration runner (§Perf): lower one cell with a named variant's
config overrides, re-analyze the roofline, and print the delta vs baseline.

    PYTHONPATH=src python -m repro.launch.perf --arch rwkv6-3b --shape train_4k \
        --variant rwkv_chunk64 --out perf_results

Variants are explicit, named, and recorded — each maps to one hypothesis in
EXPERIMENTS.md §Perf.
"""

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
from typing import Dict, Optional  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core.decorrelation import LMDecorrConfig  # noqa: E402
from repro.core.losses import DecorrConfig  # noqa: E402
from repro.launch import hlo_cost, specs as S  # noqa: E402
from repro.launch.dryrun import model_flops, num_microbatches_for  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.optim import adamw, warmup_cosine  # noqa: E402
from repro.parallel.sharding import sharding_context  # noqa: E402
from repro.train.serve import make_decode_step, make_prefill_step  # noqa: E402
from repro.train.step import make_train_step  # noqa: E402
from repro.train.train_state import TrainState  # noqa: E402


@dataclasses.dataclass
class Variant:
    name: str
    hypothesis: str
    cfg_overrides: Dict = dataclasses.field(default_factory=dict)
    microbatches: Optional[int] = None
    decorr: Optional[str] = None  # None | off | sum | sum_b128 | sum_global
    shard_grad_acc: bool = False


def _decorr_cfg(kind: str) -> LMDecorrConfig:
    if kind == "off":
        return LMDecorrConfig(
            enabled=True, decorr=DecorrConfig(style="vic", reg="off"), nu=0.04, tokens_per_seq=8
        )
    block = 128 if kind == "sum_b128" else None
    dist = "global" if kind == "sum_global" else "local"
    return LMDecorrConfig(
        enabled=True,
        decorr=DecorrConfig(style="vic", reg="sum", q=2, block_size=block, distributed=dist),
        nu=0.04,
        tokens_per_seq=8,
    )


VARIANTS: Dict[str, Variant] = {
    "baseline": Variant("baseline", "as-shipped configuration"),
    # --- rwkv6 memory hillclimb ---
    "rwkv_chunk32": Variant(
        "rwkv_chunk32",
        "chunk-parallel recurrence (C=32) turns 4096 sequential state round-trips "
        "into 128 chunk matmuls: memory term ~ /C, compute term rises slightly",
        {"rwkv_chunk": 32},
    ),
    "rwkv_chunk64": Variant(
        "rwkv_chunk64",
        "same, C=64: more intra-chunk matmul FLOPs, fewer state round-trips",
        {"rwkv_chunk": 64},
    ),
    "rwkv_chunk128": Variant(
        "rwkv_chunk128",
        "C=128: intra-chunk O(S*C*hd) FLOPs may start to dominate",
        {"rwkv_chunk": 128},
    ),
    # --- mamba/jamba ---
    "ssm_unroll8": Variant(
        "ssm_unroll8",
        "unroll the selective-scan 8x so XLA keeps h in registers across steps",
        {"ssm_unroll": 8},
    ),
    "rwkv_chunk64_dots": Variant(
        "rwkv_chunk64_dots",
        "chunked recurrence + dots_saveable remat: skip recomputing matmul "
        "outputs in bwd (trade saved residuals for fewer recompute passes)",
        {"rwkv_chunk": 64, "remat_policy": "dots"},
    ),
    "jamba_opt": Variant(
        "jamba_opt",
        "ssm unroll 8 + grouped MoE dispatch + flash attention for the hybrid",
        {"ssm_unroll": 8, "moe_group_size": 4096, "attn_chunk_threshold": 2048, "attn_chunk_size": 1024},
    ),
    # --- attention memory ---
    "flash_train": Variant(
        "flash_train",
        "chunked online-softmax attention at train seq 4096 removes the "
        "materialized (S,S) score/mask tensors from HBM",
        {"attn_chunk_threshold": 2048, "attn_chunk_size": 1024},
    ),
    # --- MoE ---
    "moe_group4k": Variant(
        "moe_group4k",
        "dispatch per 4096-token group: dispatch einsum O(T*G) instead of O(T^2)",
        {"moe_group_size": 4096},
    ),
    "moe_group2k": Variant(
        "moe_group2k", "dispatch per 2048-token group", {"moe_group_size": 2048}
    ),
    "moe_group4k_micro8": Variant(
        "moe_group4k_micro8",
        "grouped dispatch (linear in T) makes fewer microbatches affordable: "
        "halves the per-step FSDP weight re-gathers without the dispatch "
        "quadratic blowup",
        {"moe_group_size": 4096},
        microbatches=8,
    ),
    "moe_group4k_micro4": Variant(
        "moe_group4k_micro4",
        "same, 4 microbatches: quarter the weight re-gathers",
        {"moe_group_size": 4096},
        microbatches=4,
    ),
    "moe_group4k_micro2": Variant(
        "moe_group4k_micro2",
        "2 microbatches; activation memory may exceed HBM",
        {"moe_group_size": 4096},
        microbatches=2,
    ),
    "moe_group4k_micro8_shacc": Variant(
        "moe_group4k_micro8_shacc",
        "grouped dispatch + 8 microbatches + FSDP-sharded gradient "
        "accumulator: per-microbatch grads reduce-scatter into shards "
        "instead of all-reducing replicated full gradients",
        {"moe_group_size": 4096},
        microbatches=8,
        shard_grad_acc=True,
    ),
    "moe_group4k_micro16_shacc": Variant(
        "moe_group4k_micro16_shacc",
        "sharded accumulator at the baseline microbatch count",
        {"moe_group_size": 4096},
        microbatches=16,
        shard_grad_acc=True,
    ),
    "arctic_best": Variant(
        "arctic_best",
        "grouped dispatch + 8 microbatches + sequence-parallel attention "
        "(56 heads unshardable over 16-way model axis: shard q-seq instead "
        "of replicating head compute, killing score-sized bwd all-reduces)",
        {"moe_group_size": 4096, "seq_shard_attention": True},
        microbatches=8,
    ),
    "seqpar_attn": Variant(
        "seqpar_attn",
        "sequence-parallel attention only (vs baseline)",
        {"seq_shard_attention": True},
    ),
    # --- microbatching ---
    "micro8": Variant("micro8", "half the weight re-gathers per step", microbatches=8),
    "micro4": Variant("micro4", "quarter the weight re-gathers per step", microbatches=4),
    "micro2": Variant("micro2", "2 microbatches", microbatches=2),
    # --- the paper's technique on the LM cell ---
    "decorr_off_baseline": Variant(
        "decorr_off_baseline",
        "PAPER BASELINE: VICReg-style R_off on hidden states (materializes d x d)",
        decorr="off",
    ),
    "decorr_sum": Variant(
        "decorr_sum",
        "PAPER: R_sum via FFT (q=2 Parseval) — loss node O(nd log d)",
        decorr="sum",
    ),
    "decorr_sum_b128": Variant(
        "decorr_sum_b128",
        "PAPER+TPU: grouped b=128 (MXU DFT-matmul shape)",
        decorr="sum_b128",
    ),
    "decorr_sum_global": Variant(
        "decorr_sum_global",
        "BEYOND-PAPER: exact global-batch statistic via one psum of the "
        "frequency accumulator",
        decorr="sum_global",
    ),
}


def build_and_analyze(
    arch: str, shape_name: str, variant: Variant, multi_pod: bool = False
) -> Dict:
    cfg = get_config(arch)
    if variant.cfg_overrides:
        cfg = dataclasses.replace(cfg, **variant.cfg_overrides)
    if variant.decorr is not None:
        cfg = dataclasses.replace(cfg, decorr=_decorr_cfg(variant.decorr))
    shape = S.SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    moment_dtype = jnp.bfloat16 if str(cfg.optimizer_moment_dtype) in ("bfloat16", "bf16") else jnp.float32
    opt = adamw(moment_dtype=moment_dtype)
    sched = warmup_cosine(3e-4, 2000, 100_000)

    rec: Dict = {"arch": arch, "shape": shape_name, "variant": variant.name,
                 "hypothesis": variant.hypothesis, "multi_pod": multi_pod}
    with sharding_context(mesh):
        params = S.params_spec_tree(cfg, mesh)
        if shape.kind == "train":
            micro = variant.microbatches or num_microbatches_for(cfg, shape, mesh)
            rec["num_microbatches"] = micro
            grad_sh = (
                jax.tree.map(lambda p: p.sharding, params)
                if variant.shard_grad_acc
                else None
            )
            step = make_train_step(
                cfg, opt, sched, num_microbatches=micro, grad_shardings=grad_sh
            )
            state = TrainState(
                step=S.scalar_spec(mesh), params=params,
                opt_state=S.opt_state_spec_tree(opt.init, params, mesh),
                rng=S.rng_spec(mesh),
            )
            batch = S.batch_specs(cfg, shape, mesh)

            def fn(state, batch):
                with sharding_context(mesh):
                    return step(state, batch)

            args = (state, batch)
        elif shape.kind == "prefill":
            caches = S.cache_specs(cfg, shape.global_batch, shape.seq_len, mesh)
            toks = S.batch_specs(cfg, shape, mesh)
            toks.pop("labels")
            pstep = make_prefill_step(cfg)

            def fn(params, caches, inputs):
                with sharding_context(mesh):
                    return pstep(params, caches, **inputs)

            args = (params, caches, toks)
        else:
            caches = S.cache_specs(cfg, shape.global_batch, shape.seq_len, mesh)
            toks = S.decode_token_specs(cfg, shape.global_batch, mesh)
            dstep = make_decode_step(cfg)

            def fn(params, caches, cache_len, inputs):
                with sharding_context(mesh):
                    return dstep(params, caches, cache_len, **inputs)

            args = (params, caches, S.scalar_spec(mesh), toks)

        t0 = time.time()
        compiled = jax.jit(fn).lower(*args).compile()
        rec["compile_s"] = round(time.time() - t0, 2)
        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
        }
        analysis = hlo_cost.analyze_hlo(compiled.as_text())
        rec["flops"] = analysis.flops
        rec["hbm_bytes"] = analysis.hbm_bytes
        rec["collectives"] = {k: float(v) for k, v in analysis.collective_bytes.items()}
        rec["roofline"] = hlo_cost.roofline_terms(analysis)
        n_dev = 512 if multi_pod else 256
        rec["model_flops_per_device"] = model_flops(cfg, shape) / n_dev
        rec["useful_flops_ratio"] = rec["model_flops_per_device"] / max(analysis.flops, 1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="baseline", choices=list(VARIANTS))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="perf_results")
    args = ap.parse_args()

    v = VARIANTS[args.variant]
    rec = build_and_analyze(args.arch, args.shape, v, args.multi_pod)
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, f"{args.arch}__{args.shape}__{v.name}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    rl = rec["roofline"]
    print(json.dumps({
        "variant": v.name, "compile_s": rec["compile_s"],
        "compute_s": round(rl["compute_s"], 3), "memory_s": round(rl["memory_s"], 3),
        "collective_s": round(rl["collective_s"], 3), "dominant": rl["dominant"],
        "bound_s": round(rl["bound_s"], 3), "useful": round(rec["useful_flops_ratio"], 4),
        "tempGB": round(rec["memory"]["temp_bytes"] / 1e9, 1),
    }))


if __name__ == "__main__":
    main()
