"""Shared observability wiring for the train CLIs.

The serve CLI already exposes ``--metrics-port``/``--alerts``; these helpers
give ``examples/ssl_pretrain.py`` and ``repro.launch.train`` the same shape
so a training run is scrapeable exactly like a serving one:

    obs = build_train_obs(args)                       # None when not asked
    ...
    run_training(..., registry=obs.registry if obs else None,
                 perf=obs.perf if obs else None)
    finish_train_obs(args, obs)

``build_train_obs`` returns ``None`` when neither flag was given — default
runs stay completely telemetry-free (no registry on the step path), matching
the previous behavior byte for byte.
"""

from __future__ import annotations

import argparse
import time
import urllib.request
from typing import Optional


def add_obs_args(ap: argparse.ArgumentParser) -> argparse.ArgumentParser:
    ap.add_argument(
        "--metrics-port", type=int, default=None,
        help="serve /metrics, /alerts, /perf, /flight on this port during "
        "the run (0 = ephemeral); default: no telemetry",
    )
    ap.add_argument(
        "--alerts", action="store_true",
        help="evaluate the default train alert rules (relaxation-gap blowup, "
        "variance collapse) on every scrape",
    )
    return ap


def build_train_obs(args) -> Optional["Obs"]:
    """An enabled ``Obs`` bundle when the CLI asked for telemetry, else
    ``None`` (the run stays exactly as instrumentation-free as before)."""
    if args.metrics_port is None and not args.alerts:
        return None
    from repro.obs import AlertManager, Obs, default_train_rules

    return Obs(alerts=AlertManager(default_train_rules() if args.alerts else ()))


def attach_train_step(obs, step_fn, state, batch) -> bool:
    """Best-effort AOT attribution join for the jitted train step (HLO
    FLOPs/bytes -> roofline gauges).  Never fails the run."""
    if obs is None:
        return False
    try:
        return obs.perf.attach_jit("train_step", step_fn, state, batch)
    except Exception:
        return False


def finish_train_obs(args, obs, *, host: str = "127.0.0.1") -> None:
    """Post-run: start the scrape endpoint, self-scrape once (so the run's
    final state is evaluated against the alert rules and visible even in
    one-shot CLI invocations), report, and shut down."""
    if obs is None:
        return
    server = obs.start_server(port=args.metrics_port or 0, host=host)
    try:
        url = f"http://{host}:{server.port}/metrics"
        with urllib.request.urlopen(url, timeout=5) as resp:
            text = resp.read().decode()
        series = sum(
            1 for ln in text.splitlines() if ln and not ln.startswith("#")
        )
        active = obs.alerts.active()
        print(f"[obs] scraped {series} series from {url}"
              + (f"  ACTIVE ALERTS: {active}" if active else ""))
        top = obs.perf.snapshot(top_k=3)
        for row in top:
            util = row.get("roofline_utilization")
            extra = f"  util={util:.3g}" if util is not None else ""
            print(f"[obs]   {row['executable']}: {row['calls']} calls, "
                  f"total {row['total_s']:.3f}s{extra}")
        if args.metrics_port:
            # a real port was requested: hold the endpoint open briefly so an
            # external scraper pointed at the run can catch the final state
            time.sleep(0.2)
    finally:
        server.stop()
