"""Tune-cache warm-up for the decorrelation kernels (ROADMAP open item).

Kernel wrappers resolve their tile configs when jit TRACES them, so any
search cost not paid up front lands inside the first jitted training step.
``warmup_tune_cache`` pre-tunes every kernel shape one regularizer call can
reach — forward and backward — for the SHARD-LOCAL shapes the engine will
actually dispatch under the given mesh/mode:

  * ``local`` / ``global``: rows = n / data_parallel, width = d
    (batch sharded, features full);
  * ``tp``: rows = n / (data_parallel * model_parallel), width = d
    (the regularizer runs on the all_to_all-transposed full-feature rows,
    of which each model shard holds a 1/P slice of the local batch).

Called at launcher startup (``launch/train.py``, ``examples/ssl_pretrain.py``)
before the first step is traced.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

from repro.decorr.config import DecorrConfig


def shard_local_shape(
    n: int,
    d: int,
    cfg: DecorrConfig,
    *,
    data_parallel: int = 1,
    model_parallel: int = 1,
) -> Tuple[int, int]:
    """(rows, width) of the arrays the regularizer kernels see per shard."""
    rows = max(n // max(data_parallel, 1), 1)
    if cfg.distributed == "tp":
        rows = max(rows // max(model_parallel, 1), 1)
    return rows, d


def mesh_parallelism(mesh, data_axis: str = "data", model_axis: str = "model") -> Tuple[int, int]:
    """(data_parallel, model_parallel) sizes of a Mesh (1 for absent axes)."""
    if mesh is None:
        return 1, 1
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    return int(shape.get(data_axis, 1)), int(shape.get(model_axis, 1))


def warmup_tune_cache(
    n: int,
    d: int,
    cfg: DecorrConfig,
    *,
    mesh=None,
    data_parallel: Optional[int] = None,
    model_parallel: Optional[int] = None,
    mode: str = "analytic",
    persist: bool = False,
    verbose: bool = False,
) -> List:
    """Pre-tune the decorr kernel configs for the shard-local shapes.

    ``mode``: 'analytic' (instant, the default for launcher startup), 'dry'
    (compile-ranked) or 'measure' (wall-time ranked, real hardware).
    ``persist=True`` additionally writes the winners to the JSON cache so the
    *next* process also starts warm.  Returns the TuneResults.
    """
    from repro import tune
    from repro.tune.cli import jobs_for

    dp, mp = mesh_parallelism(mesh)
    dp = data_parallel if data_parallel is not None else dp
    mp = model_parallel if model_parallel is not None else mp
    rows, width = shard_local_shape(n, d, cfg, data_parallel=dp, model_parallel=mp)

    tune_kw = dict(mode=mode, persist=persist)
    plans, jobs = jobs_for(rows, width, block_size=cfg.block_size, **tune_kw)
    results = list(plans)
    for kernel, shape in jobs:
        results.append(tune.tune(kernel, shape, **tune_kw))
    if verbose:
        for r in results:
            moved = "tuned" if r.best != r.default else "kept default"
            print(f"[decorr.warmup] {r.kernel} {'x'.join(map(str, r.shape))}: {moved} {r.best}")
    return results
