"""Inference-time decorrelation probes (ROADMAP: serve-path probes under the
same engine).

``probe_metrics`` measures the representation health of a *served* batch with
exactly the training loss's semantics — same normalization (standardize for
BT-style, center for VICReg-style; shard-local moments in ``local`` mode,
psum'd global moments in ``global``/``tp`` mode), same feature permutation
(the caller's ``perm_key``, identical on every shard), same scale bookkeeping
(n for BT, n-1 for VICReg) — routed through ``repro.decorr.engine``.  Unlike
the training path nothing here is wrapped in ``stop_gradient``: serving never
differentiates through the probe, and keeping the graph clean lets the same
function run under ``shard_map`` for sharded serving.

Two health regularizers are reported:

  * ``r_sum``  — the paper's O(n d log d) FFT statistic; always computed.
  * ``r_off``  — the exact off-diagonal mass, O(n d^2); computed only when
    affordable (``include_off``; auto = d <= 4096 and mode != 'tp').

Serving typically has ONE embedding per request (no second view), so the
default is the self-correlation probe ``z2 is z1`` — redundancy collapse
shows up as off-diagonal mass of C(Z, Z) exactly as in VICReg's covariance
term.  Pass a genuine second view to probe cross-correlation instead.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.decorr import engine, modes
from repro.decorr.config import DecorrConfig

Array = jax.Array


def slot_probe_rows(hidden, active) -> np.ndarray:
    """Sample the in-flight slots' representation rows from one continuous-
    batching decode step.

    ``hidden``: (n_slots, d) final hidden states of the step (free-slot lanes
    carry garbage — they decoded a masked dummy token); ``active``: the slot
    indices that held live requests WHEN the step ran.  Returns the
    (n_active, d) f32 rows in slot order — the stream ``serve.DecorrProbe``
    buffers into its fixed probe windows, so probe readings only ever mix
    representations of real, in-flight requests even while admission and
    retirement interleave mid-stream.
    """
    rows = np.asarray(hidden, np.float32)
    idx = np.asarray(list(active), np.int64)
    if idx.size == 0:
        return rows[:0]
    return rows[idx]

# r_off materializes d x d — beyond this width the probe auto-drops it and
# relies on the O(n d log d) r_sum statistic alone.
OFF_DIAG_AUTO_LIMIT = 4096


def _should_include_off(cfg: DecorrConfig, d: int, include_off: Optional[bool]) -> bool:
    if include_off is not None:
        return include_off
    return d <= OFF_DIAG_AUTO_LIMIT and engine.effective_mode(cfg) != "tp"


def probe_metrics(
    z1: Array,
    z2: Optional[Array] = None,
    cfg: DecorrConfig = DecorrConfig(),
    perm_key: Optional[Array] = None,
    *,
    include_off: Optional[bool] = None,
) -> Dict[str, Array]:
    """Decorrelation health of a served batch, training-oracle-exact.

    Returns a flat dict of f32 scalars (shard_map-safe; replicated outputs):

      r_sum        engine-routed R_sum at the training normalizer
      r_sum_norm   r_sum / (d - 1)  (comparable across widths)
      r_off        exact off-diagonal penalty (present when affordable)
      r_off_norm   Eq. (16)-style r_off / (d (d - 1))
      mean_abs     mean_j |mu_j| of the raw embeddings (effective batch)
      std_err      mean_j |sigma_j - 1| (unit-variance drift)
      diag_err     mean_j |1 - C_jj| cross-view alignment (z2 given only)
      n_eff        effective batch the statistics were taken over
    """
    cfg.validate()
    mode = engine.effective_mode(cfg)
    same = z2 is None or z2 is z1
    z1 = z1.astype(jnp.float32)
    z2 = z1 if same else z2.astype(jnp.float32)
    n_local, d_local = z1.shape
    batch_axis = cfg.axis_name if mode in ("global", "tp") else None
    n_eff = modes.effective_batch(n_local, batch_axis)
    d = d_local
    if mode == "tp":
        d = int(d_local * modes.effective_batch(1, cfg.model_axis))

    # raw-moment drift (mode-effective batch statistics, O(n d))
    mean = modes.psum_if(jnp.sum(z1, axis=0), batch_axis) / n_eff
    zc = z1 - mean
    var = modes.psum_if(jnp.sum(zc * zc, axis=0), batch_axis) / max(n_eff - 1.0, 1.0)
    mean_abs = jnp.mean(jnp.abs(mean))
    std_err = jnp.mean(jnp.abs(jnp.sqrt(var + cfg.eps) - 1.0))
    if mode == "tp":
        p = modes.effective_batch(1, cfg.model_axis)
        mean_abs = jax.lax.psum(mean_abs, cfg.model_axis) / p
        std_err = jax.lax.psum(std_err, cfg.model_axis) / p

    # training-identical normalization + scale
    if cfg.style == "bt":
        a = engine.standardize(z1, cfg, mode)
        b = a if same else engine.standardize(z2, cfg, mode)
        ddof = 0
    else:
        a = engine.center(z1, cfg, mode)
        b = a if same else engine.center(z2, cfg, mode)
        ddof = 1

    def _reg(reg_cfg: DecorrConfig) -> Array:
        # local mode consumes the explicit scale; global/tp recompute the
        # exact effective-batch normalizer from ddof (engine semantics).
        return engine.regularizer(
            a, b, reg_cfg, max(n_local - ddof, 1), perm_key, ddof=ddof
        )

    out: Dict[str, Array] = {}
    sum_cfg = cfg if cfg.reg == "sum" else dataclasses.replace(cfg, reg="sum")
    out["r_sum"] = _reg(sum_cfg)
    out["r_sum_norm"] = out["r_sum"] / max(d - 1, 1)
    if _should_include_off(cfg, d, include_off):
        off_cfg = dataclasses.replace(cfg, reg="off", use_kernel=False)
        out["r_off"] = _reg(off_cfg)
        out["r_off_norm"] = out["r_off"] / max(d * (d - 1), 1)

    if not same:
        if cfg.style == "bt":
            cjj = modes.psum_if(jnp.sum(a * b, axis=0), batch_axis) / n_eff
            diag_err = jnp.mean(jnp.abs(1.0 - cjj))
            if mode == "tp":
                p = modes.effective_batch(1, cfg.model_axis)
                diag_err = jax.lax.psum(diag_err, cfg.model_axis) / p
            out["diag_err"] = diag_err
        else:
            inv = modes.psum_if(jnp.sum((z1 - z2) ** 2), batch_axis)
            if mode == "tp":
                inv = jax.lax.psum(inv, cfg.model_axis)
            out["diag_err"] = inv / (n_eff * d)

    out["n_eff"] = jnp.asarray(n_eff, jnp.float32)
    return out
