"""The decorrelation engine — the ONE place that routes decorrelation work.

``apply(z1, z2, cfg, perm_key)`` (and the style-specific ``barlow_twins`` /
``vicreg``) own, for every ``DecorrConfig``:

  * normalization — standardize (BT) / center (VICReg) with shard-local
    moments in ``local`` mode and psum'd global-batch moments in
    ``global``/``tp`` mode (two O(d) psums: mean, then centered variance);
  * feature permutation — one permutation per step, derived from the caller's
    ``perm_key`` identically on every shard; in ``tp`` mode it is applied to
    the full-feature rows *after* the all_to_all transpose so it equals the
    permutation a single-device run applies to the unsharded d;
  * mode routing — ``local | global | tp`` (see ``repro.decorr.modes``), with
    ``tp`` refusing to run without a ``model_axis`` instead of silently
    computing the shard-local loss;
  * impl routing — jnp vs Pallas via ``repro.tune`` (``use_kernel=True`` pins
    Pallas); kernels resolve their tile configs from the SHARD-LOCAL shapes
    they actually see inside shard_map;
  * scale bookkeeping — n vs n-1, local vs effective global batch, full vs
    shard-local feature width.

Everything in ``core/losses.py`` / ``core/decorrelation.py`` is a thin shim
over this module.  All distributed paths assume ``shard_map`` (axis names
bound by the caller, e.g. ``train/ssl.make_sharded_ssl_train_step``).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import permutation as perm_lib
from repro.core import regularizers as regs
from repro.decorr import modes
from repro.decorr.config import DecorrConfig

Array = jax.Array


def effective_mode(cfg: DecorrConfig) -> str:
    """'local' | 'global' | 'tp' — with the tp misconfiguration rejected.

    ``global`` with no ``axis_name`` is the local computation, so it degrades
    quietly.  ``tp`` with no ``model_axis`` would silently compute the wrong
    (shard-local) loss, so it raises instead.
    """
    if cfg.distributed == "tp" and cfg.model_axis is None:
        raise ValueError(
            "DecorrConfig(distributed='tp') requires model_axis (the mesh axis "
            "the feature dim is sharded over); refusing to fall back to the "
            "shard-local loss. Set model_axis or use distributed='local'/'global'."
        )
    return cfg.mode


def _batch_axis(cfg: DecorrConfig, mode: str) -> Optional[str]:
    return cfg.axis_name if mode in ("global", "tp") else None


# ---------------------------------------------------------------------------
# Normalization + moment statistics (local vs psum'd global moments)
# ---------------------------------------------------------------------------


def _mean_and_n(z: Array, batch_axis: Optional[str]) -> Tuple[Array, Array]:
    z = z.astype(jnp.float32)
    s1 = modes.psum_if(jnp.sum(z, axis=0), batch_axis)
    n = modes.effective_batch(z.shape[0], batch_axis)
    return s1 / n, n


def standardize(z: Array, cfg: DecorrConfig, mode: Optional[str] = None) -> Array:
    """Per-feature zero-mean unit-std over the (mode-effective) batch."""
    batch_axis = _batch_axis(cfg, mode or effective_mode(cfg))
    mean, n = _mean_and_n(z, batch_axis)
    zc = z.astype(jnp.float32) - mean
    var = modes.psum_if(jnp.sum(zc * zc, axis=0), batch_axis) / n
    return zc / jnp.sqrt(var + cfg.eps)


def center(z: Array, cfg: DecorrConfig, mode: Optional[str] = None) -> Array:
    """Per-feature zero-mean over the (mode-effective) batch."""
    batch_axis = _batch_axis(cfg, mode or effective_mode(cfg))
    mean, _ = _mean_and_n(z, batch_axis)
    return z.astype(jnp.float32) - mean


def variance_hinge(
    z: Array, cfg: DecorrConfig, mode: str, eps: float = 1e-4
) -> Array:
    """VICReg Eq. (4) hinge from ddof-1 moments of the effective batch,
    summed over ALL features (psum over the model axis in tp mode)."""
    batch_axis = _batch_axis(cfg, mode)
    mean, n = _mean_and_n(z, batch_axis)
    zc = z.astype(jnp.float32) - mean
    var = modes.psum_if(jnp.sum(zc * zc, axis=0), batch_axis) / max(n - 1.0, 1.0)
    hinge = jnp.sum(jnp.maximum(0.0, cfg.gamma - jnp.sqrt(var + eps)))
    if mode == "tp":
        hinge = jax.lax.psum(hinge, cfg.model_axis)
    return hinge


# ---------------------------------------------------------------------------
# Regularizer routing (mode x impl x grouped/ungrouped x q)
# ---------------------------------------------------------------------------


def _maybe_permute(z1: Array, z2: Array, cfg: DecorrConfig, perm_key) -> Tuple[Array, Array]:
    if cfg.permute and perm_key is not None and cfg.reg == "sum":
        return perm_lib.permute_views(perm_key, z1, z2)
    return z1, z2


def _impl(cfg: DecorrConfig) -> Optional[str]:
    # None defers to repro.tune.best_impl at the call site
    return "pallas" if cfg.use_kernel else None


def _local_regularizer(z1: Array, z2: Array, cfg: DecorrConfig, scale: float, perm_key) -> Array:
    if cfg.reg == "off":
        if cfg.use_kernel:
            from repro.kernels.xcorr_offdiag import ops as xops

            return xops.off_diagonal_sq_sum(z1, z2, scale=scale)
        return regs.r_off(regs.cross_correlation_matrix(z1, z2, scale=scale))
    z1, z2 = _maybe_permute(z1, z2, cfg, perm_key)
    return regs.r_sum_auto(
        z1, z2, q=cfg.q, block_size=cfg.block_size, scale=scale, impl=_impl(cfg)
    )


def _global_regularizer(z1: Array, z2: Array, cfg: DecorrConfig, total_scale, perm_key) -> Array:
    if cfg.reg == "off":
        return modes.r_off_global(z1, z2, axis_name=cfg.axis_name, total_scale=total_scale)
    z1, z2 = _maybe_permute(z1, z2, cfg, perm_key)
    b, d = cfg.block_size, z1.shape[-1]
    if b is not None and b <= 1 and b < d:
        # R_sum^(1): exactly the off-diagonal penalty (paper §4.4) — matrix
        # route on the psum'd correlation accumulator.
        c = z1.astype(jnp.float32).T @ z2.astype(jnp.float32)
        c = modes.psum_if(c, cfg.axis_name) / jnp.asarray(total_scale, jnp.float32)
        if cfg.q == 2:
            return regs.r_off(c)
        return jnp.sum(jnp.abs(c)) - jnp.sum(jnp.abs(jnp.diagonal(c)))
    return modes.r_sum_from_psummed(
        z1, z2, cfg.axis_name, q=cfg.q, block_size=b, total_scale=total_scale, impl=_impl(cfg)
    )


def _tp_regularizer(z1: Array, z2: Array, cfg: DecorrConfig, total_scale, perm_key) -> Array:
    if cfg.reg == "off" or (cfg.block_size is not None and cfg.block_size <= 1):
        raise NotImplementedError(
            "tp mode supports the R_sum family only (reg='sum', block_size > 1): "
            "the baseline R_off needs the cross-shard d x d matrix."
        )
    same = z1 is z2
    z1f = modes.all_to_all_features(z1.astype(jnp.float32), cfg.model_axis)
    z2f = z1f if same else modes.all_to_all_features(z2.astype(jnp.float32), cfg.model_axis)
    if cfg.permute and perm_key is not None:
        z1f, z2f = perm_lib.permute_views(perm_key, z1f, z2f)
    d = z1f.shape[-1]
    g = modes.frequency_accumulator(z1f, z2f, cfg.block_size, impl=_impl(cfg))
    g = jax.lax.psum(g, cfg.model_axis)
    g = modes.psum_if(g, cfg.axis_name)
    g = g / jnp.asarray(total_scale, jnp.float32).astype(g.dtype)
    if cfg.block_size is None or cfg.block_size >= d:
        return modes.reg_from_freq(g, d, cfg.q)
    return modes.grouped_reg_from_freq(g, int(cfg.block_size), cfg.q)


def regularizer(
    z1: Array,
    z2: Array,
    cfg: DecorrConfig,
    scale,
    perm_key: Optional[Array] = None,
    *,
    ddof: Optional[int] = None,
) -> Array:
    """Mode/impl-routed decorrelating term R(C).

    ``scale`` is the LOCAL normalizer of C (n_local or n_local - 1).  With
    ``ddof=None`` the ``global``/``tp`` modes multiply it by the batch-axis
    size (the historical ``r_sum_global`` semantics); passing ``ddof``
    instead normalizes by the EXACT effective-batch scale
    max(n_global - ddof, 1), matching a single-device run on the
    concatenated batch (ddof=0: BT-style n; ddof=1: VICReg-style n - 1).
    Permutation is applied inside, mode-correctly — callers must NOT
    pre-permute.
    """
    mode = effective_mode(cfg)
    if mode == "local":
        return _local_regularizer(z1, z2, cfg, float(scale), perm_key)
    if ddof is None:
        total = float(scale) * (
            modes.effective_batch(1, cfg.axis_name) if cfg.axis_name else 1.0
        )
    else:
        n_eff = modes.effective_batch(z1.shape[0], _batch_axis(cfg, mode))
        total = max(n_eff - float(ddof), 1.0)
    if mode == "global":
        return _global_regularizer(z1, z2, cfg, total, perm_key)
    return _tp_regularizer(z1, z2, cfg, total, perm_key)


# ---------------------------------------------------------------------------
# Full losses (paper Eq. 14 / Eq. 15), mode-correct end to end
# ---------------------------------------------------------------------------


def barlow_twins(
    z1: Array,
    z2: Array,
    cfg: DecorrConfig,
    perm_key: Optional[Array] = None,
) -> Tuple[Array, Dict[str, Array]]:
    """Eq. (14) with mode-correct statistics: in ``global``/``tp`` mode every
    term (standardization moments, diagonal, regularizer, n) matches a
    single-device run on the concatenated, unsharded batch."""
    cfg.validate()
    mode = effective_mode(cfg)
    batch_axis = _batch_axis(cfg, mode)
    n_local = z1.shape[0]

    z1n = standardize(z1, cfg, mode)
    z2n = standardize(z2, cfg, mode)

    # Diagonal (invariance) term: C_ii in O(n d) — additive over batch shards
    # (psum over the batch axis) and over feature shards (psum over model).
    n_eff = modes.effective_batch(n_local, batch_axis)
    cii = modes.psum_if(jnp.sum(z1n * z2n, axis=0), batch_axis) / n_eff
    invariance = jnp.sum((1.0 - cii) ** 2)
    if mode == "tp":
        invariance = jax.lax.psum(invariance, cfg.model_axis)

    if mode == "local":
        reg = _local_regularizer(z1n, z2n, cfg, float(n_local), perm_key)
    elif mode == "global":
        reg = _global_regularizer(z1n, z2n, cfg, n_eff, perm_key)
    else:
        reg = _tp_regularizer(z1n, z2n, cfg, n_eff, perm_key)

    loss = invariance + cfg.lam * reg
    return loss, {"bt_invariance": invariance, "bt_reg": reg, "bt_loss": loss}


def vicreg(
    z1: Array,
    z2: Array,
    cfg: DecorrConfig,
    perm_key: Optional[Array] = None,
) -> Tuple[Array, Dict[str, Array]]:
    """Eq. (15) with mode-correct statistics (psum'd mean/variance in
    ``global`` mode — the shard-local variance hinge was a bug)."""
    cfg.validate()
    mode = effective_mode(cfg)
    batch_axis = _batch_axis(cfg, mode)
    n_local, d_local = z1.shape
    z1 = z1.astype(jnp.float32)
    z2 = z2.astype(jnp.float32)

    # invariance: before centering (paper Eq. 3 uses raw embeddings)
    inv = jnp.sum((z1 - z2) ** 2)
    if mode == "tp":
        inv = jax.lax.psum(inv, cfg.model_axis)
    n_eff = modes.effective_batch(n_local, batch_axis)
    inv = modes.psum_if(inv, batch_axis) / n_eff

    var1 = variance_hinge(z1, cfg, mode)
    var2 = variance_hinge(z2, cfg, mode)

    c1 = center(z1, cfg, mode)
    c2 = center(z2, cfg, mode)
    if mode == "local":
        scale = float(max(n_local - 1, 1))
        reg1 = _local_regularizer(c1, c1, cfg, scale, perm_key)
        reg2 = _local_regularizer(c2, c2, cfg, scale, perm_key)
    else:
        scale = max(n_eff - 1.0, 1.0)
        route = _global_regularizer if mode == "global" else _tp_regularizer
        reg1 = route(c1, c1, cfg, scale, perm_key)
        reg2 = route(c2, c2, cfg, scale, perm_key)

    d_full = float(d_local)
    if mode == "tp":
        d_full = d_full * modes.effective_batch(1, cfg.model_axis)

    loss = (
        cfg.alpha * inv
        + (cfg.mu / d_full) * (var1 + var2)
        + (cfg.nu / d_full) * (reg1 + reg2)
    )
    return loss, {
        "vic_invariance": inv,
        "vic_var": var1 + var2,
        "vic_reg": reg1 + reg2,
        "vic_loss": loss,
    }


def apply(
    z1: Array,
    z2: Array,
    cfg: DecorrConfig,
    perm_key: Optional[Array] = None,
) -> Tuple[Array, Dict[str, Array]]:
    """The engine entry point: full SSL loss for ``cfg.style``."""
    if cfg.style == "bt":
        return barlow_twins(z1, z2, cfg, perm_key)
    return vicreg(z1, z2, cfg, perm_key)
