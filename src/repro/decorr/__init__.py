"""repro.decorr — the sharding-aware decorrelation engine.

One dispatch layer for everything decorrelation: normalization (local vs
psum'd global moments), feature permutation, mode routing
(``local | global | tp``), impl routing (jnp vs Pallas via ``repro.tune``)
and scale bookkeeping.  ``core/losses.py`` and ``core/distributed.py`` are
thin compatibility shims over this package.

    from repro import decorr
    loss, metrics = decorr.apply(z1, z2, decorr.DecorrConfig(style="bt"), key)
"""

from repro.decorr.config import DecorrConfig
from repro.decorr.engine import (
    apply,
    barlow_twins,
    center,
    effective_mode,
    regularizer,
    standardize,
    variance_hinge,
    vicreg,
)
from repro.decorr.probe import probe_metrics, slot_probe_rows
from repro.decorr.warmup import shard_local_shape, warmup_tune_cache

__all__ = [
    "probe_metrics",
    "slot_probe_rows",
    "DecorrConfig",
    "apply",
    "barlow_twins",
    "vicreg",
    "regularizer",
    "standardize",
    "center",
    "variance_hinge",
    "effective_mode",
    "shard_local_shape",
    "warmup_tune_cache",
]
