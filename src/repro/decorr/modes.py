"""Distributed decorrelation primitives (DESIGN.md §4).

Three modes for computing the decorrelation statistics under SPMD:

``local``  (paper-faithful): every data shard computes the loss on its local
    batch slice; cross-device traffic is only the usual gradient all-reduce.
    This reproduces the paper's DDP implementation, which states "we do not
    conduct collective operations" in the loss.

``global`` (beyond-paper): the frequency accumulator
    ``G = sum_k conj(F a_k) o F b_k`` is an *additive* statistic of the batch,
    so a single psum of d/2+1 complex numbers (~4d bytes at fp32) turns the
    local regularizer into the exact global-batch regularizer.  The same
    trick applies to the per-feature moments used for standardization and to
    the diagonal statistics — everything the loss needs is O(d) additive.

``tp``     (feature-sharded): when the projector output dimension d itself is
    tensor-parallel over the ``model`` axis, the FFT spans shards.  We
    transpose batch<->feature with one all_to_all (each of the P model shards
    ends up with n/P full-length feature vectors), run shard-local FFTs, and
    psum the accumulator.  Communication: n*d/P elements per shard instead of
    an all-gather's n*d.

All functions here are meant to be called inside ``shard_map``.  The mode
*routing* (which of these a given ``DecorrConfig`` hits, plus normalization,
permutation and scale bookkeeping) lives in ``repro.decorr.engine``; this
module only owns the collective algebra.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import sumvec as sv

Array = jax.Array


# ---------------------------------------------------------------------------
# Small collective helpers
# ---------------------------------------------------------------------------


def _axis_size(axis_name) -> float:
    # psum of a Python int literal is constant-folded to the static axis
    # size under shard_map — no runtime collective is emitted.
    return float(jax.lax.psum(1, axis_name))


def psum_if(x: Array, axis_name: Optional[str]) -> Array:
    """psum over ``axis_name`` when given, identity otherwise."""
    if axis_name is None:
        return x
    return jax.lax.psum(x, axis_name)


def effective_batch(n_local: int, axis_name: Optional[str]) -> float:
    """Global batch size as a STATIC float (n_local when no axis)."""
    if axis_name is None:
        return float(n_local)
    return float(n_local) * _axis_size(axis_name)


def all_to_all_features(z: Array, model_axis) -> Array:
    """(n, d_local) -> (n/P, d): split batch, exchange, concat features.

    Requires features laid out contiguously by shard index along
    ``model_axis`` (the natural layout of a TP projector output).
    """
    return jax.lax.all_to_all(z, model_axis, split_axis=0, concat_axis=1, tiled=True)


# ---------------------------------------------------------------------------
# R_sum from (already reduced + normalized) frequency accumulators
# ---------------------------------------------------------------------------


def reg_from_freq(g: Array, d: int, q: int) -> Array:
    """R_sum from an (already normalized) frequency accumulator."""
    if q == 2:
        sq, s0 = sv.sq_sum_and_zeroth_from_freq(g, d)
        return sq - s0**2
    svec = jnp.fft.irfft(g, n=d, axis=-1)
    return jnp.sum(jnp.abs(svec[..., 1:]))


def grouped_reg_from_freq(g: Array, b: int, q: int) -> Array:
    nb = g.shape[0]
    eye = jnp.eye(nb, dtype=jnp.float32)
    if q == 2:
        sq, s0 = sv.sq_sum_and_zeroth_from_freq(g, b)
        return jnp.sum(sq) - jnp.sum(eye * s0**2)
    svec = jnp.fft.irfft(g, n=b, axis=-1)
    full = jnp.sum(jnp.abs(svec), axis=-1)
    return jnp.sum(full) - jnp.sum(eye * jnp.abs(svec[..., 0]))


def frequency_accumulator(
    z1: Array, z2: Array, block_size: Optional[int], *, impl: Optional[str] = None
) -> Array:
    """The additive statistic every distributed mode psums.

    Ungrouped (block covers d): jnp rfft accumulator, (d//2+1,) complex —
    the four-step Pallas pipeline is a *time-domain* algorithm and cannot
    expose a mid-pipeline frequency accumulator, so the distributed modes
    always take the jnp FFT here (O(n d log d); the psum'd statistic is
    identical).  Grouped: routes jnp vs the Pallas block-DFT pipeline via
    ``repro.tune.best_impl`` (shard-local shapes — exactly what each shard
    sees inside shard_map).
    """
    d = z1.shape[-1]
    if block_size is None or block_size >= d:
        return sv.frequency_accumulator(z1, z2)
    b = int(block_size)
    if impl is None:
        from repro.tune import dispatch as tune_dispatch

        impl = tune_dispatch.best_impl("r_sum_grouped")
    if impl == "pallas" and b <= d:
        from repro.kernels.grouped_sumvec import ops as gops

        g_r, g_i = gops.grouped_frequency_accumulator_kernel(z1, z2, b)
        # kernel layout (nf, nb, nb) -> core layout (nb, nb, nf)
        return jnp.transpose(jax.lax.complex(g_r, g_i), (1, 2, 0))
    return sv.grouped_frequency_accumulator(z1, z2, b)


# ---------------------------------------------------------------------------
# Mode primitives (compat surface of the old core/distributed.py)
# ---------------------------------------------------------------------------


def r_sum_global(
    z1: Array,
    z2: Array,
    *,
    axis_name,
    q: int = 2,
    block_size: Optional[int] = None,
    scale: Optional[float] = None,
    impl: Optional[str] = None,
) -> Array:
    """Exact global-batch R_sum with one psum of the frequency accumulator.

    ``z1, z2``: the *local* (n_local, d) shard of the standardized/centered
    views.  ``scale``: the *local* normalizer (n_local or n_local - 1); it is
    multiplied by the axis size so the result matches a single-device run on
    the concatenated batch.  (The engine passes exact global scales instead —
    see ``engine._distributed_regularizer``.)
    """
    p = _axis_size(axis_name)
    s = (1.0 if scale is None else scale) * p
    return r_sum_from_psummed(z1, z2, axis_name, q=q, block_size=block_size, total_scale=s, impl=impl)


def r_sum_from_psummed(
    z1: Array,
    z2: Array,
    axis_name,
    *,
    q: int,
    block_size: Optional[int],
    total_scale,
    impl: Optional[str] = None,
) -> Array:
    """R_sum of the psum'd accumulator with an explicit TOTAL normalizer."""
    d = z1.shape[-1]
    g = frequency_accumulator(z1, z2, block_size, impl=impl)
    g = psum_if(g, axis_name) / jnp.asarray(total_scale, jnp.float32).astype(g.dtype)
    if block_size is None or block_size >= d:
        return reg_from_freq(g, d, q)
    return grouped_reg_from_freq(g, int(block_size), q)


def r_sum_tp(
    z1: Array,
    z2: Array,
    *,
    model_axis,
    batch_axis=None,
    q: int = 2,
    block_size: Optional[int] = None,
    scale: Optional[float] = None,
    perm_key: Optional[Array] = None,
    impl: Optional[str] = None,
) -> Array:
    """R_sum when the feature dim is sharded over ``model_axis``.

    Inside shard_map each shard holds (n, d_local) with d = P * d_local and
    features laid out contiguously by shard index.  One tiled all_to_all
    converts to (n / P, d) full-feature rows, then the computation proceeds
    as in ``global`` mode with the accumulator psum'd over the model axis
    (batch chunks) and, if given, the batch axis (data parallel shards).

    ``perm_key``: optional feature permutation applied to the full-feature
    rows after the transpose — the same key on every shard yields the exact
    permutation a single-device run would apply to the unsharded d.
    """
    from repro.core import permutation as perm_lib

    same = z1 is z2
    z1f = all_to_all_features(z1.astype(jnp.float32), model_axis)
    z2f = z1f if same else all_to_all_features(z2.astype(jnp.float32), model_axis)
    if perm_key is not None:
        z1f, z2f = perm_lib.permute_views(perm_key, z1f, z2f)
    d = z1f.shape[-1]

    g = frequency_accumulator(z1f, z2f, block_size, impl=impl)
    g = jax.lax.psum(g, model_axis)
    s = jnp.asarray(1.0 if scale is None else scale, jnp.float32)
    if batch_axis is not None:
        g = jax.lax.psum(g, batch_axis)
        s = s * _axis_size(batch_axis)
    g = g / s.astype(g.dtype)

    if block_size is None or block_size >= d:
        return reg_from_freq(g, d, q)
    return grouped_reg_from_freq(g, int(block_size), q)


def r_off_global(
    z1: Array,
    z2: Array,
    *,
    axis_name,
    total_scale,
) -> Array:
    """Exact global-batch R_off via one psum of the d x d accumulator.

    This is O(d^2) traffic — the baseline's irreducible cost, kept for
    apples-to-apples comparisons; the R_sum modes above are the O(d) path.
    """
    from repro.core import regularizers as regs

    c = z1.astype(jnp.float32).T @ z2.astype(jnp.float32)
    c = psum_if(c, axis_name) / jnp.asarray(total_scale, jnp.float32)
    return regs.r_off(c)


# ---------------------------------------------------------------------------
# Reference: what a single device computes on the concatenated global batch.
# Used by tests to check the distributed modes bit-for-bit (up to fp assoc).
# ---------------------------------------------------------------------------


def r_sum_single_device(z1, z2, *, q=2, block_size=None, scale=None):
    from repro.core import regularizers as regs

    return regs.r_sum_auto(z1, z2, q=q, block_size=block_size, scale=scale)
