"""Decorrelation engine configuration.

``DecorrConfig`` used to live in ``core/losses.py``; it moved here when the
mode / impl / normalization routing was consolidated into ``repro.decorr``.
``repro.core.losses.DecorrConfig`` remains as a compatibility re-export.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class DecorrConfig:
    """Selects and parameterizes the decorrelating regularizer.

    style:       'bt' (cross-correlation, Eq. 14) | 'vic' (covariance, Eq. 15)
    reg:         'off' (baseline R_off) | 'sum' (proposed R_sum / R_sum^(b))
    block_size:  None => no grouping (b = d); else b (paper's best: 128)
    q:           1 | 2 (paper Table 11: q=2 for BT-style, q=1 for VICReg-style)
    permute:     feature permutation each step (essential; paper Table 5)
    lam:         BT lambda
    alpha/mu/nu: VICReg coefficients;  gamma: target std
    distributed: 'local' | 'global' | 'tp'  (see repro.decorr.modes)
    axis_name:   mesh axis the BATCH is sharded over ('global'/'tp' modes);
                 None means single-shard semantics even in 'global' mode
    model_axis:  mesh axis the FEATURE dim is sharded over — required by the
                 'tp' mode (the engine refuses to run 'tp' without it rather
                 than silently computing the shard-local loss)
    use_kernel:  pin the regularizer to the Pallas route (None-like default
                 False lets ``repro.tune.best_impl`` pick per backend)
    """

    style: str = "bt"
    reg: str = "sum"
    block_size: Optional[int] = None
    q: int = 2
    permute: bool = True
    lam: float = 2.0**-10
    alpha: float = 25.0
    mu: float = 25.0
    nu: float = 1.0
    gamma: float = 1.0
    eps: float = 1e-5
    distributed: str = "local"
    axis_name: Optional[str] = None
    model_axis: Optional[str] = None
    use_kernel: bool = False

    def validate(self) -> "DecorrConfig":
        assert self.style in ("bt", "vic"), self.style
        assert self.reg in ("off", "sum"), self.reg
        assert self.q in (1, 2), self.q
        assert self.distributed in ("local", "global", "tp"), self.distributed
        return self

    @property
    def mode(self) -> str:
        """The effective distribution mode.

        'global' with no ``axis_name`` degrades to 'local' (a single-shard
        run of a global config is exactly the local computation); 'tp' never
        degrades — it raises in the engine when ``model_axis`` is missing,
        because a silent fallback would compute the wrong (shard-local) loss.
        """
        if self.distributed == "global" and self.axis_name is None:
            return "local"
        return self.distributed
