"""Mixture-of-Experts FFN: GShard-style capacity dispatch.

Dense one-hot dispatch/combine einsums (static shapes, MXU-friendly,
FLOPs proportional to top_k rather than n_experts) with per-expert capacity
``C = ceil(T / E * top_k * capacity_factor)``; overflow tokens are dropped
(their residual passes through).  Experts are sharded over the ``model``
mesh axis (expert parallelism); the dispatch einsum induces the all-to-all.

Variants for the assigned archs:
  * arctic-480b:   128 experts top-2 + a *dense residual* MLP in parallel
  * llama4-scout:  16 experts top-1 + an always-on *shared expert*
  * jamba:         16 experts top-2, MoE on every other layer
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig, activation_fn, dense_init, mlp_apply, mlp_init
from repro.parallel.sharding import shard

Array = jax.Array


def moe_init(key: Array, cfg: ArchConfig) -> Dict[str, Array]:
    d = cfg.d_model
    e = cfg.n_experts
    ff = cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(key, 6)
    gated = cfg.activation in ("swiglu", "geglu")
    params = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "w_in": dense_init(ks[1], d, ff, cfg.param_dtype) * jnp.ones((e, 1, 1), cfg.param_dtype),
        "w_out": dense_init(ks[2], ff, d, cfg.param_dtype) * jnp.ones((e, 1, 1), cfg.param_dtype),
    }
    # break expert symmetry
    noise = jax.random.normal(ks[3], params["w_in"].shape, jnp.float32).astype(cfg.param_dtype)
    params["w_in"] = params["w_in"] + 0.02 * noise / jnp.sqrt(d).astype(cfg.param_dtype)
    if gated:
        params["w_gate"] = dense_init(ks[4], d, ff, cfg.param_dtype) * jnp.ones((e, 1, 1), cfg.param_dtype)
    if cfg.dense_residual:
        params["dense"] = mlp_init(ks[5], cfg)
    if cfg.shared_expert:
        params["shared"] = mlp_init(ks[5], cfg, d_ff=ff)
    return params


def _capacity(tokens: int, cfg: ArchConfig) -> int:
    c = int(tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts) + 1
    return max(4, -(-c // 4) * 4)


def moe_apply(params: Dict[str, Array], x: Array, cfg: ArchConfig) -> Tuple[Array, Array]:
    """x: (B, S, d) -> (out, aux_loss).

    With ``cfg.moe_group_size = G`` the dense one-hot dispatch runs per
    G-token group (vmapped): the dispatch/combine einsums cost O(T*G*k*cf*d)
    instead of O(T^2*k*cf*d/E) — the difference between quadratic and linear
    in sequence length at prefill shapes (EXPERIMENTS.md §Perf).
    """
    b, s, d = x.shape
    t = b * s
    g = cfg.moe_group_size
    if g and t > g and t % g == 0:
        out, aux = _moe_grouped(params, x.reshape(t // g, g, d), cfg)
        out = out.reshape(b, s, d)
    else:
        out, aux = _moe_one_group(params, x.reshape(t, d), cfg)
        out = out.reshape(b, s, d)

    if cfg.dense_residual and "dense" in params:
        out = out + mlp_apply(params["dense"], x, cfg)
    if cfg.shared_expert and "shared" in params:
        out = out + mlp_apply(params["shared"], x, cfg)
    return out.astype(x.dtype), aux


def _moe_grouped(params: Dict[str, Array], xg: Array, cfg: ArchConfig) -> Tuple[Array, Array]:
    """xg: (n_groups, G, d) -> ((n_groups, G, d), aux).

    Explicit group axis (no vmap) so the expert-parallel sharding
    constraints keep their intended axes; group results share one merged
    per-expert capacity buffer (E, n_groups*C, d) so the expert matmuls
    stay a single large MXU contraction."""
    n, g, d = xg.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(g, cfg)
    cd = cfg.compute_dtype

    logits = xg.astype(jnp.float32) @ params["router"]  # (n, G, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (n, G, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    mask = jax.nn.one_hot(expert_idx, e, dtype=jnp.int32)  # (n, G, k, E)
    mask_flat = mask.transpose(0, 2, 1, 3).reshape(n, k * g, e)
    pos_flat = jnp.cumsum(mask_flat, axis=1) - mask_flat  # per-group count
    pos = pos_flat.reshape(n, k, g, e).transpose(0, 2, 1, 3)  # (n, G, k, E)
    pos = jnp.sum(pos * mask, axis=-1)  # (n, G, k)
    keep = (pos < cap) & (jnp.sum(mask, axis=-1) > 0)
    disp_k = jax.nn.one_hot(pos, cap, dtype=xg.dtype) * keep[..., None].astype(xg.dtype)
    dispatch = jnp.einsum("ntke,ntkc->ntec", mask.astype(xg.dtype), disp_k)
    combine = jnp.einsum(
        "ntk,ntke,ntkc->ntec", gate_vals.astype(xg.dtype), mask.astype(xg.dtype), disp_k
    )

    xe = jnp.einsum("ntec,ntd->necd", dispatch, xg)  # (n, E, C, d)
    xe = xe.transpose(1, 0, 2, 3).reshape(e, n * cap, d)  # (E, n*C, d)
    xe = shard(xe, ("experts", None, None))
    act = activation_fn(cfg.activation)
    h = jnp.einsum("ecd,edf->ecf", xe, params["w_in"].astype(cd))
    if "w_gate" in params:
        gte = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"].astype(cd))
        h = act(gte) * h
    else:
        h = act(h)
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_out"].astype(cd))
    ye = shard(ye, ("experts", None, None))
    ye = ye.reshape(e, n, cap, d).transpose(1, 0, 2, 3)  # (n, E, C, d)
    out = jnp.einsum("ntec,necd->ntd", combine, ye)

    frac_tokens = jnp.mean(mask[:, :, 0].astype(jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return out, aux


def _moe_one_group(params: Dict[str, Array], xf: Array, cfg: ArchConfig) -> Tuple[Array, Array]:
    """xf: (T, d) -> ((T, d), aux)."""
    t, d = xf.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(t, cfg)

    logits = (xf.astype(jnp.float32)) @ params["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # position-in-expert via cumulative count over (k-major, token) order
    mask = jax.nn.one_hot(expert_idx, e, dtype=jnp.int32)  # (T, k, E)
    mask_flat = mask.transpose(1, 0, 2).reshape(k * t, e)
    pos_flat = jnp.cumsum(mask_flat, axis=0) - mask_flat  # count before me
    pos = pos_flat.reshape(k, t, e).transpose(1, 0, 2)  # (T, k, E)
    pos = jnp.sum(pos * mask, axis=-1)  # (T, k)
    keep = (pos < cap) & (jnp.sum(mask, axis=-1) > 0)

    disp_k = (
        jax.nn.one_hot(pos, cap, dtype=xf.dtype)
        * keep[..., None].astype(xf.dtype)
    )  # (T, k, C)
    dispatch = jnp.einsum("tke,tkc->tec", mask.astype(xf.dtype), disp_k)  # (T, E, C)
    combine = jnp.einsum("tk,tke,tkc->tec", gate_vals.astype(xf.dtype), mask.astype(xf.dtype), disp_k)

    xe = jnp.einsum("tec,td->ecd", dispatch, xf)  # (E, C, d)
    xe = shard(xe, ("experts", None, None))
    cd = cfg.compute_dtype
    h = jnp.einsum("ecd,edf->ecf", xe, params["w_in"].astype(cd))
    act = activation_fn(cfg.activation)
    if "w_gate" in params:
        g = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"].astype(cd))
        h = act(g) * h
    else:
        h = act(h)
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_out"].astype(cd))
    ye = shard(ye, ("experts", None, None))
    out = jnp.einsum("tec,ecd->td", combine, ye)

    # Switch-style load-balance auxiliary loss
    frac_tokens = jnp.mean(mask[:, 0].astype(jnp.float32), axis=0)  # top-1 fraction
    frac_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return out, aux
