"""State-space mixers: Mamba (selective SSM, for Jamba) and RWKV-6 (Finch).

Both are sequential recurrences implemented with ``lax.scan`` over time for
train/prefill and an O(1) single-step update for decode — this is what makes
the ``long_500k`` shape (524k-token context, one-token decode) feasible:
the carried state is a few MB regardless of context length.

Decode state:
  mamba: {"conv": (B, d_conv-1, di), "ssm": (B, di, N)}
  rwkv:  {"wkv": (B, H, hd, hd), "shift_t": (B, d), "shift_c": (B, d)}
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig, dense_init

Array = jax.Array

DT_RANK_DIV = 16
LORA_DIM = 32


# ===========================================================================
# Mamba (selective SSM)
# ===========================================================================


def mamba_init(key: Array, cfg: ArchConfig) -> Dict[str, Array]:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_d_state
    dt_rank = max(1, d // DT_RANK_DIV)
    ks = jax.random.split(key, 8)
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, cfg.param_dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_d_conv, di), jnp.float32) * 0.1).astype(cfg.param_dtype),
        "conv_b": jnp.zeros((di,), cfg.param_dtype),
        "x_proj": dense_init(ks[2], di, dt_rank + 2 * n, cfg.param_dtype),
        "dt_proj": dense_init(ks[3], dt_rank, di, cfg.param_dtype),
        "dt_bias": jnp.full((di,), -4.6, cfg.param_dtype),  # softplus ~ 0.01
        "a_log": jnp.log(a),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, d, cfg.param_dtype),
    }


def _mamba_conv_full(x: Array, w: Array, b: Array) -> Array:
    """Causal depthwise conv over (B, S, di) with kernel (K, di)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + xp[:, i : i + x.shape[1], :] * w[i]
    return out + b


def mamba_apply(
    params: Dict[str, Array],
    x: Array,
    cfg: ArchConfig,
    state: Optional[Dict[str, Array]] = None,
) -> Tuple[Array, Optional[Dict[str, Array]]]:
    """x: (B, S, d). state given + S == 1 -> decode step; else full scan."""
    b, s, d = x.shape
    di = cfg.ssm_expand * d
    n = cfg.ssm_d_state
    dt_rank = max(1, d // DT_RANK_DIV)
    cd = cfg.compute_dtype

    xz = x @ params["in_proj"].astype(cd)
    xin, z = jnp.split(xz, 2, axis=-1)
    conv_w = params["conv_w"].astype(cd)
    conv_b = params["conv_b"].astype(cd)
    kk = conv_w.shape[0]

    decode = state is not None and s == 1
    if decode:
        hist = jnp.concatenate([state["conv"].astype(cd), xin], axis=1)  # (B, K, di)
        xc = jnp.sum(hist * conv_w[None], axis=1, keepdims=True) + conv_b
        new_conv = hist[:, 1:, :]
    else:
        xc = _mamba_conv_full(xin, conv_w, conv_b)
        new_conv = None
        if state is not None:  # prefill: save tail for subsequent decode
            pad = jnp.zeros((b, max(0, (kk - 1) - s), di), cd)
            new_conv = jnp.concatenate([pad, xin[:, -(kk - 1) :, :]], axis=1)
    xc = jax.nn.silu(xc)

    proj = xc @ params["x_proj"].astype(cd)
    dt_raw, b_mat, c_mat = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(
        dt_raw @ params["dt_proj"].astype(cd) + params["dt_bias"].astype(cd)
    ).astype(jnp.float32)  # (B, S, di)
    a = -jnp.exp(params["a_log"])  # (di, n)
    da = jnp.exp(dt[..., None] * a)  # (B, S, di, n)
    dbx = (dt * xc.astype(jnp.float32))[..., None] * b_mat.astype(jnp.float32)[:, :, None, :]

    h0 = state["ssm"].astype(jnp.float32) if state is not None else jnp.zeros((b, di, n), jnp.float32)

    if decode:
        h = da[:, 0] * h0 + dbx[:, 0]
        y = jnp.einsum("bdn,bn->bd", h, c_mat[:, 0].astype(jnp.float32))[:, None, :]
        new_ssm = h
    else:
        def step(h, inp):
            da_t, dbx_t, c_t = inp
            h = da_t * h + dbx_t
            y = jnp.einsum("bdn,bn->bd", h, c_t)
            return h, y

        xs = (
            jnp.moveaxis(da, 1, 0),
            jnp.moveaxis(dbx, 1, 0),
            jnp.moveaxis(c_mat.astype(jnp.float32), 1, 0),
        )
        unroll = max(1, getattr(cfg, "ssm_unroll", 1))
        new_ssm, ys = jax.lax.scan(step, h0, xs, unroll=unroll)
        y = jnp.moveaxis(ys, 0, 1)  # (B, S, di)

    y = y + xc.astype(jnp.float32) * params["d_skip"]
    y = (y.astype(cd)) * jax.nn.silu(z)
    out = y @ params["out_proj"].astype(cd)

    new_state = None
    if state is not None:
        new_state = {"conv": (new_conv if new_conv is not None else state["conv"]).astype(cd), "ssm": new_ssm}
    return out, new_state


def mamba_init_state(cfg: ArchConfig, batch: int) -> Dict[str, Array]:
    di = cfg.ssm_expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.ssm_d_conv - 1, di), cfg.compute_dtype),
        "ssm": jnp.zeros((batch, di, cfg.ssm_d_state), jnp.float32),
    }


# ===========================================================================
# RWKV-6 (Finch): data-dependent decay linear recurrence
# ===========================================================================


def rwkv_init(key: Array, cfg: ArchConfig) -> Dict[str, Array]:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    h = d // hd
    ks = jax.random.split(key, 16)
    p = {
        # time-mix (attention analogue)
        "mu_base": jnp.full((d,), 0.5, cfg.param_dtype),
        "mu": 0.5 * jnp.ones((5, d), cfg.param_dtype),  # r,k,v,w,g
        "lora_a": dense_init(ks[0], d, 5 * LORA_DIM, cfg.param_dtype),
        "lora_b": (jax.random.normal(ks[1], (5, LORA_DIM, d), jnp.float32) * 0.01).astype(cfg.param_dtype),
        "w_r": dense_init(ks[2], d, d, cfg.param_dtype),
        "w_k": dense_init(ks[3], d, d, cfg.param_dtype),
        "w_v": dense_init(ks[4], d, d, cfg.param_dtype),
        "w_g": dense_init(ks[5], d, d, cfg.param_dtype),
        "w_o": dense_init(ks[6], d, d, cfg.param_dtype),
        "decay_base": jnp.full((d,), -5.0, jnp.float32),
        "decay_lora_a": dense_init(ks[7], d, LORA_DIM, cfg.param_dtype),
        "decay_lora_b": (jax.random.normal(ks[8], (LORA_DIM, d), jnp.float32) * 0.01).astype(cfg.param_dtype),
        "bonus_u": (jax.random.normal(ks[9], (h, hd), jnp.float32) * 0.1),
        "ln_x": jnp.ones((d,), jnp.float32),
        # channel-mix (FFN analogue)
        "cmix_mu_k": jnp.full((d,), 0.5, cfg.param_dtype),
        "cmix_mu_r": jnp.full((d,), 0.5, cfg.param_dtype),
        "cmix_wk": dense_init(ks[10], d, cfg.d_ff, cfg.param_dtype),
        "cmix_wv": dense_init(ks[11], cfg.d_ff, d, cfg.param_dtype),
        "cmix_wr": dense_init(ks[12], d, d, cfg.param_dtype),
    }
    return p


def _token_shift(x: Array, prev: Optional[Array]) -> Array:
    """x_{t-1}: shift right by one; position 0 takes ``prev`` (decode carry)."""
    b, s, d = x.shape
    if s == 1:
        return prev[:, None, :] if prev is not None else jnp.zeros_like(x)
    shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]
    if prev is not None:
        shifted = shifted.at[:, 0, :].set(prev)
    return shifted


def rwkv_time_mix(
    params: Dict[str, Array],
    x: Array,
    cfg: ArchConfig,
    state: Optional[Dict[str, Array]] = None,
) -> Tuple[Array, Optional[Dict[str, Array]]]:
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd
    cd = cfg.compute_dtype

    prev = state["shift_t"] if state is not None else None
    xprev = _token_shift(x, prev)
    dx = xprev - x

    # data-dependent lerp (ddlerp) via low-rank adapters
    x_base = x + dx * params["mu_base"].astype(cd)
    lora = jnp.tanh(x_base @ params["lora_a"].astype(cd))  # (B,S,5*LORA)
    lora = lora.reshape(b, s, 5, LORA_DIM)
    adj = jnp.einsum("bsfl,fld->bsfd", lora, params["lora_b"].astype(cd))  # (B,S,5,d)
    mixed = x[:, :, None, :] + dx[:, :, None, :] * (params["mu"].astype(cd) + adj)
    xr, xk, xv, xw, xg = [mixed[:, :, i, :] for i in range(5)]

    r = (xr @ params["w_r"].astype(cd)).reshape(b, s, h, hd)
    k = (xk @ params["w_k"].astype(cd)).reshape(b, s, h, hd)
    v = (xv @ params["w_v"].astype(cd)).reshape(b, s, h, hd)
    g = jax.nn.silu(xg @ params["w_g"].astype(cd))

    # data-dependent decay w_t in (0, 1); log w = -exp(dec) used directly by
    # the chunked path (skips the exp->log round-trip and its AD chain)
    dec = params["decay_base"] + (
        jnp.tanh(xw @ params["decay_lora_a"].astype(cd)) @ params["decay_lora_b"].astype(cd)
    ).astype(jnp.float32)
    neg_logw = jnp.exp(dec).reshape(b, s, h, hd)  # -log w, > 0
    w = jnp.exp(-neg_logw)  # (B,S,H,hd)

    u = params["bonus_u"]  # (H, hd)
    r32, k32, v32 = (t.astype(jnp.float32) for t in (r, k, v))

    s0 = state["wkv"].astype(jnp.float32) if state is not None else jnp.zeros((b, h, hd, hd), jnp.float32)

    def step(wkv, inp):
        r_t, k_t, v_t, w_t = inp  # (B,H,hd) each
        kv = k_t[..., :, None] * v_t[..., None, :]  # (B,H,hd,hd)
        y = jnp.einsum("bhi,bhij->bhj", r_t, wkv + u[None, :, :, None] * kv)
        wkv = w_t[..., :, None] * wkv + kv
        return wkv, y

    chunk = getattr(cfg, "rwkv_chunk", None)
    if s == 1 and state is not None:
        inp = (r32[:, 0], k32[:, 0], v32[:, 0], w[:, 0])
        new_wkv, y = step(s0, inp)
        y = y[:, None]
    elif chunk and s % chunk == 0 and s > chunk:
        new_wkv, y = _rwkv_chunked(
            r32, k32, v32, -neg_logw, u, s0, chunk, stream_dtype=cd, decay_is_log=True
        )
    else:
        xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r32, k32, v32, w))
        new_wkv, ys = jax.lax.scan(step, s0, xs)
        y = jnp.moveaxis(ys, 0, 1)  # (B,S,H,hd)

    # per-head group norm
    mean = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    y = (y - mean) * jax.lax.rsqrt(var + 1e-5)
    y = y.reshape(b, s, d) * params["ln_x"]
    out = (y.astype(cd) * g) @ params["w_o"].astype(cd)

    new_state = None
    if state is not None:
        new_state = dict(state)
        new_state["wkv"] = new_wkv
        new_state["shift_t"] = x[:, -1, :]
    return out, new_state


def _rwkv_chunked(r, k, v, w, u, s0, chunk: int, stream_dtype=jnp.float32, decay_is_log=False):
    """Chunk-parallel RWKV-6 (GLA-style): the per-timestep recurrence

        S_t = diag(w_t) S_{t-1} + k_t v_t^T ;  y_t = r_t (S_{t-1} + u k_t v_t^T)

    is evaluated per chunk of C tokens as three MXU matmuls instead of C
    sequential HBM round-trips of the (hd x hd) state:

        cum_t   = sum_{tau<=t} log w_tau                 (per-channel)
        y_intra = tril_strict( (r e^{cum_{t-1}}) (k e^{-cum_tau})^T ) v
                  + (r . u k) v_t                        (diagonal bonus)
        y_inter = (r e^{cum_{t-1}}) S_chunk_start
        S_next  = e^{cum_C} . S + (k e^{cum_C - cum_tau})^T v

    log-decay sums are clamped at -30 per chunk for fp32 stability (decay
    factors below e^-30 contribute nothing).  Used for train/prefill; the
    sequential scan remains the decode path and the correctness oracle.

    Inputs: r/k/v (B,S,H,hd) f32, w (B,S,H,hd) decay in (0,1),
    s0 (B,H,hd,hd).  Returns (S_final, y (B,S,H,hd)).
    """
    b, s, h, hd = r.shape
    nc = s // chunk
    resh = lambda t: t.reshape(b, nc, chunk, h, hd)
    rc, kc, vc, wc = resh(r), resh(k), resh(v), resh(w)
    logw = wc if decay_is_log else jnp.log(jnp.clip(wc, 1e-38, 1.0))
    cum = jnp.cumsum(logw, axis=2)  # (B,nc,C,H,hd), <= 0, decreasing in t
    cum_prev = cum - logw  # sum_{tau <= t-1}
    cum_end = cum[:, :, -1:, :, :]
    # mid-reference factorization: e^{cum_{t-1}-cum_tau} = (e^{cum_{t-1}-m})
    # (e^{m-cum_tau}) with m = cum_end/2 halves the exponent range; clamping
    # at +-60 only bites when a channel decays below e^-120 *within one
    # chunk* (contributions there are zero to fp32 anyway).
    mid = 0.5 * cum_end
    # streams in compute dtype (bf16 in production): exponent factors are
    # bounded by the mid-reference, and all contractions accumulate in f32
    # via preferred_element_type; the carried state and cumsum stay f32.
    sd = stream_dtype
    r_dec = (rc * jnp.exp(jnp.clip(cum_prev - mid, -60.0, 60.0))).astype(sd)
    k_dec = (kc * jnp.exp(jnp.clip(mid - cum, -60.0, 60.0))).astype(sd)
    r_in = (rc * jnp.exp(cum_prev)).astype(sd)  # <= 1: inter-chunk query
    k_rem = (kc * jnp.exp(cum_end - cum)).astype(sd)  # <= 1: decay to end
    p_end = jnp.exp(cum[:, :, -1])  # (B,nc,H,hd) f32
    vc_s = vc.astype(sd)

    # intra-chunk attention-like term (strictly causal) + diagonal bonus
    a = jnp.einsum(
        "bnthi,bnchi->bnhtc", r_dec, k_dec, preferred_element_type=jnp.float32
    )  # (B,nc,H,C,C)
    ti = jnp.arange(chunk)[:, None]
    tj = jnp.arange(chunk)[None, :]
    a = jnp.where((tj < ti)[None, None, None], a, 0.0).astype(sd)
    y_intra = jnp.einsum("bnhtc,bnchj->bnthj", a, vc_s, preferred_element_type=jnp.float32)
    bonus = jnp.einsum("bnthi,hi,bnthi->bnth", rc, u.astype(jnp.float32), kc)
    y_intra = y_intra + bonus[..., None] * vc

    # inter-chunk: carried state, one matmul per chunk (scan over nc chunks)
    def carry_step(S, inp):
        rd_c, krem_c, v_c, pend_c = inp
        y = jnp.einsum("bthi,bhij->bthj", rd_c, S.astype(sd), preferred_element_type=jnp.float32)
        S = pend_c[..., None] * S + jnp.einsum(
            "bthi,bthj->bhij", krem_c, v_c, preferred_element_type=jnp.float32
        )
        return S, y

    xs = (
        jnp.moveaxis(r_in, 1, 0),
        jnp.moveaxis(k_rem, 1, 0),
        jnp.moveaxis(vc_s, 1, 0),
        jnp.moveaxis(p_end, 1, 0),
    )
    s_final, y_inter = jax.lax.scan(carry_step, s0, xs)
    y = y_intra + jnp.moveaxis(y_inter, 0, 1)
    return s_final, y.reshape(b, s, h, hd)


def rwkv_channel_mix(
    params: Dict[str, Array],
    x: Array,
    cfg: ArchConfig,
    state: Optional[Dict[str, Array]] = None,
) -> Tuple[Array, Optional[Dict[str, Array]]]:
    cd = cfg.compute_dtype
    prev = state["shift_c"] if state is not None else None
    xprev = _token_shift(x, prev)
    dx = xprev - x
    xk = x + dx * params["cmix_mu_k"].astype(cd)
    xr = x + dx * params["cmix_mu_r"].astype(cd)
    k = jnp.square(jax.nn.relu(xk @ params["cmix_wk"].astype(cd)))
    kv = k @ params["cmix_wv"].astype(cd)
    out = jax.nn.sigmoid(xr @ params["cmix_wr"].astype(cd)) * kv
    new_state = None
    if state is not None:
        new_state = dict(state)
        new_state["shift_c"] = x[:, -1, :]
    return out, new_state


def rwkv_init_state(cfg: ArchConfig, batch: int) -> Dict[str, Array]:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    h = d // hd
    return {
        "wkv": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "shift_t": jnp.zeros((batch, d), cfg.compute_dtype),
        "shift_c": jnp.zeros((batch, d), cfg.compute_dtype),
    }
