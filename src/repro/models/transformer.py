"""Generic decoder stack over a repeating block pattern.

One ``lax.scan`` over pattern repetitions (stacked params => small HLO even
at 96 layers / 512-way SPMD) with optional remat; heterogeneous patterns
(gemma2 local/global, jamba mamba/attn/MoE) apply their pattern positions
sequentially inside the scan body.

Modes (all through ``forward``):
  * train/score:   caches=None — full-sequence causal forward
  * prefill:       caches given, S > 1 — fills caches, returns logits + caches
  * decode:        caches given, S == 1 — one-token step at ``cache_len``
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.common import (
    ArchConfig,
    BlockSpec,
    dense_init,
    init_rms_norm,
    mlp_apply,
    mlp_init,
    rms_norm,
    softcap,
)
from repro.parallel.sharding import shard

Array = jax.Array


class ModelOutput(NamedTuple):
    logits: Array
    hidden: Array  # final hidden states (pre-head) — decorrelation target
    caches: Optional[Any]
    aux: Dict[str, Array]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _block_init(key: Array, cfg: ArchConfig, spec: BlockSpec) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {
        "norm1": init_rms_norm(cfg.d_model, cfg.param_dtype),
        "norm2": init_rms_norm(cfg.d_model, cfg.param_dtype),
    }
    if cfg.post_block_norm:
        p["post_norm1"] = init_rms_norm(cfg.d_model, cfg.param_dtype)
        p["post_norm2"] = init_rms_norm(cfg.d_model, cfg.param_dtype)
    if spec.mixer == "attn":
        p["attn"] = attn_lib.attn_init(ks[0], cfg)
    elif spec.mixer == "mamba":
        p["mamba"] = ssm_lib.mamba_init(ks[0], cfg)
    elif spec.mixer == "rwkv":
        p["rwkv"] = ssm_lib.rwkv_init(ks[0], cfg)
    if spec.ffn == "dense":
        p["mlp"] = mlp_init(ks[1], cfg)
    elif spec.ffn == "moe":
        p["moe"] = moe_lib.moe_init(ks[1], cfg)
    return p


def init_params(key: Array, cfg: ArchConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 4 + len(cfg.pattern))
    params: Dict[str, Any] = {}
    if cfg.frontend == "audio_codes":
        params["embed"] = (
            jax.random.normal(ks[0], (cfg.n_codebooks, cfg.vocab_size, cfg.d_model), jnp.float32)
            * 0.02
        ).astype(cfg.param_dtype)
        params["heads"] = dense_init(ks[1], cfg.d_model, cfg.n_codebooks * cfg.vocab_size, cfg.param_dtype)
    else:
        params["embed"] = (
            jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model), jnp.float32) * 0.02
        ).astype(cfg.param_dtype)
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(ks[1], cfg.d_model, cfg.vocab_size, cfg.param_dtype)
    params["final_norm"] = init_rms_norm(cfg.d_model, cfg.param_dtype)

    # stacked per-pattern-position block params: leaves (repeats, ...)
    blocks = {}
    for pos, spec in enumerate(cfg.pattern):
        rep_keys = jax.random.split(ks[4 + pos], cfg.repeats)
        blocks[f"pos{pos}"] = jax.vmap(lambda k: _block_init(k, cfg, spec))(rep_keys)
    params["blocks"] = blocks
    return params


# ---------------------------------------------------------------------------
# Caches / recurrent state
# ---------------------------------------------------------------------------


def init_caches(cfg: ArchConfig, batch: int, max_len: int) -> Dict[str, Any]:
    """Per-pattern-position stacked (repeats, ...) decode state."""

    def one(spec: BlockSpec):
        if spec.mixer == "attn":
            base = attn_lib.init_kv_cache(cfg, batch, max_len)
        elif spec.mixer == "mamba":
            base = ssm_lib.mamba_init_state(cfg, batch)
        elif spec.mixer == "rwkv":
            base = ssm_lib.rwkv_init_state(cfg, batch)
        else:
            base = {}
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (cfg.repeats,) + x.shape), base)

    return {f"pos{pos}": one(spec) for pos, spec in enumerate(cfg.pattern)}


def init_paged_caches(cfg: ArchConfig, batch: int, num_pages: int, page: int) -> Dict[str, Any]:
    """Paged decode state, dispatched per pattern position: attention gets a
    block-table page pool (repeats, P, page, kv, hd) shared by all slots,
    while SSM/RWKV state stays dense per slot — recurrent state is O(1) in
    context length, so paging it buys nothing (paging is attention-only)."""

    def one(spec: BlockSpec):
        if spec.mixer == "attn":
            base = attn_lib.init_paged_kv_cache(cfg, num_pages, page)
        elif spec.mixer == "mamba":
            base = ssm_lib.mamba_init_state(cfg, batch)
        elif spec.mixer == "rwkv":
            base = ssm_lib.rwkv_init_state(cfg, batch)
        else:
            base = {}
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (cfg.repeats,) + x.shape), base)

    return {f"pos{pos}": one(spec) for pos, spec in enumerate(cfg.pattern)}


def cache_shardings_logical(cfg: ArchConfig):
    """Logical axes of each cache leaf (for input_specs/dry-run)."""

    def one(spec: BlockSpec):
        if spec.mixer == "attn":
            return {
                "k": ("stack", "batch", "kv_seq", None, None),
                "v": ("stack", "batch", "kv_seq", None, None),
            }
        if spec.mixer == "mamba":
            return {
                "conv": ("stack", "batch", None, "ff"),
                "ssm": ("stack", "batch", "ff", None),
            }
        if spec.mixer == "rwkv":
            return {
                "wkv": ("stack", "batch", None, None, None),
                "shift_t": ("stack", "batch", None),
                "shift_c": ("stack", "batch", None),
            }
        return {}

    return {f"pos{pos}": one(spec) for pos, spec in enumerate(cfg.pattern)}


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _apply_block(
    p: Dict[str, Any],
    x: Array,
    cfg: ArchConfig,
    spec: BlockSpec,
    positions: Array,
    cache: Optional[Dict[str, Array]],
    cache_len: Optional[Array],
    block_tables: Optional[Array] = None,
    chunked_prefill: bool = False,
) -> Tuple[Array, Optional[Dict[str, Array]], Array]:
    aux = jnp.asarray(0.0, jnp.float32)
    h = rms_norm(x, p["norm1"], cfg.rms_eps)
    new_cache = cache
    if spec.mixer == "attn":
        out, new_cache = attn_lib.attn_apply(
            p["attn"], h, cfg, spec, positions, cache, cache_len,
            block_tables=block_tables, chunked=chunked_prefill,
        )
    elif spec.mixer == "mamba":
        out, new_cache = ssm_lib.mamba_apply(p["mamba"], h, cfg, cache)
    elif spec.mixer == "rwkv":
        out, new_cache = ssm_lib.rwkv_time_mix(p["rwkv"], h, cfg, cache)
    else:
        out = jnp.zeros_like(h)
    if cfg.post_block_norm:
        out = rms_norm(out, p["post_norm1"], cfg.rms_eps)
    x = x + out
    x = shard(x, ("batch", "seq", "embed"))

    h = rms_norm(x, p["norm2"], cfg.rms_eps)
    if spec.ffn == "dense":
        out = mlp_apply(p["mlp"], h, cfg)
    elif spec.ffn == "moe":
        out, moe_aux = moe_lib.moe_apply(p["moe"], h, cfg)
        aux = aux + moe_aux
    elif spec.ffn == "rwkv_cmix":
        out, new_cache = ssm_lib.rwkv_channel_mix(p["rwkv"], h, cfg, new_cache)
    else:
        out = jnp.zeros_like(h)
    if cfg.post_block_norm:
        out = rms_norm(out, p["post_norm2"], cfg.rms_eps)
    x = x + out
    x = shard(x, ("batch", "seq", "embed"))
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _embed_inputs(params, cfg: ArchConfig, tokens, embeds):
    if embeds is not None:  # modality frontends supply embeddings directly
        x = embeds.astype(cfg.compute_dtype)
    elif cfg.frontend == "audio_codes":
        # tokens: (B, S, n_q) EnCodec codes; embeddings summed over codebooks
        emb = params["embed"].astype(cfg.compute_dtype)
        x = sum(emb[q][tokens[..., q]] for q in range(cfg.n_codebooks))
    else:
        x = params["embed"].astype(cfg.compute_dtype)[tokens]
    if cfg.scale_embed:
        x = x * jnp.asarray(jnp.sqrt(cfg.d_model), cfg.compute_dtype)
    return shard(x, ("batch", "seq", "embed"))


def _logits(params, cfg: ArchConfig, h: Array) -> Array:
    if cfg.frontend == "audio_codes":
        logits = h @ params["heads"].astype(cfg.compute_dtype)
        logits = logits.reshape(*h.shape[:-1], cfg.n_codebooks, cfg.vocab_size)
    elif cfg.tie_embeddings:
        logits = h @ params["embed"].astype(cfg.compute_dtype).T
    else:
        logits = h @ params["lm_head"].astype(cfg.compute_dtype)
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return shard(logits, ("batch", "seq", "vocab"))


def forward(
    params: Dict[str, Any],
    cfg: ArchConfig,
    tokens: Optional[Array] = None,
    embeds: Optional[Array] = None,
    positions: Optional[Array] = None,
    caches: Optional[Dict[str, Any]] = None,
    cache_len: Optional[Array] = None,
    block_tables: Optional[Array] = None,
    chunked_prefill: bool = False,
) -> ModelOutput:
    x = _embed_inputs(params, cfg, tokens, embeds)
    b, s, _ = x.shape

    if positions is None:
        off = 0
        if cache_len is not None:
            # scalar (whole-batch) or (B,) per-slot decode positions; the
            # speculative verify rides the vector form — lane (slot, j)
            # passes cache_len = pos + j and gets RoPE position pos + j here,
            # exactly what the sequential decode of that token would use
            off = cache_len[:, None] if jnp.ndim(cache_len) == 1 else cache_len
        base = jnp.arange(s, dtype=jnp.int32)[None, :] + off
        positions = jnp.broadcast_to(base, (b, s))
        if cfg.mrope:
            positions = jnp.broadcast_to(positions[None], (3, b, s))

    have_cache = caches is not None

    def body(carry, xs):
        x, aux = carry
        layer_params = xs[0]
        layer_caches = xs[1] if have_cache else None
        new_caches = {}
        for pos, spec in enumerate(cfg.pattern):
            name = f"pos{pos}"
            cache = layer_caches[name] if have_cache else None
            x, nc, a = _apply_block(
                layer_params[name], x, cfg, spec, positions, cache, cache_len,
                block_tables=block_tables, chunked_prefill=chunked_prefill,
            )
            if have_cache:
                new_caches[name] = nc if nc is not None else cache
            aux = aux + a
        return (x, aux), (new_caches if have_cache else None)

    body_fn = body
    if cfg.remat:
        policy = (
            jax.checkpoint_policies.dots_saveable
            if getattr(cfg, "remat_policy", "nothing") == "dots"
            else jax.checkpoint_policies.nothing_saveable
        )
        body_fn = jax.checkpoint(body, policy=policy)

    xs = (params["blocks"], caches) if have_cache else (params["blocks"],)
    (x, aux), new_caches = jax.lax.scan(body_fn, (x, jnp.asarray(0.0, jnp.float32)), xs)

    h = rms_norm(x, params["final_norm"], cfg.rms_eps)
    logits = _logits(params, cfg, h)
    return ModelOutput(
        logits=logits,
        hidden=h,
        caches=new_caches,
        aux={"moe_aux": aux / max(cfg.n_layers, 1)},
    )
