"""GQA attention: RoPE / M-RoPE, local+global, softcap, chunked-causal
(flash-style) prefill, seq-sharded KV-cache decode, paged (block-table)
decode and incremental (chunked) prefill for the serving slot pool.

Implementation notes
  * Chunked prefill uses a *flattened (i, j <= i) pair scan*: the static list
    of causal chunk pairs is scanned with online-softmax accumulation, so the
    compiled graph does exactly the causal half of the score FLOPs (a naive
    masked two-level scan would double them — this shows up directly in the
    MODEL_FLOPS / HLO_FLOPs roofline ratio).
  * Decode attends a (B, max_len, KV, hd) cache sharded over the ``model``
    mesh axis on the *sequence* dim (flash-decoding style); XLA inserts the
    logsumexp-combining collectives for the sharded softmax reduction.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ArchConfig, BlockSpec, dense_init, softcap
from repro.parallel.sharding import shard

Array = jax.Array

NEG_INF = -1e30
CHUNK_THRESHOLD = 8192  # use chunked prefill beyond this many tokens
CHUNK_SIZE = 2048


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + multimodal M-RoPE)
# ---------------------------------------------------------------------------


def _rope_freqs(hd: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = _rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: Array, positions: Array, theta: float, sections: Tuple[int, ...]) -> Array:
    """Qwen2-VL multimodal RoPE. positions: (3, B, S) — temporal / h / w
    streams; ``sections`` partitions the hd/2 frequency dims among streams."""
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = _rope_freqs(hd, theta)  # (hd/2,)
    ang_streams = positions[..., None].astype(jnp.float32) * freqs  # (3, B, S, hd/2)
    sel = np.concatenate([np.full((s,), i) for i, s in enumerate(sections)])
    sel = jnp.asarray(sel, jnp.int32)  # (hd/2,)
    ang = jnp.take_along_axis(
        jnp.moveaxis(ang_streams, 0, -1), sel[None, None, :, None], axis=-1
    )[..., 0]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def attn_init(key: Array, cfg: ArchConfig) -> Dict[str, Array]:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    params = {
        "wq": dense_init(ks[0], d, h * hd, cfg.param_dtype),
        "wk": dense_init(ks[1], d, kv * hd, cfg.param_dtype),
        "wv": dense_init(ks[2], d, kv * hd, cfg.param_dtype),
        "wo": dense_init(ks[3], h * hd, d, cfg.param_dtype),
    }
    if cfg.qkv_bias:
        params["bq"] = jnp.zeros((h * hd,), cfg.param_dtype)
        params["bk"] = jnp.zeros((kv * hd,), cfg.param_dtype)
        params["bv"] = jnp.zeros((kv * hd,), cfg.param_dtype)
    return params


def _project_qkv(params, x: Array, cfg: ArchConfig, positions: Array):
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    cd = cfg.compute_dtype
    q = x @ params["wq"].astype(cd)
    k = x @ params["wk"].astype(cd)
    v = x @ params["wv"].astype(cd)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(cd)
        k = k + params["bk"].astype(cd)
        v = v + params["bv"].astype(cd)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    if cfg.mrope:
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        pos2d = positions if positions.ndim == 2 else positions[0]
        q = apply_rope(q, pos2d, cfg.rope_theta)
        k = apply_rope(k, pos2d, cfg.rope_theta)
    if getattr(cfg, "seq_shard_attention", False):
        from repro.parallel.sharding import current_mesh, current_rules

        mesh = current_mesh()
        n_model = 1
        if mesh is not None:
            for ax in current_rules().get("heads") or ():
                if ax in mesh.axis_names:
                    n_model *= mesh.shape[ax]
        if h % max(n_model, 1) != 0:
            # heads unshardable: shard query-sequence over `model`; k/v stay
            # replicated so scores/softmax/out are fully shard-local.
            q = shard(q, ("batch", "kv_seq", None, None))
            return q, k, v
    q = shard(q, ("batch", None, "heads", None))
    return q, k, v


def _repeat_kv(x: Array, n_rep: int) -> Array:
    if n_rep == 1:
        return x
    b, s, kv, hd = x.shape
    return jnp.repeat(x, n_rep, axis=2)


# ---------------------------------------------------------------------------
# Full (materialized-scores) attention — short sequences
# ---------------------------------------------------------------------------


def _full_attention(q, k, v, cfg: ArchConfig, spec: BlockSpec) -> Array:
    b, s, h, hd = q.shape
    scale = cfg.attn_scale or (1.0 / math.sqrt(hd))
    k = _repeat_kv(k, h // k.shape[2])
    v = _repeat_kv(v, h // v.shape[2])
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    scores = softcap(scores, cfg.attn_softcap)
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    mask = ki <= qi
    if spec.attn_type == "local":
        mask &= ki > qi - cfg.window_size
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


# ---------------------------------------------------------------------------
# Chunked-causal (flash-style) attention — long prefill
# ---------------------------------------------------------------------------


def _chunked_attention(q, k, v, cfg: ArchConfig, spec: BlockSpec, chunk: int) -> Array:
    """Online-softmax over the static list of causal chunk pairs (i, j<=i)."""
    b, s, h, hd = q.shape
    scale = cfg.attn_scale or (1.0 / math.sqrt(hd))
    k = _repeat_kv(k, h // k.shape[2])
    v = _repeat_kv(v, h // v.shape[2])
    nc = s // chunk
    assert s % chunk == 0, (s, chunk)

    pairs = np.array([(i, j) for i in range(nc) for j in range(i + 1)], np.int32)
    if spec.attn_type == "local":
        span = -(-cfg.window_size // chunk)  # chunks that can be in-window
        pairs = pairs[pairs[:, 0] - pairs[:, 1] <= span]

    qc = q.reshape(b, nc, chunk, h, hd)
    kc = k.reshape(b, nc, chunk, h, hd)
    vc = v.reshape(b, nc, chunk, h, hd)

    acc0 = jnp.zeros((b, nc, chunk, h, hd), jnp.float32)
    m0 = jnp.full((b, nc, chunk, h), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, nc, chunk, h), jnp.float32)

    qi_local = jnp.arange(chunk)[:, None]
    ki_local = jnp.arange(chunk)[None, :]

    def body(carry, pair):
        acc, m, l = carry
        i, j = pair[0], pair[1]
        qb = jax.lax.dynamic_index_in_dim(qc, i, axis=1, keepdims=False)
        kb = jax.lax.dynamic_index_in_dim(kc, j, axis=1, keepdims=False)
        vb = jax.lax.dynamic_index_in_dim(vc, j, axis=1, keepdims=False)
        sc = jnp.einsum("bqhd,bkhd->bhqk", qb, kb).astype(jnp.float32) * scale
        sc = softcap(sc, cfg.attn_softcap)
        gq = i * chunk + qi_local
        gk = j * chunk + ki_local
        mask = gk <= gq
        if spec.attn_type == "local":
            mask &= gk > gq - cfg.window_size
        sc = jnp.where(mask[None, None], sc, NEG_INF)

        mi = jax.lax.dynamic_index_in_dim(m, i, axis=1, keepdims=False)  # (b, chunk, h)
        li = jax.lax.dynamic_index_in_dim(l, i, axis=1, keepdims=False)
        ai = jax.lax.dynamic_index_in_dim(acc, i, axis=1, keepdims=False)

        sc_max = jnp.max(sc, axis=-1)  # (b, h, q)
        m_new = jnp.maximum(mi, jnp.transpose(sc_max, (0, 2, 1)))
        corr = jnp.exp(mi - m_new)  # (b, q, h)
        p = jnp.exp(sc - jnp.transpose(m_new, (0, 2, 1))[:, :, :, None])  # (b,h,q,k)
        l_new = li * corr + jnp.transpose(jnp.sum(p, axis=-1), (0, 2, 1))
        a_new = ai * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bqhd", p.astype(qb.dtype), vb
        ).astype(jnp.float32)

        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, i, axis=1)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, axis=1)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, i, axis=1)
        return (acc, m, l), None

    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), jnp.asarray(pairs))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, s, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode with KV cache
# ---------------------------------------------------------------------------


def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int) -> Dict[str, Array]:
    kv, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((batch, max_len, kv, hd), cfg.compute_dtype),
        "v": jnp.zeros((batch, max_len, kv, hd), cfg.compute_dtype),
    }


def init_paged_kv_cache(cfg: ArchConfig, num_pages: int, page: int) -> Dict[str, Array]:
    """Block-table layout: one physical pool of ``num_pages`` pages of
    ``page`` tokens each, shared by all slots through their block tables
    (page 0 is the allocator's sentinel — written by masked lanes, never
    read unmasked)."""
    kv, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k_pages": jnp.zeros((num_pages, page, kv, hd), cfg.compute_dtype),
        "v_pages": jnp.zeros((num_pages, page, kv, hd), cfg.compute_dtype),
    }


def _decode_attention(q, cache_k, cache_v, cache_len, cfg: ArchConfig, spec: BlockSpec):
    """q: (B, 1, H, hd); cache_(k|v): (B, L, KV, hd); cache_len: scalar or (B,)
    per-row lengths (continuous batching: each slot decodes at its own
    position)."""
    b, _, h, hd = q.shape
    scale = cfg.attn_scale or (1.0 / math.sqrt(hd))
    k = _repeat_kv(cache_k, h // cache_k.shape[2])
    v = _repeat_kv(cache_v, h // cache_v.shape[2])
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    scores = softcap(scores, cfg.attn_softcap)
    ki = jnp.arange(k.shape[1])[None, None, None, :]
    cl = cache_len if jnp.ndim(cache_len) == 0 else cache_len.reshape(b, 1, 1, 1)
    mask = ki < cl
    if spec.attn_type == "local":
        mask &= ki >= cl - cfg.window_size
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def _paged_decode(q, k, v, cache, cache_len, block_tables, cfg: ArchConfig, spec: BlockSpec):
    """Single-token decode through block-table pages: scatter the new token's
    k/v into its slot's current page, then attend over the table.

    The jnp route gathers the pages back into a (B, NB * page, KV, hd) dense
    view and reuses ``_decode_attention`` VERBATIM — when NB * page equals the
    dense pool's max_len (the engine guarantees it), paged decode is
    bit-identical to the dense path: rows past ``cache_len`` differ only in
    masked positions whose probability mass underflows to exactly 0.  On TPU
    ``repro.tune.best_impl`` routes to the Pallas block-table kernel instead
    (``kernels/paged_attention``), which never materializes the gather.

    The speculative k-token verify (``train.serve.make_verify_step``) runs
    THROUGH this path unchanged: each draft position is its own batch lane
    with its own ``cache_len`` and table row.  Because the scatter of every
    lane's k/v happens before any lane's gather, lane ``j`` of a slot sees
    the rows lanes ``< j`` just wrote on the shared scratch pages — one
    forward verifies k + 1 positions with per-lane math identical to this
    very decode step (the bit-identity anchor).
    """
    from repro.kernels.paged_attention import ops as paged_ops
    from repro.tune.dispatch import best_impl

    b = q.shape[0]
    hd = q.shape[-1]
    page = cache["k_pages"].shape[1]
    cl = cache_len if jnp.ndim(cache_len) == 1 else jnp.full((b,), cache_len, jnp.int32)
    rows = jnp.arange(b)
    phys = block_tables[rows, cl // page]
    kp = cache["k_pages"].at[phys, cl % page].set(k[:, 0])
    vp = cache["v_pages"].at[phys, cl % page].set(v[:, 0])
    new_cache = {"k_pages": kp, "v_pages": vp}
    if best_impl("paged_attention") == "pallas":
        out = paged_ops.paged_decode_attention(
            q[:, 0],
            kp,
            vp,
            block_tables,
            cl + 1,
            scale=cfg.attn_scale or (1.0 / math.sqrt(hd)),
            softcap=cfg.attn_softcap or 0.0,
            window=cfg.window_size if spec.attn_type == "local" else 0,
        )
        return out[:, None].astype(q.dtype), new_cache
    kv = kp.shape[2]
    kd = kp[block_tables].reshape(b, -1, kv, hd)
    vd = vp[block_tables].reshape(b, -1, kv, hd)
    return _decode_attention(q, kd, vd, cl + 1, cfg, spec), new_cache


def _offset_prefill_attention(q, cache_k, cache_v, offset, cfg: ArchConfig, spec: BlockSpec):
    """Chunked prefill: queries at absolute positions [offset, offset + S)
    attend to cache rows [0, offset + S) — causal across the already-written
    prefix AND within the chunk.  cache_(k|v) already contain the chunk's
    k/v at [offset, offset + S)."""
    b, s, h, hd = q.shape
    scale = cfg.attn_scale or (1.0 / math.sqrt(hd))
    k = _repeat_kv(cache_k, h // cache_k.shape[2])
    v = _repeat_kv(cache_v, h // cache_v.shape[2])
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    scores = softcap(scores, cfg.attn_softcap)
    qi = offset + jnp.arange(s)[:, None]
    ki = jnp.arange(k.shape[1])[None, :]
    mask = ki <= qi
    if spec.attn_type == "local":
        mask &= ki > qi - cfg.window_size
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


# ---------------------------------------------------------------------------
# Public entry
# ---------------------------------------------------------------------------


def attn_apply(
    params: Dict[str, Array],
    x: Array,
    cfg: ArchConfig,
    spec: BlockSpec,
    positions: Array,
    cache: Optional[Dict[str, Array]] = None,
    cache_len: Optional[Array] = None,
    block_tables: Optional[Array] = None,
    chunked: bool = False,
) -> Tuple[Array, Optional[Dict[str, Array]]]:
    """Returns (output (B, S, d), updated cache or None).

    * cache is None: training/scoring forward over the full sequence.
    * cache given, S == 1: single-token decode (writes position cache_len).
      A cache with ``k_pages`` routes through the paged (block-table) path;
      the dense scalar- and vector-``cache_len`` paths are untouched.
    * cache given, S > 1: prefill — fills cache[0:S] and returns it; with
      ``chunked=True`` the chunk is written at ``cache_len`` instead and
      attends across the already-prefilled prefix (incremental prefill).
    """
    b, s, _ = x.shape
    h, hd = cfg.n_heads, cfg.hd
    q, k, v = _project_qkv(params, x, cfg, positions)

    new_cache = None
    if cache is not None:
        if s == 1 and "k_pages" in cache:
            out, new_cache = _paged_decode(q, k, v, cache, cache_len, block_tables, cfg, spec)
            out = out.reshape(b, s, h * hd)
            return out @ params["wo"].astype(cfg.compute_dtype), new_cache
        if s > 1 and chunked:
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, cache_len, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, cache_len, axis=1)
            new_cache = {
                "k": shard(ck, ("batch", "kv_seq", None, None)),
                "v": shard(cv, ("batch", "kv_seq", None, None)),
            }
            out = _offset_prefill_attention(q, ck, cv, cache_len, cfg, spec)
            out = out.reshape(b, s, h * hd)
            return out @ params["wo"].astype(cfg.compute_dtype), new_cache
        if s == 1:
            if jnp.ndim(cache_len) == 1:
                # per-slot decode: row i writes its token at its own position
                rows = jnp.arange(b)
                ck = cache["k"].at[rows, cache_len].set(k[:, 0])
                cv = cache["v"].at[rows, cache_len].set(v[:, 0])
            else:
                ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, cache_len, axis=1)
                cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, cache_len, axis=1)
            ck = shard(ck, ("batch", "kv_seq", None, None))
            cv = shard(cv, ("batch", "kv_seq", None, None))
            new_cache = {"k": ck, "v": cv}
            out = _decode_attention(q, ck, cv, cache_len + 1, cfg, spec)
            out = out.reshape(b, s, h * hd)
            return out @ params["wo"].astype(cfg.compute_dtype), new_cache
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, axis=1)
        new_cache = {
            "k": shard(ck, ("batch", "kv_seq", None, None)),
            "v": shard(cv, ("batch", "kv_seq", None, None)),
        }

    threshold = getattr(cfg, "attn_chunk_threshold", CHUNK_THRESHOLD)
    chunk = getattr(cfg, "attn_chunk_size", CHUNK_SIZE)
    if s > threshold and s % chunk == 0:
        out = _chunked_attention(q, k, v, cfg, spec, chunk)
    else:
        out = _full_attention(q, k, v, cfg, spec)
    out = out.reshape(b, s, h * hd)
    out = out @ params["wo"].astype(cfg.compute_dtype)
    return out, new_cache
