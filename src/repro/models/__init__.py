from repro.models.common import ArchConfig, BlockSpec
from repro.models.transformer import init_params, forward, init_caches, ModelOutput
