"""Architecture configs and shared layer primitives (functional, no flax).

Every model is a decoder stack described by a repeating *pattern* of
``BlockSpec`` entries (mixer kind + FFN kind).  Parameters for each pattern
position are stacked across repetitions so the whole stack runs as one
``lax.scan`` (small HLO, fast 512-way SPMD compiles) with remat.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.decorrelation import LMDecorrConfig

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One layer position in the repeating pattern."""

    mixer: str = "attn"  # attn | mamba | rwkv
    attn_type: str = "global"  # global | local (sliding window)
    ffn: str = "dense"  # dense | moe | none (rwkv has its own channel mix)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    pattern: Tuple[BlockSpec, ...] = (BlockSpec(),)

    # attention options
    rope_theta: float = 10000.0
    mrope: bool = False  # qwen2-vl multimodal RoPE (3 position streams)
    mrope_sections: Tuple[int, ...] = (16, 24, 24)  # halves of head_dim
    qkv_bias: bool = False
    attn_softcap: Optional[float] = None  # gemma2: 50.0
    final_softcap: Optional[float] = None  # gemma2: 30.0
    window_size: int = 4096  # for local layers
    attn_scale: Optional[float] = None

    # mlp
    activation: str = "swiglu"  # swiglu | gelu | squared_relu
    mlp_bias: bool = False

    # moe
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: Optional[int] = None
    dense_residual: bool = False  # arctic: dense MLP in parallel with MoE
    shared_expert: bool = False  # llama4: always-on shared expert
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_group_size: Optional[int] = None  # chunk dispatch: O(T*G) not O(T^2)

    # ssm (mamba)
    ssm_d_state: int = 16
    ssm_d_conv: int = 4
    ssm_expand: int = 2
    # rwkv6
    rwkv_head_dim: int = 64
    rwkv_chunk: Optional[int] = None  # chunk-parallel recurrence (perf)
    ssm_unroll: int = 1  # mamba scan unroll: keeps state in-register u steps

    # attention execution (perf knobs; defaults reproduce the naive baseline)
    attn_chunk_threshold: int = 8192  # use chunked flash path beyond this S
    attn_chunk_size: int = 2048
    # when n_heads % model-parallelism != 0, shard attention activations on
    # the QUERY-SEQUENCE dim over `model` (Megatron-SP style) instead of
    # replicating head compute (kills score-sized bwd all-reduces)
    seq_shard_attention: bool = False

    # norms / embeddings
    rms_eps: float = 1e-6
    post_block_norm: bool = False  # gemma2 sandwich norm
    scale_embed: bool = False  # gemma2: * sqrt(d_model)
    tie_embeddings: bool = True

    # modality frontends (stubs per assignment: precomputed embeddings)
    frontend: str = "none"  # none | vision_stub | audio_codes
    n_codebooks: int = 4  # musicgen

    # dtypes
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    optimizer_moment_dtype: Any = jnp.float32

    # training features
    decorr: LMDecorrConfig = dataclasses.field(default_factory=LMDecorrConfig)
    remat: bool = True
    remat_policy: str = "nothing"  # nothing | dots (save matmul outputs)

    # citation / provenance
    source: str = ""

    def __post_init__(self):
        assert self.n_layers % len(self.pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not a multiple of "
            f"pattern length {len(self.pattern)}"
        )

    @property
    def repeats(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // max(self.n_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return all(b.mixer != "attn" for b in self.pattern)

    @property
    def supports_long_context(self) -> bool:
        """True if decode cost is sub-quadratic in context (SSM / hybrid)."""
        return all(b.mixer != "attn" or b.attn_type == "local" for b in self.pattern) or (
            self.family in ("ssm", "hybrid")
        )

    def param_count(self) -> int:
        """Approximate total parameter count (embeddings + blocks)."""
        d, ff = self.d_model, self.d_ff
        hd, h, kv = self.hd, self.n_heads, self.n_kv_heads
        total = self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d
        for spec in self.pattern:
            blk = 0
            if spec.mixer == "attn":
                blk += d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d
            elif spec.mixer == "mamba":
                di = self.ssm_expand * d
                blk += d * 2 * di + di * (2 * self.ssm_d_state + di // 8) + di * d
            elif spec.mixer == "rwkv":
                blk += 4 * d * d + 2 * d * d  # time-mix + projections (approx)
            if spec.ffn == "dense":
                mults = 3 if self.activation in ("swiglu", "geglu") else 2
                blk += mults * d * ff
            elif spec.ffn == "moe":
                mdff = self.moe_d_ff or ff
                mults = 3 if self.activation in ("swiglu", "geglu") else 2
                blk += self.n_experts * mults * d * mdff + d * self.n_experts
                if self.dense_residual:
                    blk += mults * d * ff
                if self.shared_expert:
                    blk += mults * d * mdff
            blk += 2 * d  # norms
            total += blk * self.repeats
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if self.n_experts == 0:
            return self.param_count()
        d = self.d_model
        mdff = self.moe_d_ff or self.d_ff
        mults = 3 if self.activation in ("swiglu", "geglu") else 2
        per_expert = mults * d * mdff
        inactive = 0
        for spec in self.pattern:
            if spec.ffn == "moe":
                inactive += (self.n_experts - self.top_k) * per_expert * self.repeats
        return self.param_count() - inactive

    def reduced(self, **overrides) -> "ArchConfig":
        """Small same-family config for CPU smoke tests."""
        pat_len = len(self.pattern)
        small = dict(
            n_layers=2 * pat_len,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            moe_d_ff=64 if self.n_experts else None,
            window_size=16,
            ssm_d_state=8,
            rwkv_head_dim=16,
            param_dtype=jnp.float32,
            compute_dtype=jnp.float32,
            mrope_sections=(4, 2, 2),
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


# ---------------------------------------------------------------------------
# Shared primitives
# ---------------------------------------------------------------------------


def rms_norm(x: Array, weight: Array, eps: float = 1e-6) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def init_rms_norm(d: int, dtype) -> Array:
    return jnp.zeros((d,), dtype)  # stored as (weight - 1); see rms_norm


def dense_init(key: Array, d_in: int, d_out: int, dtype, scale: Optional[float] = None) -> Array:
    s = scale if scale is not None else 1.0 / jnp.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * s).astype(dtype)


def softcap(x: Array, cap: Optional[float]) -> Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def activation_fn(name: str):
    if name == "squared_relu":
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "gelu":
        return jax.nn.gelu
    return jax.nn.silu  # swiglu gate


def mlp_init(key: Array, cfg: ArchConfig, d_ff: Optional[int] = None) -> Dict[str, Array]:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    keys = jax.random.split(key, 3)
    gated = cfg.activation in ("swiglu", "geglu")
    params = {
        "w_in": dense_init(keys[0], d, ff, cfg.param_dtype),
        "w_out": dense_init(keys[1], ff, d, cfg.param_dtype),
    }
    if gated:
        params["w_gate"] = dense_init(keys[2], d, ff, cfg.param_dtype)
    return params


def mlp_apply(params: Dict[str, Array], x: Array, cfg: ArchConfig) -> Array:
    act = activation_fn(cfg.activation)
    h = x @ params["w_in"].astype(cfg.compute_dtype)
    if "w_gate" in params:
        g = x @ params["w_gate"].astype(cfg.compute_dtype)
        h = act(g) * h
    else:
        h = act(h)
    return h @ params["w_out"].astype(cfg.compute_dtype)
