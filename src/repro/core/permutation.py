"""Feature permutation (paper §4.3).

Random feature permutation applied identically to both views every training
step.  Rationale (paper): minimizing R_sum with fixed feature order solves an
under-determined homogeneous system (d-1 equations, d(d-1) unknowns); each
fresh permutation contributes a new set of equations, eventually ruling out
the non-trivial (badly-correlated) solutions.

SPMD notes (beyond the paper, which ran DDP with per-process host RNG):
  * The permutation MUST be identical across data shards when the ``global``
    distributed mode is used — otherwise the psum'd frequency accumulator
    mixes incompatible orderings.  We therefore derive the permutation from a
    step-keyed PRNG (`jax.random.fold_in(seed_key, step)`) that every shard
    computes identically; no communication needed.
  * The permutation is sampled *inside* jit — `jax.random.permutation` on an
    iota is a lowered sort, O(d log d), negligible next to the loss.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def permutation_for_step(key: Array, step: Array | int, d: int) -> Array:
    """Deterministic permutation of [0, d) for a given (key, step)."""
    k = jax.random.fold_in(key, jnp.asarray(step, dtype=jnp.uint32))
    return jax.random.permutation(k, d)


def permute_features(z: Array, perm: Array) -> Array:
    """Apply a feature permutation along the last axis."""
    return jnp.take(z, perm, axis=-1)


def permute_views(
    key: Optional[Array], z1: Array, z2: Optional[Array] = None
) -> Tuple[Array, Optional[Array]]:
    """Sample one permutation and apply it to both views (paper Listing 1).

    ``key=None`` disables permutation (ablation arm).
    """
    if key is None:
        return z1, z2
    d = z1.shape[-1]
    perm = jax.random.permutation(key, d)
    z1p = permute_features(z1, perm)
    z2p = permute_features(z2, perm) if z2 is not None else None
    return z1p, z2p
