"""Full SSL loss functions (paper §3, §4.6).

* ``barlow_twins_loss``   — Eq. (14): invariance-on-the-diagonal + lambda * R
* ``vicreg_loss``         — Eq. (15): alpha*MSE + mu*R_var + nu*R
with R in {R_off (baseline), R_sum, R_sum^(b)} selected by ``DecorrConfig``.

Normalization follows the paper's listings: BT-style standardizes both views
and divides the correlation statistics by n; VICReg-style centers each view
and divides by (n - 1).

The diagonal (invariance) terms never need the d x d matrix:
``C_ii = (1/n) sum_k a_ki b_ki`` is an O(n d) columnwise reduction.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import permutation as perm_lib
from repro.core import regularizers as regs

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DecorrConfig:
    """Selects and parameterizes the decorrelating regularizer.

    style:       'bt' (cross-correlation, Eq. 14) | 'vic' (covariance, Eq. 15)
    reg:         'off' (baseline R_off) | 'sum' (proposed R_sum / R_sum^(b))
    block_size:  None => no grouping (b = d); else b (paper's best: 128)
    q:           1 | 2 (paper Table 11: q=2 for BT-style, q=1 for VICReg-style)
    permute:     feature permutation each step (essential; paper Table 5)
    lam:         BT lambda
    alpha/mu/nu: VICReg coefficients;  gamma: target std
    distributed: 'local' | 'global' | 'tp'  (see core/distributed.py)
    use_kernel:  route the regularizer through the Pallas kernels
    """

    style: str = "bt"
    reg: str = "sum"
    block_size: Optional[int] = None
    q: int = 2
    permute: bool = True
    lam: float = 2.0**-10
    alpha: float = 25.0
    mu: float = 25.0
    nu: float = 1.0
    gamma: float = 1.0
    eps: float = 1e-5
    distributed: str = "local"
    axis_name: Optional[str] = None
    use_kernel: bool = False

    def validate(self) -> "DecorrConfig":
        assert self.style in ("bt", "vic"), self.style
        assert self.reg in ("off", "sum"), self.reg
        assert self.q in (1, 2), self.q
        assert self.distributed in ("local", "global", "tp"), self.distributed
        return self


def standardize(z: Array, eps: float = 1e-5) -> Array:
    """Per-feature zero-mean unit-std over the batch (BT preprocessing)."""
    z = z.astype(jnp.float32)
    mean = jnp.mean(z, axis=0, keepdims=True)
    var = jnp.var(z, axis=0, keepdims=True)
    return (z - mean) / jnp.sqrt(var + eps)


def center(z: Array) -> Array:
    """Per-feature zero-mean over the batch (VICReg preprocessing)."""
    z = z.astype(jnp.float32)
    return z - jnp.mean(z, axis=0, keepdims=True)


# ---------------------------------------------------------------------------
# Regularizer dispatch
# ---------------------------------------------------------------------------


def _psum_if(x: Array, cfg: DecorrConfig) -> Array:
    if cfg.distributed == "global" and cfg.axis_name is not None:
        return jax.lax.psum(x, cfg.axis_name)
    return x


def _decorrelating_term(z1: Array, z2: Array, cfg: DecorrConfig, scale: float) -> Array:
    """R(C) with C = (1/scale) Z1^T Z2 — dispatches baseline / proposed /
    kernel / distributed variants."""
    if cfg.reg == "off":
        if cfg.use_kernel:
            from repro.kernels.xcorr_offdiag import ops as xops

            return xops.off_diagonal_sq_sum(z1, z2, scale=scale)
        c = regs.cross_correlation_matrix(z1, z2, scale=scale)
        return regs.r_off(c)

    # proposed R_sum / R_sum^(b)
    if cfg.distributed == "global" and cfg.axis_name is not None:
        from repro.core import distributed as dist

        return dist.r_sum_global(
            z1, z2, axis_name=cfg.axis_name, q=cfg.q, block_size=cfg.block_size, scale=scale
        )
    if cfg.use_kernel:
        from repro.kernels.grouped_sumvec import ops as gops

        return gops.r_sum_kernel(z1, z2, block_size=cfg.block_size, q=cfg.q, scale=scale)
    return regs.r_sum_auto(z1, z2, q=cfg.q, block_size=cfg.block_size, scale=scale)


# ---------------------------------------------------------------------------
# Barlow Twins-style loss (Eq. 14)
# ---------------------------------------------------------------------------


def barlow_twins_loss(
    z1: Array,
    z2: Array,
    cfg: DecorrConfig,
    perm_key: Optional[Array] = None,
) -> tuple[Array, Dict[str, Array]]:
    """Eq. (14). Returns (loss, metrics). ``z1, z2``: raw (n, d) projections."""
    cfg.validate()
    n = z1.shape[0]
    z1 = standardize(z1, cfg.eps)
    z2 = standardize(z2, cfg.eps)
    if cfg.permute and perm_key is not None and cfg.reg == "sum":
        z1, z2 = perm_lib.permute_views(perm_key, z1, z2)

    # Diagonal (invariance) term: C_ii in O(n d).  With 'global' mode the
    # batch statistics are combined across shards (n -> global n).
    cii_local = jnp.sum(z1 * z2, axis=0)
    cii = _psum_if(cii_local, cfg)
    n_eff = _psum_if(jnp.asarray(n, jnp.float32), cfg)
    cii = cii / n_eff
    invariance = jnp.sum((1.0 - cii) ** 2)

    reg = _decorrelating_term(z1, z2, cfg, scale=float(n))
    if cfg.distributed == "global" and cfg.axis_name is not None and cfg.reg == "off":
        reg = jax.lax.pmean(reg, cfg.axis_name)

    loss = invariance + cfg.lam * reg
    return loss, {
        "bt_invariance": invariance,
        "bt_reg": reg,
        "bt_loss": loss,
    }


# ---------------------------------------------------------------------------
# VICReg-style loss (Eq. 15)
# ---------------------------------------------------------------------------


def vicreg_loss(
    z1: Array,
    z2: Array,
    cfg: DecorrConfig,
    perm_key: Optional[Array] = None,
) -> tuple[Array, Dict[str, Array]]:
    """Eq. (15). Returns (loss, metrics)."""
    cfg.validate()
    n, d = z1.shape
    z1 = z1.astype(jnp.float32)
    z2 = z2.astype(jnp.float32)

    # invariance: before centering (paper Eq. 3 uses raw embeddings)
    inv = jnp.mean(jnp.sum((z1 - z2) ** 2, axis=-1))

    c1 = center(z1)
    c2 = center(z2)
    var1 = regs.r_var_from_embeddings(c1 + 0.0, cfg.gamma)
    var2 = regs.r_var_from_embeddings(c2 + 0.0, cfg.gamma)

    if cfg.permute and perm_key is not None and cfg.reg == "sum":
        c1, c2 = perm_lib.permute_views(perm_key, c1, c2)

    scale = float(max(n - 1, 1))
    reg1 = _decorrelating_term(c1, c1, cfg, scale=scale)
    reg2 = _decorrelating_term(c2, c2, cfg, scale=scale)

    loss = (
        cfg.alpha * inv
        + (cfg.mu / d) * (var1 + var2)
        + (cfg.nu / d) * (reg1 + reg2)
    )
    return loss, {
        "vic_invariance": inv,
        "vic_var": var1 + var2,
        "vic_reg": reg1 + reg2,
        "vic_loss": loss,
    }


def ssl_loss(
    z1: Array,
    z2: Array,
    cfg: DecorrConfig,
    perm_key: Optional[Array] = None,
) -> tuple[Array, Dict[str, Array]]:
    """Dispatch on cfg.style."""
    if cfg.style == "bt":
        return barlow_twins_loss(z1, z2, cfg, perm_key)
    return vicreg_loss(z1, z2, cfg, perm_key)


# ---------------------------------------------------------------------------
# Paper's evaluation metrics (Eq. 16 / 17) — decorrelation quality probes
# ---------------------------------------------------------------------------


def normalized_bt_regularizer(z1: Array, z2: Array, eps: float = 1e-5) -> Array:
    """Eq. (16): R_off(C(A,B)) / (d (d-1)) on standardized views."""
    d = z1.shape[-1]
    c = regs.cross_correlation_matrix(standardize(z1, eps), standardize(z2, eps))
    return regs.r_off(c) / (d * (d - 1))


def normalized_vic_regularizer(z1: Array, z2: Array) -> Array:
    """Eq. (17): (R_off(K(A)) + R_off(K(B))) / (2 d (d-1))."""
    n, d = z1.shape
    k1 = regs.cross_correlation_matrix(center(z1), center(z1), scale=max(n - 1, 1))
    k2 = regs.cross_correlation_matrix(center(z2), center(z2), scale=max(n - 1, 1))
    return (regs.r_off(k1) + regs.r_off(k2)) / (2 * d * (d - 1))
