"""Full SSL loss functions (paper §3, §4.6) — compatibility shim.

* ``barlow_twins_loss``   — Eq. (14): invariance-on-the-diagonal + lambda * R
* ``vicreg_loss``         — Eq. (15): alpha*MSE + mu*R_var + nu*R
with R in {R_off (baseline), R_sum, R_sum^(b)} selected by ``DecorrConfig``.

All routing (normalization moments, permutation, distribution mode, jnp vs
Pallas impl, scale bookkeeping) lives in ``repro.decorr.engine``; this module
only preserves the historical import surface plus the paper's evaluation
metrics (Eq. 16 / 17), which are single-device probes.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import regularizers as regs
from repro.decorr import engine as _engine
from repro.decorr.config import DecorrConfig  # noqa: F401  (compat re-export)

Array = jax.Array


def standardize(z: Array, eps: float = 1e-5) -> Array:
    """Per-feature zero-mean unit-std over the batch (BT preprocessing)."""
    z = z.astype(jnp.float32)
    mean = jnp.mean(z, axis=0, keepdims=True)
    var = jnp.var(z, axis=0, keepdims=True)
    return (z - mean) / jnp.sqrt(var + eps)


def center(z: Array) -> Array:
    """Per-feature zero-mean over the batch (VICReg preprocessing)."""
    z = z.astype(jnp.float32)
    return z - jnp.mean(z, axis=0, keepdims=True)


def barlow_twins_loss(
    z1: Array,
    z2: Array,
    cfg: DecorrConfig,
    perm_key: Optional[Array] = None,
) -> tuple[Array, Dict[str, Array]]:
    """Eq. (14). Returns (loss, metrics). ``z1, z2``: raw (n, d) projections."""
    return _engine.barlow_twins(z1, z2, cfg, perm_key)


def vicreg_loss(
    z1: Array,
    z2: Array,
    cfg: DecorrConfig,
    perm_key: Optional[Array] = None,
) -> tuple[Array, Dict[str, Array]]:
    """Eq. (15). Returns (loss, metrics)."""
    return _engine.vicreg(z1, z2, cfg, perm_key)


def ssl_loss(
    z1: Array,
    z2: Array,
    cfg: DecorrConfig,
    perm_key: Optional[Array] = None,
) -> tuple[Array, Dict[str, Array]]:
    """Dispatch on cfg.style."""
    return _engine.apply(z1, z2, cfg, perm_key)


# ---------------------------------------------------------------------------
# Paper's evaluation metrics (Eq. 16 / 17) — decorrelation quality probes
# ---------------------------------------------------------------------------


def normalized_bt_regularizer(z1: Array, z2: Array, eps: float = 1e-5) -> Array:
    """Eq. (16): R_off(C(A,B)) / (d (d-1)) on standardized views."""
    d = z1.shape[-1]
    c = regs.cross_correlation_matrix(standardize(z1, eps), standardize(z2, eps))
    return regs.r_off(c) / (d * (d - 1))


def normalized_vic_regularizer(z1: Array, z2: Array) -> Array:
    """Eq. (17): (R_off(K(A)) + R_off(K(B))) / (2 d (d-1))."""
    n, d = z1.shape
    k1 = regs.cross_correlation_matrix(center(z1), center(z1), scale=max(n - 1, 1))
    k2 = regs.cross_correlation_matrix(center(z2), center(z2), scale=max(n - 1, 1))
    return (regs.r_off(k1) + regs.r_off(k2)) / (2 * d * (d - 1))
