"""Core library: the paper's contribution (FFT decorrelation) in JAX."""

from repro.core.sumvec import (
    involution,
    circular_convolve,
    circular_correlate_naive,
    sumvec_from_matrix,
    sumvec_fft,
    sumvec_direct,
    frequency_accumulator,
    grouped_frequency_accumulator,
    grouped_sumvec_fft,
    grouped_sumvec_from_matrix,
)
from repro.core.regularizers import (
    r_off,
    r_var,
    r_var_from_embeddings,
    r_sum,
    r_sum_grouped,
    r_sum_auto,
    r_sum_from_sumvec,
    r_sum_from_matrix,
    r_sum_grouped_from_matrix,
    cross_correlation_matrix,
)
from repro.core.losses import (
    DecorrConfig,
    barlow_twins_loss,
    vicreg_loss,
    ssl_loss,
    standardize,
    center,
    normalized_bt_regularizer,
    normalized_vic_regularizer,
)
from repro.core.permutation import permute_views, permutation_for_step, permute_features
from repro.core.decorrelation import LMDecorrConfig, lm_decorrelation_loss, subsample_tokens

__all__ = [k for k in dir() if not k.startswith("_")]
