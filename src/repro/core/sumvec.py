"""Summary-vector (``sumvec``) primitives — the paper's Eq. (5)–(12).

The summary vector of a square matrix ``C`` collects its "wrapped diagonals"::

    [sumvec(C)]_i = sum_j C[j, (i + j) mod d]          (Eq. 5)

Component 0 is the trace; components 1..d-1 partition the off-diagonal
elements (every element of C appears in exactly one component).

The key identity (Eq. 10/12): when ``C = (1/s) * sum_k a_k b_k^T`` the summary
vector equals an average of circular correlations, computable **without
materializing C** via the convolution theorem::

    sumvec(C) = (1/s) * F^-1( sum_k conj(F(a_k)) o F(b_k) )

which is O(n d log d) time and O(n d) space, versus O(n d^2) / O(n d + d^2)
for the matrix route.

All functions are pure-jnp and jit/vjp friendly.  FFT work is done in float32
regardless of input dtype (correlation statistics are long reductions and
bf16 accumulation destroys them); see DESIGN.md §6.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array

# ---------------------------------------------------------------------------
# Basic building blocks (Eq. 5, Eq. 7, involution)
# ---------------------------------------------------------------------------


def involution(x: Array) -> Array:
    """inv(x): reverse components 1..d-1, keep component 0 (paper §4.2).

    ``[inv(x)]_i = [x]_{(d - i) mod d}``. Works on the last axis.
    """
    d = x.shape[-1]
    idx = (-jnp.arange(d)) % d
    return x[..., idx]


def circular_convolve(x: Array, y: Array) -> Array:
    """Circular convolution x * y along the last axis (Eq. 7). O(d^2) naive."""
    d = x.shape[-1]
    i = jnp.arange(d)[:, None]
    j = jnp.arange(d)[None, :]
    # [x * y]_i = sum_j x_j y_{(i-j) mod d}
    gather = (i - j) % d
    return jnp.einsum("...j,...ij->...i", x, y[..., gather])


def circular_correlate_naive(x: Array, y: Array) -> Array:
    """inv(x) * y along last axis via the direct O(d^2) sum (Appendix A).

    ``[inv(x) * y]_i = sum_j x_j y_{(i+j) mod d}``.
    """
    d = x.shape[-1]
    i = jnp.arange(d)[:, None]
    j = jnp.arange(d)[None, :]
    gather = (i + j) % d
    return jnp.einsum("...j,...ij->...i", x, y[..., gather])


def sumvec_from_matrix(c: Array) -> Array:
    """Eq. (5): summary vector of a square matrix. O(d^2); reference path."""
    d = c.shape[-1]
    i = jnp.arange(d)[:, None]  # output component
    j = jnp.arange(d)[None, :]  # row index
    cols = (i + j) % d  # shape (d, d): column gathered for (i, j)
    # sumvec[i] = sum_j C[j, cols[i, j]]
    return jnp.sum(c[..., j, cols], axis=-1)


# ---------------------------------------------------------------------------
# FFT path (Eq. 12) — the paper's contribution
# ---------------------------------------------------------------------------


def frequency_accumulator(z1: Array, z2: Array, *, precision_dtype=jnp.float32) -> Array:
    """``G = sum_k conj(F(z1_k)) o F(z2_k)`` — rfft bins, complex64.

    ``z1, z2``: (n, d). Returns (d//2 + 1,) complex. This is the only
    batch-dependent work in the FFT path; everything downstream is O(d).
    In the distributed ``global`` mode this accumulator is what gets psum'd
    (see core/distributed.py).
    """
    z1 = z1.astype(precision_dtype)
    z2 = z2.astype(precision_dtype)
    f1 = jnp.fft.rfft(z1, axis=-1)
    f2 = jnp.fft.rfft(z2, axis=-1)
    return jnp.sum(jnp.conj(f1) * f2, axis=0)


def sumvec_fft(z1: Array, z2: Array, *, scale: Optional[float] = None) -> Array:
    """Eq. (12): sumvec of the (scaled) sum of outer products, via FFT.

    ``z1, z2``: (n, d) — row k holds a^(k) resp. b^(k).
    ``scale``: divisor ``s`` in ``C = (1/s) sum_k a_k b_k^T``; defaults to 1
    (caller applies its own normalization, e.g. n for BT, n-1 for VICReg).
    Returns the d-vector sumvec(C) in float32.
    """
    d = z1.shape[-1]
    g = frequency_accumulator(z1, z2)
    sv = jnp.fft.irfft(g, n=d, axis=-1)
    if scale is not None:
        sv = sv / scale
    return sv


def sumvec_direct(z1: Array, z2: Array, *, scale: Optional[float] = None) -> Array:
    """Eq. (10): sumvec via per-sample circular correlation. O(n d^2) oracle."""
    cc = circular_correlate_naive(z1.astype(jnp.float32), z2.astype(jnp.float32))
    sv = jnp.sum(cc, axis=0)
    if scale is not None:
        sv = sv / scale
    return sv


# ---------------------------------------------------------------------------
# Grouped (block) path — paper §4.4
# ---------------------------------------------------------------------------


def pad_to_blocks(z: Array, block_size: int) -> Array:
    """Pad trailing feature dim with zeros to a multiple of ``block_size``.

    Paper §4.4 footnote: "pad dummy features that are constantly 0 in the
    last group".  Padding is applied AFTER standardization/centering so the
    dummy features contribute exactly zero to every correlation.
    """
    d = z.shape[-1]
    rem = (-d) % block_size
    if rem == 0:
        return z
    pad = [(0, 0)] * (z.ndim - 1) + [(0, rem)]
    return jnp.pad(z, pad)


def blockify(z: Array, block_size: int) -> Array:
    """(n, d) -> (n, d/b, b) after zero padding."""
    z = pad_to_blocks(z, block_size)
    n = z.shape[0]
    return z.reshape(n, -1, block_size)


def grouped_frequency_accumulator(
    z1: Array, z2: Array, block_size: int, *, precision_dtype=jnp.float32
) -> Array:
    """``G[i, j, f] = sum_k conj(F(a_k,i))[f] * F(b_k,j)[f]`` for all block
    pairs (i, j).

    ``z1, z2``: (n, d). Returns (nb, nb, b//2+1) complex64 where
    nb = ceil(d / b).  Cost: O(n d log b) for the FFTs + O(n (d/b)^2 b) for
    the pairwise products — the paper's O((n d^2 / b) log b) with the log
    factor moved into an MXU-friendly batched contraction over n (this einsum
    is a batch of (nb x n) @ (n x nb) complex matmuls, one per frequency bin;
    the Pallas kernel in kernels/grouped_sumvec tiles exactly this).
    """
    b1 = blockify(z1.astype(precision_dtype), block_size)
    b2 = blockify(z2.astype(precision_dtype), block_size)
    f1 = jnp.fft.rfft(b1, axis=-1)  # (n, nb, nf)
    f2 = jnp.fft.rfft(b2, axis=-1)
    return jnp.einsum("kif,kjf->ijf", jnp.conj(f1), f2)


def grouped_sumvec_fft(
    z1: Array, z2: Array, block_size: int, *, scale: Optional[float] = None
) -> Array:
    """sumvec(C_ij) for every b x b block of C. Returns (nb, nb, b)."""
    g = grouped_frequency_accumulator(z1, z2, block_size)
    sv = jnp.fft.irfft(g, n=block_size, axis=-1)
    if scale is not None:
        sv = sv / scale
    return sv


def grouped_sumvec_from_matrix(c: Array, block_size: int) -> Array:
    """Oracle: blockify a full matrix C and sumvec each block. (nb, nb, b)."""
    d = c.shape[-1]
    rem = (-d) % block_size
    if rem:
        c = jnp.pad(c, ((0, rem), (0, rem)))
    nb = c.shape[-1] // block_size
    blocks = c.reshape(nb, block_size, nb, block_size).transpose(0, 2, 1, 3)
    return jax.vmap(jax.vmap(sumvec_from_matrix))(blocks)


# ---------------------------------------------------------------------------
# Parseval shortcuts (beyond-paper; DESIGN.md §3.3)
# ---------------------------------------------------------------------------


def rfft_parseval_weights(d: int) -> jax.Array:
    """w_f such that sum_t s[t]^2 = (1/d) sum_f w_f |S_rfft[f]|^2."""
    nf = d // 2 + 1
    w = jnp.full((nf,), 2.0, dtype=jnp.float32)
    w = w.at[0].set(1.0)
    if d % 2 == 0:
        w = w.at[-1].set(1.0)
    return w


def sq_sum_and_zeroth_from_freq(g: Array, d: int) -> tuple[Array, Array]:
    """Given rfft-domain G (last axis = bins) of a real signal s of length d,
    return (sum_t s[t]^2, s[0]) computed WITHOUT an inverse transform.

    sum_t s[t]^2 = (1/d) sum_f w_f |G_f|^2           (Parseval)
    s[0]         = (1/d) sum_f w_f Re(G_f)           (DC synthesis)
    """
    w = rfft_parseval_weights(d)
    sq = jnp.sum(w * (g.real**2 + g.imag**2), axis=-1) / d
    s0 = jnp.sum(w * g.real, axis=-1) / d
    return sq, s0
