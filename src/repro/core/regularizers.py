"""Decorrelating regularizers.

Baselines (paper §3):
  * ``r_off``  — Barlow Twins / VICReg off-diagonal penalty, Eq. (2).  O(n d^2).
  * ``r_var``  — VICReg variance hinge, Eq. (4).  O(n d).

Proposed (paper §4):
  * ``r_sum``          — Eq. (6), FFT path, O(n d log d).
  * ``r_sum_grouped``  — Eq. (13), block size b, O((n d^2 / b) log b).

Both proposed regularizers take the *embeddings* (already standardized or
centered by the caller), never a materialized correlation matrix.  For q=2
the sums of squares are evaluated directly in the frequency domain via
Parseval (beyond-paper; skips the inverse FFT — see DESIGN.md §3.3); for q=1
the inverse transform is required because the l1 norm is not a frequency-
domain quantity.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import sumvec as sv
from repro.tune import dispatch as tune_dispatch

Array = jax.Array


# ---------------------------------------------------------------------------
# Baseline regularizers (matrix route)
# ---------------------------------------------------------------------------


def r_off(m: Array) -> Array:
    """Eq. (2): sum of squared off-diagonal elements."""
    total = jnp.sum(m.astype(jnp.float32) ** 2)
    diag = jnp.sum(jnp.diagonal(m).astype(jnp.float32) ** 2)
    return total - diag


def r_var(m: Array, gamma: float = 1.0, eps: float = 1e-4) -> Array:
    """Eq. (4): hinge on per-feature standard deviation (diagonal of K)."""
    std = jnp.sqrt(jnp.clip(jnp.diagonal(m).astype(jnp.float32), 0.0) + eps)
    return jnp.sum(jnp.maximum(0.0, gamma - std))


def r_var_from_embeddings(z: Array, gamma: float = 1.0, eps: float = 1e-4) -> Array:
    """Variance hinge straight from (n, d) embeddings — O(n d)."""
    var = jnp.var(z.astype(jnp.float32), axis=0, ddof=1)
    std = jnp.sqrt(var + eps)
    return jnp.sum(jnp.maximum(0.0, gamma - std))


def cross_correlation_matrix(z1: Array, z2: Array, scale: Optional[float] = None) -> Array:
    """C = (1/scale) Z1^T Z2 — caller standardizes/centers first. O(n d^2)."""
    n = z1.shape[0]
    c = z1.astype(jnp.float32).T @ z2.astype(jnp.float32)
    return c / (n if scale is None else scale)


# ---------------------------------------------------------------------------
# Proposed regularizers (paper Eq. 6 / Eq. 13)
# ---------------------------------------------------------------------------


def _resolve_impl(op: str, q: int, impl: Optional[str]) -> str:
    """Shared q/impl validation + backend routing for r_sum / r_sum_grouped."""
    if q not in (1, 2):
        raise ValueError(f"q must be 1 or 2, got {q!r}")
    if impl is None:
        impl = tune_dispatch.best_impl(op)
    if impl not in ("jnp", "pallas"):
        raise ValueError(f"impl must be 'jnp' or 'pallas', got {impl!r}")
    return impl


def r_sum_from_sumvec(svec: Array, q: int) -> Array:
    """Eq. (6) given a precomputed summary vector (drops component 0)."""
    tail = svec[..., 1:]
    if q == 1:
        return jnp.sum(jnp.abs(tail))
    return jnp.sum(tail**2)


def r_sum(
    z1: Array,
    z2: Array,
    *,
    q: int = 2,
    scale: Optional[float] = None,
    impl: Optional[str] = None,
) -> Array:
    """Eq. (6) computed via FFT directly from embeddings.

    ``z1, z2`` : (n, d) standardized (BT-style) or centered (VICReg-style,
    with z1 is z2) views. ``scale``: normalizer s of C (n or n-1).
    ``impl``: None consults ``repro.tune`` (jnp FFT off-TPU, Pallas four-step
    on TPU); "jnp" / "pallas" pin the route.
    """
    d = z1.shape[-1]
    s = 1.0 if scale is None else float(scale)
    impl = _resolve_impl("r_sum", q, impl)
    if impl == "pallas":
        from repro.kernels.sumvec_fft import ops as fops

        return fops.r_sum_fourstep(z1, z2, q=q, scale=s)
    if q == 2:
        # Parseval path — no inverse FFT (beyond-paper optimization).
        g = sv.frequency_accumulator(z1, z2) / s
        sq, s0 = sv.sq_sum_and_zeroth_from_freq(g, d)
        return sq - s0**2
    svec = sv.sumvec_fft(z1, z2, scale=s)
    return r_sum_from_sumvec(svec, q)


def r_sum_grouped(
    z1: Array,
    z2: Array,
    block_size: int,
    *,
    q: int = 2,
    scale: Optional[float] = None,
    impl: Optional[str] = None,
) -> Array:
    """Eq. (13): grouped summary regularizer with block size b.

    Diagonal blocks drop their component 0 (the trace entries of C);
    off-diagonal blocks keep all b components (they contain only
    off-diagonal elements of C).  ``impl`` as in :func:`r_sum`.
    """
    b = int(block_size)
    s = 1.0 if scale is None else float(scale)
    impl = _resolve_impl("r_sum_grouped", q, impl)
    # b > d means "pad d up to b" here (matching the matrix oracle), but the
    # Pallas kernel clamps b to d — route the degenerate case through jnp on
    # every backend so the loss value never depends on hardware.
    if impl == "pallas" and b <= z1.shape[-1]:
        from repro.kernels.grouped_sumvec import ops as gops

        return gops.r_sum_kernel(z1, z2, block_size=b, q=q, scale=s)
    g = sv.grouped_frequency_accumulator(z1, z2, b) / s  # (nb, nb, nf)
    nb = g.shape[0]
    eye = jnp.eye(nb, dtype=jnp.float32)
    if q == 2:
        sq, s0 = sv.sq_sum_and_zeroth_from_freq(g, b)  # (nb, nb) each
        # all blocks: full Parseval energy; diagonal blocks: subtract s0^2.
        return jnp.sum(sq) - jnp.sum(eye * s0**2)
    svec = jnp.fft.irfft(g, n=b, axis=-1)  # (nb, nb, b)
    full = jnp.sum(jnp.abs(svec), axis=-1)  # includes component 0
    zeroth = jnp.abs(svec[..., 0])
    return jnp.sum(full) - jnp.sum(eye * zeroth)


def r_sum_auto(
    z1: Array,
    z2: Array,
    *,
    q: int = 2,
    block_size: Optional[int] = None,
    scale: Optional[float] = None,
    impl: Optional[str] = None,
) -> Array:
    """Dispatch between grouped / ungrouped forms (b = None or b >= d ==> Eq. 6).

    ``impl`` forwards to :func:`r_sum` / :func:`r_sum_grouped` (None consults
    ``repro.tune``); the degenerate b <= 1 matrix route ignores it.
    """
    d = z1.shape[-1]
    if block_size is None or block_size >= d:
        return r_sum(z1, z2, q=q, scale=scale, impl=impl)
    if block_size <= 1:
        # R_sum^(1) with q=2 is exactly R_off (paper §4.4); compute the
        # matrix route for fidelity at this degenerate setting.
        c = cross_correlation_matrix(z1, z2, scale=scale)
        if q == 2:
            return r_off(c)
        off = jnp.sum(jnp.abs(c)) - jnp.sum(jnp.abs(jnp.diagonal(c)))
        return off
    return r_sum_grouped(z1, z2, block_size, q=q, scale=scale, impl=impl)


# ---------------------------------------------------------------------------
# Oracle forms (used by tests/benchmarks only)
# ---------------------------------------------------------------------------


def r_sum_from_matrix(c: Array, q: int = 2) -> Array:
    """Eq. (6) by explicitly building sumvec(C) from the matrix."""
    return r_sum_from_sumvec(sv.sumvec_from_matrix(c), q)


def r_sum_grouped_from_matrix(c: Array, block_size: int, q: int = 2) -> Array:
    """Eq. (13) from an explicit matrix (oracle)."""
    blocks = sv.grouped_sumvec_from_matrix(c, block_size)  # (nb, nb, b)
    nb = blocks.shape[0]
    if q == 1:
        vals = jnp.abs(blocks)
    else:
        vals = blocks**2
    full = jnp.sum(vals, axis=-1)
    zeroth = vals[..., 0]
    eye = jnp.eye(nb, dtype=vals.dtype)
    return jnp.sum(full) - jnp.sum(eye * zeroth)
