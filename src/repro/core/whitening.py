"""Whitening-based decorrelation baseline (paper §2, W-MSE / Zero-CL
family): explicitly whiten features with an inverse covariance square root
instead of regularizing.

Included for baseline completeness: the paper's complexity argument is that
whitening needs the full eigendecomposition of a d x d covariance —
O(min(d n^2, n d^2)) per step plus an O(d^3) eigh — which is exactly what
R_sum avoids.  We implement ZCA whitening with a Newton–Schulz iteration
(matmul-only inverse square root — TPU-friendly, no eigh) and the W-MSE
style loss, so benchmarks can quote the whitening cost next to R_off/R_sum.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def newton_schulz_inv_sqrt(mat: Array, iters: int = 7, eps: float = 1e-5) -> Array:
    """Matmul-only inverse matrix square root of an SPD matrix.

    Coupled Newton-Schulz: Y_{k+1} = 0.5 Y_k (3I - Z_k Y_k),
    Z_{k+1} = 0.5 (3I - Z_k Y_k) Z_k with Y_0 = A/||A||, Z_0 = I converges to
    Y -> A^{1/2}/sqrt(||A||), Z -> A^{-1/2} sqrt(||A||).
    """
    d = mat.shape[-1]
    ident = jnp.eye(d, dtype=jnp.float32)
    a = mat.astype(jnp.float32) + eps * ident
    norm = jnp.linalg.norm(a)
    y = a / norm
    z = ident

    def body(_, yz):
        y, z = yz
        t = 0.5 * (3.0 * ident - z @ y)
        return y @ t, t @ z

    y, z = jax.lax.fori_loop(0, iters, body, (y, z))
    return z / jnp.sqrt(norm)


def zca_whiten(z: Array, eps: float = 1e-5, iters: int = 7) -> Array:
    """Whiten (n, d) embeddings: output has (approximately) identity
    covariance.  O(n d^2 + d^3-via-matmuls) — the cost the paper's O(nd log d)
    regularizer avoids."""
    n, d = z.shape
    zc = z.astype(jnp.float32) - jnp.mean(z, axis=0, keepdims=True)
    cov = (zc.T @ zc) / max(n - 1, 1)
    w = newton_schulz_inv_sqrt(cov, iters=iters, eps=eps)
    return zc @ w


def wmse_loss(z1: Array, z2: Array, eps: float = 1e-5) -> Tuple[Array, dict]:
    """W-MSE-style loss: whiten each view, then align (MSE on normalized
    whitened embeddings)."""
    w1 = zca_whiten(z1, eps)
    w2 = zca_whiten(z2, eps)
    w1 = w1 / (jnp.linalg.norm(w1, axis=-1, keepdims=True) + 1e-9)
    w2 = w2 / (jnp.linalg.norm(w2, axis=-1, keepdims=True) + 1e-9)
    loss = jnp.mean(jnp.sum((w1 - w2) ** 2, axis=-1))
    return loss, {"wmse_loss": loss}
