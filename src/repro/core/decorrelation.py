"""Decorrelation as a first-class training feature for the LM architectures.

The paper's regularizer is feature-space, not architecture-space, so the
framework attaches it to any model as an *auxiliary loss* on hidden states
(DESIGN.md §5): VICReg-style covariance regularization (single view — no
augmentation pair needed for LMs) on a strided subsample of final hidden
states.

    L = L_ce + mu/d * R_var(K(H)) + nu/d * R(K(H))

with R = R_sum / R_sum^(b) via FFT — O(n d log d) on top of a 6 N D training
step, invisible in the roofline (quantified in EXPERIMENTS.md).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import losses as losses_lib
from repro.decorr import engine as decorr_engine

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class LMDecorrConfig:
    """Auxiliary decorrelation on LM hidden states.

    enabled:        off by default; archs opt in via their config.
    tokens_per_seq: subsample stride target — caps the statistic's batch at
                    batch * tokens_per_seq rows (keeps the loss O(n d log d)
                    with a bounded n even at seq 32k).
    """

    enabled: bool = False
    decorr: losses_lib.DecorrConfig = dataclasses.field(
        default_factory=lambda: losses_lib.DecorrConfig(style="vic", reg="sum")
    )
    tokens_per_seq: int = 8
    mu: float = 1.0
    nu: float = 0.04

    def validate(self) -> "LMDecorrConfig":
        self.decorr.validate()
        assert self.tokens_per_seq >= 1
        return self


def subsample_tokens(h: Array, tokens_per_seq: int) -> Array:
    """(B, S, D) -> (B * min(S, tokens_per_seq), D), strided & static."""
    b, s, d = h.shape
    take = min(s, tokens_per_seq)
    stride = max(1, s // take)
    sub = h[:, :: stride, :][:, :take, :]
    return sub.reshape(b * take, d)


def lm_decorrelation_loss(
    hidden: Array,
    cfg: LMDecorrConfig,
    perm_key: Optional[Array] = None,
) -> tuple[Array, Dict[str, Array]]:
    """Covariance decorrelation aux loss on hidden states (single view).

    ``hidden``: (B, S, D) final hidden states (pre-LM-head).
    Returns (aux_loss, metrics); aux_loss == 0 when disabled.
    """
    cfg.validate()
    if not cfg.enabled:
        zero = jnp.asarray(0.0, jnp.float32)
        return zero, {"decorr_aux": zero}

    z = subsample_tokens(hidden, cfg.tokens_per_seq)
    n, d = z.shape
    mode = decorr_engine.effective_mode(cfg.decorr)
    zc = decorr_engine.center(z, cfg.decorr, mode)

    var = decorr_engine.variance_hinge(z, cfg.decorr, mode)

    # The engine owns permutation, mode and impl routing; ddof=1 makes the
    # 'global' mode normalize by the exact effective-batch n - 1, matching
    # the variance hinge above.
    scale = float(max(n - 1, 1))
    reg = decorr_engine.regularizer(zc, zc, cfg.decorr, scale, perm_key=perm_key, ddof=1)

    aux = (cfg.mu / d) * var + (cfg.nu / d) * reg
    return aux, {
        "decorr_aux": aux,
        "decorr_var": var,
        "decorr_reg": reg,
    }
