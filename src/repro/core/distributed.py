"""Distributed decorrelation — compatibility shim.

The mode primitives moved to ``repro.decorr.modes`` when the decorrelation
engine (``repro.decorr``) consolidated mode/impl/normalization routing into
one dispatch layer; import from there in new code.  This module re-exports
the historical surface so existing call sites keep working.
"""

from __future__ import annotations

from repro.decorr.modes import (  # noqa: F401
    all_to_all_features,
    frequency_accumulator,
    grouped_reg_from_freq,
    psum_if,
    r_off_global,
    r_sum_from_psummed,
    r_sum_global,
    r_sum_single_device,
    r_sum_tp,
    reg_from_freq,
)

# Historical private names, kept for any external pin.
_reg_from_freq = reg_from_freq
_grouped_reg_from_freq = grouped_reg_from_freq

__all__ = [
    "all_to_all_features",
    "frequency_accumulator",
    "grouped_reg_from_freq",
    "psum_if",
    "r_off_global",
    "r_sum_from_psummed",
    "r_sum_global",
    "r_sum_single_device",
    "r_sum_tp",
    "reg_from_freq",
]
