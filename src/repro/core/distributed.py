"""Distributed decorrelation (DESIGN.md §4).

Three modes for computing R_sum under SPMD:

``local``  (paper-faithful): every data shard computes the regularizer on its
    local batch slice; cross-device traffic is only the usual gradient
    all-reduce.  This reproduces the paper's DDP implementation, which states
    "we do not conduct collective operations" in the loss.

``global`` (beyond-paper): the frequency accumulator
    ``G = sum_k conj(F a_k) o F b_k`` is an *additive* statistic of the batch,
    so a single psum of d complex numbers (64 KiB at d = 8192) turns the
    local regularizer into the exact global-batch regularizer.  The paper's
    DDP run cannot see cross-shard correlations; this mode can, for free.

``tp``     (feature-sharded): when the projector output dimension d itself is
    tensor-parallel over the ``model`` axis, the FFT spans shards.  We
    transpose batch<->feature with one all_to_all (each of the P model shards
    ends up with n/P full-length feature vectors), run shard-local FFTs, and
    psum the accumulator.  Communication: n*d/P elements per shard instead of
    an all-gather's n*d.

All functions here are meant to be called inside ``shard_map`` (or jit with
explicit axis names via ``jax.lax`` collectives).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import sumvec as sv

Array = jax.Array


def _axis_size(axis_name) -> Array:
    return jax.lax.psum(jnp.asarray(1.0, jnp.float32), axis_name)


def _reg_from_freq(g: Array, d: int, q: int) -> Array:
    """R_sum from an (already normalized) frequency accumulator."""
    if q == 2:
        sq, s0 = sv.sq_sum_and_zeroth_from_freq(g, d)
        return sq - s0**2
    svec = jnp.fft.irfft(g, n=d, axis=-1)
    return jnp.sum(jnp.abs(svec[..., 1:]))


def _grouped_reg_from_freq(g: Array, b: int, q: int) -> Array:
    nb = g.shape[0]
    eye = jnp.eye(nb, dtype=jnp.float32)
    if q == 2:
        sq, s0 = sv.sq_sum_and_zeroth_from_freq(g, b)
        return jnp.sum(sq) - jnp.sum(eye * s0**2)
    svec = jnp.fft.irfft(g, n=b, axis=-1)
    full = jnp.sum(jnp.abs(svec), axis=-1)
    return jnp.sum(full) - jnp.sum(eye * jnp.abs(svec[..., 0]))


def r_sum_global(
    z1: Array,
    z2: Array,
    *,
    axis_name,
    q: int = 2,
    block_size: Optional[int] = None,
    scale: Optional[float] = None,
) -> Array:
    """Exact global-batch R_sum with one psum of the frequency accumulator.

    ``z1, z2``: the *local* (n_local, d) shard of the standardized/centered
    views.  ``scale``: the *local* normalizer (n_local or n_local - 1); it is
    multiplied by the axis size so the result matches a single-device run on
    the concatenated batch.
    """
    d = z1.shape[-1]
    p = _axis_size(axis_name)
    s = (1.0 if scale is None else float(scale)) * p
    if block_size is None or block_size >= d:
        g = sv.frequency_accumulator(z1, z2)
        g = jax.lax.psum(g, axis_name) / s.astype(g.dtype)
        return _reg_from_freq(g, d, q)
    g = sv.grouped_frequency_accumulator(z1, z2, block_size)
    g = jax.lax.psum(g, axis_name) / s.astype(g.dtype)
    return _grouped_reg_from_freq(g, int(block_size), q)


def r_sum_tp(
    z1: Array,
    z2: Array,
    *,
    model_axis,
    batch_axis=None,
    q: int = 2,
    block_size: Optional[int] = None,
    scale: Optional[float] = None,
) -> Array:
    """R_sum when the feature dim is sharded over ``model_axis``.

    Inside shard_map each shard holds (n, d_local) with d = P * d_local and
    features laid out contiguously by shard index.  One tiled all_to_all
    converts to (n / P, d) full-feature rows, then the computation proceeds
    as in ``global`` mode with the accumulator psum'd over the model axis
    (batch chunks) and, if given, the batch axis (data parallel shards).
    """
    n = z1.shape[0]
    p = jax.lax.psum(1, model_axis)  # static int under shard_map

    def to_full_features(z):
        # (n, d_local) -> (n/P, d): split batch, exchange, concat features.
        return jax.lax.all_to_all(z, model_axis, split_axis=0, concat_axis=1, tiled=True)

    z1f = to_full_features(z1.astype(jnp.float32))
    z2f = to_full_features(z2.astype(jnp.float32))
    d = z1f.shape[-1]

    if block_size is None or block_size >= d:
        g = sv.frequency_accumulator(z1f, z2f)
    else:
        g = sv.grouped_frequency_accumulator(z1f, z2f, block_size)

    g = jax.lax.psum(g, model_axis)
    s = 1.0 if scale is None else float(scale)
    if batch_axis is not None:
        g = jax.lax.psum(g, batch_axis)
        s = s * jax.lax.psum(1, batch_axis)
    g = g / jnp.asarray(s, g.dtype)

    if block_size is None or block_size >= d:
        return _reg_from_freq(g, d, q)
    return _grouped_reg_from_freq(g, int(block_size), q)


# ---------------------------------------------------------------------------
# Reference: what a single device computes on the concatenated global batch.
# Used by tests to check the distributed modes bit-for-bit (up to fp assoc).
# ---------------------------------------------------------------------------


def r_sum_single_device(z1, z2, *, q=2, block_size=None, scale=None):
    from repro.core import regularizers as regs

    return regs.r_sum_auto(z1, z2, q=q, block_size=block_size, scale=scale)
