"""Four-step-FFT sumvec: jit'd wrappers over the kernels.

Layout discipline (see kernel.py docstring): with t = t1*d2 + t2 and
f = k1 + d1*k2,

  x (n, d) -> (n, d1, d2)                                  [t1, t2]
  step 1: contract t1 with W_{d1}  -> (n, d2, d1)          [t2, k1]
  step 2: twiddle W_d^{t2 k1}      -> (n, d2, d1)          [t2, k1]
  step 3: contract t2 with W_{d2}  -> (n, d1, d2)          [k1, k2]

The frequency accumulator G = sum_k conj(F1_k) o F2_k is computed in the
[k1, k2] layout; for q = 2 the regularizer only needs full-spectrum sums
(Parseval), which are layout-invariant, so no unscramble transpose is ever
materialized.  For q = 1 an inverse four-step produces the time-domain
summary vector.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.pallas_utils import full_dft_matrices
from repro.kernels.sumvec_fft import kernel as K

Array = jax.Array


def choose_factors(d: int) -> tuple[int, int]:
    """d = d1 * d2 with d1 <= d2, d1 as close to sqrt(d) as possible."""
    best = (1, d)
    for d1 in range(1, int(np.sqrt(d)) + 1):
        if d % d1 == 0:
            best = (d1, d // d1)
    return best


def _twiddle(d1: int, d2: int, sign: int) -> tuple[Array, Array]:
    """W_d^{sign * t2 * k1} flattened to (d2 * d1,) in [t2, k1] order."""
    d = d1 * d2
    t2 = np.arange(d2)[:, None]
    k1 = np.arange(d1)[None, :]
    ang = 2.0 * np.pi * t2 * k1 / d * sign
    return (
        jnp.asarray(np.cos(ang).reshape(-1), jnp.float32),
        jnp.asarray(np.sin(ang).reshape(-1), jnp.float32),
    )


def four_step_fft(x: Array, d1: int, d2: int) -> tuple[Array, Array]:
    """Full complex DFT of real rows x (n, d). Returns (n, d1, d2) pair in
    [k1, k2] layout (f = k1 + d1*k2)."""
    n, d = x.shape
    assert d == d1 * d2, (d, d1, d2)
    w1r, w1i = full_dft_matrices(d1, sign=-1)
    w2r, w2i = full_dft_matrices(d2, sign=-1)
    twr, twi = _twiddle(d1, d2, sign=-1)

    xt = x.reshape(n, d1, d2).transpose(0, 2, 1).reshape(n * d2, d1)  # [t2, t1]
    s1r, s1i = K.rmatmul_complex_basis(xt.astype(jnp.float32), w1r, w1i)  # [t2, k1]
    s2r, s2i = K.ctwiddle(s1r.reshape(n, d2 * d1), s1i.reshape(n, d2 * d1), twr, twi)
    s2r = s2r.reshape(n, d2, d1).transpose(0, 2, 1).reshape(n * d1, d2)  # [k1, t2]
    s2i = s2i.reshape(n, d2, d1).transpose(0, 2, 1).reshape(n * d1, d2)
    s3r, s3i = K.cmatmul(s2r, s2i, w2r, w2i)  # contract t2 -> [k1, k2]
    return s3r.reshape(n, d1, d2), s3i.reshape(n, d1, d2)


def four_step_ifft(gr: Array, gi: Array, d1: int, d2: int) -> Array:
    """Inverse DFT of (..., d1, d2) [k1, k2]-layout spectrum; returns the
    real part in natural time order (..., d) (imag is ~0 for our G)."""
    lead = gr.shape[:-2]
    n = int(np.prod(lead)) if lead else 1
    d = d1 * d2
    w1r, w1i = full_dft_matrices(d1, sign=+1)
    w2r, w2i = full_dft_matrices(d2, sign=+1)
    twr, twi = _twiddle(d1, d2, sign=+1)

    g2r = gr.reshape(n * d1, d2)
    g2i = gi.reshape(n * d1, d2)
    s1r, s1i = K.cmatmul(g2r, g2i, w2r, w2i)  # contract k2 -> [k1, t2]
    s1r = s1r.reshape(n, d1, d2).transpose(0, 2, 1).reshape(n, d2 * d1)  # [t2, k1]
    s1i = s1i.reshape(n, d1, d2).transpose(0, 2, 1).reshape(n, d2 * d1)
    s2r, s2i = K.ctwiddle(s1r, s1i, twr, twi)
    s2r = s2r.reshape(n * d2, d1)
    s2i = s2i.reshape(n * d2, d1)
    s3r, _ = K.cmatmul(s2r, s2i, w1r, w1i)  # contract k1 -> [t2, t1]
    out = s3r.reshape(n, d2, d1).transpose(0, 2, 1).reshape(*lead, d) / d
    return out


def frequency_accumulator_fourstep(z1: Array, z2: Array, d1: int, d2: int):
    """G = sum_k conj(F z1_k) o (F z2_k), (d1, d2) [k1,k2] layout pair."""
    f1r, f1i = four_step_fft(z1, d1, d2)
    f2r, f2i = four_step_fft(z2, d1, d2)
    gr = jnp.sum(f1r * f2r + f1i * f2i, axis=0)
    gi = jnp.sum(f1r * f2i - f1i * f2r, axis=0)
    return gr, gi


@functools.partial(jax.jit, static_argnames=("q", "scale"))
def r_sum_fourstep(
    z1: Array, z2: Array, *, q: int = 2, scale: Optional[float] = None
) -> Array:
    """Ungrouped Eq. (6) through the four-step Pallas pipeline."""
    n, d = z1.shape
    d1, d2 = choose_factors(d)
    s = 1.0 if scale is None else float(scale)
    gr, gi = frequency_accumulator_fourstep(
        z1.astype(jnp.float32), z2.astype(jnp.float32), d1, d2
    )
    gr, gi = gr / s, gi / s
    if q == 2:
        # Full-spectrum Parseval: sum_t sv[t]^2 = (1/d) sum_f |G_f|^2,
        # sv[0] = (1/d) sum_f Re G_f — layout invariant.
        sq = jnp.sum(gr**2 + gi**2) / d
        s0 = jnp.sum(gr) / d
        return sq - s0**2
    sv = four_step_ifft(gr, gi, d1, d2)  # (1?, d) natural order
    sv = sv.reshape(d)
    return jnp.sum(jnp.abs(sv[1:]))


def sumvec_fourstep(z1: Array, z2: Array, scale: Optional[float] = None) -> Array:
    """Time-domain sumvec via four-step fwd+inv (kernel analogue of Eq. 12)."""
    n, d = z1.shape
    d1, d2 = choose_factors(d)
    gr, gi = frequency_accumulator_fourstep(
        z1.astype(jnp.float32), z2.astype(jnp.float32), d1, d2
    )
    sv = four_step_ifft(gr, gi, d1, d2).reshape(d)
    if scale is not None:
        sv = sv / scale
    return sv
