"""Four-step-FFT sumvec: jit'd wrappers over the kernels.

Layout discipline (see kernel.py docstring): with t = t1*d2 + t2 and
f = k1 + d1*k2,

  x (n, d) -> (n, d1, d2)                                  [t1, t2]
  step 1: contract t1 with W_{d1}  -> (n, d2, d1)          [t2, k1]
  step 2: twiddle W_d^{t2 k1}      -> (n, d2, d1)          [t2, k1]
  step 3: contract t2 with W_{d2}  -> (n, d1, d2)          [k1, k2]

The frequency accumulator G = sum_k conj(F1_k) o F2_k is computed in the
[k1, k2] layout; for q = 2 the regularizer only needs full-spectrum sums
(Parseval), which are layout-invariant, so no unscramble transpose is ever
materialized.  For q = 1 an inverse four-step produces the time-domain
summary vector.

Factorization plans come from ``repro.tune`` (kernel ``sumvec_fft_plan``).
For prime / near-prime d the balanced factorization degenerates toward
(1, d) — a full O(d^2) DFT with a d x d basis.  The tuned fallback zero-pads
the feature axis to a highly composite dp >= 2d - 1: at that length the
circular correlation of the padded rows equals the *linear* correlation (no
wraparound), and the length-d circular summary vector is recovered exactly by
folding lag -(d-t) onto lag t (``_fold_linear_to_circular``).  Padding is
therefore semantics-preserving — unlike naive padding to an arbitrary dp,
which would regroup the wrapped diagonals *before* the nonlinearity.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.pallas_utils import full_dft_matrices, pad_axis
from repro.kernels.sumvec_fft import kernel as K
from repro.tune.dispatch import best_config
from repro.tune.space import balanced_factors

Array = jax.Array


def choose_factors(d: int) -> tuple[int, int]:
    """d = d1 * d2 with d1 <= d2, d1 as close to sqrt(d) as possible.

    Exact (never pads): callers that require a factorization of d itself
    (e.g. the spectrum-layout tests) use this.  The regularizer entry points
    use :func:`fft_plan`, which may instead pick a padded length when the
    best exact factorization is pessimal (prime / near-prime d).
    """
    return balanced_factors(d)


@dataclasses.dataclass(frozen=True)
class FFTPlan:
    """A tuned four-step execution plan for logical DFT length d.

    dp == d: exact in-place factorization d = d1 * d2.
    dp > d : zero-pad to dp = d1 * d2 >= 2d - 1 and fold the linear
             correlation back to d circular lags (exact; see module doc).

    Frozen + hashable so it can ride through jit static args.
    """

    d: int
    dp: int
    d1: int
    d2: int

    @property
    def padded(self) -> bool:
        return self.dp > self.d

    def __post_init__(self):
        # explicit raises, not asserts: a violated invariant means a silently
        # WRONG loss (aliased fold), which must not survive python -O
        if self.d1 * self.d2 != self.dp:
            raise ValueError(f"FFTPlan: d1 * d2 != dp ({self.d1} * {self.d2} != {self.dp})")
        if self.dp != self.d and self.dp < 2 * self.d - 1:
            raise ValueError(
                f"FFTPlan: padded dp={self.dp} < 2d-1={2 * self.d - 1} aliases the fold"
            )


def fft_plan(d: int) -> FFTPlan:
    """The tuned plan for length d (override via tune.override("sumvec_fft_plan"))."""
    cfg = best_config("sumvec_fft_plan", (d,))
    return FFTPlan(d=d, dp=cfg["dp"], d1=cfg["d1"], d2=cfg["d2"])


def _twiddle(d1: int, d2: int, sign: int) -> tuple[Array, Array]:
    """W_d^{sign * t2 * k1} flattened to (d2 * d1,) in [t2, k1] order."""
    d = d1 * d2
    t2 = np.arange(d2)[:, None]
    k1 = np.arange(d1)[None, :]
    ang = 2.0 * np.pi * t2 * k1 / d * sign
    return (
        jnp.asarray(np.cos(ang).reshape(-1), jnp.float32),
        jnp.asarray(np.sin(ang).reshape(-1), jnp.float32),
    )


def four_step_fft(x: Array, d1: int, d2: int) -> tuple[Array, Array]:
    """Full complex DFT of real rows x (n, d). Returns (n, d1, d2) pair in
    [k1, k2] layout (f = k1 + d1*k2)."""
    n, d = x.shape
    assert d == d1 * d2, (d, d1, d2)
    w1r, w1i = full_dft_matrices(d1, sign=-1)
    w2r, w2i = full_dft_matrices(d2, sign=-1)
    twr, twi = _twiddle(d1, d2, sign=-1)

    xt = x.reshape(n, d1, d2).transpose(0, 2, 1).reshape(n * d2, d1)  # [t2, t1]
    s1r, s1i = K.rmatmul_complex_basis(xt.astype(jnp.float32), w1r, w1i)  # [t2, k1]
    s2r, s2i = K.ctwiddle(s1r.reshape(n, d2 * d1), s1i.reshape(n, d2 * d1), twr, twi)
    s2r = s2r.reshape(n, d2, d1).transpose(0, 2, 1).reshape(n * d1, d2)  # [k1, t2]
    s2i = s2i.reshape(n, d2, d1).transpose(0, 2, 1).reshape(n * d1, d2)
    s3r, s3i = K.cmatmul(s2r, s2i, w2r, w2i)  # contract t2 -> [k1, k2]
    return s3r.reshape(n, d1, d2), s3i.reshape(n, d1, d2)


def four_step_ifft(gr: Array, gi: Array, d1: int, d2: int) -> Array:
    """Inverse DFT of (..., d1, d2) [k1, k2]-layout spectrum; returns the
    real part in natural time order (..., d) (imag is ~0 for our G)."""
    lead = gr.shape[:-2]
    n = int(np.prod(lead)) if lead else 1
    d = d1 * d2
    w1r, w1i = full_dft_matrices(d1, sign=+1)
    w2r, w2i = full_dft_matrices(d2, sign=+1)
    twr, twi = _twiddle(d1, d2, sign=+1)

    g2r = gr.reshape(n * d1, d2)
    g2i = gi.reshape(n * d1, d2)
    s1r, s1i = K.cmatmul(g2r, g2i, w2r, w2i)  # contract k2 -> [k1, t2]
    s1r = s1r.reshape(n, d1, d2).transpose(0, 2, 1).reshape(n, d2 * d1)  # [t2, k1]
    s1i = s1i.reshape(n, d1, d2).transpose(0, 2, 1).reshape(n, d2 * d1)
    s2r, s2i = K.ctwiddle(s1r, s1i, twr, twi)
    s2r = s2r.reshape(n * d2, d1)
    s2i = s2i.reshape(n * d2, d1)
    s3r, _ = K.cmatmul(s2r, s2i, w1r, w1i)  # contract k1 -> [t2, t1]
    out = s3r.reshape(n, d2, d1).transpose(0, 2, 1).reshape(*lead, d) / d
    return out


def frequency_accumulator_fourstep(z1: Array, z2: Array, d1: int, d2: int):
    """G = sum_k conj(F z1_k) o (F z2_k), (d1, d2) [k1,k2] layout pair."""
    f1r, f1i = four_step_fft(z1, d1, d2)
    f2r, f2i = four_step_fft(z2, d1, d2)
    gr = jnp.sum(f1r * f2r + f1i * f2i, axis=0)
    gi = jnp.sum(f1r * f2i - f1i * f2r, axis=0)
    return gr, gi


def _fold_linear_to_circular(sv: Array, d: int) -> Array:
    """Exact length-d circular summary vector from a length-dp (dp >= 2d-1)
    linear-correlation output: sv_d[t] = lin[t] + lin[-(d-t)], where lag -s
    sits at index dp - s of the padded circular output."""
    dp = sv.shape[-1]
    if dp == d:
        return sv
    head = sv[..., :d]
    neg = sv[..., dp - d + 1 :]  # lags -(d-1) .. -1
    zero = jnp.zeros(sv.shape[:-1] + (1,), sv.dtype)
    return head + jnp.concatenate([zero, neg], axis=-1)


def _sumvec_impl(z1: Array, z2: Array, s: float, plan: FFTPlan) -> Array:
    """Length-d time-domain summary vector through the (possibly padded)
    four-step pipeline. Inputs (n, d) float32."""
    zp1 = pad_axis(z1, 1, plan.dp)
    zp2 = pad_axis(z2, 1, plan.dp)
    gr, gi = frequency_accumulator_fourstep(zp1, zp2, plan.d1, plan.d2)
    sv = four_step_ifft(gr, gi, plan.d1, plan.d2).reshape(plan.dp)
    return _fold_linear_to_circular(sv, plan.d) / s


def _r_sum_impl(z1: Array, z2: Array, q: int, s: float, plan: FFTPlan) -> Array:
    z1 = z1.astype(jnp.float32)
    z2 = z2.astype(jnp.float32)
    if q == 2 and not plan.padded:
        # Full-spectrum Parseval: sum_t sv[t]^2 = (1/d) sum_f |G_f|^2,
        # sv[0] = (1/d) sum_f Re G_f — layout invariant, no inverse FFT.
        gr, gi = frequency_accumulator_fourstep(z1, z2, plan.d1, plan.d2)
        gr, gi = gr / s, gi / s
        sq = jnp.sum(gr**2 + gi**2) / plan.d
        s0 = jnp.sum(gr) / plan.d
        return sq - s0**2
    # padded plans fold in the time domain (Parseval at dp would regroup the
    # wrapped diagonals); q = 1 needs the time domain regardless.
    sv = _sumvec_impl(z1, z2, s, plan)
    if q == 2:
        return jnp.sum(sv**2) - sv[0] ** 2
    return jnp.sum(jnp.abs(sv[1:]))


@functools.partial(jax.jit, static_argnames=("q", "scale", "plan"))
def r_sum_fourstep(
    z1: Array,
    z2: Array,
    *,
    q: int = 2,
    scale: Optional[float] = None,
    plan: Optional[FFTPlan] = None,
) -> Array:
    """Ungrouped Eq. (6) through the four-step Pallas pipeline.

    ``plan=None`` consults the tuner; pass an explicit :class:`FFTPlan` to
    pin the factorization (it is hashable, so it jit-caches cleanly).
    """
    d = z1.shape[-1]
    if plan is None:
        plan = fft_plan(d)
    if plan.d != d:
        # raise, don't assert: a stale plan under python -O would fold to
        # plan.d and return a silently wrong loss
        raise ValueError(f"plan built for d={plan.d}, inputs have d={d}")
    s = 1.0 if scale is None else float(scale)
    return _r_sum_impl(z1, z2, q, s, plan)


@functools.partial(jax.jit, static_argnames=("scale", "plan"))
def sumvec_fourstep(
    z1: Array,
    z2: Array,
    scale: Optional[float] = None,
    plan: Optional[FFTPlan] = None,
) -> Array:
    """Time-domain sumvec via four-step fwd+inv (kernel analogue of Eq. 12)."""
    d = z1.shape[-1]
    if plan is None:
        plan = fft_plan(d)
    if plan.d != d:
        raise ValueError(f"plan built for d={plan.d}, inputs have d={d}")
    s = 1.0 if scale is None else float(scale)
    return _sumvec_impl(z1.astype(jnp.float32), z2.astype(jnp.float32), s, plan)
