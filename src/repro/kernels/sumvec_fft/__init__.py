from repro.kernels.sumvec_fft.ops import (
    FFTPlan,
    r_sum_fourstep,
    sumvec_fourstep,
    fft_plan,
    four_step_fft,
    four_step_ifft,
    frequency_accumulator_fourstep,
    choose_factors,
)
