"""Pallas TPU kernels for the ungrouped sumvec via a four-step FFT.

For the ungrouped regularizer the DFT length is the full projector width d
(up to 16384 in the paper).  A direct DFT-matmul would need a d x d basis
(1 GiB at d = 16384) — instead we use the classic Bailey four-step
factorization d = d1 * d2 (DESIGN.md §3.2):

    t = t1*d2 + t2,  f = k1 + d1*k2
    step 1: DFT_{d1} along t1      (batched d1 x d1 complex matmul)
    step 2: twiddle by W_d^{t2 k1} (elementwise complex multiply)
    step 3: DFT_{d2} along t2      (batched d2 x d2 complex matmul)

Both matmul steps run on the MXU with ~sqrt(d)-sized bases that live in
VMEM; total O(n d (d1 + d2)) FLOPs instead of O(n d^2).

Kernels here:
  * ``cmatmul``  — fused complex matmul (4 real dots, 2 outputs) with a
                   custom_vjp expressed as two more cmatmuls (conjugate
                   transpose identities).
  * ``ctwiddle`` — elementwise complex multiply by a constant plane; vjp is
                   a ctwiddle by the conjugate plane.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pallas_utils import INTERPRET, LANE, SUBLANE, next_multiple, pad_axis
from repro.tune.dispatch import best_config


# ---------------------------------------------------------------------------
# cmatmul: (Ar + i Ai) @ (Br + i Bi) fused
# ---------------------------------------------------------------------------


def _cmm_kernel(ar_ref, ai_ref, br_ref, bi_ref, cr_ref, ci_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        cr_ref[...] = jnp.zeros_like(cr_ref)
        ci_ref[...] = jnp.zeros_like(ci_ref)

    ar, ai = ar_ref[...], ai_ref[...]
    br, bi = br_ref[...], bi_ref[...]
    dot = lambda x, y: jnp.dot(x, y, preferred_element_type=jnp.float32)
    cr_ref[...] += dot(ar, br) - dot(ai, bi)
    ci_ref[...] += dot(ar, bi) + dot(ai, br)


def _cmatmul_raw(ar, ai, br, bi, tm=None, tn=None, tk=None):
    m, kdim = ar.shape
    _, n = br.shape
    if tm is None or tn is None or tk is None:
        cfg = best_config("cmatmul", (m, kdim, n), ar.dtype)
        tm = cfg["tm"] if tm is None else tm
        tn = cfg["tn"] if tn is None else tn
        tk = cfg["tk"] if tk is None else tk
    tm = min(tm, next_multiple(m, SUBLANE))
    tn = min(tn, next_multiple(n, LANE))
    tk = min(tk, next_multiple(kdim, LANE))
    mp, kp, np_ = next_multiple(m, tm), next_multiple(kdim, tk), next_multiple(n, tn)
    pad = lambda x, s0, s1: pad_axis(pad_axis(x, 0, s0), 1, s1)
    ar, ai = pad(ar, mp, kp), pad(ai, mp, kp)
    br, bi = pad(br, kp, np_), pad(bi, kp, np_)
    grid = (mp // tm, np_ // tn, kp // tk)
    a_spec = pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk))
    b_spec = pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j))
    o_spec = pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j))
    cr, ci = pl.pallas_call(
        _cmm_kernel,
        grid=grid,
        in_specs=[a_spec, a_spec, b_spec, b_spec],
        out_specs=[o_spec, o_spec],
        out_shape=[
            jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        ],
        interpret=INTERPRET,
    )(
        ar.astype(jnp.float32),
        ai.astype(jnp.float32),
        br.astype(jnp.float32),
        bi.astype(jnp.float32),
    )
    return cr[:m, :n], ci[:m, :n]


@jax.custom_vjp
def cmatmul(ar, ai, br, bi):
    """Complex matmul on real/imag planes: C = A @ B."""
    return _cmatmul_raw(ar, ai, br, bi)


def _cmm_fwd(ar, ai, br, bi):
    return _cmatmul_raw(ar, ai, br, bi), (ar, ai, br, bi)


def _cmm_bwd(res, g):
    ar, ai, br, bi = res
    gr, gi = g
    # dA = g @ B^H ;  dB = A^H @ g   (conjugate transposes)
    dar, dai = _cmatmul_raw(gr, gi, br.T, -bi.T)
    dbr, dbi = _cmatmul_raw(ar.T, -ai.T, gr, gi)
    return dar, dai, dbr, dbi


cmatmul.defvjp(_cmm_fwd, _cmm_bwd)


def rmatmul_complex_basis(x, br, bi):
    """Real input times complex basis — cmatmul with Ai = 0 folded out."""
    return cmatmul(x, jnp.zeros_like(x), br, bi)


# ---------------------------------------------------------------------------
# ctwiddle: elementwise complex multiply by a constant plane
# ---------------------------------------------------------------------------


def _ctw_kernel(xr_ref, xi_ref, wr_ref, wi_ref, yr_ref, yi_ref):
    xr, xi = xr_ref[...], xi_ref[...]
    wr, wi = wr_ref[...], wi_ref[...]
    yr_ref[...] = xr * wr - xi * wi
    yi_ref[...] = xr * wi + xi * wr


def _ctwiddle_raw(xr, xi, wr, wi, tn=None):
    n, d = xr.shape
    assert wr.shape == (d,), (xr.shape, wr.shape)
    if tn is None:
        tn = best_config("ctwiddle", (n, d), xr.dtype)["tn"]
    tn = min(tn, next_multiple(n, SUBLANE))
    dp = next_multiple(d, LANE)
    np_ = next_multiple(n, tn)
    xr = pad_axis(pad_axis(xr, 0, np_), 1, dp)
    xi = pad_axis(pad_axis(xi, 0, np_), 1, dp)
    wr2 = pad_axis(wr, 0, dp).reshape(1, dp)
    wi2 = pad_axis(wi, 0, dp).reshape(1, dp)
    grid = (np_ // tn,)
    yr, yi = pl.pallas_call(
        _ctw_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, dp), lambda i: (i, 0)),
            pl.BlockSpec((tn, dp), lambda i: (i, 0)),
            pl.BlockSpec((1, dp), lambda i: (0, 0)),
            pl.BlockSpec((1, dp), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tn, dp), lambda i: (i, 0)),
            pl.BlockSpec((tn, dp), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_, dp), jnp.float32),
            jax.ShapeDtypeStruct((np_, dp), jnp.float32),
        ],
        interpret=INTERPRET,
    )(xr.astype(jnp.float32), xi.astype(jnp.float32), wr2, wi2)
    return yr[:n, :d], yi[:n, :d]


@jax.custom_vjp
def ctwiddle(xr, xi, wr, wi):
    """y = x o w (x: (n, d) complex pair, w: (d,) complex pair constant)."""
    return _ctwiddle_raw(xr, xi, wr, wi)


def _ctw_fwd(xr, xi, wr, wi):
    return _ctwiddle_raw(xr, xi, wr, wi), (xr, xi, wr, wi)


def _ctw_bwd(res, g):
    xr, xi, wr, wi = res
    gr, gi = g
    # dx = g o conj(w)
    dxr, dxi = _ctwiddle_raw(gr, gi, wr, -wi)
    # dw = sum_k conj(x_k) o g_k   (w is a constant basis; grads rarely used)
    dwr = jnp.sum(xr * gr + xi * gi, axis=0)
    dwi = jnp.sum(xr * gi - xi * gr, axis=0)
    return dxr, dxi, dwr, dwi


ctwiddle.defvjp(_ctw_fwd, _ctw_bwd)
