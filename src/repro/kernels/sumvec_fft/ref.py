"""Pure-jnp oracle for the four-step sumvec kernels.

Independent of repro.core: direct circular correlation sums (Appendix A) and
numpy-FFT spectra, used to validate both the spectrum layout and the
regularizer values of the Pallas pipeline.
"""

from __future__ import annotations

import jax.numpy as jnp


def sumvec_ref(z1, z2, scale=1.0):
    """sumvec(C) by direct O(n d^2) circular-correlation sums."""
    n, d = z1.shape
    z1 = z1.astype(jnp.float32)
    z2 = z2.astype(jnp.float32)
    i = jnp.arange(d)[:, None]
    j = jnp.arange(d)[None, :]
    gather = (i + j) % d  # (d_out, d_in)
    # sum_k sum_j z1[k, j] * z2[k, (i + j) % d]
    return jnp.einsum("kj,kij->i", z1, z2[:, gather]) / scale


def r_sum_ref(z1, z2, q=2, scale=1.0):
    sv = sumvec_ref(z1, z2, scale)
    tail = sv[1:]
    return jnp.sum(jnp.abs(tail)) if q == 1 else jnp.sum(tail**2)


def spectrum_ref(x):
    """Full complex DFT of real rows (n, d) -> complex (n, d), natural order."""
    return jnp.fft.fft(x.astype(jnp.float32), axis=-1)
