"""Shared Pallas/TPU helpers: padding, tiling, interpret-mode dispatch.

TPU tiling rules baked in here:
  * lane (last) dim of every VMEM block is a multiple of 128,
  * sublane (second-to-last) a multiple of 8 for f32.
Inputs are zero-padded up to tile multiples in the op wrappers — all our
contractions are linear, so zero padding never changes results, and outputs
are sliced back.

``INTERPRET`` is True on CPU backends: kernels execute their Python bodies
(the Pallas interpreter), which validates the kernel logic on this container;
on a real TPU the same code lowers to Mosaic.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

INTERPRET = jax.default_backend() == "cpu"

LANE = 128
SUBLANE = 8


def next_multiple(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def pad_axis(x, axis: int, target: int):
    """Zero-pad ``axis`` of x up to length ``target``."""
    cur = x.shape[axis]
    if cur == target:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - cur)
    return jnp.pad(x, pad)


def pad_to_tiles(x, tile_by_axis: dict[int, int]):
    for axis, tile in tile_by_axis.items():
        x = pad_axis(x, axis, next_multiple(x.shape[axis], tile))
    return x


def dft_matrices(d: int, dtype=jnp.float32):
    """Real/imag rfft basis: F[f] = sum_t z[t] * (Cr[t,f] + i Ci[t,f]).

    Cr[t, f] = cos(2 pi t f / d);  Ci[t, f] = -sin(2 pi t f / d).
    Shapes (d, d//2 + 1).
    """
    nf = d // 2 + 1
    t = np.arange(d)[:, None]
    f = np.arange(nf)[None, :]
    ang = 2.0 * np.pi * t * f / d
    return jnp.asarray(np.cos(ang), dtype), jnp.asarray(-np.sin(ang), dtype)


def full_dft_matrices(d: int, sign: int = -1, dtype=jnp.float32):
    """Full complex DFT basis W[t, f] = exp(sign * 2 pi i t f / d) as (re, im)."""
    t = np.arange(d)[:, None]
    f = np.arange(d)[None, :]
    ang = 2.0 * np.pi * t * f / d * sign
    return jnp.asarray(np.cos(ang), dtype), jnp.asarray(np.sin(ang), dtype)


def irfft_basis(d: int, dtype=jnp.float32):
    """Synthesis basis: s[t] = sum_f  Br[f, t] * Gr[f] + Bi[f, t] * Gi[f].

    Derived from s = irfft(G):  s[t] = (1/d) sum_f w_f (Gr cos(2pi ft/d)
    - Gi sin(2pi ft/d)), w_f the rfft duplication weights.
    Shapes (d//2+1, d).
    """
    nf = d // 2 + 1
    w = np.full((nf,), 2.0)
    w[0] = 1.0
    if d % 2 == 0:
        w[-1] = 1.0
    f = np.arange(nf)[:, None]
    t = np.arange(d)[None, :]
    ang = 2.0 * np.pi * f * t / d
    br = (w[:, None] * np.cos(ang)) / d
    bi = (-w[:, None] * np.sin(ang)) / d
    return jnp.asarray(br, dtype), jnp.asarray(bi, dtype)
