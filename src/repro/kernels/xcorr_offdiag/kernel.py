"""Fused off-diagonal penalty kernel — the O(n d^2) baseline, done right.

Barlow Twins / VICReg compute ``R_off = sum_{i != j} C_ij^2`` by materializing
the d x d matrix C = (1/s) Z1^T Z2 in HBM (1 GiB fp32 at d = 16384).  This
kernel streams C tile-by-tile through VMEM: each (ti, tj) tile is accumulated
over the batch contraction in a VMEM scratch buffer, squared, diagonal-masked
and folded into a running scalar — C never exists in HBM.

Grid: (I, J, K) with K (the batch contraction) innermost; the scalar output
block has a constant index map so it stays VMEM-resident for the whole grid.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_utils import INTERPRET, LANE, SUBLANE, next_multiple, pad_axis
from repro.tune.dispatch import best_config


def _xcorr_kernel(z1_ref, z2_ref, out_ref, acc_ref):
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when((i == 0) & (j == 0) & (k == 0))
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(k == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        z1_ref[...].T, z2_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _fold():
        c = acc_ref[...]
        sq = c * c
        ti, tj = sq.shape
        row = jax.lax.broadcasted_iota(jnp.int32, (ti, tj), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (ti, tj), 1)
        # global diagonal: tile (i, j) covers rows i*ti + row, cols j*tj + col
        is_diag = (i * ti + row) == (j * tj + col)
        off_sum = jnp.sum(jnp.where(is_diag, 0.0, sq))
        out_ref[0, 0] += off_sum


def off_diagonal_sq_sum_raw(z1, z2, tile_d=None, tile_n=None):
    """sum_{i != j} (Z1^T Z2)_{ij}^2 without materializing the d x d matrix.

    Tiling comes from ``repro.tune`` unless pinned explicitly via the
    ``tile_d`` / ``tile_n`` arguments (tests, benchmarks, the tuner itself).
    """
    n, d = z1.shape
    if tile_d is None or tile_n is None:
        cfg = best_config("xcorr_offdiag", (n, d), z1.dtype)
        tile_d = cfg["tile_d"] if tile_d is None else tile_d
        tile_n = cfg["tile_n"] if tile_n is None else tile_n
    td = min(tile_d, next_multiple(d, LANE))
    tn = min(tile_n, next_multiple(n, SUBLANE))
    dp = next_multiple(d, td)
    np_ = next_multiple(n, tn)
    z1 = pad_axis(pad_axis(z1, 0, np_), 1, dp).astype(jnp.float32)
    z2 = pad_axis(pad_axis(z2, 0, np_), 1, dp).astype(jnp.float32)
    grid = (dp // td, dp // td, np_ // tn)
    out = pl.pallas_call(
        _xcorr_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, td), lambda i, j, k: (k, i)),
            pl.BlockSpec((tn, td), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((td, td), jnp.float32)],
        interpret=INTERPRET,
    )(z1, z2)
    return out[0, 0]
