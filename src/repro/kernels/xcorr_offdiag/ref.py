"""Pure-jnp oracle for the fused off-diagonal kernel: materialize C, square,
mask the diagonal, sum."""

from __future__ import annotations

import jax.numpy as jnp


def off_diagonal_sq_sum_ref(z1, z2, scale=1.0):
    c = (z1.astype(jnp.float32).T @ z2.astype(jnp.float32)) / scale
    sq = c * c
    return jnp.sum(sq) - jnp.sum(jnp.diagonal(sq))
