from repro.kernels.xcorr_offdiag.ops import off_diagonal_sq_sum, r_off_gram
from repro.kernels.xcorr_offdiag.ref import off_diagonal_sq_sum_ref
