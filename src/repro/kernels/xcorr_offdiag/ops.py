"""Off-diagonal penalty: fused Pallas forward + Gram-trick backward.

Beyond-paper insight (DESIGN.md, EXPERIMENTS.md §Perf): the *gradient* of
R_off never needs the d x d matrix either.  With C = (1/s) Z1^T Z2,

    dR/dZ1 = (2/s) Z2 (C - diag C)^T
           = (2/s^2) (Z2 Z2^T) Z1 - (2/s) Z2 * c_diag

— an n x n Gram matrix route costing O(n^2 d), a factor d/n cheaper than the
textbook O(n d^2) whenever the batch is smaller than the width (n = 256 vs
d = 8192: 32x).  The same identity gives an O(n^2 d) *forward*
(``r_off_gram``), used as the strengthened baseline in benchmarks.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.xcorr_offdiag.kernel import off_diagonal_sq_sum_raw

Array = jax.Array


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _off_diag_sq_sum(z1: Array, z2: Array, scale: float) -> Array:
    return off_diagonal_sq_sum_raw(z1, z2) / (scale * scale)


def _fwd(z1, z2, scale):
    return _off_diag_sq_sum(z1, z2, scale), (z1, z2)


def _bwd(scale, res, g):
    z1, z2 = res
    z1 = z1.astype(jnp.float32)
    z2 = z2.astype(jnp.float32)
    s = float(scale)
    c_diag = jnp.sum(z1 * z2, axis=0) / s  # (d,)
    n, d = z1.shape
    if n <= d:
        gram2 = z2 @ z2.T  # (n, n)
        gram1 = z1 @ z1.T
        dz1 = (2.0 / s**2) * (gram2 @ z1) - (2.0 / s) * z2 * c_diag
        dz2 = (2.0 / s**2) * (gram1 @ z2) - (2.0 / s) * z1 * c_diag
    else:
        c = (z1.T @ z2) / s
        coff = c - jnp.diag(jnp.diagonal(c))
        dz1 = (2.0 / s) * (z2 @ coff.T)
        dz2 = (2.0 / s) * (z1 @ coff)
    return g * dz1, g * dz2


_off_diag_sq_sum.defvjp(_fwd, _bwd)


def off_diagonal_sq_sum(z1: Array, z2: Array, *, scale: Optional[float] = None) -> Array:
    """R_off(C) with C = (1/scale) Z1^T Z2 — fused kernel fwd, Gram bwd."""
    s = 1.0 if scale is None else float(scale)
    return _off_diag_sq_sum(z1, z2, s)


def r_off_gram(z1: Array, z2: Array, *, scale: Optional[float] = None) -> Array:
    """O(n^2 d) forward for R_off via Gram matrices (strengthened baseline).

    ||C||_F^2 = (1/s^2) tr(Z2^T Z1 Z1^T Z2) = (1/s^2) <Z1 Z1^T, Z2 Z2^T>.
    """
    s = 1.0 if scale is None else float(scale)
    z1 = z1.astype(jnp.float32)
    z2 = z2.astype(jnp.float32)
    g1 = z1 @ z1.T
    g2 = z2 @ z2.T
    fro = jnp.sum(g1 * g2) / (s * s)
    c_diag = jnp.sum(z1 * z2, axis=0) / s
    return fro - jnp.sum(c_diag**2)
