"""Pallas TPU kernels for the decorrelation hot spots.

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd,
differentiable wrapper), ref.py (pure-jnp oracle).  Validated in
interpret mode on CPU; targeted at TPU v5e (MXU 128x128, VMEM ~16 MiB).
"""
