"""Pallas TPU kernel for paged (block-table) decode attention.

One grid step = (slot b, logical block j).  The block table and the per-slot
context lengths ride in as *scalar prefetch* operands, so the k/v ``BlockSpec``
index maps can pick the PHYSICAL page ``bt[b, j]`` for each grid step — the
kernel never sees a gathered dense cache, only one page of it at a time.
Per-slot online-softmax state (running max / normalizer / value accumulator)
lives in VMEM scratch, re-initialized at j == 0 and folded across the slot's
pages exactly like the chunked-prefill scan in ``models/attention.py``; the
output block for slot b is revisited every j and the final page's write wins.

Pages whose first token is already past the slot's valid length are skipped
with ``pl.when`` (free-slot lanes decode a single masked row, same as the
dense path — the engine discards their output).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_utils import INTERPRET, LANE, SUBLANE, next_multiple, pad_axis

NEG_INF = -1e30


def _paged_decode_kernel(
    bt_ref,  # (B, NB) int32 scalar prefetch: block table
    len_ref,  # (B,) int32 scalar prefetch: valid context tokens per slot
    q_ref,  # (1, KVp, Rp, HDp): queries grouped by shared kv head
    k_ref,  # (1, page, KVp, HDp): the physical page bt[b, j]
    v_ref,  # (1, page, KVp, HDp)
    o_ref,  # (1, KVp, Rp, HDp)
    acc_ref,  # (KVp, Rp, HDp) f32 scratch: value accumulator
    m_ref,  # (KVp, Rp, LANE) f32 scratch: running max (broadcast over lanes)
    l_ref,  # (KVp, Rp, LANE) f32 scratch: running normalizer
    *,
    page: int,
    scale: float,
    softcap: float,
    window: int,
):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[b]

    @pl.when(j * page < length)
    def _accumulate():
        q = q_ref[0].astype(jnp.float32)  # (KVp, Rp, HDp)
        k = k_ref[0].astype(jnp.float32)  # (page, KVp, HDp)
        v = v_ref[0].astype(jnp.float32)
        # GQA without expansion: batch over kv heads, each serving its Rp
        # query heads — (KVp, Rp, HDp) x (page, KVp, HDp) -> (KVp, Rp, page)
        s = jax.lax.dot_general(
            q,
            k,
            dimension_numbers=(((2,), (2,)), ((0,), (1,))),
            preferred_element_type=jnp.float32,
        )
        s = s * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        pos = j * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        mask = pos < length
        if window:
            mask &= pos >= length - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :, :1]  # (KVp, Rp, 1)
        l_prev = l_ref[:, :, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=2, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)  # masked lanes: exp(NEG_INF - m) == 0 exactly
        l_new = l_prev * corr + jnp.sum(p, axis=2, keepdims=True)
        # (KVp, Rp, page) x (page, KVp, HDp) -> (KVp, Rp, HDp)
        pv = jax.lax.dot_general(
            p,
            v,
            dimension_numbers=(((2,), (0,)), ((0,), (1,))),
            preferred_element_type=jnp.float32,
        )
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == pl.num_programs(1) - 1)
    def _finalize():
        o_ref[0] = acc_ref[...] / jnp.maximum(l_ref[:, :, :1], 1e-30)


def paged_decode_kernel_call(q, k_pages, v_pages, block_tables, lens, *, scale, softcap, window):
    """Pad to tile boundaries and launch the kernel.

    ``q``: (B, H, hd) with H == n_rep * KV; pages stay in their native
    (P, page, KV, hd) layout and dtype — GQA is handled by batching the dots
    over kv heads inside the kernel and the f32 cast happens per block, so
    the only whole-pool materialization is the zero-pad of kv/hd up to tile
    boundaries (a no-op at real model shapes like kv=8, hd=128/256).
    """
    b, h, hd = q.shape
    p_total, page, kv, hdk = k_pages.shape
    assert hdk == hd and h % kv == 0, (q.shape, k_pages.shape)
    assert page % SUBLANE == 0, f"page size {page} must be a sublane multiple"
    n_rep = h // kv
    nb = block_tables.shape[1]
    kvp = next_multiple(kv, SUBLANE)
    rp = next_multiple(n_rep, SUBLANE)
    hdp = next_multiple(hd, LANE)
    q = q.reshape(b, kv, n_rep, hd)
    q = pad_axis(pad_axis(pad_axis(q, 1, kvp), 2, rp), 3, hdp)
    k_pages = pad_axis(pad_axis(k_pages, 2, kvp), 3, hdp)
    v_pages = pad_axis(pad_axis(v_pages, 2, kvp), 3, hdp)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, nb),
        in_specs=[
            pl.BlockSpec((1, kvp, rp, hdp), lambda bb, jj, bt, ln: (bb, 0, 0, 0)),
            pl.BlockSpec((1, page, kvp, hdp), lambda bb, jj, bt, ln: (bt[bb, jj], 0, 0, 0)),
            pl.BlockSpec((1, page, kvp, hdp), lambda bb, jj, bt, ln: (bt[bb, jj], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, kvp, rp, hdp), lambda bb, jj, bt, ln: (bb, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kvp, rp, hdp), jnp.float32),
            pltpu.VMEM((kvp, rp, LANE), jnp.float32),
            pltpu.VMEM((kvp, rp, LANE), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _paged_decode_kernel,
            page=page,
            scale=float(scale),
            softcap=float(softcap or 0.0),
            window=int(window or 0),
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvp, rp, hdp), jnp.float32),
        interpret=INTERPRET,
    )(block_tables.astype(jnp.int32), lens.astype(jnp.int32), q, k_pages, v_pages)
    return out[:, :kv, :n_rep, :hd].reshape(b, h, hd)
