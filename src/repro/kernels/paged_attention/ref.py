"""Pure-jnp oracle for paged decode attention.

Deliberately independent of ``repro.models``: materializes the dense
(B, NB * page, H, hd) context view with one gather over the block table and
evaluates masked softmax attention term-by-term.  O(B * S * H * hd) with the
full gather materialized — used to validate the Pallas kernel, and as the
numerics reference the serving engine's jnp route must match bit-for-bit
against the dense cache path.

Layout conventions (all f32, heads already GQA-expanded):

  q             (B, H, hd)       one query token per pool slot
  k/v_pages     (P, page, H, hd) physical page pool (P pages of ``page`` tokens)
  block_tables  (B, NB) int32    logical block j of slot b -> physical page id
  lens          (B,) int32       valid context tokens per slot (masks the rest)
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def gather_pages(pages, block_tables):
    """(P, page, H, hd) pages + (B, NB) table -> (B, NB * page, H, hd) dense
    context view (rows beyond a slot's valid length hold arbitrary page
    content — callers must mask by ``lens``)."""
    b, nb = block_tables.shape
    _, page, h, hd = pages.shape
    return pages[block_tables].reshape(b, nb * page, h, hd)


def paged_decode_ref(
    q,
    k_pages,
    v_pages,
    block_tables,
    lens,
    *,
    scale: float,
    softcap: float = 0.0,
    window: int = 0,
):
    """Masked softmax attention over the gathered page view.

    ``window > 0`` restricts to the sliding-window rows [len - window, len)
    (local attention); ``softcap > 0`` applies the tanh logit cap.  Returns
    (B, H, hd) f32.
    """
    k = gather_pages(k_pages, block_tables)
    v = gather_pages(v_pages, block_tables)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    ki = jnp.arange(k.shape[1])[None, None, :]
    cl = lens.reshape(-1, 1, 1)
    mask = ki < cl
    if window:
        mask &= ki >= cl - window
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bhk,bkhd->bhd", p, v.astype(jnp.float32))
