"""jit'd wrappers + page-size dispatch for the paged-attention kernel family.

Unlike the matmul families, the tunable config here — the KV page size — is
baked into the PHYSICAL layout of the page pool, so it is consumed where the
pool is built (``repro.serve.paging``), not at call time: the serving engine
asks ``repro.tune`` for the page size once at construction
(``auto_page_size`` / ``best_config("paged_attention", (slots, max_len, kv,
hd))``), and every subsequent decode step just runs at that layout.  The
candidate space, analytic cost model, and dry/measure tuner builders live in
``repro.tune.{space,cost,tuner}`` like the other three kernel families.

Implementation routing follows ``r_sum``: ``repro.tune.best_impl
("paged_attention")`` picks the Pallas kernel on TPU and the jnp
gather-reference elsewhere (both overridable via ``tune.override``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention import ref as R
from repro.kernels.paged_attention.kernel import paged_decode_kernel_call
from repro.tune import space as tune_space
from repro.tune.dispatch import best_config

Array = jax.Array

# Kernel time alone always prefers the largest page (fewest grid steps), but
# every admitted request strands on average half a page of dead rows — the
# fragmentation paging exists to remove.  ``auto_page_size`` therefore caps
# the tuned pick; callers with measured workloads pass their own page size.
PAGE_PREFER = 32


def auto_page_size(
    n_slots: int, max_len: int, n_kv_heads: int, head_dim: int, prefer: int = PAGE_PREFER
) -> int:
    """Tuned default page size for a (slots, max_len, kv, hd) pool: the
    ``repro.tune`` winner (override > memo > disk cache > analytic), clamped
    to the largest legal candidate <= ``prefer``."""
    shape = (n_slots, max_len, n_kv_heads, head_dim)
    page = int(best_config("paged_attention", shape)["page"])
    if page <= prefer:
        return page
    legal = [c["page"] for c in tune_space.candidates("paged_attention", shape)]
    capped = [p for p in legal if p <= prefer]
    return max(capped) if capped else min(legal)


def _expand_heads(pages: Array, n_rep: int) -> Array:
    if n_rep == 1:
        return pages
    return jnp.repeat(pages, n_rep, axis=2)


def paged_decode_attention_raw(
    q: Array,
    k_pages: Array,
    v_pages: Array,
    block_tables: Array,
    lens: Array,
    *,
    scale: float,
    softcap: float = 0.0,
    window: int = 0,
) -> Array:
    """One decode step of attention over block-table pages (Pallas route).

    q: (B, H, hd) query rows; k/v_pages: (P, page, KV, hd) physical pools
    (GQA is batched over kv heads inside the kernel — the pools are never
    head-expanded); block_tables: (B, NB) int32; lens: (B,) valid context
    tokens per slot.  Returns (B, H, hd) f32.
    """
    return paged_decode_kernel_call(
        q,
        k_pages,
        v_pages,
        block_tables,
        lens,
        scale=scale,
        softcap=softcap,
        window=window,
    )


@functools.partial(jax.jit, static_argnames=("scale", "softcap", "window"))
def paged_decode_attention(
    q: Array,
    k_pages: Array,
    v_pages: Array,
    block_tables: Array,
    lens: Array,
    *,
    scale: float,
    softcap: float = 0.0,
    window: int = 0,
) -> Array:
    return paged_decode_attention_raw(
        q, k_pages, v_pages, block_tables, lens, scale=scale, softcap=softcap, window=window
    )


def paged_decode_jnp(
    q: Array,
    k_pages: Array,
    v_pages: Array,
    block_tables: Array,
    lens: Array,
    *,
    scale: float,
    softcap: float = 0.0,
    window: int = 0,
) -> Array:
    """The gather-reference route (CPU/interpret backends), GQA-expanding
    like the raw kernel wrapper so both impls take identical inputs."""
    n_rep = q.shape[1] // k_pages.shape[2]
    return R.paged_decode_ref(
        q,
        _expand_heads(k_pages, n_rep),
        _expand_heads(v_pages, n_rep),
        block_tables,
        lens,
        scale=scale,
        softcap=softcap,
        window=window,
    )
