"""Paged decode attention: block-table KV reads for the serving slot pool.

Kernel family layout mirrors the other three (``sumvec_fft``,
``grouped_sumvec``, ``xcorr_offdiag``):

  * ``kernel.py`` — the Pallas TPU kernel (block-table page gather via
    scalar-prefetched index maps, online-softmax accumulation per slot);
  * ``ops.py``    — jit'd wrappers + padding, page-size selection through
    ``repro.tune`` (``auto_page_size`` / ``best_config("paged_attention")``);
  * ``ref.py``    — pure-jnp oracle (dense gather + masked softmax), used to
    validate the kernel and as the CPU/interpret numerics reference.
"""

from repro.kernels.paged_attention.ops import (
    auto_page_size,
    paged_decode_attention,
    paged_decode_attention_raw,
)
from repro.kernels.paged_attention.ref import gather_pages, paged_decode_ref

__all__ = [
    "auto_page_size",
    "gather_pages",
    "paged_decode_attention",
    "paged_decode_attention_raw",
    "paged_decode_ref",
]
