"""Pallas TPU kernels for the grouped FFT decorrelation regularizer.

Three primitives, each a ``pl.pallas_call`` with explicit VMEM ``BlockSpec``
tiling, each wrapped in ``jax.custom_vjp`` whose backward pass is expressed
with the *same* kernels (so fwd and bwd both run on the MXU):

  * ``pmatmul(a, b)``      — tiled (M,K)@(K,N) matmul; used for the block-DFT
                             (Z @ [Cr | Ci]) and its transpose in the vjp.
  * ``freq_outer(a, b)``   — per-frequency batched contraction over the batch:
                             G[f] = a[f]^T @ b[f], a,b: (F, K, N) -> (F, N, N).
                             This is the "compressed outer product" of the
                             paper, evaluated for all (d/b)^2 block pairs at
                             once as b//2+1 MXU matmuls.
  * ``freq_mat(a, m)``     — per-frequency right-multiplication
                             Y[f] = a[f] @ m[f]; the vjp partner of
                             freq_outer.

TPU adaptation (DESIGN.md §3): the per-block DFT is a b x b matmul (b = 128
is the paper's best block size — exactly one MXU tile), so the whole
regularizer is systolic-array work; no vector-unit FFT is involved.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pallas_utils import INTERPRET, LANE, SUBLANE, next_multiple, pad_axis
from repro.tune.dispatch import best_config


# ---------------------------------------------------------------------------
# pmatmul: tiled matmul
# ---------------------------------------------------------------------------


def _mm_kernel(a_ref, b_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)


def _pmatmul_raw(a, b, tm=None, tn=None, tk=None):
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    if tm is None or tn is None or tk is None:
        cfg = best_config("pmatmul", (m, k, n), a.dtype)
        tm = cfg["tm"] if tm is None else tm
        tn = cfg["tn"] if tn is None else tn
        tk = cfg["tk"] if tk is None else tk
    tm = min(tm, next_multiple(m, SUBLANE))
    tn = min(tn, next_multiple(n, LANE))
    tk = min(tk, next_multiple(k, LANE))
    mp, kp, np_ = next_multiple(m, tm), next_multiple(k, tk), next_multiple(n, tn)
    a = pad_axis(pad_axis(a, 0, mp), 1, kp)
    b = pad_axis(pad_axis(b, 0, kp), 1, np_)
    grid = (mp // tm, np_ // tn, kp // tk)
    out = pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=INTERPRET,
    )(a.astype(jnp.float32), b.astype(jnp.float32))
    return out[:m, :n]


@jax.custom_vjp
def pmatmul(a, b):
    return _pmatmul_raw(a, b)


def _pmatmul_fwd(a, b):
    return _pmatmul_raw(a, b), (a, b)


def _pmatmul_bwd(res, g):
    a, b = res
    da = _pmatmul_raw(g, b.T)
    db = _pmatmul_raw(a.T, g)
    return da.astype(a.dtype), db.astype(b.dtype)


pmatmul.defvjp(_pmatmul_fwd, _pmatmul_bwd)


# ---------------------------------------------------------------------------
# freq_outer: G[f] = a[f]^T @ b[f]   (F, K, N) x (F, K, N) -> (F, N, N)
# ---------------------------------------------------------------------------


def _fo_kernel(a_ref, b_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[0]  # (tk, N)
    b = b_ref[0]  # (tk, tn)
    o_ref[0] += jnp.dot(a.T, b, preferred_element_type=jnp.float32)


def _freq_outer_raw(a, b, tk=None, tn=None):
    f, k, n = a.shape
    fb, kb, nb = b.shape
    assert (f, k) == (fb, kb), (a.shape, b.shape)
    if tk is None or tn is None:
        cfg = best_config("freq_outer", (f, k, max(n, nb)), a.dtype)
        tk = cfg["tk"] if tk is None else tk
        tn = cfg["tn"] if tn is None else tn
    npad = next_multiple(max(n, nb), LANE)
    tn = min(tn, npad)
    tk = min(tk, next_multiple(k, SUBLANE))
    kp = next_multiple(k, tk)
    a = pad_axis(pad_axis(a, 1, kp), 2, npad)
    b = pad_axis(pad_axis(b, 1, kp), 2, npad)
    grid = (f, npad // tn, kp // tk)
    out = pl.pallas_call(
        _fo_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tk, npad), lambda ff, j, kk: (ff, kk, 0)),
            pl.BlockSpec((1, tk, tn), lambda ff, j, kk: (ff, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, npad, tn), lambda ff, j, kk: (ff, 0, j)),
        out_shape=jax.ShapeDtypeStruct((f, npad, npad), jnp.float32),
        interpret=INTERPRET,
    )(a.astype(jnp.float32), b.astype(jnp.float32))
    return out[:, :n, :nb]


# ---------------------------------------------------------------------------
# freq_mat: Y[f] = a[f] @ m[f]   (F, K, N) x (F, N, N2) -> (F, K, N2)
# ---------------------------------------------------------------------------


def _fm_kernel(a_ref, m_ref, o_ref):
    o_ref[0] = jnp.dot(a_ref[0], m_ref[0], preferred_element_type=jnp.float32)


def _freq_mat_raw(a, m, tk=None):
    f, k, n = a.shape
    fm, nm, n2 = m.shape
    assert f == fm and n == nm, (a.shape, m.shape)
    if tk is None:
        tk = best_config("freq_mat", (f, k, n, n2), a.dtype)["tk"]
    npad = next_multiple(n, LANE)
    n2pad = next_multiple(n2, LANE)
    tk = min(tk, next_multiple(k, SUBLANE))
    kp = next_multiple(k, tk)
    a = pad_axis(pad_axis(a, 1, kp), 2, npad)
    m = pad_axis(pad_axis(m, 1, npad), 2, n2pad)
    grid = (f, kp // tk)
    out = pl.pallas_call(
        _fm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tk, npad), lambda ff, kk: (ff, kk, 0)),
            pl.BlockSpec((1, npad, n2pad), lambda ff, kk: (ff, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tk, n2pad), lambda ff, kk: (ff, kk, 0)),
        out_shape=jax.ShapeDtypeStruct((f, kp, n2pad), jnp.float32),
        interpret=INTERPRET,
    )(a.astype(jnp.float32), m.astype(jnp.float32))
    return out[:, :k, :n2]


@jax.custom_vjp
def freq_outer(a, b):
    """G[f] = a[f]^T @ b[f]."""
    return _freq_outer_raw(a, b)


def _fo_fwd(a, b):
    return _freq_outer_raw(a, b), (a, b)


def _fo_bwd(res, g):
    a, b = res
    # dA[f] = b[f] @ g[f]^T ; dB[f] = a[f] @ g[f]
    da = _freq_mat_raw(b, jnp.swapaxes(g, 1, 2))
    db = _freq_mat_raw(a, g)
    return da.astype(a.dtype), db.astype(b.dtype)


freq_outer.defvjp(_fo_fwd, _fo_bwd)


@jax.custom_vjp
def freq_mat(a, m):
    """Y[f] = a[f] @ m[f]."""
    return _freq_mat_raw(a, m)


def _fm_fwd(a, m):
    return _freq_mat_raw(a, m), (a, m)


def _fm_bwd(res, g):
    a, m = res
    da = _freq_mat_raw(g, jnp.swapaxes(m, 1, 2))
    dm = _freq_outer_raw(a, g)
    return da.astype(a.dtype), dm.astype(m.dtype)


freq_mat.defvjp(_fm_fwd, _fm_bwd)
