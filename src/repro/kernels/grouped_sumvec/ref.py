"""Pure-jnp oracle for the grouped sumvec regularizer.

Deliberately *independent* of repro.core: builds the full cross-correlation
matrix C = (1/scale) Z1^T Z2, extracts every b x b block, computes each
block's summary vector by explicit wrapped-diagonal sums (paper Eq. 5), and
evaluates Eq. 13 term-by-term.  O(n d^2) — used only to validate kernels.
"""

from __future__ import annotations

import jax.numpy as jnp


def _sumvec_matrix(c):
    b = c.shape[-1]
    i = jnp.arange(b)[:, None]
    j = jnp.arange(b)[None, :]
    cols = (i + j) % b
    return jnp.sum(c[j, cols], axis=-1)


def grouped_sumvec_ref(z1, z2, block_size, scale=1.0):
    """Returns (nb, nb, b) time-domain summary vectors of every block."""
    n, d = z1.shape
    rem = (-d) % block_size
    z1 = jnp.pad(z1.astype(jnp.float32), ((0, 0), (0, rem)))
    z2 = jnp.pad(z2.astype(jnp.float32), ((0, 0), (0, rem)))
    c = (z1.T @ z2) / scale
    dp = c.shape[-1]
    nb = dp // block_size
    blocks = c.reshape(nb, block_size, nb, block_size).transpose(0, 2, 1, 3)
    out = jnp.zeros((nb, nb, block_size), jnp.float32)
    for i in range(nb):
        for j in range(nb):
            out = out.at[i, j].set(_sumvec_matrix(blocks[i, j]))
    return out


def r_sum_grouped_ref(z1, z2, block_size, q=2, scale=1.0):
    """Eq. (13) from the explicit matrix route."""
    sv = grouped_sumvec_ref(z1, z2, block_size, scale)
    nb = sv.shape[0]
    vals = jnp.abs(sv) if q == 1 else sv**2
    total = jnp.sum(vals)
    diag_zeroth = jnp.sum(jnp.diagonal(vals[..., 0]))
    return total - diag_zeroth


def r_sum_ref(z1, z2, q=2, scale=1.0):
    """Ungrouped Eq. (6) oracle (single block of size d)."""
    n, d = z1.shape
    c = (z1.astype(jnp.float32).T @ z2.astype(jnp.float32)) / scale
    sv = _sumvec_matrix(c)
    tail = sv[1:]
    return jnp.sum(jnp.abs(tail)) if q == 1 else jnp.sum(tail**2)
