from repro.kernels.grouped_sumvec.ops import (
    r_sum_kernel,
    grouped_frequency_accumulator_kernel,
    block_dft,
)
from repro.kernels.grouped_sumvec.ref import r_sum_grouped_ref, r_sum_ref, grouped_sumvec_ref
