"""jit'd wrappers for the grouped-sumvec Pallas kernels.

Pipeline (all MXU work, fully differentiable — every Pallas primitive carries
a custom_vjp whose backward is the same kernels):

  Z (n, d) --blockify--> (n, nb, b)
    --pmatmul with [Cr | Ci] (block DFT)--> F_r, F_i (n, nb, nf)
    --transpose--> (nf, n, nb)
    --freq_outer x2--> G_r, G_i (nf, nb, nb)      # "compressed outer product"
    --q=2: Parseval in jnp (O(nb^2 nf));  q=1: pmatmul with synthesis basis

Complexity: O(n d b) for the DFT + O(n (d/b)^2 b) for the pairwise stage
— the paper's O((n d^2 / b) log b) with log b traded for an MXU-resident b.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.grouped_sumvec import kernel as K
from repro.kernels.pallas_utils import dft_matrices, irfft_basis
from repro.tune import space as tune_space

Array = jax.Array


def auto_block_size(d: int, prefer: int = 128) -> int:
    """A tuned default block size b for width d: the largest legal candidate
    <= ``prefer``.

    The paper (Fig. 3) finds b = 128 is the accuracy sweet spot — also
    exactly one MXU tile; widths below ``prefer`` get b = d (ungrouped,
    Eq. 6).  Note b is part of the LOSS definition — this helper is for
    call sites choosing a b (the CLI pre-tuner, configs), never silently
    applied inside ``r_sum_kernel``.
    """
    legal = tune_space.grouped_block_size_candidates(d)
    return max(b for b in legal if b <= prefer)


def _blockify(z: Array, b: int) -> Array:
    n, d = z.shape
    rem = (-d) % b
    if rem:
        z = jnp.pad(z, ((0, 0), (0, rem)))
    return z.reshape(n, -1, b)


def block_dft(z: Array, b: int) -> tuple[Array, Array]:
    """Per-block rfft of (n, d) via one MXU matmul. Returns (nf, n, nb) x2."""
    zb = _blockify(z.astype(jnp.float32), b)
    n, nb, _ = zb.shape
    nf = b // 2 + 1
    cr, ci = dft_matrices(b)
    basis = jnp.concatenate([cr, ci], axis=1)  # (b, 2 nf)
    f = K.pmatmul(zb.reshape(n * nb, b), basis)  # (n*nb, 2 nf)
    f = f.reshape(n, nb, 2 * nf)
    fr = jnp.transpose(f[..., :nf], (2, 0, 1))  # (nf, n, nb)
    fi = jnp.transpose(f[..., nf:], (2, 0, 1))
    return fr, fi


def grouped_frequency_accumulator_kernel(
    z1: Array, z2: Array, block_size: int
) -> tuple[Array, Array]:
    """G[i,j,f] = sum_k conj(F1[k,i,f]) F2[k,j,f], returned as (nf, nb, nb)
    real/imag pair.  Matches core.sumvec.grouped_frequency_accumulator
    (transposed to frequency-major layout)."""
    b = int(block_size)
    f1r, f1i = block_dft(z1, b)
    f2r, f2i = block_dft(z2, b)
    # G_r = F1r^T F2r + F1i^T F2i ; G_i = F1r^T F2i - F1i^T F2r  (per f)
    a_r = jnp.concatenate([f1r, f1i], axis=1)
    b_r = jnp.concatenate([f2r, f2i], axis=1)
    g_r = K.freq_outer(a_r, b_r)
    a_i = jnp.concatenate([f1r, -f1i], axis=1)
    b_i = jnp.concatenate([f2i, f2r], axis=1)
    g_i = K.freq_outer(a_i, b_i)
    return g_r, g_i


def _parseval_weights(b: int) -> Array:
    nf = b // 2 + 1
    w = jnp.full((nf,), 2.0, jnp.float32).at[0].set(1.0)
    if b % 2 == 0:
        w = w.at[-1].set(1.0)
    return w


@functools.partial(jax.jit, static_argnames=("block_size", "q", "scale"))
def r_sum_kernel(
    z1: Array,
    z2: Array,
    *,
    block_size: Optional[int],
    q: int = 2,
    scale: Optional[float] = None,
) -> Array:
    """Eq. (13) (or Eq. 6 when block covers d) through the Pallas pipeline."""
    d = z1.shape[-1]
    b = int(block_size) if block_size is not None else d
    b = min(b, d)
    s = 1.0 if scale is None else float(scale)
    g_r, g_i = grouped_frequency_accumulator_kernel(z1, z2, b)
    g_r = g_r / s
    g_i = g_i / s
    nf, nb, _ = g_r.shape
    w = _parseval_weights(b)[:, None, None]
    eye = jnp.eye(nb, dtype=jnp.float32)
    if q == 2:
        sq = jnp.sum(w * (g_r**2 + g_i**2), axis=0) / b  # (nb, nb)
        s0 = jnp.sum(w * g_r, axis=0) / b
        return jnp.sum(sq) - jnp.sum(eye * s0**2)
    # q = 1: synthesize the time-domain summary vectors with one more matmul.
    br, bi = irfft_basis(b)  # (nf, b) each
    gr_flat = jnp.transpose(g_r, (1, 2, 0)).reshape(nb * nb, nf)
    gi_flat = jnp.transpose(g_i, (1, 2, 0)).reshape(nb * nb, nf)
    sv = K.pmatmul(gr_flat, br) + K.pmatmul(gi_flat, bi)  # (nb*nb, b)
    sv = sv.reshape(nb, nb, b)
    full = jnp.sum(jnp.abs(sv), axis=-1)
    return jnp.sum(full) - jnp.sum(eye * jnp.abs(sv[..., 0]))
