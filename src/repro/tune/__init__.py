"""repro.tune — autotuning + kernel-config dispatch for the Pallas kernels.

Layers (see the module docstrings for detail):

  * ``space``    — legal candidate enumeration per kernel (lane/sublane
                   alignment, VMEM budget, four-step factorization plans),
  * ``cost``     — analytic / compiled-HLO / measured cost tiers,
  * ``cache``    — persistent JSON cache keyed by (kernel, padded shape,
                   dtype) per backend, schema-versioned,
  * ``dispatch`` — ``best_config`` consulted by every kernel wrapper
                   (override > memo > disk cache > analytic search),
  * ``tuner``    — offline search (``tune``), used by the CLI pre-tuner
                   ``python -m repro.tune.cli`` and benchmarks.
"""

from repro.tune.dispatch import (
    best_config,
    best_impl,
    canonical_shape,
    clear_memory_cache,
    clear_override,
    override,
    set_override,
)
from repro.tune.space import (
    KERNELS,
    VMEM_BUDGET_BYTES,
    candidates,
    default_config,
    grouped_block_size_candidates,
    is_legal,
    vmem_bytes,
)


def tune(*args, **kwargs):
    """Lazy proxy for :func:`repro.tune.tuner.tune` (keeps kernel imports
    out of this package's import time — kernels themselves import us)."""
    from repro.tune import tuner

    return tuner.tune(*args, **kwargs)


__all__ = [
    "best_config",
    "best_impl",
    "canonical_shape",
    "candidates",
    "clear_memory_cache",
    "clear_override",
    "default_config",
    "grouped_block_size_candidates",
    "is_legal",
    "KERNELS",
    "override",
    "set_override",
    "tune",
    "vmem_bytes",
    "VMEM_BUDGET_BYTES",
]
